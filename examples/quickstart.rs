//! Quickstart: train a PQDTW quantizer, encode a dataset, compute
//! approximate distances three ways, and compare against true DTW.
//!
//! Run: `cargo run --release --example quickstart`

use pqdtw::data::random_walk::RandomWalks;
use pqdtw::distance::dtw::dtw;
use pqdtw::pq::quantizer::{PqConfig, PrealignConfig, ProductQuantizer};

fn main() -> anyhow::Result<()> {
    // 1. A toy database: 200 random walks of length 128.
    let db = RandomWalks::new(42).generate(200, 128);
    println!("database: {} series of length {}", db.n_series(), db.len);

    // 2. Train the product quantizer (Algorithm 1): M=4 subspaces,
    //    K=32 centroids, 10% warping window, MODWT pre-alignment.
    let cfg = PqConfig {
        n_subspaces: 4,
        codebook_size: 32,
        window_frac: 0.1,
        prealign: Some(PrealignConfig { level: 2, tail_frac: 0.15 }),
        ..Default::default()
    };
    let pq = ProductQuantizer::train(&db, &cfg, 7)?;
    println!(
        "trained: M={} K={} L={} window={:?} (pre-aligned, tail={})",
        cfg.n_subspaces,
        pq.codebook.k,
        pq.codebook.sub_len,
        pq.codebook.window,
        pq.segmenter.tail
    );

    // 3. Encode the database (Algorithm 2). Each series becomes M small
    //    integers — the §3.4 memory model quantifies the win.
    let enc = pq.encode_dataset(&db);
    let mm = pq.memory_model();
    println!(
        "encoded {} series; compression {:.1}x ({} -> {} bits/series)",
        enc.n(),
        mm.compression_factor,
        mm.raw_bits_per_series,
        mm.code_bits_per_series
    );
    let st = enc.stats;
    println!(
        "encode work: {} candidates, {:.0}% pruned by LB cascade",
        st.candidates(),
        100.0 * (st.pruned_kim + st.pruned_keogh) as f64 / st.candidates() as f64
    );

    // 4. Distances. Symmetric: O(M) table lookups.
    let d_sym = pq.symmetric_distance(enc.code(0), enc.code(1));
    // Keogh-patched symmetric: collision-safe variant for clustering.
    let d_patched = pq.patched_distance(&enc, 0, 1);
    // Asymmetric: query stays raw; one M×K table per query, then O(M).
    let table = pq.asymmetric_table(db.row(0));
    let d_asym = pq.asymmetric_distance(&table, enc.code(1));
    // Ground truth.
    let d_true = dtw(db.row(0), db.row(1), None);
    println!("\ndistance(series 0, series 1):");
    println!("  symmetric  : {d_sym:.4}");
    println!("  patched    : {d_patched:.4}");
    println!("  asymmetric : {d_asym:.4}");
    println!("  true DTW   : {d_true:.4}");

    // 5. A 1-NN query: nearest database series to a fresh walk.
    let query_set = RandomWalks::new(1234).generate(1, 128);
    let q = query_set.row(0);
    let table = pq.asymmetric_table(q);
    let (best, d) = (0..enc.n())
        .map(|j| (j, pq.asymmetric_distance(&table, enc.code(j))))
        .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
        .unwrap();
    println!("\n1-NN of fresh query: series {best} at approx distance {d:.4}");
    println!("   (exact DTW to it: {:.4})", dtw(q, db.row(best), None));
    Ok(())
}
