//! Hierarchical clustering with PQDTW vs raw measures (paper §6.3):
//! complete-linkage agglomerative clustering of a test split, scored by
//! Rand index against the class labels.
//!
//! Run: `cargo run --release --example clustering [-- --dataset Seasonal]`

use std::time::Instant;

use pqdtw::cli::Args;
use pqdtw::cluster::{agglomerative, compact_labels, rand_index, Linkage};
use pqdtw::core::matrix::CondensedMatrix;
use pqdtw::data::ucr_like::ucr_like_by_name;
use pqdtw::distance::measure::Measure;
use pqdtw::eval::report::{fmt_f, Table};
use pqdtw::pq::quantizer::{PqConfig, PrealignConfig, ProductQuantizer};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let name = args.get("dataset", "Seasonal");
    let seed = args.get_parsed("seed", 23u64);
    let tt = ucr_like_by_name(&name, seed)
        .ok_or_else(|| anyhow::anyhow!("unknown dataset {name}"))?;
    let test = &tt.test;
    let n = test.n_series();
    let k = test.classes().len();
    let truth = compact_labels(&test.labels);
    println!("dataset {name}: clustering {n} test series into k={k}\n");

    let mut table = Table::new(
        &format!("complete-linkage clustering on {name}"),
        &["measure", "RI", "matrix time (ms)", "n_dist"],
    );

    // Raw measures: full pairwise matrix (no LB pruning possible — the
    // paper's point about why clustering hurts).
    for measure in [
        Measure::Euclidean,
        Measure::Dtw,
        Measure::CDtw { window_frac: 0.10 },
        Measure::Sbd,
    ] {
        let t0 = Instant::now();
        let m = CondensedMatrix::build(n, |i, j| measure.dist(test.row(i), test.row(j)));
        let dt = t0.elapsed();
        let labels = agglomerative(&m, Linkage::Complete).cut(k);
        table.add_row(vec![
            measure.name(),
            fmt_f(rand_index(&labels, &truth), 4),
            fmt_f(dt.as_secs_f64() * 1e3, 1),
            format!("{}", m.n_pairs()),
        ]);
    }

    // PQDTW: train on the training split, encode the test split once,
    // then the pairwise matrix is O(M) per pair via the LUT (with the
    // Keogh patch for same-code collisions, §4.2).
    let cfg = PqConfig {
        n_subspaces: 4,
        codebook_size: 64,
        window_frac: 0.1,
        prealign: Some(PrealignConfig { level: 2, tail_frac: 0.15 }),
        ..Default::default()
    };
    let pq = ProductQuantizer::train(&tt.train, &cfg, seed)?;
    let t_enc = Instant::now();
    let enc = pq.encode_dataset(test);
    let enc_dt = t_enc.elapsed();
    let t0 = Instant::now();
    let m = CondensedMatrix::build(n, |i, j| pq.patched_distance(&enc, i, j));
    let dt = t0.elapsed();
    let labels = agglomerative(&m, Linkage::Complete).cut(k);
    table.add_row(vec![
        "PQDTW".into(),
        fmt_f(rand_index(&labels, &truth), 4),
        fmt_f(dt.as_secs_f64() * 1e3, 1),
        format!("{}", m.n_pairs()),
    ]);

    println!("{}", table.render());
    println!("PQDTW one-time encode of the test split: {:.1} ms", enc_dt.as_secs_f64() * 1e3);

    // Also show all three linkage criteria for PQDTW.
    let mut l_table = Table::new("PQDTW by linkage", &["linkage", "RI"]);
    for (nm, linkage) in [
        ("single", Linkage::Single),
        ("average", Linkage::Average),
        ("complete", Linkage::Complete),
    ] {
        let labels = agglomerative(&m, linkage).cut(k);
        l_table.add_row(vec![nm.into(), fmt_f(rand_index(&labels, &truth), 4)]);
    }
    println!("\n{}", l_table.render());
    Ok(())
}
