//! End-to-end serving driver — proves all three layers compose, and
//! that serving is build-once / serve-many.
//!
//! Phase one is the cold-start demo: train an engine (the expensive
//! offline build), persist it with [`Engine::save`], reopen it with
//! [`Engine::open`], and verify the reloaded engine answers
//! bit-identically — then serve the whole run *from the loaded state*,
//! never from the trainer. Opening is pure deserialization, so process
//! start-up cost scales with load, not with training.
//!
//! The serving run starts the threaded coordinator with dynamic
//! batching, drives concurrent clients against it, and reports
//! latency/throughput percentiles. The top-k phase exercises the three
//! serving modes — exhaustive scan, IVF-probed, and DTW re-ranked —
//! and reports the recall-vs-`nprobe` trade-off: probing fewer coarse
//! cells scans a smaller fraction of the database (lower latency) at
//! the cost of recall against the exhaustive scan, while probing all
//! `nlist` cells reproduces it bit-for-bit. The re-ranked mode rescores
//! the PQ candidates with true windowed DTW, so its distances are
//! exact. With `--features pjrt` (and `make artifacts`), queries are
//! additionally cross-checked through the AOT-compiled JAX/Pallas
//! encode graph executed via PJRT — Python is never in the loop.
//!
//! Run: `cargo run --release --example serving`

use std::sync::Arc;
use std::time::Instant;

use pqdtw::cli::Args;
use pqdtw::coordinator::{BatcherConfig, Engine, Request, Response, Service, ServiceConfig};
use pqdtw::data::ucr_like::ucr_like_by_name;
use pqdtw::nn::ivf::CoarseMetric;
use pqdtw::nn::knn::PqQueryMode;
use pqdtw::pq::quantizer::{PqConfig, PqMetric};
#[cfg(feature = "pjrt")]
use pqdtw::pq::quantizer::ProductQuantizer;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let seed = args.get_parsed("seed", 5u64);
    let n_clients = args.get_parsed("clients", 4usize);
    let per_client = args.get_parsed("requests", 100usize);
    let n_workers = args.get_parsed("workers", 2usize);
    let k = args.get_parsed("topk", 5usize);

    // SpikePosition has length 100 = 4 × 25: matches the AOT artifact
    // variant (M=4, K=16, L=25, w=5) lowered by python/compile/aot.py.
    let tt = ucr_like_by_name("SpikePosition", seed).unwrap();
    let cfg = PqConfig {
        n_subspaces: 4,
        codebook_size: 16,
        window_frac: 0.2,
        metric: PqMetric::Dtw,
        ..Default::default()
    };
    println!("building engine on {} ({} series)…", tt.name, tt.train.n_series());
    let t0 = Instant::now();
    let mut trained = Engine::build(&tt.train, &cfg, seed)?;
    trained.enable_ivf(8, CoarseMetric::Dtw { window: trained.full_window() }, seed);
    let t_build = t0.elapsed();
    let nlist = trained.ivf.as_ref().map(|ivf| ivf.nlist()).unwrap_or(1);

    // --- build-once / serve-many: persist, reload, serve from disk ---
    let index_path = std::env::temp_dir()
        .join(format!("pqdtw_serving_demo_{}.pqx", std::process::id()));
    trained.save(&index_path)?;
    let file_bytes = std::fs::metadata(&index_path)?.len();
    let t0 = Instant::now();
    let mut engine = Engine::open(&index_path)?;
    let t_open = t0.elapsed();
    engine.set_scan_threads(2);
    // The reloaded engine must answer bit-identically to the trainer.
    let probe = Request::TopKQuery {
        series: tt.test.row(0).to_vec(),
        k,
        mode: PqQueryMode::Asymmetric,
        nprobe: None,
        rerank: None,
    };
    assert_eq!(
        trained.handle(&probe),
        engine.handle(&probe),
        "loaded index must answer bit-identically to the trained engine"
    );
    drop(trained);
    std::fs::remove_file(&index_path).ok();
    println!(
        "cold start: train+index {t_build:?} vs open-from-disk {t_open:?} \
         ({:.0}× faster; {:.1} KB on disk) — everything below serves from the loaded state",
        t_build.as_secs_f64() / t_open.as_secs_f64().max(1e-9),
        file_bytes as f64 / 1024.0
    );
    let engine = Arc::new(engine);

    // --- PJRT cross-check: the same encode through the AOT artifact ---
    #[cfg(feature = "pjrt")]
    {
        use pqdtw::runtime::artifacts::Manifest;
        use pqdtw::runtime::encoder::PjrtEncoder;
        let dir = Manifest::default_dir();
        if dir.join("manifest.tsv").exists() {
            let manifest = Manifest::load(&dir)?;
            let pq2 = ProductQuantizer::train(&tt.train, &cfg, seed)?;
            let mut pjrt = PjrtEncoder::new(&pq2, &manifest)?;
            let mut agree = 0;
            let n_check = 16.min(tt.test.n_series());
            let t0 = Instant::now();
            for i in 0..n_check {
                let via_pjrt = pjrt.encode(&pq2, tt.test.row(i))?;
                let (native, _, _) = pq2.encode(tt.test.row(i));
                if via_pjrt == native {
                    agree += 1;
                }
            }
            println!(
                "PJRT cross-check: {agree}/{n_check} encodes identical to native ({:?} total, AOT graph M=4 K=16 L=25)",
                t0.elapsed()
            );
        } else {
            println!("PJRT cross-check skipped: run `make artifacts` first");
        }
    }
    #[cfg(not(feature = "pjrt"))]
    println!("PJRT cross-check skipped (build with --features pjrt)");

    // --- the serving run: mixed 1-NN load from concurrent clients ---
    let svc = Arc::new(Service::start(
        Arc::clone(&engine),
        ServiceConfig {
            n_workers,
            batcher: BatcherConfig {
                max_batch: 16,
                max_delay: std::time::Duration::from_millis(1),
            },
        },
    ));
    println!(
        "service up: {n_workers} workers, {n_clients} clients × {per_client} requests\n"
    );

    let test = Arc::new(tt.test);
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for c in 0..n_clients {
        let svc = Arc::clone(&svc);
        let test = Arc::clone(&test);
        handles.push(std::thread::spawn(move || {
            let mut correct = 0usize;
            for i in 0..per_client {
                let idx = (c * per_client + i) % test.n_series();
                let mode = if i % 2 == 0 { PqQueryMode::Symmetric } else { PqQueryMode::Asymmetric };
                match svc.call(Request::NnQuery {
                    series: test.row(idx).to_vec(),
                    mode,
                    nprobe: None,
                }) {
                    Response::Nn { label, .. } => {
                        if label == Some(test.label(idx)) {
                            correct += 1;
                        }
                    }
                    other => panic!("unexpected response: {other:?}"),
                }
            }
            correct
        }));
    }
    let correct: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
    let wall = t0.elapsed();
    let m = svc.metrics();

    let total = (n_clients * per_client) as f64;
    println!("== serving results (1-NN load) ==");
    println!("requests      : {}", m.requests);
    println!("wall time     : {wall:?}");
    println!("throughput    : {:.0} req/s", total / wall.as_secs_f64());
    println!("mean latency  : {:.0} µs", m.mean_latency_us);
    println!("p50 / p90 / p99 : ≤{} / ≤{} / ≤{} µs",
        m.percentile_us(0.50), m.percentile_us(0.90), m.percentile_us(0.99));
    println!("mean batch    : {:.2}", m.mean_batch_size);
    println!("errors        : {}", m.errors);
    println!("1-NN accuracy : {:.3} (vs labels, online queries)", correct as f64 / total);

    // --- top-k in three modes: the recall/latency dial ---
    println!("\n== top-k serving modes (k={k}, nlist={nlist}) ==");
    let n_queries = 40.min(test.n_series());
    // exhaustive truth, then probed at increasing nprobe, then re-ranked
    let mut truth = Vec::with_capacity(n_queries);
    for i in 0..n_queries {
        match svc.call(Request::TopKQuery {
            series: test.row(i).to_vec(),
            k,
            mode: PqQueryMode::Asymmetric,
            nprobe: None,
            rerank: None,
        }) {
            Response::TopK(hits) => truth.push(hits),
            other => panic!("unexpected response: {other:?}"),
        }
    }
    for nprobe in [1usize, (nlist / 4).max(1), nlist] {
        let mut overlap = 0usize;
        let t0 = Instant::now();
        for (i, want) in truth.iter().enumerate() {
            match svc.call(Request::TopKQuery {
                series: test.row(i).to_vec(),
                k,
                mode: PqQueryMode::Asymmetric,
                nprobe: Some(nprobe),
                rerank: None,
            }) {
                Response::TopK(hits) => {
                    if nprobe == nlist {
                        assert_eq!(&hits, want, "full probe must be bit-identical");
                    }
                    let t: std::collections::HashSet<usize> =
                        want.iter().map(|h| h.index).collect();
                    overlap += hits.iter().filter(|h| t.contains(&h.index)).count();
                }
                other => panic!("unexpected response: {other:?}"),
            }
        }
        println!(
            "nprobe {nprobe:>3}: recall@{k} {:.3}, mean latency {:.0} µs{}",
            overlap as f64 / (n_queries * k) as f64,
            1e6 * t0.elapsed().as_secs_f64() / n_queries as f64,
            if nprobe == nlist { "  (bit-identical to exhaustive ✓)" } else { "" },
        );
    }
    let t0 = Instant::now();
    for i in 0..n_queries {
        match svc.call(Request::TopKQuery {
            series: test.row(i).to_vec(),
            k,
            mode: PqQueryMode::Asymmetric,
            nprobe: None,
            rerank: Some(4 * k),
        }) {
            Response::TopK(hits) => assert!(hits.len() <= k),
            other => panic!("unexpected response: {other:?}"),
        }
    }
    println!(
        "re-ranked : exact DTW distances, mean latency {:.0} µs (depth {})",
        1e6 * t0.elapsed().as_secs_f64() / n_queries as f64,
        4 * k
    );

    let m = svc.metrics();
    println!("\nper-mode service counters:");
    for c in &m.per_class {
        if c.requests > 0 {
            println!("  {:<16} {:>6} reqs, mean {:.0} µs", c.class.name(), c.requests, c.mean_latency_us);
        }
    }
    Ok(())
}
