//! Remote serving demo: the build-once / serve-many split, over TCP.
//!
//! One process (here: one thread) cold-starts an engine from a saved
//! index file and exposes it on a loopback port; clients then run
//! top-k queries across the whole serving-mode dial — exhaustive,
//! IVF-probed, DTW re-ranked — over the wire, getting answers
//! bit-identical to the in-process engine. Run with:
//!
//! ```sh
//! cargo run --example remote_serving
//! ```

use std::sync::Arc;

use pqdtw::coordinator::{Engine, Request, Response, Service, ServiceConfig};
use pqdtw::data::random_walk::RandomWalks;
use pqdtw::net::{Client, ClientConfig, NetServer, ServerConfig};
use pqdtw::nn::ivf::CoarseMetric;
use pqdtw::nn::knn::PqQueryMode;
use pqdtw::pq::quantizer::PqConfig;

fn main() -> anyhow::Result<()> {
    // ---- build once -----------------------------------------------------
    let db = RandomWalks::new(42).generate(512, 96);
    let queries = RandomWalks::new(1042).generate(8, 96);
    let cfg = PqConfig {
        n_subspaces: 4,
        codebook_size: 16,
        window_frac: 0.1,
        ..Default::default()
    };
    let mut engine = Engine::build(&db, &cfg, 7)?;
    engine.enable_ivf(16, CoarseMetric::Dtw { window: engine.full_window() }, 7);
    let dir = std::env::temp_dir().join(format!("pqdtw_remote_demo_{}", std::process::id()));
    std::fs::create_dir_all(&dir)?;
    let index_path = dir.join("demo.pqx");
    engine.save(&index_path)?;
    println!(
        "built + saved index: {} series, {} bytes on disk",
        engine.n_items,
        std::fs::metadata(&index_path)?.len()
    );

    // ---- serve many -----------------------------------------------------
    // A serving process reopens the index (no retraining) and listens.
    let served = Arc::new(Engine::open(&index_path)?);
    let service = Arc::new(Service::start(Arc::clone(&served), ServiceConfig::default()));
    let server = NetServer::start("127.0.0.1:0", Arc::clone(&service), ServerConfig::default())?;
    let addr = server.local_addr().to_string();
    println!("serving on {addr}");

    // ---- query remotely -------------------------------------------------
    let mut client = Client::connect(&addr, ClientConfig::default())?;
    client.ping()?;
    let nlist = served.ivf.as_ref().map(|ivf| ivf.nlist()).unwrap_or(1);
    let q = queries.row(0);
    for (label, nprobe, rerank) in [
        ("exhaustive           ", None, None),
        ("probed (nprobe=4)    ", Some(4usize), None),
        ("probed = exhaustive  ", Some(nlist), None),
        ("reranked (depth 20)  ", None, Some(20usize)),
    ] {
        let hits = client.topk(q, 5, PqQueryMode::Asymmetric, nprobe, rerank)?;
        // The remote answer is bit-identical to asking the engine
        // in-process — the wire carries f64 bit patterns.
        let local = served.handle(&Request::TopKQuery {
            series: q.to_vec(),
            k: 5,
            mode: PqQueryMode::Asymmetric,
            nprobe,
            rerank,
        });
        match local {
            Response::TopK(local_hits) => assert_eq!(hits, local_hits),
            other => anyhow::bail!("unexpected local response {other:?}"),
        }
        println!(
            "{label} top-5: {}",
            hits.iter()
                .map(|h| format!("#{}:{:.3}", h.index, h.distance))
                .collect::<Vec<_>>()
                .join(" ")
        );
    }

    // Several clients at once: their requests meet in the same dynamic
    // batcher, so concurrency turns into batching, not contention.
    let mut handles = Vec::new();
    for t in 0..4usize {
        let addr = addr.clone();
        let q = queries.row((t + 1) % queries.n_series()).to_vec();
        handles.push(std::thread::spawn(move || {
            let mut c = Client::connect(&addr, ClientConfig::default()).unwrap();
            for _ in 0..16 {
                c.topk(&q, 3, PqQueryMode::Asymmetric, Some(4), None).unwrap();
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }

    let stats = client.stats()?;
    println!(
        "server stats: {} requests, mean batch {:.1}, p50 ≤{}µs, p99 ≤{}µs",
        stats.requests, stats.mean_batch_size, stats.p50_us, stats.p99_us
    );
    for c in stats.per_class.iter().filter(|c| c.requests > 0) {
        println!("  {:<16} {:>4} reqs, p99 ≤{}µs", c.name, c.requests, c.p99_us);
    }

    // ---- drain ----------------------------------------------------------
    client.shutdown()?;
    server.wait();
    println!("server drained cleanly");
    std::fs::remove_dir_all(&dir).ok();
    Ok(())
}
