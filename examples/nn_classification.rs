//! 1-NN classification across all the paper's measures on one dataset —
//! a single-dataset slice of Table 1.
//!
//! Run: `cargo run --release --example nn_classification [-- --dataset CBF]`

use std::time::Instant;

use pqdtw::cli::Args;
use pqdtw::data::ucr_like::ucr_like_by_name;
use pqdtw::distance::measure::Measure;
use pqdtw::eval::report::{fmt_f, Table};
use pqdtw::eval::search::{tune_pq, SearchSpace};
use pqdtw::nn::knn::{nn_classify_pq, nn_classify_raw, nn_classify_sax, PqQueryMode};
use pqdtw::pq::quantizer::{PqConfig, PqMetric, ProductQuantizer};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let name = args.get("dataset", "CBF");
    let seed = args.get_parsed("seed", 17u64);
    let tt = ucr_like_by_name(&name, seed)
        .ok_or_else(|| anyhow::anyhow!("unknown dataset {name}"))?;
    println!(
        "dataset {name}: {} train / {} test, length {}, {} classes\n",
        tt.train.n_series(),
        tt.test.n_series(),
        tt.train.len,
        tt.train.classes().len()
    );

    let mut table = Table::new(
        &format!("1-NN on {name}"),
        &["measure", "error", "time (ms)"],
    );

    // Raw-data elastic + lock-step measures.
    for measure in [
        Measure::Euclidean,
        Measure::Dtw,
        Measure::CDtw { window_frac: 0.05 },
        Measure::CDtw { window_frac: 0.10 },
        Measure::Sbd,
    ] {
        let t0 = Instant::now();
        let (err, _) = nn_classify_raw(&tt.train, &tt.test, measure);
        table.add_row(vec![
            measure.name(),
            fmt_f(err, 4),
            fmt_f(t0.elapsed().as_secs_f64() * 1e3, 1),
        ]);
    }

    // SAX baseline (α=4, segments of 0.2·L — the paper's setting).
    let t0 = Instant::now();
    let (err, _) = nn_classify_sax(&tt.train, &tt.test, 4, 0.2);
    table.add_row(vec!["SAX".into(), fmt_f(err, 4), fmt_f(t0.elapsed().as_secs_f64() * 1e3, 1)]);

    // PQ_ED baseline.
    let cfg_ed = PqConfig {
        n_subspaces: 4,
        codebook_size: 64,
        metric: PqMetric::Euclidean,
        ..Default::default()
    };
    let pq_ed = ProductQuantizer::train(&tt.train, &cfg_ed, seed)?;
    let enc_ed = pq_ed.encode_dataset(&tt.train);
    let t0 = Instant::now();
    let (err, _) = nn_classify_pq(&pq_ed, &enc_ed, &tt.test, PqQueryMode::Asymmetric);
    table.add_row(vec!["PQ_ED".into(), fmt_f(err, 4), fmt_f(t0.elapsed().as_secs_f64() * 1e3, 1)]);

    // PQDTW: a short hyper-parameter search on the training set (the
    // paper's protocol, at a small budget), then test evaluation.
    let space = SearchSpace { codebook_size: 64, ..Default::default() };
    let budget = args.get_parsed("budget", 8usize);
    let search = tune_pq(&tt.train, &space, budget, 2, seed);
    println!(
        "PQDTW tuned over {} configs: M={}, window={:.2}, prealign={:?} (cv err {:.3})",
        search.evaluated,
        search.config.n_subspaces,
        search.config.window_frac,
        search.config.prealign,
        search.cv_error
    );
    let pq = ProductQuantizer::train(&tt.train, &search.config, seed)?;
    let enc = pq.encode_dataset(&tt.train);
    let t0 = Instant::now();
    let (err, _) = nn_classify_pq(&pq, &enc, &tt.test, PqQueryMode::Asymmetric);
    table.add_row(vec![
        "PQDTW (asym)".into(),
        fmt_f(err, 4),
        fmt_f(t0.elapsed().as_secs_f64() * 1e3, 1),
    ]);
    let t0 = Instant::now();
    let (err, _) = nn_classify_pq(&pq, &enc, &tt.test, PqQueryMode::Symmetric);
    table.add_row(vec![
        "PQDTW (sym)".into(),
        fmt_f(err, 4),
        fmt_f(t0.elapsed().as_secs_f64() * 1e3, 1),
    ]);

    println!("\n{}", table.render());
    println!("note: PQ rows exclude the one-time train+encode cost, which is");
    println!("amortized over all future queries (paper §3.2).");
    Ok(())
}
