"""Layer-2 correctness: the JAX graphs that get AOT-lowered for Rust."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.model import adc_table, encode_series, pairwise_symmetric
from compile.kernels.ref import batched_dtw_sq_ref

COMMON = dict(max_examples=15, deadline=None)


def _mk(rng, m, k, length):
    subs = rng.normal(size=(m, length)).astype(np.float32)
    books = rng.normal(size=(m, k, length)).astype(np.float32)
    return subs, books


@settings(**COMMON)
@given(
    m=st.integers(min_value=1, max_value=4),
    k=st.integers(min_value=1, max_value=10),
    length=st.integers(min_value=2, max_value=16),
    window=st.integers(min_value=1, max_value=16),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_adc_table_matches_ref(m, k, length, window, seed):
    rng = np.random.default_rng(seed)
    subs, books = _mk(rng, m, k, length)
    got = np.asarray(adc_table(subs, books, window=window))
    assert got.shape == (m, k)
    w = min(window, length)
    for i in range(m):
        want = batched_dtw_sq_ref(subs[i], books[i], w)
        assert_allclose(got[i], want, rtol=2e-4, atol=1e-4)


def test_encode_series_argmin_semantics():
    rng = np.random.default_rng(3)
    subs, books = _mk(rng, 3, 8, 12)
    codes, dists = encode_series(subs, books, window=4)
    codes, dists = np.asarray(codes), np.asarray(dists)
    assert codes.shape == (3,)
    assert codes.dtype == np.int32
    table = np.asarray(adc_table(subs, books, window=4))
    assert_allclose(dists, table.min(axis=1), rtol=1e-6)
    assert np.all(codes == table.argmin(axis=1))


def test_encode_exact_centroid_is_chosen():
    rng = np.random.default_rng(5)
    subs, books = _mk(rng, 2, 6, 10)
    # plant each subspace vector as centroid 4
    books[:, 4, :] = subs
    codes, dists = encode_series(subs, books, window=3)
    codes, dists = np.asarray(codes), np.asarray(dists)
    assert np.all(dists <= 1e-8)
    for m in range(2):
        # the winner must be at distance 0 (id 4 unless an exact tie)
        assert dists[m] == pytest.approx(0.0, abs=1e-8)


def test_pairwise_symmetric_matches_manual_gather():
    rng = np.random.default_rng(7)
    n, p, m, k = 5, 7, 3, 6
    lut = np.abs(rng.normal(size=(m, k, k))).astype(np.float32)
    # symmetrize with zero diagonal, like a real distance LUT
    lut = lut + lut.transpose(0, 2, 1)
    for mm in range(m):
        np.fill_diagonal(lut[mm], 0.0)
    cx = rng.integers(0, k, size=(n, m)).astype(np.int32)
    cy = rng.integers(0, k, size=(p, m)).astype(np.int32)
    got = np.asarray(pairwise_symmetric(jnp.array(cx), jnp.array(cy), jnp.array(lut)))
    assert got.shape == (n, p)
    for i in range(n):
        for j in range(p):
            want = np.sqrt(sum(lut[mm, cx[i, mm], cy[j, mm]] for mm in range(m)))
            assert got[i, j] == pytest.approx(want, rel=1e-6)


def test_pairwise_symmetric_zero_on_equal_codes():
    m, k = 4, 5
    lut = np.ones((m, k, k), dtype=np.float32)
    for mm in range(m):
        np.fill_diagonal(lut[mm], 0.0)
    codes = np.array([[1, 2, 3, 4]], dtype=np.int32)
    got = np.asarray(pairwise_symmetric(jnp.array(codes), jnp.array(codes), jnp.array(lut)))
    assert got[0, 0] == 0.0
