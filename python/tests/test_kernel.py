"""Layer-1 correctness: Pallas kernels vs the pure-numpy oracle.

Hypothesis sweeps shapes, windows and value ranges; every case asserts
allclose at float32 tolerance. This is the core correctness signal for
the compute layer that the Rust runtime executes via PJRT.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels.dtw_band import K_BLOCK, batched_dtw_sq
from compile.kernels.lb_keogh import batched_lb_keogh_sq
from compile.kernels.ref import (
    batched_dtw_sq_ref,
    dtw_sq_ref,
    envelope_ref,
    lb_keogh_sq_ref,
)

# Interpret-mode Pallas is slow; keep hypothesis cases bounded but varied.
COMMON = dict(max_examples=25, deadline=None)


def _series(rng: np.random.Generator, n: int, scale: float) -> np.ndarray:
    return (rng.normal(size=n) * scale).astype(np.float32)


@settings(**COMMON)
@given(
    length=st.integers(min_value=2, max_value=24),
    k=st.integers(min_value=1, max_value=12),
    window=st.one_of(st.none(), st.integers(min_value=1, max_value=24)),
    scale=st.sampled_from([0.1, 1.0, 10.0]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_dtw_kernel_matches_ref(length, k, window, scale, seed):
    rng = np.random.default_rng(seed)
    q = _series(rng, length, scale)
    c = np.stack([_series(rng, length, scale) for _ in range(k)])
    got = np.asarray(batched_dtw_sq(q, c, window))
    w = min(window, length) if window is not None else None
    want = batched_dtw_sq_ref(q, c, w)
    assert got.shape == (k,)
    assert got.dtype == np.float32
    assert_allclose(got, want, rtol=2e-4, atol=2e-4 * scale * scale)


@settings(**COMMON)
@given(
    length=st.integers(min_value=2, max_value=32),
    k=st.integers(min_value=1, max_value=20),
    window=st.integers(min_value=0, max_value=8),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_lb_keogh_kernel_matches_ref(length, k, window, seed):
    rng = np.random.default_rng(seed)
    q = _series(rng, length, 1.0)
    env = [envelope_ref(_series(rng, length, 1.0), window) for _ in range(k)]
    upper = np.stack([u for u, _ in env]).astype(np.float32)
    lower = np.stack([lo for _, lo in env]).astype(np.float32)
    got = np.asarray(batched_lb_keogh_sq(q, upper, lower))
    want = np.array([lb_keogh_sq_ref(q, upper[i], lower[i]) for i in range(k)])
    assert_allclose(got, want, rtol=1e-4, atol=1e-5)


@settings(**COMMON)
@given(
    length=st.integers(min_value=2, max_value=20),
    window=st.integers(min_value=1, max_value=20),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_lb_keogh_lower_bounds_dtw(length, window, seed):
    """Invariant: LB_Keogh(q, env(c, w)) <= DTW_w(q, c)."""
    rng = np.random.default_rng(seed)
    q = _series(rng, length, 1.0)
    c = _series(rng, length, 1.0)
    w = min(window, length)
    u, lo = envelope_ref(c, w)
    lb = lb_keogh_sq_ref(q, u, lo)
    d = dtw_sq_ref(q, c, w)
    assert lb <= d + 1e-5


def test_kernel_identical_series_zero():
    q = np.linspace(-1, 1, 16).astype(np.float32)
    c = np.stack([q, q + 1.0])
    got = np.asarray(batched_dtw_sq(q, c, 4))
    assert got[0] == pytest.approx(0.0, abs=1e-6)
    assert got[1] > 0.0


def test_kernel_window_monotonicity():
    rng = np.random.default_rng(7)
    q = _series(rng, 16, 1.0)
    c = np.stack([_series(rng, 16, 1.0) for _ in range(4)])
    prev = None
    for w in [1, 2, 4, 8, 16]:
        cur = np.asarray(batched_dtw_sq(q, c, w))
        if prev is not None:
            assert np.all(cur <= prev + 1e-4)
        prev = cur


def test_kernel_k_padding_exact_multiple_and_not():
    rng = np.random.default_rng(9)
    q = _series(rng, 10, 1.0)
    for k in [1, K_BLOCK - 1, K_BLOCK, K_BLOCK + 3, 3 * K_BLOCK]:
        c = np.stack([_series(rng, 10, 1.0) for _ in range(k)])
        got = np.asarray(batched_dtw_sq(q, c, 3))
        want = batched_dtw_sq_ref(q, c, 3)
        assert got.shape == (k,)
        assert_allclose(got, want, rtol=1e-4)


def test_kernel_float64_inputs_coerced():
    q = np.array([0.0, 1.0, 2.0], dtype=np.float64)
    c = np.array([[0.0, 1.0, 2.0]], dtype=np.float64)
    got = np.asarray(batched_dtw_sq(q, c, 1))
    assert got.dtype == np.float32
    assert got[0] == pytest.approx(0.0, abs=1e-7)


def test_kernel_rejects_mismatched_lengths():
    q = np.zeros(5, dtype=np.float32)
    c = np.zeros((2, 6), dtype=np.float32)
    with pytest.raises(AssertionError):
        batched_dtw_sq(q, c, 2)
