"""Cross-language golden tests.

The same inputs and expected values are asserted by rust/tests/golden.rs;
any drift between the Rust DTW/LB implementations, the Python reference
and the Pallas kernels shows up here or there.
"""

import numpy as np
from numpy.testing import assert_allclose

from compile.kernels.dtw_band import batched_dtw_sq
from compile.kernels.lb_keogh import batched_lb_keogh_sq
from compile.kernels.ref import dtw_sq_ref, envelope_ref, lb_keogh_sq_ref

# Shared fixtures (keep in sync with rust/tests/golden.rs).
GOLD_A = [0.3, -1.04, 0.75, 0.94, -1.95, -1.3, 0.13, -0.32, -0.02, -0.85]
GOLD_B = [0.88, 0.78, 0.07, 1.13, 0.47, -0.86, 0.37, -0.96, 0.88, -0.05]
# window -> accumulated squared DTW cost
GOLD_DTW_SQ = {0: 12.1145, 1: 5.4631, 2: 5.4631, 10: 4.2112}

GOLD_C = [1.0, -0.5, 2.5, 0.0, -1.5, 2.0, -0.5, 1.5]
GOLD_Q = [0.0, 2.0, -1.0, 3.0, 0.5, -2.0, 1.0, 0.0]
GOLD_ENV_W = 2
GOLD_ENV_UPPER = [2.5, 2.5, 2.5, 2.5, 2.5, 2.0, 2.0, 2.0]
GOLD_ENV_LOWER = [-0.5, -0.5, -1.5, -1.5, -1.5, -1.5, -1.5, -0.5]
GOLD_LB_SQ = 0.5


def test_ref_dtw_matches_golden():
    a, b = np.array(GOLD_A), np.array(GOLD_B)
    for w, want in GOLD_DTW_SQ.items():
        assert_allclose(dtw_sq_ref(a, b, w), want, rtol=1e-9)


def test_pallas_dtw_matches_golden():
    q = np.array(GOLD_A, dtype=np.float32)
    c = np.array([GOLD_B], dtype=np.float32)
    for w, want in GOLD_DTW_SQ.items():
        got = np.asarray(batched_dtw_sq(q, c, max(w, 1) if w == 0 else w))
        if w == 0:
            continue  # kernel clamps window to >= 1; skip the w=0 row
        assert_allclose(got[0], want, rtol=1e-5)


def test_envelope_and_lb_match_golden():
    u, lo = envelope_ref(np.array(GOLD_C), GOLD_ENV_W)
    assert_allclose(u, GOLD_ENV_UPPER)
    assert_allclose(lo, GOLD_ENV_LOWER)
    assert_allclose(lb_keogh_sq_ref(np.array(GOLD_Q), u, lo), GOLD_LB_SQ, rtol=1e-9)
    got = np.asarray(
        batched_lb_keogh_sq(
            np.array(GOLD_Q, dtype=np.float32),
            np.array([GOLD_ENV_UPPER], dtype=np.float32),
            np.array([GOLD_ENV_LOWER], dtype=np.float32),
        )
    )
    assert_allclose(got[0], GOLD_LB_SQ, rtol=1e-5)
