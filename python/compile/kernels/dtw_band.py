"""Layer-1 Pallas kernel: K-batched Sakoe-Chiba DTW via anti-diagonal
wavefront dynamic programming.

The encoding hot-spot of PQDTW is a 1-NN DTW query of one subspace vector
against all K centroids of a sub-codebook (paper Alg. 2). On TPU the
natural decomposition is:

- **grid over centroid blocks**: each program instance owns a (KB, L)
  block of the codebook, streamed HBM->VMEM once via BlockSpec;
- **anti-diagonal wavefront** inside the program: cells on one diagonal
  of the DP matrix have no mutual dependency, so each of the 2L-1 steps
  is a fully vectorized (KB, L) update on the VPU — the sequential
  dependence is only across diagonals, not across lanes;
- the Sakoe-Chiba band is a static mask (+inf outside), keeping all
  shapes static for AOT lowering.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; real-TPU performance is *estimated* in DESIGN.md §Perf.
Numerics are identical between the interpret path and the pure-jnp
reference (checked by pytest against kernels/ref.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["batched_dtw_sq", "K_BLOCK"]

# Centroids per program instance. 8 keeps the (KB, L) working set tiny
# (8 x 160 x 4 B = 5 KiB) while filling VPU sublanes.
K_BLOCK = 8

_INF = float("inf")  # python float: avoids capturing a traced constant


def _dtw_wavefront_kernel(q_ref, c_ref, o_ref, *, length: int, window: int):
    """One program: DTW of query (L,) vs a (KB, L) centroid block.

    DP matrix D[i, j]: i indexes the query, j the centroid. The diagonal
    d holds cells with i + j = d; the vector ``diag[b, i]`` stores
    D[i, d - i] for centroid b. Invalid cells (outside the matrix or the
    band) hold +inf, which makes every boundary case fall out of the
    same minimum.
    """
    L = length
    w = window
    q = q_ref[...].astype(jnp.float32)          # (L,)
    c = c_ref[...].astype(jnp.float32)          # (KB, L)
    kb = c.shape[0]

    # crev[b, x] = c[b, L-1-x]; rolling it by (d - (L-1)) aligns
    # crev[b, i + L-1-d] = c[b, d-i] with lane i.
    crev = jnp.flip(c, axis=1)
    ii = jnp.arange(L, dtype=jnp.int32)         # lane index i

    def diag_cost(d):
        shifted = jnp.roll(crev, d - (L - 1), axis=1)   # (KB, L): c[b, d-i]
        diff = q[None, :] - shifted
        return diff * diff

    def valid_mask(d):
        j = d - ii
        ok = (j >= 0) & (j <= L - 1)
        ok &= jnp.abs(ii - j) <= w
        return ok[None, :]                       # (1, L) broadcasts over KB

    # d = 0: only cell (0, 0).
    init_cost = diag_cost(0)
    diag0 = jnp.where((ii == 0)[None, :], init_cost, _INF)
    # A phantom "d = -1" diagonal of all +inf seeds prev2.
    diag_neg = jnp.full((kb, L), _INF, dtype=jnp.float32)

    def step(d, carry):
        prev2, prev1 = carry
        cost = diag_cost(d)
        # Predecessors: D[i-1, j] = prev1[i-1], D[i, j-1] = prev1[i],
        # D[i-1, j-1] = prev2[i-1]; the i-1 shifts bring +inf in at i=0.
        prev1_up = jnp.roll(prev1, 1, axis=1).at[:, 0].set(_INF)
        prev2_up = jnp.roll(prev2, 1, axis=1).at[:, 0].set(_INF)
        best = jnp.minimum(jnp.minimum(prev1, prev1_up), prev2_up)
        new = jnp.where(valid_mask(d), cost + best, _INF)
        return (prev1, new)

    _, last = jax.lax.fori_loop(1, 2 * L - 1, step, (diag_neg, diag0))
    # Final diagonal d = 2L-2 holds D[L-1, L-1] at lane i = L-1.
    o_ref[...] = last[:, L - 1]


def batched_dtw_sq(q: jax.Array, c: jax.Array, window: int | None = None) -> jax.Array:
    """Squared banded-DTW cost of ``q`` (L,) against each row of ``c`` (K, L).

    ``window`` is the Sakoe-Chiba half-width in samples (None = L, i.e.
    unconstrained). K is padded up to a multiple of ``K_BLOCK`` internally;
    the output always has shape (K,), dtype float32.
    """
    q = jnp.asarray(q, dtype=jnp.float32)
    c = jnp.asarray(c, dtype=jnp.float32)
    (L,) = q.shape
    k, lc = c.shape
    assert lc == L, f"centroid length {lc} != query length {L}"
    w = L if window is None else max(1, min(int(window), L))

    k_pad = ((k + K_BLOCK - 1) // K_BLOCK) * K_BLOCK
    if k_pad != k:
        # Padding rows never win and are sliced off below.
        pad = jnp.full((k_pad - k, L), 1e6, dtype=jnp.float32)
        c = jnp.concatenate([c, pad], axis=0)

    kernel = functools.partial(_dtw_wavefront_kernel, length=L, window=w)
    out = pl.pallas_call(
        kernel,
        grid=(k_pad // K_BLOCK,),
        in_specs=[
            pl.BlockSpec((L,), lambda g: (0,)),
            pl.BlockSpec((K_BLOCK, L), lambda g: (g, 0)),
        ],
        out_specs=pl.BlockSpec((K_BLOCK,), lambda g: (g,)),
        out_shape=jax.ShapeDtypeStruct((k_pad,), jnp.float32),
        interpret=True,
    )(q, c)
    return out[:k]
