"""Pure-numpy oracles for the Pallas kernels.

These are the correctness references: literal O(L^2) dynamic programs and
envelope bounds, written for clarity, not speed. The pytest suite asserts
the Pallas kernels (and, via the golden tests, the Rust implementation)
agree with these to float32 tolerance.

Conventions match rust/src/distance/mod.rs:
- DTW accumulates squared pointwise costs (paper Eq. 1); callers take the
  square root at the end.
- `window` is the Sakoe-Chiba half-width in samples; it is clamped up to
  |len(a) - len(b)| so a path always exists.
"""

from __future__ import annotations

import numpy as np

__all__ = [
    "dtw_sq_ref",
    "batched_dtw_sq_ref",
    "envelope_ref",
    "lb_keogh_sq_ref",
]


def dtw_sq_ref(a: np.ndarray, b: np.ndarray, window: int | None = None) -> float:
    """Accumulated squared DTW cost between 1-D arrays ``a`` and ``b``."""
    a = np.asarray(a, dtype=np.float64)
    b = np.asarray(b, dtype=np.float64)
    n, m = len(a), len(b)
    if n == 0 or m == 0:
        return 0.0 if n == m else float("inf")
    w = max(window, abs(n - m)) if window is not None else max(n, m)
    dp = np.full((n + 1, m + 1), np.inf)
    dp[0, 0] = 0.0
    for i in range(1, n + 1):
        lo = max(1, i - w)
        hi = min(m, i + w)
        for j in range(lo, hi + 1):
            cost = (a[i - 1] - b[j - 1]) ** 2
            dp[i, j] = cost + min(dp[i - 1, j - 1], dp[i - 1, j], dp[i, j - 1])
    return float(dp[n, m])


def batched_dtw_sq_ref(q: np.ndarray, c: np.ndarray, window: int | None = None) -> np.ndarray:
    """Squared DTW cost of query ``q`` (L,) against each row of ``c`` (K, L)."""
    return np.array([dtw_sq_ref(q, c[k], window) for k in range(c.shape[0])])


def envelope_ref(c: np.ndarray, window: int) -> tuple[np.ndarray, np.ndarray]:
    """Keogh envelope (upper, lower) of ``c`` for half-width ``window``."""
    c = np.asarray(c, dtype=np.float64)
    n = len(c)
    upper = np.empty(n)
    lower = np.empty(n)
    for i in range(n):
        lo = max(0, i - window)
        hi = min(n, i + window + 1)
        upper[i] = c[lo:hi].max()
        lower[i] = c[lo:hi].min()
    return upper, lower


def lb_keogh_sq_ref(q: np.ndarray, upper: np.ndarray, lower: np.ndarray) -> float:
    """Squared LB_Keogh of ``q`` against an envelope."""
    q = np.asarray(q, dtype=np.float64)
    over = np.maximum(q - upper, 0.0)
    under = np.maximum(lower - q, 0.0)
    return float(np.sum(over * over + under * under))
