"""Layer-1 Pallas kernel: batched reversed LB_Keogh.

Computes the squared Keogh lower bound of one query against the
precomputed envelopes of a block of centroids — the cascade stage the
PQDTW encoder runs before paying for full DTW (paper §3.2). Pure
elementwise + reduction, so the kernel is a single fused (KB, L) VPU
pass per program instance.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["batched_lb_keogh_sq", "K_BLOCK"]

K_BLOCK = 8


def _lb_keogh_kernel(q_ref, u_ref, l_ref, o_ref):
    q = q_ref[...].astype(jnp.float32)           # (L,)
    upper = u_ref[...].astype(jnp.float32)       # (KB, L)
    lower = l_ref[...].astype(jnp.float32)       # (KB, L)
    over = jnp.maximum(q[None, :] - upper, 0.0)
    under = jnp.maximum(lower - q[None, :], 0.0)
    o_ref[...] = jnp.sum(over * over + under * under, axis=1)


def batched_lb_keogh_sq(q: jax.Array, upper: jax.Array, lower: jax.Array) -> jax.Array:
    """Squared LB_Keogh of ``q`` (L,) against K envelopes (K, L) each.

    Returns (K,) float32. K is padded to a multiple of ``K_BLOCK``
    internally.
    """
    q = jnp.asarray(q, dtype=jnp.float32)
    upper = jnp.asarray(upper, dtype=jnp.float32)
    lower = jnp.asarray(lower, dtype=jnp.float32)
    (L,) = q.shape
    k = upper.shape[0]
    assert upper.shape == lower.shape == (k, L)

    k_pad = ((k + K_BLOCK - 1) // K_BLOCK) * K_BLOCK
    if k_pad != k:
        pad_u = jnp.full((k_pad - k, L), jnp.float32(1e6))
        pad_l = jnp.full((k_pad - k, L), jnp.float32(-1e6))
        upper = jnp.concatenate([upper, pad_u], axis=0)
        lower = jnp.concatenate([lower, pad_l], axis=0)

    out = pl.pallas_call(
        _lb_keogh_kernel,
        grid=(k_pad // K_BLOCK,),
        in_specs=[
            pl.BlockSpec((L,), lambda g: (0,)),
            pl.BlockSpec((K_BLOCK, L), lambda g: (g, 0)),
            pl.BlockSpec((K_BLOCK, L), lambda g: (g, 0)),
        ],
        out_specs=pl.BlockSpec((K_BLOCK,), lambda g: (g,)),
        out_shape=jax.ShapeDtypeStruct((k_pad,), jnp.float32),
        interpret=True,
    )(q, upper, lower)
    return out[:k]
