"""AOT lowering: JAX/Pallas graphs -> HLO text artifacts for the Rust
runtime.

HLO *text* (not serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which the `xla` crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids, so text round-trips cleanly. See /opt/xla-example/README.md.

Artifacts (one per manifest variant):
    encode_m{M}_k{K}_l{L}_w{W}.hlo.txt    encode_series
    adc_m{M}_k{K}_l{L}_w{W}.hlo.txt       adc_table
    pairsym_n{N}_p{P}_m{M}_k{K}.hlo.txt   pairwise_symmetric
plus ``manifest.tsv`` describing every artifact (kind, shape params,
filename) in a format the Rust side parses without a JSON dependency.

Run as ``python -m compile.aot --out ../artifacts`` (the Makefile's
`artifacts` target). Python never runs at serving time.
"""

from __future__ import annotations

import argparse
import functools
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# (M, K, L, window) variants to lower for encode/adc. These match the
# configurations the Rust examples/benches use with the PJRT backend;
# adding a line here is all it takes to support another shape.
ENCODE_VARIANTS = [
    (4, 16, 25, 5),   # SpikePosition-style serving demo (len 100, M=4)
    (4, 64, 32, 4),   # larger codebook, len 128
]

# (N, P, M, K) variants for the batched symmetric-distance graph.
PAIRSYM_VARIANTS = [
    (8, 64, 4, 16),
]


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_encode(m: int, k: int, length: int, window: int) -> str:
    fn = functools.partial(model.encode_series, window=window)
    subs = jax.ShapeDtypeStruct((m, length), jnp.float32)
    books = jax.ShapeDtypeStruct((m, k, length), jnp.float32)
    return to_hlo_text(jax.jit(fn).lower(subs, books))


def lower_adc(m: int, k: int, length: int, window: int) -> str:
    fn = functools.partial(model.adc_table, window=window)
    subs = jax.ShapeDtypeStruct((m, length), jnp.float32)
    books = jax.ShapeDtypeStruct((m, k, length), jnp.float32)
    return to_hlo_text(jax.jit(fn).lower(subs, books))


def lower_pairsym(n: int, p: int, m: int, k: int) -> str:
    cx = jax.ShapeDtypeStruct((n, m), jnp.int32)
    cy = jax.ShapeDtypeStruct((p, m), jnp.int32)
    lut = jax.ShapeDtypeStruct((m, k, k), jnp.float32)
    return to_hlo_text(jax.jit(model.pairwise_symmetric).lower(cx, cy, lut))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    manifest_rows: list[str] = []

    for m, k, length, w in ENCODE_VARIANTS:
        name = f"encode_m{m}_k{k}_l{length}_w{w}.hlo.txt"
        text = lower_encode(m, k, length, w)
        with open(os.path.join(args.out, name), "w") as f:
            f.write(text)
        manifest_rows.append(f"encode\t{m}\t{k}\t{length}\t{w}\t{name}")
        print(f"wrote {name} ({len(text)} chars)")

        name = f"adc_m{m}_k{k}_l{length}_w{w}.hlo.txt"
        text = lower_adc(m, k, length, w)
        with open(os.path.join(args.out, name), "w") as f:
            f.write(text)
        manifest_rows.append(f"adc\t{m}\t{k}\t{length}\t{w}\t{name}")
        print(f"wrote {name} ({len(text)} chars)")

    for n, p, m, k in PAIRSYM_VARIANTS:
        name = f"pairsym_n{n}_p{p}_m{m}_k{k}.hlo.txt"
        text = lower_pairsym(n, p, m, k)
        with open(os.path.join(args.out, name), "w") as f:
            f.write(text)
        manifest_rows.append(f"pairsym\t{n}\t{p}\t{m}\t{k}\t{name}")
        print(f"wrote {name} ({len(text)} chars)")

    with open(os.path.join(args.out, "manifest.tsv"), "w") as f:
        f.write("\n".join(manifest_rows) + "\n")
    print(f"manifest: {len(manifest_rows)} artifacts")


if __name__ == "__main__":
    main()
