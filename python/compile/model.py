"""Layer-2 JAX graphs for PQDTW, built on the Layer-1 Pallas kernels.

Three graphs are AOT-lowered (see aot.py) and executed from the Rust
runtime (rust/src/runtime/) via PJRT:

- ``encode_series``   — Algorithm 2's hot loop: one series' M subspace
  vectors against the full codebook -> codes + exact distances. The Rust
  coordinator does segmentation/pre-alignment (cheap, O(D)) and hands the
  (M, L) block to this graph.
- ``adc_table``       — the asymmetric distance table: (M, K) squared DTW
  distances of a query's subspaces against every centroid (paper §3.3).
- ``pairwise_symmetric`` — batched symmetric distances between two code
  matrices through the (M, K, K) LUT: pure gather + reduce, the O(M)
  per-pair path.

All shapes are static; one artifact is produced per (M, K, L, window)
variant listed in the AOT manifest.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels.dtw_band import batched_dtw_sq

__all__ = ["encode_series", "adc_table", "pairwise_symmetric"]


def adc_table(subspaces: jax.Array, codebooks: jax.Array, *, window: int) -> jax.Array:
    """Squared DTW of each subspace vector against its sub-codebook.

    subspaces: (M, L) float32; codebooks: (M, K, L) float32.
    Returns (M, K) float32.
    """
    m = subspaces.shape[0]
    # M is small and static: unrolling at trace time keeps the Pallas
    # grid one-dimensional and lets XLA pipeline the M kernel calls.
    rows = [
        batched_dtw_sq(subspaces[i], codebooks[i], window) for i in range(m)
    ]
    return jnp.stack(rows, axis=0)


def encode_series(
    subspaces: jax.Array, codebooks: jax.Array, *, window: int
) -> tuple[jax.Array, jax.Array]:
    """Nearest-centroid codes for one series (Algorithm 2).

    Returns (codes (M,) int32, dist_sq (M,) float32).
    """
    table = adc_table(subspaces, codebooks, window=window)
    codes = jnp.argmin(table, axis=1).astype(jnp.int32)
    dists = jnp.min(table, axis=1)
    return codes, dists


def pairwise_symmetric(
    codes_x: jax.Array, codes_y: jax.Array, lut_sq: jax.Array
) -> jax.Array:
    """Symmetric PQ distances between two code matrices.

    codes_x: (N, M) int32; codes_y: (P, M) int32; lut_sq: (M, K, K).
    Returns (N, P) float32 distances (sqrt of summed squared LUT cells).
    """
    n, m = codes_x.shape
    p, _ = codes_y.shape
    # Gather lut_sq[mm, codes_x[i, mm], codes_y[j, mm]] for all i, j, mm.
    mm = jnp.arange(m)
    # (N, 1, M) and (1, P, M) index grids
    cx = codes_x[:, None, :]
    cy = codes_y[None, :, :]
    cells = lut_sq[mm[None, None, :], cx, cy]   # (N, P, M)
    return jnp.sqrt(jnp.sum(cells, axis=-1))
