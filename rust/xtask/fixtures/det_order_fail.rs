// Fixture: deterministic-ordering violations — hash containers and a
// partial_cmp().unwrap() on a ranking path. Linted as nn/knn.rs.

use std::collections::HashMap;
use std::collections::HashSet;

pub fn rank(dists: &[(f64, usize)]) -> Vec<usize> {
    let mut seen: HashSet<usize> = HashSet::new();
    let mut best: HashMap<usize, f64> = HashMap::new();
    let mut order: Vec<(f64, usize)> = dists.to_vec();
    order.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    for &(d, i) in &order {
        if seen.insert(i) {
            best.insert(i, d);
        }
    }
    order.into_iter().map(|(_, i)| i).collect()
}
