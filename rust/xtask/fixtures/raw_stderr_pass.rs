// Fixture: the no-raw-stderr-in-serving compliant twin of
// raw_stderr_fail.rs — events flow through a structured logger, and
// `println!` (stdout, CLI-facing) stays out of the rule's reach.

pub trait EventSink {
    fn event(&self, name: &str, peer: &str);
}

pub fn on_connect(sink: &dyn EventSink, peer: &str) {
    sink.event("conn_open", peer);
}

pub fn report(count: u64) {
    println!("served {count} requests");
}
