// Fixture: the no-panic-in-serving compliant twin of
// no_panic_fail.rs — every failure surfaces as an Err.

use std::fmt;

#[derive(Debug)]
pub struct DecodeError(pub String);

impl fmt::Display for DecodeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "decode error: {}", self.0)
    }
}

pub fn load(bytes: &[u8]) -> Result<u32, DecodeError> {
    let head = bytes
        .get(..4)
        .ok_or_else(|| DecodeError("truncated header".to_string()))?;
    let mut buf = [0u8; 4];
    buf.copy_from_slice(head);
    Ok(u32::from_le_bytes(buf))
}
