// Fixture: a crate root carrying the compiler-enforced twin of the
// forbid-unsafe rule. Linted as lib.rs.

#![forbid(unsafe_code)]

pub mod pq;
pub mod store;
