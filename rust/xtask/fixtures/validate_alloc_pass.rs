// Fixture: the validate-before-alloc compliant twin — every
// value-sized allocation sits just below an explicit bounds check;
// literal capacities and `.len()` of existing buffers need none.

const MAX_BLOCK: usize = 1 << 20;

pub fn read_block(header: &[u8]) -> Result<(Vec<u8>, Vec<f32>), String> {
    let count = usize::from(header.first().copied().unwrap_or(0));
    let dims = usize::from(header.get(1).copied().unwrap_or(0));
    ensure!(count <= MAX_BLOCK, "count {count} exceeds block cap");
    ensure!(dims <= 64, "dims {dims} exceeds subspace cap");
    let codes = Vec::with_capacity(count * dims);
    let scratch = vec![0.0f32; dims];
    let fixed = [0u8; 16];
    let mut names: Vec<String> = Vec::with_capacity(4);
    names.clear();
    let copied = vec![0u8; fixed.len()];
    let _ = copied;
    Ok((codes, scratch))
}
