// Fixture: a well-formed waiver that suppresses nothing — must be
// reported as unused so stale waivers cannot accumulate.

pub fn f(x: u8) -> u8 {
    // lint:allow(no-panic-in-serving, reason = "stale waiver left behind by a refactor")
    x.wrapping_add(1)
}
