// Fixture: no-panic-in-serving violations — an unwrap, an expect, a
// panic!, and an unreachable! in non-test code. Linted as if it lived
// under `store/`.

pub fn load(bytes: &[u8]) -> u32 {
    let head: [u8; 4] = bytes[..4].try_into().unwrap();
    let tag = std::str::from_utf8(&bytes[4..8]).expect("tag bytes");
    if tag != "PQDT" {
        panic!("bad magic");
    }
    match head[0] {
        1 => u32::from_le_bytes(head),
        _ => unreachable!("unknown version"),
    }
}
