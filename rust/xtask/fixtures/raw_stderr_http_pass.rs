// Fixture: HTTP-endpoint code compliant with no-raw-stderr-in-serving —
// scrape requests and rejected connections flow through a structured
// logger, never raw stderr. Linted as if it lived under `net/`.

pub trait EventSink {
    fn event(&self, name: &str, status: u16);
}

pub fn on_scrape(sink: &dyn EventSink, status: u16) {
    sink.event("metrics_http_request", status);
}

pub fn on_rejected(sink: &dyn EventSink) {
    sink.event("metrics_http_rejected", 503);
}
