// Fixture: a waiver naming a rule the registry does not know — must
// surface as a lint-waiver finding, not silently do nothing.

pub fn f(x: Option<u8>) -> u8 {
    // lint:allow(no-such-rule, reason = "this rule name does not exist")
    x.map(|v| v.wrapping_add(1)).unwrap_or(0)
}
