// Fixture: job-plane code compliant with no-raw-stderr-in-serving —
// lifecycle events flow through a structured logger, never raw stderr.
// Linted as if it lived under `jobs/`.

pub trait EventSink {
    fn event(&self, name: &str, id: u64);
}

pub fn on_job_done(sink: &dyn EventSink, id: u64) {
    sink.event("job_done", id);
}

pub fn on_job_progress(sink: &dyn EventSink, id: u64) {
    sink.event("job_progress", id);
}
