// Fixture: forbid-unsafe violation — an unwaived unsafe block (the
// shape a future SIMD tier would take before earning its waiver).

pub fn sum(xs: &[f32]) -> f32 {
    let mut acc = 0.0f32;
    unsafe {
        let p = xs.as_ptr();
        for i in 0..xs.len() {
            acc += *p.add(i);
        }
    }
    acc
}
