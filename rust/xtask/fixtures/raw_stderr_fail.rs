// Fixture: no-raw-stderr-in-serving violations — an eprintln! and an
// eprint! in non-test code. Linted as if it lived under `net/`.

pub fn on_connect(peer: &str) {
    eprintln!("connection from {peer}");
}

pub fn on_error(msg: &str) {
    eprint!("error: ");
    eprintln!("{msg}");
}
