// Fixture: both waiver forms — standalone (suppresses the line below)
// and trailing (suppresses its own line). Lints clean.

use std::collections::VecDeque;

pub fn head_pair(q: &VecDeque<u8>) -> u8 {
    // lint:allow(no-panic-in-serving, reason = "queue is non-empty by construction at every call site")
    let first = q.front().copied().unwrap();
    let second = q.get(1).copied().unwrap(); // lint:allow(no-panic-in-serving, reason = "length two is checked by the caller")
    first.wrapping_add(second)
}
