// Fixture: HTTP-endpoint code compliant with no-panic-in-serving — a
// malformed request line becomes a 400 response and a poisoned body
// mutex is recovered, never unwrapped. Linted as if it lived under
// `net/`.

use std::sync::{Mutex, MutexGuard, PoisonError};

pub fn lock_recovering<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

pub fn route(head: &str) -> (u16, &'static str) {
    let mut parts = head.split_whitespace();
    let method = match parts.next() {
        Some(m) => m,
        None => return (400, "bad request"),
    };
    let path = match parts.next() {
        Some(p) => p,
        None => return (400, "bad request"),
    };
    if method != "GET" {
        return (405, "method not allowed");
    }
    match path {
        "/metrics" => (200, "ok"),
        "/healthz" => (200, "ok"),
        _ => (404, "not found"),
    }
}
