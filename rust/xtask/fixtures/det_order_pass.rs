// Fixture: the deterministic-ordering compliant twin — total_cmp with
// an index tiebreak, and a BTreeMap where keyed iteration is needed.

use std::collections::BTreeMap;

pub fn rank(dists: &[(f64, usize)]) -> Vec<usize> {
    let mut order: Vec<(f64, usize)> = dists.to_vec();
    order.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
    let mut best: BTreeMap<usize, f64> = BTreeMap::new();
    for &(d, i) in &order {
        best.entry(i).or_insert(d);
    }
    order.into_iter().map(|(_, i)| i).collect()
}
