// Fixture: unwraps and asserts that live only inside a #[cfg(test)]
// module — the analyzer must not flag test code even in a file whose
// path is inside the no-panic-in-serving scope.

pub fn double(x: u32) -> u32 {
    x.checked_mul(2).unwrap_or(u32::MAX)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn doubles() {
        assert_eq!(double(2), 4);
        let parsed: u32 = "8".parse().unwrap();
        assert_eq!(double(parsed), 16);
    }

    #[test]
    fn saturates() {
        assert_eq!(double(u32::MAX), u32::MAX);
    }
}
