// Fixture: router-plane code compliant with no-raw-stderr-in-serving —
// health transitions and degraded responses flow through a structured
// logger, never raw stderr. Linted as if it lived under `router/`.

pub trait EventSink {
    fn event(&self, name: &str, shard: u64);
}

pub fn on_shard_down(sink: &dyn EventSink, shard: u64) {
    sink.event("shard_health", shard);
}

pub fn on_degraded_response(sink: &dyn EventSink, shard: u64) {
    sink.event("degraded_response", shard);
}
