// Fixture: router-plane code compliant with no-panic-in-serving — a
// failed shard leg degrades the merge instead of crashing the router,
// and lock poisoning is recovered, never unwrapped. Linted as if it
// lived under `router/`.

use std::sync::{Mutex, MutexGuard, PoisonError};

pub enum Outcome {
    Hit(u64, f64),
    Missing(u64),
}

pub fn lock_recovering<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

pub fn gather(outcomes: Vec<Result<(u64, f64), u64>>) -> Vec<Outcome> {
    outcomes
        .into_iter()
        .map(|o| match o {
            Ok((shard, distance)) => Outcome::Hit(shard, distance),
            Err(shard) => Outcome::Missing(shard),
        })
        .collect()
}
