// Fixture: the no-lossy-cast-in-codec compliant twin — checked
// try_from for narrowing, plain `as`/From only for widening.

#[derive(Debug)]
pub struct Overflow;

pub fn pack(code: u32, len: u64) -> Result<(u8, u64), Overflow> {
    let b = u8::try_from(code).map_err(|_| Overflow)?;
    let widened = u64::from(code);
    let doubled = (len as u128).saturating_mul(2);
    let back = u64::try_from(doubled).map_err(|_| Overflow)?;
    Ok((b, widened.max(back)))
}
