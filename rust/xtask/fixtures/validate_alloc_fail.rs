// Fixture: validate-before-alloc violations — allocations sized from
// freshly decoded header bytes with no bounds check anywhere in the
// preceding window. Linted as store/decode.rs.

pub fn read_block(header: &[u8]) -> (Vec<u8>, Vec<f32>) {
    let count = usize::from(header[0]);
    let dims = usize::from(header[1]);
    let codes = Vec::with_capacity(count * dims);
    let scratch = vec![0.0f32; dims];
    (codes, scratch)
}
