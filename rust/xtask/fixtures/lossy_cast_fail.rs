// Fixture: no-lossy-cast-in-codec violations — `as` narrowing of
// values that flow through the byte codec. Linted as store/codec.rs.

pub fn pack(code: u32, len: u64) -> (u8, usize) {
    let b = code as u8;
    let n = len as usize;
    (b, n)
}
