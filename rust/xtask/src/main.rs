//! `cargo xtask` — repo-invariant static analysis for the pqdtw crate.
//!
//! Usage:
//!   cargo run -p xtask -- lint [--json] [--root <dir>]
//!   cargo run -p xtask -- rules
//!
//! `lint` analyzes every `.rs` file under the root (default: the
//! pqdtw crate's `src/`) and exits 0 when the tree is clean, 1 when
//! any finding remains, 2 on usage or I/O errors. `rules` prints the
//! registry. The `cargo lint` alias (rust/.cargo/config.toml) wraps
//! the first form.

mod engine;
mod lexer;
mod rules;

use std::path::PathBuf;
use std::process::ExitCode;

const USAGE: &str = "usage: cargo run -p xtask -- <command>\n\
    \n\
    commands:\n\
    \x20 lint [--json] [--root <dir>]   lint the tree (default root: rust/src)\n\
    \x20 rules                          print the rule registry\n";

fn default_root() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("..").join("src")
}

fn cmd_lint(args: &[String]) -> Result<ExitCode, String> {
    let mut json = false;
    let mut root = default_root();
    let mut it = args.iter();
    while let Some(arg) = it.next() {
        match arg.as_str() {
            "--json" => json = true,
            "--root" => {
                let dir = it.next().ok_or("--root requires a directory argument")?;
                root = PathBuf::from(dir);
            }
            other => return Err(format!("unknown argument `{other}`\n{USAGE}")),
        }
    }
    if !root.is_dir() {
        return Err(format!("lint root {} is not a directory", root.display()));
    }

    let findings = engine::lint_tree(&root)?;
    if json {
        print!("{}", engine::render_json(&findings));
    } else if findings.is_empty() {
        println!("xtask lint: clean ({} rules)", rules::RULES.len());
    } else {
        print!("{}", engine::render_text(&findings));
        eprintln!("xtask lint: {} finding(s)", findings.len());
    }
    if findings.is_empty() {
        Ok(ExitCode::SUCCESS)
    } else {
        Ok(ExitCode::from(1))
    }
}

fn cmd_rules() -> ExitCode {
    for r in rules::RULES {
        println!("{}\n  scope: {}\n  {}\n", r.name, r.scope, r.summary);
    }
    ExitCode::SUCCESS
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let result = match args.first().map(String::as_str) {
        Some("lint") => cmd_lint(&args[1..]),
        Some("rules") => Ok(cmd_rules()),
        _ => Err(USAGE.to_string()),
    };
    match result {
        Ok(code) => code,
        Err(msg) => {
            eprintln!("xtask: {msg}");
            ExitCode::from(2)
        }
    }
}
