//! Orchestration: lex a file, run the rule registry, apply waivers,
//! and render findings as text or JSON.
//!
//! Waiver semantics: `// lint:allow(<rule>, reason = "...")` suppresses
//! findings of `<rule>` on its own line or the line directly below.
//! Waivers are themselves checked — an unknown rule name, a missing
//! reason, or a waiver that suppresses nothing is a `lint-waiver`
//! finding, and those are not waivable: the waiver ledger must stay
//! honest or it stops being evidence.

use crate::lexer;
use crate::rules::{self, Finding};

/// Lint one file's source. `rel` is the path relative to the linted
/// root (forward slashes) — rule scoping matches against it.
pub fn lint_source(rel: &str, src: &str) -> Vec<Finding> {
    let cf = lexer::clean(src);
    let mut findings = rules::check_all(rel, &cf);

    for w in &cf.waivers {
        if !rules::is_known_rule(&w.rule) {
            findings.push(Finding {
                file: rel.to_string(),
                line: w.line,
                rule: rules::LINT_WAIVER,
                message: format!(
                    "waiver names unknown rule `{}` — run `cargo lint rules` \
                     for the registry",
                    w.rule
                ),
            });
            continue;
        }
        let before = findings.len();
        findings.retain(|f| {
            !(f.rule == w.rule && (f.line == w.line || f.line == w.line + 1))
        });
        if findings.len() == before {
            findings.push(Finding {
                file: rel.to_string(),
                line: w.line,
                rule: rules::LINT_WAIVER,
                message: format!(
                    "unused waiver for `{}` (reason: \"{}\") — nothing on \
                     this or the next line violates it; delete the waiver",
                    w.rule, w.reason
                ),
            });
        }
    }

    for e in &cf.waiver_errors {
        findings.push(Finding {
            file: rel.to_string(),
            line: e.line,
            rule: rules::LINT_WAIVER,
            message: e.message.clone(),
        });
    }

    findings.sort_by_key(|f| (f.line, f.rule));
    findings
}

/// Render findings as `file:line: [rule] message` lines.
pub fn render_text(findings: &[Finding]) -> String {
    let mut out = String::new();
    for f in findings {
        out.push_str(&format!("{}:{}: [{}] {}\n", f.file, f.line, f.rule, f.message));
    }
    out
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Render findings as a JSON report (stable field order, sorted input).
pub fn render_json(findings: &[Finding]) -> String {
    let mut out = String::from("{\n  \"findings\": [");
    for (i, f) in findings.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!(
            "\n    {{\"file\": \"{}\", \"line\": {}, \"rule\": \"{}\", \"message\": \"{}\"}}",
            json_escape(&f.file),
            f.line,
            f.rule,
            json_escape(&f.message)
        ));
    }
    if findings.is_empty() {
        out.push_str("],\n");
    } else {
        out.push_str("\n  ],\n");
    }
    out.push_str(&format!("  \"total\": {}\n}}\n", findings.len()));
    out
}

/// Recursively collect `.rs` files under `root`, sorted, as
/// (relative-forward-slash-path, absolute-path) pairs.
pub fn collect_rs_files(
    root: &std::path::Path,
) -> Result<Vec<(String, std::path::PathBuf)>, String> {
    let mut out = Vec::new();
    let mut stack = vec![root.to_path_buf()];
    while let Some(dir) = stack.pop() {
        let entries = std::fs::read_dir(&dir)
            .map_err(|e| format!("read_dir {}: {e}", dir.display()))?;
        for entry in entries {
            let entry = entry.map_err(|e| format!("read_dir {}: {e}", dir.display()))?;
            let path = entry.path();
            if path.is_dir() {
                stack.push(path);
            } else if path.extension().and_then(|e| e.to_str()) == Some("rs") {
                let rel = path
                    .strip_prefix(root)
                    .map_err(|e| format!("strip_prefix {}: {e}", path.display()))?
                    .components()
                    .map(|c| c.as_os_str().to_string_lossy().into_owned())
                    .collect::<Vec<_>>()
                    .join("/");
                out.push((rel, path));
            }
        }
    }
    out.sort();
    Ok(out)
}

/// Lint every `.rs` file under `root`; findings carry root-relative paths.
pub fn lint_tree(root: &std::path::Path) -> Result<Vec<Finding>, String> {
    let mut all = Vec::new();
    for (rel, path) in collect_rs_files(root)? {
        let src = std::fs::read_to_string(&path)
            .map_err(|e| format!("read {}: {e}", path.display()))?;
        all.extend(lint_source(&rel, &src));
    }
    Ok(all)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rules;
    use std::path::Path;

    /// Read a fixture from `rust/xtask/fixtures/`.
    fn fixture(name: &str) -> String {
        let path = Path::new(env!("CARGO_MANIFEST_DIR")).join("fixtures").join(name);
        std::fs::read_to_string(&path)
            .unwrap_or_else(|e| panic!("fixture {}: {e}", path.display()))
    }

    fn rules_hit(findings: &[rules::Finding]) -> Vec<&'static str> {
        findings.iter().map(|f| f.rule).collect()
    }

    // ---- no-panic-in-serving ----

    #[test]
    fn no_panic_fail_fixture_is_flagged() {
        let f = lint_source("store/broken.rs", &fixture("no_panic_fail.rs"));
        assert!(
            f.iter().filter(|f| f.rule == rules::NO_PANIC).count() >= 4,
            "expected unwrap/expect/panic!/unreachable! findings, got {f:?}"
        );
        // Findings carry 1-based lines pointing at real content.
        assert!(f.iter().all(|f| f.line >= 1));
    }

    #[test]
    fn no_panic_pass_fixture_is_clean() {
        let f = lint_source("store/clean.rs", &fixture("no_panic_pass.rs"));
        assert!(f.is_empty(), "expected clean, got {f:?}");
    }

    #[test]
    fn no_panic_ignored_outside_serving_scope() {
        let f = lint_source("pq/dist.rs", &fixture("no_panic_fail.rs"));
        assert!(
            !f.iter().any(|f| f.rule == rules::NO_PANIC),
            "pq/ is outside no-panic scope, got {f:?}"
        );
    }

    #[test]
    fn no_panic_ignored_in_test_regions() {
        let f = lint_source("net/x.rs", &fixture("test_region.rs"));
        assert!(
            !f.iter().any(|f| f.rule == rules::NO_PANIC),
            "unwraps inside #[cfg(test)] mod must not be flagged, got {f:?}"
        );
    }

    // ---- no-lossy-cast-in-codec ----

    #[test]
    fn lossy_cast_fail_fixture_is_flagged() {
        let f = lint_source("store/codec.rs", &fixture("lossy_cast_fail.rs"));
        assert!(
            f.iter().filter(|f| f.rule == rules::NO_LOSSY_CAST).count() >= 2,
            "expected narrowing-cast findings, got {f:?}"
        );
    }

    #[test]
    fn lossy_cast_pass_fixture_is_clean() {
        let f = lint_source("net/protocol.rs", &fixture("lossy_cast_pass.rs"));
        assert!(f.is_empty(), "widening casts / try_from must pass, got {f:?}");
    }

    // ---- deterministic-ordering ----

    #[test]
    fn det_order_fail_fixture_is_flagged() {
        let f = lint_source("nn/knn.rs", &fixture("det_order_fail.rs"));
        let hits = f.iter().filter(|f| f.rule == rules::DET_ORDER).count();
        assert!(hits >= 3, "expected HashMap/HashSet/partial_cmp findings, got {f:?}");
    }

    #[test]
    fn det_order_pass_fixture_is_clean() {
        let f = lint_source("pq/scan.rs", &fixture("det_order_pass.rs"));
        assert!(f.is_empty(), "total_cmp + BTreeMap must pass, got {f:?}");
    }

    #[test]
    fn det_order_catches_unwrap_on_next_line() {
        let src = "fn f(a: f64, b: f64) {\n    let o = a.partial_cmp(&b)\n        .unwrap();\n}\n";
        let f = lint_source("nn/knn.rs", src);
        assert!(f.iter().any(|f| f.rule == rules::DET_ORDER), "got {f:?}");
    }

    // ---- validate-before-alloc ----

    #[test]
    fn validate_alloc_fail_fixture_is_flagged() {
        let f = lint_source("store/decode.rs", &fixture("validate_alloc_fail.rs"));
        assert!(
            f.iter().filter(|f| f.rule == rules::VALIDATE_ALLOC).count() >= 2,
            "expected unguarded with_capacity and vec! findings, got {f:?}"
        );
    }

    #[test]
    fn validate_alloc_pass_fixture_is_clean() {
        let f = lint_source("store/decode.rs", &fixture("validate_alloc_pass.rs"));
        assert!(f.is_empty(), "guarded allocations must pass, got {f:?}");
    }

    // ---- no-raw-stderr-in-serving ----

    #[test]
    fn raw_stderr_fail_fixture_is_flagged() {
        let f = lint_source("net/server.rs", &fixture("raw_stderr_fail.rs"));
        assert!(
            f.iter().filter(|f| f.rule == rules::NO_RAW_STDERR).count() >= 3,
            "expected eprintln!/eprint! findings, got {f:?}"
        );
    }

    #[test]
    fn raw_stderr_pass_fixture_is_clean() {
        let f = lint_source("coordinator/service.rs", &fixture("raw_stderr_pass.rs"));
        assert!(f.is_empty(), "logger events and println! must pass, got {f:?}");
    }

    #[test]
    fn raw_stderr_scope_covers_the_job_plane() {
        // The fail fixture under a jobs/ path must be flagged …
        let f = lint_source("jobs/manager.rs", &fixture("raw_stderr_fail.rs"));
        assert!(
            f.iter().filter(|f| f.rule == rules::NO_RAW_STDERR).count() >= 3,
            "jobs/ is in no-raw-stderr scope, got {f:?}"
        );
        // … and the structured-logger twin must pass with zero waivers.
        let f = lint_source("jobs/manager.rs", &fixture("raw_stderr_jobs_pass.rs"));
        assert!(f.is_empty(), "logger-based job events must pass, got {f:?}");
    }

    #[test]
    fn no_panic_scope_covers_the_router_plane() {
        // The fail fixture under a router/ path must be flagged …
        let f = lint_source("router/health.rs", &fixture("no_panic_fail.rs"));
        assert!(
            f.iter().filter(|f| f.rule == rules::NO_PANIC).count() >= 4,
            "router/ is in no-panic scope, got {f:?}"
        );
        // … and the error-propagating twin must pass with zero waivers.
        let f = lint_source("router/health.rs", &fixture("no_panic_router_pass.rs"));
        assert!(f.is_empty(), "degrade-don't-crash router code must pass, got {f:?}");
    }

    #[test]
    fn raw_stderr_scope_covers_the_router_plane() {
        // The fail fixture under a router/ path must be flagged …
        let f = lint_source("router/server.rs", &fixture("raw_stderr_fail.rs"));
        assert!(
            f.iter().filter(|f| f.rule == rules::NO_RAW_STDERR).count() >= 3,
            "router/ is in no-raw-stderr scope, got {f:?}"
        );
        // … and the structured-logger twin must pass with zero waivers.
        let f = lint_source("router/server.rs", &fixture("raw_stderr_router_pass.rs"));
        assert!(f.is_empty(), "logger-based router events must pass, got {f:?}");
    }

    #[test]
    fn no_panic_scope_covers_the_http_endpoint() {
        // The fail fixture under the scrape endpoint's path must be
        // flagged …
        let f = lint_source("net/http.rs", &fixture("no_panic_fail.rs"));
        assert!(
            f.iter().filter(|f| f.rule == rules::NO_PANIC).count() >= 4,
            "net/http.rs is in no-panic scope, got {f:?}"
        );
        // … and the error-propagating twin must pass with zero waivers.
        let f = lint_source("net/http.rs", &fixture("no_panic_http_pass.rs"));
        assert!(f.is_empty(), "400-don't-crash endpoint code must pass, got {f:?}");
    }

    #[test]
    fn raw_stderr_scope_covers_the_http_endpoint() {
        // The fail fixture under the scrape endpoint's path must be
        // flagged …
        let f = lint_source("net/http.rs", &fixture("raw_stderr_fail.rs"));
        assert!(
            f.iter().filter(|f| f.rule == rules::NO_RAW_STDERR).count() >= 3,
            "net/http.rs is in no-raw-stderr scope, got {f:?}"
        );
        // … and the structured-logger twin must pass with zero waivers.
        let f = lint_source("net/http.rs", &fixture("raw_stderr_http_pass.rs"));
        assert!(f.is_empty(), "logger-based scrape events must pass, got {f:?}");
    }

    #[test]
    fn raw_stderr_ignored_outside_serving_scope() {
        let f = lint_source("obs/log.rs", &fixture("raw_stderr_fail.rs"));
        assert!(
            !f.iter().any(|f| f.rule == rules::NO_RAW_STDERR),
            "obs/ is outside no-raw-stderr scope, got {f:?}"
        );
    }

    // ---- forbid-unsafe ----

    #[test]
    fn forbid_unsafe_fail_fixture_is_flagged() {
        let f = lint_source("pq/simd.rs", &fixture("forbid_unsafe_fail.rs"));
        assert!(f.iter().any(|f| f.rule == rules::FORBID_UNSAFE), "got {f:?}");
    }

    #[test]
    fn forbid_unsafe_missing_crate_attr_is_flagged() {
        let f = lint_source("lib.rs", "pub mod pq;\n");
        assert!(
            f.iter().any(|f| f.rule == rules::FORBID_UNSAFE
                && f.message.contains("forbid(unsafe_code)")),
            "lib.rs without the attribute must be flagged, got {f:?}"
        );
    }

    #[test]
    fn forbid_unsafe_pass_fixture_is_clean() {
        let f = lint_source("lib.rs", &fixture("forbid_unsafe_pass.rs"));
        assert!(f.is_empty(), "got {f:?}");
    }

    // ---- waivers ----

    #[test]
    fn waiver_suppresses_own_line_and_next_line() {
        let f = lint_source("store/x.rs", &fixture("waiver_ok.rs"));
        assert!(f.is_empty(), "valid waivers must suppress their findings, got {f:?}");
    }

    #[test]
    fn waiver_unknown_rule_is_an_error() {
        let f = lint_source("store/x.rs", &fixture("waiver_unknown.rs"));
        assert!(
            f.iter().any(|f| f.rule == rules::LINT_WAIVER
                && f.message.contains("unknown rule")),
            "got {f:?}"
        );
    }

    #[test]
    fn waiver_unused_is_an_error() {
        let f = lint_source("store/x.rs", &fixture("waiver_unused.rs"));
        assert!(
            f.iter().any(|f| f.rule == rules::LINT_WAIVER && f.message.contains("unused")),
            "got {f:?}"
        );
    }

    #[test]
    fn waiver_missing_reason_is_an_error() {
        let src = "// lint:allow(no-panic-in-serving)\nfn f(x: Option<u8>) { x.unwrap(); }\n";
        let f = lint_source("store/x.rs", src);
        assert!(
            f.iter().any(|f| f.rule == rules::LINT_WAIVER),
            "reason-less waiver must be a lint-waiver finding, got {f:?}"
        );
        // And it does not suppress the underlying finding.
        assert!(f.iter().any(|f| f.rule == rules::NO_PANIC), "got {f:?}");
    }

    // ---- output / tree ----

    #[test]
    fn json_output_is_wellformed_and_escaped() {
        let findings = vec![rules::Finding {
            file: "a\"b.rs".to_string(),
            line: 3,
            rule: rules::NO_PANIC,
            message: "quote \" and backslash \\".to_string(),
        }];
        let j = render_json(&findings);
        assert!(j.contains("\"total\": 1"));
        assert!(j.contains("a\\\"b.rs"));
        assert!(j.contains("backslash \\\\"));
        let empty = render_json(&[]);
        assert!(empty.contains("\"total\": 0"));
    }

    #[test]
    fn rules_hit_is_deterministically_sorted() {
        let src = "fn f(x: Option<u8>) {\n    x.unwrap();\n    panic!(\"no\");\n}\n";
        let f = lint_source("store/x.rs", src);
        let lines: Vec<usize> = f.iter().map(|f| f.line).collect();
        let mut sorted = lines.clone();
        sorted.sort_unstable();
        assert_eq!(lines, sorted);
        assert_eq!(rules_hit(&f), vec![rules::NO_PANIC, rules::NO_PANIC]);
    }

    /// The real crate tree must lint clean — this is the same check CI's
    /// static-analysis job runs, kept here so `cargo test -p xtask`
    /// catches regressions without a separate step.
    #[test]
    fn real_tree_is_clean() {
        let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("..").join("src");
        let findings = lint_tree(&root).expect("walk rust/src");
        assert!(
            findings.is_empty(),
            "rust/src must lint clean:\n{}",
            render_text(&findings)
        );
    }
}
