//! Source preparation for the lint rules: a hand-rolled scan that
//! strips comments and string/char-literal *contents* (so rule
//! patterns never match inside prose), records `// lint:allow(..)`
//! waiver comments, and marks which lines lie inside test regions
//! (`#[cfg(test)]` / `#[test]` items and `mod tests { .. }` blocks).
//!
//! This is deliberately a token-level scanner, not a parser. What it
//! understands: line and (nested) block comments, string literals with
//! escapes, raw/byte strings (`r"..."`, `r#"..."#`, `b"..."`,
//! `br#"..."#`), char literals vs lifetimes, and brace nesting for
//! region tracking. What it does not understand: macro-generated code,
//! type information, control flow. The rules are written so that this
//! is enough (see `rules.rs`), and the Miri/TSan CI tiers backstop the
//! properties tokens cannot see.

/// A waiver comment: `// lint:allow(<rule>, reason = "...")`.
///
/// A waiver suppresses findings of `rule` on its own line (trailing
/// form) or on the line directly below it (standalone form). Unknown
/// rule names and waivers that suppress nothing are reported as
/// `lint-waiver` findings — waivers are part of the checked surface.
#[derive(Debug, Clone)]
pub struct Waiver {
    /// 1-based line of the comment.
    pub line: usize,
    /// Rule name the waiver claims to suppress.
    pub rule: String,
    /// Mandatory human-readable justification.
    pub reason: String,
}

/// A malformed waiver comment (missing reason, unbalanced syntax).
#[derive(Debug, Clone)]
pub struct WaiverError {
    /// 1-based line of the comment.
    pub line: usize,
    /// What was wrong with it.
    pub message: String,
}

/// A source file after cleaning and region analysis.
#[derive(Debug)]
pub struct CleanFile {
    /// Source lines with comment and literal contents blanked to
    /// spaces; line count and column offsets match the original.
    pub lines: Vec<String>,
    /// `is_test[i]`: 0-based line `i` lies inside a test region.
    pub is_test: Vec<bool>,
    /// Well-formed waivers, in source order.
    pub waivers: Vec<Waiver>,
    /// Malformed waivers, reported as findings by the engine.
    pub waiver_errors: Vec<WaiverError>,
}

fn is_ident_char(c: char) -> bool {
    c == '_' || c.is_ascii_alphanumeric()
}

/// Clean `src`: blank comments and literal contents, collect waivers,
/// then mark test regions on the cleaned text.
pub fn clean(src: &str) -> CleanFile {
    let chars: Vec<char> = src.chars().collect();
    let n = chars.len();
    let mut out: Vec<char> = Vec::with_capacity(n);
    let mut waivers = Vec::new();
    let mut waiver_errors = Vec::new();
    let mut line = 1usize;
    let mut i = 0usize;
    while i < n {
        let c = chars[i];
        if c == '\n' {
            out.push('\n');
            line += 1;
            i += 1;
        } else if c == '/' && i + 1 < n && chars[i + 1] == '/' {
            let start = i;
            while i < n && chars[i] != '\n' {
                i += 1;
            }
            let text: String = chars[start..i].iter().collect();
            parse_waivers(&text, line, &mut waivers, &mut waiver_errors);
            out.resize(out.len() + (i - start), ' ');
        } else if c == '/' && i + 1 < n && chars[i + 1] == '*' {
            let mut depth = 1usize;
            out.push(' ');
            out.push(' ');
            i += 2;
            while i < n && depth > 0 {
                if chars[i] == '/' && i + 1 < n && chars[i + 1] == '*' {
                    depth += 1;
                    out.push(' ');
                    out.push(' ');
                    i += 2;
                } else if chars[i] == '*' && i + 1 < n && chars[i + 1] == '/' {
                    depth -= 1;
                    out.push(' ');
                    out.push(' ');
                    i += 2;
                } else {
                    if chars[i] == '\n' {
                        out.push('\n');
                        line += 1;
                    } else {
                        out.push(' ');
                    }
                    i += 1;
                }
            }
        } else if c == '"' {
            i = blank_string(&chars, i, &mut out, &mut line);
        } else if (c == 'r' || c == 'b') && (i == 0 || !is_ident_char(chars[i - 1])) {
            match blank_prefixed_string(&chars, i, &mut out, &mut line) {
                Some(j) => i = j,
                None => {
                    out.push(c);
                    i += 1;
                }
            }
        } else if c == '\'' {
            i = blank_char_or_lifetime(&chars, i, &mut out, &mut line);
        } else {
            out.push(c);
            i += 1;
        }
    }

    let lines: Vec<String> = out
        .split(|&c| c == '\n')
        .map(|l| l.iter().collect())
        .collect();
    let is_test = test_regions(&out, lines.len());
    CleanFile { lines, is_test, waivers, waiver_errors }
}

/// Blank a non-raw string starting at the opening quote `chars[i]`;
/// returns the index just past the closing quote. Newlines (including
/// escaped line continuations) keep their place.
fn blank_string(chars: &[char], start: usize, out: &mut Vec<char>, line: &mut usize) -> usize {
    let n = chars.len();
    out.push(' ');
    let mut i = start + 1;
    while i < n {
        match chars[i] {
            '\\' => {
                out.push(' ');
                i += 1;
                if i < n {
                    if chars[i] == '\n' {
                        out.push('\n');
                        *line += 1;
                    } else {
                        out.push(' ');
                    }
                    i += 1;
                }
            }
            '"' => {
                out.push(' ');
                return i + 1;
            }
            '\n' => {
                out.push('\n');
                *line += 1;
                i += 1;
            }
            _ => {
                out.push(' ');
                i += 1;
            }
        }
    }
    i
}

/// Blank a raw or byte string (`r".."`, `r#".."#`, `b".."`, `br#".."#`)
/// starting at its prefix letter. Returns `None` when the characters at
/// `start` are not actually a string prefix (e.g. a raw identifier
/// `r#match` or a plain identifier starting with `r`/`b`).
fn blank_prefixed_string(
    chars: &[char],
    start: usize,
    out: &mut Vec<char>,
    line: &mut usize,
) -> Option<usize> {
    let n = chars.len();
    let mut j = start;
    let mut raw = false;
    if chars[j] == 'b' {
        j += 1;
        if j < n && chars[j] == 'r' {
            raw = true;
            j += 1;
        }
    } else {
        // chars[start] == 'r'
        raw = true;
        j += 1;
    }
    let mut hashes = 0usize;
    if raw {
        while j < n && chars[j] == '#' {
            hashes += 1;
            j += 1;
        }
    }
    if j >= n || chars[j] != '"' {
        return None;
    }
    // Blank the prefix and any hashes; the quote belongs to the body.
    out.resize(out.len() + (j - start), ' ');
    if !raw {
        return Some(blank_string(chars, j, out, line));
    }
    out.push(' '); // opening quote
    let mut i = j + 1;
    while i < n {
        if chars[i] == '"' {
            let mut k = i + 1;
            let mut h = 0usize;
            while k < n && h < hashes && chars[k] == '#' {
                h += 1;
                k += 1;
            }
            if h == hashes {
                out.resize(out.len() + (k - i), ' ');
                return Some(k);
            }
        }
        if chars[i] == '\n' {
            out.push('\n');
            *line += 1;
        } else {
            out.push(' ');
        }
        i += 1;
    }
    Some(i)
}

/// Blank a char literal, or pass a lifetime through untouched,
/// starting at the `'` at `chars[i]`.
fn blank_char_or_lifetime(
    chars: &[char],
    start: usize,
    out: &mut Vec<char>,
    line: &mut usize,
) -> usize {
    let n = chars.len();
    if start + 1 < n && chars[start + 1] == '\\' {
        // Escaped char literal: '\n', '\'', '\u{7f}', '\\' ...
        out.push(' ');
        out.push(' ');
        let mut i = start + 2;
        if i < n {
            // The escaped character itself (may be a quote).
            if chars[i] == '\n' {
                out.push('\n');
                *line += 1;
            } else {
                out.push(' ');
            }
            i += 1;
        }
        while i < n && chars[i] != '\'' {
            if chars[i] == '\n' {
                out.push('\n');
                *line += 1;
            } else {
                out.push(' ');
            }
            i += 1;
        }
        if i < n {
            out.push(' ');
            i += 1;
        }
        i
    } else if start + 2 < n && chars[start + 2] == '\'' && chars[start + 1] != '\'' {
        // Plain one-character literal like 'x' or '_'.
        out.push(' ');
        out.push(' ');
        out.push(' ');
        start + 3
    } else {
        // Lifetime (`'a`, `'static`, `'_`) or stray quote: code.
        out.push('\'');
        start + 1
    }
}

/// Parse every `lint:allow(..)` occurrence in one comment's text.
fn parse_waivers(
    text: &str,
    line: usize,
    waivers: &mut Vec<Waiver>,
    errors: &mut Vec<WaiverError>,
) {
    const MARK: &str = "lint:allow(";
    let mut rest = text;
    while let Some(pos) = rest.find(MARK) {
        let body = &rest[pos + MARK.len()..];
        match parse_one_waiver(body) {
            Ok((rule, reason, consumed)) => {
                waivers.push(Waiver { line, rule, reason });
                rest = &body[consumed..];
            }
            Err(msg) => {
                errors.push(WaiverError { line, message: msg });
                rest = body;
            }
        }
    }
}

/// Parse `<rule>, reason = "<text>")`, returning the rule, the reason
/// and how many bytes of `body` were consumed.
fn parse_one_waiver(body: &str) -> Result<(String, String, usize), String> {
    let comma = match body.find(|c: char| c == ',' || c == ')') {
        Some(p) if body.as_bytes()[p] == b',' => p,
        _ => {
            return Err(
                "waiver is missing a reason — write lint:allow(<rule>, reason = \"why\")"
                    .to_string(),
            )
        }
    };
    let rule = body[..comma].trim().to_string();
    if rule.is_empty() {
        return Err("waiver names no rule".to_string());
    }
    let after = &body[comma + 1..];
    let trimmed = after.trim_start();
    let key_off = after.len() - trimmed.len();
    let Some(eq_rest) = trimmed.strip_prefix("reason") else {
        return Err("waiver argument must be reason = \"..\"".to_string());
    };
    let eq_rest_trim = eq_rest.trim_start();
    let Some(val) = eq_rest_trim.strip_prefix('=') else {
        return Err("waiver reason is missing '='".to_string());
    };
    let val_trim = val.trim_start();
    let Some(quoted) = val_trim.strip_prefix('"') else {
        return Err("waiver reason must be a quoted string".to_string());
    };
    let Some(close) = quoted.find('"') else {
        return Err("waiver reason string is unterminated".to_string());
    };
    let reason = quoted[..close].to_string();
    if reason.trim().is_empty() {
        return Err("waiver reason is empty".to_string());
    }
    let after_quote = &quoted[close + 1..];
    let after_quote_trim = after_quote.trim_start();
    if !after_quote_trim.starts_with(')') {
        return Err("waiver is missing its closing ')'".to_string());
    }
    // Bytes consumed relative to `body`.
    let consumed = comma
        + 1
        + key_off
        + "reason".len()
        + (eq_rest.len() - eq_rest_trim.len())
        + 1
        + (val.len() - val_trim.len())
        + 1
        + close
        + 1
        + (after_quote.len() - after_quote_trim.len())
        + 1;
    Ok((rule, reason, consumed))
}

/// Does the cleaned text at `i` start marker `atoms` (each atom a word
/// or a single punctuation char), with whitespace allowed between
/// atoms and word boundaries enforced on word atoms?
fn matches_atoms(chars: &[char], mut i: usize, atoms: &[&str]) -> bool {
    let n = chars.len();
    for (ai, atom) in atoms.iter().enumerate() {
        if ai > 0 {
            while i < n && chars[i].is_whitespace() {
                i += 1;
            }
        }
        let aw: Vec<char> = atom.chars().collect();
        let is_word = aw[0].is_ascii_alphabetic() || aw[0] == '_';
        if is_word && i > 0 && is_ident_char(chars[i - 1]) {
            return false;
        }
        for &ac in &aw {
            if i >= n || chars[i] != ac {
                return false;
            }
            i += 1;
        }
        if is_word && i < n && is_ident_char(chars[i]) {
            return false;
        }
    }
    true
}

/// Mark lines inside test regions. A region opens at the `{` that
/// follows a `#[cfg(test)]` / `#[test]` attribute or a `mod tests`
/// item, and closes at its matching `}`; regions nest.
fn test_regions(chars: &[char], n_lines: usize) -> Vec<bool> {
    let mut is_test = vec![false; n_lines];
    let n = chars.len();
    let mut line = 0usize; // 0-based
    let mut stack: Vec<bool> = Vec::new();
    let mut test_depth = 0usize;
    let mut pending = false;
    let mut i = 0usize;
    while i < n {
        let c = chars[i];
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if matches_atoms(chars, i, &["#", "[", "cfg", "(", "test"])
            || matches_atoms(chars, i, &["#", "[", "test", "]"])
            || matches_atoms(chars, i, &["mod", "tests"])
        {
            pending = true;
        } else if c == '{' {
            let t = pending || test_depth > 0;
            stack.push(t);
            if t {
                test_depth += 1;
            }
            pending = false;
        } else if c == '}' {
            if let Some(t) = stack.pop() {
                if t {
                    test_depth -= 1;
                }
            }
        } else if c == ';' {
            // An attribute resolved to a braceless item (`mod tests;`).
            pending = false;
        }
        if test_depth > 0 && line < n_lines {
            is_test[line] = true;
        }
        i += 1;
    }
    is_test
}

#[cfg(test)]
mod tests {
    use super::*;

    fn clean_lines(src: &str) -> Vec<String> {
        clean(src).lines
    }

    #[test]
    fn comments_and_strings_are_blanked() {
        let src = "let x = 1; // trailing .unwrap()\nlet s = \"panic!(no)\";\n";
        let lines = clean_lines(src);
        assert!(!lines[0].contains("unwrap"));
        assert!(lines[0].contains("let x = 1;"));
        assert!(!lines[1].contains("panic"));
        assert!(lines[1].contains("let s ="));
    }

    #[test]
    fn block_comments_nest_and_keep_line_structure() {
        let src = "a /* one /* two */ still */ b\nc /* multi\nline */ d\n";
        let lines = clean_lines(src);
        assert!(lines[0].contains('a') && lines[0].contains('b'));
        assert!(!lines[0].contains("one") && !lines[0].contains("still"));
        assert_eq!(lines.len(), 4); // 3 lines + trailing empty
        assert!(lines[2].contains('d') && !lines[2].contains("line"));
    }

    #[test]
    fn raw_and_byte_strings_are_blanked() {
        let src = "let a = r#\"has \"quotes\" and unwrap()\"#; let b = b\"panic!\";\n";
        let l = &clean_lines(src)[0];
        assert!(!l.contains("unwrap") && !l.contains("panic"));
        assert!(l.contains("let a =") && l.contains("let b ="));
    }

    #[test]
    fn raw_identifiers_are_not_strings() {
        let src = "let r#type = 3; let x = r#type + 1;\n";
        let l = &clean_lines(src)[0];
        assert!(l.contains("r#type"));
    }

    #[test]
    fn char_literals_blank_but_lifetimes_survive() {
        let src = "fn f<'a>(x: &'a str) -> char { let c = '\\''; let d = 'x'; c }\n";
        let l = &clean_lines(src)[0];
        assert!(l.contains("<'a>"));
        assert!(l.contains("&'a str"));
        assert!(!l.contains("'x'"));
    }

    #[test]
    fn string_escapes_do_not_end_the_literal() {
        let src = "let s = \"a\\\"b.unwrap()c\"; let t = 1;\n";
        let l = &clean_lines(src)[0];
        assert!(!l.contains("unwrap"));
        assert!(l.contains("let t = 1;"));
    }

    #[test]
    fn waiver_parses_rule_and_reason() {
        let cf = clean("x(); // lint:allow(no-panic-in-serving, reason = \"infallible\")\n");
        assert_eq!(cf.waivers.len(), 1);
        assert_eq!(cf.waivers[0].rule, "no-panic-in-serving");
        assert_eq!(cf.waivers[0].reason, "infallible");
        assert_eq!(cf.waivers[0].line, 1);
        assert!(cf.waiver_errors.is_empty());
    }

    #[test]
    fn waiver_without_reason_is_malformed() {
        let cf = clean("// lint:allow(no-panic-in-serving)\n");
        assert!(cf.waivers.is_empty());
        assert_eq!(cf.waiver_errors.len(), 1);
        assert!(cf.waiver_errors[0].message.contains("reason"));
    }

    #[test]
    fn cfg_test_and_mod_tests_regions_are_marked() {
        let src = "\
fn serving() {}
#[cfg(test)]
mod tests {
    fn helper() {}
}
fn more_serving() {}
";
        let cf = clean(src);
        assert!(!cf.is_test[0], "serving fn is not test code");
        assert!(cf.is_test[3], "body of mod tests is test code");
        assert!(!cf.is_test[5], "code after the test mod is not test code");
    }

    #[test]
    fn test_attribute_marks_the_following_fn() {
        let src = "#[test]\nfn check() {\n    boom();\n}\nfn live() {}\n";
        let cf = clean(src);
        assert!(cf.is_test[2], "test fn body is test code");
        assert!(!cf.is_test[4], "fn after the test is live code");
    }

    #[test]
    fn cfg_not_test_is_not_a_test_region() {
        let src = "#[cfg(not(test))]\nfn live() {\n    serve();\n}\n";
        let cf = clean(src);
        assert!(!cf.is_test[2]);
    }
}
