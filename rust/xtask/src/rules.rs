//! The rule registry: each rule encodes one invariant the repo's
//! correctness story depends on, scoped to the paths where it must
//! hold. Rules run over cleaned lines (`lexer::CleanFile`) and skip
//! test regions — tests are allowed to panic, index, and cast.
//!
//! `docs/INVARIANTS.md` documents what each rule protects and how to
//! waive it; keep that file in sync when adding or changing rules.

use crate::lexer::CleanFile;

/// One diagnostic: a rule violated at a line of a file.
#[derive(Debug, Clone)]
pub struct Finding {
    /// Path relative to the crate's `src/`, forward slashes.
    pub file: String,
    /// 1-based line.
    pub line: usize,
    /// Rule name (from the registry, or `lint-waiver`).
    pub rule: &'static str,
    /// Human-readable explanation with the offending token.
    pub message: String,
}

/// Serving code must return `Err`, never panic.
pub const NO_PANIC: &str = "no-panic-in-serving";
/// Codec narrowing must be checked (`try_from`), never `as`.
pub const NO_LOSSY_CAST: &str = "no-lossy-cast-in-codec";
/// Ranking paths must use total orders and ordered containers.
pub const DET_ORDER: &str = "deterministic-ordering";
/// Decoded lengths must be bounds-checked before sizing allocations.
pub const VALIDATE_ALLOC: &str = "validate-before-alloc";
/// The crate forbids `unsafe` (waiver path documented for SIMD).
pub const FORBID_UNSAFE: &str = "forbid-unsafe";
/// Serving code must log through `JsonLogger`, never raw stderr.
pub const NO_RAW_STDERR: &str = "no-raw-stderr-in-serving";
/// Meta-rule for waiver hygiene; not itself waivable.
pub const LINT_WAIVER: &str = "lint-waiver";

/// Registry entry: name, what it protects, where it applies.
pub struct RuleInfo {
    /// Stable rule name, used in waivers and reports.
    pub name: &'static str,
    /// One-line description.
    pub summary: &'static str,
    /// Human-readable scope (path prefixes under `src/`).
    pub scope: &'static str,
}

/// Every rule the engine knows, in reporting order.
pub const RULES: &[RuleInfo] = &[
    RuleInfo {
        name: NO_PANIC,
        summary: "no unwrap/expect/panic-family macros in serving code; \
                  hostile bytes must surface as Err, never a crash",
        scope: "store/, net/, router/, coordinator/service.rs (non-test)",
    },
    RuleInfo {
        name: NO_LOSSY_CAST,
        summary: "no `as u8/u16/u32/usize` narrowing in codec code; \
                  use checked try_from/From conversions",
        scope: "store/codec.rs, net/protocol.rs (non-test)",
    },
    RuleInfo {
        name: DET_ORDER,
        summary: "no HashMap/HashSet or partial_cmp().unwrap() where the \
                  deterministic (distance, index) order is produced",
        scope: "nn/, pq/scan.rs, coordinator/ (non-test)",
    },
    RuleInfo {
        name: VALIDATE_ALLOC,
        summary: "allocations sized from decoded values must follow a \
                  bounds check (ensure!/checked_count/bail!) within the \
                  preceding 12 lines",
        scope: "store/, net/protocol.rs (non-test)",
    },
    RuleInfo {
        name: FORBID_UNSAFE,
        summary: "crate root carries #![forbid(unsafe_code)] and no file \
                  uses `unsafe` (SIMD tiers must waive with justification)",
        scope: "lib.rs (attribute), every file (unsafe keyword)",
    },
    RuleInfo {
        name: NO_RAW_STDERR,
        summary: "no eprintln!/eprint! in serving code; operational events \
                  must flow through obs::log::JsonLogger so operators get \
                  structured, machine-parseable output",
        scope: "net/, router/, coordinator/, jobs/ (non-test)",
    },
    RuleInfo {
        name: LINT_WAIVER,
        summary: "waivers must name a known rule, carry a reason, and \
                  actually suppress a finding",
        scope: "every file",
    },
];

/// Is `name` a rule the registry knows?
pub fn is_known_rule(name: &str) -> bool {
    RULES.iter().any(|r| r.name == name)
}

fn is_ident_byte(b: u8) -> bool {
    b == b'_' || b.is_ascii_alphanumeric()
}

/// Byte offsets where `word` occurs as a whole identifier.
fn find_words(line: &str, word: &str) -> Vec<usize> {
    let mut out = Vec::new();
    let bytes = line.as_bytes();
    for (pos, _) in line.match_indices(word) {
        let before_ok = pos == 0 || !is_ident_byte(bytes[pos - 1]);
        let end = pos + word.len();
        let after_ok = end >= bytes.len() || !is_ident_byte(bytes[end]);
        if before_ok && after_ok {
            out.push(pos);
        }
    }
    out
}

/// First non-space byte before `pos`.
fn prev_nonspace(line: &str, pos: usize) -> Option<u8> {
    line.as_bytes()[..pos].iter().rev().copied().find(|b| *b != b' ')
}

/// First non-space byte at or after `pos`.
fn next_nonspace(line: &str, pos: usize) -> Option<u8> {
    line.as_bytes()[pos..].iter().copied().find(|b| *b != b' ')
}

/// Offsets where `.name(` occurs (a method call on some receiver).
fn find_method_calls(line: &str, name: &str) -> Vec<usize> {
    find_words(line, name)
        .into_iter()
        .filter(|&pos| {
            prev_nonspace(line, pos) == Some(b'.')
                && next_nonspace(line, pos + name.len()) == Some(b'(')
        })
        .collect()
}

/// Offsets where `name!` occurs (a macro invocation).
fn find_macro_calls(line: &str, name: &str) -> Vec<usize> {
    find_words(line, name)
        .into_iter()
        .filter(|&pos| next_nonspace(line, pos + name.len()) == Some(b'!'))
        .collect()
}

/// Run every path-scoped rule over one cleaned file. `rel` is the
/// file's path relative to the crate's `src/`, with forward slashes.
pub fn check_all(rel: &str, cf: &CleanFile) -> Vec<Finding> {
    let mut out = Vec::new();
    if scope_no_panic(rel) {
        check_no_panic(rel, cf, &mut out);
    }
    if scope_lossy_cast(rel) {
        check_lossy_cast(rel, cf, &mut out);
    }
    if scope_det_order(rel) {
        check_det_order(rel, cf, &mut out);
    }
    if scope_validate_alloc(rel) {
        check_validate_alloc(rel, cf, &mut out);
    }
    if scope_raw_stderr(rel) {
        check_raw_stderr(rel, cf, &mut out);
    }
    check_forbid_unsafe(rel, cf, &mut out);
    out
}

fn scope_no_panic(rel: &str) -> bool {
    rel.starts_with("store/")
        || rel.starts_with("net/")
        || rel.starts_with("router/")
        || rel == "coordinator/service.rs"
}

fn scope_lossy_cast(rel: &str) -> bool {
    rel == "store/codec.rs" || rel == "net/protocol.rs"
}

fn scope_det_order(rel: &str) -> bool {
    rel.starts_with("nn/") || rel == "pq/scan.rs" || rel.starts_with("coordinator/")
}

fn scope_validate_alloc(rel: &str) -> bool {
    rel.starts_with("store/") || rel == "net/protocol.rs"
}

fn scope_raw_stderr(rel: &str) -> bool {
    rel.starts_with("net/")
        || rel.starts_with("router/")
        || rel.starts_with("coordinator/")
        || rel.starts_with("jobs/")
}

/// Panic surfaces: `.unwrap()` / `.expect(..)` calls and the panic
/// macro family. `debug_assert*` is deliberately allowed (compiled out
/// of release serving binaries); `unwrap_or*` / `expect_err` never
/// match because the match is whole-identifier.
fn check_no_panic(rel: &str, cf: &CleanFile, out: &mut Vec<Finding>) {
    const METHODS: [&str; 2] = ["unwrap", "expect"];
    const MACROS: [&str; 7] = [
        "panic",
        "unreachable",
        "todo",
        "unimplemented",
        "assert",
        "assert_eq",
        "assert_ne",
    ];
    for (idx, line) in cf.lines.iter().enumerate() {
        if cf.is_test[idx] {
            continue;
        }
        for m in METHODS {
            if !find_method_calls(line, m).is_empty() {
                out.push(Finding {
                    file: rel.to_string(),
                    line: idx + 1,
                    rule: NO_PANIC,
                    message: format!(
                        ".{m}() can panic in serving code — propagate an Err \
                         (anyhow context) instead, or waive a proven-infallible case"
                    ),
                });
            }
        }
        for m in MACROS {
            if !find_macro_calls(line, m).is_empty() {
                out.push(Finding {
                    file: rel.to_string(),
                    line: idx + 1,
                    rule: NO_PANIC,
                    message: format!(
                        "{m}! aborts the serving thread — hostile input must \
                         surface as Err, not a panic"
                    ),
                });
            }
        }
    }
}

/// Narrowing casts in the byte codecs: `as u8/u16/u32/usize` silently
/// truncates on the very inputs the hostile-byte sweeps exist for.
fn check_lossy_cast(rel: &str, cf: &CleanFile, out: &mut Vec<Finding>) {
    const NARROW: [&str; 4] = ["u8", "u16", "u32", "usize"];
    for (idx, line) in cf.lines.iter().enumerate() {
        if cf.is_test[idx] {
            continue;
        }
        for pos in find_words(line, "as") {
            let rest = line[pos + 2..].trim_start();
            let end = rest
                .char_indices()
                .find(|&(_, c)| !(c.is_ascii_alphanumeric() || c == '_'))
                .map(|(i, _)| i)
                .unwrap_or(rest.len());
            let word = &rest[..end];
            if NARROW.contains(&word) {
                out.push(Finding {
                    file: rel.to_string(),
                    line: idx + 1,
                    rule: NO_LOSSY_CAST,
                    message: format!(
                        "`as {word}` can silently truncate decoded values — \
                         use {word}::try_from (or a widening From) so hostile \
                         lengths fail loudly"
                    ),
                });
            }
        }
    }
}

/// Ordering hazards on the ranking paths: hash-iteration order leaks
/// into results, and `partial_cmp().unwrap()` both panics on NaN and
/// documents a non-total order where the (distance, index) contract
/// requires `total_cmp`.
fn check_det_order(rel: &str, cf: &CleanFile, out: &mut Vec<Finding>) {
    for (idx, line) in cf.lines.iter().enumerate() {
        if cf.is_test[idx] {
            continue;
        }
        for container in ["HashMap", "HashSet"] {
            if !find_words(line, container).is_empty() {
                out.push(Finding {
                    file: rel.to_string(),
                    line: idx + 1,
                    rule: DET_ORDER,
                    message: format!(
                        "{container} iteration order is nondeterministic — use a \
                         sorted structure (Vec + sort, BTreeMap) on ranking paths"
                    ),
                });
            }
        }
        for pos in find_method_calls(line, "partial_cmp") {
            let tail_same = &line[pos..];
            let next = match cf.lines.get(idx + 1) {
                Some(l) => l.as_str(),
                None => "",
            };
            let chained = format!("{tail_same} {next}");
            if !find_method_calls(&chained, "unwrap").is_empty()
                || !find_method_calls(&chained, "expect").is_empty()
            {
                out.push(Finding {
                    file: rel.to_string(),
                    line: idx + 1,
                    rule: DET_ORDER,
                    message: "partial_cmp().unwrap() panics on NaN and is not a \
                              total order — use f64::total_cmp"
                        .to_string(),
                });
            }
        }
    }
}

/// Is the cleaned expression a plain integer literal (possibly with
/// `_` separators or a type suffix)?
fn is_int_literal(expr: &str) -> bool {
    let t = expr.trim();
    if t.is_empty() || !t.as_bytes()[0].is_ascii_digit() {
        return false;
    }
    t.bytes().all(is_ident_byte)
}

/// Text between the `(` following byte offset `after` and its matching
/// `)` on the same line (best-effort: empty when it spills over).
fn paren_arg(line: &str, after: usize) -> Option<&str> {
    let bytes = line.as_bytes();
    let mut i = after;
    while i < bytes.len() && bytes[i] == b' ' {
        i += 1;
    }
    if i >= bytes.len() || bytes[i] != b'(' {
        return None;
    }
    let start = i + 1;
    let mut depth = 1usize;
    let mut j = start;
    while j < bytes.len() {
        match bytes[j] {
            b'(' => depth += 1,
            b')' => {
                depth -= 1;
                if depth == 0 {
                    return Some(&line[start..j]);
                }
            }
            _ => {}
        }
        j += 1;
    }
    None
}

/// Does any of the `window` cleaned lines ending at `idx` (inclusive)
/// carry a bounds check?
fn guarded(cf: &CleanFile, idx: usize, window: usize) -> bool {
    let lo = idx.saturating_sub(window);
    cf.lines[lo..=idx].iter().any(|l| {
        !find_macro_calls(l, "ensure").is_empty()
            || !find_macro_calls(l, "bail").is_empty()
            || !find_words(l, "checked_count").is_empty()
    })
}

/// Window of preceding lines in which `guarded` looks for a check.
const GUARD_WINDOW: usize = 12;

/// Allocations sized by freshly decoded values: `with_capacity(n)` and
/// `vec![x; n]` where `n` is not a literal must sit within
/// `GUARD_WINDOW` lines of an explicit bounds check, so a hostile
/// length prefix can never drive an unbounded allocation.
fn check_validate_alloc(rel: &str, cf: &CleanFile, out: &mut Vec<Finding>) {
    for (idx, line) in cf.lines.iter().enumerate() {
        if cf.is_test[idx] {
            continue;
        }
        for pos in find_words(line, "with_capacity") {
            let arg = paren_arg(line, pos + "with_capacity".len());
            let sized_from_value = match arg {
                // `.len()` of an existing container is not a decoded value.
                Some(a) => !is_int_literal(a) && find_method_calls(a, "len").is_empty(),
                None => true, // spills the line: demand a guard
            };
            if sized_from_value && !guarded(cf, idx, GUARD_WINDOW) {
                out.push(Finding {
                    file: rel.to_string(),
                    line: idx + 1,
                    rule: VALIDATE_ALLOC,
                    message: format!(
                        "with_capacity sized from a runtime value without a \
                         bounds check in the preceding {GUARD_WINDOW} lines — \
                         validate the decoded length (ensure!/checked_count) first"
                    ),
                });
            }
        }
        for pos in find_macro_calls(line, "vec") {
            // Repeat form only: vec![elem; count].
            let Some(open) = line[pos..].find('[') else { continue };
            let body_start = pos + open + 1;
            let bytes = line.as_bytes();
            let mut depth = 1usize;
            let mut semi = None;
            let mut close = None;
            let mut j = body_start;
            while j < bytes.len() {
                match bytes[j] {
                    b'[' => depth += 1,
                    b']' => {
                        depth -= 1;
                        if depth == 0 {
                            close = Some(j);
                            break;
                        }
                    }
                    b';' if depth == 1 => semi = Some(j),
                    _ => {}
                }
                j += 1;
            }
            let (Some(semi), Some(close)) = (semi, close) else { continue };
            let count = &line[semi + 1..close];
            // `.len()` of an existing container is not a decoded value.
            if !is_int_literal(count)
                && find_method_calls(count, "len").is_empty()
                && !guarded(cf, idx, GUARD_WINDOW)
            {
                out.push(Finding {
                    file: rel.to_string(),
                    line: idx + 1,
                    rule: VALIDATE_ALLOC,
                    message: format!(
                        "vec![_; n] sized from a runtime value without a bounds \
                         check in the preceding {GUARD_WINDOW} lines — validate \
                         the decoded length (ensure!/checked_count) first"
                    ),
                });
            }
        }
    }
}

/// Raw stderr in the serving plane: ad-hoc `eprintln!` lines are
/// invisible to log pipelines and interleave across threads. Serving
/// code must emit events through `obs::log::JsonLogger`, which is
/// line-atomic and machine-parseable (`serve --log-json`).
fn check_raw_stderr(rel: &str, cf: &CleanFile, out: &mut Vec<Finding>) {
    const MACROS: [&str; 2] = ["eprintln", "eprint"];
    for (idx, line) in cf.lines.iter().enumerate() {
        if cf.is_test[idx] {
            continue;
        }
        for m in MACROS {
            if !find_macro_calls(line, m).is_empty() {
                out.push(Finding {
                    file: rel.to_string(),
                    line: idx + 1,
                    rule: NO_RAW_STDERR,
                    message: format!(
                        "{m}! writes unstructured text to stderr from serving \
                         code — emit an obs::log::JsonLogger event instead \
                         (waive only for pre-logger bootstrap failures)"
                    ),
                });
            }
        }
    }
}

/// `unsafe` is forbidden everywhere, and the crate root must say so
/// (`#![forbid(unsafe_code)]`) so rustc enforces it even where the
/// token scan cannot see (macro expansions).
fn check_forbid_unsafe(rel: &str, cf: &CleanFile, out: &mut Vec<Finding>) {
    for (idx, line) in cf.lines.iter().enumerate() {
        if cf.is_test[idx] {
            continue;
        }
        if !find_words(line, "unsafe").is_empty() {
            out.push(Finding {
                file: rel.to_string(),
                line: idx + 1,
                rule: FORBID_UNSAFE,
                message: "`unsafe` is forbidden in this crate — a vetted SIMD \
                          tier must carry a waiver with its safety argument"
                    .to_string(),
            });
        }
    }
    if rel == "lib.rs" {
        let squashed: String =
            cf.lines.join("").chars().filter(|c| !c.is_whitespace()).collect();
        if !squashed.contains("#![forbid(unsafe_code)]") {
            out.push(Finding {
                file: rel.to_string(),
                line: 1,
                rule: FORBID_UNSAFE,
                message: "crate root is missing #![forbid(unsafe_code)] — the \
                          compiler-enforced twin of this rule"
                    .to_string(),
            });
        }
    }
}
