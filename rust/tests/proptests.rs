//! Property-based invariant tests over the whole distance / PQ stack,
//! via the seeded harness in `pqdtw::testutil` (proptest is unavailable
//! in the offline crate set). Every failure message includes the seed to
//! reproduce: `PQDTW_PROP_SEED=<seed> cargo test -p pqdtw --test proptests`.

use pqdtw::coordinator::{Engine, Request};
use pqdtw::core::preprocess::{reinterpolate, znorm};
use pqdtw::core::rng::Rng;
use pqdtw::core::series::Dataset;
use pqdtw::distance::dtw::{dtw, dtw_sq};
use pqdtw::distance::envelope::Envelope;
use pqdtw::distance::euclidean::euclidean_sq;
use pqdtw::distance::lower_bounds::{lb_cascade_sq, lb_keogh_sq, lb_kim_sq};
use pqdtw::distance::pruned_dtw::pruned_dtw_sq;
use pqdtw::distance::sbd::sbd;
use pqdtw::nn::ivf::CoarseMetric;
use pqdtw::nn::knn::PqQueryMode;
use pqdtw::pq::quantizer::{PqConfig, PqMetric, PrealignConfig, ProductQuantizer};
use pqdtw::repr::sax::SaxEncoder;
use pqdtw::testutil::{
    check, close, default_cases, gen_len, gen_series, gen_walk, leq, unique_temp_dir,
};
use pqdtw::wavelet::modwt::modwt_scale;

#[test]
fn prop_dtw_identity_symmetry_nonneg() {
    check("dtw axioms", default_cases(), |rng| {
        let n = gen_len(rng, 2, 40);
        let a = gen_walk(rng, n);
        let b = gen_walk(rng, n);
        let w = if rng.below(2) == 0 { None } else { Some(rng.below(n)) };
        close(dtw_sq(&a, &a, w), 0.0, 1e-12)?;
        let d_ab = dtw_sq(&a, &b, w);
        let d_ba = dtw_sq(&b, &a, w);
        if d_ab < 0.0 {
            return Err(format!("negative distance {d_ab}"));
        }
        close(d_ab, d_ba, 1e-9)
    });
}

#[test]
fn prop_lower_bound_chain() {
    // LB_Kim <= DTW_w, LB_Keogh <= DTW_w, DTW_w <= ED (equal lengths).
    check("lb chain", default_cases(), |rng| {
        let n = gen_len(rng, 2, 40);
        let q = gen_walk(rng, n);
        let c = gen_walk(rng, n);
        let w = rng.below(n);
        let env = Envelope::new(&c, w);
        let d = dtw_sq(&q, &c, Some(w));
        leq(lb_kim_sq(&q, &c), d, 1e-9)?;
        leq(lb_keogh_sq(&q, &env, f64::INFINITY), d, 1e-9)?;
        leq(lb_cascade_sq(&q, &c, &env, f64::INFINITY), d, 1e-9)?;
        leq(d, euclidean_sq(&q, &c), 1e-9)
    });
}

#[test]
fn prop_pruned_dtw_is_exact() {
    check("pruned == exact under valid ub", default_cases(), |rng| {
        let n = gen_len(rng, 2, 35);
        let a = gen_walk(rng, n);
        let b = gen_walk(rng, n);
        let w = if rng.below(2) == 0 { None } else { Some(1 + rng.below(n)) };
        let ub = euclidean_sq(&a, &b) + 1e-9;
        close(pruned_dtw_sq(&a, &b, w, ub), dtw_sq(&a, &b, w), 1e-9)
    });
}

#[test]
fn prop_window_monotone() {
    check("window monotone", default_cases(), |rng| {
        let n = gen_len(rng, 4, 30);
        let a = gen_walk(rng, n);
        let b = gen_walk(rng, n);
        let mut last = f64::INFINITY;
        for w in 0..n {
            let d = dtw_sq(&a, &b, Some(w));
            leq(d, last, 1e-9)?;
            last = d;
        }
        Ok(())
    });
}

#[test]
fn prop_envelope_widens_with_window() {
    check("envelope monotone in w", default_cases(), |rng| {
        let n = gen_len(rng, 2, 50);
        let c = gen_series(rng, n);
        let mut prev = Envelope::new(&c, 0);
        for w in 1..n.min(12) {
            let e = Envelope::new(&c, w);
            for i in 0..n {
                leq(prev.upper[i], e.upper[i], 1e-12)?;
                leq(e.lower[i], prev.lower[i], 1e-12)?;
            }
            prev = e;
        }
        Ok(())
    });
}

#[test]
fn prop_sbd_range_and_self() {
    check("sbd range", default_cases(), |rng| {
        let n = 1 << (2 + rng.below(5));
        let a = znorm(&gen_series(rng, n));
        let b = znorm(&gen_series(rng, n));
        let d = sbd(&a, &b);
        if !(-1e-9..=2.0 + 1e-9).contains(&d) {
            return Err(format!("sbd out of range: {d}"));
        }
        close(sbd(&a, &a), 0.0, 1e-9)
    });
}

#[test]
fn prop_sax_mindist_lower_bounds_ed() {
    check("sax lb", default_cases(), |rng| {
        let n = gen_len(rng, 10, 60);
        let a = znorm(&gen_series(rng, n));
        let b = znorm(&gen_series(rng, n));
        let enc = SaxEncoder::new(n, 4, 0.2);
        let lb = enc.mindist(&enc.encode(&a), &enc.encode(&b));
        leq(lb, euclidean_sq(&a, &b).sqrt(), 1e-9)
    });
}

#[test]
fn prop_modwt_preserves_mean() {
    check("modwt mean", default_cases(), |rng| {
        let n = gen_len(rng, 4, 64);
        let x = gen_series(rng, n);
        let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
        for level in 1..=4 {
            close(mean(&modwt_scale(&x, level)), mean(&x), 1e-9)?;
        }
        Ok(())
    });
}

#[test]
fn prop_reinterpolate_preserves_endpoints_and_range() {
    check("reinterp", default_cases(), |rng| {
        let n = gen_len(rng, 2, 40);
        let x = gen_series(rng, n);
        let target = gen_len(rng, 2, 60);
        let y = reinterpolate(&x, target);
        if y.len() != target {
            return Err("length".into());
        }
        close(y[0], x[0], 1e-12)?;
        close(*y.last().unwrap(), *x.last().unwrap(), 1e-12)?;
        let (lo, hi) = x.iter().fold((f64::INFINITY, f64::NEG_INFINITY), |(l, h), &v| {
            (l.min(v), h.max(v))
        });
        for &v in &y {
            if v < lo - 1e-9 || v > hi + 1e-9 {
                return Err(format!("interp escaped range: {v} not in [{lo}, {hi}]"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_pq_symmetric_distance_axioms() {
    // Symmetry, zero-self, and non-negativity of the PQ symmetric
    // distance; patched >= plain; asymmetric self-consistency.
    check("pq distance axioms", 12, |rng| {
        let n = 16 + rng.below(16);
        let len = 48 + 4 * rng.below(8);
        let mut values = Vec::with_capacity(n * len);
        for _ in 0..n {
            values.extend(gen_walk(rng, len));
        }
        let data = Dataset::from_flat(values, len);
        let prealign = if rng.below(2) == 0 {
            None
        } else {
            Some(PrealignConfig { level: 1 + rng.below(3), tail_frac: 0.15 })
        };
        let cfg = PqConfig {
            n_subspaces: 2 + rng.below(3),
            codebook_size: 4 + rng.below(8),
            window_frac: 0.2,
            metric: if rng.below(4) == 0 { PqMetric::Euclidean } else { PqMetric::Dtw },
            prealign,
            kmeans_iters: 3,
            dba_iters: 2,
            train_subsample: None,
        };
        let pq = ProductQuantizer::train(&data, &cfg, rng.next_u64()).map_err(|e| e.to_string())?;
        let enc = pq.encode_dataset(&data);
        for _ in 0..8 {
            let i = rng.below(n);
            let j = rng.below(n);
            let d_ij = pq.symmetric_distance(enc.code(i), enc.code(j));
            let d_ji = pq.symmetric_distance(enc.code(j), enc.code(i));
            close(d_ij, d_ji, 1e-9)?;
            if d_ij < 0.0 {
                return Err("negative".into());
            }
            close(pq.symmetric_distance(enc.code(i), enc.code(i)), 0.0, 1e-12)?;
            let p = pq.patched_distance(&enc, i, j);
            leq(d_ij, p, 1e-9)?;
        }
        Ok(())
    });
}

#[test]
fn prop_encoded_codes_in_range() {
    check("codes in range", 10, |rng| {
        let n = 12 + rng.below(12);
        let len = 40 + rng.below(40);
        let mut values = Vec::with_capacity(n * len);
        for _ in 0..n {
            values.extend(gen_series(rng, len));
        }
        let data = Dataset::from_flat(values, len);
        let cfg = PqConfig {
            n_subspaces: 2 + rng.below(4),
            codebook_size: 3 + rng.below(10),
            window_frac: 0.3,
            ..Default::default()
        };
        let pq = ProductQuantizer::train(&data, &cfg, rng.next_u64()).map_err(|e| e.to_string())?;
        let enc = pq.encode_dataset(&data);
        let k = pq.codebook.k as u16;
        for &c in &enc.codes {
            if c >= k {
                return Err(format!("code {c} >= K {k}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_store_roundtrip_serves_bit_identically() {
    // `Engine::open(save(engine))` must answer every serving mode —
    // exhaustive, probed, re-ranked, 1-NN — bit-identically to the
    // in-memory engine it was saved from, across random datasets,
    // configs, metrics, pre-alignment and optional IVF indexes.
    check("store roundtrip", 5, |rng| {
        let n = 12 + rng.below(10);
        let len = 32 + 4 * rng.below(6);
        let mut values = Vec::with_capacity(n * len);
        for _ in 0..n {
            values.extend(gen_walk(rng, len));
        }
        let data = Dataset::from_flat(values, len);
        let cfg = PqConfig {
            n_subspaces: 2 + rng.below(3),
            codebook_size: 4 + rng.below(6),
            window_frac: 0.25,
            metric: if rng.below(3) == 0 { PqMetric::Euclidean } else { PqMetric::Dtw },
            prealign: if rng.below(2) == 0 {
                None
            } else {
                Some(PrealignConfig { level: 2, tail_frac: 0.15 })
            },
            kmeans_iters: 2,
            dba_iters: 1,
            train_subsample: None,
        };
        let mut engine = Engine::build(&data, &cfg, rng.next_u64()).map_err(|e| e.to_string())?;
        if rng.below(2) == 0 {
            engine.enable_ivf(1 + rng.below(5), CoarseMetric::Euclidean, rng.next_u64());
        }
        let dir = unique_temp_dir("store_prop");
        let path = dir.join("index.pqx");
        engine.save(&path).map_err(|e| e.to_string())?;
        let reopened = Engine::open(&path).map_err(|e| e.to_string())?;
        std::fs::remove_dir_all(&dir).ok();
        let nlist = engine.ivf.as_ref().map(|ivf| ivf.nlist());
        for _ in 0..4 {
            let q = gen_walk(rng, len);
            let k = 1 + rng.below(5);
            let mode = if rng.below(2) == 0 {
                PqQueryMode::Symmetric
            } else {
                PqQueryMode::Asymmetric
            };
            let mut reqs = vec![
                Request::TopKQuery { series: q.clone(), k, mode, nprobe: None, rerank: None },
                Request::TopKQuery {
                    series: q.clone(),
                    k,
                    mode,
                    nprobe: None,
                    rerank: Some(k + 4),
                },
                Request::NnQuery { series: q.clone(), mode, nprobe: None },
            ];
            if let Some(nl) = nlist {
                reqs.push(Request::TopKQuery {
                    series: q,
                    k,
                    mode,
                    nprobe: Some(1 + rng.below(nl)),
                    rerank: None,
                });
            }
            for req in reqs {
                let a = engine.handle(&req);
                let b = reopened.handle(&req);
                if a != b {
                    return Err(format!("divergent responses for {req:?}: {a:?} vs {b:?}"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_blocked_scan_kernel_bit_identical_to_scalar() {
    // The blocked kernel (query-collapsed LUT over segment-major code
    // blocks) must produce bit-identical squared distances to the
    // scalar reference in all three modes — symmetric, Keogh-patched,
    // asymmetric — across u8/u16 lane widths, block-remainder sizes,
    // and with the pruning cascade on or off.
    use pqdtw::pq::codebook::Codebook;
    use pqdtw::pq::distance::{asymmetric_sq, patched_symmetric_sq, symmetric_sq};
    use pqdtw::pq::encode::{CodeBlocks, SCAN_BLOCK};
    use pqdtw::pq::scan::{scan_block, CollapsedLut};

    check("blocked kernel == scalar", 8, |rng| {
        // Synthetic codebooks straight from random centroids: cheap,
        // and lets K exceed 256 to exercise the u16 lane path.
        let m = 1 + rng.below(4);
        let (k, l) = if rng.below(4) == 0 {
            (257 + rng.below(8), 3)
        } else {
            (2 + rng.below(40), 4 + rng.below(6))
        };
        let per: Vec<Vec<f64>> = (0..m).map(|_| gen_series(rng, k * l)).collect();
        let cb = Codebook::build(per, l, Some(1), PqMetric::Dtw);
        // n spans the block-remainder cases: one short of a block, an
        // exact block, one over, and arbitrary multi-block sizes.
        let n = match rng.below(4) {
            0 => SCAN_BLOCK - 1,
            1 => SCAN_BLOCK,
            2 => SCAN_BLOCK + 1,
            _ => 1 + rng.below(3 * SCAN_BLOCK),
        };
        let mut codes: Vec<u16> = (0..n * m).map(|_| rng.below(k) as u16).collect();
        let lb: Vec<f64> = (0..n * m).map(|_| rng.uniform() * 2.0).collect();
        // Query side for each mode.
        let cx: Vec<u16> = (0..m).map(|_| rng.below(k) as u16).collect();
        let lbx: Vec<f64> = (0..m).map(|_| rng.uniform() * 2.0).collect();
        let qtab: Vec<f64> = (0..m * k).map(|_| rng.uniform() * 3.0).collect();
        // Plant diagonal hits so the patched substitution actually runs.
        for i in (0..n).step_by(5) {
            let s = i % m;
            codes[i * m + s] = cx[s];
        }
        let blocks = CodeBlocks::build(&codes, &lb, m, k);
        if (k <= 256) != blocks.uses_u8() {
            return Err(format!("lane width mis-dispatched for K={k}"));
        }
        let luts = [
            ("symmetric", CollapsedLut::symmetric(&cb, &cx)),
            ("patched", CollapsedLut::patched(&cb, &cx, &lbx)),
            ("asymmetric", CollapsedLut::asymmetric(&cb, &qtab)),
        ];
        for (name, lut) in &luts {
            let want: Vec<f64> = (0..n)
                .map(|i| {
                    let cy = &codes[i * m..(i + 1) * m];
                    let lby = &lb[i * m..(i + 1) * m];
                    match *name {
                        "symmetric" => symmetric_sq(&cb, &cx, cy),
                        "patched" => patched_symmetric_sq(&cb, &cx, cy, &lbx, lby),
                        _ => asymmetric_sq(&cb, &qtab, cy),
                    }
                })
                .collect();
            // Kernel scalar path.
            for (i, &w) in want.iter().enumerate() {
                let got = lut.dist_sq(&codes[i * m..(i + 1) * m], &lb[i * m..(i + 1) * m]);
                if got.to_bits() != w.to_bits() {
                    return Err(format!("{name}: scalar kernel item {i}: {got} != {w}"));
                }
            }
            // Blocked path, pruning off: every item emitted, bit-identical.
            let mut got = vec![f64::NAN; n];
            let mut emitted = 0usize;
            for b in 0..blocks.n_blocks() {
                let hi = (n - b * SCAN_BLOCK).min(SCAN_BLOCK);
                scan_block(lut, &blocks, b, 0, hi, f64::INFINITY, |lane, d| {
                    got[b * SCAN_BLOCK + lane] = d;
                    emitted += 1;
                });
            }
            if emitted != n {
                return Err(format!("{name}: emitted {emitted} of {n} items"));
            }
            for (i, &w) in want.iter().enumerate() {
                if got[i].to_bits() != w.to_bits() {
                    return Err(format!("{name}: blocked item {i}: {} != {w}", got[i]));
                }
            }
            // Blocked path, pruning on at a mid-range threshold: emitted
            // items bit-identical, pruned items strictly over threshold.
            let mut sorted = want.clone();
            sorted.sort_by(f64::total_cmp);
            let thr = sorted[n / 2];
            let mut seen = vec![false; n];
            for b in 0..blocks.n_blocks() {
                let hi = (n - b * SCAN_BLOCK).min(SCAN_BLOCK);
                scan_block(lut, &blocks, b, 0, hi, thr, |lane, d| {
                    let i = b * SCAN_BLOCK + lane;
                    seen[i] = d.to_bits() == want[i].to_bits();
                });
            }
            for (i, &w) in want.iter().enumerate() {
                if !seen[i] && w <= thr {
                    return Err(format!(
                        "{name}: admissible item {i} (d={w}, thr={thr}) was pruned"
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_pruned_blocked_topk_matches_unpruned_and_scalar() {
    // Threshold-pruning soundness on a real trained quantizer: the
    // pruned blocked scan must return exactly the same top-k set (same
    // ids, same bit-level distances) as the unpruned blocked scan and
    // the scalar reference, in both query modes and under sharding.
    use pqdtw::nn::topk::{topk_scan_blocked_opts, topk_scan_scalar, QueryLut};

    check("pruned topk == unpruned", 5, |rng| {
        let n = 80 + rng.below(150);
        let len = 32 + 4 * rng.below(5);
        let mut values = Vec::with_capacity(n * len);
        for _ in 0..n {
            values.extend(gen_walk(rng, len));
        }
        let data = Dataset::from_flat(values, len);
        let cfg = PqConfig {
            n_subspaces: 2 + rng.below(3),
            codebook_size: 4 + rng.below(12),
            window_frac: 0.25,
            metric: if rng.below(4) == 0 { PqMetric::Euclidean } else { PqMetric::Dtw },
            kmeans_iters: 2,
            dba_iters: 1,
            ..Default::default()
        };
        let pq = ProductQuantizer::train(&data, &cfg, rng.next_u64()).map_err(|e| e.to_string())?;
        let enc = pq.encode_dataset(&data);
        let blocks = enc.to_blocks(pq.codebook.k);
        for mode in [PqQueryMode::Symmetric, PqQueryMode::Asymmetric] {
            let q = gen_walk(rng, len);
            let lut = QueryLut::build(&pq, &q, mode);
            let clut = lut.collapse(&pq.codebook);
            let k = 1 + rng.below(9);
            let scalar = topk_scan_scalar(&pq, &enc, &lut, k);
            let unpruned = topk_scan_blocked_opts(&blocks, &clut, k, 1, false);
            let pruned = topk_scan_blocked_opts(&blocks, &clut, k, 1, true);
            let sharded = topk_scan_blocked_opts(&blocks, &clut, k, 1 + rng.below(4), true);
            if scalar != unpruned {
                return Err(format!("{mode:?}: unpruned blocked != scalar"));
            }
            if scalar != pruned {
                return Err(format!("{mode:?}: pruned blocked != scalar"));
            }
            if scalar != sharded {
                return Err(format!("{mode:?}: sharded pruned blocked != scalar"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_traced_scan_is_bit_transparent_and_counters_conserved() {
    // Kernel accounting must be a pure observer: the traced blocked
    // scan (`stats: Some`) returns bit-identical neighbours to the
    // untraced one for every mode / prune / shard combination, and its
    // counters obey the conservation law
    // `items_scanned - items_abandoned == items emitted`:
    //   - scanned is always exactly n (tail padding never counted);
    //   - with pruning off nothing is ever abandoned;
    //   - with pruning on at k >= n every item must survive the
    //     cascade (the collector keeps everything), so abandoned == 0;
    //   - with pruning on at k < n the k survivors were necessarily
    //     emitted, so abandoned <= n - k.
    use pqdtw::nn::topk::{topk_scan_blocked_opts, topk_scan_blocked_stats, QueryLut};
    use pqdtw::obs::ScanStats;

    check("traced scan == untraced + conservation", 5, |rng| {
        let n = 80 + rng.below(150);
        let len = 32 + 4 * rng.below(5);
        let mut values = Vec::with_capacity(n * len);
        for _ in 0..n {
            values.extend(gen_walk(rng, len));
        }
        let data = Dataset::from_flat(values, len);
        let cfg = PqConfig {
            n_subspaces: 2 + rng.below(3),
            codebook_size: 4 + rng.below(12),
            window_frac: 0.25,
            metric: if rng.below(4) == 0 { PqMetric::Euclidean } else { PqMetric::Dtw },
            kmeans_iters: 2,
            dba_iters: 1,
            ..Default::default()
        };
        let pq = ProductQuantizer::train(&data, &cfg, rng.next_u64()).map_err(|e| e.to_string())?;
        let enc = pq.encode_dataset(&data);
        let blocks = enc.to_blocks(pq.codebook.k);
        for mode in [PqQueryMode::Symmetric, PqQueryMode::Asymmetric] {
            let q = gen_walk(rng, len);
            let lut = QueryLut::build(&pq, &q, mode);
            let clut = lut.collapse(&pq.codebook);
            for k in [1 + rng.below(9), n + rng.below(4)] {
                for prune in [false, true] {
                    for threads in [1, 1 + rng.below(4)] {
                        let tag = format!("{mode:?} k={k} prune={prune} threads={threads}");
                        let plain = topk_scan_blocked_opts(&blocks, &clut, k, threads, prune);
                        let sink = ScanStats::new();
                        let traced = topk_scan_blocked_stats(
                            &blocks, &clut, k, threads, prune,
                            Some(&sink),
                        );
                        if plain != traced {
                            return Err(format!("{tag}: traced scan diverged"));
                        }
                        let s = sink.snapshot();
                        if s.items_scanned != n as u64 {
                            return Err(format!(
                                "{tag}: scanned {} of {n} items",
                                s.items_scanned
                            ));
                        }
                        let survivors = k.min(n) as u64;
                        let emitted = s.items_scanned - s.items_abandoned;
                        if emitted < survivors {
                            return Err(format!(
                                "{tag}: {emitted} emitted < {survivors} survivors \
                                 (conservation violated)"
                            ));
                        }
                        if (!prune || k >= n) && s.items_abandoned != 0 {
                            return Err(format!(
                                "{tag}: abandoned {} items with nothing to prune",
                                s.items_abandoned
                            ));
                        }
                        if (!prune || k >= n) && s.blocks_skipped != 0 {
                            return Err(format!(
                                "{tag}: skipped {} blocks with nothing to prune",
                                s.blocks_skipped
                            ));
                        }
                        if s.shards == 0 {
                            return Err(format!("{tag}: no shard timings recorded"));
                        }
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_dtw_triangle_violations_exist_but_bounded_scaling() {
    // DTW is not a metric (no triangle inequality) — but sqrt-costs must
    // still scale linearly under uniform scaling of inputs.
    check("dtw scaling", default_cases(), |rng| {
        let n = gen_len(rng, 2, 30);
        let a = gen_walk(rng, n);
        let b = gen_walk(rng, n);
        let s = 0.5 + rng.uniform() * 3.0;
        let a2: Vec<f64> = a.iter().map(|v| v * s).collect();
        let b2: Vec<f64> = b.iter().map(|v| v * s).collect();
        close(dtw(&a2, &b2, None), s * dtw(&a, &b, None), 1e-6)
    });
}

#[test]
fn prop_bucket_merge_is_associative_commutative_and_exact() {
    // The router's histogram federation must be a true monoid fold:
    // element-wise bucket addition is associative and commutative, and
    // the percentile of the merged distribution must equal the
    // percentile computed over one histogram of every shard's raw
    // observations concatenated — the property the old fleet-max
    // "merge" lacked.
    use pqdtw::coordinator::{histogram_percentile, BUCKETS_US};
    use pqdtw::router::{bucket_percentile, merge_buckets};

    // One raw latency, spread over the full bucket range including
    // the `u64::MAX` overflow bucket.
    fn gen_latency(rng: &mut Rng) -> u64 {
        match rng.below(4) {
            0 => rng.below(10) as u64,
            1 => rng.below(1_000) as u64,
            2 => rng.below(60_000) as u64,
            _ => 50_001 + rng.below(1_000_000) as u64,
        }
    }
    // Per-bucket counts exactly as `Metrics::record_request` buckets:
    // first upper bound with `v <= ub` wins.
    fn bucketize(obs: &[u64]) -> Vec<u64> {
        let mut row = vec![0u64; BUCKETS_US.len()];
        for &v in obs {
            if let Some(idx) = BUCKETS_US.iter().position(|&ub| v <= ub) {
                row[idx] += 1;
            }
        }
        row
    }

    check("bucket merge monoid", default_cases(), |rng| {
        let n_shards = 1 + rng.below(5);
        let mut shards: Vec<Vec<u64>> = Vec::with_capacity(n_shards);
        for _ in 0..n_shards {
            let n_obs = rng.below(40);
            shards.push((0..n_obs).map(|_| gen_latency(rng)).collect());
        }
        let rows: Vec<Vec<u64>> = shards.iter().map(|obs| bucketize(obs)).collect();
        // Commutative: merging in reverse shard order changes nothing.
        let fwd = merge_buckets(rows.iter().map(Vec::as_slice));
        let rev = merge_buckets(rows.iter().rev().map(Vec::as_slice));
        if fwd != rev {
            return Err("merge is order-sensitive".into());
        }
        // Associative: a pairwise left fold equals the one-shot merge.
        let mut acc = vec![0u64; BUCKETS_US.len()];
        for row in &rows {
            acc = merge_buckets([acc.as_slice(), row.as_slice()].into_iter());
        }
        if acc != fwd {
            return Err("pairwise fold != one-shot merge".into());
        }
        // Exactness: merged percentiles equal percentiles of the
        // global histogram over all raw observations concatenated.
        let all: Vec<u64> = shards.iter().flatten().copied().collect();
        let global: Vec<(u64, u64)> =
            BUCKETS_US.iter().zip(bucketize(&all)).map(|(&ub, c)| (ub, c)).collect();
        for &p in &[0.5, 0.9, 0.99, 1.0] {
            let merged = bucket_percentile(&fwd, p);
            let exact = histogram_percentile(&global, p);
            if merged != exact {
                return Err(format!("p={p}: merged {merged}us != concatenated {exact}us"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_shard_split_merge_is_bit_identical_to_unsharded() {
    // The router's bit-identity chain, without sockets: for every
    // `id % n` split (n ∈ {1, 2, 3, 5}), merging the shards' exhaustive
    // top-k / 1-NN answers through the deterministic `(distance, index)`
    // order must reproduce the unsharded engine's answer bit for bit —
    // including tie-heavy databases (duplicated rows) and NaN-poisoned
    // queries, where only the total order keeps the result well-defined.
    use pqdtw::coordinator::{Hit, Response};
    use pqdtw::router::{merge_nn, merge_topk};
    check("shard split merge", 5, |rng| {
        let m = 4 + rng.below(4); // distinct base rows
        let len = 32 + 4 * rng.below(4);
        let reps = 2 + rng.below(2); // duplicates ⇒ exact distance ties
        let mut bases = Vec::with_capacity(m);
        for _ in 0..m {
            bases.push(gen_walk(rng, len));
        }
        let n = m * reps + 4;
        let mut values = Vec::with_capacity(n * len);
        for i in 0..n {
            if i < m * reps {
                values.extend(bases[i % m].iter().copied());
            } else {
                values.extend(gen_walk(rng, len));
            }
        }
        let data = Dataset::from_flat(values, len);
        let cfg = PqConfig {
            n_subspaces: 2 + rng.below(2),
            codebook_size: 4 + rng.below(4),
            window_frac: 0.25,
            kmeans_iters: 2,
            dba_iters: 1,
            ..Default::default()
        };
        let seed = rng.next_u64();
        let oracle = Engine::build(&data, &cfg, seed).map_err(|e| e.to_string())?;
        for shards in [1u64, 2, 3, 5] {
            let fleet: Vec<Engine> = (0..shards)
                .map(|i| Engine::build_shard(&data, &cfg, seed, i, shards))
                .collect::<Result<_, _>>()
                .map_err(|e| e.to_string())?;
            for case in 0..3 {
                let mut q = gen_walk(rng, len);
                if case == 2 {
                    // NaN-adjacent distances: the poisoned query makes
                    // every row's distance NaN on both sides.
                    q[rng.below(len)] = f64::NAN;
                }
                let k = 1 + rng.below(8);
                let mode = if rng.below(2) == 0 {
                    PqQueryMode::Symmetric
                } else {
                    PqQueryMode::Asymmetric
                };
                let topk_req = |series: Vec<f64>| Request::TopKQuery {
                    series,
                    k,
                    mode,
                    nprobe: None,
                    rerank: None,
                };
                let want = match oracle.handle(&topk_req(q.clone())) {
                    Response::TopK(hits) => hits,
                    other => return Err(format!("oracle top-k answered {other:?}")),
                };
                let per_shard: Vec<Vec<Hit>> = fleet
                    .iter()
                    .map(|e| match e.handle(&topk_req(q.clone())) {
                        Response::TopK(hits) => Ok(hits),
                        other => Err(format!("shard top-k answered {other:?}")),
                    })
                    .collect::<Result<_, String>>()?;
                let got = merge_topk(per_shard, k);
                if got.len() != want.len() {
                    return Err(format!(
                        "n={shards} k={k}: merged {} hits, oracle {}",
                        got.len(),
                        want.len()
                    ));
                }
                for (g, w) in got.iter().zip(&want) {
                    if g.index != w.index
                        || g.distance.to_bits() != w.distance.to_bits()
                        || g.label != w.label
                    {
                        return Err(format!(
                            "n={shards} k={k} {mode:?}: merged {g:?} vs oracle {w:?}"
                        ));
                    }
                }
                let want_nn = oracle.handle(&Request::NnQuery {
                    series: q.clone(),
                    mode,
                    nprobe: None,
                });
                let winners: Vec<Hit> = fleet
                    .iter()
                    .map(|e| match e.handle(&Request::NnQuery {
                        series: q.clone(),
                        mode,
                        nprobe: None,
                    }) {
                        Response::Nn { index, distance, label } => {
                            Ok(Hit { index, distance, label })
                        }
                        other => Err(format!("shard 1-NN answered {other:?}")),
                    })
                    .collect::<Result<_, String>>()?;
                let got_nn =
                    merge_nn(winners).ok_or_else(|| "no shard returned a winner".to_string())?;
                match want_nn {
                    Response::Nn { index, distance, label } => {
                        if got_nn.index != index
                            || got_nn.distance.to_bits() != distance.to_bits()
                            || got_nn.label != label
                        {
                            return Err(format!(
                                "n={shards} {mode:?}: merged NN {got_nn:?} vs oracle \
                                 ({index}, {distance}, {label:?})"
                            ));
                        }
                    }
                    other => return Err(format!("oracle 1-NN answered {other:?}")),
                }
            }
        }
        Ok(())
    });
}
