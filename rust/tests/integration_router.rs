//! Loopback integration tests for the fault-tolerant sharded serving
//! plane: three real shard servers on 127.0.0.1:0, a real router in
//! front, and the unsharded engine as ground truth.
//!
//! The headline properties:
//!
//! - **full health ⇒ bit identity**: a routed top-k / 1-NN answer
//!   equals the unsharded engine's, byte for byte;
//! - **kill one shard mid-request ⇒ deterministic partial**: the
//!   answer is flagged `degraded`, lists the missing shard, and equals
//!   the deterministic merge of the survivors;
//! - **restart ⇒ re-admission**: once the shard is reachable again the
//!   half-open prober brings it back and answers are bit-identical to
//!   the unsharded oracle once more.
//!
//! The failure modes are driven through [`FaultProxy`], a byte-level
//! TCP proxy in front of one shard.

use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use pqdtw::coordinator::{Engine, Hit, Request, Response, Service, ServiceConfig};
use pqdtw::data::ucr_like::ucr_like_by_name;
use pqdtw::net::{Client, ClientConfig, NetServer, ServerConfig};
use pqdtw::nn::knn::PqQueryMode;
use pqdtw::obs::log::JsonLogger;
use pqdtw::obs::Stage;
use pqdtw::pq::quantizer::PqConfig;
use pqdtw::router::{
    FaultMode, FaultProxy, HealthConfig, RouterConfig, RouterServer, RouterServerConfig,
    ShardHealth,
};

const N_SHARDS: u64 = 3;

fn pq_cfg() -> PqConfig {
    PqConfig { n_subspaces: 4, codebook_size: 8, window_frac: 0.2, ..Default::default() }
}

/// The unsharded oracle plus one served engine per `id % 3` shard.
struct Fleet {
    oracle: Engine,
    queries: pqdtw::core::series::Dataset,
    servers: Vec<NetServer>,
    addrs: Vec<String>,
}

fn start_fleet() -> Fleet {
    let tt = ucr_like_by_name("SpikePosition", 77).unwrap();
    let oracle = Engine::build(&tt.train, &pq_cfg(), 3).unwrap();
    let mut servers = Vec::new();
    let mut addrs = Vec::new();
    for i in 0..N_SHARDS {
        let engine = Engine::build_shard(&tt.train, &pq_cfg(), 3, i, N_SHARDS).unwrap();
        let svc = Arc::new(Service::start(Arc::new(engine), ServiceConfig::default()));
        let server = NetServer::start("127.0.0.1:0", svc, ServerConfig::default()).unwrap();
        addrs.push(server.local_addr().to_string());
        servers.push(server);
    }
    Fleet { oracle, queries: tt.test, servers, addrs }
}

/// Tight deadlines so fault-injection tests converge in milliseconds,
/// not the production multi-second defaults.
fn fast_health() -> HealthConfig {
    HealthConfig {
        connect_timeout: Duration::from_secs(2),
        io_timeout: Duration::from_millis(300),
        base_backoff: Duration::from_millis(20),
        max_backoff: Duration::from_millis(100),
        probe_interval: Duration::from_millis(40),
        ..Default::default()
    }
}

fn quick_client(addr: &str) -> Client {
    Client::connect(
        addr,
        ClientConfig { connect_timeout: Duration::from_secs(5), io_timeout: Duration::from_secs(20) },
    )
    .unwrap()
}

fn oracle_topk(oracle: &Engine, q: &[f64], k: usize) -> Vec<Hit> {
    match oracle.handle(&Request::TopKQuery {
        series: q.to_vec(),
        k,
        mode: PqQueryMode::Asymmetric,
        nprobe: None,
        rerank: None,
    }) {
        Response::TopK(hits) => hits,
        other => panic!("unexpected oracle response {other:?}"),
    }
}

fn assert_hits_eq(got: &[Hit], want: &[Hit], ctx: &str) {
    assert_eq!(got.len(), want.len(), "{ctx}: hit count");
    for (g, w) in got.iter().zip(want) {
        assert_eq!(g.index, w.index, "{ctx}");
        assert_eq!(g.distance.to_bits(), w.distance.to_bits(), "{ctx}: distance bits");
        assert_eq!(g.label, w.label, "{ctx}");
    }
}

/// Wait until the router reports `shard` at `health`, or panic.
fn await_health(server: &RouterServer, shard: usize, health: ShardHealth) {
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        if server.router().health()[shard] == health {
            return;
        }
        assert!(
            Instant::now() < deadline,
            "shard {shard} never reached {health:?} (now {:?})",
            server.router().health()
        );
        std::thread::sleep(Duration::from_millis(10));
    }
}

/// Shared in-memory sink for asserting the router's structured events.
#[derive(Default, Clone)]
struct LogBuf(Arc<Mutex<Vec<u8>>>);

impl std::io::Write for LogBuf {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        self.0.lock().unwrap().extend_from_slice(buf);
        Ok(buf.len())
    }
    fn flush(&mut self) -> std::io::Result<()> {
        Ok(())
    }
}

impl LogBuf {
    fn text(&self) -> String {
        String::from_utf8_lossy(&self.0.lock().unwrap()).into_owned()
    }
}

#[test]
fn full_health_routing_is_bit_identical_to_the_unsharded_engine() {
    let fleet = start_fleet();
    let router = RouterServer::start(
        "127.0.0.1:0",
        RouterConfig::new(fleet.addrs.clone()),
        RouterServerConfig::default(),
    )
    .unwrap();
    let mut client = quick_client(&router.local_addr().to_string());
    for i in 0..5 {
        let q = fleet.queries.row(i);
        for k in [1, 4, 9] {
            let reply = client
                .topk_full(q, k, PqQueryMode::Asymmetric, None, None, i as u64 + 1, false)
                .unwrap();
            assert!(!reply.degraded, "query {i} k={k} unexpectedly degraded");
            assert!(reply.missing_shards.is_empty());
            assert_hits_eq(&reply.hits, &oracle_topk(&fleet.oracle, q, k), "routed top-k");
        }
        for mode in [PqQueryMode::Symmetric, PqQueryMode::Asymmetric] {
            let reply = client.nn_full(q, mode, None, i as u64 + 100, false).unwrap();
            match fleet.oracle.handle(&Request::NnQuery {
                series: q.to_vec(),
                mode,
                nprobe: None,
            }) {
                Response::Nn { index, distance, label } => {
                    assert_eq!(reply.index, index, "query {i} {mode:?}");
                    assert_eq!(reply.distance.to_bits(), distance.to_bits());
                    assert_eq!(reply.label, label);
                    assert!(!reply.degraded);
                }
                other => panic!("unexpected oracle response {other:?}"),
            }
        }
    }
    // Routed stats aggregate the fleet: n_items must equal the whole
    // database even though every shard holds only a slice.
    let stats = client.stats().unwrap();
    assert_eq!(stats.n_items as usize, fleet.oracle.n_items);
    router.shutdown();
    for s in fleet.servers {
        s.shutdown();
    }
}

#[test]
fn killed_shard_yields_a_deterministic_degraded_partial_then_recovers() {
    let fleet = start_fleet();
    // Shard 1 sits behind the fault proxy; the router only knows the
    // proxy's address.
    let proxy = FaultProxy::start(&fleet.addrs[1]).unwrap();
    let shard_addrs =
        vec![fleet.addrs[0].clone(), proxy.local_addr().to_string(), fleet.addrs[2].clone()];
    let mut cfg = RouterConfig::new(shard_addrs);
    cfg.health = fast_health();
    let router =
        RouterServer::start("127.0.0.1:0", cfg, RouterServerConfig::default()).unwrap();
    let mut client = quick_client(&router.local_addr().to_string());
    let q = fleet.queries.row(0);
    let k = 6;

    // Phase 1: healthy fleet, sanity-check bit identity.
    let reply = client
        .topk_full(q, k, PqQueryMode::Asymmetric, None, None, 1, false)
        .unwrap();
    assert!(!reply.degraded);
    assert_hits_eq(&reply.hits, &oracle_topk(&fleet.oracle, q, k), "healthy fleet");

    // Phase 2: kill shard 1 mid-request — every response is severed
    // after 5 bytes (a torn frame), including the fresh-connection
    // retry, so the scatter leg hard-fails.
    proxy.set_mode(FaultMode::CloseAfter(5));
    proxy.kill_connections();
    let reply = client
        .topk_full(q, k, PqQueryMode::Asymmetric, None, None, 2, false)
        .unwrap();
    assert!(reply.degraded, "killed shard must flag the response degraded");
    assert_eq!(reply.missing_shards, vec![1]);
    // The partial answer is exactly the merge of the survivors: the
    // oracle's ranking with shard 1's rows (index % 3 == 1) removed.
    let survivors: Vec<Hit> = oracle_topk(&fleet.oracle, q, fleet.oracle.n_items)
        .into_iter()
        .filter(|h| h.index as u64 % N_SHARDS != 1)
        .take(k)
        .collect();
    assert_hits_eq(&reply.hits, &survivors, "degraded partial");
    // Two consecutive failures (first attempt + retry) opened the
    // breaker; metrics saw the hard-failure retry.
    assert_eq!(router.router().health()[1], ShardHealth::Down);
    assert!(router.router().metrics().retries.get() >= 1);
    assert!(router.router().metrics().degraded_responses.get() >= 1);
    // While Down, the next query skips the shard instantly (breaker).
    let reply = client
        .topk_full(q, k, PqQueryMode::Asymmetric, None, None, 3, false)
        .unwrap();
    assert!(reply.degraded);
    assert_eq!(reply.missing_shards, vec![1]);

    // Phase 3: restart the shard (heal the proxy); the background
    // half-open prober must re-admit it without any client traffic.
    proxy.set_mode(FaultMode::Pass);
    await_health(&router, 1, ShardHealth::Healthy);
    let reply = client
        .topk_full(q, k, PqQueryMode::Asymmetric, None, None, 4, false)
        .unwrap();
    assert!(!reply.degraded, "recovered fleet must stop degrading");
    assert!(reply.missing_shards.is_empty());
    assert_hits_eq(&reply.hits, &oracle_topk(&fleet.oracle, q, k), "recovered fleet");

    router.shutdown();
    proxy.stop();
    for s in fleet.servers {
        s.shutdown();
    }
}

#[test]
fn require_full_fails_queries_instead_of_degrading() {
    let fleet = start_fleet();
    let proxy = FaultProxy::start(&fleet.addrs[2]).unwrap();
    proxy.set_mode(FaultMode::CloseAfter(0));
    let shard_addrs =
        vec![fleet.addrs[0].clone(), fleet.addrs[1].clone(), proxy.local_addr().to_string()];
    let mut cfg = RouterConfig::new(shard_addrs);
    cfg.require_full = true;
    cfg.health = fast_health();
    let router =
        RouterServer::start("127.0.0.1:0", cfg, RouterServerConfig::default()).unwrap();
    let mut client = quick_client(&router.local_addr().to_string());
    let q = fleet.queries.row(0);
    let err = client
        .topk_full(q, 4, PqQueryMode::Asymmetric, None, None, 1, false)
        .unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("require-full"), "{msg}");
    assert!(msg.contains('2'), "missing shard index in: {msg}");
    // The router survives and keeps answering its own liveness.
    client.ping().unwrap();
    router.shutdown();
    proxy.stop();
    for s in fleet.servers {
        s.shutdown();
    }
}

#[test]
fn router_rejects_job_requests_and_reports_its_own_metrics() {
    let fleet = start_fleet();
    let router = RouterServer::start(
        "127.0.0.1:0",
        RouterConfig::new(fleet.addrs.clone()),
        RouterServerConfig::default(),
    )
    .unwrap();
    let mut client = quick_client(&router.local_addr().to_string());
    let err = client.job_status(1).unwrap_err();
    assert!(format!("{err:#}").contains("not routed"), "{err:#}");
    let text = client.metrics_text().unwrap();
    assert!(text.contains("pqdtw_router_requests_total"), "{text}");
    assert!(text.contains("pqdtw_router_shard_health"), "{text}");
    assert!(text.contains("pqdtw_router_uptime_seconds"), "{text}");
    // Shard-engine families are deliberately NOT proxied.
    assert!(!text.contains("pqdtw_requests_total"), "{text}");
    router.shutdown();
    for s in fleet.servers {
        s.shutdown();
    }
}

#[test]
fn routed_trace_is_a_merged_ladder_with_per_shard_children() {
    let fleet = start_fleet();
    let router = RouterServer::start(
        "127.0.0.1:0",
        RouterConfig::new(fleet.addrs.clone()),
        RouterServerConfig::default(),
    )
    .unwrap();
    let mut client = quick_client(&router.local_addr().to_string());
    let q = fleet.queries.row(0);
    let k = 6;

    // Tracing is a pure observer: the traced answer is bit-identical
    // to the untraced one, and both match the unsharded oracle.
    let plain = client.topk_full(q, k, PqQueryMode::Asymmetric, None, None, 7, false).unwrap();
    let traced = client.topk_full(q, k, PqQueryMode::Asymmetric, None, None, 7, true).unwrap();
    assert!(plain.trace.is_none());
    assert_hits_eq(&traced.hits, &plain.hits, "traced vs untraced");
    assert_hits_eq(&traced.hits, &oracle_topk(&fleet.oracle, q, k), "traced vs oracle");

    let trace = traced.trace.expect("trace requested");
    assert_eq!(trace.request_id, 7);
    // One `shard_rpc` span per healthy shard; one child trace per
    // shard, ascending by shard index.
    let rpc: Vec<_> = trace.spans.iter().filter(|s| s.stage == Stage::ShardRpc).collect();
    assert_eq!(rpc.len(), N_SHARDS as usize, "{:?}", trace.spans);
    assert_eq!(trace.children.len(), N_SHARDS as usize);
    let shards: Vec<u64> = trace.children.iter().map(|c| c.shard).collect();
    assert_eq!(shards, vec![0, 1, 2]);
    for c in &trace.children {
        assert!(!c.retried && !c.hedged && !c.degraded, "healthy fleet: {c:?}");
        // Children are the shards' own single-engine ladders: depth 1,
        // never carrying router-level stages of their own.
        assert!(c.trace.children.is_empty());
        assert!(c
            .trace
            .spans
            .iter()
            .all(|s| !matches!(s.stage, Stage::Fanout | Stage::ShardRpc | Stage::Merge)));
        assert!(!c.trace.spans.is_empty(), "shard {} recorded no spans", c.shard);
    }
    let fanout = trace.span(Stage::Fanout).expect("fanout span");
    assert_eq!(fanout.candidates_in, N_SHARDS);
    assert_eq!(fanout.candidates_out, N_SHARDS);
    let merge = trace.span(Stage::Merge).expect("merge span");
    assert_eq!(merge.candidates_out, traced.hits.len() as u64);
    // Per-hit provenance: each hit is attributed to the `id % 3` shard
    // that actually owns its row.
    assert_eq!(trace.hits.len(), traced.hits.len());
    for (h, e) in traced.hits.iter().zip(&trace.hits) {
        assert_eq!(e.index, h.index as u64);
        assert_eq!(e.shard, Some(h.index as u64 % N_SHARDS), "hit {}", h.index);
    }
    // The merged scan snapshot is the fleet sum of the children's.
    let summed: u64 = trace.children.iter().map(|c| c.trace.scan.items_scanned).sum();
    assert_eq!(trace.scan.items_scanned, summed);

    // 1-NN gets the same ladder shape.
    let nn = client.nn_full(q, PqQueryMode::Asymmetric, None, 8, true).unwrap();
    let nt = nn.trace.expect("nn trace");
    assert_eq!(nt.request_id, 8);
    assert_eq!(nt.children.len(), N_SHARDS as usize);
    assert_eq!(
        nt.spans.iter().filter(|s| s.stage == Stage::ShardRpc).count(),
        N_SHARDS as usize
    );

    router.shutdown();
    for s in fleet.servers {
        s.shutdown();
    }
}

#[test]
fn one_shard_fleet_serves_stats_bit_identical_to_the_shard() {
    // A fleet of one: the router's exact histogram federation must
    // reproduce the shard's own stats bit for bit — counts, buckets,
    // percentiles and f64 means alike.
    let tt = ucr_like_by_name("SpikePosition", 77).unwrap();
    let engine = Engine::build(&tt.train, &pq_cfg(), 3).unwrap();
    let svc = Arc::new(Service::start(Arc::new(engine), ServiceConfig::default()));
    let server = NetServer::start("127.0.0.1:0", svc, ServerConfig::default()).unwrap();
    let addr = server.local_addr().to_string();
    let router = RouterServer::start(
        "127.0.0.1:0",
        RouterConfig::new(vec![addr.clone()]),
        RouterServerConfig::default(),
    )
    .unwrap();
    let mut via_router = quick_client(&router.local_addr().to_string());
    // Put real observations into every histogram family first.
    for i in 0..6 {
        let q = tt.test.row(i);
        via_router.topk(q, 3, PqQueryMode::Asymmetric, None, None).unwrap();
        via_router.nn(q, PqQueryMode::Symmetric, None).unwrap();
    }
    let mut direct = quick_client(&addr);
    let want = direct.stats().unwrap();
    let mut got = via_router.stats().unwrap();
    // `uptime_s` is the lone wall-clock scalar: the routed snapshot is
    // taken a moment after the direct one, so allow the second to tick
    // once, then require everything else bit-identical.
    assert!(
        got.uptime_s >= want.uptime_s && got.uptime_s <= want.uptime_s + 1,
        "uptime drifted: direct {} routed {}",
        want.uptime_s,
        got.uptime_s
    );
    got.uptime_s = want.uptime_s;
    assert_eq!(got, want, "one-shard fleet stats must match the shard exactly");
    router.shutdown();
    server.shutdown();
}

#[test]
fn router_healthz_reflects_a_killed_shard() {
    let fleet = start_fleet();
    let proxy = FaultProxy::start(&fleet.addrs[1]).unwrap();
    let shard_addrs =
        vec![fleet.addrs[0].clone(), proxy.local_addr().to_string(), fleet.addrs[2].clone()];
    let mut cfg = RouterConfig::new(shard_addrs);
    cfg.health = fast_health();
    let router =
        RouterServer::start("127.0.0.1:0", cfg, RouterServerConfig::default()).unwrap();
    let body = router.router().healthz_json();
    assert!(body.contains("\"status\":\"ok\""), "{body}");
    assert!(body.contains("\"health\":\"healthy\""), "{body}");

    // Kill shard 1 and let one failing query trip the breaker.
    proxy.set_mode(FaultMode::CloseAfter(0));
    proxy.kill_connections();
    let mut client = quick_client(&router.local_addr().to_string());
    let q = fleet.queries.row(0);
    let reply = client.topk_full(q, 4, PqQueryMode::Asymmetric, None, None, 1, false).unwrap();
    assert!(reply.degraded);
    await_health(&router, 1, ShardHealth::Down);

    // The same body the HTTP `/healthz` endpoint serves now carries
    // the per-shard breaker verdict.
    let body = router.router().healthz_json();
    assert!(body.contains("\"status\":\"degraded\""), "{body}");
    assert!(body.contains("\"shard\":1"), "{body}");
    assert!(body.contains("\"health\":\"down\""), "{body}");

    router.shutdown();
    proxy.stop();
    for s in fleet.servers {
        s.shutdown();
    }
}

#[test]
fn router_slow_query_log_reports_the_crossing_queries() {
    let fleet = start_fleet();
    let buf = LogBuf::default();
    let logger = Arc::new(JsonLogger::to_writer(Box::new(buf.clone())));
    let mut cfg = RouterConfig::new(fleet.addrs.clone());
    // Threshold zero: every query crosses, so the test is deterministic.
    cfg.slow_query_us = Some(0);
    let router =
        RouterServer::start_logged("127.0.0.1:0", cfg, RouterServerConfig::default(), logger)
            .unwrap();
    let mut client = quick_client(&router.local_addr().to_string());
    let q = fleet.queries.row(0);
    client.topk_full(q, 4, PqQueryMode::Asymmetric, None, None, 42, false).unwrap();
    client.nn_full(q, PqQueryMode::Asymmetric, None, 43, true).unwrap();

    let text = buf.text();
    let slow: Vec<&str> = text.lines().filter(|l| l.contains("\"event\":\"slow_query\"")).collect();
    assert_eq!(slow.len(), 2, "{text}");
    assert!(slow[0].contains("\"request_id\":42"), "{}", slow[0]);
    assert!(slow[0].contains("\"class\":\"topk\""), "{}", slow[0]);
    assert!(slow[0].contains("\"degraded\":false"), "{}", slow[0]);
    assert!(slow[1].contains("\"request_id\":43"), "{}", slow[1]);
    assert!(slow[1].contains("\"class\":\"nn\""), "{}", slow[1]);
    // The traced query's event carries the router-stage span summary.
    assert!(slow[1].contains("shard_rpc="), "{}", slow[1]);
    // And the counter is exported.
    let mtext = client.metrics_text().unwrap();
    assert!(mtext.contains("pqdtw_slow_queries_total 2"), "{mtext}");

    router.shutdown();
    for s in fleet.servers {
        s.shutdown();
    }
}
