//! Loopback integration tests for the durable job plane: a real TCP
//! server with a [`JobManager`] attached, jobs submitted/polled/
//! cancelled through the v3 wire frames, and the in-process engine as
//! ground truth.
//!
//! The acceptance properties:
//! (a) a completed `AllPairsTopK` job's persisted rows are
//!     bit-identical to serial `Engine::handle` top-k calls;
//! (b) a cancel lands within one chunk boundary and the job reports
//!     `Cancelled` with a consistent partial-progress count;
//! (c) killing the job plane mid-job and reopening the engine recovers
//!     job state from the store without corrupting existing sections;
//! (d) the Prometheus exposition exposes `pqdtw_jobs_*` families and
//!     passes `validate_exposition`.

use std::sync::Arc;
use std::time::{Duration, Instant};

use pqdtw::coordinator::{Engine, Request, Response, Service, ServiceConfig};
use pqdtw::data::random_walk::RandomWalks;
use pqdtw::data::ucr_like::ucr_like_by_name;
use pqdtw::jobs::{JobConfig, JobManager, JobResult, JobSpec, JobStatus};
use pqdtw::net::{Client, ClientConfig, NetServer, ServerConfig};
use pqdtw::nn::ivf::CoarseMetric;
use pqdtw::nn::knn::PqQueryMode;
use pqdtw::obs::log::JsonLogger;
use pqdtw::obs::prometheus;
use pqdtw::pq::quantizer::PqConfig;

/// A served engine with an IVF index and an attached job plane.
fn toy_job_server(
    job_cfg: JobConfig,
) -> (NetServer, Arc<Service>, Arc<JobManager>, Arc<Engine>, String) {
    let tt = ucr_like_by_name("SpikePosition", 77).unwrap();
    let pq_cfg = PqConfig {
        n_subspaces: 4,
        codebook_size: 8,
        window_frac: 0.2,
        kmeans_iters: 2,
        dba_iters: 1,
        ..Default::default()
    };
    let mut engine = Engine::build(&tt.train, &pq_cfg, 3).unwrap();
    engine.enable_ivf(6, CoarseMetric::Euclidean, 5);
    let engine = Arc::new(engine);
    let svc = Arc::new(Service::start(Arc::clone(&engine), ServiceConfig::default()));
    let jobs = JobManager::start(
        Arc::clone(&engine),
        Arc::new(JsonLogger::disabled()),
        None,
        job_cfg,
    );
    svc.attach_jobs(Arc::clone(&jobs));
    let server =
        NetServer::start("127.0.0.1:0", Arc::clone(&svc), ServerConfig::default()).unwrap();
    let addr = server.local_addr().to_string();
    (server, svc, jobs, engine, addr)
}

fn quick_client(addr: &str) -> Client {
    Client::connect(
        addr,
        ClientConfig {
            connect_timeout: Duration::from_secs(5),
            io_timeout: Duration::from_secs(20),
        },
    )
    .unwrap()
}

/// Poll a job over the wire until it reaches a terminal status.
fn wait_terminal(client: &mut Client, id: u64) -> pqdtw::jobs::JobSnapshot {
    let deadline = Instant::now() + Duration::from_secs(60);
    loop {
        let snap = client.job_status(id).unwrap();
        if snap.status.is_terminal() {
            return snap;
        }
        assert!(Instant::now() < deadline, "job {id} did not finish in time: {snap:?}");
        std::thread::sleep(Duration::from_millis(5));
    }
}

/// (a) + (d): a completed all-pairs job answers bit-identically to
/// serial in-process top-k calls, and the job plane shows up in the
/// Prometheus exposition.
#[test]
fn all_pairs_job_matches_serial_topk_bit_for_bit_over_loopback() {
    let (server, _svc, _jobs, engine, addr) = toy_job_server(JobConfig::default());
    let mut client = quick_client(&addr);
    let (k, rerank) = (3usize, Some(8usize));
    let id = client
        .job_submit(JobSpec::AllPairsTopK {
            k,
            mode: PqQueryMode::Asymmetric,
            nprobe: None,
            rerank,
        })
        .unwrap();
    let snap = wait_terminal(&mut client, id);
    assert_eq!(snap.status, JobStatus::Completed, "{snap:?}");
    assert_eq!(snap.done, snap.total);
    assert_eq!(snap.total, engine.n_items as u64);

    let rows = match client.job_result(id).unwrap() {
        JobResult::AllPairs(rows) => rows,
        other => panic!("unexpected result payload {other:?}"),
    };
    assert_eq!(rows.len(), engine.n_items);
    for (i, row) in rows.iter().enumerate() {
        assert_eq!(row.query_index, i as u64);
        let want = match engine.handle(&Request::TopKQuery {
            series: engine.raw.row(i).to_vec(),
            k,
            mode: PqQueryMode::Asymmetric,
            nprobe: None,
            rerank,
        }) {
            Response::TopK(hits) => hits,
            other => panic!("unexpected engine response {other:?}"),
        };
        assert_eq!(row.hits.len(), want.len(), "row {i}");
        for (got, want) in row.hits.iter().zip(want.iter()) {
            assert_eq!(got.index, want.index, "row {i}");
            assert_eq!(
                got.distance.to_bits(),
                want.distance.to_bits(),
                "row {i}: distances must be bit-identical"
            );
            assert_eq!(got.label, want.label, "row {i}");
        }
        // Per-hit provenance rides along with every row.
        assert_eq!(row.explains.len(), row.hits.len(), "row {i}");
    }

    // Events are cursor-addressable over the wire: strictly ascending
    // seqs, and a cursor at the tail returns nothing new.
    let (events, latest_seq) = client.job_events(id, 0, 4096).unwrap();
    assert!(!events.is_empty());
    for pair in events.windows(2) {
        assert!(pair[0].seq < pair[1].seq, "events must be strictly ascending");
    }
    assert_eq!(events.last().unwrap().seq, latest_seq);
    let (tail, _) = client.job_events(id, latest_seq, 4096).unwrap();
    assert!(tail.is_empty(), "cursor at the tail must return nothing, got {tail:?}");

    // (d) the exposition carries the job families and validates.
    let text = client.metrics_text().unwrap();
    let samples = prometheus::validate_exposition(&text).expect("valid exposition");
    assert!(samples > 10);
    assert!(text.contains("pqdtw_jobs_running"));
    assert!(text.contains("pqdtw_jobs_queued"));
    assert!(text.contains("pqdtw_jobs_submitted_total{kind=\"all_pairs_topk\"} 1\n"));
    assert!(text.contains("pqdtw_jobs_completed_total{kind=\"all_pairs_topk\"} 1\n"));
    assert!(text.contains("pqdtw_jobs_duration_microseconds_bucket"));

    // Unknown ids are server errors, not dead connections.
    let err = client.job_status(9999).unwrap_err().to_string();
    assert!(err.contains("server error"), "{err}");
    assert!(err.contains("unknown job id"), "{err}");
    drop(server);
}

/// (b): cancelling a running job lands within one chunk boundary and
/// reports a consistent partial-progress count.
#[test]
fn cancel_lands_within_one_chunk_boundary_over_loopback() {
    // A deliberately slow job: DTW re-ranking over a RandomWalk corpus,
    // small chunks so there are many cancellation points.
    let db = RandomWalks::new(11).generate(512, 128);
    let pq_cfg = PqConfig {
        n_subspaces: 4,
        codebook_size: 16,
        window_frac: 0.3,
        kmeans_iters: 2,
        dba_iters: 1,
        train_subsample: Some(64),
        ..Default::default()
    };
    let engine = Arc::new(Engine::build(&db, &pq_cfg, 9).unwrap());
    let svc = Arc::new(Service::start(Arc::clone(&engine), ServiceConfig::default()));
    let jobs = JobManager::start(
        Arc::clone(&engine),
        Arc::new(JsonLogger::disabled()),
        None,
        JobConfig { n_workers: 1, chunk: 8 },
    );
    svc.attach_jobs(Arc::clone(&jobs));
    let server =
        NetServer::start("127.0.0.1:0", Arc::clone(&svc), ServerConfig::default()).unwrap();
    let mut client = quick_client(&server.local_addr().to_string());

    let id = client
        .job_submit(JobSpec::AllPairsTopK {
            k: 5,
            mode: PqQueryMode::Asymmetric,
            nprobe: None,
            rerank: Some(64),
        })
        .unwrap();
    // Wait until real progress is visible, then cancel mid-run.
    let deadline = Instant::now() + Duration::from_secs(60);
    loop {
        let snap = client.job_status(id).unwrap();
        if snap.status == JobStatus::Running && snap.done > 0 {
            break;
        }
        assert!(
            !snap.status.is_terminal(),
            "job finished before the cancel could land — workload too small? {snap:?}"
        );
        assert!(Instant::now() < deadline, "job never made progress: {snap:?}");
        std::thread::sleep(Duration::from_millis(2));
    }
    let acked = client.job_cancel(id).unwrap();
    assert_eq!(acked.id, id);
    let snap = wait_terminal(&mut client, id);
    assert_eq!(snap.status, JobStatus::Cancelled, "{snap:?}");
    // Partial progress is consistent: some work done, not all of it,
    // and `done` sits on a chunk boundary (chunk = 8 over 512 queries).
    assert!(snap.done > 0, "{snap:?}");
    assert!(snap.done < snap.total, "{snap:?}");
    assert_eq!(snap.done % 8, 0, "cancel must land on a chunk boundary: {snap:?}");
    // A cancelled job has no result.
    let err = client.job_result(id).unwrap_err().to_string();
    assert!(err.contains("no result"), "{err}");
    drop(server);
}

/// (c): kill the job plane mid-job; reopening the engine recovers the
/// job from the store (re-enqueued from scratch), re-runs it to a
/// bit-identical result, and no existing section is corrupted.
#[test]
fn job_state_survives_kill_and_reopen_without_corrupting_the_store() {
    let dir = pqdtw::testutil::unique_temp_dir("jobs_recover");
    let path = dir.join("idx.pqx");
    let db = RandomWalks::new(21).generate(384, 128);
    let pq_cfg = PqConfig {
        n_subspaces: 4,
        codebook_size: 16,
        window_frac: 0.3,
        kmeans_iters: 2,
        dba_iters: 1,
        train_subsample: Some(64),
        ..Default::default()
    };
    let mut built = Engine::build(&db, &pq_cfg, 9).unwrap();
    built.enable_ivf(8, CoarseMetric::Euclidean, 5);
    built.save(&path).unwrap();

    let spec = JobSpec::AllPairsTopK {
        k: 4,
        mode: PqQueryMode::Asymmetric,
        nprobe: None,
        rerank: Some(48),
    };

    // First life: submit a slow job, then kill the plane before it can
    // finish. The graceful stop deliberately leaves the on-disk job
    // non-terminal so the next open re-runs it.
    let engine1 = Arc::new(Engine::open(&path).unwrap());
    assert!(engine1.recovered_jobs.is_empty());
    let mgr1 = JobManager::start(
        Arc::clone(&engine1),
        Arc::new(JsonLogger::disabled()),
        Some(path.clone()),
        JobConfig { n_workers: 1, chunk: 4 },
    );
    let id = mgr1.submit(spec.clone()).unwrap();
    drop(mgr1); // stop + join: the running job is abandoned, not cancelled

    // Second life: the job comes back non-terminal and re-enqueued.
    let engine2 = Arc::new(Engine::open(&path).unwrap());
    assert_eq!(engine2.recovered_jobs.len(), 1);
    let recovered = &engine2.recovered_jobs[0];
    assert_eq!(recovered.id, id);
    assert_eq!(recovered.spec, spec);
    assert!(!recovered.status.is_terminal(), "{recovered:?}");
    assert!(recovered.result.is_none());

    // Existing sections are intact: the reopened engine answers queries
    // bit-identically to the engine it was saved from.
    for i in [0usize, 7, 191] {
        let req = Request::TopKQuery {
            series: db.row(i).to_vec(),
            k: 4,
            mode: PqQueryMode::Asymmetric,
            nprobe: Some(3),
            rerank: None,
        };
        assert_eq!(engine2.handle(&req), built.handle(&req), "query {i}");
    }

    let mgr2 = JobManager::start(
        Arc::clone(&engine2),
        Arc::new(JsonLogger::disabled()),
        Some(path.clone()),
        JobConfig { n_workers: 1, chunk: 4 },
    );
    let deadline = Instant::now() + Duration::from_secs(120);
    loop {
        let snap = mgr2.status(id).expect("recovered job is registered");
        if snap.status.is_terminal() {
            assert_eq!(snap.status, JobStatus::Completed, "{snap:?}");
            break;
        }
        assert!(Instant::now() < deadline, "recovered job never finished: {snap:?}");
        std::thread::sleep(Duration::from_millis(10));
    }
    // The re-run is a pure function of the immutable index: rows are
    // bit-identical to serial in-process calls.
    let rows = match mgr2.result(id).unwrap().expect("completed job has a result") {
        JobResult::AllPairs(rows) => rows,
        other => panic!("unexpected result payload {other:?}"),
    };
    assert_eq!(rows.len(), engine2.n_items);
    for i in [0usize, 63, 383] {
        let want = match engine2.handle(&Request::TopKQuery {
            series: engine2.raw.row(i).to_vec(),
            k: 4,
            mode: PqQueryMode::Asymmetric,
            nprobe: None,
            rerank: Some(48),
        }) {
            Response::TopK(hits) => hits,
            other => panic!("unexpected engine response {other:?}"),
        };
        let got = &rows[i].hits;
        assert_eq!(got.len(), want.len(), "row {i}");
        for (g, w) in got.iter().zip(want.iter()) {
            assert_eq!((g.index, g.distance.to_bits()), (w.index, w.distance.to_bits()));
        }
    }
    drop(mgr2);

    // Third life: the terminal job (with its result) is recovered
    // verbatim, not re-run.
    let engine3 = Engine::open(&path).unwrap();
    assert_eq!(engine3.recovered_jobs.len(), 1);
    let done = &engine3.recovered_jobs[0];
    assert_eq!(done.id, id);
    assert_eq!(done.status, JobStatus::Completed);
    assert!(done.result.is_some());

    std::fs::remove_dir_all(&dir).ok();
}

/// Autotune over loopback: full sweep reaches recall 1.0 at
/// `nprobe = nlist`, and the recommendation respects the target.
#[test]
fn autotune_job_over_loopback_reaches_full_recall_at_full_probe() {
    let (server, _svc, _jobs, engine, addr) = toy_job_server(JobConfig::default());
    let nlist = engine.ivf.as_ref().unwrap().nlist();
    let mut client = quick_client(&addr);
    let id = client
        .job_submit(JobSpec::AutotuneNprobe { k: 3, target_recall: 1.0, sample: 8 })
        .unwrap();
    let snap = wait_terminal(&mut client, id);
    assert_eq!(snap.status, JobStatus::Completed, "{snap:?}");
    let (recommended, sweep) = match client.job_result(id).unwrap() {
        JobResult::Autotune { recommended_nprobe, sweep } => (recommended_nprobe, sweep),
        other => panic!("unexpected result payload {other:?}"),
    };
    assert!(recommended >= 1 && recommended <= nlist);
    let last = sweep.last().unwrap();
    assert_eq!(last.nprobe, nlist, "the ladder must end at the full probe");
    assert!(
        (last.recall - 1.0).abs() < 1e-12,
        "full probe must reproduce the exhaustive scan: {sweep:?}"
    );
    drop(server);
}

/// A server without a job plane answers job frames with a clean error.
#[test]
fn server_without_job_plane_rejects_job_frames_cleanly() {
    let tt = ucr_like_by_name("SpikePosition", 77).unwrap();
    let pq_cfg = PqConfig {
        n_subspaces: 4,
        codebook_size: 8,
        window_frac: 0.2,
        kmeans_iters: 2,
        dba_iters: 1,
        ..Default::default()
    };
    let engine = Arc::new(Engine::build(&tt.train, &pq_cfg, 3).unwrap());
    let svc = Arc::new(Service::start(Arc::clone(&engine), ServiceConfig::default()));
    let server =
        NetServer::start("127.0.0.1:0", Arc::clone(&svc), ServerConfig::default()).unwrap();
    let mut client = quick_client(&server.local_addr().to_string());
    let err = client.job_status(1).unwrap_err().to_string();
    assert!(err.contains("job plane not enabled"), "{err}");
    // The connection survives: queries still work afterwards.
    client.ping().unwrap();
    drop(server);
}
