//! Application-level integration: 1-NN classification and hierarchical
//! clustering across measures on the UCR-like suite — the paper's two
//! evaluation tasks, shrunk to test size.

use pqdtw::cluster::{agglomerative, compact_labels, rand_index, Linkage};
use pqdtw::core::matrix::CondensedMatrix;
use pqdtw::data::ucr_like::{ucr_like_by_name, ucr_like_suite};
use pqdtw::distance::measure::Measure;
use pqdtw::eval::stats::{friedman_test, average_ranks};
use pqdtw::nn::knn::{nn_classify_pq, nn_classify_raw, nn_classify_sax, PqQueryMode};
use pqdtw::pq::quantizer::{PqConfig, ProductQuantizer};

#[test]
fn all_measures_beat_chance_on_easy_dataset() {
    let tt = ucr_like_by_name("DampedOsc", 101).unwrap();
    let chance = 1.0 - 1.0 / tt.train.classes().len() as f64;
    for measure in [
        Measure::Euclidean,
        Measure::Dtw,
        Measure::CDtw { window_frac: 0.05 },
        Measure::CDtw { window_frac: 0.10 },
        Measure::Sbd,
    ] {
        let (err, _) = nn_classify_raw(&tt.train, &tt.test, measure);
        assert!(err < chance, "{}: err={err} chance={chance}", measure.name());
    }
    let (err_sax, _) = nn_classify_sax(&tt.train, &tt.test, 4, 0.2);
    assert!(err_sax <= chance + 0.05, "SAX err={err_sax}");
}

#[test]
fn elastic_beats_lockstep_on_warped_dataset() {
    // SpikePosition's class signal is *where* the spike is; within-class
    // jitter means ED suffers while DTW locks on.
    let tt = ucr_like_by_name("SpikePosition", 103).unwrap();
    let (err_ed, _) = nn_classify_raw(&tt.train, &tt.test, Measure::Euclidean);
    let (err_dtw, _) = nn_classify_raw(&tt.train, &tt.test, Measure::CDtw { window_frac: 0.1 });
    assert!(
        err_dtw <= err_ed + 0.02,
        "cDTW ({err_dtw}) should not lose to ED ({err_ed}) here"
    );
}

#[test]
fn pqdtw_competitive_with_ed_on_suite_subset() {
    // Paper's headline: no significant difference between PQDTW and ED.
    // On a 5-dataset subset, mean error difference must be small.
    let mut diffs = Vec::new();
    for name in ["CBF", "SpikePosition", "Seasonal", "DampedOsc", "BumpCount"] {
        let tt = ucr_like_by_name(name, 107).unwrap();
        let cfg = PqConfig {
            n_subspaces: 4,
            codebook_size: 32,
            window_frac: 0.2,
            ..Default::default()
        };
        let pq = ProductQuantizer::train(&tt.train, &cfg, 13).unwrap();
        let enc = pq.encode_dataset(&tt.train);
        let (err_pq, _) = nn_classify_pq(&pq, &enc, &tt.test, PqQueryMode::Asymmetric);
        let (err_ed, _) = nn_classify_raw(&tt.train, &tt.test, Measure::Euclidean);
        diffs.push(err_pq - err_ed);
    }
    let mean = diffs.iter().sum::<f64>() / diffs.len() as f64;
    assert!(mean < 0.15, "PQDTW much worse than ED: mean diff {mean} ({diffs:?})");
}

#[test]
fn clustering_recovers_structure_with_pq_distances() {
    let tt = ucr_like_by_name("Seasonal", 109).unwrap();
    let cfg = PqConfig { n_subspaces: 4, codebook_size: 24, window_frac: 0.2, ..Default::default() };
    let pq = ProductQuantizer::train(&tt.train, &cfg, 3).unwrap();
    let enc = pq.encode_dataset(&tt.test);
    let n = tt.test.n_series();
    let m = CondensedMatrix::build(n, |i, j| pq.patched_distance(&enc, i, j));
    let k = tt.test.classes().len();
    let labels = agglomerative(&m, Linkage::Complete).cut(k);
    let truth = compact_labels(&tt.test.labels);
    let ri = rand_index(&labels, &truth);
    // frequency classes are clusterable: well above random pairing
    assert!(ri > 0.6, "RI={ri}");
}

#[test]
fn clustering_linkages_all_execute() {
    let tt = ucr_like_by_name("Waveforms", 113).unwrap();
    let sub: Vec<usize> = (0..30).collect();
    let test = tt.test.subset(&sub);
    let n = test.n_series();
    let m = CondensedMatrix::build(n, |i, j| {
        Measure::Euclidean.dist(test.row(i), test.row(j))
    });
    for linkage in [Linkage::Single, Linkage::Average, Linkage::Complete] {
        let labels = agglomerative(&m, linkage).cut(3);
        assert_eq!(labels.len(), n);
    }
}

#[test]
fn friedman_pipeline_over_suite() {
    // Run two cheap measures over the suite and push the scores through
    // the statistical machinery end-to-end (shape check, not conclusions).
    let suite = ucr_like_suite(211);
    let mut scores = Vec::new();
    for tt in suite.iter().take(6) {
        let (e1, _) = nn_classify_raw(&tt.train, &tt.test, Measure::Euclidean);
        let (e2, _) = nn_classify_sax(&tt.train, &tt.test, 4, 0.2);
        scores.push(vec![e1, e2]);
    }
    let ranks = average_ranks(&scores);
    assert_eq!(ranks.len(), 2);
    let (chi2, dof, p) = friedman_test(&scores);
    assert!(chi2 >= 0.0);
    assert_eq!(dof, 1);
    assert!((0.0..=1.0).contains(&p));
}

#[test]
fn ucr_archive_path_used_when_available() {
    // The loader integrates with the CLI path; simulate a tiny archive.
    let dir = std::env::temp_dir().join("pqdtw_it_arch");
    let ds = dir.join("Tiny");
    std::fs::create_dir_all(&ds).unwrap();
    let mk_rows = |offset: f64| {
        (0..8)
            .map(|i| {
                let vals: Vec<String> =
                    (0..16).map(|t| format!("{}", offset + (i * t) as f64 * 0.01)).collect();
                format!("{}\t{}", i % 2 + 1, vals.join("\t"))
            })
            .collect::<Vec<_>>()
            .join("\n")
    };
    std::fs::write(ds.join("Tiny_TRAIN.tsv"), mk_rows(0.0)).unwrap();
    std::fs::write(ds.join("Tiny_TEST.tsv"), mk_rows(0.1)).unwrap();
    let tt = pqdtw::data::ucr_loader::load_ucr_dataset(&dir, "Tiny").unwrap();
    assert_eq!(tt.train.n_series(), 8);
    assert_eq!(tt.train.len, 16);
    let (err, _) = nn_classify_raw(&tt.train, &tt.test, Measure::Euclidean);
    assert!((0.0..=1.0).contains(&err));
}
