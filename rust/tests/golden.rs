//! Cross-language golden tests — the same fixtures and expected values
//! as python/tests/test_golden.py. Any drift between the Rust DTW/LB
//! implementations and the Python reference/Pallas kernels fails one of
//! the two suites.

use pqdtw::distance::dtw::dtw_sq;
use pqdtw::distance::envelope::Envelope;
use pqdtw::distance::lower_bounds::lb_keogh_sq;
use pqdtw::distance::pruned_dtw::pruned_dtw_sq;

const GOLD_A: [f64; 10] =
    [0.3, -1.04, 0.75, 0.94, -1.95, -1.3, 0.13, -0.32, -0.02, -0.85];
const GOLD_B: [f64; 10] =
    [0.88, 0.78, 0.07, 1.13, 0.47, -0.86, 0.37, -0.96, 0.88, -0.05];
const GOLD_DTW_SQ: [(usize, f64); 4] = [(0, 12.1145), (1, 5.4631), (2, 5.4631), (10, 4.2112)];

const GOLD_C: [f64; 8] = [1.0, -0.5, 2.5, 0.0, -1.5, 2.0, -0.5, 1.5];
const GOLD_Q: [f64; 8] = [0.0, 2.0, -1.0, 3.0, 0.5, -2.0, 1.0, 0.0];
const GOLD_ENV_W: usize = 2;
const GOLD_ENV_UPPER: [f64; 8] = [2.5, 2.5, 2.5, 2.5, 2.5, 2.0, 2.0, 2.0];
const GOLD_ENV_LOWER: [f64; 8] = [-0.5, -0.5, -1.5, -1.5, -1.5, -1.5, -1.5, -0.5];
const GOLD_LB_SQ: f64 = 0.5;

#[test]
fn dtw_matches_golden() {
    for (w, want) in GOLD_DTW_SQ {
        let got = dtw_sq(&GOLD_A, &GOLD_B, Some(w));
        assert!((got - want).abs() < 1e-9, "w={w}: {got} vs {want}");
    }
    // unconstrained == widest window here
    assert!((dtw_sq(&GOLD_A, &GOLD_B, None) - 4.2112).abs() < 1e-9);
}

#[test]
fn pruned_dtw_matches_golden() {
    for (w, want) in GOLD_DTW_SQ {
        let got = pruned_dtw_sq(&GOLD_A, &GOLD_B, Some(w), f64::INFINITY);
        assert!((got - want).abs() < 1e-9, "w={w}: {got} vs {want}");
    }
}

#[test]
fn envelope_matches_golden() {
    let env = Envelope::new(&GOLD_C, GOLD_ENV_W);
    for i in 0..8 {
        assert!((env.upper[i] - GOLD_ENV_UPPER[i]).abs() < 1e-12, "U[{i}]");
        assert!((env.lower[i] - GOLD_ENV_LOWER[i]).abs() < 1e-12, "L[{i}]");
    }
}

#[test]
fn lb_keogh_matches_golden() {
    let env = Envelope::new(&GOLD_C, GOLD_ENV_W);
    let got = lb_keogh_sq(&GOLD_Q, &env, f64::INFINITY);
    assert!((got - GOLD_LB_SQ).abs() < 1e-9, "{got}");
}
