//! Coordinator integration: the full service stack under concurrent load,
//! prediction-consistency with the library path, the three top-k serving
//! modes end-to-end, and backpressure behaviour.

use std::sync::Arc;

use pqdtw::coordinator::{
    BatcherConfig, Engine, Request, RequestClass, Response, Service, ServiceConfig,
};
use pqdtw::data::ucr_like::ucr_like_by_name;
use pqdtw::distance::dtw::dtw_sq;
use pqdtw::nn::ivf::CoarseMetric;
use pqdtw::nn::knn::{nn_classify_pq, PqQueryMode};
use pqdtw::pq::quantizer::PqConfig;

fn build_engine(seed: u64) -> (Arc<Engine>, pqdtw::core::series::Dataset) {
    let tt = ucr_like_by_name("CBF", seed).unwrap();
    let cfg = PqConfig {
        n_subspaces: 4,
        codebook_size: 16,
        window_frac: 0.2,
        ..Default::default()
    };
    (Arc::new(Engine::build(&tt.train, &cfg, seed).unwrap()), tt.test)
}

#[test]
fn service_predictions_match_library_path() {
    let (engine, test) = build_engine(301);
    // Library-path predictions (asymmetric mode).
    let (_, want_preds) = nn_classify_pq(
        &engine.pq,
        &engine.encoded,
        &test,
        PqQueryMode::Asymmetric,
    );
    let svc = Service::start(Arc::clone(&engine), ServiceConfig::default());
    for i in 0..test.n_series().min(20) {
        match svc.call(Request::NnQuery {
            series: test.row(i).to_vec(),
            mode: PqQueryMode::Asymmetric,
            nprobe: None,
        }) {
            Response::Nn { label, .. } => {
                assert_eq!(label, Some(want_preds[i]), "query {i}");
            }
            other => panic!("unexpected {other:?}"),
        }
    }
    svc.shutdown();
}

#[test]
fn concurrent_load_with_batching() {
    let (engine, test) = build_engine(303);
    let svc = Arc::new(Service::start(
        engine,
        ServiceConfig {
            n_workers: 3,
            batcher: BatcherConfig {
                max_batch: 8,
                max_delay: std::time::Duration::from_millis(1),
            },
        },
    ));
    let test = Arc::new(test);
    let mut handles = Vec::new();
    for t in 0..6 {
        let svc = Arc::clone(&svc);
        let test = Arc::clone(&test);
        handles.push(std::thread::spawn(move || {
            let mut ok = 0;
            for i in 0..15 {
                let idx = (t * 15 + i) % test.n_series();
                match svc.call(Request::NnQuery {
                    series: test.row(idx).to_vec(),
                    mode: PqQueryMode::Symmetric,
                    nprobe: None,
                }) {
                    Response::Nn { .. } => ok += 1,
                    other => panic!("{other:?}"),
                }
            }
            ok
        }));
    }
    let total: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
    assert_eq!(total, 90);
    let m = svc.metrics();
    assert_eq!(m.requests, 90);
    assert_eq!(m.errors, 0);
    assert!(m.batches <= 90, "batching should group at least sometimes");
    assert!(m.mean_latency_us > 0.0);
}

#[test]
fn topk_three_modes_end_to_end() {
    // The acceptance contract: a TopKQuery served end-to-end through the
    // threaded Service in all three modes — exhaustive scan, IVF-probed,
    // DTW re-ranked — with the full probe bit-identical to the
    // exhaustive scan and re-ranked distances equal to true DTW.
    let tt = ucr_like_by_name("CBF", 401).unwrap();
    let cfg = PqConfig {
        n_subspaces: 4,
        codebook_size: 16,
        window_frac: 0.2,
        ..Default::default()
    };
    let mut engine = Engine::build(&tt.train, &cfg, 11).unwrap();
    engine.set_scan_threads(2);
    engine.enable_ivf(6, CoarseMetric::Dtw { window: engine.full_window() }, 5);
    let nlist = engine.ivf.as_ref().unwrap().nlist();
    let window = engine.full_window();
    let train = engine.raw.clone();
    let engine = Arc::new(engine);
    let svc = Service::start(
        Arc::clone(&engine),
        ServiceConfig { n_workers: 2, batcher: BatcherConfig::default() },
    );

    let k = 5;
    for i in 0..8 {
        let q = tt.test.row(i).to_vec();

        // mode 1: exhaustive (sharded) scan
        let exhaustive = svc.call(Request::TopKQuery {
            series: q.clone(),
            k,
            mode: PqQueryMode::Asymmetric,
            nprobe: None,
            rerank: None,
        });
        let Response::TopK(ref exh_hits) = exhaustive else {
            panic!("unexpected {exhaustive:?}");
        };
        assert_eq!(exh_hits.len(), k);
        for w in exh_hits.windows(2) {
            assert!(w[0].distance <= w[1].distance + 1e-12, "ascending order");
        }
        for h in exh_hits {
            assert!(h.label.is_some(), "labels attached");
        }

        // mode 2: IVF-probed; at nprobe = nlist it must be bit-identical
        let probed_full = svc.call(Request::TopKQuery {
            series: q.clone(),
            k,
            mode: PqQueryMode::Asymmetric,
            nprobe: Some(nlist),
            rerank: None,
        });
        assert_eq!(exhaustive, probed_full, "query {i}: full probe != exhaustive");
        // a narrow probe still returns ranked hits from the probed cells
        let probed_narrow = svc.call(Request::TopKQuery {
            series: q.clone(),
            k,
            mode: PqQueryMode::Asymmetric,
            nprobe: Some(1),
            rerank: None,
        });
        let Response::TopK(ref narrow_hits) = probed_narrow else {
            panic!("unexpected {probed_narrow:?}");
        };
        // the probed cell may hold fewer than k members
        assert!(narrow_hits.len() <= k);

        // mode 3: re-ranked — distances must be true windowed DTW
        let reranked = svc.call(Request::TopKQuery {
            series: q.clone(),
            k,
            mode: PqQueryMode::Asymmetric,
            nprobe: None,
            rerank: Some(4 * k),
        });
        let Response::TopK(ref rr_hits) = reranked else {
            panic!("unexpected {reranked:?}");
        };
        assert_eq!(rr_hits.len(), k);
        for h in rr_hits {
            let want = dtw_sq(&q, train.row(h.index), window).sqrt();
            assert!(
                (h.distance - want).abs() < 1e-9,
                "query {i} index {}: re-ranked {} != true DTW {}",
                h.index,
                h.distance,
                want
            );
        }
    }

    // per-mode latency counters saw each serving mode
    let m = svc.shutdown();
    assert_eq!(m.class(RequestClass::TopKExhaustive).requests, 8);
    assert_eq!(m.class(RequestClass::TopKProbed).requests, 16);
    assert_eq!(m.class(RequestClass::TopKReranked).requests, 8);
    assert_eq!(m.errors, 0);
}

#[test]
fn saved_index_serves_identically_through_service() {
    // The build-once / serve-many contract end-to-end: an engine saved
    // to disk and reopened (no retraining) must answer every request
    // bit-identically to the original — through the threaded Service,
    // in all serving modes.
    let tt = ucr_like_by_name("CBF", 409).unwrap();
    let cfg = PqConfig {
        n_subspaces: 4,
        codebook_size: 16,
        window_frac: 0.2,
        ..Default::default()
    };
    let mut engine = Engine::build(&tt.train, &cfg, 13).unwrap();
    engine.enable_ivf(6, CoarseMetric::Dtw { window: engine.full_window() }, 13);
    let nlist = engine.ivf.as_ref().unwrap().nlist();

    let dir = pqdtw::testutil::unique_temp_dir("coord_store");
    let path = dir.join("cbf.pqx");
    engine.save(&path).unwrap();
    let reopened = Engine::open(&path).unwrap();
    std::fs::remove_dir_all(&dir).ok();

    let svc_mem = Service::start(Arc::new(engine), ServiceConfig::default());
    let svc_disk = Service::start(Arc::new(reopened), ServiceConfig::default());
    for i in 0..10 {
        let q = tt.test.row(i).to_vec();
        for req in [
            Request::NnQuery {
                series: q.clone(),
                mode: PqQueryMode::Asymmetric,
                nprobe: Some(2),
            },
            Request::TopKQuery {
                series: q.clone(),
                k: 5,
                mode: PqQueryMode::Asymmetric,
                nprobe: None,
                rerank: None,
            },
            Request::TopKQuery {
                series: q.clone(),
                k: 5,
                mode: PqQueryMode::Symmetric,
                nprobe: Some(nlist),
                rerank: None,
            },
            Request::TopKQuery {
                series: q,
                k: 3,
                mode: PqQueryMode::Asymmetric,
                nprobe: Some(2),
                rerank: Some(12),
            },
        ] {
            assert_eq!(svc_mem.call(req.clone()), svc_disk.call(req), "query {i}");
        }
    }
    svc_mem.shutdown();
    svc_disk.shutdown();
}

#[test]
fn mixed_request_types() {
    let (engine, test) = build_engine(307);
    let svc = Service::start(engine, ServiceConfig::default());
    let r1 = svc.call(Request::Encode { series: test.row(0).to_vec() });
    assert!(matches!(r1, Response::Codes(ref c) if c.len() == 4));
    let r2 = svc.call(Request::PairDist { i: 0, j: 5 });
    assert!(matches!(r2, Response::Dist(d) if d >= 0.0));
    let r3 = svc.call(Request::Encode { series: vec![0.0; 5] });
    assert!(matches!(r3, Response::Error(_)));
    let m = svc.shutdown();
    assert_eq!(m.requests, 3);
    assert_eq!(m.errors, 1);
}

#[test]
fn queue_depth_visible_under_burst() {
    let (engine, test) = build_engine(311);
    // Single slow worker, long delay: queue must build up.
    let svc = Arc::new(Service::start(
        engine,
        ServiceConfig {
            n_workers: 1,
            batcher: BatcherConfig {
                max_batch: 1,
                max_delay: std::time::Duration::from_millis(20),
            },
        },
    ));
    let mut rxs = Vec::new();
    for i in 0..10 {
        let q = test.row(i % test.n_series()).to_vec();
        rxs.push(
            svc.submit(Request::NnQuery {
                series: q,
                mode: PqQueryMode::Symmetric,
                nprobe: None,
            })
            .unwrap(),
        );
    }
    // At least some requests should still be queued at this instant.
    // (not asserted strictly — just must not panic and must drain)
    let _ = svc.queue_depth();
    for rx in rxs {
        assert!(matches!(rx.recv().unwrap(), Response::Nn { .. }));
    }
}
