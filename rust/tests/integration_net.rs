//! Loopback integration tests for the network serving plane: a real
//! `TcpListener` on 127.0.0.1:0, real client connections, and the
//! in-process engine as ground truth.
//!
//! The headline property: a networked query answers **bit-identically**
//! to `Engine::handle` across every serving mode (exhaustive scan,
//! IVF-probed, DTW re-ranked). Plus the hardening sweep: every byte
//! flip and every prefix truncation of a valid request frame, sent to a
//! live server, must never panic or wedge it.

use std::io::{Read, Write};
use std::net::{Shutdown, TcpStream};
use std::sync::Arc;
use std::time::Duration;

use pqdtw::coordinator::{Engine, Request, Response, Service, ServiceConfig};
use pqdtw::data::ucr_like::ucr_like_by_name;
use pqdtw::net::protocol::{self, NetRequest, NetResponse};
use pqdtw::net::{Client, ClientConfig, NetServer, ServerConfig};
use pqdtw::nn::ivf::CoarseMetric;
use pqdtw::nn::knn::PqQueryMode;
use pqdtw::obs::{prometheus, Stage};
use pqdtw::pq::quantizer::PqConfig;

/// A small served engine with an IVF index, plus the matching queries.
fn toy_server(
    cfg: ServerConfig,
) -> (NetServer, Arc<Service>, Arc<Engine>, pqdtw::core::series::Dataset, String) {
    let tt = ucr_like_by_name("SpikePosition", 77).unwrap();
    let pq_cfg = PqConfig {
        n_subspaces: 4,
        codebook_size: 8,
        window_frac: 0.2,
        ..Default::default()
    };
    let mut engine = Engine::build(&tt.train, &pq_cfg, 3).unwrap();
    engine.enable_ivf(6, CoarseMetric::Dtw { window: engine.full_window() }, 5);
    let engine = Arc::new(engine);
    let svc = Arc::new(Service::start(Arc::clone(&engine), ServiceConfig::default()));
    let server = NetServer::start("127.0.0.1:0", Arc::clone(&svc), cfg).unwrap();
    let addr = server.local_addr().to_string();
    (server, svc, engine, tt.test, addr)
}

fn quick_client(addr: &str) -> Client {
    Client::connect(
        addr,
        ClientConfig {
            connect_timeout: Duration::from_secs(5),
            io_timeout: Duration::from_secs(20),
        },
    )
    .unwrap()
}

#[test]
fn networked_queries_are_bit_identical_to_in_process() {
    let (server, _svc, engine, test, addr) = toy_server(ServerConfig::default());
    let nlist = engine.ivf.as_ref().unwrap().nlist();
    let mut client = quick_client(&addr);
    for i in 0..5 {
        let q = test.row(i).to_vec();
        // the full serving-mode dial: exhaustive, probed (full and
        // partial), re-ranked, probed + re-ranked
        let cases: [(Option<usize>, Option<usize>); 5] = [
            (None, None),
            (Some(nlist), None),
            (Some(2), None),
            (None, Some(12)),
            (Some(3), Some(9)),
        ];
        for (nprobe, rerank) in cases {
            let want = engine.handle(&Request::TopKQuery {
                series: q.clone(),
                k: 4,
                mode: PqQueryMode::Asymmetric,
                nprobe,
                rerank,
            });
            let got = client
                .topk(&q, 4, PqQueryMode::Asymmetric, nprobe, rerank)
                .unwrap_or_else(|e| panic!("query {i} ({nprobe:?},{rerank:?}): {e:#}"));
            match want {
                Response::TopK(hits) => assert_eq!(got, hits, "query {i} ({nprobe:?},{rerank:?})"),
                other => panic!("unexpected in-process response {other:?}"),
            }
        }
        // 1-NN, both query modes
        for mode in [PqQueryMode::Symmetric, PqQueryMode::Asymmetric] {
            let want = engine.handle(&Request::NnQuery { series: q.clone(), mode, nprobe: None });
            let (index, distance, label) = client.nn(&q, mode, None).unwrap();
            match want {
                Response::Nn { index: wi, distance: wd, label: wl } => {
                    assert_eq!((index, label), (wi, wl), "query {i} {mode:?}");
                    assert_eq!(distance.to_bits(), wd.to_bits(), "query {i} {mode:?}");
                }
                other => panic!("unexpected in-process response {other:?}"),
            }
        }
    }
    server.shutdown();
}

#[test]
fn wrong_length_query_gets_an_error_response_not_a_dead_server() {
    let (server, _svc, _engine, _test, addr) = toy_server(ServerConfig::default());
    let mut client = quick_client(&addr);
    let err = client
        .topk(&[1.0, 2.0, 3.0], 2, PqQueryMode::Asymmetric, None, None)
        .unwrap_err();
    assert!(format!("{err:#}").contains("length"), "{err:#}");
    // same connection keeps serving
    client.ping().unwrap();
    server.shutdown();
}

#[test]
fn hostile_frame_sweep_never_kills_the_server() {
    let (server, _svc, _engine, test, addr) = toy_server(ServerConfig {
        max_connections: 4096,
        ..Default::default()
    });
    // A short (deliberately wrong-length) but protocol-valid query
    // keeps the frame small enough to sweep exhaustively; the engine
    // answers it with an Error *response*, exercising the full path.
    let good = protocol::encode_request(&NetRequest::TopK {
        series: vec![0.5, -0.25, 1.5, 0.0],
        k: 2,
        mode: PqQueryMode::Asymmetric,
        nprobe: Some(2),
        rerank: Some(4),
        request_id: 7,
        trace: true,
    });
    let mut cases: Vec<Vec<u8>> = Vec::new();
    for n in 0..good.len() {
        cases.push(good[..n].to_vec());
    }
    for i in 0..good.len() {
        let mut bad = good.clone();
        bad[i] ^= 0x40;
        cases.push(bad);
    }
    for (ci, bytes) in cases.iter().enumerate() {
        let mut s = TcpStream::connect(&addr).unwrap_or_else(|e| panic!("case {ci}: {e}"));
        s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        s.set_write_timeout(Some(Duration::from_secs(10))).unwrap();
        // The server may legitimately disconnect mid-write; broken
        // pipes are part of the sweep, not failures.
        let _ = s.write_all(bytes);
        let _ = s.flush();
        // Half-close so the server sees EOF after the (possibly
        // malformed) frame and tears the connection down; draining the
        // response serializes the sweep so connections don't pile up.
        let _ = s.shutdown(Shutdown::Write);
        let mut sink = Vec::new();
        let _ = s.read_to_end(&mut sink);
    }
    // The server survived the sweep: a fresh, well-formed query works.
    let mut client = quick_client(&addr);
    client.ping().unwrap();
    let hits = client.topk(test.row(0), 3, PqQueryMode::Asymmetric, None, None).unwrap();
    assert_eq!(hits.len(), 3);
    server.shutdown();
}

#[test]
fn malformed_payload_keeps_the_connection_synchronized() {
    let (server, _svc, _engine, _test, addr) = toy_server(ServerConfig::default());
    let mut s = TcpStream::connect(&addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    // Unknown tag with a well-formed header: the payload is length-
    // delimited, so the server can report the error and keep serving
    // the same connection.
    let frame = protocol::encode_frame(42, &[1, 2, 3]);
    s.write_all(&frame).unwrap();
    let (tag, payload) = protocol::read_frame(&mut s, protocol::MAX_FRAME_BYTES)
        .unwrap()
        .expect("server must answer the bad frame");
    assert!(matches!(
        protocol::decode_response(tag, &payload).unwrap(),
        NetResponse::Error(_)
    ));
    // …and the stream is still frame-synchronized:
    s.write_all(&protocol::encode_request(&NetRequest::Ping)).unwrap();
    let (tag, payload) =
        protocol::read_frame(&mut s, protocol::MAX_FRAME_BYTES).unwrap().unwrap();
    assert_eq!(protocol::decode_response(tag, &payload).unwrap(), NetResponse::Pong);
    server.shutdown();
}

#[test]
fn oversized_frame_is_rejected_and_disconnected() {
    let (server, _svc, _engine, _test, addr) = toy_server(ServerConfig {
        max_frame_bytes: 256,
        ..Default::default()
    });
    let mut s = TcpStream::connect(&addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let frame = protocol::encode_request(&NetRequest::TopK {
        series: vec![0.0; 4096], // ≫ 256-byte frame limit
        k: 1,
        mode: PqQueryMode::Symmetric,
        nprobe: None,
        rerank: None,
        request_id: 0,
        trace: false,
    });
    let _ = s.write_all(&frame);
    let _ = s.flush();
    // First (and only) reply is an error naming the limit…
    let (tag, payload) = protocol::read_frame(&mut s, protocol::MAX_FRAME_BYTES)
        .unwrap()
        .expect("server must answer before disconnecting");
    match protocol::decode_response(tag, &payload).unwrap() {
        NetResponse::Error(msg) => assert!(msg.contains("limit"), "{msg}"),
        other => panic!("unexpected {other:?}"),
    }
    // …then the server hangs up (clean disconnect).
    assert!(protocol::read_frame(&mut s, protocol::MAX_FRAME_BYTES).unwrap().is_none());
    server.shutdown();
}

#[test]
fn connection_cap_rejects_excess_clients() {
    let (server, _svc, _engine, _test, addr) = toy_server(ServerConfig {
        max_connections: 2,
        ..Default::default()
    });
    let mut c1 = quick_client(&addr);
    c1.ping().unwrap();
    let mut c2 = quick_client(&addr);
    c2.ping().unwrap();
    // Both slots held; the third connect is turned away with an error
    // frame before any request is sent.
    let mut s = TcpStream::connect(&addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let (tag, payload) = protocol::read_frame(&mut s, protocol::MAX_FRAME_BYTES)
        .unwrap()
        .expect("rejected client must get an error frame");
    match protocol::decode_response(tag, &payload).unwrap() {
        NetResponse::Error(msg) => assert!(msg.contains("capacity"), "{msg}"),
        other => panic!("unexpected {other:?}"),
    }
    // Accepted clients are unaffected.
    c1.ping().unwrap();
    c2.ping().unwrap();
    server.shutdown();
}

#[test]
fn pipelined_requests_return_in_order() {
    let (server, _svc, _engine, _test, addr) = toy_server(ServerConfig::default());
    let mut s = TcpStream::connect(&addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(20))).unwrap();
    s.set_nodelay(true).unwrap();
    // Fire a burst without reading, then collect: replies must come
    // back in request order (ping, stats, ping, stats, …).
    for _ in 0..4 {
        s.write_all(&protocol::encode_request(&NetRequest::Ping)).unwrap();
        s.write_all(&protocol::encode_request(&NetRequest::Stats)).unwrap();
    }
    s.flush().unwrap();
    for round in 0..4 {
        let (tag, payload) =
            protocol::read_frame(&mut s, protocol::MAX_FRAME_BYTES).unwrap().unwrap();
        assert_eq!(
            protocol::decode_response(tag, &payload).unwrap(),
            NetResponse::Pong,
            "round {round}"
        );
        let (tag, payload) =
            protocol::read_frame(&mut s, protocol::MAX_FRAME_BYTES).unwrap().unwrap();
        assert!(
            matches!(protocol::decode_response(tag, &payload).unwrap(), NetResponse::Stats(_)),
            "round {round}"
        );
    }
    server.shutdown();
}

#[test]
fn stats_over_the_wire_account_for_every_class() {
    let (server, svc, _engine, test, addr) = toy_server(ServerConfig::default());
    let mut client = quick_client(&addr);
    client.ping().unwrap();
    client.topk(test.row(0), 2, PqQueryMode::Asymmetric, None, None).unwrap();
    client.topk(test.row(1), 2, PqQueryMode::Asymmetric, Some(2), None).unwrap();
    client.topk(test.row(2), 2, PqQueryMode::Asymmetric, None, Some(8)).unwrap();
    let stats = client.stats().unwrap();
    for class in ["ping", "topk_exhaustive", "topk_probed", "topk_reranked"] {
        let c = stats
            .per_class
            .iter()
            .find(|c| c.name == class)
            .unwrap_or_else(|| panic!("missing class {class}"));
        assert_eq!(c.requests, 1, "{class}");
        assert!(c.p50_us <= c.p99_us, "{class}");
    }
    // The wire snapshot mirrors the in-process one (modulo the stats
    // request itself racing the snapshot).
    assert!(svc.metrics().requests >= stats.requests);
    server.shutdown();
}

#[test]
fn traced_queries_are_bit_identical_and_explain_their_hits() {
    let (server, _svc, engine, test, addr) = toy_server(ServerConfig::default());
    let nlist = engine.ivf.as_ref().unwrap().nlist();
    let mut client = quick_client(&addr);
    // The full serving-mode dial again, this time with tracing on: the
    // trace must never perturb the ranked answer (bit-identity), and
    // the stage ladder must mirror the mode that actually ran.
    let cases: [(Option<usize>, Option<usize>); 4] =
        [(None, None), (Some(nlist), None), (None, Some(12)), (Some(3), Some(9))];
    for (i, (nprobe, rerank)) in cases.into_iter().enumerate() {
        let q = test.row(i).to_vec();
        let plain = client.topk(&q, 4, PqQueryMode::Asymmetric, nprobe, rerank).unwrap();
        let rid = 1000 + i as u64;
        let (traced, trace) = client
            .topk_traced(&q, 4, PqQueryMode::Asymmetric, nprobe, rerank, rid, true)
            .unwrap();
        assert_eq!(traced, plain, "case {i}: tracing must not change the answer");
        let t = trace.expect("trace was requested");
        assert_eq!(t.request_id, rid, "case {i}: server must echo the request id");
        // One explanation per hit, in hit order, indices matching.
        assert_eq!(t.hits.len(), traced.len(), "case {i}");
        for (ex, hit) in t.hits.iter().zip(&traced) {
            assert_eq!(ex.index, hit.index as u64, "case {i}");
            if rerank.is_some() {
                let dtw = ex.exact_dtw.expect("re-ranked hits carry exact DTW");
                assert_eq!(dtw.to_bits(), hit.distance.to_bits(), "case {i}");
            } else {
                assert_eq!(ex.pq_estimate.to_bits(), hit.distance.to_bits(), "case {i}");
                assert!(ex.exact_dtw.is_none(), "case {i}");
            }
        }
        // Stage ladder matches the dial: scan always runs, coarse probe
        // iff nprobe, rerank iff rerank.
        assert!(t.span(Stage::LutCollapse).is_some(), "case {i}");
        assert!(t.span(Stage::BlockedScan).is_some(), "case {i}");
        assert_eq!(t.span(Stage::CoarseProbe).is_some(), nprobe.is_some(), "case {i}");
        assert_eq!(t.span(Stage::Rerank).is_some(), rerank.is_some(), "case {i}");
        if let Some(s) = t.span(Stage::Rerank) {
            assert_eq!(s.candidates_out, traced.len() as u64, "case {i}");
        }
        // Kernel accounting is conserved: everything scanned was either
        // abandoned by the prune cascade or fully measured.
        assert!(t.scan.items_abandoned <= t.scan.items_scanned, "case {i}");
        // Tracing stays opt-in: same query through the traced API with
        // the flag off returns the same hits and no trace.
        let (again, none) = client
            .topk_traced(&q, 4, PqQueryMode::Asymmetric, nprobe, rerank, rid, false)
            .unwrap();
        assert_eq!(again, plain, "case {i}");
        assert!(none.is_none(), "case {i}: trace must be opt-in");
    }
    // 1-NN through the traced path, both query modes.
    let q = test.row(0).to_vec();
    for mode in [PqQueryMode::Symmetric, PqQueryMode::Asymmetric] {
        let (wi, wd, wl) = client.nn(&q, mode, None).unwrap();
        let (index, distance, label, trace) =
            client.nn_traced(&q, mode, None, 77, true).unwrap();
        assert_eq!((index, label), (wi, wl), "{mode:?}");
        assert_eq!(distance.to_bits(), wd.to_bits(), "{mode:?}");
        let t = trace.expect("trace was requested");
        assert_eq!(t.request_id, 77, "{mode:?}");
        assert!(t.span(Stage::BlockedScan).is_some(), "{mode:?}");
    }
    server.shutdown();
}

#[test]
fn metrics_text_is_valid_prometheus_over_the_wire() {
    let (server, _svc, _engine, test, addr) = toy_server(ServerConfig::default());
    let mut client = quick_client(&addr);
    client.topk(test.row(0), 2, PqQueryMode::Asymmetric, None, None).unwrap();
    client.topk(test.row(1), 2, PqQueryMode::Asymmetric, None, Some(8)).unwrap();
    let text = client.metrics_text().unwrap();
    let samples = prometheus::validate_exposition(&text)
        .unwrap_or_else(|e| panic!("invalid exposition: {e}\n{text}"));
    assert!(samples > 10, "expected a real document, got {samples} samples");
    for name in [
        "pqdtw_requests_total",
        "pqdtw_request_latency_microseconds",
        "pqdtw_stage_latency_microseconds",
        "pqdtw_scan_items_scanned_total",
        "pqdtw_index_items",
        "pqdtw_build_info",
        "pqdtw_uptime_seconds",
    ] {
        assert!(text.contains(name), "missing {name} in:\n{text}");
    }
    server.shutdown();
}

#[test]
fn wire_stats_carry_stage_histograms_and_the_index_header() {
    let (server, _svc, engine, test, addr) = toy_server(ServerConfig::default());
    let mut client = quick_client(&addr);
    client.topk(test.row(0), 2, PqQueryMode::Asymmetric, None, None).unwrap();
    client.topk(test.row(1), 2, PqQueryMode::Asymmetric, None, Some(8)).unwrap();
    let stats = client.stats().unwrap();
    // Index header summary matches the engine we built.
    let info = engine.info();
    assert_eq!(stats.n_subspaces, info.n_subspaces as u64);
    assert_eq!(stats.codebook_size, info.codebook_size as u64);
    assert_eq!(stats.series_len, info.series_len as u64);
    assert_eq!(stats.n_items, info.n_items as u64);
    assert_eq!(stats.coarse_metric, info.coarse_metric);
    assert_eq!(stats.nlist, info.nlist);
    assert_eq!(stats.version, env!("CARGO_PKG_VERSION"));
    // Per-stage histograms: both queries crossed the blocked scan, one
    // crossed the rerank.
    let by_name = |n: &str| {
        stats
            .per_stage
            .iter()
            .find(|s| s.name == n)
            .unwrap_or_else(|| panic!("missing stage {n}"))
    };
    assert_eq!(by_name("blocked_scan").count, 2);
    assert_eq!(by_name("rerank").count, 1);
    assert!(by_name("blocked_scan").p50_us <= by_name("blocked_scan").p99_us);
    // Kernel counters flowed into the engine-global sink.
    assert!(stats.scan.items_scanned > 0);
    server.shutdown();
}

/// One blocking HTTP/1.1 GET against the scrape endpoint; returns
/// (status line, full header block, body).
fn http_get(addr: &str, path: &str) -> (String, String, String) {
    let mut s = TcpStream::connect(addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    write!(s, "GET {path} HTTP/1.1\r\nHost: test\r\nConnection: close\r\n\r\n").unwrap();
    let mut raw = Vec::new();
    s.read_to_end(&mut raw).unwrap();
    let text = String::from_utf8(raw).unwrap();
    let (head, body) = text.split_once("\r\n\r\n").expect("header/body split");
    let status = head.lines().next().unwrap_or_default().to_string();
    (status, head.to_string(), body.to_string())
}

#[test]
fn http_scrape_serves_the_exposition_and_health_documents() {
    use pqdtw::net::{HttpConfig, HttpEndpoints, HttpServer};
    use pqdtw::obs::log::JsonLogger;

    let (server, svc, _engine, test, addr) = toy_server(ServerConfig::default());
    let mut client = quick_client(&addr);
    client.topk(test.row(0), 2, PqQueryMode::Asymmetric, None, None).unwrap();

    let metrics_svc = Arc::clone(&svc);
    let healthz_svc = Arc::clone(&svc);
    let http = HttpServer::start(
        "127.0.0.1:0",
        HttpEndpoints {
            metrics: Arc::new(move || metrics_svc.prometheus_text()),
            healthz: Arc::new(move || healthz_svc.healthz_json()),
        },
        HttpConfig::default(),
        Arc::new(JsonLogger::disabled()),
    )
    .unwrap();
    let haddr = http.local_addr().to_string();

    // `GET /metrics` is the same validated exposition the wire verb
    // serves, now reachable by a stock Prometheus scraper.
    let (status, head, body) = http_get(&haddr, "/metrics");
    assert!(status.contains("200"), "{status}");
    assert!(head.contains("text/plain"), "{head}");
    let samples = prometheus::validate_exposition(&body)
        .unwrap_or_else(|e| panic!("invalid exposition over HTTP: {e}\n{body}"));
    assert!(samples > 10, "expected a real document, got {samples} samples");
    assert!(body.contains("pqdtw_requests_total"), "{body}");

    // `GET /healthz` answers liveness as JSON.
    let (status, head, body) = http_get(&haddr, "/healthz");
    assert!(status.contains("200"), "{status}");
    assert!(head.contains("application/json"), "{head}");
    assert!(body.contains("\"status\":\"ok\""), "{body}");
    assert!(body.contains("\"queue_depth\""), "{body}");

    // Unknown paths are a clean 404, and the listener keeps serving.
    let (status, _, _) = http_get(&haddr, "/fav.ico");
    assert!(status.contains("404"), "{status}");
    let (status, _, _) = http_get(&haddr, "/metrics");
    assert!(status.contains("200"), "{status}");

    http.shutdown();
    server.shutdown();
}

#[test]
fn server_slow_query_log_flags_every_crossing_query() {
    use pqdtw::obs::log::JsonLogger;
    use std::sync::Mutex;

    #[derive(Default, Clone)]
    struct LogBuf(Arc<Mutex<Vec<u8>>>);
    impl Write for LogBuf {
        fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
            self.0.lock().unwrap().extend_from_slice(buf);
            Ok(buf.len())
        }
        fn flush(&mut self) -> std::io::Result<()> {
            Ok(())
        }
    }

    let tt = ucr_like_by_name("SpikePosition", 77).unwrap();
    let pq_cfg = PqConfig {
        n_subspaces: 4,
        codebook_size: 8,
        window_frac: 0.2,
        ..Default::default()
    };
    let engine = Arc::new(Engine::build(&tt.train, &pq_cfg, 3).unwrap());
    let svc = Arc::new(Service::start(Arc::clone(&engine), ServiceConfig::default()));
    let buf = LogBuf::default();
    let server = NetServer::start_logged(
        "127.0.0.1:0",
        Arc::clone(&svc),
        // Threshold zero: every query crosses, so the test is
        // deterministic regardless of machine speed.
        ServerConfig { slow_query_us: Some(0), ..Default::default() },
        Arc::new(JsonLogger::to_writer(Box::new(buf.clone()))),
    )
    .unwrap();
    let mut client = quick_client(&server.local_addr().to_string());

    let q = tt.test.row(0);
    client.topk_traced(q, 3, PqQueryMode::Asymmetric, None, None, 9, true).unwrap();
    // Non-query verbs never count as slow queries.
    client.ping().unwrap();
    client.stats().unwrap();

    let text = String::from_utf8(buf.0.lock().unwrap().clone()).unwrap();
    let slow: Vec<&str> =
        text.lines().filter(|l| l.contains("\"event\":\"slow_query\"")).collect();
    assert_eq!(slow.len(), 1, "{text}");
    assert!(slow[0].contains("\"request_id\":9"), "{}", slow[0]);
    assert!(slow[0].contains("\"class\":\"topk_exhaustive\""), "{}", slow[0]);
    assert!(slow[0].contains("\"degraded\":false"), "{}", slow[0]);
    // The traced query's event summarizes its stage ladder.
    assert!(slow[0].contains("blocked_scan="), "{}", slow[0]);
    // The counter rides the exposition.
    let mtext = client.metrics_text().unwrap();
    assert!(mtext.contains("pqdtw_slow_queries_total 1"), "{mtext}");
    server.shutdown();
}

#[test]
fn wire_stats_bucket_counts_reconstruct_the_percentiles() {
    use pqdtw::coordinator::{histogram_percentile, BUCKETS_US};

    let (server, _svc, _engine, test, addr) = toy_server(ServerConfig::default());
    let mut client = quick_client(&addr);
    for i in 0..4 {
        client.topk(test.row(i), 2, PqQueryMode::Asymmetric, None, None).unwrap();
    }
    let stats = client.stats().unwrap();
    // Raw per-bucket counts ride along with every percentile, sized to
    // the shared ladder, and total to the request count.
    assert_eq!(stats.latency_buckets.len(), BUCKETS_US.len());
    assert_eq!(stats.latency_buckets.iter().sum::<u64>(), stats.requests);
    // The scalar percentiles the server reports are exactly what the
    // buckets reproduce — the invariant exact federation relies on.
    let hist: Vec<(u64, u64)> = BUCKETS_US
        .iter()
        .zip(&stats.latency_buckets)
        .map(|(&ub, &c)| (ub, c))
        .collect();
    assert_eq!(stats.p50_us, histogram_percentile(&hist, 0.5));
    assert_eq!(stats.p99_us, histogram_percentile(&hist, 0.99));
    for class in &stats.per_class {
        assert_eq!(class.buckets.len(), BUCKETS_US.len());
        assert_eq!(class.buckets.iter().sum::<u64>(), class.requests);
    }
    for stage in &stats.per_stage {
        assert_eq!(stage.buckets.len(), BUCKETS_US.len());
        assert_eq!(stage.buckets.iter().sum::<u64>(), stage.count);
    }
    server.shutdown();
}

#[test]
fn shutdown_frame_drains_the_server() {
    let (server, svc, _engine, test, addr) = toy_server(ServerConfig::default());
    let mut worker = quick_client(&addr);
    worker.topk(test.row(0), 2, PqQueryMode::Asymmetric, None, None).unwrap();
    let mut admin = quick_client(&addr);
    admin.shutdown().unwrap(); // ShutdownAck received
    server.wait(); // returns once the drain completes; joins all threads
    // The listener is gone: new connections are refused (or reset).
    assert!(TcpStream::connect_timeout(
        &addr.parse().unwrap(),
        Duration::from_millis(500)
    )
    .is_err());
    // The service behind the server is intact and accounted the work.
    assert!(svc.metrics().requests >= 2);
}
