//! Runtime integration: the AOT-compiled JAX/Pallas encode graph (via
//! PJRT) must agree with the native Rust encoder — the cross-layer
//! correctness contract of the three-layer architecture.
//!
//! These tests are gated on the `pjrt` feature and on `make artifacts`
//! having produced the HLO files; without either they no-op so the
//! default `cargo test` loop stays hermetic.

use pqdtw::runtime::artifacts::Manifest;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.tsv").exists().then_some(dir)
}

#[test]
fn manifest_parses_when_built() {
    if let Some(dir) = artifacts_dir() {
        let m = Manifest::load(&dir).unwrap();
        assert!(!m.specs.is_empty());
    }
}

#[cfg(feature = "pjrt")]
mod pjrt {
    use super::*;
    use pqdtw::data::random_walk::RandomWalks;
    use pqdtw::pq::quantizer::{PqConfig, PqMetric, ProductQuantizer};
    use pqdtw::runtime::encoder::PjrtEncoder;

    /// Train a quantizer whose shape matches the first encode artifact
    /// variant lowered by aot.py: M=4, K=16, L=25, window=5 (series
    /// length 100).
    fn matching_quantizer() -> (ProductQuantizer, pqdtw::core::series::Dataset) {
        let data = RandomWalks::new(97).generate(64, 100);
        let cfg = PqConfig {
            n_subspaces: 4,
            codebook_size: 16,
            window_frac: 0.2, // ceil(0.2 * 25) = 5
            metric: PqMetric::Dtw,
            prealign: None,
            kmeans_iters: 4,
            dba_iters: 2,
            train_subsample: None,
        };
        let pq = ProductQuantizer::train(&data, &cfg, 11).unwrap();
        assert_eq!(pq.codebook.sub_len, 25);
        assert_eq!(pq.codebook.window, Some(5));
        (pq, data)
    }

    #[test]
    fn pjrt_encoder_matches_native() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: run `make artifacts` first");
            return;
        };
        let manifest = Manifest::load(&dir).unwrap();
        let (pq, data) = matching_quantizer();
        let mut enc = PjrtEncoder::new(&pq, &manifest).expect("encoder");
        assert_eq!(enc.shape(), (4, 16, 25));

        let mut agree = 0usize;
        let n = 32.min(data.n_series());
        for i in 0..n {
            let x = data.row(i);
            let via_pjrt = enc.encode(&pq, x).unwrap();
            let (native, _, _) = pq.encode(x);
            assert_eq!(via_pjrt.len(), native.len());
            // f32 vs f64 can flip near-exact ties; require the PJRT code
            // to be as close to the subspace as the native one within
            // float32 slack, and count exact agreement.
            if via_pjrt == native {
                agree += 1;
            } else {
                let subs = pq.segment(x);
                for (m, s) in subs.iter().enumerate() {
                    let d_pjrt = pqdtw::distance::dtw::dtw_sq(
                        s,
                        pq.codebook.centroid(m, via_pjrt[m] as usize),
                        pq.codebook.window,
                    );
                    let d_native = pqdtw::distance::dtw::dtw_sq(
                        s,
                        pq.codebook.centroid(m, native[m] as usize),
                        pq.codebook.window,
                    );
                    assert!(
                        (d_pjrt - d_native).abs() <= 1e-3 * (1.0 + d_native),
                        "series {i} subspace {m}: pjrt {d_pjrt} vs native {d_native}"
                    );
                }
            }
        }
        assert!(
            agree * 10 >= n * 9,
            "only {agree}/{n} series encoded identically via PJRT"
        );
    }

    #[test]
    fn pjrt_missing_shape_is_reported() {
        let Some(dir) = artifacts_dir() else {
            return;
        };
        let manifest = Manifest::load(&dir).unwrap();
        let data = RandomWalks::new(1).generate(8, 64);
        // Shape (2, 4, 32, w) has no artifact.
        let cfg = PqConfig {
            n_subspaces: 2,
            codebook_size: 4,
            window_frac: 0.1,
            ..Default::default()
        };
        let pq = ProductQuantizer::train(&data, &cfg, 1).unwrap();
        assert!(PjrtEncoder::new(&pq, &manifest).is_err());
    }
}
