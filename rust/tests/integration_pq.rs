//! End-to-end integration of the PQ pipeline: train → encode → distances,
//! including the approximation-quality contract against true DTW.

use pqdtw::core::matrix::CondensedMatrix;
use pqdtw::data::random_walk::RandomWalks;
use pqdtw::data::ucr_like::ucr_like_by_name;
use pqdtw::distance::dtw::dtw;
use pqdtw::pq::quantizer::{PqConfig, PqMetric, PrealignConfig, ProductQuantizer};

/// Spearman rank correlation between two equal-length slices.
fn spearman(a: &[f64], b: &[f64]) -> f64 {
    fn ranks(v: &[f64]) -> Vec<f64> {
        let mut idx: Vec<usize> = (0..v.len()).collect();
        idx.sort_by(|&i, &j| v[i].partial_cmp(&v[j]).unwrap());
        let mut r = vec![0.0; v.len()];
        for (rank, &i) in idx.iter().enumerate() {
            r[i] = rank as f64;
        }
        r
    }
    let ra = ranks(a);
    let rb = ranks(b);
    let n = a.len() as f64;
    let ma = ra.iter().sum::<f64>() / n;
    let mb = rb.iter().sum::<f64>() / n;
    let mut num = 0.0;
    let mut da = 0.0;
    let mut db = 0.0;
    for i in 0..a.len() {
        let xa = ra[i] - ma;
        let xb = rb[i] - mb;
        num += xa * xb;
        da += xa * xa;
        db += xb * xb;
    }
    num / (da.sqrt() * db.sqrt())
}

#[test]
fn pq_distances_preserve_dtw_ranking() {
    // The PQ approximation must preserve the *ordering* of DTW distances
    // well — that's what 1-NN and clustering quality rest on.
    let data = RandomWalks::new(3).generate(40, 96);
    let cfg = PqConfig {
        n_subspaces: 4,
        codebook_size: 32,
        window_frac: 0.2,
        ..Default::default()
    };
    let pq = ProductQuantizer::train(&data, &cfg, 5).unwrap();
    let enc = pq.encode_dataset(&data);
    let mut approx = Vec::new();
    let mut exact = Vec::new();
    for i in 0..data.n_series() {
        for j in (i + 1)..data.n_series() {
            approx.push(pq.patched_distance(&enc, i, j));
            exact.push(dtw(data.row(i), data.row(j), None));
        }
    }
    let rho = spearman(&approx, &exact);
    assert!(rho > 0.5, "rank correlation too low: {rho}");
}

#[test]
fn prealignment_does_not_break_pipeline_and_helps_on_phase_data() {
    let tt = ucr_like_by_name("SpikePosition", 71).unwrap();
    let base = PqConfig {
        n_subspaces: 4,
        codebook_size: 24,
        window_frac: 0.2,
        ..Default::default()
    };
    let pre = PqConfig {
        prealign: Some(PrealignConfig { level: 2, tail_frac: 0.2 }),
        ..base
    };
    for cfg in [base, pre] {
        let pq = ProductQuantizer::train(&tt.train, &cfg, 9).unwrap();
        let enc = pq.encode_dataset(&tt.train);
        let (err, _) = pqdtw::nn::knn::nn_classify_pq(
            &pq,
            &enc,
            &tt.test,
            pqdtw::nn::knn::PqQueryMode::Asymmetric,
        );
        // both must beat chance clearly on this 2-class dataset
        assert!(err < 0.4, "err={err} cfg={cfg:?}");
    }
}

#[test]
fn pq_ed_baseline_roundtrip() {
    let tt = ucr_like_by_name("CBF", 73).unwrap();
    let cfg = PqConfig {
        n_subspaces: 4,
        codebook_size: 24,
        metric: PqMetric::Euclidean,
        ..Default::default()
    };
    let pq = ProductQuantizer::train(&tt.train, &cfg, 3).unwrap();
    let enc = pq.encode_dataset(&tt.train);
    let (err, _) = pqdtw::nn::knn::nn_classify_pq(
        &pq,
        &enc,
        &tt.test,
        pqdtw::nn::knn::PqQueryMode::Asymmetric,
    );
    assert!(err < 0.5, "PQ_ED err={err}");
}

#[test]
fn symmetric_matrix_is_valid_for_clustering() {
    let data = RandomWalks::new(11).generate(24, 64);
    let cfg = PqConfig { n_subspaces: 4, codebook_size: 12, ..Default::default() };
    let pq = ProductQuantizer::train(&data, &cfg, 1).unwrap();
    let enc = pq.encode_dataset(&data);
    let n = data.n_series();
    let m = CondensedMatrix::build(n, |i, j| pq.patched_distance(&enc, i, j));
    // all finite, non-negative, and the matrix drives clustering end-to-end
    for i in 0..n {
        for j in 0..n {
            let d = m.get(i, j);
            assert!(d.is_finite() && d >= 0.0);
        }
    }
    let dend = pqdtw::cluster::agglomerative(&m, pqdtw::cluster::Linkage::Complete);
    let labels = dend.cut(3);
    assert_eq!(labels.len(), n);
    let distinct: std::collections::HashSet<_> = labels.iter().collect();
    assert_eq!(distinct.len(), 3);
}

#[test]
fn encoding_stats_show_cascade_pruning() {
    // On realistic data the LB cascade must prune a substantial share of
    // candidates (that's the paper's Fig. 5 speedup mechanism).
    let data = RandomWalks::new(17).generate(60, 128);
    let cfg = PqConfig {
        n_subspaces: 4,
        codebook_size: 32,
        window_frac: 0.1,
        ..Default::default()
    };
    let pq = ProductQuantizer::train(&data, &cfg, 2).unwrap();
    let enc = pq.encode_dataset(&data);
    let st = enc.stats;
    let pruned_frac = (st.pruned_kim + st.pruned_keogh) as f64 / st.candidates() as f64;
    assert!(
        pruned_frac > 0.3,
        "cascade pruned only {:.1}% ({:?})",
        pruned_frac * 100.0,
        st
    );
}

#[test]
fn memory_model_compression_matches_dataset() {
    let data = RandomWalks::new(23).generate(300, 256);
    let cfg = PqConfig {
        n_subspaces: 8,
        codebook_size: 256,
        train_subsample: Some(64),
        ..Default::default()
    };
    let pq = ProductQuantizer::train(&data, &cfg, 1).unwrap();
    let mm = pq.memory_model();
    // K clamps to the 64-series training subsample → 6-bit codes; the
    // §3.4 formula generalizes to 32·D / (M·log2 K).
    assert_eq!(pq.codebook.k, 64);
    assert_eq!(mm.code_bits_per_series, 8 * 6);
    assert!((mm.compression_factor - 32.0 * 256.0 / 48.0).abs() < 1e-9);
}
