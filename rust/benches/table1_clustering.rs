//! Table 1 (clustering columns): complete-linkage hierarchical clustering
//! with PQDTW vs the raw measures over the UCR-like suite — mean Rand
//! index difference and median speedup of the pairwise-matrix phase.
//!
//! Paper shape to reproduce: no significant RI differences between any of
//! the measures, but PQDTW one order of magnitude faster than cDTW/SBD
//! and two orders faster than DTW (no lower-bound pruning exists for full
//! pairwise matrices, so PQDTW's O(M)-per-pair LUT path dominates).
//!
//! Run: `cargo bench --bench table1_clustering`

use std::time::Instant;

use pqdtw::cluster::{agglomerative, compact_labels, rand_index, Linkage};
use pqdtw::core::matrix::CondensedMatrix;
use pqdtw::data::ucr_like::ucr_like_suite;
use pqdtw::distance::measure::Measure;
use pqdtw::eval::report::{fmt_mean_std, fmt_speedup, Table};
use pqdtw::eval::stats::{mean, pairwise_significance, std_dev, Significance};
use pqdtw::nn::knn::nn_classify_sax; // SAX words reused via mindist below
use pqdtw::pq::quantizer::{PqConfig, PrealignConfig, ProductQuantizer};
use pqdtw::repr::sax::SaxEncoder;

fn cluster_ri(m: &CondensedMatrix, k: usize, truth: &[usize]) -> f64 {
    let labels = agglomerative(m, Linkage::Complete).cut(k);
    rand_index(&labels, truth)
}

fn main() {
    let seed = 505u64;
    let suite = ucr_like_suite(seed);
    println!(
        "Table 1 (clustering, complete linkage) — {} UCR-like datasets\n",
        suite.len()
    );
    let names = ["ED", "DTW", "cDTW5", "cDTW10", "SBD", "SAX", "PQ_ED", "PQDTW"];
    let mut ris: Vec<Vec<f64>> = vec![Vec::new(); names.len()];
    let mut times: Vec<Vec<f64>> = vec![Vec::new(); names.len()];

    for tt in &suite {
        eprint!("  {} …", tt.name);
        let test = &tt.test;
        let n = test.n_series();
        let k = test.classes().len();
        let truth = compact_labels(&test.labels);

        // raw measures
        for (idx, measure) in [
            (0, Measure::Euclidean),
            (1, Measure::Dtw),
            (2, Measure::CDtw { window_frac: 0.05 }),
            (3, Measure::CDtw { window_frac: 0.10 }),
            (4, Measure::Sbd),
        ] {
            let t0 = Instant::now();
            let m = CondensedMatrix::build(n, |i, j| measure.dist(test.row(i), test.row(j)));
            times[idx].push(t0.elapsed().as_secs_f64());
            ris[idx].push(cluster_ri(&m, k, &truth));
        }

        // SAX mindist matrix
        {
            let enc = SaxEncoder::new(test.len, 4, 0.2);
            let t0 = Instant::now();
            let words: Vec<Vec<u8>> = (0..n).map(|i| enc.encode(test.row(i))).collect();
            let m = CondensedMatrix::build(n, |i, j| enc.mindist(&words[i], &words[j]));
            times[5].push(t0.elapsed().as_secs_f64());
            ris[5].push(cluster_ri(&m, k, &truth));
        }

        // PQ variants: train offline on the training split; the timed
        // phase is encode(test) + matrix, matching the paper's protocol.
        for (idx, metric, prealign) in [
            (6, pqdtw::pq::quantizer::PqMetric::Euclidean, None),
            (
                7,
                pqdtw::pq::quantizer::PqMetric::Dtw,
                Some(PrealignConfig { level: 2, tail_frac: 0.15 }),
            ),
        ] {
            let cfg = PqConfig {
                n_subspaces: 4,
                codebook_size: 64,
                window_frac: 0.1,
                metric,
                prealign,
                ..Default::default()
            };
            let pq = ProductQuantizer::train(&tt.train, &cfg, seed).unwrap();
            let t0 = Instant::now();
            let enc = pq.encode_dataset(test);
            let m = CondensedMatrix::build(n, |i, j| pq.patched_distance(&enc, i, j));
            times[idx].push(t0.elapsed().as_secs_f64());
            ris[idx].push(cluster_ri(&m, k, &truth));
        }
        eprintln!(" done");
    }

    // significance over RI (higher better → negate for rank machinery)
    let n_data = suite.len();
    let scores: Vec<Vec<f64>> = (0..n_data)
        .map(|d| ris.iter().map(|r| -r[d]).collect())
        .collect();
    let pq_idx = 7;

    let mut table = Table::new(
        "Table 1 — clustering vs PQDTW",
        &["measure", "mean RI diff (meas − PQDTW)", "speedup", "signif"],
    );
    for (i, name) in names.iter().enumerate().take(7) {
        let diffs: Vec<f64> = (0..n_data).map(|d| ris[i][d] - ris[pq_idx][d]).collect();
        let mut speedups: Vec<f64> =
            (0..n_data).map(|d| times[i][d] / times[pq_idx][d]).collect();
        let sig = match pairwise_significance(&scores, i, pq_idx) {
            Significance::FirstBetter => "* (PQDTW worse)",
            Significance::SecondBetter => "† (PQDTW better)",
            Significance::None => "",
        };
        table.add_row(vec![
            name.to_string(),
            fmt_mean_std(mean(&diffs), std_dev(&diffs), 3),
            fmt_speedup(pqdtw::eval::report::median(&mut speedups)),
            sig.to_string(),
        ]);
    }
    println!("\n{}", table.render());
    println!("PQDTW mean RI: {:.3}", mean(&ris[pq_idx]));
    println!("(timed phase: pairwise matrix construction + PQ test-encode;");
    println!(" agglomeration itself is measure-independent)");

    // Keep the SAX import honest (suppresses unused warnings on some
    // toolchains where inference changes): quick sanity value.
    let _ = nn_classify_sax;
}
