//! Figure 5a: empirical time complexity of PQDTW vs DTW on random walks —
//! full pairwise distance matrix runtime as a function of series length
//! and collection size.
//!
//! Paper reference points (Intel i7-2600, Cython): PQDTW 2.9× faster at
//! (N=100, len=100), 5.6× at (N=100, len=3200), 45.8× at (N=800,
//! len=3200). Lengths here are scaled to CI-friendly sizes; the *shape*
//! (speedup grows with length and with N) is the reproduction target.
//!
//! Run: `cargo bench --bench fig5a_scaling`

use std::time::Instant;

use pqdtw::core::matrix::CondensedMatrix;
use pqdtw::data::random_walk::RandomWalks;
use pqdtw::distance::euclidean::euclidean_sq;
use pqdtw::distance::pruned_dtw::pruned_dtw_sq;
use pqdtw::eval::report::{fmt_f, fmt_speedup, Table};
use pqdtw::pq::quantizer::{PqConfig, ProductQuantizer};

fn dtw_matrix_time(data: &pqdtw::core::series::Dataset) -> f64 {
    let n = data.n_series();
    let t0 = Instant::now();
    let _m = CondensedMatrix::build(n, |i, j| {
        let (a, b) = (data.row(i), data.row(j));
        let ub = euclidean_sq(a, b);
        let d = pruned_dtw_sq(a, b, None, ub + 1e-12);
        if d.is_finite() { d.sqrt() } else { ub.sqrt() }
    });
    t0.elapsed().as_secs_f64()
}

/// PQDTW with the paper's Fig. 5 setting: subspace size 20% (M=5), no
/// pre-alignment. Returns (train, encode, matrix) seconds.
fn pqdtw_times(data: &pqdtw::core::series::Dataset, k: usize) -> (f64, f64, f64) {
    let cfg = PqConfig {
        n_subspaces: 5,
        codebook_size: k,
        window_frac: 0.1,
        kmeans_iters: 3,
        dba_iters: 1,
        train_subsample: Some(64),
        ..Default::default()
    };
    let t0 = Instant::now();
    let pq = ProductQuantizer::train(data, &cfg, 1).unwrap();
    let t_train = t0.elapsed().as_secs_f64();
    let t0 = Instant::now();
    let enc = pq.encode_dataset(data);
    let t_enc = t0.elapsed().as_secs_f64();
    let t0 = Instant::now();
    let _m = CondensedMatrix::build(data.n_series(), |i, j| pq.patched_distance(&enc, i, j));
    (t_train, t_enc, t0.elapsed().as_secs_f64())
}

fn main() {
    println!("Figure 5a — pairwise distance matrix runtime, random walks\n");

    // --- sweep over series length at fixed N ---
    let n = 60;
    let mut t = Table::new(
        &format!("runtime vs series length (N={n})"),
        &["length", "DTW (s)", "PQDTW enc+mat (s)", "speedup", "(train s)"],
    );
    for len in [100, 200, 400, 800, 1600] {
        let data = RandomWalks::new(len as u64).generate(n, len);
        let t_dtw = dtw_matrix_time(&data);
        let (t_train, t_enc, t_mat) = pqdtw_times(&data, 64);
        let t_pq = t_enc + t_mat;
        t.add_row(vec![
            format!("{len}"),
            fmt_f(t_dtw, 3),
            fmt_f(t_pq, 3),
            fmt_speedup(t_dtw / t_pq),
            fmt_f(t_train, 3),
        ]);
    }
    println!("{}", t.render());

    // --- sweep over collection size at fixed length ---
    let len = 800;
    let mut t = Table::new(
        &format!("runtime vs collection size (len={len})"),
        &["N", "DTW (s)", "PQDTW enc+mat (s)", "speedup", "(train s)"],
    );
    for n in [50, 100, 200, 300] {
        let data = RandomWalks::new(n as u64).generate(n, len);
        let t_dtw = dtw_matrix_time(&data);
        let (t_train, t_enc, t_mat) = pqdtw_times(&data, 64);
        let t_pq = t_enc + t_mat;
        t.add_row(vec![
            format!("{n}"),
            fmt_f(t_dtw, 3),
            fmt_f(t_pq, 3),
            fmt_speedup(t_dtw / t_pq),
            fmt_f(t_train, 3),
        ]);
    }
    println!("{}", t.render());
    println!("paper shape: speedup grows with length (2.9x -> 5.6x at N=100)");
    println!("and with N (45.8x at N=800, len 3200): encode cost amortizes");
    println!("over O(N^2) pairs that are O(M) each.");
}
