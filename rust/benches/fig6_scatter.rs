//! Figure 6: per-dataset comparison of PQDTW against cDTWX — (a) 1-NN
//! classification error and (b) Rand index for complete-linkage
//! clustering. The paper plots these as scatter plots; this harness
//! prints the coordinate pairs plus which side of the diagonal each
//! dataset falls on.
//!
//! Paper shape: most points near the diagonal (small differences),
//! cDTWX slightly ahead on error overall.
//!
//! Run: `cargo bench --bench fig6_scatter`

use pqdtw::cluster::{agglomerative, compact_labels, rand_index, Linkage};
use pqdtw::core::matrix::CondensedMatrix;
use pqdtw::data::ucr_like::{ucr_like_suite, TrainTest};
use pqdtw::distance::measure::Measure;
use pqdtw::eval::report::{fmt_f, Table};
use pqdtw::eval::search::{tune_pq, SearchSpace};
use pqdtw::nn::knn::{nn_classify_pq, nn_classify_raw, PqQueryMode};
use pqdtw::pq::quantizer::ProductQuantizer;

fn best_window(tt: &TrainTest) -> f64 {
    let train = &tt.train;
    let n = train.n_series();
    let mut best = (f64::INFINITY, 0.05);
    for w in [0.02, 0.05, 0.1, 0.15, 0.2] {
        let measure = Measure::CDtw { window_frac: w };
        let mut errors = 0usize;
        for i in 0..n {
            let mut bd = f64::INFINITY;
            let mut bl = -1i64;
            for j in 0..n {
                if i != j {
                    let d = measure.dist(train.row(i), train.row(j));
                    if d < bd {
                        bd = d;
                        bl = train.label(j);
                    }
                }
            }
            if bl != train.label(i) {
                errors += 1;
            }
        }
        let err = errors as f64 / n as f64;
        if err < best.0 {
            best = (err, w);
        }
    }
    best.1
}

fn main() {
    let seed = 606u64;
    let suite = ucr_like_suite(seed);
    println!("Figure 6 — PQDTW vs cDTWX per dataset\n");

    let mut err_table = Table::new(
        "(a) 1NN classification error",
        &["dataset", "cDTWX err", "PQDTW err", "winner"],
    );
    let mut ri_table = Table::new(
        "(b) Rand index, complete linkage",
        &["dataset", "cDTWX RI", "PQDTW RI", "winner"],
    );
    let mut pq_wins_err = 0usize;
    let mut pq_wins_ri = 0usize;

    for tt in &suite {
        eprint!("  {} …", tt.name);
        let wx = best_window(tt);
        let cdtwx = Measure::CDtw { window_frac: wx };

        // tuned PQDTW
        let space = SearchSpace { codebook_size: 64, ..Default::default() };
        let tuned = tune_pq(&tt.train, &space, 6, 2, seed);
        let pq = ProductQuantizer::train(&tt.train, &tuned.config, seed).unwrap();
        let enc_train = pq.encode_dataset(&tt.train);

        // (a) errors
        let (err_x, _) = nn_classify_raw(&tt.train, &tt.test, cdtwx);
        let (err_pq, _) = nn_classify_pq(&pq, &enc_train, &tt.test, PqQueryMode::Symmetric);
        if err_pq <= err_x {
            pq_wins_err += 1;
        }
        err_table.add_row(vec![
            tt.name.clone(),
            fmt_f(err_x, 3),
            fmt_f(err_pq, 3),
            if err_pq < err_x { "PQDTW" } else if err_pq > err_x { "cDTWX" } else { "tie" }
                .to_string(),
        ]);

        // (b) rand index on test split
        let test = &tt.test;
        let n = test.n_series();
        let k = test.classes().len();
        let truth = compact_labels(&test.labels);
        let mx = CondensedMatrix::build(n, |i, j| cdtwx.dist(test.row(i), test.row(j)));
        let ri_x = rand_index(&agglomerative(&mx, Linkage::Complete).cut(k), &truth);
        let enc_test = pq.encode_dataset(test);
        let mp = CondensedMatrix::build(n, |i, j| pq.patched_distance(&enc_test, i, j));
        let ri_pq = rand_index(&agglomerative(&mp, Linkage::Complete).cut(k), &truth);
        if ri_pq >= ri_x {
            pq_wins_ri += 1;
        }
        ri_table.add_row(vec![
            tt.name.clone(),
            fmt_f(ri_x, 3),
            fmt_f(ri_pq, 3),
            if ri_pq > ri_x { "PQDTW" } else if ri_pq < ri_x { "cDTWX" } else { "tie" }
                .to_string(),
        ]);
        eprintln!(" done");
    }

    println!("\n{}", err_table.render());
    println!("PQDTW at least ties cDTWX on {}/{} datasets (error)\n", pq_wins_err, suite.len());
    println!("{}", ri_table.render());
    println!("PQDTW at least ties cDTWX on {}/{} datasets (RI)", pq_wins_ri, suite.len());
    println!("\npaper shape: points hug the diagonal; cDTWX slightly ahead on");
    println!("error (paper: PQDTW ≥ in 23/48), differences in RI insignificant.");
}
