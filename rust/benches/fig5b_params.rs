//! Figure 5b: effect of the subspace count M and codebook size K on
//! PQDTW runtime. Theory (paper §3.2): encoding is O(K·D²/M) — linear in
//! K, inverse-linear in M.
//!
//! Run: `cargo bench --bench fig5b_params`

use std::time::Instant;

use pqdtw::data::random_walk::RandomWalks;
use pqdtw::eval::report::{fmt_f, Table};
use pqdtw::pq::quantizer::{PqConfig, ProductQuantizer};

fn encode_time(data: &pqdtw::core::series::Dataset, m: usize, k: usize) -> (f64, f64) {
    let cfg = PqConfig {
        n_subspaces: m,
        codebook_size: k,
        window_frac: 0.1,
        kmeans_iters: 2,
        dba_iters: 1,
        train_subsample: Some(k.min(64)),
        ..Default::default()
    };
    let pq = ProductQuantizer::train(data, &cfg, 1).unwrap();
    let t0 = Instant::now();
    let enc = pq.encode_dataset(data);
    let dt = t0.elapsed().as_secs_f64();
    let st = enc.stats;
    let pruned = 100.0 * (st.pruned_kim + st.pruned_keogh) as f64 / st.candidates() as f64;
    (dt, pruned)
}

fn main() {
    println!("Figure 5b — encode runtime vs M and K, random walks\n");
    let data = RandomWalks::new(9).generate(100, 640);

    let mut t = Table::new(
        "encode time vs subspace count M (K=64, len=640, N=100)",
        &["M", "encode (s)", "LB-pruned %", "O(K·D²/M) prediction"],
    );
    let mut base = None;
    for m in [2usize, 4, 8, 16] {
        let (dt, pruned) = encode_time(&data, m, 64);
        let b = *base.get_or_insert(dt * m as f64);
        t.add_row(vec![
            format!("{m}"),
            fmt_f(dt, 3),
            fmt_f(pruned, 1),
            fmt_f(b / m as f64, 3),
        ]);
    }
    println!("{}", t.render());

    let mut t = Table::new(
        "encode time vs codebook size K (M=5, len=640, N=100)",
        &["K", "encode (s)", "LB-pruned %", "O(K) prediction"],
    );
    let mut base = None;
    for k in [16usize, 32, 64, 128] {
        let (dt, pruned) = encode_time(&data, 5, k);
        let b = *base.get_or_insert(dt / 16.0);
        t.add_row(vec![
            format!("{k}"),
            fmt_f(dt, 3),
            fmt_f(pruned, 1),
            fmt_f(b * k as f64, 3),
        ]);
    }
    println!("{}", t.render());
    println!("paper shape: runtime ~linear in K and ~1/M; LB pruning bends the");
    println!("K-curve sub-linear when the cascade is effective.");
}
