//! Table 1 (1NN columns): PQDTW vs ED / DTW / cDTW5 / cDTW10 / cDTWX /
//! SBD / SAX / PQ_ED on the UCR-like suite — mean 1-NN error difference
//! (measure − PQDTW; positive = PQDTW better) and median speedup, with
//! Friedman + Nemenyi significance markers, matching the paper's layout.
//!
//! Paper shape to reproduce: PQDTW ≈ ED (no significant difference),
//! slightly worse than DTW/cDTW/SBD (significant), much better than SAX
//! and PQ_ED (significant), while being the fastest raw-query method by
//! an order of magnitude on the elastic baselines.
//!
//! Run: `cargo bench --bench table1_1nn`

use std::time::Instant;

use pqdtw::data::ucr_like::{ucr_like_suite, TrainTest};
use pqdtw::distance::measure::Measure;
use pqdtw::eval::report::{fmt_mean_std, fmt_speedup, Table};
use pqdtw::eval::stats::{mean, pairwise_significance, std_dev, Significance};
use pqdtw::eval::search::{tune_pq, SearchSpace};
use pqdtw::nn::knn::{nn_classify_pq, nn_classify_raw, nn_classify_sax, PqQueryMode};
use pqdtw::pq::quantizer::{PqConfig, PqMetric, ProductQuantizer};

/// Pick the cDTW window minimizing leave-one-out 1-NN error on train
/// (the paper's cDTWX protocol).
fn best_window(tt: &TrainTest) -> f64 {
    let train = &tt.train;
    let n = train.n_series();
    let mut best = (f64::INFINITY, 0.05);
    for w in [0.02, 0.05, 0.1, 0.15, 0.2] {
        let measure = Measure::CDtw { window_frac: w };
        let mut errors = 0usize;
        for i in 0..n {
            let mut bd = f64::INFINITY;
            let mut bl = -1i64;
            for j in 0..n {
                if i == j {
                    continue;
                }
                let d = measure.dist(train.row(i), train.row(j));
                if d < bd {
                    bd = d;
                    bl = train.label(j);
                }
            }
            if bl != train.label(i) {
                errors += 1;
            }
        }
        let err = errors as f64 / n as f64;
        if err < best.0 {
            best = (err, w);
        }
    }
    best.1
}

struct MeasureResult {
    errors: Vec<f64>,
    times: Vec<f64>,
}

fn main() {
    let seed = 404u64;
    let suite = ucr_like_suite(seed);
    println!("Table 1 (1NN) — {} UCR-like datasets\n", suite.len());

    let names = ["ED", "DTW", "cDTW5", "cDTW10", "cDTWX", "SBD", "SAX", "PQ_ED", "PQDTW"];
    let mut results: Vec<MeasureResult> = names
        .iter()
        .map(|_| MeasureResult { errors: Vec::new(), times: Vec::new() })
        .collect();

    for tt in &suite {
        eprint!("  {} …", tt.name);
        let wx = best_window(tt);

        // raw measures
        let raw: Vec<(usize, Measure)> = vec![
            (0, Measure::Euclidean),
            (1, Measure::Dtw),
            (2, Measure::CDtw { window_frac: 0.05 }),
            (3, Measure::CDtw { window_frac: 0.10 }),
            (4, Measure::CDtw { window_frac: wx }),
            (5, Measure::Sbd),
        ];
        for (idx, measure) in raw {
            let t0 = Instant::now();
            let (err, _) = nn_classify_raw(&tt.train, &tt.test, measure);
            results[idx].errors.push(err);
            results[idx].times.push(t0.elapsed().as_secs_f64());
        }

        // SAX
        let t0 = Instant::now();
        let (err, _) = nn_classify_sax(&tt.train, &tt.test, 4, 0.2);
        results[6].errors.push(err);
        results[6].times.push(t0.elapsed().as_secs_f64());

        // PQ_ED (same M as the tuned PQDTW would use is unknowable here;
        // use the paper's fixed defaults)
        let cfg_ed = PqConfig {
            n_subspaces: 4,
            codebook_size: 64,
            metric: PqMetric::Euclidean,
            ..Default::default()
        };
        let pq_ed = ProductQuantizer::train(&tt.train, &cfg_ed, seed).unwrap();
        let enc_ed = pq_ed.encode_dataset(&tt.train);
        let t0 = Instant::now();
        let (err, _) = nn_classify_pq(&pq_ed, &enc_ed, &tt.test, PqQueryMode::Symmetric);
        results[7].errors.push(err);
        results[7].times.push(t0.elapsed().as_secs_f64());

        // PQDTW: tuned on train (small budget stand-in for the paper's TPE)
        let space = SearchSpace { codebook_size: 64, ..Default::default() };
        let tuned = tune_pq(&tt.train, &space, 6, 2, seed);
        let pq = ProductQuantizer::train(&tt.train, &tuned.config, seed).unwrap();
        let enc = pq.encode_dataset(&tt.train);
        let t0 = Instant::now();
        let (err, _) = nn_classify_pq(&pq, &enc, &tt.test, PqQueryMode::Symmetric);
        results[8].errors.push(err);
        results[8].times.push(t0.elapsed().as_secs_f64());
        eprintln!(" done (PQDTW err {err:.3})");
    }

    // scores matrix for significance: datasets × measures (lower better)
    let n_data = suite.len();
    let scores: Vec<Vec<f64>> = (0..n_data)
        .map(|d| results.iter().map(|r| r.errors[d]).collect())
        .collect();

    let pq_idx = 8;
    let mut table = Table::new(
        "Table 1 — 1NN vs PQDTW",
        &["measure", "mean err diff (meas − PQDTW)", "speedup", "signif"],
    );
    for (i, name) in names.iter().enumerate().take(8) {
        let diffs: Vec<f64> = (0..n_data)
            .map(|d| results[i].errors[d] - results[pq_idx].errors[d])
            .collect();
        let mut speedups: Vec<f64> = (0..n_data)
            .map(|d| results[i].times[d] / results[pq_idx].times[d])
            .collect();
        let sig = match pairwise_significance(&scores, i, pq_idx) {
            Significance::FirstBetter => "* (PQDTW worse)",
            Significance::SecondBetter => "† (PQDTW better)",
            Significance::None => "",
        };
        table.add_row(vec![
            name.to_string(),
            fmt_mean_std(mean(&diffs), std_dev(&diffs), 3),
            fmt_speedup(pqdtw::eval::report::median(&mut speedups)),
            sig.to_string(),
        ]);
    }
    println!("\n{}", table.render());

    let pq_mean = mean(&results[pq_idx].errors);
    println!("PQDTW mean error over suite: {pq_mean:.3}");
    println!("(speedup = median over datasets of time(measure)/time(PQDTW),");
    println!(" classification time only; PQ train+encode is offline, §3.2)");
}
