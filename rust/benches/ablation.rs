//! Ablations for the design choices DESIGN.md calls out:
//!
//! 1. **Keogh patch** (§4.2): clustering RI with plain symmetric
//!    distances (zero on code collisions) vs the Keogh-patched variant.
//! 2. **Pre-alignment** (§3.5): 1-NN error with fixed segmentation vs
//!    MODWT-elastic segmentation on phase-heavy datasets.
//! 3. **LB cascade** (§3.2): encode cost with the cascade disabled
//!    (brute-force DTW over all K) vs enabled.
//!
//! Run: `cargo bench --bench ablation`

use std::time::Instant;

use pqdtw::cluster::{agglomerative, compact_labels, rand_index, Linkage};
use pqdtw::core::matrix::CondensedMatrix;
use pqdtw::data::ucr_like::ucr_like_by_name;
use pqdtw::eval::report::{fmt_f, Table};
use pqdtw::nn::knn::{nn_classify_pq, PqQueryMode};
use pqdtw::pq::distance::symmetric_sq;
use pqdtw::pq::encode::encode_subspace_bruteforce;
use pqdtw::pq::quantizer::{PqConfig, PrealignConfig, ProductQuantizer};

fn main() {
    let seed = 808u64;

    // --- 1. Keogh patch in clustering ---
    let mut t = Table::new(
        "ablation 1: symmetric-distance collision patch (clustering RI)",
        &["dataset", "plain RI", "patched RI", "zero-dist pairs"],
    );
    for name in ["Seasonal", "CBF", "DampedOsc", "SpikePosition"] {
        let tt = ucr_like_by_name(name, seed).unwrap();
        let cfg = PqConfig {
            n_subspaces: 4,
            // small codebook => frequent code collisions => the patch matters
            codebook_size: 8,
            window_frac: 0.1,
            ..Default::default()
        };
        let pq = ProductQuantizer::train(&tt.train, &cfg, seed).unwrap();
        let enc = pq.encode_dataset(&tt.test);
        let n = tt.test.n_series();
        let k = tt.test.classes().len();
        let truth = compact_labels(&tt.test.labels);
        let mut zero_pairs = 0usize;
        let plain = CondensedMatrix::build(n, |i, j| {
            let d = symmetric_sq(&pq.codebook, enc.code(i), enc.code(j)).sqrt();
            if d == 0.0 {
                zero_pairs += 1;
            }
            d
        });
        let patched = CondensedMatrix::build(n, |i, j| pq.patched_distance(&enc, i, j));
        let ri_plain = rand_index(&agglomerative(&plain, Linkage::Complete).cut(k), &truth);
        let ri_patch = rand_index(&agglomerative(&patched, Linkage::Complete).cut(k), &truth);
        t.add_row(vec![
            name.to_string(),
            fmt_f(ri_plain, 4),
            fmt_f(ri_patch, 4),
            format!("{zero_pairs}"),
        ]);
    }
    println!("{}", t.render());

    // --- 2. pre-alignment on phase-heavy data ---
    let mut t = Table::new(
        "ablation 2: MODWT pre-alignment (1-NN error, asymmetric)",
        &["dataset", "fixed splits", "pre-aligned"],
    );
    for name in ["SpikePosition", "StepPosition", "BumpCount", "GunPointLike"] {
        let tt = ucr_like_by_name(name, seed).unwrap();
        let base = PqConfig {
            n_subspaces: 4,
            codebook_size: 32,
            window_frac: 0.1,
            ..Default::default()
        };
        let pre = PqConfig {
            prealign: Some(PrealignConfig { level: 2, tail_frac: 0.2 }),
            ..base
        };
        let mut errs = Vec::new();
        for cfg in [base, pre] {
            let pq = ProductQuantizer::train(&tt.train, &cfg, seed).unwrap();
            let enc = pq.encode_dataset(&tt.train);
            let (err, _) = nn_classify_pq(&pq, &enc, &tt.test, PqQueryMode::Asymmetric);
            errs.push(err);
        }
        t.add_row(vec![name.to_string(), fmt_f(errs[0], 4), fmt_f(errs[1], 4)]);
    }
    println!("{}", t.render());

    // --- 3. LB cascade vs brute force encoding ---
    let tt = ucr_like_by_name("TraceLike", seed).unwrap();
    let cfg = PqConfig {
        n_subspaces: 4,
        codebook_size: 40,
        window_frac: 0.1,
        ..Default::default()
    };
    let pq = ProductQuantizer::train(&tt.train, &cfg, seed).unwrap();
    let data = &tt.test;
    let t0 = Instant::now();
    let enc = pq.encode_dataset(data);
    let t_cascade = t0.elapsed().as_secs_f64();
    // brute force: same codes, no bounds
    let t0 = Instant::now();
    let mut brute_codes: Vec<u16> = Vec::new();
    for i in 0..data.n_series() {
        for (m, s) in pq.segment(data.row(i)).iter().enumerate() {
            brute_codes.push(encode_subspace_bruteforce(s, m, &pq.codebook).0);
        }
    }
    let t_brute = t0.elapsed().as_secs_f64();
    // distances must agree even when tie-broken differently
    let mut mismatch = 0usize;
    for (a, b) in enc.codes.iter().zip(brute_codes.iter()) {
        if a != b {
            mismatch += 1;
        }
    }
    let st = enc.stats;
    println!("ablation 3: LB cascade in the encoder ({} series, K=40)", data.n_series());
    println!("  cascade : {:.4} s ({:.0}% pruned)", t_cascade,
        100.0 * (st.pruned_kim + st.pruned_keogh) as f64 / st.candidates() as f64);
    println!("  brute   : {:.4} s", t_brute);
    println!("  speedup : x{:.2}", t_brute / t_cascade);
    println!("  code disagreements (ties): {mismatch}/{}", enc.codes.len());
}
