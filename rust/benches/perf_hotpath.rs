//! §Perf microbenchmarks — the hot paths the optimization pass iterates
//! on: the DTW DP inner loop, the LB-cascade encoder, the O(M) symmetric
//! distance, the asymmetric table, the top-k serving scans (exhaustive /
//! sharded / IVF-probed / DTW re-ranked), and the coordinator overhead.
//!
//! Prints ns/op style medians; EXPERIMENTS.md §Perf records before/after.
//!
//! Run: `cargo bench --bench perf_hotpath`

use std::sync::Arc;
use std::time::Instant;

use pqdtw::coordinator::{Engine, Request, Service, ServiceConfig};
use pqdtw::core::rng::Rng;
use pqdtw::data::random_walk::RandomWalks;
use pqdtw::distance::dtw::{dtw_sq_scratch, DtwScratch};
use pqdtw::distance::euclidean::euclidean_sq;
use pqdtw::distance::pruned_dtw::pruned_dtw_sq;
use pqdtw::eval::report::median;
use pqdtw::nn::ivf::{CoarseMetric, IvfIndex};
use pqdtw::nn::knn::PqQueryMode;
use pqdtw::nn::topk::{
    rerank_dtw, topk_scan_blocked_opts, topk_scan_scalar, topk_scan_with, QueryLut,
};
use pqdtw::pq::distance::{asymmetric_sq, asymmetric_table, symmetric_sq};
use pqdtw::pq::quantizer::{PqConfig, ProductQuantizer};

/// Median wall time of `f` over `reps` runs, in seconds.
fn bench<F: FnMut()>(reps: usize, mut f: F) -> f64 {
    // warmup
    f();
    let mut times: Vec<f64> = (0..reps)
        .map(|_| {
            let t0 = Instant::now();
            f();
            t0.elapsed().as_secs_f64()
        })
        .collect();
    median(&mut times)
}

fn main() {
    let mut rng = Rng::new(777);
    println!("§Perf hot-path microbenchmarks (medians)\n");

    // --- DTW DP kernel ---
    for (len, w) in [(128usize, None), (128, Some(13)), (512, Some(51)), (1024, Some(102))] {
        let a: Vec<f64> = (0..len).map(|_| rng.normal()).collect();
        let b: Vec<f64> = (0..len).map(|_| rng.normal()).collect();
        let mut scratch = DtwScratch::new(len);
        let t = bench(51, || {
            std::hint::black_box(dtw_sq_scratch(
                std::hint::black_box(&a),
                std::hint::black_box(&b),
                w,
                f64::INFINITY,
                &mut scratch,
            ));
        });
        let cells = match w {
            Some(w) => len * (2 * w + 1),
            None => len * len,
        };
        println!(
            "dtw_sq len={len:5} w={w:?}: {:9.1} µs  ({:.2} ns/cell)",
            t * 1e6,
            t * 1e9 / cells as f64
        );
    }

    // --- PrunedDTW with tight bound ---
    {
        let len = 512;
        let a: Vec<f64> = (0..len).map(|_| rng.normal()).collect();
        let b: Vec<f64> = a.iter().map(|v| v + 0.05 * rng.normal()).collect();
        let ub = euclidean_sq(&a, &b);
        let t = bench(51, || {
            std::hint::black_box(pruned_dtw_sq(&a, &b, None, std::hint::black_box(ub)));
        });
        println!("pruned_dtw len={len} (tight ub): {:9.1} µs", t * 1e6);
    }

    // --- encode (LB cascade + early-abandon DTW) ---
    let data = RandomWalks::new(31).generate(128, 512);
    let cfg = PqConfig {
        n_subspaces: 4,
        codebook_size: 64,
        window_frac: 0.1,
        kmeans_iters: 2,
        dba_iters: 1,
        train_subsample: Some(64),
        ..Default::default()
    };
    let pq = ProductQuantizer::train(&data, &cfg, 3).unwrap();
    {
        let x = data.row(0);
        let t = bench(31, || {
            std::hint::black_box(pq.encode(std::hint::black_box(x)));
        });
        println!("encode series len=512 (M=4 K=64): {:9.1} µs", t * 1e6);
    }

    // --- symmetric + asymmetric distances ---
    let enc = pq.encode_dataset(&data);
    {
        let cx = enc.code(0).to_vec();
        let cy = enc.code(1).to_vec();
        let t = bench(101, || {
            for _ in 0..1000 {
                std::hint::black_box(symmetric_sq(
                    &pq.codebook,
                    std::hint::black_box(&cx),
                    std::hint::black_box(&cy),
                ));
            }
        });
        println!("symmetric_sq (M=4):        {:9.2} ns/op", t * 1e9 / 1000.0);
    }
    {
        let table = asymmetric_table(&pq.codebook, &pq.segment(data.row(0)));
        let cy = enc.code(1).to_vec();
        let t = bench(101, || {
            for _ in 0..1000 {
                std::hint::black_box(asymmetric_sq(&pq.codebook, &table, &cy));
            }
        });
        println!("asymmetric_sq (M=4):       {:9.2} ns/op", t * 1e9 / 1000.0);
        let t = bench(11, || {
            std::hint::black_box(asymmetric_table(&pq.codebook, &pq.segment(data.row(2))));
        });
        println!("asymmetric_table (M=4 K=64): {:7.1} µs/query", t * 1e6);
    }

    // --- full pairwise matrix (the clustering hot loop) ---
    {
        let n = data.n_series();
        let t = bench(11, || {
            std::hint::black_box(pqdtw::core::matrix::CondensedMatrix::build(n, |i, j| {
                pq.patched_distance(&enc, i, j)
            }));
        });
        println!(
            "pairwise matrix n={n} (patched): {:7.1} µs ({:.1} ns/pair)",
            t * 1e6,
            t * 1e9 / (n * (n - 1) / 2) as f64
        );
    }

    // --- top-k serving scans on a large database ---
    //
    // The acceptance-relevant comparison: an IVF probe must beat the
    // exhaustive LUT scan wall-clock on a multi-thousand-series database.
    // The coarse quantizer is Euclidean here so the probe itself is
    // O(nlist·D) — the classic IVF configuration for cheap cell
    // selection; at nprobe = nlist the probed result is bit-identical to
    // the exhaustive scan (asserted below).
    {
        let (n, len, k) = (16_384usize, 64usize, 10usize);
        println!("\ntop-k serving scans (N={n}, len={len}, k={k}):");
        let db = RandomWalks::new(97).generate(n, len);
        let cfg = PqConfig {
            n_subspaces: 4,
            codebook_size: 32,
            window_frac: 0.1,
            kmeans_iters: 2,
            dba_iters: 1,
            train_subsample: Some(64),
            ..Default::default()
        };
        let t0 = Instant::now();
        let pq = ProductQuantizer::train(&db, &cfg, 5).unwrap();
        let enc = pq.encode_dataset(&db);
        println!("  (one-time train+encode: {:?})", t0.elapsed());
        let t0 = Instant::now();
        let blocks = enc.to_blocks(pq.codebook.k);
        println!("  (one-time code-block transpose: {:?})", t0.elapsed());
        let t0 = Instant::now();
        let mut ivf = IvfIndex::build(&db, 64, CoarseMetric::Euclidean, 7);
        ivf.attach_blocks(&enc, pq.codebook.k);
        println!("  (one-time IVF build, nlist=64 ED-coarse: {:?})", t0.elapsed());

        let q = RandomWalks::new(4242).generate(1, len);
        let q = q.row(0);
        let lut = QueryLut::build(&pq, q, PqQueryMode::Asymmetric);
        let clut = lut.collapse(&pq.codebook);

        let nprobe = 4;
        // correctness guards before timing: every variant bit-identical
        let want = topk_scan_scalar(&pq, &enc, &lut, k);
        assert_eq!(
            want,
            topk_scan_blocked_opts(&blocks, &clut, k, 1, false),
            "blocked scan must be bit-identical to the scalar scan"
        );
        assert_eq!(
            want,
            topk_scan_blocked_opts(&blocks, &clut, k, 1, true),
            "pruned scan must be bit-identical to the scalar scan"
        );
        assert_eq!(
            want,
            ivf.query_topk_with(&pq, &enc, &lut, q, k, ivf.nlist()),
            "full probe must be bit-identical to the exhaustive scan"
        );

        // the scan-kernel ladder: scalar -> blocked -> blocked+pruned
        let t_scalar = bench(31, || {
            std::hint::black_box(topk_scan_scalar(&pq, &enc, &lut, k));
        });
        let t_blocked = bench(31, || {
            std::hint::black_box(topk_scan_blocked_opts(&blocks, &clut, k, 1, false));
        });
        let t_exh = bench(31, || {
            std::hint::black_box(topk_scan_blocked_opts(&blocks, &clut, k, 1, true));
        });
        let t_exh4 = bench(31, || {
            std::hint::black_box(topk_scan_blocked_opts(&blocks, &clut, k, 4, true));
        });
        let t_probe = bench(31, || {
            std::hint::black_box(ivf.query_topk_with(&pq, &enc, &lut, q, k, nprobe));
        });
        let frac = ivf.scan_fraction(q, nprobe);
        println!(
            "  scalar scan (full LUT)    : {:9.1} µs",
            t_scalar * 1e6
        );
        println!(
            "  blocked scan, no pruning  : {:9.1} µs (x{:.2} vs scalar)",
            t_blocked * 1e6,
            t_scalar / t_blocked
        );
        println!(
            "  blocked+pruned, 1 thread  : {:9.1} µs (x{:.2} vs scalar)",
            t_exh * 1e6,
            t_scalar / t_exh
        );
        println!(
            "  blocked+pruned, 4 threads : {:9.1} µs (x{:.2} vs 1 thread)",
            t_exh4 * 1e6,
            t_exh / t_exh4
        );
        if t_exh >= t_scalar {
            println!("  WARNING: blocked+pruned scan did not beat the scalar scan");
        }
        println!(
            "  IVF probe nprobe={nprobe}/{}   : {:9.1} µs (x{:.2} vs exhaustive, scans {:.1}% of db)",
            ivf.nlist(),
            t_probe * 1e6,
            t_exh / t_probe,
            100.0 * frac
        );
        if t_probe >= t_exh {
            println!("  WARNING: probed scan did not beat the exhaustive scan");
        }
        // end-to-end latency including the per-query table build +
        // collapse (the engine's actual serving path over cached blocks)
        let t_exh_total = bench(31, || {
            let lut = QueryLut::build(&pq, q, PqQueryMode::Asymmetric);
            let clut = lut.collapse(&pq.codebook);
            std::hint::black_box(topk_scan_blocked_opts(&blocks, &clut, k, 1, true));
        });
        let t_probe_total = bench(31, || {
            let lut = QueryLut::build(&pq, q, PqQueryMode::Asymmetric);
            std::hint::black_box(ivf.query_topk_with(&pq, &enc, &lut, q, k, nprobe));
        });
        println!(
            "  incl. table build         : exhaustive {:9.1} µs | probed {:9.1} µs (x{:.2})",
            t_exh_total * 1e6,
            t_probe_total * 1e6,
            t_exh_total / t_probe_total
        );
        // DTW re-rank of a 4k candidate pool
        let cands = topk_scan_with(&pq, &enc, &lut, 4 * k, 1);
        let t_rerank = bench(31, || {
            std::hint::black_box(rerank_dtw(&db, q, &cands, k, Some(6)));
        });
        println!(
            "  DTW re-rank depth {:3}     : {:9.1} µs (exact distances)",
            4 * k,
            t_rerank * 1e6
        );
    }

    // --- coordinator overhead: request round-trip minus compute ---
    {
        let tt = pqdtw::data::ucr_like::ucr_like_by_name("SpikePosition", 7).unwrap();
        let cfg = PqConfig { n_subspaces: 4, codebook_size: 16, window_frac: 0.2, ..Default::default() };
        let engine = Arc::new(Engine::build(&tt.train, &cfg, 1).unwrap());
        // direct engine call
        let req = Request::NnQuery {
            series: tt.test.row(0).to_vec(),
            mode: PqQueryMode::Symmetric,
            nprobe: None,
        };
        let t_direct = bench(31, || {
            std::hint::black_box(engine.handle(std::hint::black_box(&req)));
        });
        // through the service (batcher + channel + thread hop)
        let svc = Service::start(Arc::clone(&engine), ServiceConfig::default());
        let t_svc = bench(31, || {
            std::hint::black_box(svc.call(Request::NnQuery {
                series: tt.test.row(0).to_vec(),
                mode: PqQueryMode::Symmetric,
                nprobe: None,
            }));
        });
        svc.shutdown();
        println!(
            "\nengine direct: {:7.1} µs | via service: {:7.1} µs (overhead {:+.1} µs)",
            t_direct * 1e6,
            t_svc * 1e6,
            (t_svc - t_direct) * 1e6
        );
    }
}
