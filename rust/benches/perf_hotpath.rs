//! §Perf microbenchmarks — the hot paths the optimization pass iterates
//! on: the DTW DP inner loop, the LB-cascade encoder, the O(M) symmetric
//! distance, the asymmetric table, and the coordinator overhead.
//!
//! Prints ns/op style medians; EXPERIMENTS.md §Perf records before/after.
//!
//! Run: `cargo bench --bench perf_hotpath`

use std::sync::Arc;
use std::time::Instant;

use pqdtw::coordinator::{Engine, Request, Service, ServiceConfig};
use pqdtw::core::rng::Rng;
use pqdtw::data::random_walk::RandomWalks;
use pqdtw::distance::dtw::{dtw_sq_scratch, DtwScratch};
use pqdtw::distance::euclidean::euclidean_sq;
use pqdtw::distance::pruned_dtw::pruned_dtw_sq;
use pqdtw::eval::report::median;
use pqdtw::nn::knn::PqQueryMode;
use pqdtw::pq::distance::{asymmetric_sq, asymmetric_table, symmetric_sq};
use pqdtw::pq::quantizer::{PqConfig, ProductQuantizer};

/// Median wall time of `f` over `reps` runs, in seconds.
fn bench<F: FnMut()>(reps: usize, mut f: F) -> f64 {
    // warmup
    f();
    let mut times: Vec<f64> = (0..reps)
        .map(|_| {
            let t0 = Instant::now();
            f();
            t0.elapsed().as_secs_f64()
        })
        .collect();
    median(&mut times)
}

fn main() {
    let mut rng = Rng::new(777);
    println!("§Perf hot-path microbenchmarks (medians)\n");

    // --- DTW DP kernel ---
    for (len, w) in [(128usize, None), (128, Some(13)), (512, Some(51)), (1024, Some(102))] {
        let a: Vec<f64> = (0..len).map(|_| rng.normal()).collect();
        let b: Vec<f64> = (0..len).map(|_| rng.normal()).collect();
        let mut scratch = DtwScratch::new(len);
        let t = bench(51, || {
            std::hint::black_box(dtw_sq_scratch(
                std::hint::black_box(&a),
                std::hint::black_box(&b),
                w,
                f64::INFINITY,
                &mut scratch,
            ));
        });
        let cells = match w {
            Some(w) => len * (2 * w + 1),
            None => len * len,
        };
        println!(
            "dtw_sq len={len:5} w={w:?}: {:9.1} µs  ({:.2} ns/cell)",
            t * 1e6,
            t * 1e9 / cells as f64
        );
    }

    // --- PrunedDTW with tight bound ---
    {
        let len = 512;
        let a: Vec<f64> = (0..len).map(|_| rng.normal()).collect();
        let b: Vec<f64> = a.iter().map(|v| v + 0.05 * rng.normal()).collect();
        let ub = euclidean_sq(&a, &b);
        let t = bench(51, || {
            std::hint::black_box(pruned_dtw_sq(&a, &b, None, std::hint::black_box(ub)));
        });
        println!("pruned_dtw len={len} (tight ub): {:9.1} µs", t * 1e6);
    }

    // --- encode (LB cascade + early-abandon DTW) ---
    let data = RandomWalks::new(31).generate(128, 512);
    let cfg = PqConfig {
        n_subspaces: 4,
        codebook_size: 64,
        window_frac: 0.1,
        kmeans_iters: 2,
        dba_iters: 1,
        train_subsample: Some(64),
        ..Default::default()
    };
    let pq = ProductQuantizer::train(&data, &cfg, 3).unwrap();
    {
        let x = data.row(0);
        let t = bench(31, || {
            std::hint::black_box(pq.encode(std::hint::black_box(x)));
        });
        println!("encode series len=512 (M=4 K=64): {:9.1} µs", t * 1e6);
    }

    // --- symmetric + asymmetric distances ---
    let enc = pq.encode_dataset(&data);
    {
        let cx = enc.code(0).to_vec();
        let cy = enc.code(1).to_vec();
        let t = bench(101, || {
            for _ in 0..1000 {
                std::hint::black_box(symmetric_sq(
                    &pq.codebook,
                    std::hint::black_box(&cx),
                    std::hint::black_box(&cy),
                ));
            }
        });
        println!("symmetric_sq (M=4):        {:9.2} ns/op", t * 1e9 / 1000.0);
    }
    {
        let table = asymmetric_table(&pq.codebook, &pq.segment(data.row(0)));
        let cy = enc.code(1).to_vec();
        let t = bench(101, || {
            for _ in 0..1000 {
                std::hint::black_box(asymmetric_sq(&pq.codebook, &table, &cy));
            }
        });
        println!("asymmetric_sq (M=4):       {:9.2} ns/op", t * 1e9 / 1000.0);
        let t = bench(11, || {
            std::hint::black_box(asymmetric_table(&pq.codebook, &pq.segment(data.row(2))));
        });
        println!("asymmetric_table (M=4 K=64): {:7.1} µs/query", t * 1e6);
    }

    // --- full pairwise matrix (the clustering hot loop) ---
    {
        let n = data.n_series();
        let t = bench(11, || {
            std::hint::black_box(pqdtw::core::matrix::CondensedMatrix::build(n, |i, j| {
                pq.patched_distance(&enc, i, j)
            }));
        });
        println!(
            "pairwise matrix n={n} (patched): {:7.1} µs ({:.1} ns/pair)",
            t * 1e6,
            t * 1e9 / (n * (n - 1) / 2) as f64
        );
    }

    // --- coordinator overhead: request round-trip minus compute ---
    {
        let tt = pqdtw::data::ucr_like::ucr_like_by_name("SpikePosition", 7).unwrap();
        let cfg = PqConfig { n_subspaces: 4, codebook_size: 16, window_frac: 0.2, ..Default::default() };
        let engine = Arc::new(Engine::build(&tt.train, &cfg, 1).unwrap());
        // direct engine call
        let req = Request::NnQuery { series: tt.test.row(0).to_vec(), mode: PqQueryMode::Symmetric };
        let t_direct = bench(31, || {
            std::hint::black_box(engine.handle(std::hint::black_box(&req)));
        });
        // through the service (batcher + channel + thread hop)
        let svc = Service::start(Arc::clone(&engine), ServiceConfig::default());
        let t_svc = bench(31, || {
            std::hint::black_box(svc.call(Request::NnQuery {
                series: tt.test.row(0).to_vec(),
                mode: PqQueryMode::Symmetric,
            }));
        });
        svc.shutdown();
        println!(
            "engine direct: {:7.1} µs | via service: {:7.1} µs (overhead {:+.1} µs)",
            t_direct * 1e6,
            t_svc * 1e6,
            (t_svc - t_direct) * 1e6
        );
    }
}
