//! Figure 5c: effect of the MODWT pre-alignment step on PQDTW runtime.
//! Paper finding: minor overall effect, mainly driven by the wavelet
//! decomposition level; tail length has no significant effect.
//!
//! Run: `cargo bench --bench fig5c_prealign`

use std::time::Instant;

use pqdtw::data::random_walk::RandomWalks;
use pqdtw::eval::report::{fmt_f, Table};
use pqdtw::pq::quantizer::{PqConfig, PrealignConfig, ProductQuantizer};

fn run(data: &pqdtw::core::series::Dataset, prealign: Option<PrealignConfig>) -> f64 {
    let cfg = PqConfig {
        n_subspaces: 5,
        codebook_size: 32,
        window_frac: 0.1,
        prealign,
        kmeans_iters: 2,
        dba_iters: 1,
        train_subsample: Some(32),
        ..Default::default()
    };
    let pq = ProductQuantizer::train(data, &cfg, 1).unwrap();
    // median of 3 encode passes
    let mut times: Vec<f64> = (0..3)
        .map(|_| {
            let t0 = Instant::now();
            let _ = pq.encode_dataset(data);
            t0.elapsed().as_secs_f64()
        })
        .collect();
    pqdtw::eval::report::median(&mut times)
}

fn main() {
    println!("Figure 5c — pre-alignment effect on encode runtime\n");
    let data = RandomWalks::new(21).generate(100, 640);

    let baseline = run(&data, None);
    println!("baseline (no pre-alignment): {baseline:.3} s\n");

    let mut t = Table::new(
        "encode time vs wavelet level (tail=15%)",
        &["level", "encode (s)", "overhead vs baseline"],
    );
    for level in [1usize, 2, 3, 4, 5] {
        let dt = run(&data, Some(PrealignConfig { level, tail_frac: 0.15 }));
        t.add_row(vec![
            format!("{level}"),
            fmt_f(dt, 3),
            format!("{:+.1}%", 100.0 * (dt - baseline) / baseline),
        ]);
    }
    println!("{}", t.render());

    let mut t = Table::new(
        "encode time vs tail length (level=2)",
        &["tail", "encode (s)", "overhead vs baseline"],
    );
    for tail in [0.05f64, 0.1, 0.15, 0.2, 0.3] {
        let dt = run(&data, Some(PrealignConfig { level: 2, tail_frac: tail }));
        t.add_row(vec![
            format!("{:.0}%", tail * 100.0),
            fmt_f(dt, 3),
            format!("{:+.1}%", 100.0 * (dt - baseline) / baseline),
        ]);
    }
    println!("{}", t.render());
    println!("paper shape: pre-alignment cost is minor; the MODWT level is the");
    println!("main driver (O(J·D) smoothing); tail has no significant effect");
    println!("(note: tail lengthens subspaces to l+t, so some DP cost is inherent).");
}
