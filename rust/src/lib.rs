//! # PQDTW — Elastic Product Quantization for Time Series
//!
//! A production-grade reproduction of *"Elastic Product Quantization for
//! Time Series"* (Robberechts, Meert & Davis, 2022) as a three-layer
//! Rust + JAX + Pallas system.
//!
//! The paper generalizes product quantization (PQ) from the Euclidean
//! metric to Dynamic Time Warping: time series are partitioned into `M`
//! subspaces, each subspace is vector-quantized against a DBA-k-means
//! codebook under DTW, and distances between series are then approximated
//! in `O(M)` table lookups (symmetric) or `O(K·(D/M)²)` once per query
//! (asymmetric). A MODWT-based pre-alignment step moves subspace
//! boundaries onto local structure so the segmentation does not cut
//! through warped features.
//!
//! ## Crate layout
//!
//! - [`core`] — time-series containers, preprocessing, PRNG, condensed
//!   distance matrices.
//! - [`distance`] — the elastic-measure substrate: DTW (full / banded /
//!   early-abandoned / pruned), Euclidean, SBD (+ FFT), Keogh envelopes
//!   and the lower-bound cascade.
//! - [`repr`] — baseline symbolic/segment representations (PAA, SAX).
//! - [`wavelet`] — Haar MODWT and structure-aware segmentation.
//! - [`pq`] — the paper's contribution: codebook learning (DBA k-means),
//!   LB-cascade encoding, symmetric/asymmetric distances (single and
//!   batch-scan forms), pre-alignment.
//! - [`nn`] — 1-NN classification over any measure with LB pruning, plus
//!   the serving-scale search stack: bounded-heap top-k collection with a
//!   deterministic `(distance, index)` order, sharded multi-threaded
//!   scans, an IVF inverted-file index with `nprobe` cell probing, and an
//!   exact DTW re-rank stage over the raw database.
//! - [`store`] — the versioned on-disk index format (magic / version /
//!   length-prefixed sections / checksum, explicit little-endian over
//!   `std` only): `save`/`load` of the full serving state — quantizer,
//!   codes, raw database, IVF lists — so serving processes open a
//!   prebuilt index in milliseconds instead of retraining, and answer
//!   queries bit-identically to the engine that was saved.
//! - [`cluster`] — agglomerative hierarchical clustering + Rand/ARI.
//! - [`data`] — synthetic workloads (random walks, a UCR-like suite) and
//!   a UCR `.tsv` loader.
//! - [`eval`] — cross-validation, hyper-parameter search, Friedman /
//!   Nemenyi statistics, report formatting.
//! - [`coordinator`] — the serving layer: engine state, dynamic batcher,
//!   threaded worker service, per-mode metrics. Top-k requests dial
//!   recall against latency: exhaustive scans are exact w.r.t. the PQ
//!   approximation, IVF probing with `nprobe < nlist` scans a fraction
//!   of the database (and `nprobe = nlist` is bit-identical to the
//!   exhaustive scan), and the re-rank stage returns true windowed DTW
//!   distances.
//! - [`net`] — the network serving plane: a versioned length-prefixed
//!   binary wire protocol (`docs/wire-protocol.md`), a std-only TCP
//!   server feeding concurrent connections into the coordinator's
//!   batcher, and a blocking client — remote queries answer
//!   bit-identically to the in-process engine.
//! - [`router`] — fault-tolerant sharded serving: a scatter-gather
//!   router speaking the same wire protocol fans queries out to shard
//!   servers (each holding a `id % n` slice of the database, built with
//!   `build-index --shard i/n`) and merges through the deterministic
//!   `(distance, index)` order, so a full-health routed answer is
//!   bit-identical to an unsharded scan. Per-shard supervision —
//!   deadlines, one retry on a fresh connection, a
//!   `Healthy → Degraded → Down` breaker with jittered-backoff
//!   half-open recovery — turns shard failures into flagged partial
//!   results (wire v4 `degraded` trailer) instead of outages
//!   (`docs/serving-topology.md`).
//! - [`jobs`] — the durable async job plane: a bounded worker pool
//!   running long scans (all-pairs top-k, k-medoids sweeps, `nprobe`
//!   autotuning) in cancellable chunks with cursor-polled progress
//!   events, persisted job state/results (store jobs section), and
//!   `pqdtw_jobs_*` Prometheus families.
//! - [`obs`] — the observability layer (`docs/observability.md`):
//!   lock-free prune-cascade counters flushed by the scan kernel,
//!   per-query stage-ladder traces with per-hit "why ranked"
//!   explainability, JSON-lines event logging for the serving plane,
//!   and Prometheus text exposition rendering. Tracing is opt-in per
//!   request and bit-transparent: traced queries return byte-identical
//!   results.
//! - [`runtime`] — (feature `pjrt`) loads AOT-lowered HLO artifacts
//!   produced by `python/compile/aot.py` and executes them via PJRT.
//!
//! ## Quickstart
//!
//! ```
//! use pqdtw::data::random_walk::RandomWalks;
//! use pqdtw::pq::quantizer::{PqConfig, ProductQuantizer};
//!
//! let train = RandomWalks::new(7).generate(64, 128); // 64 walks, length 128
//! let cfg = PqConfig { n_subspaces: 4, codebook_size: 16, ..Default::default() };
//! let pq = ProductQuantizer::train(&train, &cfg, 7).unwrap();
//! let codes = pq.encode_dataset(&train);
//! let d = pq.symmetric_distance(codes.code(0), codes.code(1));
//! assert!(d >= 0.0);
//!
//! // Top-3 neighbours of a query, exhaustive scan (see `nn::topk` and
//! // `coordinator` for IVF probing and DTW re-ranking behind a service).
//! use pqdtw::nn::{topk_scan, PqQueryMode};
//! let hits = topk_scan(&pq, &codes, train.row(0), 3, PqQueryMode::Asymmetric, 1);
//! assert_eq!(hits.len(), 3);
//! assert!(hits[0].distance <= hits[2].distance); // ascending
//! ```
//!
//! ## Machine-checked invariants
//!
//! The serving-plane guarantees above (no panics on hostile bytes,
//! deterministic `(distance, index)` order, checked narrowing in the
//! codecs) are enforced statically by `cargo lint` (the `xtask`
//! workspace member) — see `docs/INVARIANTS.md`.

#![forbid(unsafe_code)]

pub mod cli;
pub mod core;
pub mod distance;
pub mod repr;
pub mod wavelet;
pub mod pq;
pub mod nn;
pub mod cluster;
pub mod data;
pub mod eval;
pub mod store;
pub mod coordinator;
pub mod jobs;
pub mod net;
pub mod obs;
pub mod router;
pub mod runtime;
pub mod testutil;
