//! Router-plane counters and their `pqdtw_router_*` Prometheus
//! families (rendered by [`super::Router::prometheus_text`], verbs
//! documented in `docs/observability.md`).
//!
//! All counters are relaxed atomics: each is monotone and independent,
//! so no cross-field ordering is needed — same discipline as
//! [`crate::obs::ScanStats`].

use std::sync::atomic::{AtomicU64, Ordering};

use crate::obs::prometheus::PromText;

use super::health::ShardHealth;

/// One monotone counter.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    /// Add one.
    pub fn incr(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// The router's counter set. Fields are public so the scatter path
/// can bump them without a method per counter.
#[derive(Debug, Default)]
pub struct RouterMetrics {
    /// Client requests the router answered (any frame kind).
    pub requests: Counter,
    /// Requests answered with an `Error` frame.
    pub errors: Counter,
    /// Responses flagged `degraded` (at least one shard missing).
    pub degraded_responses: Counter,
    /// Scatter legs that failed at the transport level (both the
    /// first attempt and a failed retry count).
    pub shard_failures: Counter,
    /// Retries after a hard transport failure (refused, reset, torn
    /// frame).
    pub retries: Counter,
    /// Retries after a read timeout — the shard may only be slow, so
    /// the fresh-connection retry races the stalled one.
    pub hedges: Counter,
    /// Scatter legs skipped because the shard's breaker was open.
    pub shard_skips: Counter,
    /// Background health probes sent.
    pub probes: Counter,
    /// Background health probes that failed.
    pub probe_failures: Counter,
    /// Routed queries whose end-to-end wall time crossed the
    /// `--slow-query-ms` threshold (0 while no threshold is set).
    /// Rendered as `pqdtw_slow_queries_total` — deliberately the same
    /// family name as the single-node server's, so one dashboard query
    /// covers both planes.
    pub slow_queries: Counter,
}

impl RouterMetrics {
    /// Fresh zeroed counter set.
    pub fn new() -> Self {
        RouterMetrics::default()
    }

    /// Render the `pqdtw_router_*` families; `shards` supplies the
    /// per-shard health gauge rows as `(index, addr, health)`.
    pub fn render_prometheus(&self, p: &mut PromText, shards: &[(u64, String, ShardHealth)]) {
        p.counter("pqdtw_router_requests_total", self.requests.get());
        p.counter("pqdtw_router_errors_total", self.errors.get());
        p.counter("pqdtw_router_degraded_responses_total", self.degraded_responses.get());
        p.counter("pqdtw_router_shard_failures_total", self.shard_failures.get());
        p.counter("pqdtw_router_retries_total", self.retries.get());
        p.counter("pqdtw_router_hedges_total", self.hedges.get());
        p.counter("pqdtw_router_shard_skips_total", self.shard_skips.get());
        p.counter("pqdtw_router_probes_total", self.probes.get());
        p.counter("pqdtw_router_probe_failures_total", self.probe_failures.get());
        p.counter("pqdtw_slow_queries_total", self.slow_queries.get());
        p.gauge("pqdtw_router_shards", shards.len() as f64);
        p.family("pqdtw_router_shard_health", "gauge");
        for (index, addr, health) in shards {
            let shard = index.to_string();
            p.sample(
                "pqdtw_router_shard_health",
                &[("shard", shard.as_str()), ("addr", addr.as_str())],
                health.as_gauge(),
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::prometheus::validate_exposition;

    #[test]
    fn exposition_is_well_formed_and_carries_every_family() {
        let m = RouterMetrics::new();
        m.requests.incr();
        m.requests.incr();
        m.hedges.incr();
        m.degraded_responses.incr();
        m.slow_queries.incr();
        let shards = vec![
            (0u64, "127.0.0.1:7001".to_string(), ShardHealth::Healthy),
            (1u64, "127.0.0.1:7002".to_string(), ShardHealth::Down),
        ];
        let mut p = PromText::new();
        m.render_prometheus(&mut p, &shards);
        let text = p.finish();
        validate_exposition(&text).expect("router exposition must validate");
        assert!(text.contains("pqdtw_router_requests_total 2\n"));
        assert!(text.contains("pqdtw_router_hedges_total 1\n"));
        assert!(text.contains("pqdtw_router_degraded_responses_total 1\n"));
        assert!(text.contains("pqdtw_slow_queries_total 1\n"));
        assert!(text.contains("pqdtw_router_shards 2\n"));
        assert!(text
            .contains("pqdtw_router_shard_health{shard=\"0\",addr=\"127.0.0.1:7001\"} 0\n"));
        assert!(text
            .contains("pqdtw_router_shard_health{shard=\"1\",addr=\"127.0.0.1:7002\"} 2\n"));
    }
}
