//! `router` — fault-tolerant scatter-gather serving over shard
//! servers: one router process fans each query out to N `pqdtw serve`
//! shards over the wire protocol and merges the replies through the
//! same `(distance, index)` total order the engine uses, so a
//! full-health routed answer is **bit-identical** to the unsharded
//! scan (see `docs/serving-topology.md`).
//!
//! The shard split is `id % n` at build time (`build-index --shard
//! i/n`): every shard trains the *same* quantizer on the full dataset,
//! encodes only its own rows, and stores its global-id mapping, so the
//! hits each shard returns already carry database-global indices and
//! the merge is a pure order-preserving k-way selection.
//!
//! Robustness is the point, not an afterthought:
//!
//! - [`health`] — each shard connection is supervised by a
//!   `Healthy → Degraded → Down` state machine fed by in-band failures
//!   and background Ping probes, with jittered exponential backoff and
//!   half-open recovery probes for Down shards.
//! - per-request policy — idempotent queries are retried once on a
//!   fresh connection (a retry after a read timeout is a *hedge*:
//!   the shard may be slow, not dead); after that the router either
//!   fails the request (`--require-full`) or answers with what the
//!   surviving shards returned, flagged `degraded` with the missing
//!   shard list (the wire v4 trailer).
//! - [`metrics`] — `pqdtw_router_*` Prometheus families: per-shard
//!   health gauge, retries, hedges, degraded responses, probe
//!   counters.
//! - [`fault`] — a fault-injection proxy that can delay, black-hole,
//!   truncate, or sever a shard's traffic; the loopback integration
//!   tests drive every failure mode through it.
//!
//! Std-only like the rest of the serving plane (`std::net` + threads;
//! `docs/DESIGN.md` §3).

// rustc-side twin of the xtask no-panic-in-serving rule: router code
// must propagate errors, never unwrap. Test code is exempt on purpose.
#![cfg_attr(not(test), warn(clippy::unwrap_used, clippy::expect_used))]

use std::sync::{Arc, Mutex, MutexGuard, PoisonError};
use std::time::Instant;

use anyhow::{ensure, Result};

use crate::coordinator::Hit;
use crate::net::protocol::{NetRequest, NetResponse, WireStats};
use crate::obs::log::JsonLogger;
use crate::obs::prometheus::PromText;

pub mod fault;
pub mod health;
pub mod metrics;
pub mod server;

pub use fault::{FaultMode, FaultProxy};
pub use health::{HealthConfig, ShardConn, ShardHealth, ShardOutcome};
pub use metrics::RouterMetrics;
pub use server::{RouterRunSummary, RouterServer, RouterServerConfig};

/// Scatter-gather policy knobs.
#[derive(Debug, Clone)]
pub struct RouterConfig {
    /// Shard server addresses; position is the shard index, so the
    /// list must match the `--shard i/n` split order.
    pub shards: Vec<String>,
    /// Strict mode: fail any query a shard cannot answer instead of
    /// returning a degraded partial result.
    pub require_full: bool,
    /// Per-shard connect/read deadlines and health thresholds.
    pub health: HealthConfig,
}

impl RouterConfig {
    /// A router over `shards` with default health policy.
    pub fn new(shards: Vec<String>) -> Self {
        RouterConfig { shards, require_full: false, health: HealthConfig::default() }
    }
}

/// The deterministic hit order shared by the engine's scans and the
/// router's merge: ascending distance (IEEE-754 total order, so NaN
/// sorts deterministically too), ties broken by ascending global
/// index.
pub fn hit_order(a: &Hit, b: &Hit) -> std::cmp::Ordering {
    a.distance.total_cmp(&b.distance).then(a.index.cmp(&b.index))
}

/// Merge per-shard top-k lists into the global top-k. Because every
/// hit carries its database-global index and every shard saw the same
/// quantizer, this equals the unsharded scan's answer exactly when all
/// shards contribute.
pub fn merge_topk(per_shard: Vec<Vec<Hit>>, k: usize) -> Vec<Hit> {
    let mut all: Vec<Hit> = per_shard.into_iter().flatten().collect();
    all.sort_by(hit_order);
    all.truncate(k);
    all
}

/// Merge per-shard 1-NN winners into the global winner (`None` when no
/// shard contributed a hit).
pub fn merge_nn(per_shard: Vec<Hit>) -> Option<Hit> {
    per_shard.into_iter().min_by(hit_order)
}

/// Aggregate per-shard stats frames into one fleet view: counters sum,
/// means weight by request count, percentiles take the fleet-worst
/// (max), and the index header comes from the first reporting shard
/// with `n_items` summed across the fleet.
pub fn aggregate_stats(per_shard: &[WireStats]) -> Option<WireStats> {
    let first = per_shard.first()?;
    let mut out = first.clone();
    out.n_items = per_shard.iter().map(|s| s.n_items).sum();
    out.requests = per_shard.iter().map(|s| s.requests).sum();
    out.errors = per_shard.iter().map(|s| s.errors).sum();
    out.batches = per_shard.iter().map(|s| s.batches).sum();
    out.mean_batch_size = weighted_mean(per_shard.iter().map(|s| (s.batches, s.mean_batch_size)));
    out.mean_latency_us =
        weighted_mean(per_shard.iter().map(|s| (s.requests, s.mean_latency_us)));
    out.p50_us = per_shard.iter().map(|s| s.p50_us).max().unwrap_or(0);
    out.p99_us = per_shard.iter().map(|s| s.p99_us).max().unwrap_or(0);
    for (ci, class) in out.per_class.iter_mut().enumerate() {
        let rows: Vec<_> = per_shard.iter().filter_map(|s| s.per_class.get(ci)).collect();
        class.requests = rows.iter().map(|c| c.requests).sum();
        class.mean_latency_us =
            weighted_mean(rows.iter().map(|c| (c.requests, c.mean_latency_us)));
        class.p50_us = rows.iter().map(|c| c.p50_us).max().unwrap_or(0);
        class.p99_us = rows.iter().map(|c| c.p99_us).max().unwrap_or(0);
    }
    for (si, stage) in out.per_stage.iter_mut().enumerate() {
        let rows: Vec<_> = per_shard.iter().filter_map(|s| s.per_stage.get(si)).collect();
        stage.count = rows.iter().map(|s| s.count).sum();
        stage.mean_us = weighted_mean(rows.iter().map(|s| (s.count, s.mean_us)));
        stage.p50_us = rows.iter().map(|s| s.p50_us).max().unwrap_or(0);
        stage.p99_us = rows.iter().map(|s| s.p99_us).max().unwrap_or(0);
    }
    out.scan.items_scanned = per_shard.iter().map(|s| s.scan.items_scanned).sum();
    out.scan.items_abandoned = per_shard.iter().map(|s| s.scan.items_abandoned).sum();
    out.scan.blocks_skipped = per_shard.iter().map(|s| s.scan.blocks_skipped).sum();
    out.scan.lut_collapses = per_shard.iter().map(|s| s.scan.lut_collapses).sum();
    out.scan.shard_time_us = per_shard.iter().map(|s| s.scan.shard_time_us).sum();
    out.scan.shards = per_shard.iter().map(|s| s.scan.shards).sum();
    // Fleet-minimum uptime: "how long has the weakest member been up"
    // is the operationally honest number after a shard restart.
    out.uptime_s = per_shard.iter().map(|s| s.uptime_s).min().unwrap_or(0);
    out.version = env!("CARGO_PKG_VERSION").to_string();
    Some(out)
}

fn weighted_mean(rows: impl Iterator<Item = (u64, f64)>) -> f64 {
    let (mut weight, mut sum) = (0u64, 0.0f64);
    for (w, mean) in rows {
        weight += w;
        sum += w as f64 * mean;
    }
    if weight == 0 {
        0.0
    } else {
        sum / weight as f64
    }
}

/// Lock a mutex, recovering from poison (same rationale as the net
/// server: a panicking peer thread must not wedge the router).
pub(crate) fn lock_unpoisoned<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// The scatter-gather core: supervised shard connections plus the
/// request policy. [`RouterServer`] wraps this in a TCP accept loop;
/// tests drive it directly.
pub struct Router {
    cfg: RouterConfig,
    shards: Vec<Mutex<ShardConn>>,
    metrics: RouterMetrics,
    logger: Arc<JsonLogger>,
    started: Instant,
}

impl Router {
    /// Build the supervision state for `cfg.shards` (no connections are
    /// opened yet; the first request or probe dials lazily).
    pub fn new(cfg: RouterConfig, logger: Arc<JsonLogger>) -> Result<Router> {
        ensure!(!cfg.shards.is_empty(), "router: need at least one shard address");
        let shards = cfg
            .shards
            .iter()
            .enumerate()
            .map(|(i, addr)| Mutex::new(ShardConn::new(i as u64, addr.clone(), cfg.health)))
            .collect();
        Ok(Router { cfg, shards, metrics: RouterMetrics::new(), logger, started: Instant::now() })
    }

    /// Shard count this router scatters over.
    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// Router-level counters (shared with the serving loop).
    pub fn metrics(&self) -> &RouterMetrics {
        &self.metrics
    }

    /// Current per-shard health, by shard index.
    pub fn health(&self) -> Vec<ShardHealth> {
        self.shards.iter().map(|s| lock_unpoisoned(s).health()).collect()
    }

    /// Send `req` to every shard in parallel; returns per-shard
    /// outcomes indexed by shard.
    fn scatter(&self, req: &NetRequest) -> Vec<ShardOutcome> {
        let mut outcomes: Vec<ShardOutcome> = Vec::with_capacity(self.shards.len());
        std::thread::scope(|scope| {
            let handles: Vec<_> = self
                .shards
                .iter()
                .map(|shard| scope.spawn(move || lock_unpoisoned(shard).request(req, &self.metrics)))
                .collect();
            for (i, h) in handles.into_iter().enumerate() {
                match h.join() {
                    Ok(outcome) => outcomes.push(outcome),
                    // A panicking scatter thread counts as a failed
                    // shard, not a dead router.
                    Err(_) => outcomes.push(ShardOutcome::Failed(format!(
                        "router: scatter worker for shard {i} panicked"
                    ))),
                }
            }
        });
        outcomes
    }

    /// Probe every shard once (the background prober calls this on its
    /// interval): Down shards get their half-open recovery attempt,
    /// live shards get a liveness check.
    pub fn probe_all(&self) {
        for (i, shard) in self.shards.iter().enumerate() {
            let mut conn = lock_unpoisoned(shard);
            let before = conn.health();
            let after = conn.probe(&self.metrics);
            if before != after {
                self.logger.event(
                    "shard_health",
                    &[
                        ("shard", (i as u64).into()),
                        ("from", before.name().into()),
                        ("to", after.name().into()),
                    ],
                );
            }
        }
    }

    /// Answer one decoded client request. Everything is answered
    /// inline: the router holds no engine, so there is nothing to
    /// batch.
    pub fn dispatch(&self, req: NetRequest) -> NetResponse {
        self.metrics.requests.incr();
        let resp = self.dispatch_inner(req);
        if matches!(resp, NetResponse::Error(_)) {
            self.metrics.errors.incr();
        }
        resp
    }

    fn dispatch_inner(&self, req: NetRequest) -> NetResponse {
        match req {
            // The router answers for its own liveness; shard liveness
            // is the prober's job and is visible in the health gauge.
            NetRequest::Ping => NetResponse::Pong,
            NetRequest::MetricsText => NetResponse::MetricsText(self.prometheus_text()),
            NetRequest::Shutdown => NetResponse::ShutdownAck,
            NetRequest::Stats => self.routed_stats(),
            NetRequest::Nn { series, mode, nprobe, request_id, .. } => {
                // Traces are per-shard artifacts with no sound merge;
                // the routed query always runs untraced (documented in
                // docs/serving-topology.md).
                let fwd = NetRequest::Nn { series, mode, nprobe, request_id, trace: false };
                self.routed_nn(&fwd)
            }
            NetRequest::TopK { series, k, mode, nprobe, rerank, request_id, .. } => {
                let fwd = NetRequest::TopK {
                    series,
                    k,
                    mode,
                    nprobe,
                    rerank,
                    request_id,
                    trace: false,
                };
                self.routed_topk(&fwd, k)
            }
            NetRequest::JobCreate { .. }
            | NetRequest::JobStatus { .. }
            | NetRequest::JobEvents { .. }
            | NetRequest::JobCancel { .. }
            | NetRequest::JobResult { .. } => NetResponse::Error(
                "job plane is not routed: submit jobs to a shard server directly".into(),
            ),
        }
    }

    /// Split scatter outcomes into in-shape replies and missing shards.
    /// A shard that answered with an application `Error` frame is
    /// missing *unless every reachable shard erred* — then the error is
    /// about the query itself (wrong length, bad k) and is propagated
    /// verbatim instead of being dressed up as an outage.
    fn gather(
        &self,
        outcomes: Vec<ShardOutcome>,
    ) -> std::result::Result<(Vec<(u64, NetResponse)>, Vec<u64>), NetResponse> {
        let mut replies = Vec::new();
        let mut missing = Vec::new();
        let mut app_errors = Vec::new();
        for (i, outcome) in outcomes.into_iter().enumerate() {
            let shard = i as u64;
            match outcome {
                ShardOutcome::Ok(NetResponse::Error(msg)) => app_errors.push((shard, msg)),
                ShardOutcome::Ok(resp) => replies.push((shard, resp)),
                ShardOutcome::Skipped => missing.push(shard),
                ShardOutcome::Failed(err) => {
                    self.logger.event(
                        "shard_failed",
                        &[("shard", shard.into()), ("error", err.clone().into())],
                    );
                    missing.push(shard);
                }
            }
        }
        if replies.is_empty() {
            if let Some((_, msg)) = app_errors.into_iter().next() {
                return Err(NetResponse::Error(msg));
            }
            return Err(NetResponse::Error(format!(
                "router: no shard available ({} down/unreachable)",
                missing.len()
            )));
        }
        missing.extend(app_errors.into_iter().map(|(shard, _)| shard));
        missing.sort_unstable();
        if self.cfg.require_full && !missing.is_empty() {
            return Err(NetResponse::Error(format!(
                "router: {} of {} shards unavailable (require-full): missing {missing:?}",
                missing.len(),
                self.shards.len()
            )));
        }
        if !missing.is_empty() {
            self.metrics.degraded_responses.incr();
            self.logger.event(
                "degraded_response",
                &[("missing", format!("{missing:?}").into())],
            );
        }
        Ok((replies, missing))
    }

    fn routed_nn(&self, fwd: &NetRequest) -> NetResponse {
        let (replies, missing) = match self.gather(self.scatter(fwd)) {
            Ok(v) => v,
            Err(resp) => return resp,
        };
        let mut winners = Vec::with_capacity(replies.len());
        for (shard, resp) in replies {
            match resp {
                NetResponse::Nn { index, distance, label, .. } => {
                    winners.push(Hit { index, distance, label });
                }
                other => {
                    return NetResponse::Error(format!(
                        "router: shard {shard} answered NN with {other:?}"
                    ))
                }
            }
        }
        match merge_nn(winners) {
            Some(best) => NetResponse::Nn {
                index: best.index,
                distance: best.distance,
                label: best.label,
                trace: None,
                degraded: !missing.is_empty(),
                missing_shards: missing,
            },
            None => NetResponse::Error("router: no shard returned a neighbor".into()),
        }
    }

    fn routed_topk(&self, fwd: &NetRequest, k: usize) -> NetResponse {
        let (replies, missing) = match self.gather(self.scatter(fwd)) {
            Ok(v) => v,
            Err(resp) => return resp,
        };
        let mut per_shard = Vec::with_capacity(replies.len());
        for (shard, resp) in replies {
            match resp {
                NetResponse::TopK { hits, .. } => per_shard.push(hits),
                other => {
                    return NetResponse::Error(format!(
                        "router: shard {shard} answered TopK with {other:?}"
                    ))
                }
            }
        }
        NetResponse::TopK {
            hits: merge_topk(per_shard, k),
            trace: None,
            degraded: !missing.is_empty(),
            missing_shards: missing,
        }
    }

    fn routed_stats(&self) -> NetResponse {
        let (replies, _missing) = match self.gather(self.scatter(&NetRequest::Stats)) {
            Ok(v) => v,
            Err(resp) => return resp,
        };
        let mut stats = Vec::with_capacity(replies.len());
        for (shard, resp) in replies {
            match resp {
                NetResponse::Stats(s) => stats.push(s),
                other => {
                    return NetResponse::Error(format!(
                        "router: shard {shard} answered Stats with {other:?}"
                    ))
                }
            }
        }
        match aggregate_stats(&stats) {
            Some(s) => NetResponse::Stats(s),
            None => NetResponse::Error("router: no shard reported stats".into()),
        }
    }

    /// The router's own Prometheus exposition (`pqdtw_router_*`): it
    /// deliberately does *not* proxy shard metrics — scrape the shards
    /// directly for engine counters.
    pub fn prometheus_text(&self) -> String {
        let mut p = PromText::new();
        let healths: Vec<(u64, String, ShardHealth)> = self
            .shards
            .iter()
            .enumerate()
            .map(|(i, s)| {
                let conn = lock_unpoisoned(s);
                (i as u64, conn.addr().to_string(), conn.health())
            })
            .collect();
        self.metrics.render_prometheus(&mut p, &healths);
        p.gauge("pqdtw_router_uptime_seconds", self.started.elapsed().as_secs_f64());
        p.finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hit(index: usize, distance: f64) -> Hit {
        Hit { index, distance, label: None }
    }

    #[test]
    fn merge_topk_is_the_global_order() {
        let shard0 = vec![hit(0, 0.5), hit(3, 0.75), hit(6, 2.0)];
        let shard1 = vec![hit(1, 0.25), hit(4, 0.75), hit(7, 0.75)];
        let shard2 = vec![hit(2, 3.0)];
        let merged = merge_topk(vec![shard0, shard1, shard2], 4);
        let got: Vec<(usize, f64)> = merged.iter().map(|h| (h.index, h.distance)).collect();
        // Ties at 0.75 resolve by ascending global index: 3, 4, 7.
        assert_eq!(got, vec![(1, 0.25), (0, 0.5), (3, 0.75), (4, 0.75)]);
    }

    #[test]
    fn merge_topk_truncates_and_handles_empty_shards() {
        assert!(merge_topk(vec![], 3).is_empty());
        assert!(merge_topk(vec![vec![], vec![]], 3).is_empty());
        let merged = merge_topk(vec![vec![hit(5, 1.0)], vec![]], 3);
        assert_eq!(merged.len(), 1);
        assert_eq!(merged[0].index, 5);
    }

    #[test]
    fn merge_order_is_total_under_nan() {
        // total_cmp sorts +NaN above +inf, so a NaN distance cannot
        // shadow a finite winner and the merge stays deterministic.
        let merged = merge_topk(
            vec![vec![hit(0, f64::NAN)], vec![hit(1, f64::INFINITY)], vec![hit(2, 1.0)]],
            3,
        );
        let order: Vec<usize> = merged.iter().map(|h| h.index).collect();
        assert_eq!(order, vec![2, 1, 0]);
    }

    #[test]
    fn merge_nn_breaks_ties_by_index() {
        let best = merge_nn(vec![hit(9, 0.5), hit(2, 0.5), hit(4, 1.0)]).unwrap();
        assert_eq!(best.index, 2);
        assert!(merge_nn(vec![]).is_none());
    }

    #[test]
    fn aggregate_stats_sums_counts_and_weights_means() {
        use crate::net::protocol::WireClassStats;
        let mut a = WireStats {
            requests: 10,
            errors: 1,
            batches: 5,
            mean_batch_size: 2.0,
            mean_latency_us: 100.0,
            p50_us: 80,
            p99_us: 200,
            per_class: vec![WireClassStats {
                class: 0,
                name: "ping".into(),
                requests: 10,
                mean_latency_us: 100.0,
                p50_us: 80,
                p99_us: 200,
            }],
            per_stage: vec![],
            scan: Default::default(),
            uptime_s: 50,
            version: "x".into(),
            n_items: 100,
            n_subspaces: 4,
            codebook_size: 8,
            series_len: 64,
            window_frac: 0.1,
            coarse_metric: "dtw".into(),
            nlist: None,
        };
        a.scan.items_scanned = 7;
        let mut b = a.clone();
        b.requests = 30;
        b.mean_latency_us = 200.0;
        b.p99_us = 400;
        b.n_items = 28;
        b.uptime_s = 9;
        b.per_class[0].requests = 30;
        b.per_class[0].mean_latency_us = 200.0;
        let agg = aggregate_stats(&[a, b]).unwrap();
        assert_eq!(agg.requests, 40);
        assert_eq!(agg.errors, 2);
        assert_eq!(agg.n_items, 128);
        assert_eq!(agg.p99_us, 400);
        assert_eq!(agg.uptime_s, 9);
        assert_eq!(agg.scan.items_scanned, 14);
        // 10 × 100 + 30 × 200 over 40 requests.
        assert!((agg.mean_latency_us - 175.0).abs() < 1e-9);
        assert!((agg.per_class[0].mean_latency_us - 175.0).abs() < 1e-9);
        assert!(aggregate_stats(&[]).is_none());
    }
}
