//! `router` — fault-tolerant scatter-gather serving over shard
//! servers: one router process fans each query out to N `pqdtw serve`
//! shards over the wire protocol and merges the replies through the
//! same `(distance, index)` total order the engine uses, so a
//! full-health routed answer is **bit-identical** to the unsharded
//! scan (see `docs/serving-topology.md`).
//!
//! The shard split is `id % n` at build time (`build-index --shard
//! i/n`): every shard trains the *same* quantizer on the full dataset,
//! encodes only its own rows, and stores its global-id mapping, so the
//! hits each shard returns already carry database-global indices and
//! the merge is a pure order-preserving k-way selection.
//!
//! Robustness is the point, not an afterthought:
//!
//! - [`health`] — each shard connection is supervised by a
//!   `Healthy → Degraded → Down` state machine fed by in-band failures
//!   and background Ping probes, with jittered exponential backoff and
//!   half-open recovery probes for Down shards.
//! - per-request policy — idempotent queries are retried once on a
//!   fresh connection (a retry after a read timeout is a *hedge*:
//!   the shard may be slow, not dead); after that the router either
//!   fails the request (`--require-full`) or answers with what the
//!   surviving shards returned, flagged `degraded` with the missing
//!   shard list (the wire v4 trailer).
//! - [`metrics`] — `pqdtw_router_*` Prometheus families: per-shard
//!   health gauge, retries, hedges, degraded responses, probe
//!   counters.
//! - [`fault`] — a fault-injection proxy that can delay, black-hole,
//!   truncate, or sever a shard's traffic; the loopback integration
//!   tests drive every failure mode through it.
//!
//! Std-only like the rest of the serving plane (`std::net` + threads;
//! `docs/DESIGN.md` §3).

// rustc-side twin of the xtask no-panic-in-serving rule: router code
// must propagate errors, never unwrap. Test code is exempt on purpose.
#![cfg_attr(not(test), warn(clippy::unwrap_used, clippy::expect_used))]

use std::sync::{Arc, Mutex, MutexGuard, PoisonError};
use std::time::Instant;

use anyhow::{ensure, Result};

use crate::coordinator::{histogram_percentile, Hit, BUCKETS_US};
use crate::net::protocol::{NetRequest, NetResponse, WireStats};
use crate::obs::log::JsonLogger;
use crate::obs::prometheus::PromText;
use crate::obs::{ChildTrace, HitExplain, QueryTrace, ScanSnapshot, Stage, StageSpan};

pub mod fault;
pub mod health;
pub mod metrics;
pub mod server;

pub use fault::{FaultMode, FaultProxy};
pub use health::{HealthConfig, ShardConn, ShardHealth, ShardOutcome};
pub use metrics::RouterMetrics;
pub use server::{RouterRunSummary, RouterServer, RouterServerConfig};

/// Scatter-gather policy knobs.
#[derive(Debug, Clone)]
pub struct RouterConfig {
    /// Shard server addresses; position is the shard index, so the
    /// list must match the `--shard i/n` split order.
    pub shards: Vec<String>,
    /// Strict mode: fail any query a shard cannot answer instead of
    /// returning a degraded partial result.
    pub require_full: bool,
    /// Per-shard connect/read deadlines and health thresholds.
    pub health: HealthConfig,
    /// Emit a `slow_query` log event (and bump
    /// `pqdtw_slow_queries_total`) for routed queries whose end-to-end
    /// wall time reaches this many microseconds (`None` disables).
    pub slow_query_us: Option<u64>,
}

impl RouterConfig {
    /// A router over `shards` with default health policy.
    pub fn new(shards: Vec<String>) -> Self {
        RouterConfig {
            shards,
            require_full: false,
            health: HealthConfig::default(),
            slow_query_us: None,
        }
    }
}

/// The deterministic hit order shared by the engine's scans and the
/// router's merge: ascending distance (IEEE-754 total order, so NaN
/// sorts deterministically too), ties broken by ascending global
/// index.
pub fn hit_order(a: &Hit, b: &Hit) -> std::cmp::Ordering {
    a.distance.total_cmp(&b.distance).then(a.index.cmp(&b.index))
}

/// Merge per-shard top-k lists into the global top-k. Because every
/// hit carries its database-global index and every shard saw the same
/// quantizer, this equals the unsharded scan's answer exactly when all
/// shards contribute.
pub fn merge_topk(per_shard: Vec<Vec<Hit>>, k: usize) -> Vec<Hit> {
    let mut all: Vec<Hit> = per_shard.into_iter().flatten().collect();
    all.sort_by(hit_order);
    all.truncate(k);
    all
}

/// Merge per-shard 1-NN winners into the global winner (`None` when no
/// shard contributed a hit).
pub fn merge_nn(per_shard: Vec<Hit>) -> Option<Hit> {
    per_shard.into_iter().min_by(hit_order)
}

/// Element-wise sum of per-shard raw bucket-count arrays (aligned with
/// [`BUCKETS_US`]). Histogram addition is associative and commutative,
/// so merging loses nothing and any merge order yields the same fleet
/// distribution (proptested in `tests/proptests.rs`).
pub fn merge_buckets<'a>(rows: impl Iterator<Item = &'a [u64]>) -> Vec<u64> {
    let mut out = vec![0u64; BUCKETS_US.len()];
    for row in rows {
        for (acc, &c) in out.iter_mut().zip(row.iter()) {
            *acc = acc.saturating_add(c);
        }
    }
    out
}

/// Percentile over a raw bucket-count array, via the exact same
/// [`histogram_percentile`] definition the single-node snapshot uses —
/// routed and unsharded percentiles share one formula.
pub fn bucket_percentile(buckets: &[u64], p: f64) -> u64 {
    let hist: Vec<(u64, u64)> =
        BUCKETS_US.iter().copied().zip(buckets.iter().copied()).collect();
    histogram_percentile(&hist, p)
}

/// Aggregate per-shard stats frames into one fleet view: counters sum,
/// means weight by request count, and percentiles come from the exact
/// bucket-wise merge of the shards' raw latency histograms — the fleet
/// p50/p99 equal the percentiles over the union of every shard's raw
/// observations (at histogram resolution), exactly what one node
/// serving all the traffic would report. The index header comes from
/// the first reporting shard with `n_items` summed across the fleet.
pub fn aggregate_stats(per_shard: &[WireStats]) -> Option<WireStats> {
    let first = per_shard.first()?;
    if per_shard.len() == 1 {
        // A one-shard fleet must report stats bit-identical to the
        // shard itself. The general path recomputes each mean as
        // `(mean * n) / n`, which can drift by an ULP in f64, so the
        // identity case skips the round trip entirely.
        let mut out = first.clone();
        out.version = env!("CARGO_PKG_VERSION").to_string();
        return Some(out);
    }
    let mut out = first.clone();
    out.n_items = per_shard.iter().map(|s| s.n_items).sum();
    out.requests = per_shard.iter().map(|s| s.requests).sum();
    out.errors = per_shard.iter().map(|s| s.errors).sum();
    out.batches = per_shard.iter().map(|s| s.batches).sum();
    out.mean_batch_size = weighted_mean(per_shard.iter().map(|s| (s.batches, s.mean_batch_size)));
    out.mean_latency_us =
        weighted_mean(per_shard.iter().map(|s| (s.requests, s.mean_latency_us)));
    out.latency_buckets = merge_buckets(per_shard.iter().map(|s| s.latency_buckets.as_slice()));
    out.p50_us = bucket_percentile(&out.latency_buckets, 0.5);
    out.p99_us = bucket_percentile(&out.latency_buckets, 0.99);
    for (ci, class) in out.per_class.iter_mut().enumerate() {
        let rows: Vec<_> = per_shard.iter().filter_map(|s| s.per_class.get(ci)).collect();
        class.requests = rows.iter().map(|c| c.requests).sum();
        class.mean_latency_us =
            weighted_mean(rows.iter().map(|c| (c.requests, c.mean_latency_us)));
        class.buckets = merge_buckets(rows.iter().map(|c| c.buckets.as_slice()));
        class.p50_us = bucket_percentile(&class.buckets, 0.5);
        class.p99_us = bucket_percentile(&class.buckets, 0.99);
    }
    for (si, stage) in out.per_stage.iter_mut().enumerate() {
        let rows: Vec<_> = per_shard.iter().filter_map(|s| s.per_stage.get(si)).collect();
        stage.count = rows.iter().map(|s| s.count).sum();
        stage.mean_us = weighted_mean(rows.iter().map(|s| (s.count, s.mean_us)));
        stage.buckets = merge_buckets(rows.iter().map(|s| s.buckets.as_slice()));
        stage.p50_us = bucket_percentile(&stage.buckets, 0.5);
        stage.p99_us = bucket_percentile(&stage.buckets, 0.99);
    }
    out.scan.items_scanned = per_shard.iter().map(|s| s.scan.items_scanned).sum();
    out.scan.items_abandoned = per_shard.iter().map(|s| s.scan.items_abandoned).sum();
    out.scan.blocks_skipped = per_shard.iter().map(|s| s.scan.blocks_skipped).sum();
    out.scan.lut_collapses = per_shard.iter().map(|s| s.scan.lut_collapses).sum();
    out.scan.shard_time_us = per_shard.iter().map(|s| s.scan.shard_time_us).sum();
    out.scan.shards = per_shard.iter().map(|s| s.scan.shards).sum();
    // Fleet-minimum uptime: "how long has the weakest member been up"
    // is the operationally honest number after a shard restart.
    out.uptime_s = per_shard.iter().map(|s| s.uptime_s).min().unwrap_or(0);
    out.version = env!("CARGO_PKG_VERSION").to_string();
    Some(out)
}

fn weighted_mean(rows: impl Iterator<Item = (u64, f64)>) -> f64 {
    let (mut weight, mut sum) = (0u64, 0.0f64);
    for (w, mean) in rows {
        weight += w;
        sum += w as f64 * mean;
    }
    if weight == 0 {
        0.0
    } else {
        sum / weight as f64
    }
}

/// Lock a mutex, recovering from poison (same rationale as the net
/// server: a panicking peer thread must not wedge the router).
pub(crate) fn lock_unpoisoned<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Microseconds since `t0`, saturating instead of truncating.
fn elapsed_us(t0: Instant) -> u64 {
    u64::try_from(t0.elapsed().as_micros()).unwrap_or(u64::MAX)
}

/// One scatter leg: the shard's outcome plus the leg's wall time as
/// observed from the router.
struct Leg {
    outcome: ShardOutcome,
    wall_us: u64,
}

/// One in-shape shard reply after gathering, with the leg annotations
/// that become `shard_rpc` span / child-trace metadata.
struct ShardReply {
    shard: u64,
    wall_us: u64,
    retried: bool,
    hedged: bool,
    resp: NetResponse,
}

/// The per-hit explain recorded by `shard`'s own engine for global
/// index `index`, when that shard sent one.
fn explain_for(children: &[ChildTrace], shard: u64, index: u64) -> Option<HitExplain> {
    children
        .iter()
        .find(|c| c.shard == shard)
        .and_then(|c| c.trace.hits.iter().find(|h| h.index == index))
        .copied()
}

/// Assemble the merged router-level trace: a `fanout` span (shards
/// contacted → shards answered), one `shard_rpc` span per answering
/// shard (1:1 with `children`, both ascending by shard index), and a
/// `merge` span (candidates in → hits out). The scan snapshot is the
/// fleet sum of the children's, and `hits` carry shard provenance.
#[allow(clippy::too_many_arguments)]
fn build_routed_trace(
    request_id: u64,
    n_shards: usize,
    fanout_us: u64,
    merge_us: u64,
    merge_in: u64,
    merge_out: u64,
    rpc_spans: Vec<StageSpan>,
    children: Vec<ChildTrace>,
    hits: Vec<HitExplain>,
) -> QueryTrace {
    let mut spans = Vec::with_capacity(rpc_spans.len() + 2);
    spans.push(StageSpan {
        stage: Stage::Fanout,
        wall_us: fanout_us,
        candidates_in: n_shards as u64,
        candidates_out: children.len() as u64,
    });
    spans.extend(rpc_spans);
    spans.push(StageSpan {
        stage: Stage::Merge,
        wall_us: merge_us,
        candidates_in: merge_in,
        candidates_out: merge_out,
    });
    let mut scan = ScanSnapshot::default();
    for c in &children {
        scan.items_scanned = scan.items_scanned.saturating_add(c.trace.scan.items_scanned);
        scan.items_abandoned =
            scan.items_abandoned.saturating_add(c.trace.scan.items_abandoned);
        scan.blocks_skipped = scan.blocks_skipped.saturating_add(c.trace.scan.blocks_skipped);
        scan.lut_collapses = scan.lut_collapses.saturating_add(c.trace.scan.lut_collapses);
        scan.shard_time_us = scan.shard_time_us.saturating_add(c.trace.scan.shard_time_us);
        scan.shards = scan.shards.saturating_add(c.trace.scan.shards);
    }
    QueryTrace { request_id, spans, hits, scan, children }
}

/// The scatter-gather core: supervised shard connections plus the
/// request policy. [`RouterServer`] wraps this in a TCP accept loop;
/// tests drive it directly.
pub struct Router {
    cfg: RouterConfig,
    shards: Vec<Mutex<ShardConn>>,
    metrics: RouterMetrics,
    logger: Arc<JsonLogger>,
    started: Instant,
}

impl Router {
    /// Build the supervision state for `cfg.shards` (no connections are
    /// opened yet; the first request or probe dials lazily).
    pub fn new(cfg: RouterConfig, logger: Arc<JsonLogger>) -> Result<Router> {
        ensure!(!cfg.shards.is_empty(), "router: need at least one shard address");
        let shards = cfg
            .shards
            .iter()
            .enumerate()
            .map(|(i, addr)| Mutex::new(ShardConn::new(i as u64, addr.clone(), cfg.health)))
            .collect();
        Ok(Router { cfg, shards, metrics: RouterMetrics::new(), logger, started: Instant::now() })
    }

    /// Shard count this router scatters over.
    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// Router-level counters (shared with the serving loop).
    pub fn metrics(&self) -> &RouterMetrics {
        &self.metrics
    }

    /// Current per-shard health, by shard index.
    pub fn health(&self) -> Vec<ShardHealth> {
        self.shards.iter().map(|s| lock_unpoisoned(s).health()).collect()
    }

    /// Send `req` to every shard in parallel; returns per-shard legs
    /// indexed by shard, each timed from dispatch to joined reply (so
    /// a leg's wall time includes connect, retry, and hedge cost).
    fn scatter(&self, req: &NetRequest) -> Vec<Leg> {
        let mut legs: Vec<Leg> = Vec::with_capacity(self.shards.len());
        std::thread::scope(|scope| {
            let handles: Vec<_> = self
                .shards
                .iter()
                .map(|shard| {
                    scope.spawn(move || {
                        let t0 = Instant::now();
                        let outcome = lock_unpoisoned(shard).request(req, &self.metrics);
                        Leg { outcome, wall_us: elapsed_us(t0) }
                    })
                })
                .collect();
            for (i, h) in handles.into_iter().enumerate() {
                match h.join() {
                    Ok(leg) => legs.push(leg),
                    // A panicking scatter thread counts as a failed
                    // shard, not a dead router.
                    Err(_) => legs.push(Leg {
                        outcome: ShardOutcome::Failed(format!(
                            "router: scatter worker for shard {i} panicked"
                        )),
                        wall_us: 0,
                    }),
                }
            }
        });
        legs
    }

    /// Probe every shard once (the background prober calls this on its
    /// interval): Down shards get their half-open recovery attempt,
    /// live shards get a liveness check.
    pub fn probe_all(&self) {
        for (i, shard) in self.shards.iter().enumerate() {
            let mut conn = lock_unpoisoned(shard);
            let before = conn.health();
            let after = conn.probe(&self.metrics);
            if before != after {
                self.logger.event(
                    "shard_health",
                    &[
                        ("shard", (i as u64).into()),
                        ("from", before.name().into()),
                        ("to", after.name().into()),
                    ],
                );
            }
        }
    }

    /// Answer one decoded client request. Everything is answered
    /// inline: the router holds no engine, so there is nothing to
    /// batch.
    pub fn dispatch(&self, req: NetRequest) -> NetResponse {
        self.metrics.requests.incr();
        let resp = self.dispatch_inner(req);
        if matches!(resp, NetResponse::Error(_)) {
            self.metrics.errors.incr();
        }
        resp
    }

    fn dispatch_inner(&self, req: NetRequest) -> NetResponse {
        match req {
            // The router answers for its own liveness; shard liveness
            // is the prober's job and is visible in the health gauge.
            NetRequest::Ping => NetResponse::Pong,
            NetRequest::MetricsText => NetResponse::MetricsText(self.prometheus_text()),
            NetRequest::Shutdown => NetResponse::ShutdownAck,
            NetRequest::Stats => self.routed_stats(),
            // A traced query scatters traced: each shard's own trace
            // comes back as a child under the router's
            // fanout/shard_rpc/merge ladder (docs/serving-topology.md
            // has the merge contract).
            NetRequest::Nn { series, mode, nprobe, request_id, trace } => {
                let t0 = Instant::now();
                let fwd = NetRequest::Nn { series, mode, nprobe, request_id, trace };
                let resp = self.routed_nn(&fwd, trace);
                self.observe_slow_query(request_id, "nn", t0, &resp);
                resp
            }
            NetRequest::TopK { series, k, mode, nprobe, rerank, request_id, trace } => {
                let t0 = Instant::now();
                let fwd =
                    NetRequest::TopK { series, k, mode, nprobe, rerank, request_id, trace };
                let resp = self.routed_topk(&fwd, k, trace);
                self.observe_slow_query(request_id, "topk", t0, &resp);
                resp
            }
            NetRequest::JobCreate { .. }
            | NetRequest::JobStatus { .. }
            | NetRequest::JobEvents { .. }
            | NetRequest::JobCancel { .. }
            | NetRequest::JobResult { .. } => NetResponse::Error(
                "job plane is not routed: submit jobs to a shard server directly".into(),
            ),
        }
    }

    /// When a `--slow-query-ms` threshold is configured and this
    /// routed query crossed it, bump `pqdtw_slow_queries_total` and
    /// emit a `slow_query` event with the per-stage span summary.
    fn observe_slow_query(
        &self,
        request_id: u64,
        class: &str,
        started: Instant,
        resp: &NetResponse,
    ) {
        let Some(threshold_us) = self.cfg.slow_query_us else {
            return;
        };
        let wall_us = elapsed_us(started);
        if wall_us < threshold_us {
            return;
        }
        self.metrics.slow_queries.incr();
        let (degraded, trace) = match resp {
            NetResponse::Nn { degraded, trace, .. }
            | NetResponse::TopK { degraded, trace, .. } => (*degraded, trace.as_ref()),
            _ => (false, None),
        };
        self.logger.event(
            "slow_query",
            &[
                ("request_id", request_id.into()),
                ("class", class.into()),
                ("wall_us", wall_us.into()),
                ("degraded", degraded.into()),
                ("spans", trace.map(QueryTrace::span_summary).unwrap_or_default().into()),
            ],
        );
    }

    /// Split scatter legs into in-shape replies and missing shards.
    /// A shard that answered with an application `Error` frame is
    /// missing *unless every reachable shard erred* — then the error is
    /// about the query itself (wrong length, bad k) and is propagated
    /// verbatim instead of being dressed up as an outage.
    fn gather(
        &self,
        legs: Vec<Leg>,
    ) -> std::result::Result<(Vec<ShardReply>, Vec<u64>), NetResponse> {
        let mut replies = Vec::new();
        let mut missing = Vec::new();
        let mut app_errors = Vec::new();
        for (i, leg) in legs.into_iter().enumerate() {
            let shard = i as u64;
            match leg.outcome {
                ShardOutcome::Ok { resp: NetResponse::Error(msg), .. } => {
                    app_errors.push((shard, msg))
                }
                ShardOutcome::Ok { resp, retried, hedged } => replies.push(ShardReply {
                    shard,
                    wall_us: leg.wall_us,
                    retried,
                    hedged,
                    resp,
                }),
                ShardOutcome::Skipped => missing.push(shard),
                ShardOutcome::Failed(err) => {
                    self.logger.event(
                        "shard_failed",
                        &[("shard", shard.into()), ("error", err.clone().into())],
                    );
                    missing.push(shard);
                }
            }
        }
        if replies.is_empty() {
            if let Some((_, msg)) = app_errors.into_iter().next() {
                return Err(NetResponse::Error(msg));
            }
            return Err(NetResponse::Error(format!(
                "router: no shard available ({} down/unreachable)",
                missing.len()
            )));
        }
        missing.extend(app_errors.into_iter().map(|(shard, _)| shard));
        missing.sort_unstable();
        if self.cfg.require_full && !missing.is_empty() {
            return Err(NetResponse::Error(format!(
                "router: {} of {} shards unavailable (require-full): missing {missing:?}",
                missing.len(),
                self.shards.len()
            )));
        }
        if !missing.is_empty() {
            self.metrics.degraded_responses.incr();
            self.logger.event(
                "degraded_response",
                &[("missing", format!("{missing:?}").into())],
            );
        }
        Ok((replies, missing))
    }

    fn routed_nn(&self, fwd: &NetRequest, traced: bool) -> NetResponse {
        let request_id = match fwd {
            NetRequest::Nn { request_id, .. } => *request_id,
            _ => 0,
        };
        let fan_t0 = Instant::now();
        let legs = self.scatter(fwd);
        let n_shards = legs.len();
        let fanout_us = elapsed_us(fan_t0);
        let (replies, missing) = match self.gather(legs) {
            Ok(v) => v,
            Err(resp) => return resp,
        };
        let merge_t0 = Instant::now();
        let mut winners = Vec::with_capacity(replies.len());
        let mut rpc_spans = Vec::with_capacity(replies.len());
        let mut children = Vec::with_capacity(replies.len());
        for reply in replies {
            match reply.resp {
                NetResponse::Nn { index, distance, label, trace, degraded, .. } => {
                    winners.push((reply.shard, Hit { index, distance, label }));
                    if traced {
                        rpc_spans.push(StageSpan {
                            stage: Stage::ShardRpc,
                            wall_us: reply.wall_us,
                            candidates_in: 1,
                            candidates_out: 1,
                        });
                        children.push(ChildTrace {
                            shard: reply.shard,
                            retried: reply.retried,
                            hedged: reply.hedged,
                            degraded,
                            trace: trace.unwrap_or_default(),
                        });
                    }
                }
                other => {
                    return NetResponse::Error(format!(
                        "router: shard {} answered NN with {other:?}",
                        reply.shard
                    ))
                }
            }
        }
        let n_candidates = winners.len() as u64;
        let best = winners.into_iter().min_by(|a, b| hit_order(&a.1, &b.1));
        match best {
            Some((shard, best)) => {
                let trace = traced.then(|| {
                    let mut hits = Vec::new();
                    if let Some(mut h) = explain_for(&children, shard, best.index as u64) {
                        h.shard = Some(shard);
                        hits.push(h);
                    }
                    build_routed_trace(
                        request_id,
                        n_shards,
                        fanout_us,
                        elapsed_us(merge_t0),
                        n_candidates,
                        1,
                        rpc_spans,
                        children,
                        hits,
                    )
                });
                NetResponse::Nn {
                    index: best.index,
                    distance: best.distance,
                    label: best.label,
                    trace,
                    degraded: !missing.is_empty(),
                    missing_shards: missing,
                }
            }
            None => NetResponse::Error("router: no shard returned a neighbor".into()),
        }
    }

    fn routed_topk(&self, fwd: &NetRequest, k: usize, traced: bool) -> NetResponse {
        let request_id = match fwd {
            NetRequest::TopK { request_id, .. } => *request_id,
            _ => 0,
        };
        let fan_t0 = Instant::now();
        let legs = self.scatter(fwd);
        let n_shards = legs.len();
        let fanout_us = elapsed_us(fan_t0);
        let (replies, missing) = match self.gather(legs) {
            Ok(v) => v,
            Err(resp) => return resp,
        };
        let merge_t0 = Instant::now();
        let mut per_shard = Vec::with_capacity(replies.len());
        let mut rpc_spans = Vec::with_capacity(replies.len());
        let mut children = Vec::with_capacity(replies.len());
        for reply in replies {
            match reply.resp {
                NetResponse::TopK { hits, trace, degraded, .. } => {
                    if traced {
                        rpc_spans.push(StageSpan {
                            stage: Stage::ShardRpc,
                            wall_us: reply.wall_us,
                            candidates_in: 1,
                            candidates_out: hits.len() as u64,
                        });
                        children.push(ChildTrace {
                            shard: reply.shard,
                            retried: reply.retried,
                            hedged: reply.hedged,
                            degraded,
                            trace: trace.unwrap_or_default(),
                        });
                    }
                    per_shard.push((reply.shard, hits));
                }
                other => {
                    return NetResponse::Error(format!(
                        "router: shard {} answered TopK with {other:?}",
                        reply.shard
                    ))
                }
            }
        }
        let n_candidates: u64 = per_shard.iter().map(|(_, h)| h.len() as u64).sum();
        let merged =
            merge_topk(per_shard.iter().map(|(_, h)| h.clone()).collect(), k);
        let trace = traced.then(|| {
            let hits = merged
                .iter()
                .filter_map(|hit| {
                    let shard = per_shard
                        .iter()
                        .find(|(_, hs)| hs.iter().any(|h| h.index == hit.index))
                        .map(|(s, _)| *s)?;
                    let mut h = explain_for(&children, shard, hit.index as u64)
                        .unwrap_or(HitExplain {
                            index: hit.index as u64,
                            pq_estimate: hit.distance,
                            exact_dtw: None,
                            admitted_by: Stage::Merge,
                            shard: None,
                        });
                    h.shard = Some(shard);
                    Some(h)
                })
                .collect();
            build_routed_trace(
                request_id,
                n_shards,
                fanout_us,
                elapsed_us(merge_t0),
                n_candidates,
                merged.len() as u64,
                rpc_spans,
                children,
                hits,
            )
        });
        NetResponse::TopK {
            hits: merged,
            trace,
            degraded: !missing.is_empty(),
            missing_shards: missing,
        }
    }

    fn routed_stats(&self) -> NetResponse {
        let (replies, _missing) = match self.gather(self.scatter(&NetRequest::Stats)) {
            Ok(v) => v,
            Err(resp) => return resp,
        };
        let mut stats = Vec::with_capacity(replies.len());
        for reply in replies {
            match reply.resp {
                NetResponse::Stats(s) => stats.push(s),
                other => {
                    return NetResponse::Error(format!(
                        "router: shard {} answered Stats with {other:?}",
                        reply.shard
                    ))
                }
            }
        }
        match aggregate_stats(&stats) {
            Some(s) => NetResponse::Stats(s),
            None => NetResponse::Error("router: no shard reported stats".into()),
        }
    }

    /// Per-shard `(index, addr, health)` rows for exposition and the
    /// `/healthz` body.
    fn shard_healths(&self) -> Vec<(u64, String, ShardHealth)> {
        self.shards
            .iter()
            .enumerate()
            .map(|(i, s)| {
                let conn = lock_unpoisoned(s);
                (i as u64, conn.addr().to_string(), conn.health())
            })
            .collect()
    }

    /// The router's own Prometheus exposition (`pqdtw_router_*` plus
    /// the fleet-joinable `pqdtw_build_info`): it deliberately does
    /// *not* proxy shard metrics — scrape the shards directly for
    /// engine counters.
    pub fn prometheus_text(&self) -> String {
        let mut p = PromText::new();
        self.metrics.render_prometheus(&mut p, &self.shard_healths());
        p.gauge("pqdtw_router_uptime_seconds", self.started.elapsed().as_secs_f64());
        // Same family name as the single-node server's so fleet
        // dashboards can join router and shards on version.
        p.family("pqdtw_build_info", "gauge");
        p.sample(
            "pqdtw_build_info",
            &[("version", env!("CARGO_PKG_VERSION")), ("role", "router")],
            1.0,
        );
        p.finish()
    }

    /// JSON body for `GET /healthz`: overall status (`ok` when every
    /// shard is healthy, `down` when every breaker is open, `degraded`
    /// otherwise) plus the per-shard breaker states the prober
    /// maintains.
    pub fn healthz_json(&self) -> String {
        use std::fmt::Write as _;
        let healths = self.shard_healths();
        let status = if healths.iter().all(|(_, _, h)| *h == ShardHealth::Healthy) {
            "ok"
        } else if healths.iter().all(|(_, _, h)| *h == ShardHealth::Down) {
            "down"
        } else {
            "degraded"
        };
        let mut body = String::new();
        let _ = write!(body, "{{\"status\":\"{status}\",\"shards\":[");
        for (i, (index, addr, health)) in healths.iter().enumerate() {
            if i > 0 {
                body.push(',');
            }
            let _ = write!(
                body,
                "{{\"shard\":{index},\"addr\":\"{}\",\"health\":\"{}\"}}",
                crate::obs::log::escape(addr),
                health.name()
            );
        }
        body.push_str("]}");
        body
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hit(index: usize, distance: f64) -> Hit {
        Hit { index, distance, label: None }
    }

    #[test]
    fn merge_topk_is_the_global_order() {
        let shard0 = vec![hit(0, 0.5), hit(3, 0.75), hit(6, 2.0)];
        let shard1 = vec![hit(1, 0.25), hit(4, 0.75), hit(7, 0.75)];
        let shard2 = vec![hit(2, 3.0)];
        let merged = merge_topk(vec![shard0, shard1, shard2], 4);
        let got: Vec<(usize, f64)> = merged.iter().map(|h| (h.index, h.distance)).collect();
        // Ties at 0.75 resolve by ascending global index: 3, 4, 7.
        assert_eq!(got, vec![(1, 0.25), (0, 0.5), (3, 0.75), (4, 0.75)]);
    }

    #[test]
    fn merge_topk_truncates_and_handles_empty_shards() {
        assert!(merge_topk(vec![], 3).is_empty());
        assert!(merge_topk(vec![vec![], vec![]], 3).is_empty());
        let merged = merge_topk(vec![vec![hit(5, 1.0)], vec![]], 3);
        assert_eq!(merged.len(), 1);
        assert_eq!(merged[0].index, 5);
    }

    #[test]
    fn merge_order_is_total_under_nan() {
        // total_cmp sorts +NaN above +inf, so a NaN distance cannot
        // shadow a finite winner and the merge stays deterministic.
        let merged = merge_topk(
            vec![vec![hit(0, f64::NAN)], vec![hit(1, f64::INFINITY)], vec![hit(2, 1.0)]],
            3,
        );
        let order: Vec<usize> = merged.iter().map(|h| h.index).collect();
        assert_eq!(order, vec![2, 1, 0]);
    }

    #[test]
    fn merge_nn_breaks_ties_by_index() {
        let best = merge_nn(vec![hit(9, 0.5), hit(2, 0.5), hit(4, 1.0)]).unwrap();
        assert_eq!(best.index, 2);
        assert!(merge_nn(vec![]).is_none());
    }

    /// Per-bucket counts with `n` observations in the bucket at
    /// `idx` ([`BUCKETS_US`] alignment).
    fn buckets_with(idx: usize, n: u64) -> Vec<u64> {
        let mut b = vec![0u64; BUCKETS_US.len()];
        b[idx] = n;
        b
    }

    /// A stats frame whose scalar percentiles are derived from its own
    /// buckets (as a real server's are), so aggregation identities are
    /// exact.
    fn stats_with(requests: u64, bucket_idx: usize) -> WireStats {
        use crate::net::protocol::WireClassStats;
        let buckets = buckets_with(bucket_idx, requests);
        WireStats {
            requests,
            errors: 1,
            batches: 5,
            mean_batch_size: 2.0,
            mean_latency_us: 100.0,
            p50_us: bucket_percentile(&buckets, 0.5),
            p99_us: bucket_percentile(&buckets, 0.99),
            latency_buckets: buckets.clone(),
            per_class: vec![WireClassStats {
                class: 0,
                name: "ping".into(),
                requests,
                mean_latency_us: 100.0,
                p50_us: bucket_percentile(&buckets, 0.5),
                p99_us: bucket_percentile(&buckets, 0.99),
                buckets,
            }],
            per_stage: vec![],
            scan: Default::default(),
            uptime_s: 50,
            version: env!("CARGO_PKG_VERSION").into(),
            n_items: 100,
            n_subspaces: 4,
            codebook_size: 8,
            series_len: 64,
            window_frac: 0.1,
            coarse_metric: "dtw".into(),
            nlist: None,
        }
    }

    #[test]
    fn aggregate_stats_sums_counts_and_weights_means() {
        // Shard a: 10 requests in the 100µs bucket; shard b: 30 in the
        // 250µs bucket.
        let mut a = stats_with(10, 3);
        a.scan.items_scanned = 7;
        let mut b = stats_with(30, 4);
        b.mean_latency_us = 200.0;
        b.per_class[0].mean_latency_us = 200.0;
        b.n_items = 28;
        b.uptime_s = 9;
        b.scan.items_scanned = 7;
        let agg = aggregate_stats(&[a, b]).unwrap();
        assert_eq!(agg.requests, 40);
        assert_eq!(agg.errors, 2);
        assert_eq!(agg.n_items, 128);
        assert_eq!(agg.uptime_s, 9);
        assert_eq!(agg.scan.items_scanned, 14);
        // The merged histogram holds both shards' raw counts…
        assert_eq!(agg.latency_buckets, {
            let mut m = buckets_with(3, 10);
            m[4] = 30;
            m
        });
        // …and the percentiles are computed over the union: the 20th
        // of 40 observations lands in the 250µs bucket.
        assert_eq!(agg.p50_us, 250);
        assert_eq!(agg.p99_us, 250);
        assert_eq!(agg.per_class[0].p50_us, 250);
        // 10 × 100 + 30 × 200 over 40 requests.
        assert!((agg.mean_latency_us - 175.0).abs() < 1e-9);
        assert!((agg.per_class[0].mean_latency_us - 175.0).abs() < 1e-9);
        assert!(aggregate_stats(&[]).is_none());
    }

    #[test]
    fn exact_merge_beats_fleet_max_percentiles() {
        // 99 fast observations on one shard, 1 slow on another. The
        // old fleet-max rule would report p99 = 50 000 µs; the exact
        // merged distribution puts the 99th of 100 observations in the
        // 10 µs bucket.
        let a = stats_with(99, 0);
        let b = stats_with(1, 10);
        let agg = aggregate_stats(&[a, b]).unwrap();
        assert_eq!(agg.p99_us, 10);
        assert_eq!(agg.p50_us, 10);
    }

    #[test]
    fn one_shard_fleet_stats_are_identical_to_the_shard() {
        let mut a = stats_with(10, 3);
        a.scan.items_scanned = 42;
        let agg = aggregate_stats(&[a.clone()]).unwrap();
        assert_eq!(agg, a);
    }

    #[test]
    fn merge_buckets_is_associative_and_commutative_on_samples() {
        let a = buckets_with(0, 3);
        let b = buckets_with(4, 7);
        let c = buckets_with(11, 1);
        let ab_c = merge_buckets(
            [merge_buckets([a.as_slice(), b.as_slice()].into_iter()).as_slice(), c.as_slice()]
                .into_iter(),
        );
        let a_bc = merge_buckets(
            [a.as_slice(), merge_buckets([b.as_slice(), c.as_slice()].into_iter()).as_slice()]
                .into_iter(),
        );
        let ba = merge_buckets([b.as_slice(), a.as_slice()].into_iter());
        let ab = merge_buckets([a.as_slice(), b.as_slice()].into_iter());
        assert_eq!(ab_c, a_bc);
        assert_eq!(ab, ba);
    }
}
