//! Supervised shard connections: per-shard health state machine,
//! jittered exponential-backoff reconnects, half-open recovery, and
//! the one-retry-per-request policy.
//!
//! The machine (`docs/serving-topology.md` has the full diagram):
//!
//! ```text
//! Healthy --failure--> Degraded --N consecutive failures--> Down
//!    ^                     |                                  |
//!    +------success--------+        half-open probe succeeds  |
//!    +-----------------------------------------------------—-+
//! ```
//!
//! `Down` is a circuit breaker: requests skip the shard outright (it
//! is reported missing immediately, costing the query nothing) until a
//! jittered backoff deadline passes, at which point exactly one
//! request or background probe is allowed through as the *half-open*
//! trial. Success re-admits the shard; failure re-arms the breaker
//! with a longer deadline.

use std::time::{Duration, Instant};

use anyhow::Result;

use crate::core::rng::Rng;
use crate::net::client::{is_timeout_error, jittered_backoff, Client, ClientConfig};
use crate::net::protocol::{NetRequest, NetResponse};

use super::metrics::RouterMetrics;

/// Health-policy knobs shared by every shard connection.
#[derive(Debug, Clone, Copy)]
pub struct HealthConfig {
    /// TCP connect deadline per dial.
    pub connect_timeout: Duration,
    /// Read/write deadline per frame (a breach is a *timeout* failure,
    /// the retry for which counts as a hedge).
    pub io_timeout: Duration,
    /// Consecutive failures that open the breaker (`Down`).
    pub failures_to_down: u32,
    /// First half-open retry delay; doubles per failed trial.
    pub base_backoff: Duration,
    /// Half-open retry delay ceiling.
    pub max_backoff: Duration,
    /// Background probe cadence ([`super::RouterServer`]'s prober).
    pub probe_interval: Duration,
    /// Seed for the deterministic backoff jitter.
    pub jitter_seed: u64,
}

impl Default for HealthConfig {
    fn default() -> Self {
        HealthConfig {
            connect_timeout: Duration::from_secs(2),
            io_timeout: Duration::from_secs(10),
            failures_to_down: 2,
            base_backoff: Duration::from_millis(100),
            max_backoff: Duration::from_secs(5),
            probe_interval: Duration::from_millis(500),
            jitter_seed: 0xda7a_b0a7,
        }
    }
}

/// Rolling health of one shard, as exposed in the
/// `pqdtw_router_shard_health` gauge.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShardHealth {
    /// Last interaction succeeded.
    Healthy,
    /// At least one recent failure; still being tried on every request.
    Degraded,
    /// Breaker open: skipped until the half-open deadline.
    Down,
}

impl ShardHealth {
    /// Stable display name (log events, CLI output).
    pub fn name(self) -> &'static str {
        match self {
            ShardHealth::Healthy => "healthy",
            ShardHealth::Degraded => "degraded",
            ShardHealth::Down => "down",
        }
    }

    /// Gauge encoding: 0 healthy, 1 degraded, 2 down.
    pub fn as_gauge(self) -> f64 {
        match self {
            ShardHealth::Healthy => 0.0,
            ShardHealth::Degraded => 1.0,
            ShardHealth::Down => 2.0,
        }
    }
}

/// The pure state machine, separated from the socket so the
/// transition table is unit-testable without a network.
#[derive(Debug)]
pub(crate) struct HealthMachine {
    state: ShardHealth,
    consecutive_failures: u32,
    failures_to_down: u32,
    /// Failed half-open trials since the breaker opened (drives the
    /// backoff exponent).
    down_trials: u32,
}

impl HealthMachine {
    pub(crate) fn new(failures_to_down: u32) -> Self {
        HealthMachine {
            state: ShardHealth::Healthy,
            consecutive_failures: 0,
            failures_to_down: failures_to_down.max(1),
            down_trials: 0,
        }
    }

    pub(crate) fn state(&self) -> ShardHealth {
        self.state
    }

    /// Any successful interaction fully re-admits the shard.
    pub(crate) fn on_success(&mut self) -> ShardHealth {
        self.state = ShardHealth::Healthy;
        self.consecutive_failures = 0;
        self.down_trials = 0;
        self.state
    }

    /// One failed interaction; returns the new state and, when the
    /// breaker is (still) open, the backoff exponent for the next
    /// half-open deadline.
    pub(crate) fn on_failure(&mut self) -> (ShardHealth, Option<u32>) {
        self.consecutive_failures = self.consecutive_failures.saturating_add(1);
        if self.consecutive_failures >= self.failures_to_down {
            if self.state == ShardHealth::Down {
                self.down_trials = self.down_trials.saturating_add(1);
            }
            self.state = ShardHealth::Down;
            (self.state, Some(self.down_trials.saturating_add(1)))
        } else {
            self.state = ShardHealth::Degraded;
            (self.state, None)
        }
    }
}

/// How one scatter leg ended.
#[derive(Debug)]
pub enum ShardOutcome {
    /// A frame came back (possibly an application `Error` frame).
    Ok {
        /// The shard's reply.
        resp: NetResponse,
        /// True when the reply came from the single retry after a hard
        /// transport failure (surfaced as a `shard_rpc` trace
        /// annotation).
        retried: bool,
        /// True when the reply came from the single retry after a
        /// timeout — a hedge: the first attempt may still complete on
        /// the shard, but its reply is discarded.
        hedged: bool,
    },
    /// Breaker open and not yet due for a half-open trial; the shard
    /// was not contacted.
    Skipped,
    /// Transport failure after the retry budget (rendered message —
    /// `anyhow::Error` is not `Clone` and the scatter joins threads).
    Failed(String),
}

/// One supervised shard connection. All methods take `&mut self`; the
/// router wraps each in a `Mutex` and scatters with one thread per
/// shard.
pub struct ShardConn {
    shard_index: u64,
    addr: String,
    cfg: HealthConfig,
    client: Option<Client>,
    machine: HealthMachine,
    rng: Rng,
    /// Half-open deadline while the breaker is open.
    next_trial_at: Option<Instant>,
}

impl ShardConn {
    /// Supervision state for the shard at `addr` (dials lazily).
    pub fn new(shard_index: u64, addr: String, cfg: HealthConfig) -> ShardConn {
        // Distinct jitter stream per shard so breakers opened by one
        // outage do not retry in lockstep.
        let rng = Rng::new(cfg.jitter_seed ^ shard_index.wrapping_mul(0x9e37_79b9_7f4a_7c15));
        ShardConn {
            shard_index,
            addr,
            machine: HealthMachine::new(cfg.failures_to_down),
            cfg,
            client: None,
            rng,
            next_trial_at: None,
        }
    }

    /// This shard's address (metrics labels).
    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// Current health.
    pub fn health(&self) -> ShardHealth {
        self.machine.state()
    }

    fn client_config(&self) -> ClientConfig {
        ClientConfig {
            connect_timeout: self.cfg.connect_timeout,
            io_timeout: self.cfg.io_timeout,
        }
    }

    /// True while the breaker is open and the half-open deadline has
    /// not passed.
    fn breaker_blocks(&self, now: Instant) -> bool {
        self.machine.state() == ShardHealth::Down
            && self.next_trial_at.is_some_and(|at| now < at)
    }

    fn record_success(&mut self) {
        self.machine.on_success();
        self.next_trial_at = None;
    }

    fn record_failure(&mut self, now: Instant) {
        let (_, backoff_exp) = self.machine.on_failure();
        if let Some(exp) = backoff_exp {
            self.next_trial_at = Some(
                now + jittered_backoff(
                    self.cfg.base_backoff,
                    self.cfg.max_backoff,
                    exp,
                    &mut self.rng,
                ),
            );
        }
    }

    /// One dial + round trip, no policy.
    fn attempt(&mut self, req: &NetRequest) -> Result<NetResponse> {
        if self.client.as_ref().map_or(true, Client::is_poisoned) {
            self.client = Some(Client::connect(&self.addr, self.client_config())?);
        }
        match self.client.as_mut() {
            Some(client) => client.roundtrip(req),
            // Unreachable: assigned above. Degrade to an error rather
            // than panic in serving code.
            None => Err(anyhow::anyhow!("router: shard {} has no connection", self.shard_index)),
        }
    }

    /// One request under the full policy: breaker check, dial, round
    /// trip, and on transport failure one retry on a fresh connection
    /// (a hedge when the failure was a timeout — the old connection
    /// may still deliver a late reply, which poisoning discards).
    pub fn request(&mut self, req: &NetRequest, metrics: &RouterMetrics) -> ShardOutcome {
        let now = Instant::now();
        if self.breaker_blocks(now) {
            metrics.shard_skips.incr();
            return ShardOutcome::Skipped;
        }
        let first_err = match self.attempt(req) {
            Ok(resp) => {
                self.record_success();
                return ShardOutcome::Ok { resp, retried: false, hedged: false };
            }
            Err(e) => e,
        };
        metrics.shard_failures.incr();
        let hedged = is_timeout_error(&first_err);
        if hedged {
            metrics.hedges.incr();
        } else {
            metrics.retries.incr();
        }
        // The failed connection is gone either way; retry exactly once
        // on a fresh one. Queries are idempotent, so a duplicate
        // execution on the shard is harmless.
        self.client = None;
        match self.attempt(req) {
            Ok(resp) => {
                self.record_success();
                ShardOutcome::Ok { resp, retried: !hedged, hedged }
            }
            Err(retry_err) => {
                self.client = None;
                // Two strikes in one request: count both, so two failed
                // requests open a `failures_to_down = 4` breaker just
                // like four straight single failures would.
                self.record_failure(now);
                self.record_failure(now);
                metrics.shard_failures.incr();
                ShardOutcome::Failed(format!(
                    "shard {} at {}: {first_err:#}; retry: {retry_err:#}",
                    self.shard_index, self.addr
                ))
            }
        }
    }

    /// One background Ping under the breaker policy (the half-open
    /// trial for Down shards); returns the post-probe health.
    pub fn probe(&mut self, metrics: &RouterMetrics) -> ShardHealth {
        let now = Instant::now();
        if self.breaker_blocks(now) {
            return self.health();
        }
        metrics.probes.incr();
        match self.attempt(&NetRequest::Ping) {
            Ok(NetResponse::Pong) => self.record_success(),
            Ok(_) | Err(_) => {
                metrics.probe_failures.incr();
                self.client = None;
                self.record_failure(now);
            }
        }
        self.health()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Read;
    use std::net::TcpListener;

    #[test]
    fn machine_walks_healthy_degraded_down_and_back() {
        let mut m = HealthMachine::new(2);
        assert_eq!(m.state(), ShardHealth::Healthy);
        let (s, exp) = m.on_failure();
        assert_eq!(s, ShardHealth::Degraded);
        assert!(exp.is_none());
        let (s, exp) = m.on_failure();
        assert_eq!(s, ShardHealth::Down);
        assert_eq!(exp, Some(1));
        // Failed half-open trials stretch the backoff exponent.
        let (s, exp) = m.on_failure();
        assert_eq!(s, ShardHealth::Down);
        assert_eq!(exp, Some(2));
        assert_eq!(m.on_success(), ShardHealth::Healthy);
        // Recovery resets the failure count: one new failure is
        // Degraded again, not Down.
        let (s, _) = m.on_failure();
        assert_eq!(s, ShardHealth::Degraded);
    }

    #[test]
    fn machine_with_threshold_one_skips_degraded() {
        let mut m = HealthMachine::new(1);
        let (s, exp) = m.on_failure();
        assert_eq!(s, ShardHealth::Down);
        assert_eq!(exp, Some(1));
    }

    fn test_cfg() -> HealthConfig {
        HealthConfig {
            connect_timeout: Duration::from_millis(300),
            io_timeout: Duration::from_millis(300),
            failures_to_down: 2,
            base_backoff: Duration::from_millis(30),
            max_backoff: Duration::from_millis(120),
            probe_interval: Duration::from_millis(50),
            jitter_seed: 7,
        }
    }

    #[test]
    fn unreachable_shard_opens_the_breaker_then_skips() {
        // Bind-then-drop yields a port with nothing listening.
        let addr = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        let metrics = RouterMetrics::new();
        let mut conn = ShardConn::new(0, addr, test_cfg());
        // One request = two failed attempts = breaker open.
        match conn.request(&NetRequest::Ping, &metrics) {
            ShardOutcome::Failed(msg) => assert!(msg.contains("shard 0"), "{msg}"),
            other => panic!("expected Failed, got {other:?}"),
        }
        assert_eq!(conn.health(), ShardHealth::Down);
        // Immediately after opening, the half-open deadline blocks.
        assert!(matches!(
            conn.request(&NetRequest::Ping, &metrics),
            ShardOutcome::Skipped
        ));
        assert_eq!(metrics.shard_skips.get(), 1);
        assert!(metrics.shard_failures.get() >= 2);
    }

    #[test]
    fn half_open_probe_readmits_a_recovered_shard() {
        let addr = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        let metrics = RouterMetrics::new();
        let mut conn = ShardConn::new(1, addr.clone(), test_cfg());
        let _ = conn.request(&NetRequest::Ping, &metrics);
        assert_eq!(conn.health(), ShardHealth::Down);

        // "Restart" the shard: a tiny Ping-answering server on the
        // same port the breaker remembers.
        let listener = TcpListener::bind(&addr).unwrap();
        let server = std::thread::spawn(move || {
            if let Ok((mut stream, _)) = listener.accept() {
                let frame = crate::net::protocol::read_frame(
                    &mut stream,
                    crate::net::protocol::MAX_FRAME_BYTES,
                );
                if let Ok(Some((tag, _))) = frame {
                    assert_eq!(tag, crate::net::protocol::TAG_PING);
                }
                let reply = crate::net::protocol::encode_response(&NetResponse::Pong);
                let _ = crate::net::protocol::write_frame(&mut stream, &reply);
                // Hold the connection until the client is done.
                let mut scratch = [0u8; 16];
                let _ = stream.read(&mut scratch);
            }
        });
        // Wait out the half-open deadline, then probe until re-admitted
        // (the first due probe should do it).
        let mut state = conn.health();
        for _ in 0..50 {
            std::thread::sleep(Duration::from_millis(20));
            state = conn.probe(&metrics);
            if state == ShardHealth::Healthy {
                break;
            }
        }
        assert_eq!(state, ShardHealth::Healthy);
        drop(conn);
        server.join().unwrap();
    }
}
