//! `FaultPlan` test harness: a TCP proxy that sits between the router
//! and one shard and injects the failure modes the robustness tests
//! need — delay, black-hole (accept but never answer), truncation
//! (sever mid-frame), and mid-request connection kills.
//!
//! The proxy shapes only the upstream→client direction (the shard's
//! responses); requests pass through untouched, so a shaped shard
//! still *executes* queries — exactly the "slow or dying, not
//! cleanly absent" behavior that distinguishes a timeout from a
//! refused connect. Lives in the library (not `#[cfg(test)]`) so the
//! loopback integration tests and the CI smoke can drive it; the
//! serving-plane lints apply to it like any router code, so it is
//! panic-free by construction.

use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{Context, Result};

use super::lock_unpoisoned;

/// What the proxy does to each chunk of shard→router traffic.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultMode {
    /// Relay faithfully.
    Pass,
    /// Relay after sleeping this long per chunk (a slow shard; the
    /// router's read deadline turns this into a timeout failure).
    Delay(Duration),
    /// Swallow response bytes entirely (a hung shard: the connection
    /// stays open, the router's read times out).
    BlackHole,
    /// Relay this many more bytes per connection, then sever both
    /// sides (a torn frame: the router sees a decode-level transport
    /// error, not a timeout).
    CloseAfter(usize),
}

struct ProxyShared {
    upstream: String,
    mode: Mutex<FaultMode>,
    stop: AtomicBool,
    next_conn: AtomicU64,
    /// Client/upstream stream clones per live relay pair, severable
    /// from outside for the mid-request kill.
    conns: Mutex<HashMap<u64, (TcpStream, TcpStream)>>,
    threads: Mutex<Vec<JoinHandle<()>>>,
}

impl ProxyShared {
    fn sever_all(&self) {
        for (client, upstream) in lock_unpoisoned(&self.conns).values() {
            let _ = client.shutdown(Shutdown::Both);
            let _ = upstream.shutdown(Shutdown::Both);
        }
    }
}

/// A running fault-injection proxy for one shard.
pub struct FaultProxy {
    shared: Arc<ProxyShared>,
    local_addr: SocketAddr,
    accept_thread: Option<JoinHandle<()>>,
}

impl FaultProxy {
    /// Listen on an ephemeral loopback port and forward to `upstream`.
    pub fn start(upstream: &str) -> Result<FaultProxy> {
        let listener =
            TcpListener::bind("127.0.0.1:0").context("fault proxy: binding listener")?;
        let local_addr = listener.local_addr().context("fault proxy: reading bound address")?;
        let shared = Arc::new(ProxyShared {
            upstream: upstream.to_string(),
            mode: Mutex::new(FaultMode::Pass),
            stop: AtomicBool::new(false),
            next_conn: AtomicU64::new(0),
            conns: Mutex::new(HashMap::new()),
            threads: Mutex::new(Vec::new()),
        });
        let accept_shared = Arc::clone(&shared);
        let accept_thread = std::thread::spawn(move || accept_loop(listener, accept_shared));
        Ok(FaultProxy { shared, local_addr, accept_thread: Some(accept_thread) })
    }

    /// The address the router should use as this shard's address.
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Switch the failure mode; applies to in-flight and future
    /// connections at their next relayed chunk.
    pub fn set_mode(&self, mode: FaultMode) {
        *lock_unpoisoned(&self.shared.mode) = mode;
    }

    /// Sever every live relay right now (the "shard killed
    /// mid-request" injection). New connections still accept.
    pub fn kill_connections(&self) {
        self.shared.sever_all();
    }

    /// Stop accepting, sever everything, join relay threads. After
    /// this the port refuses connects — the "shard process gone" state.
    pub fn stop(mut self) {
        self.halt();
    }

    fn halt(&mut self) {
        if self.shared.stop.swap(true, Ordering::SeqCst) {
            return;
        }
        // Unblock the accept loop with a throwaway self-connect.
        let _ = TcpStream::connect_timeout(&self.local_addr, Duration::from_secs(1));
        self.shared.sever_all();
        if let Some(h) = self.accept_thread.take() {
            let _ = h.join();
        }
        let handles: Vec<JoinHandle<()>> =
            lock_unpoisoned(&self.shared.threads).drain(..).collect();
        for h in handles {
            let _ = h.join();
        }
    }
}

impl Drop for FaultProxy {
    fn drop(&mut self) {
        self.halt();
    }
}

fn accept_loop(listener: TcpListener, shared: Arc<ProxyShared>) {
    for stream in listener.incoming() {
        if shared.stop.load(Ordering::SeqCst) {
            break;
        }
        let client = match stream {
            Ok(s) => s,
            Err(_) => {
                std::thread::sleep(Duration::from_millis(10));
                continue;
            }
        };
        let _ = client.set_nodelay(true);
        let upstream = match TcpStream::connect(&shared.upstream) {
            Ok(s) => s,
            Err(_) => {
                // Upstream gone: refuse by closing, like a dead shard.
                let _ = client.shutdown(Shutdown::Both);
                continue;
            }
        };
        let _ = upstream.set_nodelay(true);
        let id = shared.next_conn.fetch_add(1, Ordering::SeqCst);
        if let (Ok(c), Ok(u)) = (client.try_clone(), upstream.try_clone()) {
            lock_unpoisoned(&shared.conns).insert(id, (c, u));
        }
        let (c2u_from, c2u_to) = (client.try_clone(), upstream.try_clone());
        let shaped_shared = Arc::clone(&shared);
        let plain_shared = Arc::clone(&shared);
        let mut threads = lock_unpoisoned(&shared.threads);
        threads.retain(|t| !t.is_finished());
        // Requests pass through unshaped…
        if let (Ok(from), Ok(to)) = (c2u_from, c2u_to) {
            threads.push(std::thread::spawn(move || {
                relay(from, to, plain_shared, false, id)
            }));
        }
        // …responses are shaped by the current mode.
        threads.push(std::thread::spawn(move || {
            relay(upstream, client, shaped_shared, true, id)
        }));
    }
}

/// Pump bytes `from` → `to`, applying the fault mode when `shaped`.
/// Ends on EOF, error, or a severed stream; the conn registry entry is
/// dropped by whichever direction finishes last.
fn relay(mut from: TcpStream, mut to: TcpStream, shared: Arc<ProxyShared>, shaped: bool, id: u64) {
    let mut buf = [0u8; 8192];
    let mut close_budget: Option<usize> = None;
    loop {
        let n = match from.read(&mut buf) {
            Ok(0) | Err(_) => break,
            Ok(n) => n,
        };
        let mode = if shaped { *lock_unpoisoned(&shared.mode) } else { FaultMode::Pass };
        match mode {
            FaultMode::Pass => {
                if to.write_all(&buf[..n]).is_err() {
                    break;
                }
            }
            FaultMode::Delay(d) => {
                std::thread::sleep(d);
                if to.write_all(&buf[..n]).is_err() {
                    break;
                }
            }
            FaultMode::BlackHole => {
                // Swallow; keep reading so the upstream is not
                // backpressured into noticing.
            }
            FaultMode::CloseAfter(limit) => {
                let budget = close_budget.get_or_insert(limit);
                let send = n.min(*budget);
                if send > 0 && to.write_all(&buf[..send]).is_err() {
                    break;
                }
                *budget -= send;
                if *budget == 0 {
                    let _ = to.shutdown(Shutdown::Both);
                    let _ = from.shutdown(Shutdown::Both);
                    break;
                }
            }
        }
    }
    // Propagate the close: without this the other side would block on
    // a half-dead pair forever.
    let _ = to.shutdown(Shutdown::Both);
    let _ = from.shutdown(Shutdown::Both);
    lock_unpoisoned(&shared.conns).remove(&id);
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Echo server: accepts one connection, echoes bytes back.
    fn echo_server() -> (SocketAddr, JoinHandle<()>) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let handle = std::thread::spawn(move || {
            while let Ok((mut stream, _)) = listener.accept() {
                let mut buf = [0u8; 1024];
                loop {
                    match stream.read(&mut buf) {
                        Ok(0) | Err(_) => break,
                        Ok(n) => {
                            if stream.write_all(&buf[..n]).is_err() {
                                break;
                            }
                        }
                    }
                }
                // One connection per test is enough.
                break;
            }
        });
        (addr, handle)
    }

    #[test]
    fn pass_mode_relays_both_directions() {
        let (addr, server) = echo_server();
        let proxy = FaultProxy::start(&addr.to_string()).unwrap();
        let mut client = TcpStream::connect(proxy.local_addr()).unwrap();
        client.write_all(b"hello").unwrap();
        let mut back = [0u8; 5];
        client.read_exact(&mut back).unwrap();
        assert_eq!(&back, b"hello");
        drop(client);
        proxy.stop();
        server.join().unwrap();
    }

    #[test]
    fn black_hole_swallows_responses_and_close_after_truncates() {
        let (addr, server) = echo_server();
        let proxy = FaultProxy::start(&addr.to_string()).unwrap();
        proxy.set_mode(FaultMode::BlackHole);
        let mut client = TcpStream::connect(proxy.local_addr()).unwrap();
        client.set_read_timeout(Some(Duration::from_millis(200))).unwrap();
        client.write_all(b"swallowed").unwrap();
        let mut buf = [0u8; 16];
        // The echo never arrives: the read must time out.
        assert!(client.read(&mut buf).is_err());

        // Same connection, now truncating: 3 bytes arrive, then EOF.
        proxy.set_mode(FaultMode::CloseAfter(3));
        client.set_read_timeout(Some(Duration::from_secs(2))).unwrap();
        client.write_all(b"truncated").unwrap();
        let mut got = Vec::new();
        loop {
            match client.read(&mut buf) {
                Ok(0) | Err(_) => break,
                Ok(n) => got.extend_from_slice(&buf[..n]),
            }
        }
        assert_eq!(got, b"tru");
        drop(client);
        proxy.stop();
        server.join().unwrap();
    }

    #[test]
    fn kill_connections_severs_mid_stream() {
        let (addr, server) = echo_server();
        let proxy = FaultProxy::start(&addr.to_string()).unwrap();
        let mut client = TcpStream::connect(proxy.local_addr()).unwrap();
        client.write_all(b"ping").unwrap();
        let mut back = [0u8; 4];
        client.read_exact(&mut back).unwrap();
        proxy.kill_connections();
        client.set_read_timeout(Some(Duration::from_secs(2))).unwrap();
        // The severed relay surfaces as EOF or reset, never a hang.
        let mut buf = [0u8; 4];
        match client.read(&mut buf) {
            Ok(0) | Err(_) => {}
            Ok(n) => panic!("expected severed stream, read {n} bytes"),
        }
        drop(client);
        proxy.stop();
        server.join().unwrap();
    }
}
