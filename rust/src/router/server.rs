//! The router's TCP front end: the same wire protocol as a shard
//! server, so existing clients (`query --connect`, `stats --connect`)
//! point at a router unchanged; plus the background prober thread that
//! drives half-open recovery while no queries are flowing.
//!
//! Unlike [`crate::net::server::NetServer`] there is no service or
//! batcher behind this listener — every reply is produced inline by
//! [`Router::dispatch`], whose scatter threads do the waiting — so a
//! connection is one thread doing strict read/dispatch/write
//! alternation, and per-connection ordering is trivial.
//!
//! Shutdown semantics: a `Shutdown` frame stops the *router only*.
//! Shard servers keep running and must be drained individually — the
//! router does not own their lifecycle (`docs/serving-topology.md`).

use std::collections::HashMap;
use std::net::{IpAddr, Ipv4Addr, Ipv6Addr, Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, PoisonError};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::net::protocol::{self, NetRequest, NetResponse};
use crate::obs::log::JsonLogger;

use super::{lock_unpoisoned, Router, RouterConfig};

/// Listener-side limits (the scatter policy lives in [`RouterConfig`]).
#[derive(Debug, Clone, Copy)]
pub struct RouterServerConfig {
    /// Maximum concurrent client connections.
    pub max_connections: usize,
    /// Per-frame payload ceiling for incoming requests.
    pub max_frame_bytes: usize,
    /// Write timeout per response frame.
    pub write_timeout: Duration,
}

impl Default for RouterServerConfig {
    fn default() -> Self {
        RouterServerConfig {
            max_connections: 64,
            max_frame_bytes: protocol::MAX_FRAME_BYTES,
            write_timeout: Duration::from_secs(30),
        }
    }
}

struct Shared {
    router: Router,
    cfg: RouterServerConfig,
    logger: Arc<JsonLogger>,
    local_addr: SocketAddr,
    stop: AtomicBool,
    active: AtomicUsize,
    next_conn: AtomicU64,
    conns: Mutex<HashMap<u64, TcpStream>>,
    conn_threads: Mutex<Vec<JoinHandle<()>>>,
    done: (Mutex<bool>, Condvar),
}

impl Shared {
    /// Begin the drain exactly once (same shape as the net server):
    /// stop accepting, wake the accept loop, half-close connections,
    /// wake the prober, release [`RouterServer::wait`].
    fn trigger(&self) {
        if self.stop.swap(true, Ordering::SeqCst) {
            return;
        }
        let mut wake = self.local_addr;
        if wake.ip().is_unspecified() {
            wake.set_ip(match wake.ip() {
                IpAddr::V4(_) => IpAddr::V4(Ipv4Addr::LOCALHOST),
                IpAddr::V6(_) => IpAddr::V6(Ipv6Addr::LOCALHOST),
            });
        }
        let _ = TcpStream::connect_timeout(&wake, Duration::from_secs(1));
        for stream in lock_unpoisoned(&self.conns).values() {
            let _ = stream.shutdown(Shutdown::Read);
        }
        let (lock, cv) = &self.done;
        *lock_unpoisoned(lock) = true;
        cv.notify_all();
    }
}

/// Final counter totals [`RouterServer::wait`] hands back for the
/// CLI's shutdown summary line.
#[derive(Debug, Clone, Copy)]
pub struct RouterRunSummary {
    /// Client requests answered.
    pub requests: u64,
    /// Requests answered with an `Error` frame.
    pub errors: u64,
    /// Responses flagged degraded.
    pub degraded_responses: u64,
    /// Hard-failure retries.
    pub retries: u64,
    /// Timeout-driven retries.
    pub hedges: u64,
}

/// A running scatter-gather router. Dropping it (or calling
/// [`RouterServer::shutdown`]) drains connections, stops the prober,
/// and joins every thread.
pub struct RouterServer {
    shared: Arc<Shared>,
    accept_thread: Option<JoinHandle<()>>,
    probe_thread: Option<JoinHandle<()>>,
}

impl RouterServer {
    /// Bind `addr` and start routing over `cfg.shards`.
    pub fn start(addr: &str, cfg: RouterConfig, srv: RouterServerConfig) -> Result<RouterServer> {
        RouterServer::start_logged(addr, cfg, srv, Arc::new(JsonLogger::disabled()))
    }

    /// [`RouterServer::start`] with a structured event logger
    /// (`serve --router --log-json`).
    pub fn start_logged(
        addr: &str,
        cfg: RouterConfig,
        srv: RouterServerConfig,
        logger: Arc<JsonLogger>,
    ) -> Result<RouterServer> {
        let probe_interval = cfg.health.probe_interval;
        let router = Router::new(cfg, Arc::clone(&logger))?;
        let listener =
            TcpListener::bind(addr).with_context(|| format!("router: binding {addr}"))?;
        let local_addr = listener.local_addr().context("router: reading bound address")?;
        logger.event(
            "router_start",
            &[
                ("addr", local_addr.to_string().into()),
                ("shards", (router.n_shards() as u64).into()),
            ],
        );
        let shared = Arc::new(Shared {
            router,
            cfg: srv,
            logger,
            local_addr,
            stop: AtomicBool::new(false),
            active: AtomicUsize::new(0),
            next_conn: AtomicU64::new(0),
            conns: Mutex::new(HashMap::new()),
            conn_threads: Mutex::new(Vec::new()),
            done: (Mutex::new(false), Condvar::new()),
        });
        let accept_shared = Arc::clone(&shared);
        let accept_thread = std::thread::spawn(move || accept_loop(listener, accept_shared));
        let probe_shared = Arc::clone(&shared);
        let probe_thread =
            std::thread::spawn(move || probe_loop(probe_shared, probe_interval));
        Ok(RouterServer {
            shared,
            accept_thread: Some(accept_thread),
            probe_thread: Some(probe_thread),
        })
    }

    /// The address the router actually bound (resolves `:0` ports).
    pub fn local_addr(&self) -> SocketAddr {
        self.shared.local_addr
    }

    /// The scatter core (tests inspect health and metrics through it).
    pub fn router(&self) -> &Router {
        &self.shared.router
    }

    /// Scrape-endpoint bodies backed by this router, for
    /// `serve --router --metrics-listen`: `/metrics` renders the full
    /// router exposition, `/healthz` the per-shard breaker-state JSON.
    /// The closures hold the router alive independently of `self`.
    pub fn http_endpoints(&self) -> crate::net::http::HttpEndpoints {
        let metrics = Arc::clone(&self.shared);
        let healthz = Arc::clone(&self.shared);
        crate::net::http::HttpEndpoints {
            metrics: Arc::new(move || metrics.router.prometheus_text()),
            healthz: Arc::new(move || healthz.router.healthz_json()),
        }
    }

    /// Block until a client's `Shutdown` frame stops the router, then
    /// drain, join every thread, and report the final counter totals.
    pub fn wait(mut self) -> RouterRunSummary {
        {
            let (lock, cv) = &self.shared.done;
            let mut done = lock_unpoisoned(lock);
            while !*done {
                done = cv.wait(done).unwrap_or_else(PoisonError::into_inner);
            }
        }
        self.finish();
        let m = self.shared.router.metrics();
        RouterRunSummary {
            requests: m.requests.get(),
            errors: m.errors.get(),
            degraded_responses: m.degraded_responses.get(),
            retries: m.retries.get(),
            hedges: m.hedges.get(),
        }
    }

    /// Stop the router from this side.
    pub fn shutdown(mut self) {
        self.shared.trigger();
        self.finish();
    }

    fn finish(&mut self) {
        if let Some(h) = self.accept_thread.take() {
            let _ = h.join();
        }
        if let Some(h) = self.probe_thread.take() {
            let _ = h.join();
        }
        let handles: Vec<JoinHandle<()>> =
            lock_unpoisoned(&self.shared.conn_threads).drain(..).collect();
        for h in handles {
            let _ = h.join();
        }
    }
}

impl Drop for RouterServer {
    fn drop(&mut self) {
        self.shared.trigger();
        self.finish();
    }
}

/// Background prober: probes every shard on the configured cadence
/// (half-open trials for Down shards, liveness checks otherwise),
/// sleeping on the done condvar so shutdown interrupts it promptly.
fn probe_loop(shared: Arc<Shared>, interval: Duration) {
    let interval = interval.max(Duration::from_millis(10));
    loop {
        {
            let (lock, cv) = &shared.done;
            let done = lock_unpoisoned(lock);
            // A spurious wakeup just probes early; that is harmless.
            let (done, _) =
                cv.wait_timeout(done, interval).unwrap_or_else(PoisonError::into_inner);
            if *done {
                return;
            }
        }
        shared.router.probe_all();
    }
}

fn accept_loop(listener: TcpListener, shared: Arc<Shared>) {
    for stream in listener.incoming() {
        if shared.stop.load(Ordering::SeqCst) {
            break;
        }
        let stream = match stream {
            Ok(s) => s,
            Err(_) => {
                std::thread::sleep(Duration::from_millis(20));
                continue;
            }
        };
        let _ = stream.set_nodelay(true);
        let _ = stream.set_write_timeout(Some(shared.cfg.write_timeout));
        if shared.active.load(Ordering::SeqCst) >= shared.cfg.max_connections {
            let mut stream = stream;
            let frame = protocol::encode_response(&NetResponse::Error(format!(
                "router at its {}-connection capacity",
                shared.cfg.max_connections
            )));
            let _ = protocol::write_frame(&mut stream, &frame);
            let _ = stream.shutdown(Shutdown::Both);
            continue;
        }
        shared.active.fetch_add(1, Ordering::SeqCst);
        let id = shared.next_conn.fetch_add(1, Ordering::SeqCst);
        if shared.logger.is_enabled() {
            let peer = stream
                .peer_addr()
                .map(|a| a.to_string())
                .unwrap_or_else(|_| "unknown".into());
            shared
                .logger
                .event("conn_open", &[("conn", id.into()), ("peer", peer.into())]);
        }
        {
            // Same registration race discipline as the net server: a
            // concurrent trigger either sees this connection or its
            // stop store is visible here.
            let mut conns = lock_unpoisoned(&shared.conns);
            if let Ok(clone) = stream.try_clone() {
                conns.insert(id, clone);
            }
            if shared.stop.load(Ordering::SeqCst) {
                let _ = stream.shutdown(Shutdown::Read);
            }
        }
        let conn_shared = Arc::clone(&shared);
        let handle = std::thread::spawn(move || handle_connection(stream, id, conn_shared));
        let mut threads = lock_unpoisoned(&shared.conn_threads);
        threads.retain(|t| !t.is_finished());
        threads.push(handle);
    }
}

fn handle_connection(stream: TcpStream, id: u64, shared: Arc<Shared>) {
    let saw_shutdown = serve_connection(&stream, &shared);
    lock_unpoisoned(&shared.conns).remove(&id);
    shared.logger.event("conn_close", &[("conn", id.into())]);
    shared.active.fetch_sub(1, Ordering::SeqCst);
    let _ = stream.shutdown(Shutdown::Both);
    if saw_shutdown {
        shared.trigger();
    }
}

/// One connection: read a frame, dispatch through the router, write
/// the reply, repeat. Returns whether a `Shutdown` frame was served.
fn serve_connection(stream: &TcpStream, shared: &Shared) -> bool {
    let mut reader = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return false,
    };
    let mut writer = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return false,
    };
    loop {
        match protocol::read_frame(&mut reader, shared.cfg.max_frame_bytes) {
            Ok(None) => return false,
            Ok(Some((tag, payload))) => match protocol::decode_request(tag, &payload) {
                Ok(req) => {
                    let is_shutdown = matches!(req, NetRequest::Shutdown);
                    let resp = shared.router.dispatch(req);
                    let frame = protocol::encode_response(&resp);
                    if protocol::write_frame(&mut writer, &frame).is_err() || is_shutdown {
                        return is_shutdown;
                    }
                }
                Err(e) => {
                    // Payload fully read: the stream is still on a
                    // frame boundary; answer and keep serving.
                    shared
                        .logger
                        .event("bad_request", &[("error", format!("{e:#}").into())]);
                    let frame =
                        protocol::encode_response(&NetResponse::Error(format!("{e:#}")));
                    if protocol::write_frame(&mut writer, &frame).is_err() {
                        return false;
                    }
                }
            },
            Err(e) => {
                // Torn header or over-limit length: best-effort error
                // frame, then drop the connection.
                shared
                    .logger
                    .event("frame_error", &[("error", format!("{e:#}").into())]);
                let frame = protocol::encode_response(&NetResponse::Error(format!("{e:#}")));
                let _ = protocol::write_frame(&mut writer, &frame);
                return false;
            }
        }
    }
}
