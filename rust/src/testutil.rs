//! Seeded property-testing harness (the offline registry carries no
//! `proptest`, so the integration suite uses this instead).
//!
//! [`check`] runs a property over `n` generated cases and reports the
//! seed of the first failing case, so failures reproduce exactly:
//! `PQDTW_PROP_SEED=<seed> cargo test <name>`.

use crate::core::rng::Rng;

/// Number of cases per property (overridable via `PQDTW_PROP_CASES`).
pub fn default_cases() -> usize {
    std::env::var("PQDTW_PROP_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(64)
}

/// Run `prop` over `cases` seeded inputs. Each case gets an independent
/// [`Rng`]; a returned `Err(msg)` fails the property with the seed.
pub fn check<F>(name: &str, cases: usize, mut prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    let base: u64 = std::env::var("PQDTW_PROP_SEED")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0x5EED_0001);
    for case in 0..cases {
        let seed = base.wrapping_add(case as u64);
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property '{name}' failed at seed {seed} (case {case}): {msg}");
        }
    }
}

/// Generator: random series of length `n` (iid standard normal).
pub fn gen_series(rng: &mut Rng, n: usize) -> Vec<f64> {
    (0..n).map(|_| rng.normal()).collect()
}

/// Generator: random walk of length `n` (integrated normal steps).
pub fn gen_walk(rng: &mut Rng, n: usize) -> Vec<f64> {
    let mut acc = 0.0;
    (0..n)
        .map(|_| {
            acc += rng.normal();
            acc
        })
        .collect()
}

/// Generator: random length in `[lo, hi]`.
pub fn gen_len(rng: &mut Rng, lo: usize, hi: usize) -> usize {
    lo + rng.below(hi - lo + 1)
}

/// A fresh, collision-free temp directory for one test: pid plus a
/// per-process atomic counter, so tests running concurrently inside one
/// test binary (or across binaries) can never clobber each other's
/// files. The directory is created before returning; callers that care
/// about cleanup remove it themselves.
pub fn unique_temp_dir(tag: &str) -> std::path::PathBuf {
    use std::sync::atomic::{AtomicUsize, Ordering};
    static NEXT: AtomicUsize = AtomicUsize::new(0);
    let n = NEXT.fetch_add(1, Ordering::Relaxed);
    let dir = std::env::temp_dir().join(format!("pqdtw_{tag}_{}_{n}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("creating unique temp dir");
    dir
}

/// Assertion helper: `a ≈ b` within `tol`.
pub fn close(a: f64, b: f64, tol: f64) -> Result<(), String> {
    if (a - b).abs() <= tol {
        Ok(())
    } else {
        Err(format!("{a} !≈ {b} (tol {tol})"))
    }
}

/// Assertion helper: `a ≤ b + tol`.
pub fn leq(a: f64, b: f64, tol: f64) -> Result<(), String> {
    if a <= b + tol {
        Ok(())
    } else {
        Err(format!("{a} !<= {b} (tol {tol})"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_trivial_property() {
        check("trivial", 10, |rng| {
            let x = rng.uniform();
            if (0.0..1.0).contains(&x) { Ok(()) } else { Err(format!("{x}")) }
        });
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn check_reports_failure_with_seed() {
        check("fails", 5, |_| Err("always".into()));
    }

    #[test]
    fn unique_temp_dirs_do_not_collide() {
        let a = unique_temp_dir("selftest");
        let b = unique_temp_dir("selftest");
        assert_ne!(a, b);
        assert!(a.is_dir() && b.is_dir());
        std::fs::remove_dir_all(&a).ok();
        std::fs::remove_dir_all(&b).ok();
    }

    #[test]
    fn generators_shapes() {
        let mut rng = Rng::new(1);
        assert_eq!(gen_series(&mut rng, 17).len(), 17);
        assert_eq!(gen_walk(&mut rng, 9).len(), 9);
        let l = gen_len(&mut rng, 5, 10);
        assert!((5..=10).contains(&l));
    }
}
