//! Piecewise Aggregate Approximation (Keogh et al. 2001).
//!
//! PAA reduces a length-`n` series to `m` segment means. It underlies SAX
//! and is a baseline dimensionality reduction in its own right. Handles
//! `n % m != 0` with fractional segment boundaries (each sample's weight
//! is split proportionally across the segments it overlaps).

/// PAA of `xs` with `m` segments.
pub fn paa(xs: &[f64], m: usize) -> Vec<f64> {
    let n = xs.len();
    assert!(m > 0 && n > 0, "paa: empty input");
    if m >= n {
        return xs.to_vec();
    }
    if n % m == 0 {
        let w = n / m;
        return xs.chunks_exact(w).map(|c| c.iter().sum::<f64>() / w as f64).collect();
    }
    // Fractional boundaries: segment k covers [k*n/m, (k+1)*n/m).
    let mut out = vec![0.0; m];
    let seg_len = n as f64 / m as f64;
    for (k, o) in out.iter_mut().enumerate() {
        let start = k as f64 * seg_len;
        let end = start + seg_len;
        let mut acc = 0.0;
        let mut i = start.floor() as usize;
        while (i as f64) < end && i < n {
            let lo = (i as f64).max(start);
            let hi = ((i + 1) as f64).min(end);
            acc += xs[i] * (hi - lo);
            i += 1;
        }
        *o = acc / seg_len;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_division() {
        let v = [1.0, 3.0, 5.0, 7.0, 2.0, 4.0];
        assert_eq!(paa(&v, 3), vec![2.0, 6.0, 3.0]);
    }

    #[test]
    fn identity_when_m_ge_n() {
        let v = [1.0, 2.0, 3.0];
        assert_eq!(paa(&v, 3), v.to_vec());
        assert_eq!(paa(&v, 5), v.to_vec());
    }

    #[test]
    fn fractional_boundaries_preserve_mean() {
        // Total weighted mass must equal the series mean regardless of m.
        let v: Vec<f64> = (0..7).map(|i| i as f64 * 1.3 - 2.0).collect();
        let mean = v.iter().sum::<f64>() / v.len() as f64;
        for m in [2, 3, 4, 5] {
            let p = paa(&v, m);
            let pm = p.iter().sum::<f64>() / m as f64;
            assert!((pm - mean).abs() < 1e-9, "m={m}");
        }
    }

    #[test]
    fn constant_series() {
        let v = [4.2; 10];
        for m in [1, 2, 3, 7] {
            assert!(paa(&v, m).iter().all(|&x| (x - 4.2).abs() < 1e-12));
        }
    }
}
