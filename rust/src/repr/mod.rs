//! Baseline segment/symbolic representations: PAA and SAX.

pub mod paa;
pub mod sax;

pub use paa::paa;
pub use sax::SaxEncoder;
