//! Symbolic Aggregate approXimation (Lin et al. 2007).
//!
//! SAX converts a z-normalized series to a word over an alphabet of size
//! `α` by (1) PAA-reducing it to `m` segments and (2) discretizing each
//! segment mean with Gaussian-equiprobable breakpoints. Distances between
//! words use MINDIST, which lower-bounds the Euclidean distance on the
//! original series.
//!
//! The paper's settings: `α = 4`, segment length `l = 0.2·L`, i.e. `m = 5`
//! segments for any series length.

use super::paa::paa;

/// Gaussian equiprobable breakpoints for alphabet sizes 2..=10 (standard
/// SAX table; values are Φ⁻¹(k/α)).
fn breakpoints(alpha: usize) -> Vec<f64> {
    match alpha {
        2 => vec![0.0],
        3 => vec![-0.43, 0.43],
        4 => vec![-0.67, 0.0, 0.67],
        5 => vec![-0.84, -0.25, 0.25, 0.84],
        6 => vec![-0.97, -0.43, 0.0, 0.43, 0.97],
        7 => vec![-1.07, -0.57, -0.18, 0.18, 0.57, 1.07],
        8 => vec![-1.15, -0.67, -0.32, 0.0, 0.32, 0.67, 1.15],
        9 => vec![-1.22, -0.76, -0.43, -0.14, 0.14, 0.43, 0.76, 1.22],
        10 => vec![-1.28, -0.84, -0.52, -0.25, 0.0, 0.25, 0.52, 0.84, 1.28],
        _ => panic!("SAX alphabet size {alpha} unsupported (2..=10)"),
    }
}

/// A SAX encoder for series of a fixed length.
#[derive(Debug, Clone)]
pub struct SaxEncoder {
    /// Original series length.
    pub series_len: usize,
    /// Alphabet size α.
    pub alphabet: usize,
    /// Number of PAA segments.
    pub n_segments: usize,
    betas: Vec<f64>,
}

impl SaxEncoder {
    /// Encoder for series of `series_len`, alphabet `alphabet`, segment
    /// length `seg_frac · series_len` (the paper uses `seg_frac = 0.2`).
    pub fn new(series_len: usize, alphabet: usize, seg_frac: f64) -> Self {
        assert!(series_len > 0);
        assert!(seg_frac > 0.0 && seg_frac <= 1.0);
        let n_segments = ((1.0 / seg_frac).round() as usize).clamp(1, series_len);
        SaxEncoder { series_len, alphabet, n_segments, betas: breakpoints(alphabet) }
    }

    /// Encode a (z-normalized) series into a SAX word.
    pub fn encode(&self, xs: &[f64]) -> Vec<u8> {
        let segments = paa(xs, self.n_segments);
        segments
            .iter()
            .map(|&v| {
                // Number of breakpoints below v == symbol id.
                self.betas.iter().take_while(|&&b| v > b).count() as u8
            })
            .collect()
    }

    /// Symbol-pair cell of the MINDIST lookup: 0 for adjacent symbols,
    /// otherwise the gap between the nearest breakpoints.
    #[inline]
    fn cell(&self, r: u8, c: u8) -> f64 {
        let (r, c) = (r as usize, c as usize);
        if r.abs_diff(c) <= 1 {
            0.0
        } else {
            let (hi, lo) = if r > c { (r, c) } else { (c, r) };
            self.betas[hi - 1] - self.betas[lo]
        }
    }

    /// MINDIST between two SAX words (lower-bounds the Euclidean distance
    /// between the original z-normalized series).
    pub fn mindist(&self, a: &[u8], b: &[u8]) -> f64 {
        debug_assert_eq!(a.len(), b.len());
        let scale = self.series_len as f64 / self.n_segments as f64;
        let s: f64 = a
            .iter()
            .zip(b.iter())
            .map(|(&x, &y)| {
                let c = self.cell(x, y);
                c * c
            })
            .sum();
        (scale * s).sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::preprocess::znorm;
    use crate::core::rng::Rng;
    use crate::distance::euclidean::euclidean;

    #[test]
    fn symbols_in_alphabet() {
        let mut rng = Rng::new(83);
        let enc = SaxEncoder::new(50, 4, 0.2);
        for _ in 0..20 {
            let xs = znorm(&(0..50).map(|_| rng.normal()).collect::<Vec<_>>());
            let w = enc.encode(&xs);
            assert_eq!(w.len(), 5);
            assert!(w.iter().all(|&s| s < 4));
        }
    }

    #[test]
    fn monotone_series_monotone_symbols() {
        let xs = znorm(&(0..20).map(|i| i as f64).collect::<Vec<_>>());
        let enc = SaxEncoder::new(20, 4, 0.2);
        let w = enc.encode(&xs);
        for k in 1..w.len() {
            assert!(w[k] >= w[k - 1], "{w:?}");
        }
        assert_eq!(w[0], 0);
        assert_eq!(*w.last().unwrap(), 3);
    }

    #[test]
    fn identical_words_zero_distance() {
        let enc = SaxEncoder::new(25, 4, 0.2);
        let w = vec![0u8, 1, 2, 3, 2];
        assert_eq!(enc.mindist(&w, &w), 0.0);
    }

    #[test]
    fn mindist_lower_bounds_euclidean() {
        let mut rng = Rng::new(89);
        let enc = SaxEncoder::new(40, 4, 0.2);
        for _ in 0..60 {
            let a = znorm(&(0..40).map(|_| rng.normal()).collect::<Vec<_>>());
            let b = znorm(&(0..40).map(|_| rng.normal()).collect::<Vec<_>>());
            let lb = enc.mindist(&enc.encode(&a), &enc.encode(&b));
            let ed = euclidean(&a, &b);
            assert!(lb <= ed + 1e-9, "lb={lb} ed={ed}");
        }
    }

    #[test]
    fn adjacent_symbols_cost_zero() {
        let enc = SaxEncoder::new(10, 4, 0.2);
        assert_eq!(enc.cell(1, 2), 0.0);
        assert_eq!(enc.cell(2, 1), 0.0);
        assert!(enc.cell(0, 3) > 0.0);
        assert!((enc.cell(0, 3) - (0.67 - (-0.67))).abs() < 1e-9);
    }
}
