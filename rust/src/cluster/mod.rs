//! Agglomerative hierarchical clustering and external quality metrics
//! (paper §4.2 / §6.3).

pub mod hierarchical;
pub mod metrics;

pub use hierarchical::{agglomerative, Dendrogram, Linkage};
pub use metrics::{adjusted_rand_index, compact_labels, rand_index};
