//! Agglomerative hierarchical clustering with single / average / complete
//! linkage (paper §4.2 / §6.3), via Lance–Williams updates on a condensed
//! distance matrix, plus dendrogram cutting.

use crate::core::matrix::CondensedMatrix;

/// Linkage criterion.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Linkage {
    /// Minimum pairwise distance between clusters.
    Single,
    /// Unweighted average pairwise distance (UPGMA).
    Average,
    /// Maximum pairwise distance between clusters.
    Complete,
}

/// One merge step of the dendrogram.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Merge {
    /// First merged cluster id (ids `0..n` are leaves; merge `t` creates
    /// cluster `n + t`).
    pub a: usize,
    /// Second merged cluster id.
    pub b: usize,
    /// Linkage distance at which the merge happened.
    pub height: f64,
    /// Size of the newly formed cluster.
    pub size: usize,
}

/// A full agglomerative clustering (dendrogram).
#[derive(Debug, Clone)]
pub struct Dendrogram {
    /// Number of leaves.
    pub n: usize,
    /// `n - 1` merges in non-decreasing height order (as produced by the
    /// greedy agglomeration; heights may locally invert for average
    /// linkage on pathological data, which is standard behaviour).
    pub merges: Vec<Merge>,
}

impl Dendrogram {
    /// Cut the dendrogram to exactly `k` clusters: apply the first
    /// `n - k` merges and label the resulting components `0..k`.
    pub fn cut(&self, k: usize) -> Vec<usize> {
        assert!(k >= 1 && k <= self.n, "cut: k out of range");
        // union-find over leaves + internal nodes
        let mut parent: Vec<usize> = (0..self.n + self.merges.len()).collect();
        fn find(parent: &mut Vec<usize>, mut x: usize) -> usize {
            while parent[x] != x {
                parent[x] = parent[parent[x]];
                x = parent[x];
            }
            x
        }
        for (t, m) in self.merges.iter().take(self.n - k).enumerate() {
            let node = self.n + t;
            let ra = find(&mut parent, m.a);
            let rb = find(&mut parent, m.b);
            parent[ra] = node;
            parent[rb] = node;
        }
        // Map roots to compact labels.
        let mut labels = vec![usize::MAX; self.n];
        let mut next = 0usize;
        let mut root_label = std::collections::HashMap::new();
        for i in 0..self.n {
            let r = find(&mut parent, i);
            let l = *root_label.entry(r).or_insert_with(|| {
                let l = next;
                next += 1;
                l
            });
            labels[i] = l;
        }
        debug_assert_eq!(next, k);
        labels
    }
}

/// Agglomerative clustering of a condensed pairwise distance matrix.
pub fn agglomerative(dist: &CondensedMatrix, linkage: Linkage) -> Dendrogram {
    let n = dist.n();
    assert!(n >= 1);
    // Active cluster list; cluster distances kept in a mutable square
    // matrix for O(1) access (n is moderate for hierarchical clustering).
    let mut d = vec![f64::INFINITY; n * n];
    for i in 0..n {
        for j in 0..n {
            if i != j {
                d[i * n + j] = dist.get(i, j);
            }
        }
    }
    let mut active: Vec<bool> = vec![true; n];
    let mut sizes: Vec<usize> = vec![1; n];
    // node id of the cluster currently occupying slot i
    let mut node_id: Vec<usize> = (0..n).collect();
    let mut merges = Vec::with_capacity(n.saturating_sub(1));

    for t in 0..n.saturating_sub(1) {
        // Find the closest active pair.
        let (mut bi, mut bj, mut bd) = (0usize, 0usize, f64::INFINITY);
        for i in 0..n {
            if !active[i] {
                continue;
            }
            for j in (i + 1)..n {
                if !active[j] {
                    continue;
                }
                let v = d[i * n + j];
                if v < bd {
                    bd = v;
                    bi = i;
                    bj = j;
                }
            }
        }
        // Merge bj into bi (slot bi holds the new cluster).
        let new_size = sizes[bi] + sizes[bj];
        merges.push(Merge { a: node_id[bi], b: node_id[bj], height: bd, size: new_size });
        // Lance–Williams distance update for the remaining clusters.
        for x in 0..n {
            if !active[x] || x == bi || x == bj {
                continue;
            }
            let dxi = d[x * n + bi];
            let dxj = d[x * n + bj];
            let nd = match linkage {
                Linkage::Single => dxi.min(dxj),
                Linkage::Complete => dxi.max(dxj),
                Linkage::Average => {
                    (sizes[bi] as f64 * dxi + sizes[bj] as f64 * dxj) / new_size as f64
                }
            };
            d[x * n + bi] = nd;
            d[bi * n + x] = nd;
        }
        active[bj] = false;
        sizes[bi] = new_size;
        node_id[bi] = n + t;
    }
    Dendrogram { n, merges }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Distance matrix for points on a line: |x_i - x_j|.
    fn line_matrix(points: &[f64]) -> CondensedMatrix {
        CondensedMatrix::build(points.len(), |i, j| (points[i] - points[j]).abs())
    }

    #[test]
    fn two_obvious_clusters() {
        // {0, 1, 2} and {10, 11}
        let m = line_matrix(&[0.0, 1.0, 2.0, 10.0, 11.0]);
        for linkage in [Linkage::Single, Linkage::Average, Linkage::Complete] {
            let dend = agglomerative(&m, linkage);
            let labels = dend.cut(2);
            assert_eq!(labels[0], labels[1]);
            assert_eq!(labels[1], labels[2]);
            assert_eq!(labels[3], labels[4]);
            assert_ne!(labels[0], labels[3]);
        }
    }

    #[test]
    fn cut_to_n_is_singletons_and_1_is_everything() {
        let m = line_matrix(&[0.0, 5.0, 9.0, 14.0]);
        let dend = agglomerative(&m, Linkage::Complete);
        let singles = dend.cut(4);
        let mut sorted = singles.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 4);
        let all = dend.cut(1);
        assert!(all.iter().all(|&l| l == all[0]));
    }

    #[test]
    fn single_vs_complete_chaining() {
        // A chain 0-1-2-3-4 with gaps 1 and a far point: single linkage
        // chains the whole line together before absorbing the far point;
        // complete linkage splits the chain earlier. Classic behaviour.
        let m = line_matrix(&[0.0, 1.0, 2.0, 3.0, 4.0, 20.0]);
        let s = agglomerative(&m, Linkage::Single);
        let labels = s.cut(2);
        assert!(labels[..5].iter().all(|&l| l == labels[0]));
        assert_ne!(labels[5], labels[0]);
    }

    #[test]
    fn merge_heights_nondecreasing_single_complete() {
        let m = line_matrix(&[0.0, 2.0, 3.0, 7.0, 8.0, 8.5, 15.0]);
        for linkage in [Linkage::Single, Linkage::Complete] {
            let dend = agglomerative(&m, linkage);
            for w in dend.merges.windows(2) {
                assert!(w[1].height >= w[0].height - 1e-12, "{linkage:?}");
            }
        }
    }

    #[test]
    fn average_linkage_heights_sane() {
        let m = line_matrix(&[0.0, 1.0, 10.0, 11.0]);
        let dend = agglomerative(&m, Linkage::Average);
        // first two merges at height 1, final at avg distance 10
        assert!((dend.merges[0].height - 1.0).abs() < 1e-12);
        assert!((dend.merges[1].height - 1.0).abs() < 1e-12);
        assert!((dend.merges[2].height - 10.0).abs() < 1e-12);
    }

    #[test]
    fn sizes_accumulate() {
        let m = line_matrix(&[0.0, 1.0, 2.0, 3.0]);
        let dend = agglomerative(&m, Linkage::Single);
        assert_eq!(dend.merges.last().unwrap().size, 4);
    }

    #[test]
    fn single_point() {
        let m = CondensedMatrix::new(1);
        let dend = agglomerative(&m, Linkage::Single);
        assert!(dend.merges.is_empty());
        assert_eq!(dend.cut(1), vec![0]);
    }
}
