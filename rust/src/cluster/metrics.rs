//! External clustering quality: Rand index (paper's clustering metric)
//! and Adjusted Rand Index.

/// Rand index between two labelings (Rand 1971): fraction of item pairs
/// on which the two labelings agree (same-same or different-different).
/// In `[0, 1]`, 1 = identical partitions.
pub fn rand_index(a: &[usize], b: &[usize]) -> f64 {
    assert_eq!(a.len(), b.len());
    let n = a.len();
    if n < 2 {
        return 1.0;
    }
    let mut agree = 0usize;
    for i in 0..n {
        for j in (i + 1)..n {
            let same_a = a[i] == a[j];
            let same_b = b[i] == b[j];
            if same_a == same_b {
                agree += 1;
            }
        }
    }
    agree as f64 / (n * (n - 1) / 2) as f64
}

/// Adjusted Rand Index (Hubert & Arabie): Rand index corrected for
/// chance; 0 ≈ random labeling, 1 = identical partitions.
pub fn adjusted_rand_index(a: &[usize], b: &[usize]) -> f64 {
    assert_eq!(a.len(), b.len());
    let n = a.len();
    if n < 2 {
        return 1.0;
    }
    let ka = a.iter().max().map(|&m| m + 1).unwrap_or(0);
    let kb = b.iter().max().map(|&m| m + 1).unwrap_or(0);
    // contingency table
    let mut table = vec![0u64; ka * kb];
    let mut rows = vec![0u64; ka];
    let mut cols = vec![0u64; kb];
    for i in 0..n {
        table[a[i] * kb + b[i]] += 1;
        rows[a[i]] += 1;
        cols[b[i]] += 1;
    }
    let c2 = |x: u64| (x * x.saturating_sub(1) / 2) as f64;
    let sum_ij: f64 = table.iter().map(|&x| c2(x)).sum();
    let sum_a: f64 = rows.iter().map(|&x| c2(x)).sum();
    let sum_b: f64 = cols.iter().map(|&x| c2(x)).sum();
    let total = c2(n as u64);
    let expected = sum_a * sum_b / total;
    let max_index = 0.5 * (sum_a + sum_b);
    if (max_index - expected).abs() < 1e-12 {
        return 0.0;
    }
    (sum_ij - expected) / (max_index - expected)
}

/// Convert arbitrary i64 class labels to compact usize labels.
pub fn compact_labels(labels: &[i64]) -> Vec<usize> {
    let mut map = std::collections::HashMap::new();
    let mut next = 0usize;
    labels
        .iter()
        .map(|&l| {
            *map.entry(l).or_insert_with(|| {
                let v = next;
                next += 1;
                v
            })
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identical_partitions() {
        let a = vec![0, 0, 1, 1, 2];
        assert_eq!(rand_index(&a, &a), 1.0);
        assert_eq!(adjusted_rand_index(&a, &a), 1.0);
    }

    #[test]
    fn permuted_labels_still_identical() {
        let a = vec![0, 0, 1, 1, 2, 2];
        let b = vec![2, 2, 0, 0, 1, 1];
        assert_eq!(rand_index(&a, &b), 1.0);
        assert_eq!(adjusted_rand_index(&a, &b), 1.0);
    }

    #[test]
    fn hand_computed_rand_index() {
        // a: {0,1},{2}; b: {0},{1,2}. Pairs: (0,1) same-a diff-b;
        // (0,2) diff-diff agree; (1,2) diff-a same-b. agree = 1 of 3.
        let a = vec![0, 0, 1];
        let b = vec![0, 1, 1];
        assert!((rand_index(&a, &b) - 1.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn ari_zero_for_random_vs_structure() {
        // One big cluster vs alternating labels: ARI ≈ 0 or negative.
        let a = vec![0; 20];
        let b: Vec<usize> = (0..20).map(|i| i % 2).collect();
        let ari = adjusted_rand_index(&a, &b);
        assert!(ari.abs() < 1e-9, "ari={ari}");
    }

    #[test]
    fn ari_le_ri_relationship_monotone() {
        let a = vec![0, 0, 0, 1, 1, 1, 2, 2, 2];
        let near = vec![0, 0, 1, 1, 1, 1, 2, 2, 2];
        let far = vec![0, 1, 2, 0, 1, 2, 0, 1, 2];
        assert!(adjusted_rand_index(&a, &near) > adjusted_rand_index(&a, &far));
        assert!(rand_index(&a, &near) > rand_index(&a, &far));
    }

    #[test]
    fn compact_mapping() {
        let l = vec![5i64, -3, 5, 7, -3];
        let c = compact_labels(&l);
        assert_eq!(c, vec![0, 1, 0, 2, 1]);
    }
}
