//! `pqdtw` — leader binary: train / encode / query / topk / cluster /
//! build-index / serve / stats / shutdown / selftest over the PQDTW
//! library.
//!
//! Examples:
//!   pqdtw selftest
//!   pqdtw train --dataset CBF --subspaces 4 --codebook 32
//!   pqdtw query --dataset CBF --mode asymmetric --queries 50
//!   pqdtw topk --dataset CBF --topk 5 --nlist 16 --nprobe 4 --rerank 20
//!   pqdtw cluster --dataset Waveforms --linkage complete
//!   pqdtw build-index --dataset RandomWalk-4096x128 --nlist 32 --out rw.pqx
//!   pqdtw serve --index rw.pqx --dataset RandomWalk-4096x128 --topk 5 --nprobe 4
//!   pqdtw serve --listen 127.0.0.1:7447 --index rw.pqx
//!   pqdtw query --connect 127.0.0.1:7447 --dataset RandomWalk-4096x128 --topk 5 --nprobe 4
//!   pqdtw query --connect 127.0.0.1:7447 --dataset RandomWalk-4096x128 --topk 5 --trace
//!   pqdtw serve --listen 127.0.0.1:7447 --index rw.pqx --log-json
//!   pqdtw serve --listen 127.0.0.1:7447 --index rw.pqx --metrics-listen 127.0.0.1:9464 --slow-query-ms 50
//!   pqdtw stats --connect 127.0.0.1:7447
//!   pqdtw stats --connect 127.0.0.1:7447 --prometheus
//!   pqdtw shutdown --connect 127.0.0.1:7447
//!   pqdtw topk --index rw.pqx --dataset RandomWalk-4096x128 --nlist 32 --verify
//!   pqdtw bench-scan --json --out BENCH_scan.json
//!   pqdtw bench-scan --json --out BENCH_scan.json --baseline BENCH_prev.json --threshold 75
//!   pqdtw job submit --connect 127.0.0.1:7447 --kind autotune --topk 10 --target-recall 0.95
//!   pqdtw job events --connect 127.0.0.1:7447 --id 1 --follow
//!   pqdtw job result --connect 127.0.0.1:7447 --id 1
//!   pqdtw info --index rw.pqx
//!   pqdtw build-index --dataset RandomWalk-4096x128 --shard 0/3 --nlist 0 --out s0.pqx
//!   pqdtw serve --listen 127.0.0.1:7448 --index s0.pqx
//!   pqdtw serve --router --listen 127.0.0.1:7450 --shards 127.0.0.1:7448,127.0.0.1:7449
//!   pqdtw query --connect 127.0.0.1:7450 --dataset RandomWalk-4096x128 --topk 5
//!
//! The build-once / serve-many split: `build-index` trains, encodes and
//! persists the full serving state; `serve --index` / `topk --index`
//! reopen it without retraining and answer bit-identically to the
//! in-memory engine it was saved from. `serve --listen` exposes that
//! engine to remote clients over the wire protocol
//! (`docs/wire-protocol.md`); networked queries are bit-identical to
//! in-process ones. Unknown subcommands and flags are hard errors
//! listing the valid options (a typo like `--nporbe` must never
//! silently degrade results).

use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, ensure, Context, Result};

use pqdtw::cluster::{agglomerative, compact_labels, rand_index, Linkage};
use pqdtw::coordinator::{Engine, Request, Response, Service, ServiceConfig};
use pqdtw::jobs::{JobConfig, JobManager, JobResult, JobSnapshot, JobSpec};
use pqdtw::core::matrix::CondensedMatrix;
use pqdtw::data::random_walk::RandomWalks;
use pqdtw::data::ucr_like::{ucr_like_by_name, TrainTest};
use pqdtw::distance::measure::Measure;
use pqdtw::net::{
    connect_with_retry, Client, ClientConfig, HttpConfig, HttpEndpoints, HttpServer, NetServer,
    RetryConfig, ServerConfig,
};
use pqdtw::nn::ivf::CoarseMetric;
use pqdtw::nn::knn::{nn_classify_pq, nn_classify_raw, PqQueryMode};
use pqdtw::pq::quantizer::{PqConfig, PqMetric, PrealignConfig, ProductQuantizer};
use pqdtw::router::{RouterConfig, RouterServer, RouterServerConfig};

use pqdtw::cli::{Args, CommandSpec};

/// Common dataset/quantizer flags shared by every training command.
macro_rules! pq_flags {
    ($($extra:literal),*) => {
        &[
            "dataset", "seed", "subspaces", "codebook", "window", "metric", "tail",
            "level", "kmeans-iters", "dba-iters", $($extra),*
        ]
    };
}

/// Every subcommand with the exact flag set it accepts; anything else
/// is rejected by [`Args::validate`] before dispatch.
const SPECS: &[CommandSpec] = &[
    CommandSpec { name: "train", flags: pq_flags!() },
    CommandSpec {
        name: "query",
        flags: pq_flags!("mode", "queries", "connect", "topk", "nprobe", "rerank", "trace"),
    },
    CommandSpec {
        name: "topk",
        flags: pq_flags!(
            "topk", "nlist", "nprobe", "rerank", "coarse", "scan-threads", "queries",
            "index", "verify"
        ),
    },
    CommandSpec { name: "cluster", flags: pq_flags!("linkage") },
    CommandSpec {
        name: "serve",
        flags: pq_flags!(
            "workers", "requests", "topk", "nprobe", "rerank", "nlist", "coarse",
            "scan-threads", "index", "listen", "port-file", "max-conns", "log-json",
            "job-workers", "router", "shards", "require-full", "metrics-listen",
            "metrics-port-file", "slow-query-ms"
        ),
    },
    CommandSpec { name: "build-index", flags: pq_flags!("out", "nlist", "coarse", "shard") },
    CommandSpec {
        name: "bench-scan",
        flags: &[
            "n", "len", "seed", "subspaces", "codebook", "topk", "reps", "threads", "json",
            "out", "baseline", "threshold",
        ],
    },
    CommandSpec { name: "stats", flags: &["connect", "prometheus"] },
    CommandSpec { name: "shutdown", flags: &["connect"] },
    CommandSpec {
        name: "job submit",
        flags: &[
            "connect", "kind", "topk", "mode", "nprobe", "rerank", "clusters", "iters",
            "seed", "target-recall", "sample",
        ],
    },
    CommandSpec { name: "job status", flags: &["connect", "id"] },
    CommandSpec { name: "job events", flags: &["connect", "id", "cursor", "max", "follow"] },
    CommandSpec { name: "job cancel", flags: &["connect", "id"] },
    CommandSpec { name: "job result", flags: &["connect", "id"] },
    CommandSpec { name: "selftest", flags: &["seed"] },
    CommandSpec { name: "info", flags: &["index"] },
];

/// `RandomWalk` or `RandomWalk-<n>x<len>`: an unlabeled synthetic
/// random-walk corpus (the paper's §6.1 scaling workload), generated
/// deterministically from the seed — usable anywhere a named dataset
/// is, including `build-index` and the CI store smoke test.
fn random_walk_tt(name: &str, seed: u64) -> Option<TrainTest> {
    let rest = name.strip_prefix("RandomWalk")?;
    let (n, len) = if rest.is_empty() {
        (256usize, 128usize)
    } else {
        let (a, b) = rest.strip_prefix('-')?.split_once('x')?;
        (a.parse().ok()?, b.parse().ok()?)
    };
    if n == 0 || len == 0 {
        return None;
    }
    let train = RandomWalks::new(seed).generate(n, len);
    let test = RandomWalks::new(seed.wrapping_add(0x9E37_79B9_7F4A_7C15))
        .generate(n.div_ceil(4), len);
    Some(TrainTest { name: format!("RandomWalk(n={n},len={len})"), train, test })
}

fn load_dataset(name: &str, seed: u64) -> Result<TrainTest> {
    // Real UCR archive takes precedence when available.
    if let Ok(dir) = std::env::var("UCR_ARCHIVE_DIR") {
        let dir = std::path::PathBuf::from(dir);
        if dir.join(name).exists() {
            return pqdtw::data::ucr_loader::load_ucr_dataset(&dir, name);
        }
    }
    if let Some(tt) = random_walk_tt(name, seed) {
        return Ok(tt);
    }
    ucr_like_by_name(name, seed)
        .with_context(|| format!("unknown dataset '{name}' (and no UCR_ARCHIVE_DIR)"))
}

fn config_from_args(a: &Args) -> PqConfig {
    let tail: f64 = a.get_parsed("tail", 0.0f64);
    PqConfig {
        n_subspaces: a.get_parsed("subspaces", 4usize),
        codebook_size: a.get_parsed("codebook", 64usize),
        window_frac: a.get_parsed("window", 0.1f64),
        metric: if a.get("metric", "dtw") == "ed" { PqMetric::Euclidean } else { PqMetric::Dtw },
        prealign: (tail > 0.0).then(|| PrealignConfig {
            level: a.get_parsed("level", 2usize),
            tail_frac: tail,
        }),
        kmeans_iters: a.get_parsed("kmeans-iters", 8usize),
        dba_iters: a.get_parsed("dba-iters", 3usize),
        train_subsample: None,
    }
}

/// Flags that describe how to *build* an engine and therefore conflict
/// with `--index` (the index file carries its own configuration —
/// accepting and ignoring them would be exactly the silent degradation
/// `Args::validate` exists to prevent).
const BUILD_FLAGS: &[&str] = &[
    "subspaces",
    "codebook",
    "window",
    "metric",
    "tail",
    "level",
    "kmeans-iters",
    "dba-iters",
    "nlist",
    "coarse",
];

/// Error out when any of `flags` is present: each would be a silent
/// no-op in the current mode, which `Args::validate` exists to prevent.
fn reject_flags(a: &Args, flags: &[&str], why: &str) -> Result<()> {
    let mut set: Vec<&str> = flags.iter().copied().filter(|f| a.flags.contains_key(*f)).collect();
    set.sort_unstable();
    if let Some(first) = set.first() {
        bail!("--{first} {why}");
    }
    Ok(())
}

/// Error out when a build-shape flag is combined with `--index`.
fn reject_build_flags_with_index(a: &Args) -> Result<()> {
    reject_flags(
        a,
        BUILD_FLAGS,
        "has no effect with --index: the index file carries its own \
         configuration (drop the flag, or rebuild it with build-index)",
    )
}

/// Open an index file and check it against the query dataset (shared
/// by `serve --index` and `topk --index`).
fn open_index(path: &str, tt: &TrainTest) -> Result<Engine> {
    let engine = Engine::open(Path::new(path))?;
    ensure!(
        engine.pq.series_len == tt.test.len,
        "index {path} was built for series of length {}, but dataset {} has length {}",
        engine.pq.series_len,
        tt.name,
        tt.test.len
    );
    println!("loaded index {path} (no retraining)");
    Ok(engine)
}

/// Coarse IVF metric from the `--coarse` flag (DTW unless `ed`).
fn coarse_metric(a: &Args, engine: &Engine) -> CoarseMetric {
    if a.get("coarse", "dtw") == "ed" {
        CoarseMetric::Euclidean
    } else {
        CoarseMetric::Dtw { window: engine.full_window() }
    }
}

fn cmd_train(a: &Args) -> Result<()> {
    let seed = a.get_parsed("seed", 7u64);
    let tt = load_dataset(&a.get("dataset", "CBF"), seed)?;
    let cfg = config_from_args(a);
    let t0 = Instant::now();
    let pq = ProductQuantizer::train(&tt.train, &cfg, seed)?;
    let train_t = t0.elapsed();
    let t0 = Instant::now();
    let enc = pq.encode_dataset(&tt.train);
    let enc_t = t0.elapsed();
    let mm = pq.memory_model();
    println!("dataset        : {} (n={}, D={})", tt.name, tt.train.n_series(), tt.train.len);
    println!("codebook       : M={} K={} L={} window={:?}", cfg.n_subspaces, pq.codebook.k, pq.codebook.sub_len, pq.codebook.window);
    println!("train time     : {train_t:?}");
    println!("encode time    : {enc_t:?} ({} series)", enc.n());
    println!("compression    : {:.1}x ({} -> {} bits/series)", mm.compression_factor, mm.raw_bits_per_series, mm.code_bits_per_series);
    println!("aux memory     : {:.2} MB", mm.aux_bits() as f64 / 8.0 / 1024.0 / 1024.0);
    let st = enc.stats;
    println!(
        "encode pruning : {} candidates, {:.1}% kim, {:.1}% keogh, {:.1}% dtw ({:.1}% abandoned)",
        st.candidates(),
        100.0 * st.pruned_kim as f64 / st.candidates().max(1) as f64,
        100.0 * st.pruned_keogh as f64 / st.candidates().max(1) as f64,
        100.0 * st.dtw_evals as f64 / st.candidates().max(1) as f64,
        100.0 * st.dtw_abandoned as f64 / st.dtw_evals.max(1) as f64,
    );
    Ok(())
}

/// Remote retrieval driver: generate queries from the dataset's test
/// split and run them against a `serve --listen` process. The serving
/// mode (top-k / probed / re-ranked) is chosen per request by flags.
fn cmd_query_remote(a: &Args, addr: &str) -> Result<()> {
    reject_flags(
        a,
        BUILD_FLAGS,
        "has no effect with --connect: the server's engine was configured when it \
         was built (see `build-index` / `serve`)",
    )?;
    let seed = a.get_parsed("seed", 7u64);
    let tt = load_dataset(&a.get("dataset", "CBF"), seed)?;
    let mode = if a.get("mode", "asymmetric") == "symmetric" {
        PqQueryMode::Symmetric
    } else {
        PqQueryMode::Asymmetric
    };
    let k = a.get_parsed("topk", 5usize).max(1);
    let nprobe: Option<usize> = a.get_opt("nprobe");
    let rerank: Option<usize> = a.get_opt("rerank");
    let n_queries = a.get_parsed("queries", 10usize).min(tt.test.n_series()).max(1);
    let want_trace = a.has("trace");
    let mut client = Client::connect(addr, ClientConfig::default())?;
    let t0 = Instant::now();
    let mut n_hits = 0usize;
    let mut n_degraded = 0usize;
    for i in 0..n_queries {
        let reply = client.topk_full(
            tt.test.row(i),
            k,
            mode,
            nprobe,
            rerank,
            i as u64 + 1,
            want_trace,
        )?;
        ensure!(!reply.hits.is_empty(), "server returned no hits for query {i}");
        ensure!(
            reply.trace.is_some() == want_trace,
            "server trace presence does not match the --trace flag for query {i} \
             (both shard servers and routers must echo the trace request)"
        );
        n_hits += reply.hits.len();
        if reply.degraded {
            if n_degraded == 0 {
                println!(
                    "WARNING: degraded result for query {i} — shards {:?} missing, \
                     hits cover the surviving shards only",
                    reply.missing_shards
                );
            }
            n_degraded += 1;
        }
        if i == 0 {
            println!("query 0 top-{k} ({mode:?}, nprobe={nprobe:?}, rerank={rerank:?}):");
            for h in &reply.hits {
                match h.label {
                    Some(l) => println!("  #{:<8} d={:.6} label={l}", h.index, h.distance),
                    None => println!("  #{:<8} d={:.6}", h.index, h.distance),
                }
            }
            if let Some(t) = &reply.trace {
                print!("{}", t.render_text());
            }
        }
    }
    let dt = t0.elapsed();
    println!(
        "{n_queries} remote queries to {addr} in {dt:?} ({:.0} req/s, {n_hits} hits)",
        n_queries as f64 / dt.as_secs_f64()
    );
    if n_degraded > 0 {
        println!("degraded : {n_degraded} of {n_queries} queries answered partially");
    }
    Ok(())
}

fn cmd_query(a: &Args) -> Result<()> {
    if let Some(addr) = a.flags.get("connect") {
        return cmd_query_remote(a, addr);
    }
    reject_flags(
        a,
        &["topk", "nprobe", "rerank"],
        "has no effect without --connect: local `query` is the 1-NN classification \
         driver (use `topk` for ranked retrieval, or `query --connect` against a server)",
    )?;
    let seed = a.get_parsed("seed", 7u64);
    let tt = load_dataset(&a.get("dataset", "CBF"), seed)?;
    ensure!(
        tt.train.is_labeled(),
        "dataset {} is unlabeled; 1-NN classification needs labels",
        tt.name
    );
    let cfg = config_from_args(a);
    let mode = if a.get("mode", "asymmetric") == "symmetric" {
        PqQueryMode::Symmetric
    } else {
        PqQueryMode::Asymmetric
    };
    let pq = ProductQuantizer::train(&tt.train, &cfg, seed)?;
    let enc = pq.encode_dataset(&tt.train);
    let n_queries = a.get_parsed("queries", tt.test.n_series());
    let test = tt.test.subset(&(0..n_queries.min(tt.test.n_series())).collect::<Vec<_>>());
    let t0 = Instant::now();
    let (err, _) = nn_classify_pq(&pq, &enc, &test, mode);
    let dt = t0.elapsed();
    let (err_ed, _) = nn_classify_raw(&tt.train, &test, Measure::Euclidean);
    println!("dataset   : {}", tt.name);
    println!("mode      : {mode:?}");
    println!("1NN error : PQDTW {err:.4} | ED {err_ed:.4}");
    println!("query time: {dt:?} ({} queries)", test.n_series());
    Ok(())
}

fn cmd_cluster(a: &Args) -> Result<()> {
    let seed = a.get_parsed("seed", 7u64);
    let tt = load_dataset(&a.get("dataset", "Waveforms"), seed)?;
    ensure!(
        tt.test.is_labeled(),
        "dataset {} is unlabeled; clustering evaluation needs labels",
        tt.name
    );
    let cfg = config_from_args(a);
    let linkage = match a.get("linkage", "complete").as_str() {
        "single" => Linkage::Single,
        "average" => Linkage::Average,
        _ => Linkage::Complete,
    };
    let pq = ProductQuantizer::train(&tt.train, &cfg, seed)?;
    let enc = pq.encode_dataset(&tt.test);
    let n = tt.test.n_series();
    let t0 = Instant::now();
    let dist = CondensedMatrix::build(n, |i, j| pq.patched_distance(&enc, i, j));
    let dend = agglomerative(&dist, linkage);
    let k = tt.test.classes().len();
    let labels = dend.cut(k);
    let dt = t0.elapsed();
    let truth = compact_labels(&tt.test.labels);
    println!("dataset : {}", tt.name);
    println!("linkage : {linkage:?}, k={k}");
    println!("RI      : {:.4}", rand_index(&labels, &truth));
    println!("time    : {dt:?} (n={n})");
    Ok(())
}

/// `--shard i/n` (e.g. `0/3`): this process builds shard `i` of an
/// `n`-way deterministic `id % n` split.
fn parse_shard_spec(spec: &str) -> Result<(u64, u64)> {
    let (i, n) = spec
        .split_once('/')
        .with_context(|| format!("--shard must be <index>/<count> (e.g. 0/3), got '{spec}'"))?;
    let i: u64 = i.trim().parse().with_context(|| format!("--shard index in '{spec}'"))?;
    let n: u64 = n.trim().parse().with_context(|| format!("--shard count in '{spec}'"))?;
    ensure!(n >= 1, "--shard count must be >= 1, got '{spec}'");
    ensure!(i < n, "--shard index must be < count, got '{spec}'");
    Ok((i, n))
}

/// Offline build phase of the build-once / serve-many split: train,
/// encode, optionally build the IVF index, and persist everything as
/// one index file that `serve --index` / `topk --index` reopen without
/// retraining. With `--shard i/n` the quantizer still trains on the
/// full dataset (bit-identical codebooks across shards) but only the
/// `id % n == i` rows are encoded and kept, for `serve --router`
/// fleets (`docs/serving-topology.md`).
fn cmd_build_index(a: &Args) -> Result<()> {
    let seed = a.get_parsed("seed", 7u64);
    let tt = load_dataset(&a.get("dataset", "CBF"), seed)?;
    let cfg = config_from_args(a);
    let out = a.get("out", "index.pqx");
    let nlist: usize = a.get_parsed("nlist", 16usize);
    let shard = match a.flags.get("shard") {
        Some(spec) => Some(parse_shard_spec(spec)?),
        None => None,
    };
    let t0 = Instant::now();
    let mut engine = match shard {
        Some((i, n)) => Engine::build_shard(&tt.train, &cfg, seed, i, n)?,
        None => Engine::build(&tt.train, &cfg, seed)?,
    };
    if nlist > 0 {
        let metric = coarse_metric(a, &engine);
        engine.enable_ivf(nlist, metric, seed);
    }
    let build_t = t0.elapsed();
    let t0 = Instant::now();
    engine.save(Path::new(&out))?;
    let save_t = t0.elapsed();
    let file_bytes = std::fs::metadata(&out)?.len();
    let mm = engine.pq.memory_model();
    println!("dataset     : {} (n={}, D={})", tt.name, engine.n_items, tt.train.len);
    if let Some(info) = engine.shard.as_ref() {
        println!(
            "shard       : {}/{} ({} of {} rows retained, global ids preserved)",
            info.shard_index,
            info.shard_count,
            engine.n_items,
            tt.train.n_series()
        );
    }
    println!("build time  : {build_t:?} (train + encode + IVF), save {save_t:?}");
    println!(
        "index file  : {out} ({file_bytes} bytes = {:.2} MB on disk)",
        file_bytes as f64 / 1024.0 / 1024.0
    );
    println!(
        "memory model: {} code bits/series × {} series + {:.2} MB aux (analytic, f32)",
        mm.code_bits_per_series,
        engine.n_items,
        mm.aux_bits() as f64 / 8.0 / 1024.0 / 1024.0
    );
    match engine.ivf.as_ref() {
        Some(ivf) => println!("ivf         : {} coarse cells", ivf.nlist()),
        None => println!("ivf         : none (--nlist 0)"),
    }
    // Cold-start proof: reopening must serve without retraining.
    let t0 = Instant::now();
    let _reopened = Engine::open(Path::new(&out))?;
    println!("reopen time : {:?} (vs {build_t:?} to rebuild from scratch)", t0.elapsed());
    Ok(())
}

/// Scan-kernel benchmark: scalar vs blocked vs blocked+pruned top-k
/// scans over a RandomWalk database, in both query modes, with a
/// machine-readable `--json` output (optionally written to `--out`) so
/// CI can archive the perf trajectory as `BENCH_scan.json`. Results are
/// correctness-guarded: every blocked variant is asserted bit-identical
/// to the scalar reference before anything is timed.
fn cmd_bench_scan(a: &Args) -> Result<()> {
    use pqdtw::nn::topk::{
        topk_scan_blocked_opts, topk_scan_blocked_stats, topk_scan_scalar, QueryLut,
    };
    use pqdtw::obs::ScanStats;

    let n: usize = a.get_parsed("n", 16_384usize);
    let len: usize = a.get_parsed("len", 64usize);
    let k: usize = a.get_parsed("topk", 10usize).max(1);
    let reps: usize = a.get_parsed("reps", 21usize).max(1);
    let threads: usize = a.get_parsed("threads", 4usize).max(1);
    let seed = a.get_parsed("seed", 97u64);
    ensure!(n >= 64 && len >= 16, "bench-scan needs --n >= 64 and --len >= 16");
    let db = RandomWalks::new(seed).generate(n, len);
    let cfg = PqConfig {
        n_subspaces: a.get_parsed("subspaces", 4usize),
        codebook_size: a.get_parsed("codebook", 32usize),
        window_frac: 0.1,
        kmeans_iters: 2,
        dba_iters: 1,
        train_subsample: Some(64.min(n)),
        ..Default::default()
    };
    let t0 = Instant::now();
    let pq = ProductQuantizer::train(&db, &cfg, seed)?;
    let enc = pq.encode_dataset(&db);
    let blocks = enc.to_blocks(pq.codebook.k);
    let setup = t0.elapsed();
    let queries = RandomWalks::new(seed ^ 0xB1_0C55).generate(1, len);
    let q = queries.row(0);

    fn median_us(reps: usize, mut f: impl FnMut()) -> f64 {
        f(); // warmup
        let mut ts: Vec<f64> = (0..reps)
            .map(|_| {
                let t0 = Instant::now();
                f();
                t0.elapsed().as_secs_f64() * 1e6
            })
            .collect();
        ts.sort_by(f64::total_cmp);
        ts[ts.len() / 2]
    }

    let mut results: Vec<(String, f64)> = Vec::new();
    let mut prune_stats: Vec<(String, pqdtw::obs::ScanSnapshot)> = Vec::new();
    for (mode_name, mode) in [
        ("symmetric", PqQueryMode::Symmetric),
        ("asymmetric", PqQueryMode::Asymmetric),
    ] {
        let lut = QueryLut::build(&pq, q, mode);
        let clut = lut.collapse(&pq.codebook);
        let want = topk_scan_scalar(&pq, &enc, &lut, k);
        for (variant, th, prune) in
            [("blocked", 1usize, false), ("pruned", 1, true), ("pruned_mt", threads, true)]
        {
            let got = topk_scan_blocked_opts(&blocks, &clut, k, th, prune);
            ensure!(
                got == want,
                "{variant} scan diverged from the scalar reference ({mode_name})"
            );
        }
        // Prune-cascade accounting for this mode (single-threaded so the
        // abandon counts are deterministic across runs).
        let sink = ScanStats::new();
        let traced = topk_scan_blocked_stats(&blocks, &clut, k, 1, true, Some(&sink));
        ensure!(traced == want, "stats-sink scan diverged from the scalar reference");
        prune_stats.push((mode_name.to_string(), sink.snapshot()));
        results.push((
            format!("scalar_{mode_name}"),
            median_us(reps, || {
                std::hint::black_box(topk_scan_scalar(&pq, &enc, &lut, k));
            }),
        ));
        results.push((
            format!("blocked_{mode_name}"),
            median_us(reps, || {
                std::hint::black_box(topk_scan_blocked_opts(&blocks, &clut, k, 1, false));
            }),
        ));
        results.push((
            format!("blocked_pruned_{mode_name}"),
            median_us(reps, || {
                std::hint::black_box(topk_scan_blocked_opts(&blocks, &clut, k, 1, true));
            }),
        ));
        results.push((
            format!("blocked_pruned_{threads}threads_{mode_name}"),
            median_us(reps, || {
                std::hint::black_box(topk_scan_blocked_opts(&blocks, &clut, k, threads, true));
            }),
        ));
    }

    let mut json = String::from("{\n");
    json.push_str("  \"bench\": \"scan\",\n");
    json.push_str(&format!(
        "  \"n\": {n},\n  \"len\": {len},\n  \"m\": {},\n  \"k\": {},\n  \"topk\": {k},\n",
        cfg.n_subspaces, pq.codebook.k
    ));
    json.push_str(&format!(
        "  \"block\": {},\n  \"u8_lanes\": {},\n  \"reps\": {reps},\n",
        pqdtw::pq::SCAN_BLOCK,
        blocks.uses_u8()
    ));
    json.push_str("  \"prune\": [\n");
    for (i, (mode_name, s)) in prune_stats.iter().enumerate() {
        let sep = if i + 1 < prune_stats.len() { "," } else { "" };
        json.push_str(&format!(
            "    {{\"mode\": \"{mode_name}\", \"items_scanned\": {}, \
             \"items_abandoned\": {}, \"abandon_rate\": {:.4}, \
             \"blocks_skipped\": {}}}{sep}\n",
            s.items_scanned,
            s.items_abandoned,
            s.abandon_rate(),
            s.blocks_skipped
        ));
    }
    json.push_str("  ],\n");
    json.push_str("  \"results\": [\n");
    for (i, (name, us)) in results.iter().enumerate() {
        let sep = if i + 1 < results.len() { "," } else { "" };
        json.push_str(&format!("    {{\"name\": \"{name}\", \"us\": {us:.3}}}{sep}\n"));
    }
    json.push_str("  ]\n}\n");

    if let Some(out) = a.flags.get("out") {
        std::fs::write(out, &json).with_context(|| format!("writing --out {out}"))?;
        println!("wrote {out}");
    }
    if let Some(baseline_path) = a.flags.get("baseline") {
        // Regression gate: compare per-mode medians against an archived
        // run of the same bench. The artifact was already written above,
        // so a failing gate still leaves the fresh numbers on disk.
        let threshold: f64 = a.get_parsed("threshold", 75.0f64);
        let base_text = std::fs::read_to_string(baseline_path)
            .with_context(|| format!("reading --baseline {baseline_path}"))?;
        let base = parse_bench_results(&base_text);
        ensure!(
            !base.is_empty(),
            "--baseline {baseline_path} contains no bench-scan result entries"
        );
        let mut offenders: Vec<String> = Vec::new();
        println!("baseline compare vs {baseline_path} (fail past +{threshold:.0}%):");
        for (name, us) in &results {
            match base.iter().find(|(b, _)| b == name) {
                Some((_, base_us)) if *base_us > 0.0 => {
                    let delta = 100.0 * (us - base_us) / base_us;
                    println!(
                        "  {name:<40} {base_us:10.1} -> {us:10.1} µs ({delta:+6.1}%)"
                    );
                    if delta > threshold {
                        offenders.push(format!("{name} ({delta:+.1}%)"));
                    }
                }
                _ => println!("  {name:<40} (no baseline entry)"),
            }
        }
        ensure!(
            offenders.is_empty(),
            "bench-scan regressions past the +{threshold:.0}% threshold: {}",
            offenders.join(", ")
        );
    }
    if a.has("json") {
        println!("{json}");
    } else {
        println!("scan kernel bench: N={n} len={len} M={} K={} top-{k} (medians of {reps})",
            cfg.n_subspaces, pq.codebook.k);
        println!("(one-time train+encode+transpose: {setup:?})");
        for (name, us) in &results {
            println!("  {name:<32} {us:10.1} µs");
        }
        for (mode_name, s) in &prune_stats {
            println!(
                "  prune ({mode_name}): {}/{} items abandoned ({:.1}%), {} blocks skipped",
                s.items_abandoned,
                s.items_scanned,
                100.0 * s.abandon_rate(),
                s.blocks_skipped
            );
        }
        for mode_name in ["symmetric", "asymmetric"] {
            let scalar_name = format!("scalar_{mode_name}");
            let pruned_name = format!("blocked_pruned_{mode_name}");
            let find = |want: &String| {
                results.iter().find(|(name, _)| name == want).map(|(_, us)| *us)
            };
            if let (Some(s), Some(p)) = (find(&scalar_name), find(&pruned_name)) {
                println!("  speedup blocked+pruned vs scalar ({mode_name}): x{:.2}", s / p);
            }
        }
    }
    Ok(())
}

/// Extract the `{"name": ..., "us": ...}` result pairs from a
/// bench-scan JSON document. The document is this binary's own output
/// (one result object per line), so a full JSON parser is unnecessary;
/// lines of any other shape are skipped.
fn parse_bench_results(json: &str) -> Vec<(String, f64)> {
    let mut out = Vec::new();
    for line in json.lines() {
        let Some(rest) = line.trim().strip_prefix("{\"name\": \"") else { continue };
        let Some((name, rest)) = rest.split_once('"') else { continue };
        let Some(rest) = rest.strip_prefix(", \"us\": ") else { continue };
        let num: String = rest
            .chars()
            .take_while(|c| c.is_ascii_digit() || *c == '.' || *c == '-')
            .collect();
        if let Ok(us) = num.parse::<f64>() {
            out.push((name.to_string(), us));
        }
    }
    out
}

/// `--slow-query-ms` converted to microseconds; `Some(0)` flags every
/// query (useful for smoke tests), `None` disables detection.
fn slow_query_us(a: &Args) -> Option<u64> {
    a.get_opt::<u64>("slow-query-ms").map(|ms| ms.saturating_mul(1000))
}

/// Start the HTTP scrape endpoint when `--metrics-listen` is present;
/// the returned guard keeps it serving until dropped. The bound
/// address is written to `--metrics-port-file` only after the listener
/// is live (same supervisor contract as `--port-file`).
fn start_metrics_http(
    a: &Args,
    endpoints: HttpEndpoints,
    logger: &Arc<pqdtw::obs::log::JsonLogger>,
) -> Result<Option<HttpServer>> {
    let Some(addr) = a.flags.get("metrics-listen") else {
        ensure!(
            !a.flags.contains_key("metrics-port-file"),
            "--metrics-port-file has no effect without --metrics-listen"
        );
        return Ok(None);
    };
    let server = HttpServer::start(addr, endpoints, HttpConfig::default(), Arc::clone(logger))?;
    let http_addr = server.local_addr();
    if let Some(port_file) = a.flags.get("metrics-port-file") {
        std::fs::write(port_file, http_addr.to_string())
            .with_context(|| format!("writing --metrics-port-file {port_file}"))?;
    }
    println!("metrics on http://{http_addr}/metrics (health: http://{http_addr}/healthz)");
    Ok(Some(server))
}

/// Network serving: cold-start an engine (straight from an index file,
/// or trained from dataset flags), put the threaded service behind a
/// TCP listener, and run until a client sends a `Shutdown` frame.
fn cmd_serve_listen(a: &Args, listen: &str) -> Result<()> {
    reject_flags(
        a,
        &["requests", "topk", "nprobe", "rerank"],
        "has no effect with --listen: serving modes are chosen per request by the \
         connecting clients",
    )?;
    let seed = a.get_parsed("seed", 7u64);
    let mut engine = match a.flags.get("index") {
        Some(path) => {
            reject_build_flags_with_index(a)?;
            reject_flags(
                a,
                &["dataset"],
                "has no effect with --listen --index: queries come from the network, \
                 and the index file carries its own database",
            )?;
            let engine = Engine::open(Path::new(path))?;
            println!(
                "loaded index {path}: {} series × {} samples, ivf={:?} (no retraining)",
                engine.n_items,
                engine.pq.series_len,
                engine.ivf.as_ref().map(|ivf| ivf.nlist())
            );
            engine
        }
        None => {
            let tt = load_dataset(&a.get("dataset", "SpikePosition"), seed)?;
            let cfg = config_from_args(a);
            let mut engine = Engine::build(&tt.train, &cfg, seed)?;
            let nlist = a.get_parsed("nlist", 0usize);
            if nlist > 0 {
                let metric = coarse_metric(a, &engine);
                engine.enable_ivf(nlist, metric, seed);
            }
            engine
        }
    };
    engine.set_scan_threads(a.get_parsed("scan-threads", 1usize));
    let engine = Arc::new(engine);
    let svc = Arc::new(Service::start(
        Arc::clone(&engine),
        ServiceConfig {
            n_workers: a.get_parsed("workers", 2usize),
            batcher: Default::default(),
        },
    ));
    let logger = if a.has("log-json") {
        Arc::new(pqdtw::obs::log::JsonLogger::stderr())
    } else {
        Arc::new(pqdtw::obs::log::JsonLogger::disabled())
    };
    // The durable job plane: background jobs run over the same engine,
    // stream progress through the structured logger, and (with --index)
    // persist their state into the index file so a restart resumes or
    // replays them.
    let jobs = JobManager::start(
        Arc::clone(&engine),
        Arc::clone(&logger),
        a.flags.get("index").map(std::path::PathBuf::from),
        JobConfig { n_workers: a.get_parsed("job-workers", 1usize).max(1), ..Default::default() },
    );
    svc.attach_jobs(Arc::clone(&jobs));
    let server = NetServer::start_logged(
        listen,
        Arc::clone(&svc),
        ServerConfig {
            max_connections: a.get_parsed("max-conns", 64usize),
            slow_query_us: slow_query_us(a),
            ..Default::default()
        },
        Arc::clone(&logger),
    )?;
    let metrics_svc = Arc::clone(&svc);
    let healthz_svc = Arc::clone(&svc);
    let _metrics_http = start_metrics_http(
        a,
        HttpEndpoints {
            metrics: Arc::new(move || metrics_svc.prometheus_text()),
            healthz: Arc::new(move || healthz_svc.healthz_json()),
        },
        &logger,
    )?;
    let addr = server.local_addr();
    if let Some(port_file) = a.flags.get("port-file") {
        // Written only after the listener is live, so a supervisor (or
        // the CI smoke step) can poll this file to learn the bound
        // ephemeral port.
        std::fs::write(port_file, addr.to_string())
            .with_context(|| format!("writing --port-file {port_file}"))?;
    }
    println!("listening on {addr} (stop with `pqdtw shutdown --connect {addr}`)");
    server.wait();
    let m = svc.metrics();
    println!(
        "shutdown: served {} requests ({} errors), {} batches (mean size {:.1})",
        m.requests, m.errors, m.batches, m.mean_batch_size
    );
    println!(
        "latency : mean {:.0}µs, p50 ≤{}µs, p99 ≤{}µs",
        m.mean_latency_us,
        m.percentile_us(0.5),
        m.percentile_us(0.99)
    );
    for c in &m.per_class {
        if c.requests > 0 {
            println!(
                "  {:<16} {:>8} reqs, mean {:>7.0}µs, p50 ≤{}µs, p99 ≤{}µs",
                c.class.name(),
                c.requests,
                c.mean_latency_us,
                c.p50_us,
                c.p99_us
            );
        }
    }
    for st in &m.per_stage {
        if st.count > 0 {
            println!(
                "  stage {:<13} {:>5} spans, mean {:>7.0}µs, p50 ≤{}µs, p99 ≤{}µs",
                st.stage.name(),
                st.count,
                st.mean_us,
                st.p50_us,
                st.p99_us
            );
        }
    }
    Ok(())
}

/// Scatter-gather router front end: no engine of its own, just the
/// supervised shard fleet (`docs/serving-topology.md`). Queries fan out
/// to every shard and merge deterministically; failed shards produce
/// degraded partial results unless `--require-full`.
fn cmd_serve_router(a: &Args) -> Result<()> {
    reject_flags(
        a,
        &[
            "dataset", "index", "workers", "job-workers", "scan-threads", "nlist",
            "coarse", "requests", "topk", "nprobe", "rerank",
        ],
        "has no effect with --router: the router holds no engine — build the shards \
         with `build-index --shard i/n` and serve each with `serve --listen --index`",
    )?;
    let shards: Vec<String> = a
        .require("shards")
        .map_err(anyhow::Error::msg)?
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect();
    ensure!(
        !shards.is_empty(),
        "--shards needs at least one address (comma-separated, in shard order)"
    );
    let listen = a.get("listen", "127.0.0.1:0");
    let mut cfg = RouterConfig::new(shards);
    cfg.require_full = a.has("require-full");
    cfg.slow_query_us = slow_query_us(a);
    let logger = if a.has("log-json") {
        Arc::new(pqdtw::obs::log::JsonLogger::stderr())
    } else {
        Arc::new(pqdtw::obs::log::JsonLogger::disabled())
    };
    let server = RouterServer::start_logged(
        &listen,
        cfg,
        RouterServerConfig {
            max_connections: a.get_parsed("max-conns", 64usize),
            ..Default::default()
        },
        Arc::clone(&logger),
    )?;
    let _metrics_http = start_metrics_http(a, server.http_endpoints(), &logger)?;
    let addr = server.local_addr();
    if let Some(port_file) = a.flags.get("port-file") {
        std::fs::write(port_file, addr.to_string())
            .with_context(|| format!("writing --port-file {port_file}"))?;
    }
    println!(
        "routing {} shards on {addr} (stop with `pqdtw shutdown --connect {addr}`; \
         shard servers keep running)",
        server.router().n_shards()
    );
    let m = server.wait();
    println!(
        "shutdown: routed {} requests ({} errors, {} degraded), {} retries + {} hedges",
        m.requests, m.errors, m.degraded_responses, m.retries, m.hedges
    );
    Ok(())
}

fn cmd_serve(a: &Args) -> Result<()> {
    if a.has("router") {
        return cmd_serve_router(a);
    }
    reject_flags(
        a,
        &["shards", "require-full"],
        "has no effect without --router: a plain server holds one engine (add \
         --router to scatter over a shard fleet)",
    )?;
    if let Some(listen) = a.flags.get("listen") {
        return cmd_serve_listen(a, listen);
    }
    reject_flags(
        a,
        &[
            "port-file", "max-conns", "log-json", "metrics-listen", "metrics-port-file",
            "slow-query-ms",
        ],
        "has no effect without --listen: the local synthetic load loop binds no \
         socket (add --listen <addr> to serve over TCP)",
    )?;
    let seed = a.get_parsed("seed", 7u64);
    let tt = load_dataset(&a.get("dataset", "SpikePosition"), seed)?;
    let topk: usize = a.get_parsed("topk", 0usize); // 0 = classic 1-NN requests
    let nprobe: Option<usize> = a.get_opt("nprobe");
    let rerank: Option<usize> = a.get_opt("rerank");
    let mut engine = match a.flags.get("index") {
        Some(path) => {
            reject_build_flags_with_index(a)?;
            open_index(path, &tt)?
        }
        None => {
            let cfg = config_from_args(a);
            let mut engine = Engine::build(&tt.train, &cfg, seed)?;
            if nprobe.is_some() {
                let nlist = a.get_parsed("nlist", 16usize);
                let metric = coarse_metric(a, &engine);
                engine.enable_ivf(nlist, metric, seed);
            }
            engine
        }
    };
    if nprobe.is_some() && engine.ivf.is_none() {
        bail!("--nprobe requires an IVF index (rebuild the index with --nlist > 0)");
    }
    engine.set_scan_threads(a.get_parsed("scan-threads", 1usize));
    let engine = Arc::new(engine);
    let svc = Service::start(
        engine,
        ServiceConfig {
            n_workers: a.get_parsed("workers", 2usize),
            batcher: Default::default(),
        },
    );
    let n_requests = a.get_parsed("requests", 100usize);
    let t0 = Instant::now();
    for i in 0..n_requests {
        let q = tt.test.row(i % tt.test.n_series()).to_vec();
        let req = if topk > 0 {
            Request::TopKQuery {
                series: q,
                k: topk,
                mode: PqQueryMode::Asymmetric,
                nprobe,
                rerank,
            }
        } else {
            Request::NnQuery { series: q, mode: PqQueryMode::Symmetric, nprobe }
        };
        match svc.call(req) {
            Response::Nn { .. } | Response::TopK(_) => {}
            other => bail!("unexpected response {other:?}"),
        }
    }
    let dt = t0.elapsed();
    let m = svc.shutdown();
    println!("served {} requests in {dt:?} ({:.0} req/s)", m.requests, m.requests as f64 / dt.as_secs_f64());
    println!("mean latency {:.0}µs, p50 ≤{}µs, p99 ≤{}µs, mean batch {:.1}", m.mean_latency_us, m.percentile_us(0.5), m.percentile_us(0.99), m.mean_batch_size);
    for c in &m.per_class {
        if c.requests > 0 {
            println!(
                "  {:<16} {:>6} reqs, mean {:.0}µs, p50 ≤{}µs, p99 ≤{}µs",
                c.class.name(),
                c.requests,
                c.mean_latency_us,
                c.p50_us,
                c.p99_us
            );
        }
    }
    Ok(())
}

/// Print a remote server's metrics snapshot, or (with `--prometheus`)
/// its raw text exposition document for a scrape-compatible pipeline.
fn cmd_stats(a: &Args) -> Result<()> {
    let addr = a.require("connect").map_err(anyhow::Error::msg)?;
    let mut client = Client::connect(&addr, ClientConfig::default())?;
    if a.has("prometheus") {
        print!("{}", client.metrics_text()?);
        return Ok(());
    }
    let s = client.stats()?;
    println!("server   : {addr} (pqdtw {}, up {}s)", s.version, s.uptime_s);
    println!(
        "index    : {} series × {} samples, M={} K={}, window={:.2}, coarse={}, ivf={}",
        s.n_items,
        s.series_len,
        s.n_subspaces,
        s.codebook_size,
        s.window_frac,
        s.coarse_metric,
        match s.nlist {
            Some(n) => format!("{n} cells"),
            None => "none".to_string(),
        }
    );
    println!("requests : {} ({} errors)", s.requests, s.errors);
    println!("batches  : {} (mean size {:.1})", s.batches, s.mean_batch_size);
    println!(
        "latency  : mean {:.0}µs, p50 ≤{}µs, p99 ≤{}µs",
        s.mean_latency_us, s.p50_us, s.p99_us
    );
    for c in &s.per_class {
        if c.requests > 0 {
            println!(
                "  {:<16} {:>8} reqs, mean {:>7.0}µs, p50 ≤{}µs, p99 ≤{}µs",
                c.name, c.requests, c.mean_latency_us, c.p50_us, c.p99_us
            );
        }
    }
    println!("stages   :");
    for st in &s.per_stage {
        if st.count > 0 {
            println!(
                "  {:<16} {:>8} spans, mean {:>7.0}µs, p50 ≤{}µs, p99 ≤{}µs",
                st.name, st.count, st.mean_us, st.p50_us, st.p99_us
            );
        }
    }
    println!(
        "scan     : {} items, {} abandoned ({:.1}%), {} blocks skipped, {} LUT collapses",
        s.scan.items_scanned,
        s.scan.items_abandoned,
        100.0 * s.scan.abandon_rate(),
        s.scan.blocks_skipped,
        s.scan.lut_collapses
    );
    Ok(())
}

/// Ask a remote server to drain and exit.
fn cmd_shutdown(a: &Args) -> Result<()> {
    let addr = a.require("connect").map_err(anyhow::Error::msg)?;
    let mut client = Client::connect(&addr, ClientConfig::default())?;
    client.shutdown()?;
    println!("server {addr} acknowledged shutdown and is draining");
    Ok(())
}

/// Shared `--connect`/`--id` preamble for the job verbs that address
/// an existing job.
fn job_client(a: &Args) -> Result<(Client, u64)> {
    let addr = a.require("connect").map_err(anyhow::Error::msg)?;
    let id: u64 = a
        .require("id")
        .map_err(anyhow::Error::msg)?
        .parse()
        .context("--id must be a job id (a non-negative integer)")?;
    Ok((Client::connect(&addr, ClientConfig::default())?, id))
}

fn print_job_snapshot(s: &JobSnapshot) {
    let pct = if s.total > 0 { 100.0 * s.done as f64 / s.total as f64 } else { 0.0 };
    let eta = match s.eta_us {
        Some(us) => format!("{:.1}s", us as f64 / 1e6),
        None => "-".to_string(),
    };
    println!(
        "job {}: {} [{}] {}/{} chunks ({pct:.1}%), eta {eta}, latest event seq {}",
        s.id,
        s.kind.name(),
        s.status.name(),
        s.done,
        s.total,
        s.latest_seq
    );
    if let pqdtw::jobs::JobStatus::Failed(msg) = &s.status {
        println!("  error: {msg}");
    }
}

/// Submit a background job to a remote server. The spec flags mirror
/// the query verbs: `--kind all-pairs` takes the top-k serving dial,
/// `--kind cluster` the k-medoids shape, `--kind autotune` the
/// recall-target sweep.
fn cmd_job_submit(a: &Args) -> Result<()> {
    let addr = a.require("connect").map_err(anyhow::Error::msg)?;
    let kind = a.get("kind", "all-pairs");
    let mode = if a.get("mode", "asymmetric") == "symmetric" {
        PqQueryMode::Symmetric
    } else {
        PqQueryMode::Asymmetric
    };
    let spec = match kind.as_str() {
        "all-pairs" | "all_pairs_topk" => JobSpec::AllPairsTopK {
            k: a.get_parsed("topk", 5usize).max(1),
            mode,
            nprobe: a.get_opt("nprobe"),
            rerank: a.get_opt("rerank"),
        },
        "cluster" | "cluster_sweep" => JobSpec::ClusterSweep {
            k_clusters: a.get_parsed("clusters", 8usize),
            max_iters: a.get_parsed("iters", 10usize),
            seed: a.get_parsed("seed", 7u64),
        },
        "autotune" | "autotune_nprobe" => JobSpec::AutotuneNprobe {
            k: a.get_parsed("topk", 10usize).max(1),
            target_recall: a.get_parsed("target-recall", 0.95f64),
            sample: a.get_parsed("sample", 32usize),
        },
        other => bail!("unknown --kind '{other}' (valid: all-pairs|cluster|autotune)"),
    };
    let mut client = Client::connect(&addr, ClientConfig::default())?;
    let id = client.job_submit(spec)?;
    println!("job {id} submitted ({kind})");
    println!("  follow with `pqdtw job events --connect {addr} --id {id} --follow`");
    Ok(())
}

fn cmd_job_status(a: &Args) -> Result<()> {
    let (mut client, id) = job_client(a)?;
    print_job_snapshot(&client.job_status(id)?);
    Ok(())
}

/// A transport-level failure (an I/O error somewhere in the chain):
/// retryable on a fresh connection, unlike an application `Error`
/// frame (e.g. "no such job"), which would fail identically forever.
fn is_transport_error(err: &anyhow::Error) -> bool {
    err.chain().any(|c| c.downcast_ref::<std::io::Error>().is_some())
}

/// Print a job's progress events past `--cursor`. With `--follow`,
/// keep polling (and advancing the cursor) until the job reaches a
/// terminal status, then print the final snapshot. Losing the server
/// connection mid-follow is survivable: the cursor protocol is
/// resumable by design (event seqs are stable server-side), so the
/// client reconnects with jittered backoff, re-polls from the last
/// cursor, and prints a single `reconnected` notice — no events are
/// double-printed and none are skipped.
fn cmd_job_events(a: &Args) -> Result<()> {
    let addr = a.require("connect").map_err(anyhow::Error::msg)?;
    let id: u64 = a
        .require("id")
        .map_err(anyhow::Error::msg)?
        .parse()
        .context("--id must be a job id (a non-negative integer)")?;
    let mut cursor: u64 = a.get_parsed("cursor", 0u64);
    let max: usize =
        a.get_parsed("max", 256usize).clamp(1, pqdtw::net::protocol::MAX_JOB_EVENTS);
    let follow = a.has("follow");
    let mut client = Client::connect(&addr, ClientConfig::default())?;
    let mut reconnecting = false;
    loop {
        let step = client
            .job_events(id, cursor, max)
            .and_then(|(events, _latest_seq)| client.job_status(id).map(|s| (events, s)));
        let (events, snap) = match step {
            Ok(v) => v,
            Err(err) if follow && is_transport_error(&err) => {
                if !reconnecting {
                    println!("  connection to {addr} lost ({err:#}); reconnecting");
                    reconnecting = true;
                }
                client = connect_with_retry(
                    &addr,
                    ClientConfig::default(),
                    RetryConfig { attempts: 30, ..Default::default() },
                )?;
                continue;
            }
            Err(err) => return Err(err),
        };
        if reconnecting {
            println!("  reconnected to {addr}, resuming from cursor {cursor}");
            reconnecting = false;
        }
        for e in &events {
            let eta = match e.eta_us {
                Some(us) => format!(" (eta {:.1}s)", us as f64 / 1e6),
                None => String::new(),
            };
            println!(
                "  seq {:>4} [{}] {}/{} {}{eta}",
                e.seq,
                e.stage.name(),
                e.done,
                e.total,
                e.message
            );
            cursor = e.seq;
        }
        if !follow || snap.status.is_terminal() {
            print_job_snapshot(&snap);
            return Ok(());
        }
        std::thread::sleep(std::time::Duration::from_millis(100));
    }
}

fn cmd_job_cancel(a: &Args) -> Result<()> {
    let (mut client, id) = job_client(a)?;
    let snap = client.job_cancel(id)?;
    println!("cancel requested (a running job stops at its next chunk boundary):");
    print_job_snapshot(&snap);
    Ok(())
}

/// Fetch and summarize a completed job's persisted result.
fn cmd_job_result(a: &Args) -> Result<()> {
    let (mut client, id) = job_client(a)?;
    match client.job_result(id)? {
        JobResult::AllPairs(rows) => {
            println!("job {id}: all-pairs top-k result, {} rows", rows.len());
            for row in rows.iter().take(5) {
                match row.hits.first() {
                    Some(h) => println!(
                        "  query #{:<6} best hit #{} d={:.6} ({} hits, {} explains)",
                        row.query_index,
                        h.index,
                        h.distance,
                        row.hits.len(),
                        row.explains.len()
                    ),
                    None => println!("  query #{:<6} (no hits)", row.query_index),
                }
            }
            if rows.len() > 5 {
                println!("  … {} more rows", rows.len() - 5);
            }
        }
        JobResult::Cluster { medoids, assignment, cost } => {
            println!(
                "job {id}: k-medoids result, k={} over {} items, cost {cost:.6}",
                medoids.len(),
                assignment.len()
            );
            println!("  medoids: {medoids:?}");
        }
        JobResult::Autotune { recommended_nprobe, sweep } => {
            println!("job {id}: autotune result — recommended nprobe {recommended_nprobe}");
            for p in &sweep {
                println!("  nprobe {:>5} -> recall {:.4}", p.nprobe, p.recall);
            }
        }
    }
    Ok(())
}

/// Offline top-k driver: one engine (trained in memory or reopened
/// from an index file), the three serving modes side by side, with
/// recall of the probed scan against the exhaustive one. With
/// `--index --verify`, additionally retrains an in-memory engine from
/// the same flags and asserts the loaded index answers bit-identically
/// (the CI smoke test's diff).
fn cmd_topk(a: &Args) -> Result<()> {
    let seed = a.get_parsed("seed", 7u64);
    let tt = load_dataset(&a.get("dataset", "CBF"), seed)?;
    let cfg = config_from_args(a);
    let k = a.get_parsed("topk", 5usize).max(1);
    let index_path = a.flags.get("index").cloned();
    ensure!(
        index_path.is_some() || !a.has("verify"),
        "--verify compares a loaded index against a fresh engine and needs --index <path>"
    );
    let mut engine = match &index_path {
        Some(path) => {
            // With --verify the build flags are *used* (they configure
            // the in-memory reference engine); without it they would be
            // silently ignored, so reject them.
            if !a.has("verify") {
                reject_build_flags_with_index(a)?;
            }
            let engine = open_index(path, &tt)?;
            ensure!(
                engine.ivf.is_some(),
                "index {path} has no IVF section; rebuild with `build-index --nlist > 0`"
            );
            engine
        }
        None => {
            let mut engine = Engine::build(&tt.train, &cfg, seed)?;
            let nlist = a.get_parsed("nlist", 16usize);
            let metric = coarse_metric(a, &engine);
            engine.enable_ivf(nlist, metric, seed);
            engine
        }
    };
    engine.set_scan_threads(a.get_parsed("scan-threads", 1usize));
    let nlist = engine.ivf.as_ref().map(|ivf| ivf.nlist()).unwrap_or(1);
    let nprobe = a.get_opt("nprobe").unwrap_or_else(|| (nlist / 4).max(1));
    let rerank = a.get_opt("rerank").unwrap_or(4 * k);
    let n_queries = a.get_parsed("queries", 30usize).min(tt.test.n_series());

    if index_path.is_some() && a.has("verify") {
        // Rebuild the engine in memory from the same dataset/config
        // flags and diff every serving mode. Training is deterministic
        // per seed, so the answers must be bit-identical as long as the
        // flags match the ones `build-index` ran with.
        let mut reference = Engine::build(&tt.train, &cfg, seed)?;
        let nlist_flag = a.get_parsed("nlist", 16usize);
        ensure!(nlist_flag > 0, "--verify needs --nlist matching the build (got 0)");
        let metric = coarse_metric(a, &reference);
        reference.enable_ivf(nlist_flag, metric, seed);
        let ref_nlist = reference.ivf.as_ref().map(|ivf| ivf.nlist()).unwrap_or(1);
        ensure!(
            ref_nlist == nlist,
            "in-memory IVF has {ref_nlist} cells but the index has {nlist} — \
             do the flags match the ones build-index ran with?"
        );
        for i in 0..n_queries {
            let q = tt.test.row(i).to_vec();
            for req in [
                Request::TopKQuery {
                    series: q.clone(),
                    k,
                    mode: PqQueryMode::Asymmetric,
                    nprobe: None,
                    rerank: None,
                },
                Request::TopKQuery {
                    series: q.clone(),
                    k,
                    mode: PqQueryMode::Asymmetric,
                    nprobe: Some(nprobe),
                    rerank: None,
                },
                Request::TopKQuery {
                    series: q,
                    k,
                    mode: PqQueryMode::Asymmetric,
                    nprobe: None,
                    rerank: Some(rerank),
                },
            ] {
                let got = engine.handle(&req);
                let want = reference.handle(&req);
                ensure!(
                    got == want,
                    "loaded index diverges from the in-memory engine on query {i}: \
                     {got:?} vs {want:?}"
                );
            }
        }
        println!(
            "verify: {n_queries} queries × 3 modes bit-identical between the loaded \
             index and a freshly trained engine ✓"
        );
    }

    println!(
        "top-k serving on {} (n={}, k={k}, nlist={nlist}, nprobe={nprobe}, rerank depth {rerank})",
        tt.name,
        engine.n_items
    );
    let mut overlap = 0usize;
    let mut t_exh = 0.0f64;
    let mut t_probe = 0.0f64;
    let mut t_rerank = 0.0f64;
    for i in 0..n_queries {
        let q = tt.test.row(i).to_vec();
        let t0 = Instant::now();
        let exh = engine.handle(&Request::TopKQuery {
            series: q.clone(),
            k,
            mode: PqQueryMode::Asymmetric,
            nprobe: None,
            rerank: None,
        });
        t_exh += t0.elapsed().as_secs_f64();
        let t0 = Instant::now();
        let probed = engine.handle(&Request::TopKQuery {
            series: q.clone(),
            k,
            mode: PqQueryMode::Asymmetric,
            nprobe: Some(nprobe),
            rerank: None,
        });
        t_probe += t0.elapsed().as_secs_f64();
        let t0 = Instant::now();
        let reranked = engine.handle(&Request::TopKQuery {
            series: q,
            k,
            mode: PqQueryMode::Asymmetric,
            nprobe: None,
            rerank: Some(rerank),
        });
        t_rerank += t0.elapsed().as_secs_f64();
        match (exh, probed, reranked) {
            (Response::TopK(e), Response::TopK(p), Response::TopK(_)) => {
                let truth: std::collections::HashSet<usize> =
                    e.iter().map(|h| h.index).collect();
                overlap += p.iter().filter(|h| truth.contains(&h.index)).count();
            }
            other => bail!("unexpected responses {other:?}"),
        }
    }
    let denom = (n_queries * k) as f64;
    println!("recall@{k} of probed vs exhaustive: {:.3}", overlap as f64 / denom);
    println!(
        "mean latency: exhaustive {:.0}µs | probed {:.0}µs | reranked {:.0}µs",
        1e6 * t_exh / n_queries as f64,
        1e6 * t_probe / n_queries as f64,
        1e6 * t_rerank / n_queries as f64,
    );
    println!("(probing all {nlist} cells reproduces the exhaustive scan bit-for-bit)");
    Ok(())
}

fn cmd_selftest(a: &Args) -> Result<()> {
    let seed = a.get_parsed("seed", 3u64);
    println!("[1/4] training + encoding on CBF…");
    let tt = load_dataset("CBF", seed)?;
    let cfg = PqConfig { n_subspaces: 4, codebook_size: 16, window_frac: 0.2, ..Default::default() };
    let pq = ProductQuantizer::train(&tt.train, &cfg, seed)?;
    let enc = pq.encode_dataset(&tt.train);
    anyhow::ensure!(enc.n() == tt.train.n_series(), "encode count");

    println!("[2/4] 1-NN sanity…");
    let (err, _) = nn_classify_pq(&pq, &enc, &tt.test, PqQueryMode::Asymmetric);
    anyhow::ensure!(err < 0.67, "PQDTW no better than chance: {err}");

    println!("[3/4] service round-trip (1-NN + top-k, probed and re-ranked)…");
    let mut engine = Engine::build(&tt.train, &cfg, seed)?;
    engine.enable_ivf(8, CoarseMetric::Dtw { window: engine.full_window() }, seed);
    let nlist = engine.ivf.as_ref().map(|ivf| ivf.nlist()).unwrap_or(1);
    let engine = Arc::new(engine);
    let svc = Service::start(engine, ServiceConfig::default());
    let r = svc.call(Request::NnQuery {
        series: tt.test.row(0).to_vec(),
        mode: PqQueryMode::Symmetric,
        nprobe: None,
    });
    anyhow::ensure!(matches!(r, Response::Nn { .. }), "service response");
    let exh = svc.call(Request::TopKQuery {
        series: tt.test.row(0).to_vec(),
        k: 3,
        mode: PqQueryMode::Asymmetric,
        nprobe: None,
        rerank: None,
    });
    let probed_full = svc.call(Request::TopKQuery {
        series: tt.test.row(0).to_vec(),
        k: 3,
        mode: PqQueryMode::Asymmetric,
        nprobe: Some(nlist),
        rerank: None,
    });
    anyhow::ensure!(exh == probed_full, "full probe must match exhaustive scan");
    let reranked = svc.call(Request::TopKQuery {
        series: tt.test.row(0).to_vec(),
        k: 3,
        mode: PqQueryMode::Asymmetric,
        nprobe: None,
        rerank: Some(12),
    });
    anyhow::ensure!(matches!(reranked, Response::TopK(ref h) if h.len() == 3), "re-rank");
    svc.shutdown();

    #[cfg(feature = "pjrt")]
    {
        println!("[4/4] PJRT artifact execution…");
        let dir = pqdtw::runtime::artifacts::Manifest::default_dir();
        if dir.join("manifest.tsv").exists() {
            use pqdtw::data::random_walk::RandomWalks;
            let data = RandomWalks::new(97).generate(32, 100);
            let cfg = PqConfig { n_subspaces: 4, codebook_size: 16, window_frac: 0.2, ..Default::default() };
            let pq = ProductQuantizer::train(&data, &cfg, 11)?;
            let manifest = pqdtw::runtime::artifacts::Manifest::load(&dir)?;
            let mut enc = pqdtw::runtime::encoder::PjrtEncoder::new(&pq, &manifest)?;
            let codes = enc.encode(&pq, data.row(0))?;
            anyhow::ensure!(codes.len() == 4, "pjrt encode");
        } else {
            println!("      (skipped: no artifacts/ — run `make artifacts`)");
        }
    }
    #[cfg(not(feature = "pjrt"))]
    println!("[4/4] PJRT check skipped (build with --features pjrt)");

    println!("selftest OK");
    Ok(())
}

fn cmd_info(a: &Args) -> Result<()> {
    if let Some(path) = a.flags.get("index") {
        let h = pqdtw::store::read_header(Path::new(path))?;
        println!("index    : {path}");
        println!("format   : version {} ({} bytes on disk)", h.version, h.file_bytes);
        println!(
            "quantizer: M={} K={} L={} window={:?} metric={:?}",
            h.n_subspaces, h.codebook_size, h.sub_len, h.window, h.metric
        );
        println!("database : {} series × {} samples", h.n_series, h.series_len);
        match h.ivf_nlist {
            Some(nlist) => println!("ivf      : {nlist} coarse cells"),
            None => println!("ivf      : none (exhaustive scans only)"),
        }
        return Ok(());
    }
    println!("pqdtw {} — Elastic Product Quantization for Time Series", env!("CARGO_PKG_VERSION"));
    println!("features : pjrt={}", cfg!(feature = "pjrt"));
    println!("datasets : synthetic UCR-like suite of 16, RandomWalk[-<n>x<len>] (or UCR_ARCHIVE_DIR)");
    let dir = pqdtw::runtime::artifacts::Manifest::default_dir();
    match pqdtw::runtime::artifacts::Manifest::load(&dir) {
        Ok(m) => println!("artifacts: {} in {}", m.specs.len(), dir.display()),
        Err(_) => println!("artifacts: none (run `make artifacts`)"),
    }
    Ok(())
}

fn main() -> Result<()> {
    let mut args = Args::from_env();
    if args.command.is_empty() {
        args.command = "info".to_string();
    }
    if args.command == "job" {
        args.promote_action().map_err(anyhow::Error::msg)?;
    }
    args.validate(SPECS).map_err(anyhow::Error::msg)?;
    match args.command.as_str() {
        "train" => cmd_train(&args),
        "query" => cmd_query(&args),
        "topk" => cmd_topk(&args),
        "cluster" => cmd_cluster(&args),
        "build-index" => cmd_build_index(&args),
        "bench-scan" => cmd_bench_scan(&args),
        "serve" => cmd_serve(&args),
        "stats" => cmd_stats(&args),
        "shutdown" => cmd_shutdown(&args),
        "job submit" => cmd_job_submit(&args),
        "job status" => cmd_job_status(&args),
        "job events" => cmd_job_events(&args),
        "job cancel" => cmd_job_cancel(&args),
        "job result" => cmd_job_result(&args),
        "selftest" => cmd_selftest(&args),
        "info" => cmd_info(&args),
        other => bail!("unknown command '{other}'"), // unreachable after validate
    }
}
