//! `pqdtw` — leader binary: train / encode / query / topk / cluster /
//! serve / selftest over the PQDTW library.
//!
//! Examples:
//!   pqdtw selftest
//!   pqdtw train --dataset CBF --subspaces 4 --codebook 32
//!   pqdtw query --dataset CBF --mode asymmetric --queries 50
//!   pqdtw topk --dataset CBF --topk 5 --nlist 16 --nprobe 4 --rerank 20
//!   pqdtw cluster --dataset Waveforms --linkage complete
//!   pqdtw serve --workers 4 --requests 200 --topk 5 --nprobe 4
//!   pqdtw info

use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use pqdtw::cluster::{agglomerative, compact_labels, rand_index, Linkage};
use pqdtw::coordinator::{Engine, Request, Response, Service, ServiceConfig};
use pqdtw::core::matrix::CondensedMatrix;
use pqdtw::data::ucr_like::{ucr_like_by_name, TrainTest};
use pqdtw::nn::ivf::CoarseMetric;
use pqdtw::nn::knn::{nn_classify_pq, nn_classify_raw, PqQueryMode};
use pqdtw::distance::measure::Measure;
use pqdtw::pq::quantizer::{PqConfig, PqMetric, PrealignConfig, ProductQuantizer};

use pqdtw::cli::Args;

fn load_dataset(name: &str, seed: u64) -> Result<TrainTest> {
    // Real UCR archive takes precedence when available.
    if let Ok(dir) = std::env::var("UCR_ARCHIVE_DIR") {
        let dir = std::path::PathBuf::from(dir);
        if dir.join(name).exists() {
            return pqdtw::data::ucr_loader::load_ucr_dataset(&dir, name);
        }
    }
    ucr_like_by_name(name, seed)
        .with_context(|| format!("unknown dataset '{name}' (and no UCR_ARCHIVE_DIR)"))
}

fn config_from_args(a: &Args) -> PqConfig {
    let tail: f64 = a.get_parsed("tail", 0.0f64);
    PqConfig {
        n_subspaces: a.get_parsed("subspaces", 4usize),
        codebook_size: a.get_parsed("codebook", 64usize),
        window_frac: a.get_parsed("window", 0.1f64),
        metric: if a.get("metric", "dtw") == "ed" { PqMetric::Euclidean } else { PqMetric::Dtw },
        prealign: (tail > 0.0).then(|| PrealignConfig {
            level: a.get_parsed("level", 2usize),
            tail_frac: tail,
        }),
        kmeans_iters: a.get_parsed("kmeans-iters", 8usize),
        dba_iters: a.get_parsed("dba-iters", 3usize),
        train_subsample: None,
    }
}

fn cmd_train(a: &Args) -> Result<()> {
    let seed = a.get_parsed("seed", 7u64);
    let tt = load_dataset(&a.get("dataset", "CBF"), seed)?;
    let cfg = config_from_args(a);
    let t0 = Instant::now();
    let pq = ProductQuantizer::train(&tt.train, &cfg, seed)?;
    let train_t = t0.elapsed();
    let t0 = Instant::now();
    let enc = pq.encode_dataset(&tt.train);
    let enc_t = t0.elapsed();
    let mm = pq.memory_model();
    println!("dataset        : {} (n={}, D={})", tt.name, tt.train.n_series(), tt.train.len);
    println!("codebook       : M={} K={} L={} window={:?}", cfg.n_subspaces, pq.codebook.k, pq.codebook.sub_len, pq.codebook.window);
    println!("train time     : {train_t:?}");
    println!("encode time    : {enc_t:?} ({} series)", enc.n());
    println!("compression    : {:.1}x ({} -> {} bits/series)", mm.compression_factor, mm.raw_bits_per_series, mm.code_bits_per_series);
    println!("aux memory     : {:.2} MB", mm.aux_bits() as f64 / 8.0 / 1024.0 / 1024.0);
    let st = enc.stats;
    println!(
        "encode pruning : {} candidates, {:.1}% kim, {:.1}% keogh, {:.1}% dtw ({:.1}% abandoned)",
        st.candidates(),
        100.0 * st.pruned_kim as f64 / st.candidates().max(1) as f64,
        100.0 * st.pruned_keogh as f64 / st.candidates().max(1) as f64,
        100.0 * st.dtw_evals as f64 / st.candidates().max(1) as f64,
        100.0 * st.dtw_abandoned as f64 / st.dtw_evals.max(1) as f64,
    );
    Ok(())
}

fn cmd_query(a: &Args) -> Result<()> {
    let seed = a.get_parsed("seed", 7u64);
    let tt = load_dataset(&a.get("dataset", "CBF"), seed)?;
    let cfg = config_from_args(a);
    let mode = if a.get("mode", "asymmetric") == "symmetric" {
        PqQueryMode::Symmetric
    } else {
        PqQueryMode::Asymmetric
    };
    let pq = ProductQuantizer::train(&tt.train, &cfg, seed)?;
    let enc = pq.encode_dataset(&tt.train);
    let n_queries = a.get_parsed("queries", tt.test.n_series());
    let test = tt.test.subset(&(0..n_queries.min(tt.test.n_series())).collect::<Vec<_>>());
    let t0 = Instant::now();
    let (err, _) = nn_classify_pq(&pq, &enc, &test, mode);
    let dt = t0.elapsed();
    let (err_ed, _) = nn_classify_raw(&tt.train, &test, Measure::Euclidean);
    println!("dataset   : {}", tt.name);
    println!("mode      : {mode:?}");
    println!("1NN error : PQDTW {err:.4} | ED {err_ed:.4}");
    println!("query time: {dt:?} ({} queries)", test.n_series());
    Ok(())
}

fn cmd_cluster(a: &Args) -> Result<()> {
    let seed = a.get_parsed("seed", 7u64);
    let tt = load_dataset(&a.get("dataset", "Waveforms"), seed)?;
    let cfg = config_from_args(a);
    let linkage = match a.get("linkage", "complete").as_str() {
        "single" => Linkage::Single,
        "average" => Linkage::Average,
        _ => Linkage::Complete,
    };
    let pq = ProductQuantizer::train(&tt.train, &cfg, seed)?;
    let enc = pq.encode_dataset(&tt.test);
    let n = tt.test.n_series();
    let t0 = Instant::now();
    let dist = CondensedMatrix::build(n, |i, j| pq.patched_distance(&enc, i, j));
    let dend = agglomerative(&dist, linkage);
    let k = tt.test.classes().len();
    let labels = dend.cut(k);
    let dt = t0.elapsed();
    let truth = compact_labels(&tt.test.labels);
    println!("dataset : {}", tt.name);
    println!("linkage : {linkage:?}, k={k}");
    println!("RI      : {:.4}", rand_index(&labels, &truth));
    println!("time    : {dt:?} (n={n})");
    Ok(())
}

fn cmd_serve(a: &Args) -> Result<()> {
    let seed = a.get_parsed("seed", 7u64);
    let tt = load_dataset(&a.get("dataset", "SpikePosition"), seed)?;
    let cfg = config_from_args(a);
    let topk: usize = a.get_parsed("topk", 0usize); // 0 = classic 1-NN requests
    let nprobe: Option<usize> = a.get_opt("nprobe");
    let rerank: Option<usize> = a.get_opt("rerank");
    let mut engine = Engine::build(&tt.train, &cfg, seed)?;
    engine.set_scan_threads(a.get_parsed("scan-threads", 1usize));
    if nprobe.is_some() {
        let nlist = a.get_parsed("nlist", 16usize);
        let metric = if a.get("coarse", "dtw") == "ed" {
            CoarseMetric::Euclidean
        } else {
            CoarseMetric::Dtw { window: engine.full_window() }
        };
        engine.enable_ivf(nlist, metric, seed);
    }
    let engine = Arc::new(engine);
    let svc = Service::start(
        engine,
        ServiceConfig {
            n_workers: a.get_parsed("workers", 2usize),
            batcher: Default::default(),
        },
    );
    let n_requests = a.get_parsed("requests", 100usize);
    let t0 = Instant::now();
    for i in 0..n_requests {
        let q = tt.test.row(i % tt.test.n_series()).to_vec();
        let req = if topk > 0 {
            Request::TopKQuery {
                series: q,
                k: topk,
                mode: PqQueryMode::Asymmetric,
                nprobe,
                rerank,
            }
        } else {
            Request::NnQuery { series: q, mode: PqQueryMode::Symmetric, nprobe }
        };
        match svc.call(req) {
            Response::Nn { .. } | Response::TopK(_) => {}
            other => bail!("unexpected response {other:?}"),
        }
    }
    let dt = t0.elapsed();
    let m = svc.shutdown();
    println!("served {} requests in {dt:?} ({:.0} req/s)", m.requests, m.requests as f64 / dt.as_secs_f64());
    println!("mean latency {:.0}µs, p50 ≤{}µs, p99 ≤{}µs, mean batch {:.1}", m.mean_latency_us, m.percentile_us(0.5), m.percentile_us(0.99), m.mean_batch_size);
    for c in &m.per_class {
        if c.requests > 0 {
            println!("  {:<16} {:>6} reqs, mean {:.0}µs", c.class.name(), c.requests, c.mean_latency_us);
        }
    }
    Ok(())
}

/// Offline top-k driver: one engine, the three serving modes side by
/// side, with recall of the probed scan against the exhaustive one.
fn cmd_topk(a: &Args) -> Result<()> {
    let seed = a.get_parsed("seed", 7u64);
    let tt = load_dataset(&a.get("dataset", "CBF"), seed)?;
    let cfg = config_from_args(a);
    let k = a.get_parsed("topk", 5usize).max(1);
    let nlist = a.get_parsed("nlist", 16usize);
    let mut engine = Engine::build(&tt.train, &cfg, seed)?;
    engine.set_scan_threads(a.get_parsed("scan-threads", 1usize));
    let metric = if a.get("coarse", "dtw") == "ed" {
        CoarseMetric::Euclidean
    } else {
        CoarseMetric::Dtw { window: engine.full_window() }
    };
    engine.enable_ivf(nlist, metric, seed);
    let nlist = engine.ivf.as_ref().map(|ivf| ivf.nlist()).unwrap_or(1);
    let nprobe = a.get_opt("nprobe").unwrap_or_else(|| (nlist / 4).max(1));
    let rerank = a.get_opt("rerank").unwrap_or(4 * k);
    let n_queries = a.get_parsed("queries", 30usize).min(tt.test.n_series());

    println!(
        "top-k serving on {} (n={}, k={k}, nlist={nlist}, nprobe={nprobe}, rerank depth {rerank})",
        tt.name,
        engine.n_items
    );
    let mut overlap = 0usize;
    let mut t_exh = 0.0f64;
    let mut t_probe = 0.0f64;
    let mut t_rerank = 0.0f64;
    for i in 0..n_queries {
        let q = tt.test.row(i).to_vec();
        let t0 = Instant::now();
        let exh = engine.handle(&Request::TopKQuery {
            series: q.clone(),
            k,
            mode: PqQueryMode::Asymmetric,
            nprobe: None,
            rerank: None,
        });
        t_exh += t0.elapsed().as_secs_f64();
        let t0 = Instant::now();
        let probed = engine.handle(&Request::TopKQuery {
            series: q.clone(),
            k,
            mode: PqQueryMode::Asymmetric,
            nprobe: Some(nprobe),
            rerank: None,
        });
        t_probe += t0.elapsed().as_secs_f64();
        let t0 = Instant::now();
        let reranked = engine.handle(&Request::TopKQuery {
            series: q,
            k,
            mode: PqQueryMode::Asymmetric,
            nprobe: None,
            rerank: Some(rerank),
        });
        t_rerank += t0.elapsed().as_secs_f64();
        match (exh, probed, reranked) {
            (Response::TopK(e), Response::TopK(p), Response::TopK(_)) => {
                let truth: std::collections::HashSet<usize> =
                    e.iter().map(|h| h.index).collect();
                overlap += p.iter().filter(|h| truth.contains(&h.index)).count();
            }
            other => bail!("unexpected responses {other:?}"),
        }
    }
    let denom = (n_queries * k) as f64;
    println!("recall@{k} of probed vs exhaustive: {:.3}", overlap as f64 / denom);
    println!(
        "mean latency: exhaustive {:.0}µs | probed {:.0}µs | reranked {:.0}µs",
        1e6 * t_exh / n_queries as f64,
        1e6 * t_probe / n_queries as f64,
        1e6 * t_rerank / n_queries as f64,
    );
    println!("(probing all {nlist} cells reproduces the exhaustive scan bit-for-bit)");
    Ok(())
}

fn cmd_selftest(a: &Args) -> Result<()> {
    let seed = a.get_parsed("seed", 3u64);
    println!("[1/4] training + encoding on CBF…");
    let tt = load_dataset("CBF", seed)?;
    let cfg = PqConfig { n_subspaces: 4, codebook_size: 16, window_frac: 0.2, ..Default::default() };
    let pq = ProductQuantizer::train(&tt.train, &cfg, seed)?;
    let enc = pq.encode_dataset(&tt.train);
    anyhow::ensure!(enc.n() == tt.train.n_series(), "encode count");

    println!("[2/4] 1-NN sanity…");
    let (err, _) = nn_classify_pq(&pq, &enc, &tt.test, PqQueryMode::Asymmetric);
    anyhow::ensure!(err < 0.67, "PQDTW no better than chance: {err}");

    println!("[3/4] service round-trip (1-NN + top-k, probed and re-ranked)…");
    let mut engine = Engine::build(&tt.train, &cfg, seed)?;
    engine.enable_ivf(8, CoarseMetric::Dtw { window: engine.full_window() }, seed);
    let nlist = engine.ivf.as_ref().map(|ivf| ivf.nlist()).unwrap_or(1);
    let engine = Arc::new(engine);
    let svc = Service::start(engine, ServiceConfig::default());
    let r = svc.call(Request::NnQuery {
        series: tt.test.row(0).to_vec(),
        mode: PqQueryMode::Symmetric,
        nprobe: None,
    });
    anyhow::ensure!(matches!(r, Response::Nn { .. }), "service response");
    let exh = svc.call(Request::TopKQuery {
        series: tt.test.row(0).to_vec(),
        k: 3,
        mode: PqQueryMode::Asymmetric,
        nprobe: None,
        rerank: None,
    });
    let probed_full = svc.call(Request::TopKQuery {
        series: tt.test.row(0).to_vec(),
        k: 3,
        mode: PqQueryMode::Asymmetric,
        nprobe: Some(nlist),
        rerank: None,
    });
    anyhow::ensure!(exh == probed_full, "full probe must match exhaustive scan");
    let reranked = svc.call(Request::TopKQuery {
        series: tt.test.row(0).to_vec(),
        k: 3,
        mode: PqQueryMode::Asymmetric,
        nprobe: None,
        rerank: Some(12),
    });
    anyhow::ensure!(matches!(reranked, Response::TopK(ref h) if h.len() == 3), "re-rank");
    svc.shutdown();

    #[cfg(feature = "pjrt")]
    {
        println!("[4/4] PJRT artifact execution…");
        let dir = pqdtw::runtime::artifacts::Manifest::default_dir();
        if dir.join("manifest.tsv").exists() {
            use pqdtw::data::random_walk::RandomWalks;
            let data = RandomWalks::new(97).generate(32, 100);
            let cfg = PqConfig { n_subspaces: 4, codebook_size: 16, window_frac: 0.2, ..Default::default() };
            let pq = ProductQuantizer::train(&data, &cfg, 11)?;
            let manifest = pqdtw::runtime::artifacts::Manifest::load(&dir)?;
            let mut enc = pqdtw::runtime::encoder::PjrtEncoder::new(&pq, &manifest)?;
            let codes = enc.encode(&pq, data.row(0))?;
            anyhow::ensure!(codes.len() == 4, "pjrt encode");
        } else {
            println!("      (skipped: no artifacts/ — run `make artifacts`)");
        }
    }
    #[cfg(not(feature = "pjrt"))]
    println!("[4/4] PJRT check skipped (build with --features pjrt)");

    println!("selftest OK");
    Ok(())
}

fn cmd_info() -> Result<()> {
    println!("pqdtw {} — Elastic Product Quantization for Time Series", env!("CARGO_PKG_VERSION"));
    println!("features : pjrt={}", cfg!(feature = "pjrt"));
    println!("datasets : synthetic UCR-like suite of 16 (or UCR_ARCHIVE_DIR)");
    let dir = pqdtw::runtime::artifacts::Manifest::default_dir();
    match pqdtw::runtime::artifacts::Manifest::load(&dir) {
        Ok(m) => println!("artifacts: {} in {}", m.specs.len(), dir.display()),
        Err(_) => println!("artifacts: none (run `make artifacts`)"),
    }
    Ok(())
}

fn main() -> Result<()> {
    let args = Args::from_env();
    match args.command.as_str() {
        "train" => cmd_train(&args),
        "query" => cmd_query(&args),
        "topk" => cmd_topk(&args),
        "cluster" => cmd_cluster(&args),
        "serve" => cmd_serve(&args),
        "selftest" => cmd_selftest(&args),
        "info" | "" => cmd_info(),
        other => bail!("unknown command '{other}' (train|query|topk|cluster|serve|selftest|info)"),
    }
}
