//! Random-walk corpus generator (paper §6.1's scaling workload).

use crate::core::preprocess::znorm_inplace;
use crate::core::rng::Rng;
use crate::core::series::Dataset;

/// Generator for z-normalized Gaussian random walks.
#[derive(Debug, Clone)]
pub struct RandomWalks {
    seed: u64,
    /// Standard deviation of the walk increments.
    pub step_std: f64,
    /// Whether to z-normalize each walk (the UCR convention); on by
    /// default.
    pub znormalize: bool,
}

impl RandomWalks {
    /// New generator with the given seed.
    pub fn new(seed: u64) -> Self {
        RandomWalks { seed, step_std: 1.0, znormalize: true }
    }

    /// Generate `n` walks of length `len`.
    pub fn generate(&self, n: usize, len: usize) -> Dataset {
        let mut rng = Rng::new(self.seed);
        let mut values = Vec::with_capacity(n * len);
        for _ in 0..n {
            let mut acc = 0.0;
            let start = values.len();
            for _ in 0..len {
                acc += self.step_std * rng.normal();
                values.push(acc);
            }
            if self.znormalize {
                znorm_inplace(&mut values[start..]);
            }
        }
        let mut d = Dataset::from_flat(values, len);
        d.name = format!("RandomWalk(n={n},len={len})");
        d
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::preprocess::{mean, std_dev};

    #[test]
    fn shape_and_normalization() {
        let d = RandomWalks::new(1).generate(10, 50);
        assert_eq!(d.n_series(), 10);
        assert_eq!(d.len, 50);
        for r in d.rows() {
            assert!(mean(r).abs() < 1e-9);
            assert!((std_dev(r) - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn deterministic() {
        let a = RandomWalks::new(7).generate(3, 20);
        let b = RandomWalks::new(7).generate(3, 20);
        assert_eq!(a.values, b.values);
        let c = RandomWalks::new(8).generate(3, 20);
        assert_ne!(a.values, c.values);
    }

    #[test]
    fn raw_walks_are_correlated() {
        // Adjacent samples of a random walk are highly correlated —
        // sanity-check the generator actually integrates noise.
        let mut g = RandomWalks::new(3);
        g.znormalize = false;
        let d = g.generate(1, 2000);
        let r = d.row(0);
        let diffs: Vec<f64> = r.windows(2).map(|w| w[1] - w[0]).collect();
        assert!((std_dev(&diffs) - 1.0).abs() < 0.1);
        assert!(std_dev(r) > 2.0); // walk variance grows
    }
}
