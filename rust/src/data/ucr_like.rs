//! A UCR-like benchmark suite: 16 labeled synthetic dataset generators.
//!
//! Each generator produces class-conditional *shape families* sampled with
//! random phase shift, smooth random time warping, amplitude jitter and
//! additive noise — the distortion axes that differentiate elastic from
//! lock-step measures (and that the real UCR archive exhibits). Series are
//! z-normalized, matching the UCR protocol.
//!
//! This suite substitutes for the 48 UCR-2018 datasets the paper uses
//! (not redistributable here); see DESIGN.md §3 for the substitution
//! rationale. Dataset sizes and lengths vary across the suite like the
//! archive's do.

use crate::core::preprocess::znorm_inplace;
use crate::core::rng::Rng;
use crate::core::series::Dataset;

/// A named train/test split.
#[derive(Debug, Clone)]
pub struct TrainTest {
    /// Dataset name.
    pub name: String,
    /// Training split (labeled).
    pub train: Dataset,
    /// Test split (labeled).
    pub test: Dataset,
}

/// Per-series distortion parameters.
#[derive(Debug, Clone, Copy)]
struct Distortion {
    /// Global phase shift in [0,1) time units.
    shift: f64,
    /// Amplitude of the smooth warp.
    warp_amp: f64,
    /// Phase of the smooth warp.
    warp_phase: f64,
    /// Amplitude scale.
    amp: f64,
    /// Additive noise std.
    noise: f64,
}

impl Distortion {
    fn sample(rng: &mut Rng, shift_max: f64, warp_max: f64, noise: f64) -> Self {
        Distortion {
            shift: rng.uniform_in(-shift_max, shift_max),
            warp_amp: rng.uniform_in(0.0, warp_max),
            warp_phase: rng.uniform_in(0.0, std::f64::consts::TAU),
            amp: rng.uniform_in(0.85, 1.15),
            noise,
        }
    }

    /// Warped time: monotone when `warp_amp < 1/(2π)`.
    #[inline]
    fn warp(&self, u: f64) -> f64 {
        u + self.shift + self.warp_amp * (std::f64::consts::TAU * u + self.warp_phase).sin()
    }
}

/// Render a continuous class shape into a distorted, z-normalized series.
fn render<F: Fn(f64) -> f64>(
    shape: F,
    len: usize,
    d: &Distortion,
    rng: &mut Rng,
) -> Vec<f64> {
    let mut out = Vec::with_capacity(len);
    for i in 0..len {
        let u = i as f64 / (len - 1) as f64;
        let t = d.warp(u);
        out.push(d.amp * shape(t) + d.noise * rng.normal());
    }
    znorm_inplace(&mut out);
    out
}

/// Shape helpers ---------------------------------------------------------

fn gaussian_bump(u: f64, center: f64, width: f64) -> f64 {
    let z = (u - center) / width;
    (-0.5 * z * z).exp()
}

fn plateau(u: f64, start: f64, end: f64, ramp: f64) -> f64 {
    // smooth step up at `start`, down at `end`
    let up = 1.0 / (1.0 + (-(u - start) / ramp).exp());
    let down = 1.0 / (1.0 + (-(u - end) / ramp).exp());
    up - down
}

/// Spec: one dataset = name + per-class shape closures + sampling params.
struct Spec {
    name: &'static str,
    len: usize,
    n_train_per_class: usize,
    n_test_per_class: usize,
    shift_max: f64,
    warp_max: f64,
    noise: f64,
    classes: Vec<Box<dyn Fn(f64, &mut Rng) -> Box<dyn Fn(f64) -> f64>>>,
}

/// Build one dataset from a spec. The outer closure receives a per-series
/// random draw `r ∈ [0,1)` so classes can have internal variation.
fn build(spec: &Spec, seed: u64) -> TrainTest {
    let mut rng = Rng::new(seed);
    let make_split = |n_per_class: usize, rng: &mut Rng| -> Dataset {
        let mut values = Vec::new();
        let mut labels = Vec::new();
        for (ci, class) in spec.classes.iter().enumerate() {
            for _ in 0..n_per_class {
                let r = rng.uniform();
                let shape = class(r, rng);
                let d = Distortion::sample(rng, spec.shift_max, spec.warp_max, spec.noise);
                let series = render(|u| shape(u), spec.len, &d, rng);
                values.extend_from_slice(&series);
                labels.push(ci as i64);
            }
        }
        let mut ds = Dataset::from_flat(values, spec.len);
        ds.labels = labels;
        ds.name = spec.name.to_string();
        ds
    };
    let train = make_split(spec.n_train_per_class, &mut rng);
    let test = make_split(spec.n_test_per_class, &mut rng);
    TrainTest { name: spec.name.to_string(), train, test }
}

macro_rules! class {
    (|$r:ident, $rng:ident| $body:expr) => {
        Box::new(move |$r: f64, $rng: &mut Rng| -> Box<dyn Fn(f64) -> f64> { $body })
    };
}

fn specs() -> Vec<Spec> {
    use std::f64::consts::TAU;
    let mut specs: Vec<Spec> = Vec::new();

    // 1. CBF: cylinder / bell / funnel.
    specs.push(Spec {
        name: "CBF",
        len: 128,
        n_train_per_class: 10,
        n_test_per_class: 30,
        shift_max: 0.08,
        warp_max: 0.02,
        noise: 0.15,
        classes: vec![
            class!(|r, _rng| {
                let (a, b) = (0.2 + 0.1 * r, 0.7 + 0.1 * r);
                Box::new(move |u| plateau(u, a, b, 0.01) * 2.0)
            }),
            class!(|r, _rng| {
                let (a, b) = (0.2 + 0.1 * r, 0.75);
                Box::new(move |u| {
                    if u < a || u > b { 0.0 } else { 2.0 * (u - a) / (b - a) }
                })
            }),
            class!(|r, _rng| {
                let (a, b) = (0.25, 0.7 + 0.1 * r);
                Box::new(move |u| {
                    if u < a || u > b { 0.0 } else { 2.0 * (b - u) / (b - a) }
                })
            }),
        ],
    });

    // 2. TwoPatterns: up-up / up-down / down-up / down-down steps.
    for (name, s1, s2) in [("TwoPatterns", 1.0, 1.0)] {
        let mk = |sa: f64, sb: f64| {
            class!(|r, _rng| {
                let c1 = 0.25 + 0.08 * r;
                let c2 = 0.7 - 0.08 * r;
                let (sa, sb) = (sa, sb);
                Box::new(move |u| {
                    sa * plateau(u, c1 - 0.06, c1 + 0.06, 0.008)
                        + sb * plateau(u, c2 - 0.06, c2 + 0.06, 0.008)
                })
            })
        };
        specs.push(Spec {
            name,
            len: 128,
            n_train_per_class: 12,
            n_test_per_class: 25,
            shift_max: 0.1,
            warp_max: 0.025,
            noise: 0.1,
            classes: vec![mk(s1, s2), mk(s1, -s2), mk(-s1, s2), mk(-s1, -s2)],
        });
    }

    // 3. GunPoint: bump vs bump-with-dip.
    specs.push(Spec {
        name: "GunPointLike",
        len: 150,
        n_train_per_class: 12,
        n_test_per_class: 25,
        shift_max: 0.05,
        warp_max: 0.02,
        noise: 0.05,
        classes: vec![
            class!(|r, _rng| {
                let w = 0.12 + 0.04 * r;
                Box::new(move |u| 2.0 * gaussian_bump(u, 0.5, w))
            }),
            class!(|r, _rng| {
                let w = 0.12 + 0.04 * r;
                Box::new(move |u| {
                    2.0 * gaussian_bump(u, 0.5, w) - 0.8 * gaussian_bump(u, 0.32, 0.03)
                })
            }),
        ],
    });

    // 4. TraceLike: step + oscillating transient combinations.
    specs.push(Spec {
        name: "TraceLike",
        len: 200,
        n_train_per_class: 10,
        n_test_per_class: 20,
        shift_max: 0.06,
        warp_max: 0.015,
        noise: 0.03,
        classes: vec![
            class!(|r, _rng| {
                let c = 0.45 + 0.1 * r;
                Box::new(move |u| plateau(u, c, 2.0, 0.01) * 2.0)
            }),
            class!(|r, _rng| {
                let c = 0.45 + 0.1 * r;
                Box::new(move |u| {
                    plateau(u, c, 2.0, 0.01) * 2.0
                        + gaussian_bump(u, c - 0.08, 0.02) * (TAU * 30.0 * u).sin()
                })
            }),
            class!(|r, _rng| {
                let c = 0.45 + 0.1 * r;
                Box::new(move |u| -plateau(u, c, 2.0, 0.01) * 2.0)
            }),
            class!(|r, _rng| {
                let c = 0.45 + 0.1 * r;
                Box::new(move |u| {
                    -plateau(u, c, 2.0, 0.01) * 2.0
                        + gaussian_bump(u, c - 0.08, 0.02) * (TAU * 30.0 * u).sin()
                })
            }),
        ],
    });

    // 5. ECGLike: normal beat vs widened/ectopic beat.
    specs.push(Spec {
        name: "ECGLike",
        len: 96,
        n_train_per_class: 15,
        n_test_per_class: 30,
        shift_max: 0.06,
        warp_max: 0.02,
        noise: 0.06,
        classes: vec![
            class!(|r, _rng| {
                let c = 0.4 + 0.05 * r;
                Box::new(move |u| {
                    -0.3 * gaussian_bump(u, c - 0.07, 0.02) + 3.0 * gaussian_bump(u, c, 0.012)
                        - 0.5 * gaussian_bump(u, c + 0.06, 0.025)
                        + 0.6 * gaussian_bump(u, c + 0.25, 0.05)
                })
            }),
            class!(|r, _rng| {
                let c = 0.4 + 0.05 * r;
                Box::new(move |u| {
                    2.0 * gaussian_bump(u, c, 0.05) - 0.9 * gaussian_bump(u, c + 0.12, 0.04)
                        + 0.4 * gaussian_bump(u, c + 0.3, 0.06)
                })
            }),
        ],
    });

    // 6. Seasonal: three base frequencies.
    specs.push(Spec {
        name: "Seasonal",
        len: 144,
        n_train_per_class: 10,
        n_test_per_class: 25,
        shift_max: 0.2,
        warp_max: 0.03,
        noise: 0.2,
        classes: vec![
            class!(|_r, _rng| Box::new(move |u| (TAU * 2.0 * u).sin())),
            class!(|_r, _rng| Box::new(move |u| (TAU * 4.0 * u).sin())),
            class!(|_r, _rng| Box::new(move |u| (TAU * 7.0 * u).sin())),
        ],
    });

    // 7. SpikePosition: early vs late spike (pure phase class).
    specs.push(Spec {
        name: "SpikePosition",
        len: 100,
        n_train_per_class: 12,
        n_test_per_class: 25,
        shift_max: 0.03,
        warp_max: 0.01,
        noise: 0.08,
        classes: vec![
            class!(|r, _rng| {
                let c = 0.25 + 0.08 * r;
                Box::new(move |u| 3.0 * gaussian_bump(u, c, 0.02))
            }),
            class!(|r, _rng| {
                let c = 0.65 + 0.08 * r;
                Box::new(move |u| 3.0 * gaussian_bump(u, c, 0.02))
            }),
        ],
    });

    // 8. WarpedSines: same frequency, different harmonic content, heavy warp.
    specs.push(Spec {
        name: "WarpedSines",
        len: 160,
        n_train_per_class: 12,
        n_test_per_class: 25,
        shift_max: 0.1,
        warp_max: 0.05,
        noise: 0.12,
        classes: vec![
            class!(|_r, _rng| Box::new(move |u| (TAU * 3.0 * u).sin())),
            class!(|_r, _rng| {
                Box::new(move |u| (TAU * 3.0 * u).sin() + 0.6 * (TAU * 6.0 * u).sin())
            }),
            class!(|_r, _rng| {
                Box::new(move |u| (TAU * 3.0 * u).sin().abs() * 2.0 - 1.0)
            }),
        ],
    });

    // 9. Waveforms: triangle vs square vs sawtooth.
    specs.push(Spec {
        name: "Waveforms",
        len: 128,
        n_train_per_class: 10,
        n_test_per_class: 25,
        shift_max: 0.15,
        warp_max: 0.02,
        noise: 0.15,
        classes: vec![
            class!(|_r, _rng| {
                Box::new(move |u| {
                    let p = (3.0 * u).fract();
                    if p < 0.5 { 4.0 * p - 1.0 } else { 3.0 - 4.0 * p }
                })
            }),
            class!(|_r, _rng| {
                Box::new(move |u| if (3.0 * u).fract() < 0.5 { 1.0 } else { -1.0 })
            }),
            class!(|_r, _rng| Box::new(move |u| 2.0 * (3.0 * u).fract() - 1.0)),
        ],
    });

    // 10. PlateauWidth: narrow vs wide plateau.
    specs.push(Spec {
        name: "PlateauWidth",
        len: 120,
        n_train_per_class: 12,
        n_test_per_class: 25,
        shift_max: 0.08,
        warp_max: 0.02,
        noise: 0.1,
        classes: vec![
            class!(|r, _rng| {
                let c = 0.45 + 0.1 * r;
                Box::new(move |u| 2.0 * plateau(u, c - 0.08, c + 0.08, 0.01))
            }),
            class!(|r, _rng| {
                let c = 0.45 + 0.1 * r;
                Box::new(move |u| 2.0 * plateau(u, c - 0.25, c + 0.25, 0.01))
            }),
        ],
    });

    // 11. Chirp: rising vs falling instantaneous frequency.
    specs.push(Spec {
        name: "Chirp",
        len: 160,
        n_train_per_class: 10,
        n_test_per_class: 20,
        shift_max: 0.05,
        warp_max: 0.015,
        noise: 0.1,
        classes: vec![
            class!(|_r, _rng| Box::new(move |u| (TAU * (1.0 + 5.0 * u) * u).sin())),
            class!(|_r, _rng| {
                Box::new(move |u| (TAU * (6.0 - 5.0 * u) * u).sin())
            }),
        ],
    });

    // 12. DampedOsc: three damping rates.
    specs.push(Spec {
        name: "DampedOsc",
        len: 128,
        n_train_per_class: 10,
        n_test_per_class: 20,
        shift_max: 0.04,
        warp_max: 0.02,
        noise: 0.08,
        classes: vec![
            class!(|_r, _rng| Box::new(move |u| (-1.5 * u).exp() * (TAU * 5.0 * u).sin())),
            class!(|_r, _rng| Box::new(move |u| (-4.0 * u).exp() * (TAU * 5.0 * u).sin())),
            class!(|_r, _rng| Box::new(move |u| (-9.0 * u).exp() * (TAU * 5.0 * u).sin())),
        ],
    });

    // 13. DriftWalk: drift sign classes over smooth noise.
    specs.push(Spec {
        name: "DriftWalk",
        len: 96,
        n_train_per_class: 15,
        n_test_per_class: 25,
        shift_max: 0.0,
        warp_max: 0.0,
        noise: 0.25,
        classes: vec![
            class!(|r, _rng| {
                let k = 1.5 + r;
                Box::new(move |u| k * u)
            }),
            class!(|r, _rng| {
                let k = 1.5 + r;
                Box::new(move |u| -k * u)
            }),
            class!(|r, _rng| {
                let k = 2.0 + r;
                Box::new(move |u| k * (u - 0.5).abs())
            }),
        ],
    });

    // 14. BumpCount: one vs two bumps.
    specs.push(Spec {
        name: "BumpCount",
        len: 110,
        n_train_per_class: 12,
        n_test_per_class: 25,
        shift_max: 0.08,
        warp_max: 0.02,
        noise: 0.1,
        classes: vec![
            class!(|r, _rng| {
                let c = 0.4 + 0.2 * r;
                Box::new(move |u| 2.5 * gaussian_bump(u, c, 0.06))
            }),
            class!(|r, _rng| {
                let c = 0.3 + 0.1 * r;
                Box::new(move |u| {
                    2.0 * gaussian_bump(u, c, 0.05) + 2.0 * gaussian_bump(u, c + 0.35, 0.05)
                })
            }),
        ],
    });

    // 15. FreqAmp: 2 frequencies × 2 amplitude envelopes.
    specs.push(Spec {
        name: "FreqAmp",
        len: 144,
        n_train_per_class: 8,
        n_test_per_class: 18,
        shift_max: 0.12,
        warp_max: 0.025,
        noise: 0.12,
        classes: vec![
            class!(|_r, _rng| Box::new(move |u| (TAU * 3.0 * u).sin())),
            class!(|_r, _rng| Box::new(move |u| u * (TAU * 3.0 * u).sin() * 2.0)),
            class!(|_r, _rng| Box::new(move |u| (TAU * 5.0 * u).sin())),
            class!(|_r, _rng| Box::new(move |u| u * (TAU * 5.0 * u).sin() * 2.0)),
        ],
    });

    // 16. StepPosition: step in first vs second half (warp-sensitive).
    specs.push(Spec {
        name: "StepPosition",
        len: 100,
        n_train_per_class: 12,
        n_test_per_class: 25,
        shift_max: 0.04,
        warp_max: 0.015,
        noise: 0.1,
        classes: vec![
            class!(|r, _rng| {
                let c = 0.3 + 0.1 * r;
                Box::new(move |u| if u > c { 1.5 } else { -0.5 })
            }),
            class!(|r, _rng| {
                let c = 0.6 + 0.1 * r;
                Box::new(move |u| if u > c { 1.5 } else { -0.5 })
            }),
        ],
    });

    specs
}

/// Generate the full UCR-like suite deterministically from `seed`.
/// Dataset `i` uses seed `seed + i` so datasets are independent.
pub fn ucr_like_suite(seed: u64) -> Vec<TrainTest> {
    specs()
        .iter()
        .enumerate()
        .map(|(i, s)| build(s, seed.wrapping_add(i as u64)))
        .collect()
}

/// Generate a subset of the suite by name (used by examples and tests).
pub fn ucr_like_by_name(name: &str, seed: u64) -> Option<TrainTest> {
    specs()
        .iter()
        .enumerate()
        .find(|(_, s)| s.name == name)
        .map(|(i, s)| build(s, seed.wrapping_add(i as u64)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::preprocess::{mean, std_dev};

    #[test]
    fn suite_has_16_datasets() {
        let suite = ucr_like_suite(1);
        assert_eq!(suite.len(), 16);
        let mut names: Vec<&str> = suite.iter().map(|d| d.name.as_str()).collect();
        names.dedup();
        assert_eq!(names.len(), 16, "duplicate dataset names");
    }

    #[test]
    fn splits_are_labeled_and_normalized() {
        for tt in ucr_like_suite(2) {
            for split in [&tt.train, &tt.test] {
                assert!(split.is_labeled(), "{}", tt.name);
                assert!(split.n_series() >= 16, "{}", tt.name);
                assert!(split.classes().len() >= 2, "{}", tt.name);
                for r in split.rows() {
                    assert!(mean(r).abs() < 1e-9);
                    assert!((std_dev(r) - 1.0).abs() < 1e-6);
                }
            }
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let a = ucr_like_by_name("CBF", 5).unwrap();
        let b = ucr_like_by_name("CBF", 5).unwrap();
        assert_eq!(a.train.values, b.train.values);
        let c = ucr_like_by_name("CBF", 6).unwrap();
        assert_ne!(a.train.values, c.train.values);
    }

    #[test]
    fn classes_are_separable_by_ed_1nn_above_chance() {
        // Smoke: on every dataset, 1NN-ED on raw series beats random
        // guessing by a comfortable margin (the suite must be learnable).
        use crate::distance::euclidean::euclidean_sq;
        for tt in ucr_like_suite(3) {
            let (tr, te) = (&tt.train, &tt.test);
            let mut correct = 0;
            for i in 0..te.n_series() {
                let q = te.row(i);
                let mut best = f64::INFINITY;
                let mut pred = -1;
                for j in 0..tr.n_series() {
                    let d = euclidean_sq(q, tr.row(j));
                    if d < best {
                        best = d;
                        pred = tr.label(j);
                    }
                }
                if pred == te.label(i) {
                    correct += 1;
                }
            }
            let acc = correct as f64 / te.n_series() as f64;
            let chance = 1.0 / tt.train.classes().len() as f64;
            assert!(
                acc > chance + 0.15,
                "{}: acc {acc:.3} vs chance {chance:.3}",
                tt.name
            );
        }
    }

    #[test]
    fn lookup_by_name() {
        assert!(ucr_like_by_name("Chirp", 1).is_some());
        assert!(ucr_like_by_name("NoSuchDataset", 1).is_none());
    }

    #[test]
    fn varied_lengths_across_suite() {
        let suite = ucr_like_suite(4);
        let lengths: std::collections::HashSet<usize> =
            suite.iter().map(|d| d.train.len).collect();
        assert!(lengths.len() >= 5, "suite lengths too uniform: {lengths:?}");
    }
}
