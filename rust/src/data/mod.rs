//! Synthetic workloads and dataset loading.
//!
//! The paper evaluates on (a) random walks for runtime scaling (Fig. 5)
//! and (b) 48 UCR-2018 archives for accuracy (Table 1 / Fig. 6). The UCR
//! archive is not redistributable inside this environment, so
//! [`ucr_like`] provides a suite of 16 labeled generators that reproduce
//! the properties the evaluated measures are sensitive to — class-specific
//! shapes, local phase shifts, warping, noise — while [`ucr_loader`] can
//! ingest the real archive's `.tsv` files when present.

pub mod random_walk;
pub mod ucr_like;
pub mod ucr_loader;

pub use random_walk::RandomWalks;
pub use ucr_like::{ucr_like_suite, TrainTest};
