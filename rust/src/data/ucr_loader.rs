//! Loader for the real UCR-2018 archive format (`<Name>_TRAIN.tsv` /
//! `<Name>_TEST.tsv`: one series per line, label first, tab-separated).
//!
//! When a local copy of the archive exists (`UCR_ARCHIVE_DIR` or an
//! explicit path), benchmarks can run on the paper's actual datasets
//! instead of the synthetic suite.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use super::ucr_like::TrainTest;
use crate::core::preprocess::znorm_dataset;
use crate::core::series::Dataset;

/// Parse one UCR `.tsv` file into a labeled dataset.
pub fn load_tsv(path: &Path) -> Result<Dataset> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    let mut values: Vec<f64> = Vec::new();
    let mut labels: Vec<i64> = Vec::new();
    let mut len: Option<usize> = None;
    for (ln, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let mut fields = line.split(['\t', ',']).filter(|f| !f.is_empty());
        let label: f64 = fields
            .next()
            .context("empty line")?
            .parse()
            .with_context(|| format!("{}:{} bad label", path.display(), ln + 1))?;
        let row: Vec<f64> = fields
            .map(|f| f.parse::<f64>())
            .collect::<std::result::Result<_, _>>()
            .with_context(|| format!("{}:{} bad value", path.display(), ln + 1))?;
        match len {
            None => len = Some(row.len()),
            Some(l) if l != row.len() => {
                bail!("{}:{} ragged series ({} vs {l})", path.display(), ln + 1, row.len())
            }
            _ => {}
        }
        labels.push(label as i64);
        values.extend(row);
    }
    let len = len.context("empty file")?;
    if len < 2 {
        bail!("{}: series too short", path.display());
    }
    let mut d = Dataset::from_flat(values, len);
    d.labels = labels;
    d.name = path
        .file_stem()
        .map(|s| s.to_string_lossy().into_owned())
        .unwrap_or_default();
    Ok(d)
}

/// Load a named UCR dataset (`dir/<name>/<name>_TRAIN.tsv` + `_TEST.tsv`),
/// z-normalizing both splits (UCR-2018 files are mostly pre-normalized;
/// re-normalizing is idempotent and covers the stragglers).
pub fn load_ucr_dataset(dir: &Path, name: &str) -> Result<TrainTest> {
    let base: PathBuf = dir.join(name);
    let mut train = load_tsv(&base.join(format!("{name}_TRAIN.tsv")))?;
    let mut test = load_tsv(&base.join(format!("{name}_TEST.tsv")))?;
    if train.len != test.len {
        bail!("{name}: train/test length mismatch");
    }
    znorm_dataset(&mut train);
    znorm_dataset(&mut test);
    Ok(TrainTest { name: name.to_string(), train, test })
}

/// All dataset names available under an archive directory.
pub fn list_ucr_datasets(dir: &Path) -> Vec<String> {
    let mut names = Vec::new();
    if let Ok(entries) = std::fs::read_dir(dir) {
        for e in entries.flatten() {
            let name = e.file_name().to_string_lossy().into_owned();
            if e.path().join(format!("{name}_TRAIN.tsv")).exists() {
                names.push(name);
            }
        }
    }
    names.sort();
    names
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_tmp(name: &str, content: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("pqdtw_ucr_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join(name);
        std::fs::write(&p, content).unwrap();
        p
    }

    #[test]
    fn parses_tsv() {
        let p = write_tmp("a.tsv", "1\t0.1\t0.2\t0.3\n2\t1.0\t2.0\t3.0\n");
        let d = load_tsv(&p).unwrap();
        assert_eq!(d.n_series(), 2);
        assert_eq!(d.len, 3);
        assert_eq!(d.labels, vec![1, 2]);
        assert_eq!(d.row(1), &[1.0, 2.0, 3.0]);
    }

    #[test]
    fn rejects_ragged() {
        let p = write_tmp("b.tsv", "1\t0.1\t0.2\n2\t1.0\n");
        assert!(load_tsv(&p).is_err());
    }

    #[test]
    fn full_dataset_roundtrip() {
        let dir = std::env::temp_dir().join("pqdtw_ucr_test").join("arch");
        let ds = dir.join("Toy");
        std::fs::create_dir_all(&ds).unwrap();
        std::fs::write(
            ds.join("Toy_TRAIN.tsv"),
            "1\t0.0\t1.0\t2.0\t1.0\n2\t2.0\t1.0\t0.0\t1.0\n",
        )
        .unwrap();
        std::fs::write(
            ds.join("Toy_TEST.tsv"),
            "1\t0.1\t1.1\t2.1\t1.1\n2\t2.1\t1.1\t0.1\t1.1\n",
        )
        .unwrap();
        let tt = load_ucr_dataset(&dir, "Toy").unwrap();
        assert_eq!(tt.train.n_series(), 2);
        assert_eq!(tt.test.n_series(), 2);
        assert_eq!(list_ucr_datasets(&dir), vec!["Toy".to_string()]);
    }

    #[test]
    fn missing_file_errors() {
        let dir = std::env::temp_dir().join("pqdtw_ucr_test_missing");
        assert!(load_ucr_dataset(&dir, "Nope").is_err());
    }
}
