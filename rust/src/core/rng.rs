//! Deterministic PRNG (xoshiro256++) with the distributions the library
//! needs (uniform, normal, choice without replacement).
//!
//! The offline crate registry does not carry `rand`, so we ship our own
//! generator. xoshiro256++ is the generator used by `rand`'s `SmallRng`;
//! it is fast, has a 256-bit state and passes BigCrush. All stochastic
//! components of the library (k-means seeding, synthetic data, search)
//! take an explicit seed so every experiment is reproducible.

/// xoshiro256++ PRNG.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a seed. The seed is expanded with
    /// SplitMix64 as recommended by the xoshiro authors (a raw low-entropy
    /// seed such as `7` would otherwise start in a weak state).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next_sm = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next_sm(), next_sm(), next_sm(), next_sm()] }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        // 53 top bits -> [0,1) double.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in `[lo, hi)`.
    #[inline]
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in `[0, n)`. Uses Lemire's multiply-shift rejection
    /// to avoid modulo bias.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "Rng::below(0)");
        let n = n as u64;
        loop {
            let x = self.next_u64();
            let m = (x as u128) * (n as u128);
            let low = m as u64;
            if low >= n.wrapping_neg() % n {
                return (m >> 64) as usize;
            }
        }
    }

    /// Standard normal via Box–Muller (polar-free variant; two uniforms).
    pub fn normal(&mut self) -> f64 {
        // Guard against log(0).
        let u1 = loop {
            let u = self.uniform();
            if u > 0.0 {
                break u;
            }
        };
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Normal with mean/std.
    #[inline]
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// `k` distinct indices sampled uniformly from `[0, n)`
    /// (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample_indices: k={k} > n={n}");
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Shuffle a slice in place (Fisher–Yates).
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Derive an independent child generator (for per-worker seeding).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut r = Rng::new(7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s1 += x;
            s2 += x * x;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(5);
        let s = r.sample_indices(100, 30);
        assert_eq!(s.len(), 30);
        let mut sorted = s.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 30);
        assert!(sorted.iter().all(|&i| i < 100));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn fork_is_independent() {
        let mut a = Rng::new(13);
        let mut c = a.fork();
        // forked stream differs from parent's continuation
        let same = (0..64).filter(|_| a.next_u64() == c.next_u64()).count();
        assert!(same < 4);
    }
}
