//! Preprocessing: z-normalization and linear re-interpolation.
//!
//! The UCR evaluation protocol z-normalizes every series; the PQ
//! pre-alignment step re-interpolates variable-length segments back to a
//! fixed length (paper §3.5, following Mueen & Keogh's resampling note).

use super::series::Dataset;

/// Z-normalize a slice in place: zero mean, unit variance. Series with
/// (near-)zero variance are centered only — dividing by ~0 would blow up.
pub fn znorm_inplace(xs: &mut [f64]) {
    let n = xs.len();
    if n == 0 {
        return;
    }
    let mean = xs.iter().sum::<f64>() / n as f64;
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
    let std = var.sqrt();
    if std < 1e-12 {
        for x in xs.iter_mut() {
            *x -= mean;
        }
    } else {
        for x in xs.iter_mut() {
            *x = (*x - mean) / std;
        }
    }
}

/// Z-normalized copy.
pub fn znorm(xs: &[f64]) -> Vec<f64> {
    let mut v = xs.to_vec();
    znorm_inplace(&mut v);
    v
}

/// Z-normalize every row of a dataset in place.
pub fn znorm_dataset(d: &mut Dataset) {
    let len = d.len;
    for i in 0..d.n_series() {
        znorm_inplace(&mut d.values[i * len..(i + 1) * len]);
    }
}

/// Linearly re-interpolate `xs` to `target_len` samples. Endpoints are
/// preserved exactly. `xs` must contain at least two samples.
pub fn reinterpolate(xs: &[f64], target_len: usize) -> Vec<f64> {
    assert!(xs.len() >= 2, "reinterpolate: need >= 2 samples");
    assert!(target_len >= 2, "reinterpolate: target_len >= 2");
    if xs.len() == target_len {
        return xs.to_vec();
    }
    let n = xs.len();
    let scale = (n - 1) as f64 / (target_len - 1) as f64;
    let mut out = Vec::with_capacity(target_len);
    for i in 0..target_len {
        let pos = i as f64 * scale;
        let lo = pos.floor() as usize;
        if lo + 1 >= n {
            out.push(xs[n - 1]);
        } else {
            let frac = pos - lo as f64;
            out.push(xs[lo] * (1.0 - frac) + xs[lo + 1] * frac);
        }
    }
    out
}

/// Simple mean of a slice.
#[inline]
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Population standard deviation of a slice.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn znorm_moments() {
        let mut v = vec![1.0, 2.0, 3.0, 4.0, 5.0];
        znorm_inplace(&mut v);
        assert!(mean(&v).abs() < 1e-12);
        assert!((std_dev(&v) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn znorm_constant_series() {
        let mut v = vec![3.0; 8];
        znorm_inplace(&mut v);
        assert!(v.iter().all(|&x| x.abs() < 1e-12));
    }

    #[test]
    fn reinterp_identity() {
        let v = vec![1.0, 5.0, 2.0, 8.0];
        assert_eq!(reinterpolate(&v, 4), v);
    }

    #[test]
    fn reinterp_endpoints_preserved() {
        let v = vec![2.0, -1.0, 4.0, 0.5, 3.0];
        for target in [2, 3, 7, 11, 50] {
            let r = reinterpolate(&v, target);
            assert_eq!(r.len(), target);
            assert!((r[0] - 2.0).abs() < 1e-12);
            assert!((r[target - 1] - 3.0).abs() < 1e-12);
        }
    }

    #[test]
    fn reinterp_upsample_linear_line() {
        // A straight line stays a straight line under linear interpolation.
        let v: Vec<f64> = (0..5).map(|i| i as f64).collect();
        let r = reinterpolate(&v, 9);
        for (i, x) in r.iter().enumerate() {
            assert!((x - i as f64 * 0.5).abs() < 1e-12);
        }
    }

    #[test]
    fn znorm_dataset_rows() {
        let mut d = Dataset::from_flat(vec![1.0, 2.0, 3.0, 10.0, 20.0, 30.0], 3);
        znorm_dataset(&mut d);
        for r in d.rows() {
            assert!(mean(r).abs() < 1e-12);
            assert!((std_dev(r) - 1.0).abs() < 1e-9);
        }
    }
}
