//! Time-series and dataset containers.
//!
//! All series values are `f64` (the paper's Cython implementation uses
//! doubles; single precision only matters for the memory *model*, which is
//! analytic — see [`crate::pq::quantizer::MemoryModel`]). Datasets store
//! their values in one flat row-major buffer so the hot loops never chase
//! pointers.

/// A single univariate time series with an optional class label.
#[derive(Debug, Clone, PartialEq)]
pub struct TimeSeries {
    /// Observations, equally spaced in time.
    pub values: Vec<f64>,
    /// Class label for classification/clustering benchmarks (`None` for
    /// unlabeled data such as random-walk scaling corpora).
    pub label: Option<i64>,
}

impl TimeSeries {
    /// New unlabeled series.
    pub fn new(values: Vec<f64>) -> Self {
        TimeSeries { values, label: None }
    }

    /// New labeled series.
    pub fn labeled(values: Vec<f64>, label: i64) -> Self {
        TimeSeries { values, label: Some(label) }
    }

    /// Number of observations.
    #[inline]
    pub fn len(&self) -> usize {
        self.values.len()
    }

    /// True if the series has no observations.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }
}

/// A collection of equal-length time series stored in a flat row-major
/// buffer (`n_series × len`).
#[derive(Debug, Clone, Default)]
pub struct Dataset {
    /// Flat values, row-major: series `i` occupies
    /// `values[i*len .. (i+1)*len]`.
    pub values: Vec<f64>,
    /// Length of each series.
    pub len: usize,
    /// Labels, parallel to rows; empty when the dataset is unlabeled.
    pub labels: Vec<i64>,
    /// Human-readable name (dataset generators fill this in).
    pub name: String,
}

impl Dataset {
    /// Build a dataset from individual series. All series must share one
    /// length; labels are kept only if *every* series is labeled.
    pub fn from_series(series: &[TimeSeries]) -> Self {
        assert!(!series.is_empty(), "Dataset::from_series: empty input");
        let len = series[0].len();
        assert!(
            series.iter().all(|s| s.len() == len),
            "Dataset::from_series: unequal lengths"
        );
        let mut values = Vec::with_capacity(series.len() * len);
        for s in series {
            values.extend_from_slice(&s.values);
        }
        let labels = if series.iter().all(|s| s.label.is_some()) {
            series.iter().map(|s| s.label.unwrap()).collect()
        } else {
            Vec::new()
        };
        Dataset { values, len, labels, name: String::new() }
    }

    /// Build from a flat buffer.
    pub fn from_flat(values: Vec<f64>, len: usize) -> Self {
        assert!(len > 0 && values.len() % len == 0, "from_flat: ragged buffer");
        Dataset { values, len, labels: Vec::new(), name: String::new() }
    }

    /// Number of series.
    #[inline]
    pub fn n_series(&self) -> usize {
        if self.len == 0 { 0 } else { self.values.len() / self.len }
    }

    /// Borrow series `i` as a slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.values[i * self.len..(i + 1) * self.len]
    }

    /// Mutable borrow of series `i`.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.values[i * self.len..(i + 1) * self.len]
    }

    /// Label of series `i` (panics when unlabeled).
    #[inline]
    pub fn label(&self, i: usize) -> i64 {
        self.labels[i]
    }

    /// True when every row carries a label.
    pub fn is_labeled(&self) -> bool {
        !self.labels.is_empty()
    }

    /// Iterator over rows.
    pub fn rows(&self) -> impl Iterator<Item = &[f64]> {
        self.values.chunks_exact(self.len.max(1))
    }

    /// The sorted set of distinct labels.
    pub fn classes(&self) -> Vec<i64> {
        let mut cs = self.labels.clone();
        cs.sort_unstable();
        cs.dedup();
        cs
    }

    /// Sub-dataset with the given row indices (labels carried over).
    pub fn subset(&self, idx: &[usize]) -> Dataset {
        let mut values = Vec::with_capacity(idx.len() * self.len);
        let mut labels = Vec::with_capacity(if self.is_labeled() { idx.len() } else { 0 });
        for &i in idx {
            values.extend_from_slice(self.row(i));
            if self.is_labeled() {
                labels.push(self.labels[i]);
            }
        }
        Dataset { values, len: self.len, labels, name: self.name.clone() }
    }

    /// Column slice `[start, end)` of every series, as a new dataset
    /// (used to cut out one PQ subspace).
    pub fn column_slice(&self, start: usize, end: usize) -> Dataset {
        assert!(start < end && end <= self.len, "column_slice out of range");
        let w = end - start;
        let mut values = Vec::with_capacity(self.n_series() * w);
        for r in self.rows() {
            values.extend_from_slice(&r[start..end]);
        }
        Dataset { values, len: w, labels: self.labels.clone(), name: self.name.clone() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> Dataset {
        Dataset::from_series(&[
            TimeSeries::labeled(vec![1.0, 2.0, 3.0, 4.0], 0),
            TimeSeries::labeled(vec![5.0, 6.0, 7.0, 8.0], 1),
            TimeSeries::labeled(vec![9.0, 10.0, 11.0, 12.0], 0),
        ])
    }

    #[test]
    fn roundtrip_rows() {
        let d = toy();
        assert_eq!(d.n_series(), 3);
        assert_eq!(d.len, 4);
        assert_eq!(d.row(1), &[5.0, 6.0, 7.0, 8.0]);
        assert_eq!(d.label(2), 0);
        assert_eq!(d.classes(), vec![0, 1]);
    }

    #[test]
    fn subset_keeps_labels() {
        let d = toy();
        let s = d.subset(&[2, 0]);
        assert_eq!(s.n_series(), 2);
        assert_eq!(s.row(0), &[9.0, 10.0, 11.0, 12.0]);
        assert_eq!(s.labels, vec![0, 0]);
    }

    #[test]
    fn column_slice_cuts_subspace() {
        let d = toy();
        let s = d.column_slice(1, 3);
        assert_eq!(s.len, 2);
        assert_eq!(s.row(0), &[2.0, 3.0]);
        assert_eq!(s.row(2), &[10.0, 11.0]);
    }

    #[test]
    #[should_panic]
    fn unequal_lengths_panic() {
        Dataset::from_series(&[
            TimeSeries::new(vec![1.0]),
            TimeSeries::new(vec![1.0, 2.0]),
        ]);
    }

    #[test]
    fn unlabeled_dataset() {
        let d = Dataset::from_flat(vec![0.0; 12], 3);
        assert_eq!(d.n_series(), 4);
        assert!(!d.is_labeled());
    }
}
