//! Core containers and numeric utilities shared by every subsystem.

pub mod series;
pub mod preprocess;
pub mod rng;
pub mod matrix;

pub use matrix::CondensedMatrix;
pub use rng::Rng;
pub use series::{Dataset, TimeSeries};
