//! Condensed (upper-triangle) symmetric distance matrix.
//!
//! Pairwise matrices dominate the clustering benchmarks; storing only the
//! `n(n-1)/2` upper triangle halves memory and keeps accesses cache-local
//! for the agglomerative pass.

/// Symmetric `n×n` matrix with zero diagonal stored as its condensed
/// upper triangle.
#[derive(Debug, Clone)]
pub struct CondensedMatrix {
    n: usize,
    data: Vec<f64>,
}

impl CondensedMatrix {
    /// Zero-filled matrix for `n` items.
    pub fn new(n: usize) -> Self {
        CondensedMatrix { n, data: vec![0.0; n * (n - 1) / 2] }
    }

    /// Number of items.
    #[inline]
    pub fn n(&self) -> usize {
        self.n
    }

    /// Flat index of the pair `(i, j)`, `i != j`.
    #[inline]
    fn idx(&self, i: usize, j: usize) -> usize {
        let (i, j) = if i < j { (i, j) } else { (j, i) };
        debug_assert!(j < self.n);
        // row i starts at i*n - i(i+1)/2 - i (elements strictly above diag)
        i * self.n - i * (i + 1) / 2 + (j - i - 1)
    }

    /// Distance between `i` and `j` (0 on the diagonal).
    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        if i == j {
            0.0
        } else {
            self.data[self.idx(i, j)]
        }
    }

    /// Set the distance between `i` and `j` (`i != j`).
    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        let k = self.idx(i, j);
        self.data[k] = v;
    }

    /// Borrow the condensed buffer.
    pub fn as_slice(&self) -> &[f64] {
        &self.data
    }

    /// Build by evaluating `f(i, j)` for every pair `i < j`.
    pub fn build<F: FnMut(usize, usize) -> f64>(n: usize, mut f: F) -> Self {
        let mut m = CondensedMatrix::new(n);
        for i in 0..n {
            for j in (i + 1)..n {
                let v = f(i, j);
                m.set(i, j, v);
            }
        }
        m
    }

    /// Number of stored pairs.
    pub fn n_pairs(&self) -> usize {
        self.data.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn symmetric_and_zero_diagonal() {
        let mut m = CondensedMatrix::new(4);
        m.set(0, 3, 1.5);
        m.set(2, 1, 2.5);
        assert_eq!(m.get(3, 0), 1.5);
        assert_eq!(m.get(1, 2), 2.5);
        for i in 0..4 {
            assert_eq!(m.get(i, i), 0.0);
        }
    }

    #[test]
    fn indexing_covers_all_pairs_uniquely() {
        let n = 7;
        let m = CondensedMatrix::new(n);
        let mut seen = vec![false; n * (n - 1) / 2];
        for i in 0..n {
            for j in (i + 1)..n {
                let k = m.idx(i, j);
                assert!(!seen[k], "dup index for ({i},{j})");
                seen[k] = true;
            }
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn build_fills_pairs() {
        let m = CondensedMatrix::build(5, |i, j| (i * 10 + j) as f64);
        assert_eq!(m.get(1, 4), 14.0);
        assert_eq!(m.get(4, 1), 14.0);
        assert_eq!(m.n_pairs(), 10);
    }
}
