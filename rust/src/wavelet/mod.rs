//! Maximal Overlap Discrete Wavelet Transform (Haar) and the structure-
//! aware segmentation built on it (paper §3.5, following Hong et al.'s
//! SSDTW segmentation).

pub mod modwt;
pub mod segment;

pub use modwt::{modwt_scale, modwt_pyramid};
pub use segment::{elastic_split_points, fixed_split_points, modwt_segment_points};
