//! Structure-aware elastic segmentation (paper §3.5).
//!
//! Fixed-length partitioning can cut straight through a local structure
//! (a peak, a valley), forcing DTW to align the two halves independently
//! and inflating the approximate distance. The fix: smooth the series with
//! the Haar MODWT, extract candidate segment points where `x - V_J`
//! changes sign (the series crosses its own smoothing), and snap each
//! fixed split point `l` to the right-most candidate inside the tail
//! window `[l - t, l]`. Points without a candidate stay at `l`, so every
//! series is still cut into exactly `M` segments.

use super::modwt::modwt_scale;

/// Fixed split points `l_k = k·(D/M)` for `k = 1..M` (segment *ends*,
/// exclusive; the final boundary `D` is implicit).
pub fn fixed_split_points(len: usize, n_subspaces: usize) -> Vec<usize> {
    assert!(n_subspaces >= 1 && len >= n_subspaces);
    (1..n_subspaces).map(|k| k * len / n_subspaces).collect()
}

/// MODWT segment candidates: indices `i ≥ 1` where the sign of
/// `x[i] - V_J[i]` differs from the sign at `i - 1`. Zero diffs adopt the
/// previous sign so flat stretches do not spray spurious points.
pub fn modwt_segment_points(x: &[f64], level: usize) -> Vec<usize> {
    if x.len() < 2 {
        return Vec::new();
    }
    let smooth = modwt_scale(x, level);
    let mut points = Vec::new();
    let mut prev_sign = 0i8;
    for i in 0..x.len() {
        let d = x[i] - smooth[i];
        let sign = if d > 0.0 {
            1
        } else if d < 0.0 {
            -1
        } else {
            prev_sign
        };
        if i > 0 && sign != 0 && prev_sign != 0 && sign != prev_sign {
            points.push(i);
        }
        if sign != 0 {
            prev_sign = sign;
        }
    }
    points
}

/// Elastic split points: each fixed point `l` is replaced by the
/// right-most MODWT candidate in `[l - tail, l]` when one exists.
/// Returns `M - 1` strictly increasing interior boundaries.
pub fn elastic_split_points(
    x: &[f64],
    n_subspaces: usize,
    level: usize,
    tail: usize,
) -> Vec<usize> {
    let fixed = fixed_split_points(x.len(), n_subspaces);
    if tail == 0 || n_subspaces <= 1 {
        return fixed;
    }
    let candidates = modwt_segment_points(x, level);
    let mut out = Vec::with_capacity(fixed.len());
    let mut prev_boundary = 0usize;
    for &l in &fixed {
        let lo = l.saturating_sub(tail).max(prev_boundary + 1);
        // Right-most candidate within [lo, l].
        let snapped = candidates
            .iter()
            .rev()
            .find(|&&c| c >= lo && c <= l)
            .copied()
            .unwrap_or(l);
        // Keep boundaries strictly increasing and leave at least one
        // sample for the next segment.
        let b = snapped.max(prev_boundary + 1).min(x.len() - 1);
        out.push(b);
        prev_boundary = b;
    }
    out
}

/// Cut `x` at `boundaries` (interior, strictly increasing) into
/// `boundaries.len() + 1` segments.
pub fn cut_at<'a>(x: &'a [f64], boundaries: &[usize]) -> Vec<&'a [f64]> {
    let mut segs = Vec::with_capacity(boundaries.len() + 1);
    let mut start = 0usize;
    for &b in boundaries {
        segs.push(&x[start..b]);
        start = b;
    }
    segs.push(&x[start..]);
    segs
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::rng::Rng;

    #[test]
    fn fixed_points_even_split() {
        assert_eq!(fixed_split_points(100, 4), vec![25, 50, 75]);
        assert_eq!(fixed_split_points(10, 1), Vec::<usize>::new());
        assert_eq!(fixed_split_points(7, 3), vec![2, 4]);
    }

    #[test]
    fn sine_crossings_found() {
        // A sine crosses its smoothing roughly every half period.
        let x: Vec<f64> = (0..128).map(|i| ((i as f64) * 0.2).sin()).collect();
        let pts = modwt_segment_points(&x, 3);
        assert!(pts.len() >= 4, "found {} points", pts.len());
        // π / 0.2 ≈ 31.4 samples per half period
        for w in pts.windows(2) {
            assert!(w[1] - w[0] >= 10, "{pts:?}");
        }
    }

    #[test]
    fn constant_series_no_crossings() {
        let x = [5.0; 64];
        assert!(modwt_segment_points(&x, 2).is_empty());
    }

    #[test]
    fn elastic_points_stay_in_tail_window() {
        let mut rng = Rng::new(107);
        let x: Vec<f64> = (0..120).map(|_| rng.normal()).collect();
        let m = 4;
        let tail = 8;
        let fixed = fixed_split_points(x.len(), m);
        let elastic = elastic_split_points(&x, m, 2, tail);
        assert_eq!(elastic.len(), fixed.len());
        for (e, f) in elastic.iter().zip(fixed.iter()) {
            assert!(*e <= *f, "boundary moved right: {e} > {f}");
            assert!(*e + tail >= *f, "boundary moved beyond tail: {e} < {f}-{tail}");
        }
    }

    #[test]
    fn elastic_points_strictly_increasing() {
        let mut rng = Rng::new(109);
        for _ in 0..20 {
            let x: Vec<f64> = (0..80).map(|_| rng.normal()).collect();
            let pts = elastic_split_points(&x, 8, 1, 6);
            for w in pts.windows(2) {
                assert!(w[0] < w[1], "{pts:?}");
            }
            assert!(*pts.last().unwrap() < x.len());
            assert!(pts[0] >= 1);
        }
    }

    #[test]
    fn zero_tail_is_fixed_partition() {
        let x: Vec<f64> = (0..60).map(|i| (i as f64 * 0.5).cos()).collect();
        assert_eq!(
            elastic_split_points(&x, 5, 2, 0),
            fixed_split_points(60, 5)
        );
    }

    #[test]
    fn cut_at_covers_series() {
        let x: Vec<f64> = (0..30).map(|i| i as f64).collect();
        let b = vec![7, 15, 22];
        let segs = cut_at(&x, &b);
        assert_eq!(segs.len(), 4);
        let total: usize = segs.iter().map(|s| s.len()).sum();
        assert_eq!(total, 30);
        assert_eq!(segs[0], &x[0..7]);
        assert_eq!(segs[3], &x[22..30]);
    }

    #[test]
    fn snaps_to_rightmost_candidate_in_window() {
        // Spec check: every elastic boundary equals the right-most MODWT
        // candidate inside [l - tail, l], or l itself when none exists.
        let mut rng = Rng::new(113);
        for _ in 0..20 {
            let x: Vec<f64> = {
                let mut acc = 0.0;
                (0..96)
                    .map(|_| {
                        acc += rng.normal();
                        acc
                    })
                    .collect()
            };
            let (m, level, tail) = (4, 2, 7);
            let fixed = fixed_split_points(x.len(), m);
            let candidates = modwt_segment_points(&x, level);
            let elastic = elastic_split_points(&x, m, level, tail);
            let mut prev = 0usize;
            for (&e, &l) in elastic.iter().zip(fixed.iter()) {
                let lo = l.saturating_sub(tail).max(prev + 1);
                let want = candidates
                    .iter()
                    .rev()
                    .find(|&&c| c >= lo && c <= l)
                    .copied()
                    .unwrap_or(l)
                    .max(prev + 1)
                    .min(x.len() - 1);
                assert_eq!(e, want);
                prev = e;
            }
        }
    }

    #[test]
    fn peak_boundary_snaps_before_structure() {
        // A distinctive bump rising just before the fixed split: the
        // elastic boundary should move onto the sign-change at the bump's
        // rise so the structure is not cut. A gentle sine baseline keeps
        // x - smooth nonzero everywhere.
        let mut x: Vec<f64> =
            (0..64).map(|i| 0.1 * ((i as f64) * 0.11).sin()).collect();
        // bump spanning the fixed split at 32
        for (i, v) in [(29, 0.8), (30, 2.4), (31, 3.1), (32, 3.0), (33, 2.2), (34, 0.7)] {
            x[i] += v;
        }
        let elastic = elastic_split_points(&x, 2, 2, 8);
        // The rise crossing sits at the bump onset (~29); the boundary
        // must have moved off the fixed point 32 and be at/before the rise
        // of the bump's core.
        assert!(elastic[0] < 32, "elastic={elastic:?}");
        assert!(elastic[0] >= 24, "elastic={elastic:?}");
    }
}
