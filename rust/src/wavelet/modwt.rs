//! Haar MODWT scale (smooth) coefficients.
//!
//! The Maximal Overlap DWT is the undecimated wavelet transform: unlike
//! the ordinary DWT it is shift-invariant and produces coefficient vectors
//! of the *same length* as the input at every level — exactly the
//! property the pre-alignment step needs (paper §3.5).
//!
//! For the Haar scaling filter the MODWT pyramid recursion is
//!
//! `V_j[t] = ( V_{j-1}[t] + V_{j-1}[t - 2^(j-1)] ) / 2`,  `V_0 = x`,
//!
//! with circular boundary treatment (standard MODWT convention). `V_j` is
//! then a weighted moving average over a window of `2^j` samples —
//! "proportional to the mean of the raw time series data" as the paper
//! puts it.

/// Scale (smooth) coefficients `V_j` of the Haar MODWT at `level` `j ≥ 1`.
/// Output has the same length as `x`.
pub fn modwt_scale(x: &[f64], level: usize) -> Vec<f64> {
    assert!(level >= 1, "modwt_scale: level must be >= 1");
    let n = x.len();
    let mut v = x.to_vec();
    if n == 0 {
        return v;
    }
    let mut next = vec![0.0; n];
    for j in 1..=level {
        let shift = 1usize << (j - 1);
        for t in 0..n {
            // circular boundary: index (t - shift) mod n
            let s = (t + n - (shift % n)) % n;
            next[t] = 0.5 * (v[t] + v[s]);
        }
        std::mem::swap(&mut v, &mut next);
    }
    v
}

/// All scale coefficient vectors `V_1..=V_level` (used by tests and the
/// level-sweep benchmark).
pub fn modwt_pyramid(x: &[f64], level: usize) -> Vec<Vec<f64>> {
    let mut out = Vec::with_capacity(level);
    let mut v = x.to_vec();
    let n = x.len();
    let mut next = vec![0.0; n];
    for j in 1..=level {
        let shift = 1usize << (j - 1);
        for t in 0..n {
            let s = (t + n - (shift % n)) % n;
            next[t] = 0.5 * (v[t] + v[s]);
        }
        std::mem::swap(&mut v, &mut next);
        out.push(v.clone());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::preprocess::mean;
    use crate::core::rng::Rng;

    #[test]
    fn length_preserved() {
        let x: Vec<f64> = (0..37).map(|i| i as f64).collect();
        for j in 1..=4 {
            assert_eq!(modwt_scale(&x, j).len(), 37);
        }
    }

    #[test]
    fn level1_is_pairwise_average() {
        let x = [2.0, 4.0, 6.0, 8.0];
        let v = modwt_scale(&x, 1);
        // V_1[t] = (x[t] + x[t-1 mod n]) / 2
        assert_eq!(v, vec![(2.0 + 8.0) / 2.0, 3.0, 5.0, 7.0]);
    }

    #[test]
    fn mean_preserved_every_level() {
        // Averaging filters preserve the series mean (circular boundary).
        let mut rng = Rng::new(97);
        let x: Vec<f64> = (0..64).map(|_| rng.normal()).collect();
        let m0 = mean(&x);
        for j in 1..=5 {
            let v = modwt_scale(&x, j);
            assert!((mean(&v) - m0).abs() < 1e-9, "level {j}");
        }
    }

    #[test]
    fn constant_series_fixed_point() {
        let x = [3.3; 16];
        for j in 1..=4 {
            assert!(modwt_scale(&x, j).iter().all(|&v| (v - 3.3).abs() < 1e-12));
        }
    }

    #[test]
    fn smooths_monotonically_in_level() {
        // Higher levels average over wider windows → lower variance.
        let mut rng = Rng::new(101);
        let x: Vec<f64> = (0..256).map(|_| rng.normal()).collect();
        let mut last_var = f64::INFINITY;
        for j in 1..=6 {
            let v = modwt_scale(&x, j);
            let m = mean(&v);
            let var = v.iter().map(|a| (a - m) * (a - m)).sum::<f64>() / v.len() as f64;
            assert!(var < last_var, "level {j}: {var} !< {last_var}");
            last_var = var;
        }
    }

    #[test]
    fn pyramid_matches_direct() {
        let mut rng = Rng::new(103);
        let x: Vec<f64> = (0..48).map(|_| rng.normal()).collect();
        let pyr = modwt_pyramid(&x, 4);
        for (j, v) in pyr.iter().enumerate() {
            assert_eq!(v, &modwt_scale(&x, j + 1));
        }
    }
}
