//! Statistical comparison of algorithms over multiple datasets: Friedman
//! test + Nemenyi post-hoc critical difference (paper §5, "Statistical
//! analysis"), plus the paired helpers the report tables need.

/// Mean of a slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() { 0.0 } else { xs.iter().sum::<f64>() / xs.len() as f64 }
}

/// Sample standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Average ranks per algorithm: `scores[d][a]` is algorithm `a`'s score
/// on dataset `d`, *lower is better*. Ties get the average rank.
pub fn average_ranks(scores: &[Vec<f64>]) -> Vec<f64> {
    let n_algos = scores[0].len();
    let mut ranks = vec![0.0; n_algos];
    for row in scores {
        assert_eq!(row.len(), n_algos);
        // rank with average tie handling
        let mut idx: Vec<usize> = (0..n_algos).collect();
        idx.sort_by(|&a, &b| row[a].partial_cmp(&row[b]).unwrap());
        let mut i = 0;
        while i < n_algos {
            let mut j = i;
            while j + 1 < n_algos && (row[idx[j + 1]] - row[idx[i]]).abs() < 1e-12 {
                j += 1;
            }
            let avg_rank = (i + j) as f64 / 2.0 + 1.0;
            for &a in idx.iter().take(j + 1).skip(i) {
                ranks[a] += avg_rank;
            }
            i = j + 1;
        }
    }
    for r in ranks.iter_mut() {
        *r /= scores.len() as f64;
    }
    ranks
}

/// Friedman test over `scores[d][a]` (lower is better). Returns the
/// chi-square statistic, degrees of freedom and the p-value.
pub fn friedman_test(scores: &[Vec<f64>]) -> (f64, usize, f64) {
    let n = scores.len() as f64;
    let k = scores[0].len() as f64;
    let ranks = average_ranks(scores);
    let sum_sq: f64 = ranks.iter().map(|r| (r - (k + 1.0) / 2.0).powi(2)).sum();
    let chi2 = 12.0 * n / (k * (k + 1.0)) * sum_sq;
    let dof = scores[0].len() - 1;
    (chi2, dof, 1.0 - chi2_cdf(chi2, dof as f64))
}

/// Nemenyi critical difference at α = 0.05 for `k` algorithms over `n`
/// datasets. Two algorithms differ significantly when their average
/// ranks differ by more than this.
pub fn nemenyi_cd_005(k: usize, n: usize) -> f64 {
    // q_0.05 values (infinite-df studentized range / sqrt(2)), Demšar 2006.
    const Q05: [f64; 11] = [
        0.0, 0.0, 1.960, 2.343, 2.569, 2.728, 2.850, 2.949, 3.031, 3.102, 3.164,
    ];
    assert!((2..=10).contains(&k), "Nemenyi table covers k in 2..=10");
    Q05[k] * (k as f64 * (k as f64 + 1.0) / (6.0 * n as f64)).sqrt()
}

/// Outcome of a pairwise significance check, matching the paper's Table 1
/// annotations.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Significance {
    /// First algorithm significantly better (lower score).
    FirstBetter,
    /// Second algorithm significantly better.
    SecondBetter,
    /// No significant difference.
    None,
}

/// Pairwise Nemenyi check between algorithms `a` and `b` of a score
/// matrix (lower = better).
pub fn pairwise_significance(scores: &[Vec<f64>], a: usize, b: usize) -> Significance {
    let k = scores[0].len();
    let n = scores.len();
    let ranks = average_ranks(scores);
    let cd = nemenyi_cd_005(k, n);
    let diff = ranks[a] - ranks[b];
    if diff.abs() <= cd {
        Significance::None
    } else if diff < 0.0 {
        Significance::FirstBetter
    } else {
        Significance::SecondBetter
    }
}

/// Regularized lower incomplete gamma P(s, x) via series / continued
/// fraction (Numerical Recipes style) — powers the chi-square CDF.
fn gamma_p(s: f64, x: f64) -> f64 {
    if x < 0.0 || s <= 0.0 {
        return 0.0;
    }
    if x == 0.0 {
        return 0.0;
    }
    let ln_gamma_s = ln_gamma(s);
    if x < s + 1.0 {
        // series expansion
        let mut sum = 1.0 / s;
        let mut term = sum;
        let mut a = s;
        for _ in 0..500 {
            a += 1.0;
            term *= x / a;
            sum += term;
            if term.abs() < sum.abs() * 1e-15 {
                break;
            }
        }
        sum * (-x + s * x.ln() - ln_gamma_s).exp()
    } else {
        // continued fraction for Q, then P = 1 - Q
        let mut b = x + 1.0 - s;
        let mut c = 1.0 / 1e-300;
        let mut d = 1.0 / b;
        let mut h = d;
        for i in 1..500 {
            let an = -(i as f64) * (i as f64 - s);
            b += 2.0;
            d = an * d + b;
            if d.abs() < 1e-300 {
                d = 1e-300;
            }
            c = b + an / c;
            if c.abs() < 1e-300 {
                c = 1e-300;
            }
            d = 1.0 / d;
            let del = d * c;
            h *= del;
            if (del - 1.0).abs() < 1e-15 {
                break;
            }
        }
        1.0 - (-x + s * x.ln() - ln_gamma_s).exp() * h
    }
}

/// Lanczos log-gamma.
fn ln_gamma(x: f64) -> f64 {
    const G: [f64; 6] = [
        76.18009172947146,
        -86.50532032941677,
        24.01409824083091,
        -1.231739572450155,
        0.1208650973866179e-2,
        -0.5395239384953e-5,
    ];
    let mut y = x;
    let tmp = x + 5.5;
    let tmp = tmp - (x + 0.5) * tmp.ln();
    let mut ser = 1.000000000190015;
    for g in G {
        y += 1.0;
        ser += g / y;
    }
    -tmp + (2.5066282746310005 * ser / x).ln()
}

/// Chi-square CDF with `k` degrees of freedom.
pub fn chi2_cdf(x: f64, k: f64) -> f64 {
    gamma_p(k / 2.0, x / 2.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ranks_simple() {
        // algo 0 always best, algo 2 always worst
        let scores = vec![
            vec![0.1, 0.2, 0.3],
            vec![0.0, 0.5, 0.9],
            vec![0.2, 0.3, 0.4],
        ];
        let r = average_ranks(&scores);
        assert_eq!(r, vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn tied_ranks_averaged() {
        let scores = vec![vec![0.1, 0.1, 0.3]];
        let r = average_ranks(&scores);
        assert_eq!(r, vec![1.5, 1.5, 3.0]);
    }

    #[test]
    fn chi2_cdf_known_values() {
        // chi2 with 1 dof: CDF(3.841) ≈ 0.95
        assert!((chi2_cdf(3.841, 1.0) - 0.95).abs() < 1e-3);
        // chi2 with 5 dof: CDF(11.07) ≈ 0.95
        assert!((chi2_cdf(11.07, 5.0) - 0.95).abs() < 1e-3);
        assert!(chi2_cdf(0.0, 3.0).abs() < 1e-12);
    }

    #[test]
    fn friedman_detects_consistent_ordering() {
        // 20 datasets where algo 0 always clearly wins
        let scores: Vec<Vec<f64>> = (0..20)
            .map(|i| vec![0.1, 0.3 + 0.001 * i as f64, 0.5])
            .collect();
        let (chi2, dof, p) = friedman_test(&scores);
        assert_eq!(dof, 2);
        assert!(chi2 > 30.0);
        assert!(p < 0.001, "p={p}");
    }

    #[test]
    fn friedman_no_difference() {
        // alternate which algo wins → no consistent ranking
        let scores: Vec<Vec<f64>> = (0..20)
            .map(|i| {
                if i % 2 == 0 {
                    vec![0.1, 0.2]
                } else {
                    vec![0.2, 0.1]
                }
            })
            .collect();
        let (_, _, p) = friedman_test(&scores);
        assert!(p > 0.5, "p={p}");
    }

    #[test]
    fn nemenyi_cd_reference_value() {
        // Demšar's example: k=5, N=30 → CD ≈ 1.102... q=2.728
        let cd = nemenyi_cd_005(5, 30);
        assert!((cd - 2.728 * (5.0 * 6.0 / 180.0f64).sqrt()).abs() < 1e-9);
    }

    #[test]
    fn pairwise_significance_directions() {
        let scores: Vec<Vec<f64>> = (0..40).map(|_| vec![0.1, 0.9]).collect();
        assert_eq!(pairwise_significance(&scores, 0, 1), Significance::FirstBetter);
        assert_eq!(pairwise_significance(&scores, 1, 0), Significance::SecondBetter);
        let even: Vec<Vec<f64>> = (0..40)
            .map(|i| if i % 2 == 0 { vec![0.1, 0.9] } else { vec![0.9, 0.1] })
            .collect();
        assert_eq!(pairwise_significance(&even, 0, 1), Significance::None);
    }
}
