//! Plain-text report tables for the benchmark harness (the benches print
//! the same rows the paper's tables/figures report).

/// A fixed-width text table.
#[derive(Debug, Clone, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
    title: String,
}

impl Table {
    /// New table with column headers.
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            title: title.to_string(),
        }
    }

    /// Append a row (must match the header width).
    pub fn add_row(&mut self, row: Vec<String>) {
        assert_eq!(row.len(), self.header.len(), "row width mismatch");
        self.rows.push(row);
    }

    /// Render with aligned columns.
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for c in 0..ncol {
                widths[c] = widths[c].max(row[c].len());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("== {} ==\n", self.title));
        }
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .zip(widths.iter())
                .map(|(c, w)| format!("{c:>w$}", w = w))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (ncol - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    /// Number of data rows.
    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }
}

/// Format a float with `prec` decimals.
pub fn fmt_f(x: f64, prec: usize) -> String {
    format!("{x:.prec$}")
}

/// Format a speedup factor like the paper ("x14.00").
pub fn fmt_speedup(x: f64) -> String {
    format!("x{x:.2}")
}

/// Format mean ± std.
pub fn fmt_mean_std(mean: f64, std: f64, prec: usize) -> String {
    format!("{mean:.prec$} ± {std:.prec$}")
}

/// Median of a sample (used for runtimes, matching the paper's protocol).
pub fn median(xs: &mut [f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = xs.len();
    if n % 2 == 1 {
        xs[n / 2]
    } else {
        0.5 * (xs[n / 2 - 1] + xs[n / 2])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["name", "value"]);
        t.add_row(vec!["a".into(), "1.00".into()]);
        t.add_row(vec!["longer".into(), "2".into()]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        let lines: Vec<&str> = s.lines().collect();
        // header + separator + 2 rows (+title)
        assert_eq!(lines.len(), 5);
        assert_eq!(lines[3].len(), lines[4].len());
    }

    #[test]
    #[should_panic]
    fn row_width_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.add_row(vec!["only-one".into()]);
    }

    #[test]
    fn formatting_helpers() {
        assert_eq!(fmt_f(1.23456, 2), "1.23");
        assert_eq!(fmt_speedup(14.0), "x14.00");
        assert_eq!(fmt_mean_std(0.017, 0.066, 3), "0.017 ± 0.066");
    }

    #[test]
    fn median_odd_even() {
        assert_eq!(median(&mut [3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&mut [4.0, 1.0, 2.0, 3.0]), 2.5);
        assert!(median(&mut []).is_nan());
    }
}
