//! Hyper-parameter search for PQDTW (paper §5 "Parameter settings").
//!
//! The paper tunes subspace size, wavelet level, tail and quantization
//! window with Optuna's TPE for 12 h per dataset. Offline here, we use
//! the same evaluation protocol (k-fold CV of the 1-NN error on the
//! training set) under a bounded evaluation budget, with a two-stage
//! strategy: a coarse randomized sweep over the grid followed by local
//! refinement around the incumbent. Deterministic given the seed.

use crate::core::rng::Rng;
use crate::core::series::Dataset;
use crate::eval::cv::stratified_kfold;
use crate::nn::knn::{nn_classify_pq, PqQueryMode};
use crate::pq::quantizer::{PqConfig, PqMetric, PrealignConfig, ProductQuantizer};

/// Candidate grid for the tunable parameters.
#[derive(Debug, Clone)]
pub struct SearchSpace {
    /// Candidate subspace counts `M`.
    pub n_subspaces: Vec<usize>,
    /// Candidate quantization windows (fraction of subspace length).
    pub window_fracs: Vec<f64>,
    /// Candidate MODWT levels (pre-alignment).
    pub levels: Vec<usize>,
    /// Candidate tails (fraction of subspace length); `0.0` disables
    /// pre-alignment.
    pub tail_fracs: Vec<f64>,
    /// Codebook size (fixed; the paper defaults to 256).
    pub codebook_size: usize,
}

impl Default for SearchSpace {
    fn default() -> Self {
        SearchSpace {
            n_subspaces: vec![2, 4, 6, 8],
            window_fracs: vec![0.05, 0.1, 0.2, 0.5],
            levels: vec![1, 2, 3],
            tail_fracs: vec![0.0, 0.1, 0.2],
            codebook_size: 256,
        }
    }
}

/// Search outcome.
#[derive(Debug, Clone)]
pub struct SearchResult {
    /// Best configuration found.
    pub config: PqConfig,
    /// Its cross-validated 1-NN error.
    pub cv_error: f64,
    /// Number of configurations evaluated.
    pub evaluated: usize,
}

/// Cross-validated 1-NN error of one configuration on the training set.
pub fn cv_error(train: &Dataset, cfg: &PqConfig, folds: usize, seed: u64) -> Option<f64> {
    let splits = stratified_kfold(train, folds, seed);
    let mut total_err = 0.0;
    for (fi, fold) in splits.iter().enumerate() {
        let tr = train.subset(&fold.train_idx);
        let va = train.subset(&fold.val_idx);
        if tr.n_series() < 2 || va.n_series() == 0 {
            return None;
        }
        let pq = ProductQuantizer::train(&tr, cfg, seed.wrapping_add(fi as u64)).ok()?;
        let enc = pq.encode_dataset(&tr);
        let (err, _) = nn_classify_pq(&pq, &enc, &va, PqQueryMode::Symmetric);
        total_err += err;
    }
    Some(total_err / folds as f64)
}

/// Randomized sweep + local refinement under an evaluation budget.
pub fn tune_pq(
    train: &Dataset,
    space: &SearchSpace,
    budget: usize,
    folds: usize,
    seed: u64,
) -> SearchResult {
    let mut rng = Rng::new(seed);
    let mut evaluated = 0usize;
    let mut best: Option<(f64, PqConfig)> = None;
    let mut seen: std::collections::HashSet<String> = std::collections::HashSet::new();

    let make_cfg = |m: usize, w: f64, level: usize, tail: f64, space: &SearchSpace| PqConfig {
        n_subspaces: m,
        codebook_size: space.codebook_size,
        window_frac: w,
        metric: PqMetric::Dtw,
        prealign: if tail > 0.0 {
            Some(PrealignConfig { level, tail_frac: tail })
        } else {
            None
        },
        kmeans_iters: 5,
        dba_iters: 2,
        train_subsample: None,
    };
    let key = |c: &PqConfig| format!("{c:?}");

    let try_cfg = |cfg: PqConfig,
                       evaluated: &mut usize,
                       best: &mut Option<(f64, PqConfig)>,
                       seen: &mut std::collections::HashSet<String>| {
        if train.len < 2 * cfg.n_subspaces || !seen.insert(key(&cfg)) {
            return;
        }
        if let Some(err) = cv_error(train, &cfg, folds, seed) {
            *evaluated += 1;
            let better = match best {
                Some((e, _)) => err < *e,
                None => true,
            };
            if better {
                *best = Some((err, cfg));
            }
        }
    };

    // Stage 1: randomized coarse sweep (half the budget).
    let coarse = (budget / 2).max(1);
    for _ in 0..coarse {
        let cfg = make_cfg(
            space.n_subspaces[rng.below(space.n_subspaces.len())],
            space.window_fracs[rng.below(space.window_fracs.len())],
            space.levels[rng.below(space.levels.len())],
            space.tail_fracs[rng.below(space.tail_fracs.len())],
            space,
        );
        try_cfg(cfg, &mut evaluated, &mut best, &mut seen);
    }

    // Stage 2: local refinement around the incumbent — vary one axis at a
    // time through its neighbouring grid values.
    if let Some((_, inc)) = best.clone() {
        let mut neighbours: Vec<PqConfig> = Vec::new();
        let pos = |v: usize, grid: &[usize]| grid.iter().position(|&g| g == v);
        let posf = |v: f64, grid: &[f64]| grid.iter().position(|&g| (g - v).abs() < 1e-12);
        if let Some(p) = pos(inc.n_subspaces, &space.n_subspaces) {
            for q in [p.wrapping_sub(1), p + 1] {
                if let Some(&m) = space.n_subspaces.get(q) {
                    let (level, tail) = match inc.prealign {
                        Some(pa) => (pa.level, pa.tail_frac),
                        None => (space.levels[0], 0.0),
                    };
                    neighbours.push(make_cfg(m, inc.window_frac, level, tail, space));
                }
            }
        }
        if let Some(p) = posf(inc.window_frac, &space.window_fracs) {
            for q in [p.wrapping_sub(1), p + 1] {
                if let Some(&w) = space.window_fracs.get(q) {
                    let (level, tail) = match inc.prealign {
                        Some(pa) => (pa.level, pa.tail_frac),
                        None => (space.levels[0], 0.0),
                    };
                    neighbours.push(make_cfg(inc.n_subspaces, w, level, tail, space));
                }
            }
        }
        for &tail in &space.tail_fracs {
            for &level in &space.levels {
                neighbours.push(make_cfg(inc.n_subspaces, inc.window_frac, level, tail, space));
            }
        }
        for cfg in neighbours.into_iter().take(budget.saturating_sub(evaluated)) {
            try_cfg(cfg, &mut evaluated, &mut best, &mut seen);
        }
    }

    let (cv_err, config) = best.expect("no feasible configuration in search space");
    SearchResult { config, cv_error: cv_err, evaluated }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::ucr_like::ucr_like_by_name;

    #[test]
    fn finds_feasible_config() {
        let tt = ucr_like_by_name("SpikePosition", 29).unwrap();
        let space = SearchSpace {
            n_subspaces: vec![2, 4],
            window_fracs: vec![0.1, 0.3],
            levels: vec![2],
            tail_fracs: vec![0.0, 0.15],
            codebook_size: 16,
        };
        let res = tune_pq(&tt.train, &space, 6, 2, 7);
        assert!(res.evaluated >= 3, "evaluated={}", res.evaluated);
        assert!((0.0..=1.0).contains(&res.cv_error));
        assert!(space.n_subspaces.contains(&res.config.n_subspaces));
    }

    #[test]
    fn deterministic_given_seed() {
        let tt = ucr_like_by_name("Chirp", 31).unwrap();
        let space = SearchSpace {
            n_subspaces: vec![2, 4],
            window_fracs: vec![0.2],
            levels: vec![1],
            tail_fracs: vec![0.0],
            codebook_size: 8,
        };
        let a = tune_pq(&tt.train, &space, 4, 2, 3);
        let b = tune_pq(&tt.train, &space, 4, 2, 3);
        assert_eq!(a.config, b.config);
        assert_eq!(a.cv_error, b.cv_error);
    }

    #[test]
    fn cv_error_in_range() {
        let tt = ucr_like_by_name("BumpCount", 37).unwrap();
        let cfg = PqConfig { n_subspaces: 2, codebook_size: 8, ..Default::default() };
        let err = cv_error(&tt.train, &cfg, 2, 1).unwrap();
        assert!((0.0..=1.0).contains(&err));
    }
}
