//! Evaluation protocol: cross-validation, hyper-parameter search,
//! statistical tests and report formatting (paper §5).

pub mod cv;
pub mod report;
pub mod search;
pub mod stats;

pub use cv::{stratified_kfold, Fold};
pub use search::{tune_pq, SearchResult, SearchSpace};
pub use stats::{friedman_test, nemenyi_cd_005, pairwise_significance, Significance};
