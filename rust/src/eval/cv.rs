//! Stratified k-fold cross-validation splits.

use crate::core::rng::Rng;
use crate::core::series::Dataset;

/// A single CV fold: train/validation row indices.
#[derive(Debug, Clone)]
pub struct Fold {
    /// Training row indices.
    pub train_idx: Vec<usize>,
    /// Validation row indices.
    pub val_idx: Vec<usize>,
}

/// Stratified `k`-fold split: class proportions are preserved per fold.
pub fn stratified_kfold(data: &Dataset, k: usize, seed: u64) -> Vec<Fold> {
    assert!(data.is_labeled(), "stratified CV needs labels");
    assert!(k >= 2, "need k >= 2 folds");
    let mut rng = Rng::new(seed);
    // group indices by class, shuffled
    let classes = data.classes();
    let mut per_class: Vec<Vec<usize>> = classes
        .iter()
        .map(|&c| {
            let mut idx: Vec<usize> =
                (0..data.n_series()).filter(|&i| data.label(i) == c).collect();
            rng.shuffle(&mut idx);
            idx
        })
        .collect();
    // deal each class round-robin into folds
    let mut fold_members: Vec<Vec<usize>> = vec![Vec::new(); k];
    for idx in per_class.iter_mut() {
        for (pos, &i) in idx.iter().enumerate() {
            fold_members[pos % k].push(i);
        }
    }
    (0..k)
        .map(|f| {
            let val_idx = fold_members[f].clone();
            let train_idx: Vec<usize> = (0..k)
                .filter(|&g| g != f)
                .flat_map(|g| fold_members[g].iter().copied())
                .collect();
            Fold { train_idx, val_idx }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::series::{Dataset, TimeSeries};

    fn toy(n_per_class: usize) -> Dataset {
        let mut series = Vec::new();
        for c in 0..3i64 {
            for i in 0..n_per_class {
                series.push(TimeSeries::labeled(vec![c as f64, i as f64], c));
            }
        }
        Dataset::from_series(&series)
    }

    #[test]
    fn folds_partition_the_data() {
        let d = toy(10);
        let folds = stratified_kfold(&d, 5, 1);
        assert_eq!(folds.len(), 5);
        let mut all_val: Vec<usize> = folds.iter().flat_map(|f| f.val_idx.clone()).collect();
        all_val.sort_unstable();
        assert_eq!(all_val, (0..30).collect::<Vec<_>>());
        for f in &folds {
            assert_eq!(f.train_idx.len() + f.val_idx.len(), 30);
            // no overlap
            for v in &f.val_idx {
                assert!(!f.train_idx.contains(v));
            }
        }
    }

    #[test]
    fn stratification_preserved() {
        let d = toy(10);
        let folds = stratified_kfold(&d, 5, 2);
        for f in &folds {
            // each fold gets 2 of each class (10 per class / 5 folds)
            for c in 0..3i64 {
                let cnt = f.val_idx.iter().filter(|&&i| d.label(i) == c).count();
                assert_eq!(cnt, 2);
            }
        }
    }

    #[test]
    fn deterministic() {
        let d = toy(7);
        let a = stratified_kfold(&d, 3, 9);
        let b = stratified_kfold(&d, 3, 9);
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.val_idx, y.val_idx);
        }
    }
}
