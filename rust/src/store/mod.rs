//! `store` — the versioned on-disk index format: build once, serve many.
//!
//! Until this subsystem existed, every serving process re-trained
//! codebooks, re-encoded the database and rebuilt the IVF index from
//! scratch, so cold-start cost scaled with *training* rather than with
//! *load*. The store persists the full serving state — the trained
//! [`ProductQuantizer`] (codebooks, centroid envelopes, precomputed
//! elastic LUTs, config), the [`EncodedDataset`] (codes + self lower
//! bounds), the optional [`IvfIndex`] (coarse centroids + posting lists
//! + metric), and the raw [`Dataset`] needed for exact DTW re-ranking —
//! as one self-describing binary file, and reconstructs an engine that
//! answers queries **bit-identically** to the one that was saved.
//! Version 2 adds an optional trailing jobs section so the durable job
//! plane ([`crate::jobs`]) survives restarts: job specs, statuses,
//! progress and completed-result payloads ride in the same file.
//!
//! ## File layout (version 2)
//!
//! ```text
//! magic    8 B   "PQDTWIDX"
//! version  4 B   u32 LE
//! sections       tag u8 · length u64 LE · payload
//!                (header, quantizer, encoded, raw, [ivf], [jobs]) in order
//! checksum 8 B   FNV-1a 64 of every preceding byte, u64 LE
//! ```
//!
//! Everything is explicit little-endian and hand-rolled over `std` —
//! no serialization dependency. `f64` values round-trip via their IEEE
//! bit patterns, which is what makes reloaded answers bit-identical.
//! Corrupt inputs (truncation, bad magic, wrong version, flipped bits,
//! hostile section lengths) are rejected with `anyhow` errors before
//! any state is constructed — never a panic, never an unbounded
//! allocation. See `docs/index-format.md` for the full specification
//! and the version-bump policy.
//!
//! The scan kernel's blocked code layouts (`pq::scan`, `docs/DESIGN.md`
//! §6) are deliberately *not* persisted: they are cheap deterministic
//! transposes of the row-major codes stored here, so `Engine::open`
//! rebuilds them on load and the section layout is unchanged.

// rustc-side twin of the xtask no-panic-in-serving rule: serving code
// must propagate errors. Test code (crate-wide `cfg(test)` under
// `cargo test`) is exempt on purpose.
#![cfg_attr(not(test), warn(clippy::unwrap_used, clippy::expect_used))]

pub mod codec;
pub mod format;
pub(crate) mod jobs;

use std::path::Path;

use anyhow::{bail, ensure, Context, Result};

use crate::core::series::Dataset;
use crate::jobs::PersistedJob;
use crate::nn::ivf::IvfIndex;
use crate::pq::codebook::PqMetric;
use crate::pq::quantizer::{EncodedDataset, ProductQuantizer};

use self::format::{fnv1a, ByteReader, ByteWriter, MAGIC, VERSION};

/// Section tags, in required file order.
const SEC_HEADER: u8 = 1;
const SEC_QUANTIZER: u8 = 2;
const SEC_ENCODED: u8 = 3;
const SEC_RAW: u8 = 4;
const SEC_IVF: u8 = 5;
const SEC_JOBS: u8 = 6;

/// The full serving state reconstructed from disk.
pub struct StoredIndex {
    /// Trained product quantizer.
    pub pq: ProductQuantizer,
    /// Encoded database.
    pub encoded: EncodedDataset,
    /// Raw database (exact DTW re-ranking).
    pub raw: Dataset,
    /// Optional inverted-file index.
    pub ivf: Option<IvfIndex>,
    /// Persisted jobs (empty when the file carries no jobs section).
    pub jobs: Vec<PersistedJob>,
}

/// Summary of an index file — the `info --index` view, readable without
/// reconstructing the index.
#[derive(Debug, Clone, PartialEq)]
pub struct StoreHeader {
    /// Format version.
    pub version: u32,
    /// Number of subspaces `M`.
    pub n_subspaces: usize,
    /// Codebook size `K` (post-clamping, i.e. the trained value).
    pub codebook_size: usize,
    /// Subspace vector length `L`.
    pub sub_len: usize,
    /// Quantization warping window (`None` = unconstrained).
    pub window: Option<usize>,
    /// Quantizer metric.
    pub metric: PqMetric,
    /// Series length the quantizer was trained for.
    pub series_len: usize,
    /// Number of encoded database series.
    pub n_series: usize,
    /// IVF coarse-cell count, when an IVF section is present.
    pub ivf_nlist: Option<usize>,
    /// Total file size in bytes.
    pub file_bytes: u64,
}

fn put_header(w: &mut ByteWriter, pq: &ProductQuantizer, n_series: usize, ivf: Option<&IvfIndex>) {
    w.usize(pq.config.n_subspaces);
    w.usize(pq.codebook.k);
    w.usize(pq.codebook.sub_len);
    w.opt_usize(pq.codebook.window);
    w.u8(codec::metric_tag(pq.codebook.metric));
    w.usize(pq.series_len);
    w.usize(n_series);
    w.opt_usize(ivf.map(|i| i.nlist()));
}

fn get_header(payload: &[u8], version: u32, file_bytes: u64) -> Result<StoreHeader> {
    let mut r = ByteReader::new(payload);
    let h = StoreHeader {
        version,
        n_subspaces: r.usize()?,
        codebook_size: r.usize()?,
        sub_len: r.usize()?,
        window: r.opt_usize()?,
        metric: codec::metric_from(r.u8()?)?,
        series_len: r.usize()?,
        n_series: r.usize()?,
        ivf_nlist: r.opt_usize()?,
        file_bytes,
    };
    ensure!(r.is_exhausted(), "store: trailing bytes in header section");
    Ok(h)
}

/// Serialize the full serving state to the version-2 byte format,
/// with no jobs section.
pub fn encode_index(
    pq: &ProductQuantizer,
    encoded: &EncodedDataset,
    raw: &Dataset,
    ivf: Option<&IvfIndex>,
) -> Vec<u8> {
    encode_index_with_jobs(pq, encoded, raw, ivf, &[])
}

/// Serialize the full serving state plus the durable job registry. An
/// empty `jobs` slice writes no jobs section, so indexes without jobs
/// are byte-identical to [`encode_index`] output.
pub fn encode_index_with_jobs(
    pq: &ProductQuantizer,
    encoded: &EncodedDataset,
    raw: &Dataset,
    ivf: Option<&IvfIndex>,
    persisted_jobs: &[PersistedJob],
) -> Vec<u8> {
    let mut w = ByteWriter::new();
    w.bytes(&MAGIC);
    w.u32(VERSION);
    let mut s = ByteWriter::new();
    put_header(&mut s, pq, encoded.n(), ivf);
    w.section(SEC_HEADER, &s.into_bytes());
    let mut s = ByteWriter::new();
    codec::put_quantizer(&mut s, pq);
    w.section(SEC_QUANTIZER, &s.into_bytes());
    let mut s = ByteWriter::new();
    codec::put_encoded(&mut s, encoded);
    w.section(SEC_ENCODED, &s.into_bytes());
    let mut s = ByteWriter::new();
    codec::put_dataset(&mut s, raw);
    w.section(SEC_RAW, &s.into_bytes());
    if let Some(ivf) = ivf {
        let mut s = ByteWriter::new();
        codec::put_ivf(&mut s, ivf);
        w.section(SEC_IVF, &s.into_bytes());
    }
    if !persisted_jobs.is_empty() {
        let mut s = ByteWriter::new();
        jobs::put_jobs(&mut s, persisted_jobs);
        w.section(SEC_JOBS, &s.into_bytes());
    }
    let mut buf = w.into_bytes();
    let sum = fnv1a(&buf);
    buf.extend_from_slice(&sum.to_le_bytes());
    buf
}

/// Validate framing — size, magic, version, checksum — and return a
/// reader positioned at the first section.
fn checked_body(bytes: &[u8]) -> Result<ByteReader<'_>> {
    const MIN: usize = 8 + 4 + 8; // magic + version + checksum
    ensure!(
        bytes.len() >= MIN,
        "store: file of {} bytes is too small to be a pqdtw index",
        bytes.len()
    );
    let (body, tail) = bytes.split_at(bytes.len() - 8);
    let mut r = ByteReader::new(body);
    let magic = r.take(8)?;
    ensure!(magic == &MAGIC[..], "store: bad magic {magic:02x?} (not a pqdtw index)");
    let version = r.u32()?;
    ensure!(
        version == VERSION,
        "store: unsupported format version {version} (this build reads version {VERSION})"
    );
    let stored = ByteReader::new(tail).u64()?;
    let computed = fnv1a(body);
    ensure!(
        computed == stored,
        "store: checksum mismatch ({stored:016x} on disk, {computed:016x} computed)"
    );
    Ok(r)
}

/// Deserialize and fully validate an index from its byte form.
pub fn decode_index(bytes: &[u8]) -> Result<StoredIndex> {
    let mut r = checked_body(bytes)?;
    let (tag, payload) = r.section()?;
    ensure!(tag == SEC_HEADER, "store: expected header section, found tag {tag}");
    let header = get_header(payload, VERSION, bytes.len() as u64)?;
    let (tag, payload) = r.section()?;
    ensure!(tag == SEC_QUANTIZER, "store: expected quantizer section, found tag {tag}");
    let pq = codec::get_quantizer(payload)?;
    let (tag, payload) = r.section()?;
    ensure!(tag == SEC_ENCODED, "store: expected encoded section, found tag {tag}");
    let encoded = codec::get_encoded(payload, &pq)?;
    let (tag, payload) = r.section()?;
    ensure!(tag == SEC_RAW, "store: expected raw-dataset section, found tag {tag}");
    let raw = codec::get_dataset(payload)?;
    ensure!(
        raw.len == pq.series_len,
        "store: raw series length {} != quantizer length {}",
        raw.len,
        pq.series_len
    );
    ensure!(
        raw.n_series() == encoded.n(),
        "store: raw count {} != encoded count {}",
        raw.n_series(),
        encoded.n()
    );
    // Optional tail: [ivf] then [jobs], either independently absent.
    let mut ivf = None;
    let mut stored_jobs = Vec::new();
    if !r.is_exhausted() {
        let (tag, payload) = r.section()?;
        match tag {
            SEC_IVF => {
                ivf = Some(codec::get_ivf(payload, pq.series_len, encoded.n())?);
                if !r.is_exhausted() {
                    let (tag, payload) = r.section()?;
                    ensure!(tag == SEC_JOBS, "store: expected jobs section, found tag {tag}");
                    let mut jr = ByteReader::new(payload);
                    stored_jobs = jobs::get_jobs(&mut jr)?;
                    ensure!(jr.is_exhausted(), "store: trailing bytes in jobs section");
                }
            }
            SEC_JOBS => {
                let mut jr = ByteReader::new(payload);
                stored_jobs = jobs::get_jobs(&mut jr)?;
                ensure!(jr.is_exhausted(), "store: trailing bytes in jobs section");
            }
            other => bail!("store: unexpected section tag {other}"),
        }
    }
    ensure!(r.is_exhausted(), "store: trailing bytes after final section");
    ensure!(
        header.n_subspaces == pq.config.n_subspaces
            && header.codebook_size == pq.codebook.k
            && header.sub_len == pq.codebook.sub_len
            && header.window == pq.codebook.window
            && header.metric == pq.codebook.metric
            && header.series_len == pq.series_len
            && header.n_series == encoded.n()
            && header.ivf_nlist == ivf.as_ref().map(|i| i.nlist()),
        "store: header summary disagrees with section contents"
    );
    Ok(StoredIndex { pq, encoded, raw, ivf, jobs: stored_jobs })
}

/// Write the full serving state to `path`, atomically: the bytes go to
/// a sibling `<path>.tmp` first and are renamed into place, so an
/// interrupted save can never destroy a previously good index (the
/// index file is the long-lived artifact of the build-once /
/// serve-many split).
pub fn save_index(
    path: &Path,
    pq: &ProductQuantizer,
    encoded: &EncodedDataset,
    raw: &Dataset,
    ivf: Option<&IvfIndex>,
) -> Result<()> {
    save_index_with_jobs(path, pq, encoded, raw, ivf, &[])
}

/// [`save_index`] plus the durable job registry (the job plane's
/// persistence hook). An empty `jobs` slice writes no jobs section.
pub fn save_index_with_jobs(
    path: &Path,
    pq: &ProductQuantizer,
    encoded: &EncodedDataset,
    raw: &Dataset,
    ivf: Option<&IvfIndex>,
    persisted_jobs: &[PersistedJob],
) -> Result<()> {
    let bytes = encode_index_with_jobs(pq, encoded, raw, ivf, persisted_jobs);
    let mut tmp_name = path.as_os_str().to_owned();
    tmp_name.push(".tmp");
    let tmp = std::path::PathBuf::from(tmp_name);
    std::fs::write(&tmp, &bytes)
        .with_context(|| format!("store: writing index to {}", tmp.display()))?;
    std::fs::rename(&tmp, path)
        .with_context(|| format!("store: moving {} into place", tmp.display()))
}

/// Read and fully validate the index at `path`.
pub fn load_index(path: &Path) -> Result<StoredIndex> {
    let bytes = std::fs::read(path)
        .with_context(|| format!("store: reading index from {}", path.display()))?;
    decode_index(&bytes).with_context(|| format!("store: decoding {}", path.display()))
}

/// Read only the summary header of the index at `path` (checksum still
/// verified — a corrupt file must not present a plausible header).
pub fn read_header(path: &Path) -> Result<StoreHeader> {
    let bytes = std::fs::read(path)
        .with_context(|| format!("store: reading index from {}", path.display()))?;
    let mut r = checked_body(&bytes)?;
    let (tag, payload) = r.section()?;
    ensure!(tag == SEC_HEADER, "store: expected header section, found tag {tag}");
    get_header(payload, VERSION, bytes.len() as u64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::random_walk::RandomWalks;
    use crate::nn::ivf::CoarseMetric;
    use crate::pq::quantizer::PqConfig;

    fn tiny_state() -> (ProductQuantizer, EncodedDataset, Dataset, IvfIndex) {
        let db = RandomWalks::new(17).generate(12, 24);
        let cfg = PqConfig {
            n_subspaces: 3,
            codebook_size: 4,
            window_frac: 0.3,
            kmeans_iters: 2,
            dba_iters: 1,
            ..Default::default()
        };
        let pq = ProductQuantizer::train(&db, &cfg, 7).unwrap();
        let enc = pq.encode_dataset(&db);
        let ivf = IvfIndex::build(&db, 3, CoarseMetric::Euclidean, 5);
        (pq, enc, db, ivf)
    }

    fn tiny_bytes() -> Vec<u8> {
        let (pq, enc, db, ivf) = tiny_state();
        encode_index(&pq, &enc, &db, Some(&ivf))
    }

    fn tiny_jobs() -> Vec<PersistedJob> {
        use crate::coordinator::Hit;
        use crate::jobs::{AllPairsRow, JobResult, JobSpec, JobStatus};
        use crate::nn::knn::PqQueryMode;
        use crate::obs::{HitExplain, Stage};
        vec![
            PersistedJob {
                id: 1,
                spec: JobSpec::AllPairsTopK {
                    k: 2,
                    mode: PqQueryMode::Asymmetric,
                    nprobe: None,
                    rerank: Some(4),
                },
                status: JobStatus::Completed,
                done: 2,
                total: 2,
                result: Some(JobResult::AllPairs(vec![AllPairsRow {
                    query_index: 0,
                    hits: vec![Hit { index: 0, distance: 0.0, label: None }],
                    explains: vec![HitExplain {
                        index: 0,
                        pq_estimate: 0.5,
                        exact_dtw: Some(0.25),
                        admitted_by: Stage::Rerank,
                    }],
                }])),
            },
            PersistedJob {
                id: 2,
                spec: JobSpec::ClusterSweep { k_clusters: 3, max_iters: 4, seed: 7 },
                status: JobStatus::Failed("worker died".into()),
                done: 5,
                total: 48,
                result: None,
            },
            PersistedJob {
                id: 4,
                spec: JobSpec::AutotuneNprobe { k: 3, target_recall: 0.95, sample: 8 },
                status: JobStatus::Queued,
                done: 0,
                total: 0,
                result: None,
            },
        ]
    }

    fn tiny_bytes_with_jobs() -> Vec<u8> {
        let (pq, enc, db, ivf) = tiny_state();
        encode_index_with_jobs(&pq, &enc, &db, Some(&ivf), &tiny_jobs())
    }

    fn restamp_checksum(bytes: &mut [u8]) {
        let n = bytes.len() - 8;
        let sum = fnv1a(&bytes[..n]);
        bytes[n..].copy_from_slice(&sum.to_le_bytes());
    }

    #[test]
    fn roundtrip_reconstructs_state_bit_exactly() {
        let (pq, enc, db, ivf) = tiny_state();
        let bytes = encode_index(&pq, &enc, &db, Some(&ivf));
        let idx = decode_index(&bytes).unwrap();
        assert_eq!(idx.pq.config, pq.config);
        assert_eq!(idx.pq.segmenter, pq.segmenter);
        assert_eq!(idx.pq.series_len, pq.series_len);
        assert_eq!(idx.pq.codebook.centroids, pq.codebook.centroids);
        assert_eq!(idx.pq.codebook.envelopes, pq.codebook.envelopes);
        assert_eq!(idx.pq.codebook.lut_sq, pq.codebook.lut_sq);
        assert_eq!(idx.pq.codebook.window, pq.codebook.window);
        assert_eq!(idx.encoded.codes, enc.codes);
        assert_eq!(idx.encoded.lb_self_sq, enc.lb_self_sq);
        assert_eq!(idx.encoded.labels, enc.labels);
        assert_eq!(idx.encoded.stats, enc.stats);
        assert_eq!(idx.raw.values, db.values);
        assert_eq!(idx.raw.len, db.len);
        assert_eq!(idx.raw.name, db.name);
        let r = idx.ivf.expect("IVF section present");
        assert_eq!(r.nlist(), ivf.nlist());
        assert_eq!(r.list_sizes(), ivf.list_sizes());
    }

    #[test]
    fn roundtrip_without_ivf() {
        let (pq, enc, db, _) = tiny_state();
        let bytes = encode_index(&pq, &enc, &db, None);
        let idx = decode_index(&bytes).unwrap();
        assert!(idx.ivf.is_none());
        assert!(idx.jobs.is_empty());
    }

    #[test]
    fn jobs_section_roundtrips_with_and_without_ivf() {
        let (pq, enc, db, ivf) = tiny_state();
        let jobs = tiny_jobs();
        // With IVF: sections [.., ivf, jobs].
        let bytes = encode_index_with_jobs(&pq, &enc, &db, Some(&ivf), &jobs);
        let idx = decode_index(&bytes).unwrap();
        assert!(idx.ivf.is_some());
        assert_eq!(idx.jobs, jobs);
        // Without IVF: sections [.., jobs].
        let bytes = encode_index_with_jobs(&pq, &enc, &db, None, &jobs);
        let idx = decode_index(&bytes).unwrap();
        assert!(idx.ivf.is_none());
        assert_eq!(idx.jobs, jobs);
    }

    #[test]
    fn empty_jobs_slice_is_byte_identical_to_the_plain_encoder() {
        let (pq, enc, db, ivf) = tiny_state();
        assert_eq!(
            encode_index(&pq, &enc, &db, Some(&ivf)),
            encode_index_with_jobs(&pq, &enc, &db, Some(&ivf), &[])
        );
    }

    #[test]
    fn header_summarizes_index_file() {
        let (pq, enc, db, ivf) = tiny_state();
        let bytes = encode_index(&pq, &enc, &db, Some(&ivf));
        let dir = crate::testutil::unique_temp_dir("store_header");
        let path = dir.join("idx.pqx");
        std::fs::write(&path, &bytes).unwrap();
        let h = read_header(&path).unwrap();
        assert_eq!(h.version, VERSION);
        assert_eq!(h.n_subspaces, 3);
        assert_eq!(h.codebook_size, 4);
        assert_eq!(h.sub_len, pq.codebook.sub_len);
        assert_eq!(h.window, pq.codebook.window);
        assert_eq!(h.series_len, 24);
        assert_eq!(h.n_series, 12);
        assert_eq!(h.ivf_nlist, Some(ivf.nlist()));
        assert_eq!(h.file_bytes, bytes.len() as u64);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn corrupt_inputs_error_without_panicking() {
        let good = tiny_bytes();

        let mut bad_magic = good.clone();
        bad_magic[0] ^= 0xff;
        restamp_checksum(&mut bad_magic);

        // Re-stamp the checksum so the *version* check fires, not the
        // checksum check.
        let mut wrong_version = good.clone();
        wrong_version[8..12].copy_from_slice(&999u32.to_le_bytes());
        restamp_checksum(&mut wrong_version);

        let mut flipped_checksum = good.clone();
        let last = flipped_checksum.len() - 1;
        flipped_checksum[last] ^= 0x01;

        // First section's length prefix lives at bytes [13, 21): claim
        // an absurd section length — must be rejected without a huge
        // allocation.
        let mut oversized = good.clone();
        oversized[13..21].copy_from_slice(&u64::MAX.to_le_bytes());
        restamp_checksum(&mut oversized);

        let cases: Vec<(&str, Vec<u8>)> = vec![
            ("empty", Vec::new()),
            ("below minimum size", good[..10].to_vec()),
            ("truncated to half", good[..good.len() / 2].to_vec()),
            ("truncated by one byte", good[..good.len() - 1].to_vec()),
            ("bad magic", bad_magic),
            ("wrong version", wrong_version),
            ("flipped checksum byte", flipped_checksum),
            ("oversized section length", oversized),
        ];
        for (name, bytes) in cases {
            assert!(decode_index(&bytes).is_err(), "case '{name}' must fail");
        }
    }

    #[test]
    fn wrong_version_error_names_the_version() {
        let mut bytes = tiny_bytes();
        bytes[8..12].copy_from_slice(&7u32.to_le_bytes());
        restamp_checksum(&mut bytes);
        let err = decode_index(&bytes).unwrap_err().to_string();
        assert!(err.contains("version 7"), "unexpected error: {err}");
    }

    /// Under Miri every decode costs seconds, not microseconds; stride
    /// the exhaustive sweeps so the UB check still covers a sample of
    /// every region without taking hours. Native runs stay exhaustive.
    fn sweep_stride() -> usize {
        if cfg!(miri) {
            61 // prime, so successive runs touch different offsets mod stride
        } else {
            1
        }
    }

    #[test]
    fn every_prefix_truncation_errors() {
        let good = tiny_bytes();
        for n in (0..good.len()).step_by(sweep_stride()) {
            assert!(decode_index(&good[..n]).is_err(), "prefix of {n} bytes must fail");
        }
    }

    #[test]
    fn every_single_byte_flip_errors() {
        // The checksum covers the body and the trailing checksum bytes
        // protect themselves: any single-byte corruption must be caught.
        let good = tiny_bytes();
        for i in (0..good.len()).step_by(sweep_stride()) {
            let mut bad = good.clone();
            bad[i] ^= 0x40;
            assert!(decode_index(&bad).is_err(), "flip at byte {i} must fail");
        }
    }

    /// The corruption sweeps over a file *with* a jobs section: the new
    /// trailing section must not weaken the existing guarantees, and
    /// corrupting it must never corrupt (or crash on) the sections
    /// before it. The jobs section sits at the end of the body, so the
    /// sweep tail exercises it specifically.
    #[test]
    fn every_prefix_truncation_errors_with_jobs_section() {
        let good = tiny_bytes_with_jobs();
        for n in (0..good.len()).step_by(sweep_stride()) {
            assert!(decode_index(&good[..n]).is_err(), "prefix of {n} bytes must fail");
        }
    }

    #[test]
    fn every_single_byte_flip_errors_with_jobs_section() {
        let good = tiny_bytes_with_jobs();
        for i in (0..good.len()).step_by(sweep_stride()) {
            let mut bad = good.clone();
            bad[i] ^= 0x40;
            assert!(decode_index(&bad).is_err(), "flip at byte {i} must fail");
        }
    }

    /// Even with a valid checksum (re-stamped after corruption), a
    /// hostile job count inside the jobs section must be rejected
    /// before allocating.
    #[test]
    fn restamped_hostile_job_count_is_rejected() {
        let good = tiny_bytes_with_jobs();
        // Locate the jobs section: walk the sections from the front.
        let mut pos = 12; // magic + version
        let body_end = good.len() - 8;
        let jobs_payload_start = loop {
            assert!(pos + 9 <= body_end, "jobs section must exist");
            let tag = good[pos];
            let len = u64::from_le_bytes(good[pos + 1..pos + 9].try_into().unwrap());
            if tag == SEC_JOBS {
                break pos + 9;
            }
            pos += 9 + usize::try_from(len).unwrap();
        };
        let mut bad = good.clone();
        // First payload field is the u64 job count.
        bad[jobs_payload_start..jobs_payload_start + 8]
            .copy_from_slice(&u64::MAX.to_le_bytes());
        restamp_checksum(&mut bad);
        let err = decode_index(&bad).unwrap_err().to_string();
        assert!(err.contains("job count"), "unexpected error: {err}");
    }

    #[test]
    fn missing_file_errors_with_path() {
        let err = load_index(Path::new("/nonexistent/pqdtw.idx")).unwrap_err();
        assert!(format!("{err:#}").contains("nonexistent"));
    }
}
