//! `store` — the versioned on-disk index format: build once, serve many.
//!
//! Until this subsystem existed, every serving process re-trained
//! codebooks, re-encoded the database and rebuilt the IVF index from
//! scratch, so cold-start cost scaled with *training* rather than with
//! *load*. The store persists the full serving state — the trained
//! [`ProductQuantizer`] (codebooks, centroid envelopes, precomputed
//! elastic LUTs, config), the [`EncodedDataset`] (codes + self lower
//! bounds), the optional [`IvfIndex`] (coarse centroids + posting lists
//! + metric), and the raw [`Dataset`] needed for exact DTW re-ranking —
//! as one self-describing binary file, and reconstructs an engine that
//! answers queries **bit-identically** to the one that was saved.
//! Version 2 adds an optional trailing jobs section so the durable job
//! plane ([`crate::jobs`]) survives restarts: job specs, statuses,
//! progress and completed-result payloads ride in the same file.
//!
//! ## File layout (version 2)
//!
//! ```text
//! magic    8 B   "PQDTWIDX"
//! version  4 B   u32 LE
//! sections       tag u8 · length u64 LE · payload
//!                (header, quantizer, encoded, raw, [ivf], [jobs],
//!                [shard]) in order
//! checksum 8 B   FNV-1a 64 of every preceding byte, u64 LE
//! ```
//!
//! The optional trailing shard section records shard membership for
//! `build-index --shard i/n` splits (shard index/count plus the
//! database-global id of every retained row); files without it are
//! unsharded and byte-identical to what pre-shard writers produced.
//!
//! Everything is explicit little-endian and hand-rolled over `std` —
//! no serialization dependency. `f64` values round-trip via their IEEE
//! bit patterns, which is what makes reloaded answers bit-identical.
//! Corrupt inputs (truncation, bad magic, wrong version, flipped bits,
//! hostile section lengths) are rejected with `anyhow` errors before
//! any state is constructed — never a panic, never an unbounded
//! allocation. See `docs/index-format.md` for the full specification
//! and the version-bump policy.
//!
//! The scan kernel's blocked code layouts (`pq::scan`, `docs/DESIGN.md`
//! §6) are deliberately *not* persisted: they are cheap deterministic
//! transposes of the row-major codes stored here, so `Engine::open`
//! rebuilds them on load and the section layout is unchanged.

// rustc-side twin of the xtask no-panic-in-serving rule: serving code
// must propagate errors. Test code (crate-wide `cfg(test)` under
// `cargo test`) is exempt on purpose.
#![cfg_attr(not(test), warn(clippy::unwrap_used, clippy::expect_used))]

pub mod codec;
pub mod format;
pub(crate) mod jobs;

use std::path::Path;

use anyhow::{bail, ensure, Context, Result};

use crate::core::series::Dataset;
use crate::jobs::PersistedJob;
use crate::nn::ivf::IvfIndex;
use crate::pq::codebook::PqMetric;
use crate::pq::quantizer::{EncodedDataset, ProductQuantizer};

use self::format::{fnv1a, ByteReader, ByteWriter, MAGIC, VERSION};

/// Section tags, in required file order.
const SEC_HEADER: u8 = 1;
const SEC_QUANTIZER: u8 = 2;
const SEC_ENCODED: u8 = 3;
const SEC_RAW: u8 = 4;
const SEC_IVF: u8 = 5;
const SEC_JOBS: u8 = 6;
const SEC_SHARD: u8 = 7;

/// Shard membership metadata (the optional trailing `SEC_SHARD`
/// section): which deterministic slice of a larger database this index
/// holds. `build-index --shard i/n` keeps rows with `id % n == i`, in
/// ascending id order, so `global_ids` is strictly increasing — local
/// tie-break order equals global tie-break order, which is what lets a
/// scatter-gather router merge shard results bit-identically to the
/// unsharded scan (`docs/serving-topology.md`). A file without this
/// section is an unsharded index and is byte-identical to what older
/// writers produced.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardInfo {
    /// This shard's index in `0..shard_count`.
    pub shard_index: u64,
    /// Total shards in the split.
    pub shard_count: u64,
    /// Database-global id of each local row (local `i` holds global
    /// `global_ids[i]`; strictly increasing).
    pub global_ids: Vec<u64>,
}

fn put_shard(w: &mut ByteWriter, s: &ShardInfo) {
    w.u64(s.shard_index);
    w.u64(s.shard_count);
    w.usize(s.global_ids.len());
    for &id in &s.global_ids {
        w.u64(id);
    }
}

fn get_shard(payload: &[u8], n_series: usize) -> Result<ShardInfo> {
    let mut r = ByteReader::new(payload);
    let shard_index = r.u64()?;
    let shard_count = r.u64()?;
    ensure!(shard_count >= 1, "store: shard count must be >= 1");
    ensure!(
        shard_index < shard_count,
        "store: shard index {shard_index} out of range for {shard_count} shards"
    );
    let n = r.usize()?;
    ensure!(
        n.saturating_mul(8) <= r.remaining(),
        "store: shard id count {n} exceeds remaining section bytes"
    );
    let mut global_ids = Vec::with_capacity(n);
    for _ in 0..n {
        global_ids.push(r.u64()?);
    }
    ensure!(r.is_exhausted(), "store: trailing bytes in shard section");
    ensure!(
        global_ids.len() == n_series,
        "store: shard id count {} != encoded row count {n_series}",
        global_ids.len()
    );
    ensure!(
        global_ids.windows(2).all(|w| w[0] < w[1]),
        "store: shard global ids must be strictly increasing"
    );
    ensure!(
        global_ids.iter().all(|&id| id % shard_count == shard_index),
        "store: shard global ids disagree with the id % {shard_count} == {shard_index} split"
    );
    Ok(ShardInfo { shard_index, shard_count, global_ids })
}

/// The full serving state reconstructed from disk.
pub struct StoredIndex {
    /// Trained product quantizer.
    pub pq: ProductQuantizer,
    /// Encoded database.
    pub encoded: EncodedDataset,
    /// Raw database (exact DTW re-ranking).
    pub raw: Dataset,
    /// Optional inverted-file index.
    pub ivf: Option<IvfIndex>,
    /// Persisted jobs (empty when the file carries no jobs section).
    pub jobs: Vec<PersistedJob>,
    /// Shard membership, when this index holds a slice of a larger
    /// database (`None` = unsharded).
    pub shard: Option<ShardInfo>,
}

/// Summary of an index file — the `info --index` view, readable without
/// reconstructing the index.
#[derive(Debug, Clone, PartialEq)]
pub struct StoreHeader {
    /// Format version.
    pub version: u32,
    /// Number of subspaces `M`.
    pub n_subspaces: usize,
    /// Codebook size `K` (post-clamping, i.e. the trained value).
    pub codebook_size: usize,
    /// Subspace vector length `L`.
    pub sub_len: usize,
    /// Quantization warping window (`None` = unconstrained).
    pub window: Option<usize>,
    /// Quantizer metric.
    pub metric: PqMetric,
    /// Series length the quantizer was trained for.
    pub series_len: usize,
    /// Number of encoded database series.
    pub n_series: usize,
    /// IVF coarse-cell count, when an IVF section is present.
    pub ivf_nlist: Option<usize>,
    /// Total file size in bytes.
    pub file_bytes: u64,
}

fn put_header(w: &mut ByteWriter, pq: &ProductQuantizer, n_series: usize, ivf: Option<&IvfIndex>) {
    w.usize(pq.config.n_subspaces);
    w.usize(pq.codebook.k);
    w.usize(pq.codebook.sub_len);
    w.opt_usize(pq.codebook.window);
    w.u8(codec::metric_tag(pq.codebook.metric));
    w.usize(pq.series_len);
    w.usize(n_series);
    w.opt_usize(ivf.map(|i| i.nlist()));
}

fn get_header(payload: &[u8], version: u32, file_bytes: u64) -> Result<StoreHeader> {
    let mut r = ByteReader::new(payload);
    let h = StoreHeader {
        version,
        n_subspaces: r.usize()?,
        codebook_size: r.usize()?,
        sub_len: r.usize()?,
        window: r.opt_usize()?,
        metric: codec::metric_from(r.u8()?)?,
        series_len: r.usize()?,
        n_series: r.usize()?,
        ivf_nlist: r.opt_usize()?,
        file_bytes,
    };
    ensure!(r.is_exhausted(), "store: trailing bytes in header section");
    Ok(h)
}

/// Serialize the full serving state to the version-2 byte format,
/// with no jobs section.
pub fn encode_index(
    pq: &ProductQuantizer,
    encoded: &EncodedDataset,
    raw: &Dataset,
    ivf: Option<&IvfIndex>,
) -> Vec<u8> {
    encode_index_with_jobs(pq, encoded, raw, ivf, &[])
}

/// Serialize the full serving state plus the durable job registry. An
/// empty `jobs` slice writes no jobs section, so indexes without jobs
/// are byte-identical to [`encode_index`] output.
pub fn encode_index_with_jobs(
    pq: &ProductQuantizer,
    encoded: &EncodedDataset,
    raw: &Dataset,
    ivf: Option<&IvfIndex>,
    persisted_jobs: &[PersistedJob],
) -> Vec<u8> {
    encode_index_full(pq, encoded, raw, ivf, persisted_jobs, None)
}

/// Serialize everything: serving state, job registry, and shard
/// membership. `None` shard writes no shard section, so unsharded
/// indexes are byte-identical to [`encode_index_with_jobs`] output.
pub fn encode_index_full(
    pq: &ProductQuantizer,
    encoded: &EncodedDataset,
    raw: &Dataset,
    ivf: Option<&IvfIndex>,
    persisted_jobs: &[PersistedJob],
    shard: Option<&ShardInfo>,
) -> Vec<u8> {
    let mut w = ByteWriter::new();
    w.bytes(&MAGIC);
    w.u32(VERSION);
    let mut s = ByteWriter::new();
    put_header(&mut s, pq, encoded.n(), ivf);
    w.section(SEC_HEADER, &s.into_bytes());
    let mut s = ByteWriter::new();
    codec::put_quantizer(&mut s, pq);
    w.section(SEC_QUANTIZER, &s.into_bytes());
    let mut s = ByteWriter::new();
    codec::put_encoded(&mut s, encoded);
    w.section(SEC_ENCODED, &s.into_bytes());
    let mut s = ByteWriter::new();
    codec::put_dataset(&mut s, raw);
    w.section(SEC_RAW, &s.into_bytes());
    if let Some(ivf) = ivf {
        let mut s = ByteWriter::new();
        codec::put_ivf(&mut s, ivf);
        w.section(SEC_IVF, &s.into_bytes());
    }
    if !persisted_jobs.is_empty() {
        let mut s = ByteWriter::new();
        jobs::put_jobs(&mut s, persisted_jobs);
        w.section(SEC_JOBS, &s.into_bytes());
    }
    if let Some(shard) = shard {
        let mut s = ByteWriter::new();
        put_shard(&mut s, shard);
        w.section(SEC_SHARD, &s.into_bytes());
    }
    let mut buf = w.into_bytes();
    let sum = fnv1a(&buf);
    buf.extend_from_slice(&sum.to_le_bytes());
    buf
}

/// Validate framing — size, magic, version, checksum — and return a
/// reader positioned at the first section.
fn checked_body(bytes: &[u8]) -> Result<ByteReader<'_>> {
    const MIN: usize = 8 + 4 + 8; // magic + version + checksum
    ensure!(
        bytes.len() >= MIN,
        "store: file of {} bytes is too small to be a pqdtw index",
        bytes.len()
    );
    let (body, tail) = bytes.split_at(bytes.len() - 8);
    let mut r = ByteReader::new(body);
    let magic = r.take(8)?;
    ensure!(magic == &MAGIC[..], "store: bad magic {magic:02x?} (not a pqdtw index)");
    let version = r.u32()?;
    ensure!(
        version == VERSION,
        "store: unsupported format version {version} (this build reads version {VERSION})"
    );
    let stored = ByteReader::new(tail).u64()?;
    let computed = fnv1a(body);
    ensure!(
        computed == stored,
        "store: checksum mismatch ({stored:016x} on disk, {computed:016x} computed)"
    );
    Ok(r)
}

/// Deserialize and fully validate an index from its byte form.
pub fn decode_index(bytes: &[u8]) -> Result<StoredIndex> {
    let mut r = checked_body(bytes)?;
    let (tag, payload) = r.section()?;
    ensure!(tag == SEC_HEADER, "store: expected header section, found tag {tag}");
    let header = get_header(payload, VERSION, bytes.len() as u64)?;
    let (tag, payload) = r.section()?;
    ensure!(tag == SEC_QUANTIZER, "store: expected quantizer section, found tag {tag}");
    let pq = codec::get_quantizer(payload)?;
    let (tag, payload) = r.section()?;
    ensure!(tag == SEC_ENCODED, "store: expected encoded section, found tag {tag}");
    let encoded = codec::get_encoded(payload, &pq)?;
    let (tag, payload) = r.section()?;
    ensure!(tag == SEC_RAW, "store: expected raw-dataset section, found tag {tag}");
    let raw = codec::get_dataset(payload)?;
    ensure!(
        raw.len == pq.series_len,
        "store: raw series length {} != quantizer length {}",
        raw.len,
        pq.series_len
    );
    ensure!(
        raw.n_series() == encoded.n(),
        "store: raw count {} != encoded count {}",
        raw.n_series(),
        encoded.n()
    );
    // Optional tail: [ivf], [jobs], [shard] — each independently
    // absent, but always in ascending tag order (which also rejects
    // duplicate sections).
    let mut ivf = None;
    let mut stored_jobs = Vec::new();
    let mut shard = None;
    let mut last_tag = SEC_RAW;
    while !r.is_exhausted() {
        let (tag, payload) = r.section()?;
        ensure!(
            tag > last_tag,
            "store: section tag {tag} out of order after tag {last_tag}"
        );
        last_tag = tag;
        match tag {
            SEC_IVF => {
                ivf = Some(codec::get_ivf(payload, pq.series_len, encoded.n())?);
            }
            SEC_JOBS => {
                let mut jr = ByteReader::new(payload);
                stored_jobs = jobs::get_jobs(&mut jr)?;
                ensure!(jr.is_exhausted(), "store: trailing bytes in jobs section");
            }
            SEC_SHARD => {
                shard = Some(get_shard(payload, encoded.n())?);
            }
            other => bail!("store: unexpected section tag {other}"),
        }
    }
    ensure!(
        header.n_subspaces == pq.config.n_subspaces
            && header.codebook_size == pq.codebook.k
            && header.sub_len == pq.codebook.sub_len
            && header.window == pq.codebook.window
            && header.metric == pq.codebook.metric
            && header.series_len == pq.series_len
            && header.n_series == encoded.n()
            && header.ivf_nlist == ivf.as_ref().map(|i| i.nlist()),
        "store: header summary disagrees with section contents"
    );
    Ok(StoredIndex { pq, encoded, raw, ivf, jobs: stored_jobs, shard })
}

/// Write the full serving state to `path`, atomically: the bytes go to
/// a sibling `<path>.tmp` first and are renamed into place, so an
/// interrupted save can never destroy a previously good index (the
/// index file is the long-lived artifact of the build-once /
/// serve-many split).
pub fn save_index(
    path: &Path,
    pq: &ProductQuantizer,
    encoded: &EncodedDataset,
    raw: &Dataset,
    ivf: Option<&IvfIndex>,
) -> Result<()> {
    save_index_with_jobs(path, pq, encoded, raw, ivf, &[])
}

/// [`save_index`] plus the durable job registry (the job plane's
/// persistence hook). An empty `jobs` slice writes no jobs section.
pub fn save_index_with_jobs(
    path: &Path,
    pq: &ProductQuantizer,
    encoded: &EncodedDataset,
    raw: &Dataset,
    ivf: Option<&IvfIndex>,
    persisted_jobs: &[PersistedJob],
) -> Result<()> {
    save_index_full(path, pq, encoded, raw, ivf, persisted_jobs, None)
}

/// [`save_index_with_jobs`] plus shard membership — the full writer
/// behind `build-index --shard i/n`. `None` shard writes no shard
/// section.
#[allow(clippy::too_many_arguments)]
pub fn save_index_full(
    path: &Path,
    pq: &ProductQuantizer,
    encoded: &EncodedDataset,
    raw: &Dataset,
    ivf: Option<&IvfIndex>,
    persisted_jobs: &[PersistedJob],
    shard: Option<&ShardInfo>,
) -> Result<()> {
    let bytes = encode_index_full(pq, encoded, raw, ivf, persisted_jobs, shard);
    let mut tmp_name = path.as_os_str().to_owned();
    tmp_name.push(".tmp");
    let tmp = std::path::PathBuf::from(tmp_name);
    std::fs::write(&tmp, &bytes)
        .with_context(|| format!("store: writing index to {}", tmp.display()))?;
    std::fs::rename(&tmp, path)
        .with_context(|| format!("store: moving {} into place", tmp.display()))
}

/// Read and fully validate the index at `path`.
pub fn load_index(path: &Path) -> Result<StoredIndex> {
    let bytes = std::fs::read(path)
        .with_context(|| format!("store: reading index from {}", path.display()))?;
    decode_index(&bytes).with_context(|| format!("store: decoding {}", path.display()))
}

/// Read only the summary header of the index at `path` (checksum still
/// verified — a corrupt file must not present a plausible header).
pub fn read_header(path: &Path) -> Result<StoreHeader> {
    let bytes = std::fs::read(path)
        .with_context(|| format!("store: reading index from {}", path.display()))?;
    let mut r = checked_body(&bytes)?;
    let (tag, payload) = r.section()?;
    ensure!(tag == SEC_HEADER, "store: expected header section, found tag {tag}");
    get_header(payload, VERSION, bytes.len() as u64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::random_walk::RandomWalks;
    use crate::nn::ivf::CoarseMetric;
    use crate::pq::quantizer::PqConfig;

    fn tiny_state() -> (ProductQuantizer, EncodedDataset, Dataset, IvfIndex) {
        let db = RandomWalks::new(17).generate(12, 24);
        let cfg = PqConfig {
            n_subspaces: 3,
            codebook_size: 4,
            window_frac: 0.3,
            kmeans_iters: 2,
            dba_iters: 1,
            ..Default::default()
        };
        let pq = ProductQuantizer::train(&db, &cfg, 7).unwrap();
        let enc = pq.encode_dataset(&db);
        let ivf = IvfIndex::build(&db, 3, CoarseMetric::Euclidean, 5);
        (pq, enc, db, ivf)
    }

    fn tiny_bytes() -> Vec<u8> {
        let (pq, enc, db, ivf) = tiny_state();
        encode_index(&pq, &enc, &db, Some(&ivf))
    }

    fn tiny_jobs() -> Vec<PersistedJob> {
        use crate::coordinator::Hit;
        use crate::jobs::{AllPairsRow, JobResult, JobSpec, JobStatus};
        use crate::nn::knn::PqQueryMode;
        use crate::obs::{HitExplain, Stage};
        vec![
            PersistedJob {
                id: 1,
                spec: JobSpec::AllPairsTopK {
                    k: 2,
                    mode: PqQueryMode::Asymmetric,
                    nprobe: None,
                    rerank: Some(4),
                },
                status: JobStatus::Completed,
                done: 2,
                total: 2,
                result: Some(JobResult::AllPairs(vec![AllPairsRow {
                    query_index: 0,
                    hits: vec![Hit { index: 0, distance: 0.0, label: None }],
                    explains: vec![HitExplain {
                        index: 0,
                        pq_estimate: 0.5,
                        exact_dtw: Some(0.25),
                        admitted_by: Stage::Rerank,
                        shard: None,
                    }],
                }])),
            },
            PersistedJob {
                id: 2,
                spec: JobSpec::ClusterSweep { k_clusters: 3, max_iters: 4, seed: 7 },
                status: JobStatus::Failed("worker died".into()),
                done: 5,
                total: 48,
                result: None,
            },
            PersistedJob {
                id: 4,
                spec: JobSpec::AutotuneNprobe { k: 3, target_recall: 0.95, sample: 8 },
                status: JobStatus::Queued,
                done: 0,
                total: 0,
                result: None,
            },
        ]
    }

    fn tiny_bytes_with_jobs() -> Vec<u8> {
        let (pq, enc, db, ivf) = tiny_state();
        encode_index_with_jobs(&pq, &enc, &db, Some(&ivf), &tiny_jobs())
    }

    fn restamp_checksum(bytes: &mut [u8]) {
        let n = bytes.len() - 8;
        let sum = fnv1a(&bytes[..n]);
        bytes[n..].copy_from_slice(&sum.to_le_bytes());
    }

    #[test]
    fn roundtrip_reconstructs_state_bit_exactly() {
        let (pq, enc, db, ivf) = tiny_state();
        let bytes = encode_index(&pq, &enc, &db, Some(&ivf));
        let idx = decode_index(&bytes).unwrap();
        assert_eq!(idx.pq.config, pq.config);
        assert_eq!(idx.pq.segmenter, pq.segmenter);
        assert_eq!(idx.pq.series_len, pq.series_len);
        assert_eq!(idx.pq.codebook.centroids, pq.codebook.centroids);
        assert_eq!(idx.pq.codebook.envelopes, pq.codebook.envelopes);
        assert_eq!(idx.pq.codebook.lut_sq, pq.codebook.lut_sq);
        assert_eq!(idx.pq.codebook.window, pq.codebook.window);
        assert_eq!(idx.encoded.codes, enc.codes);
        assert_eq!(idx.encoded.lb_self_sq, enc.lb_self_sq);
        assert_eq!(idx.encoded.labels, enc.labels);
        assert_eq!(idx.encoded.stats, enc.stats);
        assert_eq!(idx.raw.values, db.values);
        assert_eq!(idx.raw.len, db.len);
        assert_eq!(idx.raw.name, db.name);
        let r = idx.ivf.expect("IVF section present");
        assert_eq!(r.nlist(), ivf.nlist());
        assert_eq!(r.list_sizes(), ivf.list_sizes());
    }

    #[test]
    fn roundtrip_without_ivf() {
        let (pq, enc, db, _) = tiny_state();
        let bytes = encode_index(&pq, &enc, &db, None);
        let idx = decode_index(&bytes).unwrap();
        assert!(idx.ivf.is_none());
        assert!(idx.jobs.is_empty());
    }

    #[test]
    fn jobs_section_roundtrips_with_and_without_ivf() {
        let (pq, enc, db, ivf) = tiny_state();
        let jobs = tiny_jobs();
        // With IVF: sections [.., ivf, jobs].
        let bytes = encode_index_with_jobs(&pq, &enc, &db, Some(&ivf), &jobs);
        let idx = decode_index(&bytes).unwrap();
        assert!(idx.ivf.is_some());
        assert_eq!(idx.jobs, jobs);
        // Without IVF: sections [.., jobs].
        let bytes = encode_index_with_jobs(&pq, &enc, &db, None, &jobs);
        let idx = decode_index(&bytes).unwrap();
        assert!(idx.ivf.is_none());
        assert_eq!(idx.jobs, jobs);
    }

    #[test]
    fn empty_jobs_slice_is_byte_identical_to_the_plain_encoder() {
        let (pq, enc, db, ivf) = tiny_state();
        assert_eq!(
            encode_index(&pq, &enc, &db, Some(&ivf)),
            encode_index_with_jobs(&pq, &enc, &db, Some(&ivf), &[])
        );
    }

    /// Shard info for the 12-row tiny state: shard 1 of a 3-way split
    /// holds global rows 1, 4, 7, 10.
    fn tiny_shard() -> ShardInfo {
        ShardInfo { shard_index: 1, shard_count: 3, global_ids: vec![1, 4, 7, 10] }
    }

    /// Tiny state cut down to the 4 rows of [`tiny_shard`], so the
    /// shard section's row-count cross-check passes.
    fn tiny_shard_state() -> (ProductQuantizer, EncodedDataset, Dataset) {
        let (pq, _, db, _) = tiny_state();
        let sub = db.subset(&[1, 4, 7, 10]);
        let enc = pq.encode_dataset(&sub);
        (pq, enc, sub)
    }

    #[test]
    fn shard_section_roundtrips() {
        let (pq, enc, db) = tiny_shard_state();
        let shard = tiny_shard();
        let bytes = encode_index_full(&pq, &enc, &db, None, &[], Some(&shard));
        let idx = decode_index(&bytes).unwrap();
        assert_eq!(idx.shard, Some(shard));
        // With the full optional tail: [ivf], [jobs], [shard].
        let ivf = IvfIndex::build(&db, 2, CoarseMetric::Euclidean, 5);
        let shard = tiny_shard();
        let bytes =
            encode_index_full(&pq, &enc, &db, Some(&ivf), &tiny_jobs(), Some(&shard));
        let idx = decode_index(&bytes).unwrap();
        assert!(idx.ivf.is_some());
        assert_eq!(idx.jobs, tiny_jobs());
        assert_eq!(idx.shard, Some(shard));
    }

    #[test]
    fn absent_shard_is_byte_identical_to_the_jobs_encoder() {
        let (pq, enc, db, ivf) = tiny_state();
        assert_eq!(
            encode_index_with_jobs(&pq, &enc, &db, Some(&ivf), &tiny_jobs()),
            encode_index_full(&pq, &enc, &db, Some(&ivf), &tiny_jobs(), None)
        );
        assert!(decode_index(&encode_index(&pq, &enc, &db, None)).unwrap().shard.is_none());
    }

    #[test]
    fn hostile_shard_sections_are_rejected() {
        let (pq, enc, db) = tiny_shard_state();
        let cases: Vec<(&str, ShardInfo)> = vec![
            (
                "index out of range",
                ShardInfo { shard_index: 3, shard_count: 3, global_ids: vec![1, 4, 7, 10] },
            ),
            (
                "zero shard count",
                ShardInfo { shard_index: 0, shard_count: 0, global_ids: vec![1, 4, 7, 10] },
            ),
            (
                "id count mismatch",
                ShardInfo { shard_index: 1, shard_count: 3, global_ids: vec![1, 4, 7] },
            ),
            (
                "non-increasing ids",
                ShardInfo { shard_index: 1, shard_count: 3, global_ids: vec![1, 7, 4, 10] },
            ),
            (
                "id off the modular split",
                ShardInfo { shard_index: 1, shard_count: 3, global_ids: vec![1, 4, 7, 9] },
            ),
        ];
        for (name, shard) in cases {
            let bytes = encode_index_full(&pq, &enc, &db, None, &[], Some(&shard));
            assert!(decode_index(&bytes).is_err(), "case '{name}' must fail");
        }
    }

    #[test]
    fn every_single_byte_flip_errors_with_shard_section() {
        let (pq, enc, db) = tiny_shard_state();
        let good = encode_index_full(&pq, &enc, &db, None, &[], Some(&tiny_shard()));
        for i in (0..good.len()).step_by(sweep_stride()) {
            let mut bad = good.clone();
            bad[i] ^= 0x40;
            assert!(decode_index(&bad).is_err(), "flip at byte {i} must fail");
        }
    }

    #[test]
    fn restamped_hostile_shard_id_count_is_rejected() {
        let (pq, enc, db) = tiny_shard_state();
        let good = encode_index_full(&pq, &enc, &db, None, &[], Some(&tiny_shard()));
        // Locate the shard section and forge its id-count field (which
        // sits after the two u64 index/count fields).
        let mut pos = 12;
        let body_end = good.len() - 8;
        let payload_start = loop {
            assert!(pos + 9 <= body_end, "shard section must exist");
            let tag = good[pos];
            let len = u64::from_le_bytes(good[pos + 1..pos + 9].try_into().unwrap());
            if tag == SEC_SHARD {
                break pos + 9;
            }
            pos += 9 + usize::try_from(len).unwrap();
        };
        let count_at = payload_start + 16;
        let mut bad = good.clone();
        bad[count_at..count_at + 8].copy_from_slice(&u64::MAX.to_le_bytes());
        restamp_checksum(&mut bad);
        let err = decode_index(&bad).unwrap_err().to_string();
        assert!(err.contains("shard id count"), "unexpected error: {err}");
    }

    #[test]
    fn out_of_order_tail_sections_are_rejected() {
        // Hand-assemble a file whose optional tail carries [jobs] then
        // [ivf] — valid tags, wrong order — and assert the ordered-tag
        // check fires.
        let (pq, enc, db, ivf) = tiny_state();
        let mut w = ByteWriter::new();
        w.bytes(&MAGIC);
        w.u32(VERSION);
        let mut s = ByteWriter::new();
        put_header(&mut s, &pq, enc.n(), Some(&ivf));
        w.section(SEC_HEADER, &s.into_bytes());
        let mut s = ByteWriter::new();
        codec::put_quantizer(&mut s, &pq);
        w.section(SEC_QUANTIZER, &s.into_bytes());
        let mut s = ByteWriter::new();
        codec::put_encoded(&mut s, &enc);
        w.section(SEC_ENCODED, &s.into_bytes());
        let mut s = ByteWriter::new();
        codec::put_dataset(&mut s, &db);
        w.section(SEC_RAW, &s.into_bytes());
        let mut s = ByteWriter::new();
        jobs::put_jobs(&mut s, &tiny_jobs());
        w.section(SEC_JOBS, &s.into_bytes());
        let mut s = ByteWriter::new();
        codec::put_ivf(&mut s, &ivf);
        w.section(SEC_IVF, &s.into_bytes());
        let mut buf = w.into_bytes();
        let sum = fnv1a(&buf);
        buf.extend_from_slice(&sum.to_le_bytes());
        let err = decode_index(&buf).unwrap_err().to_string();
        assert!(err.contains("out of order"), "unexpected error: {err}");
    }

    #[test]
    fn header_summarizes_index_file() {
        let (pq, enc, db, ivf) = tiny_state();
        let bytes = encode_index(&pq, &enc, &db, Some(&ivf));
        let dir = crate::testutil::unique_temp_dir("store_header");
        let path = dir.join("idx.pqx");
        std::fs::write(&path, &bytes).unwrap();
        let h = read_header(&path).unwrap();
        assert_eq!(h.version, VERSION);
        assert_eq!(h.n_subspaces, 3);
        assert_eq!(h.codebook_size, 4);
        assert_eq!(h.sub_len, pq.codebook.sub_len);
        assert_eq!(h.window, pq.codebook.window);
        assert_eq!(h.series_len, 24);
        assert_eq!(h.n_series, 12);
        assert_eq!(h.ivf_nlist, Some(ivf.nlist()));
        assert_eq!(h.file_bytes, bytes.len() as u64);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn corrupt_inputs_error_without_panicking() {
        let good = tiny_bytes();

        let mut bad_magic = good.clone();
        bad_magic[0] ^= 0xff;
        restamp_checksum(&mut bad_magic);

        // Re-stamp the checksum so the *version* check fires, not the
        // checksum check.
        let mut wrong_version = good.clone();
        wrong_version[8..12].copy_from_slice(&999u32.to_le_bytes());
        restamp_checksum(&mut wrong_version);

        let mut flipped_checksum = good.clone();
        let last = flipped_checksum.len() - 1;
        flipped_checksum[last] ^= 0x01;

        // First section's length prefix lives at bytes [13, 21): claim
        // an absurd section length — must be rejected without a huge
        // allocation.
        let mut oversized = good.clone();
        oversized[13..21].copy_from_slice(&u64::MAX.to_le_bytes());
        restamp_checksum(&mut oversized);

        let cases: Vec<(&str, Vec<u8>)> = vec![
            ("empty", Vec::new()),
            ("below minimum size", good[..10].to_vec()),
            ("truncated to half", good[..good.len() / 2].to_vec()),
            ("truncated by one byte", good[..good.len() - 1].to_vec()),
            ("bad magic", bad_magic),
            ("wrong version", wrong_version),
            ("flipped checksum byte", flipped_checksum),
            ("oversized section length", oversized),
        ];
        for (name, bytes) in cases {
            assert!(decode_index(&bytes).is_err(), "case '{name}' must fail");
        }
    }

    #[test]
    fn wrong_version_error_names_the_version() {
        let mut bytes = tiny_bytes();
        bytes[8..12].copy_from_slice(&7u32.to_le_bytes());
        restamp_checksum(&mut bytes);
        let err = decode_index(&bytes).unwrap_err().to_string();
        assert!(err.contains("version 7"), "unexpected error: {err}");
    }

    /// Under Miri every decode costs seconds, not microseconds; stride
    /// the exhaustive sweeps so the UB check still covers a sample of
    /// every region without taking hours. Native runs stay exhaustive.
    fn sweep_stride() -> usize {
        if cfg!(miri) {
            61 // prime, so successive runs touch different offsets mod stride
        } else {
            1
        }
    }

    #[test]
    fn every_prefix_truncation_errors() {
        let good = tiny_bytes();
        for n in (0..good.len()).step_by(sweep_stride()) {
            assert!(decode_index(&good[..n]).is_err(), "prefix of {n} bytes must fail");
        }
    }

    #[test]
    fn every_single_byte_flip_errors() {
        // The checksum covers the body and the trailing checksum bytes
        // protect themselves: any single-byte corruption must be caught.
        let good = tiny_bytes();
        for i in (0..good.len()).step_by(sweep_stride()) {
            let mut bad = good.clone();
            bad[i] ^= 0x40;
            assert!(decode_index(&bad).is_err(), "flip at byte {i} must fail");
        }
    }

    /// The corruption sweeps over a file *with* a jobs section: the new
    /// trailing section must not weaken the existing guarantees, and
    /// corrupting it must never corrupt (or crash on) the sections
    /// before it. The jobs section sits at the end of the body, so the
    /// sweep tail exercises it specifically.
    #[test]
    fn every_prefix_truncation_errors_with_jobs_section() {
        let good = tiny_bytes_with_jobs();
        for n in (0..good.len()).step_by(sweep_stride()) {
            assert!(decode_index(&good[..n]).is_err(), "prefix of {n} bytes must fail");
        }
    }

    #[test]
    fn every_single_byte_flip_errors_with_jobs_section() {
        let good = tiny_bytes_with_jobs();
        for i in (0..good.len()).step_by(sweep_stride()) {
            let mut bad = good.clone();
            bad[i] ^= 0x40;
            assert!(decode_index(&bad).is_err(), "flip at byte {i} must fail");
        }
    }

    /// Even with a valid checksum (re-stamped after corruption), a
    /// hostile job count inside the jobs section must be rejected
    /// before allocating.
    #[test]
    fn restamped_hostile_job_count_is_rejected() {
        let good = tiny_bytes_with_jobs();
        // Locate the jobs section: walk the sections from the front.
        let mut pos = 12; // magic + version
        let body_end = good.len() - 8;
        let jobs_payload_start = loop {
            assert!(pos + 9 <= body_end, "jobs section must exist");
            let tag = good[pos];
            let len = u64::from_le_bytes(good[pos + 1..pos + 9].try_into().unwrap());
            if tag == SEC_JOBS {
                break pos + 9;
            }
            pos += 9 + usize::try_from(len).unwrap();
        };
        let mut bad = good.clone();
        // First payload field is the u64 job count.
        bad[jobs_payload_start..jobs_payload_start + 8]
            .copy_from_slice(&u64::MAX.to_le_bytes());
        restamp_checksum(&mut bad);
        let err = decode_index(&bad).unwrap_err().to_string();
        assert!(err.contains("job count"), "unexpected error: {err}");
    }

    #[test]
    fn missing_file_errors_with_path() {
        let err = load_index(Path::new("/nonexistent/pqdtw.idx")).unwrap_err();
        assert!(format!("{err:#}").contains("nonexistent"));
    }
}
