//! Encoders/decoders for the durable job plane ([`crate::jobs`]): the
//! store's jobs section *and* the v3 wire frames share these codecs,
//! so the validate-before-alloc discipline is enforced in one place.
//!
//! Layout notes: every length prefix is validated against a per-element
//! minimum byte size *before* any allocation (a hostile count can never
//! trigger a huge allocation); `f64` values round-trip via their IEEE
//! bit patterns; enum discriminants are the stable `as_u8`/`tag` values
//! documented on the types themselves.

use anyhow::{bail, ensure, Result};

use crate::coordinator::Hit;
use crate::jobs::{
    AllPairsRow, JobEvent, JobKind, JobResult, JobSnapshot, JobSpec, JobStatus, PersistedJob,
    SweepPoint,
};
use crate::nn::knn::PqQueryMode;
use crate::obs::{HitExplain, Stage};

use super::format::{ByteReader, ByteWriter};

fn mode_tag(m: PqQueryMode) -> u8 {
    match m {
        PqQueryMode::Symmetric => 0,
        PqQueryMode::Asymmetric => 1,
    }
}

fn mode_from(tag: u8) -> Result<PqQueryMode> {
    match tag {
        0 => Ok(PqQueryMode::Symmetric),
        1 => Ok(PqQueryMode::Asymmetric),
        other => bail!("jobs: unknown query-mode tag {other}"),
    }
}

fn put_opt_f64(w: &mut ByteWriter, v: Option<f64>) {
    match v {
        Some(x) => {
            w.u8(1);
            w.f64(x);
        }
        None => w.u8(0),
    }
}

fn get_opt_f64(r: &mut ByteReader) -> Result<Option<f64>> {
    match r.u8()? {
        0 => Ok(None),
        1 => Ok(Some(r.f64()?)),
        other => bail!("jobs: bad option flag {other}"),
    }
}

fn put_opt_i64(w: &mut ByteWriter, v: Option<i64>) {
    match v {
        Some(x) => {
            w.u8(1);
            w.bytes(&x.to_le_bytes());
        }
        None => w.u8(0),
    }
}

fn get_opt_i64(r: &mut ByteReader) -> Result<Option<i64>> {
    match r.u8()? {
        0 => Ok(None),
        1 => Ok(Some(i64::from_le_bytes(r.u64()?.to_le_bytes()))),
        other => bail!("jobs: bad option flag {other}"),
    }
}

fn put_opt_u64(w: &mut ByteWriter, v: Option<u64>) {
    match v {
        Some(x) => {
            w.u8(1);
            w.u64(x);
        }
        None => w.u8(0),
    }
}

fn get_opt_u64(r: &mut ByteReader) -> Result<Option<u64>> {
    match r.u8()? {
        0 => Ok(None),
        1 => Ok(Some(r.u64()?)),
        other => bail!("jobs: bad option flag {other}"),
    }
}

fn get_kind(r: &mut ByteReader) -> Result<JobKind> {
    let v = r.u8()?;
    JobKind::from_u8(v).ok_or_else(|| anyhow::anyhow!("jobs: unknown job-kind tag {v}"))
}

fn get_stage(r: &mut ByteReader) -> Result<Stage> {
    let v = r.u8()?;
    Stage::from_u8(v).ok_or_else(|| anyhow::anyhow!("jobs: unknown stage tag {v}"))
}

/// Serialize a job spec (kind tag + parameters).
pub(crate) fn put_spec(w: &mut ByteWriter, spec: &JobSpec) {
    w.u8(spec.kind().as_u8());
    match spec {
        JobSpec::AllPairsTopK { k, mode, nprobe, rerank } => {
            w.usize(*k);
            w.u8(mode_tag(*mode));
            w.opt_usize(*nprobe);
            w.opt_usize(*rerank);
        }
        JobSpec::ClusterSweep { k_clusters, max_iters, seed } => {
            w.usize(*k_clusters);
            w.usize(*max_iters);
            w.u64(*seed);
        }
        JobSpec::AutotuneNprobe { k, target_recall, sample } => {
            w.usize(*k);
            w.f64(*target_recall);
            w.usize(*sample);
        }
    }
}

/// Deserialize a job spec.
pub(crate) fn get_spec(r: &mut ByteReader) -> Result<JobSpec> {
    Ok(match get_kind(r)? {
        JobKind::AllPairsTopK => JobSpec::AllPairsTopK {
            k: r.usize()?,
            mode: mode_from(r.u8()?)?,
            nprobe: r.opt_usize()?,
            rerank: r.opt_usize()?,
        },
        JobKind::ClusterSweep => JobSpec::ClusterSweep {
            k_clusters: r.usize()?,
            max_iters: r.usize()?,
            seed: r.u64()?,
        },
        JobKind::AutotuneNprobe => JobSpec::AutotuneNprobe {
            k: r.usize()?,
            target_recall: r.f64()?,
            sample: r.usize()?,
        },
    })
}

/// Serialize a status (tag + failure message when `Failed`).
pub(crate) fn put_status(w: &mut ByteWriter, status: &JobStatus) {
    w.u8(status.tag());
    if let JobStatus::Failed(msg) = status {
        w.string(msg);
    }
}

/// Deserialize a status.
pub(crate) fn get_status(r: &mut ByteReader) -> Result<JobStatus> {
    Ok(match r.u8()? {
        0 => JobStatus::Queued,
        1 => JobStatus::Running,
        2 => JobStatus::Completed,
        3 => JobStatus::Cancelled,
        4 => JobStatus::Failed(r.string()?),
        other => bail!("jobs: unknown status tag {other}"),
    })
}

/// Serialize a snapshot (the `JobStatus` wire frame body).
pub(crate) fn put_snapshot(w: &mut ByteWriter, s: &JobSnapshot) {
    w.u64(s.id);
    w.u8(s.kind.as_u8());
    put_status(w, &s.status);
    w.u64(s.done);
    w.u64(s.total);
    put_opt_u64(w, s.eta_us);
    w.u64(s.latest_seq);
}

/// Deserialize a snapshot.
pub(crate) fn get_snapshot(r: &mut ByteReader) -> Result<JobSnapshot> {
    Ok(JobSnapshot {
        id: r.u64()?,
        kind: get_kind(r)?,
        status: get_status(r)?,
        done: r.u64()?,
        total: r.u64()?,
        eta_us: get_opt_u64(r)?,
        latest_seq: r.u64()?,
    })
}

/// Serialize one progress event.
pub(crate) fn put_event(w: &mut ByteWriter, e: &JobEvent) {
    w.u64(e.seq);
    w.u8(e.stage.as_u8());
    w.u64(e.done);
    w.u64(e.total);
    put_opt_u64(w, e.eta_us);
    w.string(&e.message);
}

/// Deserialize one progress event.
pub(crate) fn get_event(r: &mut ByteReader) -> Result<JobEvent> {
    Ok(JobEvent {
        seq: r.u64()?,
        stage: get_stage(r)?,
        done: r.u64()?,
        total: r.u64()?,
        eta_us: get_opt_u64(r)?,
        message: r.string()?,
    })
}

/// Minimum encoded size of one event: seq 8 + stage 1 + done 8 +
/// total 8 + eta flag 1 + message length 8.
pub(crate) const MIN_EVENT_BYTES: usize = 34;

/// Serialize an event list.
pub(crate) fn put_events(w: &mut ByteWriter, events: &[JobEvent]) {
    w.usize(events.len());
    for e in events {
        put_event(w, e);
    }
}

/// Deserialize an event list (count validated before allocation).
pub(crate) fn get_events(r: &mut ByteReader) -> Result<Vec<JobEvent>> {
    let n = r.usize()?;
    ensure!(
        n.saturating_mul(MIN_EVENT_BYTES) <= r.remaining(),
        "jobs: event count {n} exceeds remaining bytes"
    );
    let mut events = Vec::with_capacity(n);
    for _ in 0..n {
        events.push(get_event(r)?);
    }
    Ok(events)
}

/// Serialize a result payload (kind tag + payload).
pub(crate) fn put_result(w: &mut ByteWriter, result: &JobResult) {
    w.u8(result.kind().as_u8());
    match result {
        JobResult::AllPairs(rows) => {
            w.usize(rows.len());
            for row in rows {
                w.u64(row.query_index);
                w.usize(row.hits.len());
                for h in &row.hits {
                    w.usize(h.index);
                    w.f64(h.distance);
                    put_opt_i64(w, h.label);
                }
                w.usize(row.explains.len());
                for e in &row.explains {
                    w.u64(e.index);
                    w.f64(e.pq_estimate);
                    put_opt_f64(w, e.exact_dtw);
                    w.u8(e.admitted_by.as_u8());
                }
            }
        }
        JobResult::Cluster { medoids, assignment, cost } => {
            w.vec_usize(medoids);
            w.vec_usize(assignment);
            w.f64(*cost);
        }
        JobResult::Autotune { recommended_nprobe, sweep } => {
            w.usize(*recommended_nprobe);
            w.usize(sweep.len());
            for p in sweep {
                w.usize(p.nprobe);
                w.f64(p.recall);
            }
        }
    }
}

/// Deserialize a result payload.
pub(crate) fn get_result(r: &mut ByteReader) -> Result<JobResult> {
    Ok(match get_kind(r)? {
        JobKind::AllPairsTopK => {
            let n_rows = r.usize()?;
            // query index + hit count + explain count = ≥ 24 B per row.
            ensure!(
                n_rows.saturating_mul(24) <= r.remaining(),
                "jobs: row count {n_rows} exceeds remaining bytes"
            );
            let mut rows = Vec::with_capacity(n_rows);
            for _ in 0..n_rows {
                let query_index = r.u64()?;
                let n_hits = r.usize()?;
                // index + distance + label presence byte = ≥ 17 B.
                ensure!(
                    n_hits.saturating_mul(17) <= r.remaining(),
                    "jobs: hit count {n_hits} exceeds remaining bytes"
                );
                let mut hits = Vec::with_capacity(n_hits);
                for _ in 0..n_hits {
                    hits.push(Hit {
                        index: r.usize()?,
                        distance: r.f64()?,
                        label: get_opt_i64(r)?,
                    });
                }
                let n_explains = r.usize()?;
                // index + estimate + exact presence + stage = ≥ 18 B.
                ensure!(
                    n_explains.saturating_mul(18) <= r.remaining(),
                    "jobs: explain count {n_explains} exceeds remaining bytes"
                );
                let mut explains = Vec::with_capacity(n_explains);
                for _ in 0..n_explains {
                    // The job store never persists shard provenance —
                    // job results are computed by one engine.
                    explains.push(HitExplain {
                        index: r.u64()?,
                        pq_estimate: r.f64()?,
                        exact_dtw: get_opt_f64(r)?,
                        admitted_by: get_stage(r)?,
                        shard: None,
                    });
                }
                rows.push(AllPairsRow { query_index, hits, explains });
            }
            JobResult::AllPairs(rows)
        }
        JobKind::ClusterSweep => JobResult::Cluster {
            medoids: r.vec_usize()?,
            assignment: r.vec_usize()?,
            cost: r.f64()?,
        },
        JobKind::AutotuneNprobe => {
            let recommended_nprobe = r.usize()?;
            let n = r.usize()?;
            // nprobe + recall = 16 B per sweep point.
            ensure!(
                n.saturating_mul(16) <= r.remaining(),
                "jobs: sweep count {n} exceeds remaining bytes"
            );
            let mut sweep = Vec::with_capacity(n);
            for _ in 0..n {
                sweep.push(SweepPoint { nprobe: r.usize()?, recall: r.f64()? });
            }
            JobResult::Autotune { recommended_nprobe, sweep }
        }
    })
}

/// Serialize the jobs-section payload: a job count followed by each
/// job's id, spec, status, progress and optional result.
pub(crate) fn put_jobs(w: &mut ByteWriter, jobs: &[PersistedJob]) {
    w.usize(jobs.len());
    for j in jobs {
        w.u64(j.id);
        put_spec(w, &j.spec);
        put_status(w, &j.status);
        w.u64(j.done);
        w.u64(j.total);
        match &j.result {
            Some(result) => {
                w.u8(1);
                put_result(w, result);
            }
            None => w.u8(0),
        }
    }
}

/// Deserialize the jobs-section payload, cross-checking that each
/// result's kind matches its spec's kind.
pub(crate) fn get_jobs(r: &mut ByteReader) -> Result<Vec<PersistedJob>> {
    let n = r.usize()?;
    // id 8 + spec (kind tag + smallest body) 12 + status 1 + done 8 +
    // total 8 + result presence byte 1 = ≥ 38 B per job.
    ensure!(
        n.saturating_mul(38) <= r.remaining(),
        "jobs: job count {n} exceeds remaining bytes"
    );
    let mut jobs = Vec::with_capacity(n);
    let mut prev_id: Option<u64> = None;
    for _ in 0..n {
        let id = r.u64()?;
        if let Some(p) = prev_id {
            ensure!(id > p, "jobs: ids must be strictly ascending ({p} then {id})");
        }
        prev_id = Some(id);
        let spec = get_spec(r)?;
        let status = get_status(r)?;
        let done = r.u64()?;
        let total = r.u64()?;
        let result = match r.u8()? {
            0 => None,
            1 => Some(get_result(r)?),
            other => bail!("jobs: bad result flag {other}"),
        };
        if let Some(res) = &result {
            ensure!(
                res.kind() == spec.kind(),
                "jobs: result kind {:?} disagrees with spec kind {:?}",
                res.kind(),
                spec.kind()
            );
            ensure!(
                status == JobStatus::Completed,
                "jobs: result present on non-completed job {id}"
            );
        }
        jobs.push(PersistedJob { id, spec, status, done, total, result });
    }
    Ok(jobs)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_jobs() -> Vec<PersistedJob> {
        vec![
            PersistedJob {
                id: 1,
                spec: JobSpec::AllPairsTopK {
                    k: 3,
                    mode: PqQueryMode::Asymmetric,
                    nprobe: Some(2),
                    rerank: Some(8),
                },
                status: JobStatus::Completed,
                done: 4,
                total: 4,
                result: Some(JobResult::AllPairs(vec![AllPairsRow {
                    query_index: 0,
                    hits: vec![
                        Hit { index: 0, distance: 0.0, label: Some(-3) },
                        Hit { index: 2, distance: f64::NAN, label: None },
                    ],
                    explains: vec![HitExplain {
                        index: 2,
                        pq_estimate: 1.25,
                        exact_dtw: Some(-0.0),
                        admitted_by: Stage::Rerank,
                        shard: None,
                    }],
                }])),
            },
            PersistedJob {
                id: 2,
                spec: JobSpec::ClusterSweep { k_clusters: 2, max_iters: 5, seed: 99 },
                status: JobStatus::Failed("synthetic failure".into()),
                done: 1,
                total: 10,
                result: None,
            },
            PersistedJob {
                id: 7,
                spec: JobSpec::AutotuneNprobe { k: 5, target_recall: 0.9, sample: 16 },
                status: JobStatus::Queued,
                done: 0,
                total: 0,
                result: None,
            },
        ]
    }

    #[test]
    fn jobs_roundtrip_is_bit_exact() {
        let jobs = sample_jobs();
        let mut w = ByteWriter::new();
        put_jobs(&mut w, &jobs);
        let bytes = w.into_bytes();
        let mut r = ByteReader::new(&bytes);
        let back = get_jobs(&mut r).unwrap();
        assert!(r.is_exhausted());
        assert_eq!(back.len(), jobs.len());
        // NaN distances break PartialEq; compare the NaN hit by bits.
        let (Some(JobResult::AllPairs(rows)), Some(JobResult::AllPairs(orig))) =
            (&back[0].result, &jobs[0].result)
        else {
            panic!("first job must carry an all-pairs result")
        };
        assert_eq!(
            rows[0].hits[1].distance.to_bits(),
            orig[0].hits[1].distance.to_bits()
        );
        assert_eq!(back[1], jobs[1]);
        assert_eq!(back[2], jobs[2]);
    }

    #[test]
    fn hostile_counts_are_rejected_before_allocating() {
        // Job count far larger than the buffer.
        let mut w = ByteWriter::new();
        w.usize(usize::MAX / 64);
        let bytes = w.into_bytes();
        assert!(get_jobs(&mut ByteReader::new(&bytes)).is_err());

        // Event count far larger than the buffer.
        let mut w = ByteWriter::new();
        w.usize(1 << 60);
        let bytes = w.into_bytes();
        assert!(get_events(&mut ByteReader::new(&bytes)).is_err());

        // Hostile row count inside an all-pairs result.
        let mut w = ByteWriter::new();
        w.u8(JobKind::AllPairsTopK.as_u8());
        w.usize(1 << 59);
        let bytes = w.into_bytes();
        assert!(get_result(&mut ByteReader::new(&bytes)).is_err());
    }

    #[test]
    fn result_kind_mismatch_is_rejected() {
        let mut w = ByteWriter::new();
        put_jobs(
            &mut w,
            &[PersistedJob {
                id: 1,
                spec: JobSpec::ClusterSweep { k_clusters: 2, max_iters: 1, seed: 0 },
                status: JobStatus::Completed,
                done: 1,
                total: 1,
                result: Some(JobResult::Autotune { recommended_nprobe: 1, sweep: vec![] }),
            }],
        );
        let bytes = w.into_bytes();
        assert!(get_jobs(&mut ByteReader::new(&bytes)).is_err());
    }

    #[test]
    fn result_on_non_completed_job_is_rejected() {
        let mut w = ByteWriter::new();
        put_jobs(
            &mut w,
            &[PersistedJob {
                id: 3,
                spec: JobSpec::AutotuneNprobe { k: 1, target_recall: 1.0, sample: 1 },
                status: JobStatus::Running,
                done: 0,
                total: 4,
                result: Some(JobResult::Autotune { recommended_nprobe: 1, sweep: vec![] }),
            }],
        );
        let bytes = w.into_bytes();
        assert!(get_jobs(&mut ByteReader::new(&bytes)).is_err());
    }

    #[test]
    fn non_ascending_ids_are_rejected() {
        let job = PersistedJob {
            id: 5,
            spec: JobSpec::AutotuneNprobe { k: 1, target_recall: 1.0, sample: 1 },
            status: JobStatus::Queued,
            done: 0,
            total: 0,
            result: None,
        };
        let mut w = ByteWriter::new();
        put_jobs(&mut w, &[job.clone(), job]);
        let bytes = w.into_bytes();
        let err = get_jobs(&mut ByteReader::new(&bytes)).unwrap_err().to_string();
        assert!(err.contains("ascending"), "unexpected error: {err}");
    }

    #[test]
    fn unknown_tags_are_rejected() {
        // Unknown kind tag.
        let mut r = ByteReader::new(&[0xEE]);
        assert!(get_spec(&mut r).is_err());
        // Unknown status tag.
        let mut r = ByteReader::new(&[9]);
        assert!(get_status(&mut r).is_err());
        // Unknown stage tag inside an event.
        let mut w = ByteWriter::new();
        w.u64(1); // seq
        w.u8(0xEE); // stage
        let bytes = w.into_bytes();
        assert!(get_event(&mut ByteReader::new(&bytes)).is_err());
    }
}
