//! Low-level byte-layout primitives for the on-disk index format.
//!
//! Everything is explicit little-endian, hand-rolled over `std` — the
//! offline registry carries no serialization crate and the format must
//! not depend on one. Reading is slice-based and bounds-checked: every
//! length prefix is validated against the bytes actually present
//! *before* any allocation, so truncated or hostile inputs return
//! `Err` instead of panicking or triggering a huge allocation.

use anyhow::{bail, Context, Result};

/// Magic bytes at offset 0 of every index file.
pub const MAGIC: [u8; 8] = *b"PQDTWIDX";

/// Current format version (see `docs/index-format.md` for the bump
/// policy: any layout change increments this and readers reject files
/// they were not built to parse).
pub const VERSION: u32 = 2;

/// FNV-1a 64-bit hash — the file's dependency-free corruption check.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Append-only little-endian byte sink.
#[derive(Debug, Default)]
pub struct ByteWriter {
    buf: Vec<u8>,
}

impl ByteWriter {
    /// Empty writer.
    pub fn new() -> Self {
        Self::default()
    }

    /// Bytes written so far.
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    /// True when nothing has been written.
    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Consume the writer, returning its buffer.
    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    /// Raw bytes, verbatim.
    pub fn bytes(&mut self, b: &[u8]) {
        self.buf.extend_from_slice(b);
    }

    /// One byte.
    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    /// `u32`, little-endian.
    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// `u64`, little-endian.
    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// `usize`, stored as a little-endian `u64`.
    pub fn usize(&mut self, v: usize) {
        self.u64(v as u64);
    }

    /// `f64` as its IEEE-754 bit pattern, little-endian (bit-exact
    /// round-trip, NaN payloads included).
    pub fn f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// `Option<usize>` as a presence byte plus the value.
    pub fn opt_usize(&mut self, v: Option<usize>) {
        match v {
            Some(x) => {
                self.u8(1);
                self.usize(x);
            }
            None => self.u8(0),
        }
    }

    /// Length-prefixed `f64` buffer.
    pub fn vec_f64(&mut self, v: &[f64]) {
        self.usize(v.len());
        for &x in v {
            self.f64(x);
        }
    }

    /// Length-prefixed `u16` buffer.
    pub fn vec_u16(&mut self, v: &[u16]) {
        self.usize(v.len());
        for &x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }

    /// Length-prefixed `i64` buffer.
    pub fn vec_i64(&mut self, v: &[i64]) {
        self.usize(v.len());
        for &x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }

    /// Length-prefixed `usize` buffer (elements as `u64`).
    pub fn vec_usize(&mut self, v: &[usize]) {
        self.usize(v.len());
        for &x in v {
            self.usize(x);
        }
    }

    /// Length-prefixed UTF-8 string.
    pub fn string(&mut self, s: &str) {
        self.usize(s.len());
        self.bytes(s.as_bytes());
    }

    /// Append `payload` as a tagged, length-prefixed section.
    pub fn section(&mut self, tag: u8, payload: &[u8]) {
        self.u8(tag);
        self.usize(payload.len());
        self.bytes(payload);
    }
}

/// Bounds-checked little-endian slice reader. A failed read consumes
/// nothing, and no read ever reaches past the slice.
#[derive(Debug)]
pub struct ByteReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> ByteReader<'a> {
    /// Reader over `buf`, positioned at its start.
    pub fn new(buf: &'a [u8]) -> Self {
        ByteReader { buf, pos: 0 }
    }

    /// Bytes left to read.
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// True when every byte has been consumed.
    pub fn is_exhausted(&self) -> bool {
        self.remaining() == 0
    }

    /// Borrow the next `n` bytes.
    pub fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if n > self.remaining() {
            bail!(
                "store: need {n} bytes but only {} remain (truncated file?)",
                self.remaining()
            );
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    /// Borrow the next `N` bytes as a fixed-size array. `take`
    /// returns exactly `N` bytes on success, so the conversion is
    /// infallible in practice; it still propagates rather than panics.
    fn arr<const N: usize>(&mut self) -> Result<[u8; N]> {
        self.take(N)?
            .try_into()
            .context("store: fixed-width read returned the wrong length")
    }

    /// One byte.
    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    /// `u32`, little-endian.
    pub fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.arr()?))
    }

    /// `u64`, little-endian.
    pub fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.arr()?))
    }

    /// `f64` from its little-endian bit pattern.
    pub fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.arr()?))
    }

    /// `usize` from a little-endian `u64`.
    pub fn usize(&mut self) -> Result<usize> {
        let v = self.u64()?;
        usize::try_from(v).context("store: stored value exceeds usize")
    }

    /// `Option<usize>` from a presence byte plus the value.
    pub fn opt_usize(&mut self) -> Result<Option<usize>> {
        match self.u8()? {
            0 => Ok(None),
            1 => Ok(Some(self.usize()?)),
            other => bail!("store: bad option flag {other}"),
        }
    }

    /// Element count for `elem_size`-byte items, validated against the
    /// bytes actually remaining — a hostile length prefix can therefore
    /// never trigger a huge allocation.
    fn checked_count(&mut self, elem_size: usize) -> Result<usize> {
        let n = self.usize()?;
        match n.checked_mul(elem_size) {
            Some(total) if total <= self.remaining() => Ok(n),
            _ => bail!(
                "store: section claims {n} elements of {elem_size} B but only {} bytes remain",
                self.remaining()
            ),
        }
    }

    /// Length-prefixed `f64` buffer.
    pub fn vec_f64(&mut self) -> Result<Vec<f64>> {
        let n = self.checked_count(8)?;
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            v.push(self.f64()?);
        }
        Ok(v)
    }

    /// Length-prefixed `u16` buffer.
    pub fn vec_u16(&mut self) -> Result<Vec<u16>> {
        let n = self.checked_count(2)?;
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            v.push(u16::from_le_bytes(self.arr()?));
        }
        Ok(v)
    }

    /// Length-prefixed `i64` buffer.
    pub fn vec_i64(&mut self) -> Result<Vec<i64>> {
        let n = self.checked_count(8)?;
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            v.push(i64::from_le_bytes(self.arr()?));
        }
        Ok(v)
    }

    /// Length-prefixed `usize` buffer (elements as `u64`).
    pub fn vec_usize(&mut self) -> Result<Vec<usize>> {
        let n = self.checked_count(8)?;
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            v.push(self.usize()?);
        }
        Ok(v)
    }

    /// Length-prefixed UTF-8 string.
    pub fn string(&mut self) -> Result<String> {
        let n = self.checked_count(1)?;
        let bytes = self.take(n)?;
        String::from_utf8(bytes.to_vec()).context("store: invalid UTF-8 in string")
    }

    /// Read one section header, returning `(tag, payload)`.
    pub fn section(&mut self) -> Result<(u8, &'a [u8])> {
        let tag = self.u8()?;
        let len = self.checked_count(1)?;
        let payload = self.take(len)?;
        Ok((tag, payload))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn primitive_roundtrip_is_bit_exact() {
        let mut w = ByteWriter::new();
        w.u8(7);
        w.u32(0xdead_beef);
        w.u64(u64::MAX - 1);
        w.f64(-0.0);
        w.f64(f64::NAN);
        w.opt_usize(None);
        w.opt_usize(Some(42));
        w.vec_f64(&[1.5, -2.5]);
        w.vec_u16(&[1, 65535]);
        w.vec_i64(&[-9, 9]);
        w.vec_usize(&[3, 1, 2]);
        w.string("héllo");
        let bytes = w.into_bytes();
        let mut r = ByteReader::new(&bytes);
        assert_eq!(r.u8().unwrap(), 7);
        assert_eq!(r.u32().unwrap(), 0xdead_beef);
        assert_eq!(r.u64().unwrap(), u64::MAX - 1);
        assert_eq!(r.f64().unwrap().to_bits(), (-0.0f64).to_bits());
        assert!(r.f64().unwrap().is_nan());
        assert_eq!(r.opt_usize().unwrap(), None);
        assert_eq!(r.opt_usize().unwrap(), Some(42));
        assert_eq!(r.vec_f64().unwrap(), vec![1.5, -2.5]);
        assert_eq!(r.vec_u16().unwrap(), vec![1, 65535]);
        assert_eq!(r.vec_i64().unwrap(), vec![-9, 9]);
        assert_eq!(r.vec_usize().unwrap(), vec![3, 1, 2]);
        assert_eq!(r.string().unwrap(), "héllo");
        assert!(r.is_exhausted());
    }

    #[test]
    fn oversized_count_is_rejected_without_allocating() {
        let mut w = ByteWriter::new();
        w.u64(u64::MAX); // claims u64::MAX 8-byte elements
        let bytes = w.into_bytes();
        assert!(ByteReader::new(&bytes).vec_f64().is_err());
        let mut w = ByteWriter::new();
        w.u64(1 << 60); // plausible-looking but larger than the file
        let bytes = w.into_bytes();
        assert!(ByteReader::new(&bytes).vec_usize().is_err());
    }

    #[test]
    fn short_reads_error_and_consume_nothing() {
        let mut r = ByteReader::new(&[1, 2, 3]);
        assert!(r.u64().is_err());
        assert_eq!(r.remaining(), 3);
        assert_eq!(r.u8().unwrap(), 1);
    }

    #[test]
    fn fnv_is_stable_and_sensitive() {
        assert_eq!(fnv1a(b"hello"), fnv1a(b"hello"));
        assert_ne!(fnv1a(b"hello"), fnv1a(b"hellp"));
        // Known FNV-1a 64 offset basis: hash of the empty input.
        assert_eq!(fnv1a(b""), 0xcbf2_9ce4_8422_2325);
    }

    #[test]
    fn sections_carry_tag_and_payload() {
        let mut w = ByteWriter::new();
        w.section(9, &[1, 2, 3]);
        w.section(10, &[]);
        let bytes = w.into_bytes();
        let mut r = ByteReader::new(&bytes);
        let (t, p) = r.section().unwrap();
        assert_eq!((t, p), (9, &[1u8, 2, 3][..]));
        let (t, p) = r.section().unwrap();
        assert_eq!(t, 10);
        assert!(p.is_empty());
        assert!(r.is_exhausted());
    }

    #[test]
    fn invalid_option_flag_errors() {
        let mut r = ByteReader::new(&[2]);
        assert!(r.opt_usize().is_err());
    }
}
