//! Encoders/decoders for the serving-state types over the byte-layout
//! primitives in [`super::format`].
//!
//! Every decoder validates shapes and value ranges before constructing
//! state, so a tampered or corrupt file fails with an `Err` at load
//! time instead of panicking (or overflowing) deep inside a query hot
//! loop later. Notably: centroid/LUT/envelope buffer sizes must agree
//! with `M`/`K`/`L`, code ids must be `< K`, IVF lists must be an exact
//! partition of the database, and warping windows are bounded by the
//! vector length they apply to (an unbounded window would overflow the
//! `i + w` band arithmetic in the DTW kernels).

use anyhow::{bail, ensure, Context, Result};

use crate::core::series::Dataset;
use crate::distance::envelope::Envelope;
use crate::nn::ivf::{CoarseMetric, IvfIndex};
use crate::pq::codebook::{Codebook, PqMetric};
use crate::pq::encode::EncodeStats;
use crate::pq::prealign::Segmenter;
use crate::pq::quantizer::{EncodedDataset, PqConfig, PrealignConfig, ProductQuantizer};

use super::format::{ByteReader, ByteWriter};

/// On-disk tag of a [`PqMetric`].
pub(crate) fn metric_tag(m: PqMetric) -> u8 {
    match m {
        PqMetric::Dtw => 0,
        PqMetric::Euclidean => 1,
    }
}

/// [`PqMetric`] from its on-disk tag.
pub(crate) fn metric_from(tag: u8) -> Result<PqMetric> {
    match tag {
        0 => Ok(PqMetric::Dtw),
        1 => Ok(PqMetric::Euclidean),
        other => bail!("store: unknown metric tag {other}"),
    }
}

/// Serialize a trained quantizer: config, segmenter, series length and
/// the codebook with its precomputed envelopes and symmetric LUT.
pub fn put_quantizer(w: &mut ByteWriter, pq: &ProductQuantizer) {
    let cfg = &pq.config;
    w.usize(cfg.n_subspaces);
    w.usize(cfg.codebook_size);
    w.f64(cfg.window_frac);
    w.u8(metric_tag(cfg.metric));
    match cfg.prealign {
        Some(p) => {
            w.u8(1);
            w.usize(p.level);
            w.f64(p.tail_frac);
        }
        None => w.u8(0),
    }
    w.usize(cfg.kmeans_iters);
    w.usize(cfg.dba_iters);
    w.opt_usize(cfg.train_subsample);
    w.usize(pq.segmenter.n_subspaces);
    w.usize(pq.segmenter.level);
    w.usize(pq.segmenter.tail);
    w.usize(pq.series_len);
    let cb = &pq.codebook;
    w.usize(cb.n_subspaces);
    w.usize(cb.k);
    w.usize(cb.sub_len);
    w.opt_usize(cb.window);
    w.u8(metric_tag(cb.metric));
    w.vec_f64(&cb.centroids);
    w.usize(cb.envelopes.len());
    for e in &cb.envelopes {
        w.vec_f64(&e.upper);
        w.vec_f64(&e.lower);
    }
    w.vec_f64(&cb.lut_sq);
}

/// Deserialize and validate a quantizer section.
pub fn get_quantizer(payload: &[u8]) -> Result<ProductQuantizer> {
    let mut r = ByteReader::new(payload);
    let n_subspaces = r.usize()?;
    let codebook_size = r.usize()?;
    let window_frac = r.f64()?;
    let metric = metric_from(r.u8()?)?;
    let prealign = match r.u8()? {
        0 => None,
        1 => Some(PrealignConfig { level: r.usize()?, tail_frac: r.f64()? }),
        other => bail!("store: bad prealign flag {other}"),
    };
    let kmeans_iters = r.usize()?;
    let dba_iters = r.usize()?;
    let train_subsample = r.opt_usize()?;
    let config = PqConfig {
        n_subspaces,
        codebook_size,
        window_frac,
        metric,
        prealign,
        kmeans_iters,
        dba_iters,
        train_subsample,
    };
    let segmenter = Segmenter {
        n_subspaces: r.usize()?,
        level: r.usize()?,
        tail: r.usize()?,
    };
    let series_len = r.usize()?;
    let m = r.usize()?;
    let k = r.usize()?;
    let sub_len = r.usize()?;
    let window = r.opt_usize()?;
    let cb_metric = metric_from(r.u8()?)?;
    let centroids = r.vec_f64()?;
    let n_env = r.usize()?;
    // Each envelope holds at least its two length prefixes, so any
    // count claiming more envelopes than the remaining bytes could
    // possibly encode is corrupt — reject before reserving capacity.
    ensure!(
        n_env.saturating_mul(16) <= r.remaining(),
        "store: envelope count {n_env} exceeds remaining section bytes"
    );
    let mut envelopes = Vec::with_capacity(n_env);
    for _ in 0..n_env {
        let upper = r.vec_f64()?;
        let lower = r.vec_f64()?;
        ensure!(
            upper.len() == sub_len && lower.len() == sub_len,
            "store: envelope length != L = {sub_len}"
        );
        envelopes.push(Envelope { upper, lower });
    }
    let lut_sq = r.vec_f64()?;
    ensure!(r.is_exhausted(), "store: trailing bytes in quantizer section");

    ensure!(
        n_subspaces >= 1 && m == n_subspaces && segmenter.n_subspaces == n_subspaces,
        "store: inconsistent subspace counts (config {n_subspaces}, codebook {m}, segmenter {})",
        segmenter.n_subspaces
    );
    ensure!(k >= 1 && sub_len >= 1, "store: degenerate codebook (K={k}, L={sub_len})");
    let mk = m.checked_mul(k).context("store: M*K overflows")?;
    let mkl = mk.checked_mul(sub_len).context("store: M*K*L overflows")?;
    ensure!(
        centroids.len() == mkl,
        "store: centroid buffer holds {} values, expected M*K*L = {mkl}",
        centroids.len()
    );
    let mkk = mk.checked_mul(k).context("store: M*K*K overflows")?;
    ensure!(
        lut_sq.len() == mkk,
        "store: LUT buffer holds {} values, expected M*K*K = {mkk}",
        lut_sq.len()
    );
    match cb_metric {
        PqMetric::Dtw => ensure!(
            envelopes.len() == mk,
            "store: expected {mk} envelopes under DTW, got {}",
            envelopes.len()
        ),
        PqMetric::Euclidean => {
            ensure!(envelopes.is_empty(), "store: ED codebook carries envelopes")
        }
    }
    if let Some(w) = window {
        ensure!(w <= sub_len, "store: quantization window {w} exceeds L = {sub_len}");
    }
    ensure!(
        series_len >= 2 * n_subspaces,
        "store: series length {series_len} too short for {n_subspaces} subspaces"
    );
    // MODWT level and tail feed `segment()` on the query path: an
    // out-of-range level would panic (or spin) inside `modwt_scale`,
    // and an absurd tail would overflow the sub-length arithmetic —
    // reject both here instead. (Any legitimately trained segmenter
    // has 1 <= level <= 64; `Segmenter::fixed` uses level 1.)
    ensure!(
        (1..=64).contains(&segmenter.level),
        "store: MODWT level {} out of range [1, 64]",
        segmenter.level
    );
    let want_sub_len = series_len
        .div_ceil(n_subspaces)
        .checked_add(segmenter.tail)
        .context("store: segmenter tail overflows the sub-length")?;
    ensure!(
        want_sub_len == sub_len,
        "store: segmenter sub-length {want_sub_len} disagrees with codebook L = {sub_len}"
    );

    let codebook = Codebook {
        n_subspaces: m,
        k,
        sub_len,
        window,
        metric: cb_metric,
        centroids,
        envelopes,
        lut_sq,
    };
    Ok(ProductQuantizer { config, segmenter, codebook, series_len })
}

/// Serialize an encoded database: codes, self bounds, labels, counters.
pub fn put_encoded(w: &mut ByteWriter, enc: &EncodedDataset) {
    w.usize(enc.n_subspaces);
    w.vec_u16(&enc.codes);
    w.vec_f64(&enc.lb_self_sq);
    w.vec_i64(&enc.labels);
    w.usize(enc.stats.pruned_kim);
    w.usize(enc.stats.pruned_keogh);
    w.usize(enc.stats.dtw_evals);
    w.usize(enc.stats.dtw_abandoned);
}

/// Deserialize and validate an encoded-database section against the
/// already-loaded quantizer.
pub fn get_encoded(payload: &[u8], pq: &ProductQuantizer) -> Result<EncodedDataset> {
    let mut r = ByteReader::new(payload);
    let m = r.usize()?;
    let codes = r.vec_u16()?;
    let lb_self_sq = r.vec_f64()?;
    let labels = r.vec_i64()?;
    let stats = EncodeStats {
        pruned_kim: r.usize()?,
        pruned_keogh: r.usize()?,
        dtw_evals: r.usize()?,
        dtw_abandoned: r.usize()?,
    };
    ensure!(r.is_exhausted(), "store: trailing bytes in encoded section");
    ensure!(
        m == pq.config.n_subspaces,
        "store: encoded M = {m} != quantizer M = {}",
        pq.config.n_subspaces
    );
    ensure!(codes.len() % m == 0, "store: ragged code buffer ({} codes, M = {m})", codes.len());
    let n = codes.len() / m;
    ensure!(
        lb_self_sq.len() == codes.len(),
        "store: self-bound buffer ({}) disagrees with codes ({})",
        lb_self_sq.len(),
        codes.len()
    );
    ensure!(
        labels.is_empty() || labels.len() == n,
        "store: label count {} != series count {n}",
        labels.len()
    );
    let k = pq.codebook.k;
    ensure!(
        codes.iter().all(|&c| usize::from(c) < k),
        "store: code id out of range (K = {k})"
    );
    Ok(EncodedDataset { codes, lb_self_sq, n_subspaces: m, labels, stats })
}

/// Serialize a raw dataset (retained for exact DTW re-ranking).
pub fn put_dataset(w: &mut ByteWriter, ds: &Dataset) {
    w.usize(ds.len);
    w.vec_f64(&ds.values);
    w.vec_i64(&ds.labels);
    w.string(&ds.name);
}

/// Deserialize and validate a raw-dataset section.
pub fn get_dataset(payload: &[u8]) -> Result<Dataset> {
    let mut r = ByteReader::new(payload);
    let len = r.usize()?;
    let values = r.vec_f64()?;
    let labels = r.vec_i64()?;
    let name = r.string()?;
    ensure!(r.is_exhausted(), "store: trailing bytes in raw-dataset section");
    ensure!(len >= 1, "store: zero series length in raw dataset");
    ensure!(
        values.len() % len == 0,
        "store: ragged dataset buffer ({} values, length {len})",
        values.len()
    );
    let n = values.len() / len;
    ensure!(
        labels.is_empty() || labels.len() == n,
        "store: dataset label count {} != series count {n}",
        labels.len()
    );
    Ok(Dataset { values, len, labels, name })
}

/// Serialize an IVF index: coarse centroids, metric, inverted lists.
/// The index stores its lists in CSR form; `to_parts` materializes the
/// per-list vectors so the on-disk layout is unchanged from version 1.
pub fn put_ivf(w: &mut ByteWriter, ivf: &IvfIndex) {
    let (coarse, dim, metric, lists) = ivf.to_parts();
    w.usize(dim);
    match metric {
        CoarseMetric::Dtw { window } => {
            w.u8(0);
            w.opt_usize(window);
        }
        CoarseMetric::Euclidean => w.u8(1),
    }
    w.usize(lists.len());
    for l in &lists {
        w.vec_usize(l);
    }
    w.vec_f64(coarse);
}

/// Deserialize and validate an IVF section: the lists must be an exact
/// partition of the `n_items`-item database and the coarse geometry
/// must match the series length.
pub fn get_ivf(payload: &[u8], series_len: usize, n_items: usize) -> Result<IvfIndex> {
    let mut r = ByteReader::new(payload);
    let dim = r.usize()?;
    let metric = match r.u8()? {
        0 => CoarseMetric::Dtw { window: r.opt_usize()? },
        1 => CoarseMetric::Euclidean,
        other => bail!("store: unknown coarse metric tag {other}"),
    };
    if let CoarseMetric::Dtw { window: Some(w) } = metric {
        ensure!(w <= dim, "store: coarse DTW window {w} exceeds series length {dim}");
    }
    let nlist = r.usize()?;
    ensure!(nlist >= 1, "store: IVF index with zero lists");
    ensure!(nlist <= n_items, "store: nlist {nlist} exceeds database size {n_items}");
    let mut lists = Vec::with_capacity(nlist);
    let mut seen = vec![false; n_items];
    for _ in 0..nlist {
        let l = r.vec_usize()?;
        for &id in &l {
            ensure!(id < n_items, "store: IVF member id {id} out of range ({n_items} items)");
            ensure!(!seen[id], "store: IVF lists assign item {id} twice");
            seen[id] = true;
        }
        lists.push(l);
    }
    let coarse = r.vec_f64()?;
    ensure!(r.is_exhausted(), "store: trailing bytes in IVF section");
    ensure!(dim == series_len, "store: IVF dim {dim} != series length {series_len}");
    let want = nlist.checked_mul(dim).context("store: nlist*dim overflows")?;
    ensure!(
        coarse.len() == want,
        "store: coarse buffer holds {} values, expected nlist*dim = {want}",
        coarse.len()
    );
    ensure!(
        seen.iter().all(|&s| s),
        "store: IVF lists do not cover every database item"
    );
    Ok(IvfIndex::from_parts(coarse, dim, metric, lists))
}
