//! Nearest-neighbour search and 1-NN classification (paper §4.1), plus
//! the serving-scale extensions: bounded-heap top-k collection, sharded
//! multi-threaded scans, IVF cell probing and exact DTW re-ranking.

pub mod ivf;
pub mod knn;
pub mod topk;

pub use ivf::{CoarseMetric, IvfIndex};
pub use knn::{
    nn_classify_pq, nn_classify_raw, nn_classify_sax, NnIndex, PqQueryMode, RawNnSearcher,
};
pub use topk::{
    rerank_dtw, topk_scan, topk_scan_blocked, topk_scan_blocked_opts, topk_scan_scalar,
    topk_scan_with, Neighbor, QueryLut, TopKCollector,
};
