//! Nearest-neighbour search and 1-NN classification (paper §4.1).

pub mod ivf;
pub mod knn;

pub use ivf::IvfIndex;
pub use knn::{
    nn_classify_pq, nn_classify_raw, nn_classify_sax, NnIndex, PqQueryMode, RawNnSearcher,
};
