//! 1-NN search over raw measures (with lower-bound pruning and early
//! abandoning, matching the paper's experimental settings: Keogh lower
//! bound for DTW/cDTW, PrunedDTW for the unconstrained case) and over PQ
//! codes (symmetric and asymmetric modes).

use crate::core::series::Dataset;
use crate::distance::dtw::{dtw_sq_scratch, DtwScratch};
use crate::distance::envelope::Envelope;
use crate::distance::euclidean::{euclidean_ea_sq, euclidean_sq};
use crate::distance::lower_bounds::{lb_keogh_sq, lb_kim_sq};
use crate::distance::measure::Measure;
use crate::distance::pruned_dtw::pruned_dtw_sq;
use crate::distance::sbd::sbd;
use crate::pq::quantizer::{EncodedDataset, ProductQuantizer};
use crate::repr::sax::SaxEncoder;

/// Result of a nearest-neighbour query.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NnIndex {
    /// Index of the nearest training series.
    pub index: usize,
    /// Distance to it.
    pub distance: f64,
}

/// A prepared raw-measure 1-NN searcher: envelopes (for DTW-family
/// measures) are built once over the training set, reversed-role style.
pub struct RawNnSearcher<'a> {
    train: &'a Dataset,
    measure: Measure,
    window: Option<usize>,
    envelopes: Vec<Envelope>,
}

impl<'a> RawNnSearcher<'a> {
    /// Prepare a searcher (precomputes envelopes for cDTW).
    pub fn new(train: &'a Dataset, measure: Measure) -> Self {
        let window = measure.window(train.len);
        let envelopes = match measure {
            Measure::CDtw { .. } => {
                let w = window.unwrap();
                (0..train.n_series())
                    .map(|i| Envelope::new(train.row(i), w))
                    .collect()
            }
            _ => Vec::new(),
        };
        RawNnSearcher { train, measure, window, envelopes }
    }

    /// Nearest neighbour of `q` in the training set.
    pub fn query(&self, q: &[f64]) -> NnIndex {
        let n = self.train.n_series();
        let mut scratch = DtwScratch::new(self.train.len);
        let mut best_sq = f64::INFINITY;
        let mut best_i = 0usize;
        match self.measure {
            Measure::Euclidean => {
                for i in 0..n {
                    let d = euclidean_ea_sq(q, self.train.row(i), best_sq);
                    if d < best_sq {
                        best_sq = d;
                        best_i = i;
                    }
                }
            }
            Measure::Dtw => {
                // PrunedDTW: the running best-so-far is the upper bound.
                // While no candidate has completed, seed the bound with
                // ED (a valid DTW upper bound); the epsilon keeps
                // boundary-equal costs from being pruned spuriously.
                for i in 0..n {
                    let r = self.train.row(i);
                    let ub = if best_sq.is_infinite() {
                        euclidean_sq(q, r) + 1e-12
                    } else {
                        best_sq
                    };
                    let d = pruned_dtw_sq(q, r, None, ub);
                    // An aborted (infinite) result only proves the true
                    // DTW exceeds `ub` — skip the candidate; recording
                    // the bound would report an ED value as a DTW
                    // distance.
                    if d.is_finite() && d < best_sq {
                        best_sq = d;
                        best_i = i;
                    }
                }
            }
            Measure::CDtw { .. } => {
                // LB_Kim → reversed LB_Keogh cascade, then early-abandoned
                // DTW (paper: "Keogh lower bound for early stopping").
                for i in 0..n {
                    let r = self.train.row(i);
                    if lb_kim_sq(q, r) >= best_sq {
                        continue;
                    }
                    if lb_keogh_sq(q, &self.envelopes[i], best_sq) >= best_sq {
                        continue;
                    }
                    let d = dtw_sq_scratch(q, r, self.window, best_sq, &mut scratch);
                    if d < best_sq {
                        best_sq = d;
                        best_i = i;
                    }
                }
            }
            Measure::Sbd => {
                for i in 0..n {
                    let d = sbd(q, self.train.row(i));
                    let d = d * d; // keep comparisons in squared space
                    if d < best_sq {
                        best_sq = d;
                        best_i = i;
                    }
                }
            }
            Measure::Sax { .. } => {
                // Representation-based; handled by `nn_classify_sax`.
                for i in 0..n {
                    let d = self.measure.dist(q, self.train.row(i));
                    let d = d * d;
                    if d < best_sq {
                        best_sq = d;
                        best_i = i;
                    }
                }
            }
        }
        NnIndex { index: best_i, distance: best_sq.sqrt() }
    }
}

/// 1-NN classification error of `measure` on a train/test split.
pub fn nn_classify_raw(train: &Dataset, test: &Dataset, measure: Measure) -> (f64, Vec<i64>) {
    assert!(train.is_labeled() && test.is_labeled());
    let searcher = RawNnSearcher::new(train, measure);
    let mut errors = 0usize;
    let mut preds = Vec::with_capacity(test.n_series());
    for i in 0..test.n_series() {
        let nn = searcher.query(test.row(i));
        let pred = train.label(nn.index);
        preds.push(pred);
        if pred != test.label(i) {
            errors += 1;
        }
    }
    (errors as f64 / test.n_series() as f64, preds)
}

/// SAX 1-NN: words precomputed for the training set once.
pub fn nn_classify_sax(
    train: &Dataset,
    test: &Dataset,
    alphabet: usize,
    seg_frac: f64,
) -> (f64, Vec<i64>) {
    let enc = SaxEncoder::new(train.len, alphabet, seg_frac);
    let train_words: Vec<Vec<u8>> =
        (0..train.n_series()).map(|i| enc.encode(train.row(i))).collect();
    let mut errors = 0usize;
    let mut preds = Vec::with_capacity(test.n_series());
    for i in 0..test.n_series() {
        let qw = enc.encode(test.row(i));
        let mut best = f64::INFINITY;
        let mut best_j = 0usize;
        for (j, tw) in train_words.iter().enumerate() {
            let d = enc.mindist(&qw, tw);
            if d < best {
                best = d;
                best_j = j;
            }
        }
        let pred = train.label(best_j);
        preds.push(pred);
        if pred != test.label(i) {
            errors += 1;
        }
    }
    (errors as f64 / test.n_series() as f64, preds)
}

/// PQ query mode (paper §3.3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PqQueryMode {
    /// Encode the query, then `O(M)` LUT lookups per database item.
    Symmetric,
    /// Build the `M×K` query table with real DTW, then `O(M)` lookups —
    /// lower distortion, recommended for 1-NN (paper §4.1).
    Asymmetric,
}

/// 1-NN classification with a trained PQ over an encoded training set.
pub fn nn_classify_pq(
    pq: &ProductQuantizer,
    enc_train: &EncodedDataset,
    test: &Dataset,
    mode: PqQueryMode,
) -> (f64, Vec<i64>) {
    assert!(!enc_train.labels.is_empty() && test.is_labeled());
    let n = enc_train.n();
    let mut errors = 0usize;
    let mut preds = Vec::with_capacity(test.n_series());
    for i in 0..test.n_series() {
        let q = test.row(i);
        let mut best = f64::INFINITY;
        let mut best_j = 0usize;
        match mode {
            PqQueryMode::Symmetric => {
                let (codes, _, _) = pq.encode(q);
                for j in 0..n {
                    let d = crate::pq::distance::symmetric_sq(
                        &pq.codebook,
                        &codes,
                        enc_train.code(j),
                    );
                    if d < best {
                        best = d;
                        best_j = j;
                    }
                }
            }
            PqQueryMode::Asymmetric => {
                let table = pq.asymmetric_table(q);
                for j in 0..n {
                    let d = crate::pq::distance::asymmetric_sq(
                        &pq.codebook,
                        &table,
                        enc_train.code(j),
                    );
                    if d < best {
                        best = d;
                        best_j = j;
                    }
                }
            }
        }
        let pred = enc_train.labels[best_j];
        preds.push(pred);
        if pred != test.label(i) {
            errors += 1;
        }
    }
    (errors as f64 / test.n_series() as f64, preds)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::ucr_like::ucr_like_by_name;
    use crate::pq::quantizer::{PqConfig, ProductQuantizer};

    #[test]
    fn raw_searchers_agree_with_bruteforce() {
        let tt = ucr_like_by_name("SpikePosition", 11).unwrap();
        let (train, test) = (&tt.train, &tt.test);
        for measure in [
            Measure::Euclidean,
            Measure::Dtw,
            Measure::CDtw { window_frac: 0.1 },
        ] {
            let searcher = RawNnSearcher::new(train, measure);
            for i in 0..10 {
                let q = test.row(i);
                let fast = searcher.query(q);
                // brute force with the plain measure
                let mut best = f64::INFINITY;
                let mut best_j = 0;
                for j in 0..train.n_series() {
                    let d = measure.dist(q, train.row(j));
                    if d < best {
                        best = d;
                        best_j = j;
                    }
                }
                assert!(
                    (fast.distance - best).abs() < 1e-6,
                    "{measure:?}: {} vs {}",
                    fast.distance,
                    best
                );
                if fast.index != best_j {
                    // tie: distances must match
                    assert!((fast.distance - best).abs() < 1e-9);
                }
            }
        }
    }

    #[test]
    fn dtw_searcher_distance_is_true_dtw_regression() {
        // Regression for the aborted-candidate bug: when `pruned_dtw_sq`
        // early-abandons, the searcher must skip the candidate, never
        // record its ED upper bound as a DTW distance. Checked by exact
        // agreement with an unpruned brute-force scan across many seeded
        // random databases/queries.
        use crate::core::series::Dataset;
        use crate::distance::dtw::dtw_sq;
        use crate::testutil::{check, gen_walk};
        check("dtw 1-NN exactness", 25, |rng| {
            let len = 8 + rng.below(24);
            let n = 3 + rng.below(10);
            let mut values = Vec::with_capacity(n * len);
            for _ in 0..n {
                values.extend(gen_walk(rng, len));
            }
            let train = Dataset::from_flat(values, len);
            let searcher = RawNnSearcher::new(&train, Measure::Dtw);
            let q = gen_walk(rng, len);
            let got = searcher.query(&q);
            let (want_i, want_sq) = (0..n)
                .map(|j| (j, dtw_sq(&q, train.row(j), None)))
                .min_by(|a, b| a.1.total_cmp(&b.1))
                .unwrap();
            if (got.distance - want_sq.sqrt()).abs() > 1e-9 {
                return Err(format!(
                    "distance {} != true DTW {} (index {} vs {})",
                    got.distance,
                    want_sq.sqrt(),
                    got.index,
                    want_i
                ));
            }
            Ok(())
        });
    }

    #[test]
    fn elastic_beats_chance_on_phase_dataset() {
        let tt = ucr_like_by_name("SpikePosition", 13).unwrap();
        let (err_dtw, _) = nn_classify_raw(&tt.train, &tt.test, Measure::Dtw);
        assert!(err_dtw < 0.3, "DTW err={err_dtw}");
    }

    #[test]
    fn pq_modes_classify_reasonably() {
        let tt = ucr_like_by_name("CBF", 17).unwrap();
        let cfg = PqConfig {
            n_subspaces: 4,
            codebook_size: 24,
            window_frac: 0.2,
            ..Default::default()
        };
        let pq = ProductQuantizer::train(&tt.train, &cfg, 5).unwrap();
        let enc = pq.encode_dataset(&tt.train);
        let (err_sym, preds_sym) = nn_classify_pq(&pq, &enc, &tt.test, PqQueryMode::Symmetric);
        let (err_asym, _) = nn_classify_pq(&pq, &enc, &tt.test, PqQueryMode::Asymmetric);
        assert_eq!(preds_sym.len(), tt.test.n_series());
        let chance = 1.0 - 1.0 / 3.0;
        assert!(err_sym < chance, "sym err={err_sym}");
        assert!(err_asym < chance, "asym err={err_asym}");
    }

    #[test]
    fn sax_classifier_runs() {
        let tt = ucr_like_by_name("Waveforms", 19).unwrap();
        let (err, preds) = nn_classify_sax(&tt.train, &tt.test, 4, 0.2);
        assert_eq!(preds.len(), tt.test.n_series());
        assert!((0.0..=1.0).contains(&err));
    }

    #[test]
    fn perfect_on_self_classification() {
        // Querying the training set itself: nearest neighbour is the
        // series itself at distance 0 → error 0.
        let tt = ucr_like_by_name("Chirp", 23).unwrap();
        let (err, _) = nn_classify_raw(&tt.train, &tt.train, Measure::Euclidean);
        assert_eq!(err, 0.0);
    }
}
