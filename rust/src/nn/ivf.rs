//! IVF-PQDTW: inverted-file index for million-scale NN search.
//!
//! The paper (§4.1) notes that a linear scan over PQ codes is still O(N)
//! and defers to the original PQ paper's inverted-index system for
//! million-scale search. This module implements that extension under
//! DTW: a coarse k-means quantizer over whole series partitions the
//! database into `nlist` inverted lists; a query probes only the
//! `nprobe` nearest coarse cells and scans their members with the
//! PQ code distances.
//!
//! Recall/latency trade-off is controlled by `nprobe`: probing all lists
//! visits every item exactly once and is therefore *bit-identical* to
//! the exhaustive scan (the [`TopKCollector`]'s `(distance, index)`
//! total order makes the result independent of visit order). The coarse
//! metric is selectable: windowed DTW is paper-faithful but costs
//! `nlist` full-length DTWs per probe; Euclidean is the classic IVF
//! choice and makes the probe `O(nlist·D)` — cheap enough that probing
//! beats the exhaustive LUT scan wall-clock on multi-thousand-series
//! databases (see `benches/perf_hotpath.rs`).

use crate::core::rng::Rng;
use crate::core::series::Dataset;
use crate::distance::dtw::{dtw_sq_scratch, DtwScratch};
use crate::distance::euclidean::euclidean_sq;
use crate::obs::ScanStats;
use crate::pq::encode::CodeBlocks;
use crate::pq::kmeans::{kmeans, KmeansGeometry};
use crate::pq::quantizer::{EncodedDataset, ProductQuantizer};

use super::knn::PqQueryMode;
use super::topk::{scan_blocks_into, Neighbor, QueryLut, TopKCollector};

/// Distance used for coarse clustering and cell probing.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum CoarseMetric {
    /// Windowed DTW with DBA centroids (paper-faithful; a probe costs
    /// `nlist` full-length DTW evaluations).
    Dtw {
        /// Sakoe-Chiba half-width for coarse assignment (`None` =
        /// unconstrained).
        window: Option<usize>,
    },
    /// Plain Euclidean (the classic IVF coarse quantizer; a probe costs
    /// `nlist × D` flops).
    Euclidean,
}

/// Coarse-probe stage accounting returned by
/// [`IvfIndex::query_topk_traced`]: what the `coarse_probe` span of a
/// query trace reports.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ProbeInfo {
    /// Number of coarse cells the query probed.
    pub cells_probed: u64,
    /// Total members of the probed cells (the blocked-scan stage's
    /// candidate input).
    pub items_in_cells: u64,
    /// Wall-time of the coarse probe ordering, microseconds.
    pub probe_us: u64,
}

/// An inverted-file index over PQ-encoded series.
///
/// Posting lists are stored flattened in CSR form — one offsets array
/// plus one flat id array — so probing walks contiguous memory instead
/// of chasing one heap allocation per list. When the blocked code copy
/// is attached ([`IvfIndex::attach_blocks`]), probed cells are scanned
/// through the same kernel as the exhaustive path, pruning cascade
/// included.
pub struct IvfIndex {
    /// Coarse centroids, flat `nlist × D`.
    coarse: Vec<f64>,
    /// Series length.
    dim: usize,
    /// Coarse assignment/probe metric.
    metric: CoarseMetric,
    /// CSR offsets: list `c` owns `list_ids[list_offsets[c]..list_offsets[c + 1]]`.
    list_offsets: Vec<usize>,
    /// Member ids of every list, concatenated in list order.
    list_ids: Vec<usize>,
    /// Blocked copy of the member codes *in CSR order*, so each posting
    /// list is a contiguous position range for the scan kernel. Built
    /// by [`IvfIndex::attach_blocks`]; probing falls back to per-id
    /// gathers (bit-identical results) when absent.
    blocks: Option<CodeBlocks>,
}

impl IvfIndex {
    /// Build an index over a raw database: `nlist` coarse cells learned
    /// by k-means under the chosen coarse metric. (The PQ codes are not
    /// needed to build the lists — they are only read at query time;
    /// call [`IvfIndex::attach_blocks`] once they exist to enable the
    /// kernel-blocked probe path.)
    pub fn build(db: &Dataset, nlist: usize, metric: CoarseMetric, seed: u64) -> Self {
        let n = db.n_series();
        let nlist = nlist.min(n).max(1);
        let rows: Vec<&[f64]> = (0..n).map(|i| db.row(i)).collect();
        let mut rng = Rng::new(seed);
        let geo = match metric {
            CoarseMetric::Dtw { window } => KmeansGeometry::Dtw { window, dba_iters: 2 },
            CoarseMetric::Euclidean => KmeansGeometry::Euclidean,
        };
        let res = kmeans(&rows, nlist, geo, 5, &mut rng);
        // Counting sort of the assignment into CSR form; ids stay
        // ascending within each list.
        let mut counts = vec![0usize; res.k()];
        for &a in &res.assignment {
            counts[a] += 1;
        }
        let mut list_offsets = Vec::with_capacity(res.k() + 1);
        let mut acc = 0usize;
        list_offsets.push(0);
        for &c in &counts {
            acc += c;
            list_offsets.push(acc);
        }
        let mut cursor: Vec<usize> = list_offsets[..res.k()].to_vec();
        let mut list_ids = vec![0usize; res.assignment.len()];
        for (i, &a) in res.assignment.iter().enumerate() {
            list_ids[cursor[a]] = i;
            cursor[a] += 1;
        }
        IvfIndex {
            coarse: res.centroids,
            dim: db.len,
            metric,
            list_offsets,
            list_ids,
            blocks: None,
        }
    }

    /// Number of inverted lists.
    pub fn nlist(&self) -> usize {
        self.list_offsets.len() - 1
    }

    /// Coarse assignment/probe metric.
    pub fn coarse_metric(&self) -> CoarseMetric {
        self.metric
    }

    /// Build the blocked, CSR-ordered copy of the member codes that the
    /// scan kernel streams at probe time (`k` is the codebook size).
    /// Derived state: rebuilt on `Engine::open`, never persisted. Self
    /// bounds are omitted — probes only run the symmetric/asymmetric
    /// modes, which never read them.
    pub fn attach_blocks(&mut self, encoded: &EncodedDataset, k: usize) {
        let m = encoded.n_subspaces;
        let mut codes = Vec::with_capacity(self.list_ids.len() * m);
        for &id in &self.list_ids {
            codes.extend_from_slice(encoded.code(id));
        }
        self.blocks = Some(CodeBlocks::build(&codes, &[], m, k));
    }

    /// Decompose into raw parts for the on-disk store (crate-internal):
    /// `(coarse centroids, dim, metric, inverted lists)`. The per-list
    /// id vectors are materialized from the CSR layout so the on-disk
    /// shape is unchanged.
    pub(crate) fn to_parts(&self) -> (&[f64], usize, CoarseMetric, Vec<Vec<usize>>) {
        let lists: Vec<Vec<usize>> = (0..self.nlist())
            .map(|c| self.list_ids[self.list_offsets[c]..self.list_offsets[c + 1]].to_vec())
            .collect();
        (self.coarse.as_slice(), self.dim, self.metric, lists)
    }

    /// Reassemble from parts loaded from the store (crate-internal).
    /// The store's decoder validates shapes before calling this; the
    /// blocked code copy is attached separately by the engine.
    pub(crate) fn from_parts(
        coarse: Vec<f64>,
        dim: usize,
        metric: CoarseMetric,
        lists: Vec<Vec<usize>>,
    ) -> Self {
        let mut list_offsets = Vec::with_capacity(lists.len() + 1);
        list_offsets.push(0usize);
        let mut list_ids = Vec::with_capacity(lists.iter().map(|l| l.len()).sum());
        for l in &lists {
            list_ids.extend_from_slice(l);
            list_offsets.push(list_ids.len());
        }
        IvfIndex { coarse, dim, metric, list_offsets, list_ids, blocks: None }
    }

    /// Occupancy of each list (diagnostics).
    pub fn list_sizes(&self) -> Vec<usize> {
        (0..self.nlist())
            .map(|c| self.list_offsets[c + 1] - self.list_offsets[c])
            .collect()
    }

    /// Squared coarse distance of `q` to centroid `c`.
    fn coarse_dist_sq(&self, q: &[f64], c: usize, scratch: &mut DtwScratch) -> f64 {
        let cent = &self.coarse[c * self.dim..(c + 1) * self.dim];
        match self.metric {
            CoarseMetric::Dtw { window } => {
                dtw_sq_scratch(q, cent, window, f64::INFINITY, scratch)
            }
            CoarseMetric::Euclidean => euclidean_sq(q, cent),
        }
    }

    /// The `nprobe` coarse cells nearest to the query under the coarse
    /// metric, nearest first. Total-order sort: NaN distances (from
    /// pathological inputs) sink to the end instead of panicking.
    pub fn probe_order(&self, q: &[f64], nprobe: usize) -> Vec<usize> {
        let mut scratch = DtwScratch::new(self.dim);
        let mut dists: Vec<(usize, f64)> = (0..self.nlist())
            .map(|c| (c, self.coarse_dist_sq(q, c, &mut scratch)))
            .collect();
        dists.sort_by(|a, b| a.1.total_cmp(&b.1).then_with(|| a.0.cmp(&b.0)));
        dists.into_iter().take(nprobe).map(|(c, _)| c).collect()
    }

    /// Top-k over the `nprobe` nearest cells with PQ code distances in
    /// the given query mode. At `nprobe >= nlist` this visits every item
    /// exactly once and is bit-identical to the exhaustive scan.
    pub fn query_topk(
        &self,
        pq: &ProductQuantizer,
        encoded: &EncodedDataset,
        q: &[f64],
        k: usize,
        nprobe: usize,
        mode: PqQueryMode,
    ) -> Vec<Neighbor> {
        let lut = QueryLut::build(pq, q, mode);
        self.query_topk_with(pq, encoded, &lut, q, k, nprobe)
    }

    /// [`IvfIndex::query_topk`] with the query-side LUT already built
    /// (shared with an exhaustive scan or a re-rank pipeline). With the
    /// blocked code copy attached, each probed cell's CSR range is
    /// streamed through the scan kernel with the pruning cascade; the
    /// fallback gathers per id. Both paths produce bit-identical
    /// results (same collapsed-LUT values, same `(distance, index)`
    /// total order).
    pub fn query_topk_with(
        &self,
        pq: &ProductQuantizer,
        encoded: &EncodedDataset,
        lut: &QueryLut,
        q: &[f64],
        k: usize,
        nprobe: usize,
    ) -> Vec<Neighbor> {
        self.query_topk_traced(pq, encoded, lut, q, k, nprobe, None).0
    }

    /// [`IvfIndex::query_topk_with`] plus observability: scan counters
    /// flush into the optional `stats` sink and the returned
    /// [`ProbeInfo`] reports the coarse-probe stage's accounting
    /// (cells probed, items in the probed cells, probe wall-time). The
    /// neighbour list is bit-identical to the untraced call.
    pub fn query_topk_traced(
        &self,
        pq: &ProductQuantizer,
        encoded: &EncodedDataset,
        lut: &QueryLut,
        q: &[f64],
        k: usize,
        nprobe: usize,
        stats: Option<&ScanStats>,
    ) -> (Vec<Neighbor>, ProbeInfo) {
        let t0 = std::time::Instant::now();
        let cells = self.probe_order(q, nprobe.max(1));
        let probe_us = t0.elapsed().as_micros() as u64;
        let items_in_cells: usize = cells
            .iter()
            .map(|&c| self.list_offsets[c + 1] - self.list_offsets[c])
            .sum();
        let info = ProbeInfo {
            cells_probed: cells.len() as u64,
            items_in_cells: items_in_cells as u64,
            probe_us,
        };
        let mut coll = TopKCollector::new(k.max(1));
        match &self.blocks {
            Some(blocks) => {
                let clut = lut.collapse(&pq.codebook);
                if let (Some(st), QueryLut::Symmetric(_)) = (stats, lut) {
                    st.add_lut_collapse();
                }
                for c in cells {
                    scan_blocks_into(
                        &clut,
                        blocks,
                        self.list_offsets[c],
                        self.list_offsets[c + 1],
                        Some(&self.list_ids),
                        true,
                        &mut coll,
                        stats,
                    );
                }
            }
            None => {
                for c in cells {
                    let ids = &self.list_ids[self.list_offsets[c]..self.list_offsets[c + 1]];
                    for &id in ids {
                        coll.offer(id, lut.dist_sq(&pq.codebook, encoded.code(id)));
                    }
                }
                if let Some(st) = stats {
                    // The gather path streams every member — nothing
                    // abandoned, no blocks in play.
                    st.add_range(items_in_cells as u64, items_in_cells as u64, 0);
                }
            }
        }
        (coll.into_sorted(), info)
    }

    /// Approximate 1-NN via asymmetric PQ distances over the probed
    /// lists. Returns `(database index, approx distance)`; `None` when
    /// every probed list is empty.
    pub fn query(
        &self,
        pq: &ProductQuantizer,
        encoded: &EncodedDataset,
        q: &[f64],
        nprobe: usize,
    ) -> Option<(usize, f64)> {
        self.query_topk(pq, encoded, q, 1, nprobe, PqQueryMode::Asymmetric)
            .first()
            .map(|n| (n.index, n.distance))
    }

    /// Fraction of the database scanned when probing `nprobe` lists for
    /// this query (work model; diagnostics for the recall/latency curve).
    pub fn scan_fraction(&self, q: &[f64], nprobe: usize) -> f64 {
        let total = self.list_ids.len();
        if total == 0 {
            return 0.0;
        }
        let scanned: usize = self
            .probe_order(q, nprobe)
            .into_iter()
            .map(|c| self.list_offsets[c + 1] - self.list_offsets[c])
            .sum();
        scanned as f64 / total as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::random_walk::RandomWalks;
    use crate::nn::topk::topk_scan;
    use crate::pq::quantizer::PqConfig;

    fn setup() -> (Dataset, ProductQuantizer, EncodedDataset, IvfIndex) {
        let db = RandomWalks::new(51).generate(80, 64);
        let cfg = PqConfig {
            n_subspaces: 4,
            codebook_size: 16,
            window_frac: 0.2,
            kmeans_iters: 3,
            dba_iters: 1,
            ..Default::default()
        };
        let pq = ProductQuantizer::train(&db, &cfg, 1).unwrap();
        let enc = pq.encode_dataset(&db);
        let ivf = IvfIndex::build(&db, 8, CoarseMetric::Dtw { window: Some(6) }, 2);
        (db, pq, enc, ivf)
    }

    #[test]
    fn lists_partition_database() {
        let (db, _, _, ivf) = setup();
        let total: usize = ivf.list_sizes().iter().sum();
        assert_eq!(total, db.n_series());
        assert!(ivf.nlist() <= 8);
    }

    #[test]
    fn full_probe_equals_linear_scan() {
        let (db, pq, enc, ivf) = setup();
        let q = db.row(3);
        let (ivf_id, ivf_d) = ivf.query(&pq, &enc, q, ivf.nlist()).unwrap();
        // linear scan reference
        let table = pq.asymmetric_table(q);
        let (lin_id, lin_d) = (0..enc.n())
            .map(|j| (j, pq.asymmetric_distance(&table, enc.code(j))))
            .min_by(|a, b| a.1.total_cmp(&b.1))
            .unwrap();
        assert!((ivf_d - lin_d).abs() < 1e-9);
        if ivf_id != lin_id {
            assert!((ivf_d - lin_d).abs() < 1e-12); // tie
        }
    }

    #[test]
    fn full_probe_topk_bitidentical_to_exhaustive() {
        let (db, pq, enc, ivf) = setup();
        for mode in [PqQueryMode::Symmetric, PqQueryMode::Asymmetric] {
            for qi in [0usize, 7, 33] {
                let q = db.row(qi);
                let exhaustive = topk_scan(&pq, &enc, q, 10, mode, 1);
                let probed = ivf.query_topk(&pq, &enc, q, 10, ivf.nlist(), mode);
                // bit-identical: same indices AND same f64 distances
                assert_eq!(exhaustive, probed, "mode {mode:?} query {qi}");
            }
        }
    }

    #[test]
    fn attached_blocks_probe_bitidentical_to_gather_path() {
        let (db, pq, enc, mut ivf) = setup();
        let nlist = ivf.nlist();
        // Narrow and full probes on the per-id gather path first…
        let mut plain = Vec::new();
        for mode in [PqQueryMode::Symmetric, PqQueryMode::Asymmetric] {
            for qi in [1usize, 12, 40] {
                for nprobe in [1usize, 3, nlist] {
                    plain.push(ivf.query_topk(&pq, &enc, db.row(qi), 6, nprobe, mode));
                }
            }
        }
        // …then the same probes through the blocked kernel.
        ivf.attach_blocks(&enc, pq.codebook.k);
        let mut it = plain.into_iter();
        for mode in [PqQueryMode::Symmetric, PqQueryMode::Asymmetric] {
            for qi in [1usize, 12, 40] {
                for nprobe in [1usize, 3, nlist] {
                    let blocked = ivf.query_topk(&pq, &enc, db.row(qi), 6, nprobe, mode);
                    assert_eq!(
                        it.next().unwrap(),
                        blocked,
                        "mode {mode:?} query {qi} nprobe {nprobe}"
                    );
                }
            }
        }
        // And the full blocked probe still reproduces the exhaustive scan.
        let q = db.row(7);
        let exhaustive = topk_scan(&pq, &enc, q, 10, PqQueryMode::Asymmetric, 1);
        let probed = ivf.query_topk(&pq, &enc, q, 10, nlist, PqQueryMode::Asymmetric);
        assert_eq!(exhaustive, probed);
    }

    #[test]
    fn traced_probe_is_bit_identical_and_accounts_for_probed_cells() {
        let (db, pq, enc, mut ivf) = setup();
        ivf.attach_blocks(&enc, pq.codebook.k);
        let q = db.row(4);
        for nprobe in [1usize, 3, ivf.nlist()] {
            let lut = QueryLut::build(&pq, q, PqQueryMode::Symmetric);
            let plain = ivf.query_topk_with(&pq, &enc, &lut, q, 6, nprobe);
            let stats = ScanStats::new();
            let (traced, info) =
                ivf.query_topk_traced(&pq, &enc, &lut, q, 6, nprobe, Some(&stats));
            assert_eq!(plain, traced, "nprobe={nprobe}");
            assert_eq!(info.cells_probed, nprobe as u64);
            let s = stats.snapshot();
            assert_eq!(s.items_scanned, info.items_in_cells);
            assert_eq!(s.lut_collapses, 1, "symmetric probe collapses once");
            // Conservation: in − abandoned = emitted ≤ in.
            assert!(s.items_abandoned <= s.items_scanned);
        }
    }

    #[test]
    fn euclidean_coarse_variant_probes() {
        let (db, pq, enc, _) = setup();
        let ivf = IvfIndex::build(&db, 8, CoarseMetric::Euclidean, 9);
        let q = db.row(5);
        let exhaustive = topk_scan(&pq, &enc, q, 5, PqQueryMode::Asymmetric, 1);
        let probed = ivf.query_topk(&pq, &enc, q, 5, ivf.nlist(), PqQueryMode::Asymmetric);
        assert_eq!(exhaustive, probed);
        // narrow probe returns at most k hits, drawn from the probed
        // cell only (which may legitimately be small)
        let narrow = ivf.query_topk(&pq, &enc, q, 5, 1, PqQueryMode::Asymmetric);
        assert!(narrow.len() <= 5);
        let probed_total: usize = ivf.list_sizes().iter().sum();
        assert_eq!(probed_total, db.n_series());
    }

    #[test]
    fn narrow_probe_scans_less() {
        let (db, _, _, ivf) = setup();
        let q = db.row(10);
        let f1 = ivf.scan_fraction(q, 1);
        let fall = ivf.scan_fraction(q, ivf.nlist());
        assert!(f1 > 0.0 && f1 < 1.0, "f1={f1}");
        assert!((fall - 1.0).abs() < 1e-12);
    }

    #[test]
    fn probe_order_total_and_stable() {
        let (db, _, _, ivf) = setup();
        let q = db.row(0);
        let all = ivf.probe_order(q, ivf.nlist());
        assert_eq!(all.len(), ivf.nlist());
        let mut sorted = all.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), ivf.nlist(), "probe order must be a permutation");
    }

    #[test]
    fn recall_improves_with_nprobe() {
        let (db, pq, enc, ivf) = setup();
        // ground truth by linear scan; recall@1 over queries
        let mut recall = vec![0usize; 2]; // nprobe = 1, nlist
        let queries: Vec<usize> = (0..20).collect();
        for &qi in &queries {
            let q = db.row(qi);
            let table = pq.asymmetric_table(q);
            let truth = (0..enc.n())
                .map(|j| (j, pq.asymmetric_distance(&table, enc.code(j))))
                .min_by(|a, b| a.1.total_cmp(&b.1))
                .unwrap();
            for (ri, nprobe) in [(0usize, 1usize), (1, ivf.nlist())] {
                if let Some((id, d)) = ivf.query(&pq, &enc, q, nprobe) {
                    if id == truth.0 || (d - truth.1).abs() < 1e-9 {
                        recall[ri] += 1;
                    }
                }
            }
        }
        assert_eq!(recall[1], queries.len(), "full probe must have full recall");
        assert!(recall[0] <= recall[1]);
        // probing a single cell still finds the true NN often (self is in DB)
        assert!(recall[0] >= queries.len() / 2, "recall@nprobe=1: {}", recall[0]);
    }
}
