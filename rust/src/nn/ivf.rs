//! IVF-PQDTW: inverted-file index for million-scale NN search.
//!
//! The paper (§4.1) notes that a linear scan over PQ codes is still O(N)
//! and defers to the original PQ paper's inverted-index system for
//! million-scale search. This module implements that extension under
//! DTW: a coarse DBA-k-means quantizer over whole series partitions the
//! database into `nlist` inverted lists; a query probes only the
//! `nprobe` nearest coarse cells and scans their members with the
//! PQ code distances.
//!
//! Recall/latency trade-off is controlled by `nprobe` (probing all lists
//! degrades to the exact linear scan over codes).

use crate::core::rng::Rng;
use crate::core::series::Dataset;
use crate::distance::dtw::{dtw_sq_scratch, DtwScratch};
use crate::pq::distance::{asymmetric_sq, asymmetric_table};
use crate::pq::kmeans::{kmeans, KmeansGeometry};
use crate::pq::quantizer::{EncodedDataset, ProductQuantizer};

/// An inverted-file index over PQ-encoded series.
pub struct IvfIndex {
    /// Coarse centroids, flat `nlist × D`.
    coarse: Vec<f64>,
    /// Series length.
    dim: usize,
    /// Warping window for coarse assignment.
    window: Option<usize>,
    /// Member ids per inverted list.
    lists: Vec<Vec<usize>>,
}

impl IvfIndex {
    /// Build an index over an encoded database. `nlist` coarse cells;
    /// coarse clustering runs DTW k-means over the raw series.
    pub fn build(
        db: &Dataset,
        _encoded: &EncodedDataset,
        nlist: usize,
        window: Option<usize>,
        seed: u64,
    ) -> Self {
        let n = db.n_series();
        let nlist = nlist.min(n).max(1);
        let rows: Vec<&[f64]> = (0..n).map(|i| db.row(i)).collect();
        let mut rng = Rng::new(seed);
        let geo = KmeansGeometry::Dtw { window, dba_iters: 2 };
        let res = kmeans(&rows, nlist, geo, 5, &mut rng);
        let mut lists = vec![Vec::new(); res.k()];
        for (i, &a) in res.assignment.iter().enumerate() {
            lists[a].push(i);
        }
        IvfIndex { coarse: res.centroids, dim: db.len, window, lists }
    }

    /// Number of inverted lists.
    pub fn nlist(&self) -> usize {
        self.lists.len()
    }

    /// Occupancy of each list (diagnostics).
    pub fn list_sizes(&self) -> Vec<usize> {
        self.lists.iter().map(|l| l.len()).collect()
    }

    /// The `nprobe` coarse cells nearest to the query under windowed DTW.
    fn probe_order(&self, q: &[f64], nprobe: usize) -> Vec<usize> {
        let mut scratch = DtwScratch::new(self.dim);
        let mut dists: Vec<(usize, f64)> = (0..self.nlist())
            .map(|c| {
                let cent = &self.coarse[c * self.dim..(c + 1) * self.dim];
                (c, dtw_sq_scratch(q, cent, self.window, f64::INFINITY, &mut scratch))
            })
            .collect();
        dists.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
        dists.into_iter().take(nprobe).map(|(c, _)| c).collect()
    }

    /// Approximate 1-NN via asymmetric PQ distances over the probed
    /// lists. Returns `(database index, approx distance)`; `None` when
    /// every probed list is empty.
    pub fn query(
        &self,
        pq: &ProductQuantizer,
        encoded: &EncodedDataset,
        q: &[f64],
        nprobe: usize,
    ) -> Option<(usize, f64)> {
        let cells = self.probe_order(q, nprobe.max(1));
        let table = asymmetric_table(&pq.codebook, &pq.segment(q));
        let mut best: Option<(usize, f64)> = None;
        for c in cells {
            for &id in &self.lists[c] {
                let d = asymmetric_sq(&pq.codebook, &table, encoded.code(id));
                if best.map(|(_, bd)| d < bd).unwrap_or(true) {
                    best = Some((id, d));
                }
            }
        }
        best.map(|(i, d)| (i, d.sqrt()))
    }

    /// Fraction of the database scanned when probing `nprobe` lists for
    /// this query (work model; diagnostics for the recall/latency curve).
    pub fn scan_fraction(&self, q: &[f64], nprobe: usize) -> f64 {
        let total: usize = self.lists.iter().map(|l| l.len()).sum();
        if total == 0 {
            return 0.0;
        }
        let scanned: usize = self
            .probe_order(q, nprobe)
            .into_iter()
            .map(|c| self.lists[c].len())
            .sum();
        scanned as f64 / total as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::random_walk::RandomWalks;
    use crate::pq::quantizer::PqConfig;

    fn setup() -> (Dataset, ProductQuantizer, EncodedDataset, IvfIndex) {
        let db = RandomWalks::new(51).generate(80, 64);
        let cfg = PqConfig {
            n_subspaces: 4,
            codebook_size: 16,
            window_frac: 0.2,
            kmeans_iters: 3,
            dba_iters: 1,
            ..Default::default()
        };
        let pq = ProductQuantizer::train(&db, &cfg, 1).unwrap();
        let enc = pq.encode_dataset(&db);
        let ivf = IvfIndex::build(&db, &enc, 8, Some(6), 2);
        (db, pq, enc, ivf)
    }

    #[test]
    fn lists_partition_database() {
        let (db, _, _, ivf) = setup();
        let total: usize = ivf.list_sizes().iter().sum();
        assert_eq!(total, db.n_series());
        assert!(ivf.nlist() <= 8);
    }

    #[test]
    fn full_probe_equals_linear_scan() {
        let (db, pq, enc, ivf) = setup();
        let q = db.row(3);
        let (ivf_id, ivf_d) = ivf.query(&pq, &enc, q, ivf.nlist()).unwrap();
        // linear scan reference
        let table = pq.asymmetric_table(q);
        let (lin_id, lin_d) = (0..enc.n())
            .map(|j| (j, pq.asymmetric_distance(&table, enc.code(j))))
            .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
            .unwrap();
        assert!((ivf_d - lin_d).abs() < 1e-9);
        if ivf_id != lin_id {
            assert!((ivf_d - lin_d).abs() < 1e-12); // tie
        }
    }

    #[test]
    fn narrow_probe_scans_less() {
        let (db, _, _, ivf) = setup();
        let q = db.row(10);
        let f1 = ivf.scan_fraction(q, 1);
        let fall = ivf.scan_fraction(q, ivf.nlist());
        assert!(f1 > 0.0 && f1 < 1.0, "f1={f1}");
        assert!((fall - 1.0).abs() < 1e-12);
    }

    #[test]
    fn recall_improves_with_nprobe() {
        let (db, pq, enc, ivf) = setup();
        // ground truth by linear scan; recall@1 over queries
        let mut recall = vec![0usize; 2]; // nprobe = 1, nlist
        let queries: Vec<usize> = (0..20).collect();
        for &qi in &queries {
            let q = db.row(qi);
            let table = pq.asymmetric_table(q);
            let truth = (0..enc.n())
                .map(|j| (j, pq.asymmetric_distance(&table, enc.code(j))))
                .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
                .unwrap();
            for (ri, nprobe) in [(0usize, 1usize), (1, ivf.nlist())] {
                if let Some((id, d)) = ivf.query(&pq, &enc, q, nprobe) {
                    if id == truth.0 || (d - truth.1).abs() < 1e-9 {
                        recall[ri] += 1;
                    }
                }
            }
        }
        assert_eq!(recall[1], queries.len(), "full probe must have full recall");
        assert!(recall[0] <= recall[1]);
        // probing a single cell still finds the true NN often (self is in DB)
        assert!(recall[0] >= queries.len() / 2, "recall@nprobe=1: {}", recall[0]);
    }
}
