//! IVF-PQDTW: inverted-file index for million-scale NN search.
//!
//! The paper (§4.1) notes that a linear scan over PQ codes is still O(N)
//! and defers to the original PQ paper's inverted-index system for
//! million-scale search. This module implements that extension under
//! DTW: a coarse k-means quantizer over whole series partitions the
//! database into `nlist` inverted lists; a query probes only the
//! `nprobe` nearest coarse cells and scans their members with the
//! PQ code distances.
//!
//! Recall/latency trade-off is controlled by `nprobe`: probing all lists
//! visits every item exactly once and is therefore *bit-identical* to
//! the exhaustive scan (the [`TopKCollector`]'s `(distance, index)`
//! total order makes the result independent of visit order). The coarse
//! metric is selectable: windowed DTW is paper-faithful but costs
//! `nlist` full-length DTWs per probe; Euclidean is the classic IVF
//! choice and makes the probe `O(nlist·D)` — cheap enough that probing
//! beats the exhaustive LUT scan wall-clock on multi-thousand-series
//! databases (see `benches/perf_hotpath.rs`).

use crate::core::rng::Rng;
use crate::core::series::Dataset;
use crate::distance::dtw::{dtw_sq_scratch, DtwScratch};
use crate::distance::euclidean::euclidean_sq;
use crate::pq::kmeans::{kmeans, KmeansGeometry};
use crate::pq::quantizer::{EncodedDataset, ProductQuantizer};

use super::knn::PqQueryMode;
use super::topk::{Neighbor, QueryLut, TopKCollector};

/// Distance used for coarse clustering and cell probing.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum CoarseMetric {
    /// Windowed DTW with DBA centroids (paper-faithful; a probe costs
    /// `nlist` full-length DTW evaluations).
    Dtw {
        /// Sakoe-Chiba half-width for coarse assignment (`None` =
        /// unconstrained).
        window: Option<usize>,
    },
    /// Plain Euclidean (the classic IVF coarse quantizer; a probe costs
    /// `nlist × D` flops).
    Euclidean,
}

/// An inverted-file index over PQ-encoded series.
pub struct IvfIndex {
    /// Coarse centroids, flat `nlist × D`.
    coarse: Vec<f64>,
    /// Series length.
    dim: usize,
    /// Coarse assignment/probe metric.
    metric: CoarseMetric,
    /// Member ids per inverted list.
    lists: Vec<Vec<usize>>,
}

impl IvfIndex {
    /// Build an index over a raw database: `nlist` coarse cells learned
    /// by k-means under the chosen coarse metric. (The PQ codes are not
    /// needed to build the lists — they are only read at query time.)
    pub fn build(db: &Dataset, nlist: usize, metric: CoarseMetric, seed: u64) -> Self {
        let n = db.n_series();
        let nlist = nlist.min(n).max(1);
        let rows: Vec<&[f64]> = (0..n).map(|i| db.row(i)).collect();
        let mut rng = Rng::new(seed);
        let geo = match metric {
            CoarseMetric::Dtw { window } => KmeansGeometry::Dtw { window, dba_iters: 2 },
            CoarseMetric::Euclidean => KmeansGeometry::Euclidean,
        };
        let res = kmeans(&rows, nlist, geo, 5, &mut rng);
        let mut lists = vec![Vec::new(); res.k()];
        for (i, &a) in res.assignment.iter().enumerate() {
            lists[a].push(i);
        }
        IvfIndex { coarse: res.centroids, dim: db.len, metric, lists }
    }

    /// Number of inverted lists.
    pub fn nlist(&self) -> usize {
        self.lists.len()
    }

    /// Decompose into raw parts for the on-disk store (crate-internal):
    /// `(coarse centroids, dim, metric, inverted lists)`.
    pub(crate) fn to_parts(&self) -> (&[f64], usize, CoarseMetric, &[Vec<usize>]) {
        (self.coarse.as_slice(), self.dim, self.metric, self.lists.as_slice())
    }

    /// Reassemble from parts loaded from the store (crate-internal).
    /// The store's decoder validates shapes before calling this.
    pub(crate) fn from_parts(
        coarse: Vec<f64>,
        dim: usize,
        metric: CoarseMetric,
        lists: Vec<Vec<usize>>,
    ) -> Self {
        IvfIndex { coarse, dim, metric, lists }
    }

    /// Occupancy of each list (diagnostics).
    pub fn list_sizes(&self) -> Vec<usize> {
        self.lists.iter().map(|l| l.len()).collect()
    }

    /// Squared coarse distance of `q` to centroid `c`.
    fn coarse_dist_sq(&self, q: &[f64], c: usize, scratch: &mut DtwScratch) -> f64 {
        let cent = &self.coarse[c * self.dim..(c + 1) * self.dim];
        match self.metric {
            CoarseMetric::Dtw { window } => {
                dtw_sq_scratch(q, cent, window, f64::INFINITY, scratch)
            }
            CoarseMetric::Euclidean => euclidean_sq(q, cent),
        }
    }

    /// The `nprobe` coarse cells nearest to the query under the coarse
    /// metric, nearest first. Total-order sort: NaN distances (from
    /// pathological inputs) sink to the end instead of panicking.
    pub fn probe_order(&self, q: &[f64], nprobe: usize) -> Vec<usize> {
        let mut scratch = DtwScratch::new(self.dim);
        let mut dists: Vec<(usize, f64)> = (0..self.nlist())
            .map(|c| (c, self.coarse_dist_sq(q, c, &mut scratch)))
            .collect();
        dists.sort_by(|a, b| a.1.total_cmp(&b.1).then_with(|| a.0.cmp(&b.0)));
        dists.into_iter().take(nprobe).map(|(c, _)| c).collect()
    }

    /// Top-k over the `nprobe` nearest cells with PQ code distances in
    /// the given query mode. At `nprobe >= nlist` this visits every item
    /// exactly once and is bit-identical to the exhaustive scan.
    pub fn query_topk(
        &self,
        pq: &ProductQuantizer,
        encoded: &EncodedDataset,
        q: &[f64],
        k: usize,
        nprobe: usize,
        mode: PqQueryMode,
    ) -> Vec<Neighbor> {
        let lut = QueryLut::build(pq, q, mode);
        self.query_topk_with(pq, encoded, &lut, q, k, nprobe)
    }

    /// [`IvfIndex::query_topk`] with the query-side LUT already built
    /// (shared with an exhaustive scan or a re-rank pipeline).
    pub fn query_topk_with(
        &self,
        pq: &ProductQuantizer,
        encoded: &EncodedDataset,
        lut: &QueryLut,
        q: &[f64],
        k: usize,
        nprobe: usize,
    ) -> Vec<Neighbor> {
        let cells = self.probe_order(q, nprobe.max(1));
        let mut coll = TopKCollector::new(k.max(1));
        for c in cells {
            for &id in &self.lists[c] {
                coll.offer(id, lut.dist_sq(&pq.codebook, encoded.code(id)));
            }
        }
        coll.into_sorted()
    }

    /// Approximate 1-NN via asymmetric PQ distances over the probed
    /// lists. Returns `(database index, approx distance)`; `None` when
    /// every probed list is empty.
    pub fn query(
        &self,
        pq: &ProductQuantizer,
        encoded: &EncodedDataset,
        q: &[f64],
        nprobe: usize,
    ) -> Option<(usize, f64)> {
        self.query_topk(pq, encoded, q, 1, nprobe, PqQueryMode::Asymmetric)
            .first()
            .map(|n| (n.index, n.distance))
    }

    /// Fraction of the database scanned when probing `nprobe` lists for
    /// this query (work model; diagnostics for the recall/latency curve).
    pub fn scan_fraction(&self, q: &[f64], nprobe: usize) -> f64 {
        let total: usize = self.lists.iter().map(|l| l.len()).sum();
        if total == 0 {
            return 0.0;
        }
        let scanned: usize = self
            .probe_order(q, nprobe)
            .into_iter()
            .map(|c| self.lists[c].len())
            .sum();
        scanned as f64 / total as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::random_walk::RandomWalks;
    use crate::nn::topk::topk_scan;
    use crate::pq::quantizer::PqConfig;

    fn setup() -> (Dataset, ProductQuantizer, EncodedDataset, IvfIndex) {
        let db = RandomWalks::new(51).generate(80, 64);
        let cfg = PqConfig {
            n_subspaces: 4,
            codebook_size: 16,
            window_frac: 0.2,
            kmeans_iters: 3,
            dba_iters: 1,
            ..Default::default()
        };
        let pq = ProductQuantizer::train(&db, &cfg, 1).unwrap();
        let enc = pq.encode_dataset(&db);
        let ivf = IvfIndex::build(&db, 8, CoarseMetric::Dtw { window: Some(6) }, 2);
        (db, pq, enc, ivf)
    }

    #[test]
    fn lists_partition_database() {
        let (db, _, _, ivf) = setup();
        let total: usize = ivf.list_sizes().iter().sum();
        assert_eq!(total, db.n_series());
        assert!(ivf.nlist() <= 8);
    }

    #[test]
    fn full_probe_equals_linear_scan() {
        let (db, pq, enc, ivf) = setup();
        let q = db.row(3);
        let (ivf_id, ivf_d) = ivf.query(&pq, &enc, q, ivf.nlist()).unwrap();
        // linear scan reference
        let table = pq.asymmetric_table(q);
        let (lin_id, lin_d) = (0..enc.n())
            .map(|j| (j, pq.asymmetric_distance(&table, enc.code(j))))
            .min_by(|a, b| a.1.total_cmp(&b.1))
            .unwrap();
        assert!((ivf_d - lin_d).abs() < 1e-9);
        if ivf_id != lin_id {
            assert!((ivf_d - lin_d).abs() < 1e-12); // tie
        }
    }

    #[test]
    fn full_probe_topk_bitidentical_to_exhaustive() {
        let (db, pq, enc, ivf) = setup();
        for mode in [PqQueryMode::Symmetric, PqQueryMode::Asymmetric] {
            for qi in [0usize, 7, 33] {
                let q = db.row(qi);
                let exhaustive = topk_scan(&pq, &enc, q, 10, mode, 1);
                let probed = ivf.query_topk(&pq, &enc, q, 10, ivf.nlist(), mode);
                // bit-identical: same indices AND same f64 distances
                assert_eq!(exhaustive, probed, "mode {mode:?} query {qi}");
            }
        }
    }

    #[test]
    fn euclidean_coarse_variant_probes() {
        let (db, pq, enc, _) = setup();
        let ivf = IvfIndex::build(&db, 8, CoarseMetric::Euclidean, 9);
        let q = db.row(5);
        let exhaustive = topk_scan(&pq, &enc, q, 5, PqQueryMode::Asymmetric, 1);
        let probed = ivf.query_topk(&pq, &enc, q, 5, ivf.nlist(), PqQueryMode::Asymmetric);
        assert_eq!(exhaustive, probed);
        // narrow probe returns at most k hits, drawn from the probed
        // cell only (which may legitimately be small)
        let narrow = ivf.query_topk(&pq, &enc, q, 5, 1, PqQueryMode::Asymmetric);
        assert!(narrow.len() <= 5);
        let probed_total: usize = ivf.list_sizes().iter().sum();
        assert_eq!(probed_total, db.n_series());
    }

    #[test]
    fn narrow_probe_scans_less() {
        let (db, _, _, ivf) = setup();
        let q = db.row(10);
        let f1 = ivf.scan_fraction(q, 1);
        let fall = ivf.scan_fraction(q, ivf.nlist());
        assert!(f1 > 0.0 && f1 < 1.0, "f1={f1}");
        assert!((fall - 1.0).abs() < 1e-12);
    }

    #[test]
    fn probe_order_total_and_stable() {
        let (db, _, _, ivf) = setup();
        let q = db.row(0);
        let all = ivf.probe_order(q, ivf.nlist());
        assert_eq!(all.len(), ivf.nlist());
        let mut sorted = all.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), ivf.nlist(), "probe order must be a permutation");
    }

    #[test]
    fn recall_improves_with_nprobe() {
        let (db, pq, enc, ivf) = setup();
        // ground truth by linear scan; recall@1 over queries
        let mut recall = vec![0usize; 2]; // nprobe = 1, nlist
        let queries: Vec<usize> = (0..20).collect();
        for &qi in &queries {
            let q = db.row(qi);
            let table = pq.asymmetric_table(q);
            let truth = (0..enc.n())
                .map(|j| (j, pq.asymmetric_distance(&table, enc.code(j))))
                .min_by(|a, b| a.1.total_cmp(&b.1))
                .unwrap();
            for (ri, nprobe) in [(0usize, 1usize), (1, ivf.nlist())] {
                if let Some((id, d)) = ivf.query(&pq, &enc, q, nprobe) {
                    if id == truth.0 || (d - truth.1).abs() < 1e-9 {
                        recall[ri] += 1;
                    }
                }
            }
        }
        assert_eq!(recall[1], queries.len(), "full probe must have full recall");
        assert!(recall[0] <= recall[1]);
        // probing a single cell still finds the true NN often (self is in DB)
        assert!(recall[0] >= queries.len() / 2, "recall@nprobe=1: {}", recall[0]);
    }
}
