//! Top-k nearest-neighbour search over PQ codes (paper §4.1, scaled up).
//!
//! The serving primitives the coordinator builds on:
//!
//! - [`TopKCollector`] — a bounded max-heap over squared distances with a
//!   deterministic `(distance, index)` total order, so the k best
//!   candidates are independent of visit order. That is what makes an
//!   IVF probe over all cells *bit-identical* to the exhaustive scan and
//!   a sharded scan identical to the sequential one.
//! - [`QueryLut`] — the per-query precomputation shared by every scan
//!   mode: the encoded query code word (symmetric) or the `M×K`
//!   asymmetric table; [`QueryLut::collapse`] lowers either into the
//!   blocked kernel's compact `M×K` form (`pq::scan`,
//!   `docs/DESIGN.md` §6).
//! - [`topk_scan_blocked`] — the serving hot path: the blocked kernel
//!   over prebuilt [`CodeBlocks`], threading the collector's admission
//!   bound into the kernel's exact pruning cascade, optionally sharded
//!   over `std::thread` workers in block-aligned chunks.
//! - [`topk_scan`] / [`topk_scan_with`] — one-shot conveniences that
//!   build the blocks per call; [`topk_scan_scalar`] is the unblocked
//!   per-item reference loop kept as the bit-identity oracle and bench
//!   baseline.
//! - [`rerank_dtw`] — the exact re-rank stage: rescore the PQ-approximate
//!   candidate list with true windowed DTW against the raw database,
//!   early-abandoning against the running k-th best.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use crate::core::series::Dataset;
use crate::distance::dtw::{dtw_sq_scratch, DtwScratch};
use crate::obs::ScanStats;
use crate::pq::codebook::Codebook;
use crate::pq::distance as pqdist;
use crate::pq::encode::{CodeBlocks, SCAN_BLOCK};
use crate::pq::quantizer::{EncodedDataset, ProductQuantizer};
use crate::pq::scan::{scan_block, CollapsedLut};

use super::knn::PqQueryMode;

/// One ranked neighbour: database index and (non-squared) distance.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Neighbor {
    /// Index of the database series.
    pub index: usize,
    /// Distance to it (same units as the underlying measure).
    pub distance: f64,
}

/// Internal heap entry ordered by `(distance, index)` under
/// `f64::total_cmp` — a total order, so NaN cannot panic a sort and ties
/// resolve to the smaller index regardless of visit order.
#[derive(Debug, Clone, Copy)]
struct Entry {
    d_sq: f64,
    index: usize,
}

impl PartialEq for Entry {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}

impl Eq for Entry {}

impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Entry {
    fn cmp(&self, other: &Self) -> Ordering {
        self.d_sq
            .total_cmp(&other.d_sq)
            .then_with(|| self.index.cmp(&other.index))
    }
}

/// A bounded max-heap collecting the k smallest squared distances seen.
///
/// `offer` is `O(log k)` and a no-op once the candidate is worse than the
/// current k-th best, so a full scan is `O(N log k)` worst case and close
/// to `O(N)` on shuffled data.
#[derive(Debug, Clone)]
pub struct TopKCollector {
    k: usize,
    heap: BinaryHeap<Entry>,
}

impl TopKCollector {
    /// Collector for the `k` nearest candidates (`k >= 1`).
    pub fn new(k: usize) -> Self {
        assert!(k >= 1, "top-k requires k >= 1");
        TopKCollector { k, heap: BinaryHeap::with_capacity(k + 1) }
    }

    /// Number of candidates currently held (`<= k`).
    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// True when no candidate has been accepted yet.
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Current admission bound (squared): `INFINITY` until the collector
    /// is full, then the k-th smallest squared distance. Any candidate
    /// with a strictly larger squared distance cannot enter — which is
    /// exactly the early-abandon bound for a re-rank DTW.
    pub fn threshold_sq(&self) -> f64 {
        if self.heap.len() < self.k {
            f64::INFINITY
        } else {
            self.heap.peek().map(|e| e.d_sq).unwrap_or(f64::INFINITY)
        }
    }

    /// Offer one candidate.
    pub fn offer(&mut self, index: usize, d_sq: f64) {
        let e = Entry { d_sq, index };
        if self.heap.len() < self.k {
            self.heap.push(e);
        } else if let Some(&worst) = self.heap.peek() {
            if e < worst {
                self.heap.pop();
                self.heap.push(e);
            }
        }
    }

    /// Fold another collector in (the merge step of a sharded scan).
    pub fn merge(&mut self, other: TopKCollector) {
        for e in other.heap {
            self.offer(e.index, e.d_sq);
        }
    }

    /// Finish: neighbours ascending by `(distance, index)`, with the
    /// square root applied.
    pub fn into_sorted(self) -> Vec<Neighbor> {
        let mut entries = self.heap.into_vec();
        entries.sort_unstable();
        entries
            .into_iter()
            .map(|e| Neighbor { index: e.index, distance: e.d_sq.sqrt() })
            .collect()
    }
}

/// Per-query precomputed lookup state for a PQ code scan. Build once,
/// then every database item is `O(M)` table lookups in either mode.
#[derive(Debug, Clone)]
pub enum QueryLut {
    /// Encoded query code word (symmetric mode: LUT-vs-LUT lookups).
    Symmetric(Vec<u16>),
    /// Query-specific `M×K` squared-distance table (asymmetric mode).
    Asymmetric(Vec<f64>),
}

impl QueryLut {
    /// Precompute the query side of a scan in the given mode.
    pub fn build(pq: &ProductQuantizer, q: &[f64], mode: PqQueryMode) -> Self {
        match mode {
            PqQueryMode::Symmetric => {
                let (codes, _, _) = pq.encode(q);
                QueryLut::Symmetric(codes)
            }
            PqQueryMode::Asymmetric => QueryLut::Asymmetric(pq.asymmetric_table(q)),
        }
    }

    /// Squared PQ distance of the query to one encoded item.
    #[inline]
    pub fn dist_sq(&self, cb: &Codebook, code: &[u16]) -> f64 {
        match self {
            QueryLut::Symmetric(cx) => pqdist::symmetric_sq(cb, cx, code),
            QueryLut::Asymmetric(table) => pqdist::asymmetric_sq(cb, table, code),
        }
    }

    /// Lower the query-side state into the blocked kernel's compact
    /// `M×K` form. For the symmetric mode this slices the query's rows
    /// out of the full `M×K²` LUT (shrinking the per-scan working set
    /// by a factor of K); the asymmetric table already has the right
    /// shape. Distances computed through the result are bit-identical
    /// to [`QueryLut::dist_sq`].
    pub fn collapse(&self, cb: &Codebook) -> CollapsedLut {
        match self {
            QueryLut::Symmetric(cx) => CollapsedLut::symmetric(cb, cx),
            QueryLut::Asymmetric(table) => CollapsedLut::asymmetric(cb, table),
        }
    }
}

/// Scan item positions `[start, end)` of the blocked codes into `coll`
/// through the kernel, re-reading the collector's admission threshold
/// once per block (when `prune` is set) so hopeless items are abandoned
/// mid-accumulation — lossless for the final top-k, since only items
/// whose partial sum already exceeds the bound are dropped. `ids` maps
/// a block position to the database id it represents (the CSR-permuted
/// IVF layout); `None` means positions are ids.
///
/// `stats` is the optional prune-cascade counter sink (`obs`): `None`
/// runs the untouched hot loop (zero tracing overhead); `Some` counts
/// items in / emitted / fully-skipped blocks in locals and flushes them
/// into the atomics once per call. The emitted distances — and therefore
/// the final top-k — are bit-identical either way (proptested).
pub(crate) fn scan_blocks_into(
    lut: &CollapsedLut,
    blocks: &CodeBlocks,
    start: usize,
    end: usize,
    ids: Option<&[usize]>,
    prune: bool,
    coll: &mut TopKCollector,
    stats: Option<&ScanStats>,
) {
    let end = end.min(blocks.n());
    let Some(stats) = stats else {
        let mut pos = start;
        while pos < end {
            let block = pos / SCAN_BLOCK;
            let base = block * SCAN_BLOCK;
            let lo = pos - base;
            let hi = (end - base).min(SCAN_BLOCK);
            let thr = if prune { coll.threshold_sq() } else { f64::INFINITY };
            scan_block(lut, blocks, block, lo, hi, thr, |lane, d| {
                let p = base + lane;
                let id = match ids {
                    Some(ids) => ids[p],
                    None => p,
                };
                coll.offer(id, d);
            });
            pos = base + hi;
        }
        return;
    };
    // Counted twin of the loop above: identical kernel calls and emit
    // order, plus local accounting flushed once at the end.
    let mut items_in = 0u64;
    let mut emitted = 0u64;
    let mut blocks_skipped = 0u64;
    let mut pos = start;
    while pos < end {
        let block = pos / SCAN_BLOCK;
        let base = block * SCAN_BLOCK;
        let lo = pos - base;
        let hi = (end - base).min(SCAN_BLOCK);
        let thr = if prune { coll.threshold_sq() } else { f64::INFINITY };
        let before = emitted;
        scan_block(lut, blocks, block, lo, hi, thr, |lane, d| {
            let p = base + lane;
            let id = match ids {
                Some(ids) => ids[p],
                None => p,
            };
            emitted += 1;
            coll.offer(id, d);
        });
        items_in += (hi - lo) as u64;
        if prune && emitted == before && hi > lo {
            blocks_skipped += 1;
        }
        pos = base + hi;
    }
    stats.add_range(items_in, emitted, blocks_skipped);
}

/// Exhaustive top-k scan of an encoded database, sharded over
/// `n_threads` std threads (1 = sequential). The result is independent
/// of `n_threads` thanks to the collector's deterministic total order.
pub fn topk_scan(
    pq: &ProductQuantizer,
    enc: &EncodedDataset,
    q: &[f64],
    k: usize,
    mode: PqQueryMode,
    n_threads: usize,
) -> Vec<Neighbor> {
    let lut = QueryLut::build(pq, q, mode);
    topk_scan_with(pq, enc, &lut, k, n_threads)
}

/// [`topk_scan`] with the query-side precomputation already done. A
/// one-shot convenience: it transposes the codes into their blocked
/// form per call. A serving loop should build [`CodeBlocks`] once and
/// call [`topk_scan_blocked`] instead (the engine does).
pub fn topk_scan_with(
    pq: &ProductQuantizer,
    enc: &EncodedDataset,
    lut: &QueryLut,
    k: usize,
    n_threads: usize,
) -> Vec<Neighbor> {
    if enc.n() == 0 {
        return Vec::new();
    }
    let blocks = enc.to_blocks(pq.codebook.k);
    let clut = lut.collapse(&pq.codebook);
    topk_scan_blocked(&blocks, &clut, k, n_threads)
}

/// The serving hot path: exhaustive blocked top-k scan over prebuilt
/// code blocks with the pruning cascade on. Sharded over `n_threads`
/// std threads in block-aligned chunks (1 = sequential); bit-identical
/// to the scalar reference ([`topk_scan_scalar`]) for any thread count.
pub fn topk_scan_blocked(
    blocks: &CodeBlocks,
    lut: &CollapsedLut,
    k: usize,
    n_threads: usize,
) -> Vec<Neighbor> {
    topk_scan_blocked_opts(blocks, lut, k, n_threads, true)
}

/// [`topk_scan_blocked`] with the pruning cascade selectable (`prune =
/// false` streams every item — the bench's pruned-vs-unpruned axis; the
/// final top-k is identical either way).
pub fn topk_scan_blocked_opts(
    blocks: &CodeBlocks,
    lut: &CollapsedLut,
    k: usize,
    n_threads: usize,
    prune: bool,
) -> Vec<Neighbor> {
    topk_scan_blocked_stats(blocks, lut, k, n_threads, prune, None)
}

/// [`topk_scan_blocked_opts`] with an optional prune-cascade counter
/// sink. When `stats` is `Some`, each shard additionally records its
/// wall-time ([`ScanStats::add_shard_time`]); the returned top-k is
/// bit-identical to the untraced call for any thread count.
pub fn topk_scan_blocked_stats(
    blocks: &CodeBlocks,
    lut: &CollapsedLut,
    k: usize,
    n_threads: usize,
    prune: bool,
    stats: Option<&ScanStats>,
) -> Vec<Neighbor> {
    let n = blocks.n();
    if n == 0 {
        return Vec::new();
    }
    let threads = n_threads.max(1).min(n);
    if threads == 1 {
        let t0 = stats.map(|_| std::time::Instant::now());
        let mut coll = TopKCollector::new(k);
        scan_blocks_into(lut, blocks, 0, n, None, prune, &mut coll, stats);
        if let (Some(st), Some(t0)) = (stats, t0) {
            st.add_shard_time(t0.elapsed().as_micros() as u64);
        }
        return coll.into_sorted();
    }
    // Block-aligned shards: no two workers ever touch the same block.
    let blocks_per_shard = blocks.n_blocks().div_ceil(threads).max(1);
    let chunk = blocks_per_shard * SCAN_BLOCK;
    let acc = std::thread::scope(|s| {
        let mut handles = Vec::with_capacity(threads);
        let mut start = 0usize;
        while start < n {
            let end = (start + chunk).min(n);
            handles.push(s.spawn(move || {
                let t0 = stats.map(|_| std::time::Instant::now());
                let mut coll = TopKCollector::new(k);
                scan_blocks_into(lut, blocks, start, end, None, prune, &mut coll, stats);
                if let (Some(st), Some(t0)) = (stats, t0) {
                    st.add_shard_time(t0.elapsed().as_micros() as u64);
                }
                coll
            }));
            start = end;
        }
        let mut acc = TopKCollector::new(k);
        for h in handles {
            acc.merge(h.join().expect("top-k scan worker panicked"));
        }
        acc
    });
    acc.into_sorted()
}

/// Scalar reference scan: one full-LUT lookup chain per item over the
/// row-major codes, no blocking, no pruning — the pre-kernel hot loop,
/// kept as the bit-identity oracle for the kernel tests and the
/// baseline for `benches/perf_hotpath.rs` / `bench-scan`.
pub fn topk_scan_scalar(
    pq: &ProductQuantizer,
    enc: &EncodedDataset,
    lut: &QueryLut,
    k: usize,
) -> Vec<Neighbor> {
    let mut coll = TopKCollector::new(k);
    for i in 0..enc.n() {
        coll.offer(i, lut.dist_sq(&pq.codebook, enc.code(i)));
    }
    coll.into_sorted()
}

/// Exact re-rank: rescore PQ-approximate `candidates` with true windowed
/// DTW against the raw database and keep the `k` best. Early-abandons
/// each DTW against the running k-th best, which is lossless for the
/// final top-k (an abandoned candidate provably cannot enter it).
///
/// Returned distances are true DTW values, not PQ approximations.
pub fn rerank_dtw(
    db: &Dataset,
    q: &[f64],
    candidates: &[Neighbor],
    k: usize,
    window: Option<usize>,
) -> Vec<Neighbor> {
    let mut coll = TopKCollector::new(k.max(1));
    let mut scratch = DtwScratch::new(db.len);
    for c in candidates {
        let ub = coll.threshold_sq();
        let d = dtw_sq_scratch(q, db.row(c.index), window, ub, &mut scratch);
        if d.is_finite() {
            coll.offer(c.index, d);
        }
    }
    coll.into_sorted()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::ucr_like::ucr_like_by_name;
    use crate::distance::dtw::dtw_sq;
    use crate::nn::knn::nn_classify_pq;
    use crate::pq::quantizer::PqConfig;

    fn toy() -> (ProductQuantizer, EncodedDataset, Dataset, Dataset) {
        let tt = ucr_like_by_name("CBF", 907).unwrap();
        let cfg = PqConfig {
            n_subspaces: 4,
            codebook_size: 16,
            window_frac: 0.2,
            ..Default::default()
        };
        let pq = ProductQuantizer::train(&tt.train, &cfg, 3).unwrap();
        let enc = pq.encode_dataset(&tt.train);
        (pq, enc, tt.train, tt.test)
    }

    #[test]
    fn collector_keeps_k_smallest_with_index_ties() {
        let mut c = TopKCollector::new(3);
        for (i, d) in [(5usize, 4.0), (1, 1.0), (9, 1.0), (2, 9.0), (7, 0.5), (3, 4.0)] {
            c.offer(i, d);
        }
        let out = c.into_sorted();
        let got: Vec<(usize, f64)> = out.iter().map(|n| (n.index, n.distance * n.distance)).collect();
        assert_eq!(got.len(), 3);
        assert_eq!(got[0].0, 7);
        assert_eq!(got[1].0, 1);
        assert_eq!(got[2].0, 9); // the (1.0, 9) tie beats (4.0, _)
        assert!((got[0].1 - 0.5).abs() < 1e-12);
    }

    #[test]
    fn collector_threshold_tracks_kth_best() {
        let mut c = TopKCollector::new(2);
        assert!(c.threshold_sq().is_infinite());
        c.offer(0, 3.0);
        assert!(c.threshold_sq().is_infinite());
        c.offer(1, 1.0);
        assert_eq!(c.threshold_sq(), 3.0);
        c.offer(2, 2.0);
        assert_eq!(c.threshold_sq(), 2.0);
        c.offer(3, 10.0); // rejected
        assert_eq!(c.threshold_sq(), 2.0);
    }

    #[test]
    fn collector_ignores_nan_gracefully() {
        let mut c = TopKCollector::new(2);
        c.offer(0, f64::NAN);
        c.offer(1, 1.0);
        c.offer(2, 2.0);
        let out = c.into_sorted();
        assert_eq!(out[0].index, 1);
        assert_eq!(out[1].index, 2);
    }

    #[test]
    fn scan_matches_bruteforce_both_modes() {
        let (pq, enc, _, test) = toy();
        for mode in [PqQueryMode::Symmetric, PqQueryMode::Asymmetric] {
            for qi in 0..5 {
                let q = test.row(qi);
                let hits = topk_scan(&pq, &enc, q, 4, mode, 1);
                // brute force over the same per-item distance
                let lut = QueryLut::build(&pq, q, mode);
                let mut all: Vec<(usize, f64)> = (0..enc.n())
                    .map(|j| (j, lut.dist_sq(&pq.codebook, enc.code(j))))
                    .collect();
                all.sort_by(|a, b| a.1.total_cmp(&b.1).then(a.0.cmp(&b.0)));
                assert_eq!(hits.len(), 4);
                for (h, want) in hits.iter().zip(all.iter()) {
                    assert_eq!(h.index, want.0, "mode {mode:?} query {qi}");
                    assert!((h.distance - want.1.sqrt()).abs() < 1e-12);
                }
            }
        }
    }

    #[test]
    fn blocked_scan_bit_identical_to_scalar_reference() {
        let (pq, enc, _, test) = toy();
        let blocks = enc.to_blocks(pq.codebook.k);
        for mode in [PqQueryMode::Symmetric, PqQueryMode::Asymmetric] {
            for qi in 0..4 {
                let q = test.row(qi);
                let lut = QueryLut::build(&pq, q, mode);
                let clut = lut.collapse(&pq.codebook);
                let scalar = topk_scan_scalar(&pq, &enc, &lut, 6);
                for prune in [false, true] {
                    for threads in [1usize, 3] {
                        let got = topk_scan_blocked_opts(&blocks, &clut, 6, threads, prune);
                        assert_eq!(
                            scalar, got,
                            "mode {mode:?} q{qi} prune={prune} threads={threads}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn threaded_scan_identical_to_sequential() {
        let (pq, enc, _, test) = toy();
        for qi in 0..5 {
            let q = test.row(qi);
            let seq = topk_scan(&pq, &enc, q, 7, PqQueryMode::Asymmetric, 1);
            for threads in [2, 3, 8] {
                let par = topk_scan(&pq, &enc, q, 7, PqQueryMode::Asymmetric, threads);
                assert_eq!(seq, par, "threads={threads} query {qi}");
            }
        }
    }

    #[test]
    fn topk1_agrees_with_nn_classify_pq() {
        let (pq, enc, _, test) = toy();
        for mode in [PqQueryMode::Symmetric, PqQueryMode::Asymmetric] {
            let (_, preds) = nn_classify_pq(&pq, &enc, &test, mode);
            for i in 0..test.n_series() {
                let hits = topk_scan(&pq, &enc, test.row(i), 1, mode, 2);
                assert_eq!(hits.len(), 1);
                assert_eq!(
                    enc.labels[hits[0].index],
                    preds[i],
                    "mode {mode:?} query {i}"
                );
            }
        }
    }

    #[test]
    fn rerank_yields_true_dtw_distances_and_exact_topk() {
        let (pq, enc, train, test) = toy();
        let window = Some(6);
        let q = test.row(2);
        // generous PQ candidate pool, then exact re-rank to k=5
        let cands = topk_scan(&pq, &enc, q, 30, PqQueryMode::Asymmetric, 1);
        let hits = rerank_dtw(&train, q, &cands, 5, window);
        assert_eq!(hits.len(), 5);
        // 1. distances are true DTW values
        for h in &hits {
            let want = dtw_sq(q, train.row(h.index), window).sqrt();
            assert!(
                (h.distance - want).abs() < 1e-9,
                "index {}: {} vs true {}",
                h.index,
                h.distance,
                want
            );
        }
        // 2. exactly the 5 best of the candidate pool under true DTW
        let mut truth: Vec<(usize, f64)> = cands
            .iter()
            .map(|c| (c.index, dtw_sq(q, train.row(c.index), window)))
            .collect();
        truth.sort_by(|a, b| a.1.total_cmp(&b.1).then(a.0.cmp(&b.0)));
        for (h, want) in hits.iter().zip(truth.iter()) {
            assert_eq!(h.index, want.0);
        }
        // 3. ascending order
        for w in hits.windows(2) {
            assert!(w[0].distance <= w[1].distance + 1e-12);
        }
    }

    #[test]
    fn stats_sink_is_bit_transparent_and_counts_are_consistent() {
        let (pq, enc, _, test) = toy();
        let blocks = enc.to_blocks(pq.codebook.k);
        let q = test.row(1);
        let lut = QueryLut::build(&pq, q, PqQueryMode::Asymmetric);
        let clut = lut.collapse(&pq.codebook);
        for prune in [false, true] {
            for threads in [1usize, 3] {
                let plain = topk_scan_blocked_opts(&blocks, &clut, 5, threads, prune);
                let stats = ScanStats::new();
                let traced =
                    topk_scan_blocked_stats(&blocks, &clut, 5, threads, prune, Some(&stats));
                assert_eq!(plain, traced, "prune={prune} threads={threads}");
                let s = stats.snapshot();
                assert_eq!(s.items_scanned, enc.n() as u64);
                assert!(s.items_abandoned <= s.items_scanned);
                assert!(s.shards >= 1);
                if !prune {
                    assert_eq!(s.items_abandoned, 0, "streaming scan abandons nothing");
                    assert_eq!(s.blocks_skipped, 0);
                }
            }
        }
    }

    #[test]
    fn k_larger_than_db_returns_everything() {
        let (pq, enc, _, test) = toy();
        let n = enc.n();
        let hits = topk_scan(&pq, &enc, test.row(0), n + 50, PqQueryMode::Symmetric, 2);
        assert_eq!(hits.len(), n);
        let mut seen: Vec<usize> = hits.iter().map(|h| h.index).collect();
        seen.sort_unstable();
        seen.dedup();
        assert_eq!(seen.len(), n);
    }
}
