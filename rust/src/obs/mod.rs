//! Observability layer: prune-cascade counters, per-query traces, and the
//! serving-path log/exposition surfaces.
//!
//! Everything here is std-only and lock-free on the hot path:
//!
//! - [`ScanStats`] is an atomic sink the blocked-scan kernel flushes into
//!   once per scanned range (never per item). Callers pass
//!   `Option<&ScanStats>`; `None` runs the untouched hot loop, so tracing
//!   costs nothing when disabled.
//! - [`QueryTrace`] records the stage ladder one query walks
//!   (`lut_collapse → coarse_probe → blocked_scan → rerank`) with
//!   wall-times and candidate in/out counts, plus optional per-hit
//!   [`HitExplain`] records ("why ranked").
//! - [`log::JsonLogger`] emits structured JSON-lines events for the
//!   serving plane (the `no-raw-stderr-in-serving` lint requires serving
//!   code to log through it rather than `eprintln!`).
//! - [`prometheus::PromText`] renders counters/histograms in Prometheus
//!   text exposition format.
//!
//! The trace schema and metric names are documented in
//! `docs/observability.md`.

pub mod log;
pub mod prometheus;

use std::sync::atomic::{AtomicU64, Ordering};

/// Lock-free sink for kernel-level scan counters.
///
/// One `ScanStats` can serve as a per-query scratch (snapshot it into the
/// query's trace) or as a long-lived process-wide accumulator (the engine
/// keeps one for the Prometheus counters). All updates are relaxed atomic
/// adds: the counters are monotone and independent, so no cross-field
/// ordering is needed.
#[derive(Debug, Default)]
pub struct ScanStats {
    /// Items entering the blocked-scan cascade (lanes actually requested,
    /// excluding block tail padding).
    pub items_scanned: AtomicU64,
    /// Items abandoned mid-cascade by the exact prune
    /// (`items_scanned - emitted`).
    pub items_abandoned: AtomicU64,
    /// Blocks where the prune abandoned every requested lane.
    pub blocks_skipped: AtomicU64,
    /// Per-query LUT collapses (symmetric `M·K² → M·K` row gathers).
    pub lut_collapses: AtomicU64,
    /// Wall-time summed over scan shards, in microseconds.
    pub shard_time_us: AtomicU64,
    /// Number of scan shards timed into `shard_time_us`.
    pub shards: AtomicU64,
}

impl ScanStats {
    pub fn new() -> Self {
        Self::default()
    }

    /// Flush one scanned range: `items_in` lanes entered, `emitted`
    /// survived the cascade, `blocks_skipped` blocks lost every lane.
    pub fn add_range(&self, items_in: u64, emitted: u64, blocks_skipped: u64) {
        self.items_scanned.fetch_add(items_in, Ordering::Relaxed);
        self.items_abandoned
            .fetch_add(items_in.saturating_sub(emitted), Ordering::Relaxed);
        self.blocks_skipped.fetch_add(blocks_skipped, Ordering::Relaxed);
    }

    /// Record one symmetric-LUT collapse.
    pub fn add_lut_collapse(&self) {
        self.lut_collapses.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one scan shard's wall-time.
    pub fn add_shard_time(&self, us: u64) {
        self.shard_time_us.fetch_add(us, Ordering::Relaxed);
        self.shards.fetch_add(1, Ordering::Relaxed);
    }

    /// Read a consistent-enough point-in-time copy (fields are read
    /// independently; exactness across fields is not required by any
    /// consumer — per-query sinks are quiescent when snapshotted).
    pub fn snapshot(&self) -> ScanSnapshot {
        ScanSnapshot {
            items_scanned: self.items_scanned.load(Ordering::Relaxed),
            items_abandoned: self.items_abandoned.load(Ordering::Relaxed),
            blocks_skipped: self.blocks_skipped.load(Ordering::Relaxed),
            lut_collapses: self.lut_collapses.load(Ordering::Relaxed),
            shard_time_us: self.shard_time_us.load(Ordering::Relaxed),
            shards: self.shards.load(Ordering::Relaxed),
        }
    }

    /// Add this sink's current totals into `other` (used to roll a
    /// per-query sink into the engine-wide accumulator).
    pub fn merge_into(&self, other: &ScanStats) {
        let s = self.snapshot();
        other.items_scanned.fetch_add(s.items_scanned, Ordering::Relaxed);
        other
            .items_abandoned
            .fetch_add(s.items_abandoned, Ordering::Relaxed);
        other.blocks_skipped.fetch_add(s.blocks_skipped, Ordering::Relaxed);
        other.lut_collapses.fetch_add(s.lut_collapses, Ordering::Relaxed);
        other.shard_time_us.fetch_add(s.shard_time_us, Ordering::Relaxed);
        other.shards.fetch_add(s.shards, Ordering::Relaxed);
    }
}

/// Plain-value copy of [`ScanStats`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ScanSnapshot {
    pub items_scanned: u64,
    pub items_abandoned: u64,
    pub blocks_skipped: u64,
    pub lut_collapses: u64,
    pub shard_time_us: u64,
    pub shards: u64,
}

impl ScanSnapshot {
    /// Fraction of scanned items the prune cascade abandoned, in `[0, 1]`.
    pub fn abandon_rate(&self) -> f64 {
        if self.items_scanned == 0 {
            0.0
        } else {
            self.items_abandoned as f64 / self.items_scanned as f64
        }
    }
}

/// One rung of the query ladder. Wire encoding and Prometheus label both
/// use [`Stage::name`]; the discriminant is stable (`as_u8`/`from_u8`).
///
/// Tags 0–3 are the single-engine ladder; tags 4–6 are the router-level
/// stages a scatter-gather router records around its shard fan-out
/// (they never appear in a single-engine trace).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Stage {
    /// Query-side LUT derivation (symmetric collapse or asymmetric build).
    LutCollapse,
    /// IVF coarse-centroid probe ordering (absent for exhaustive scans).
    CoarseProbe,
    /// Blocked PQ-code scan with the exact prune cascade.
    BlockedScan,
    /// Exact windowed-DTW re-rank of the PQ candidate pool.
    Rerank,
    /// Router: scatter of one query to every healthy shard (wall time of
    /// the whole fan-out, including the slowest leg).
    Fanout,
    /// Router: one shard's RPC leg (one span per shard that answered).
    ShardRpc,
    /// Router: deterministic k-way merge of the shard answers.
    Merge,
}

/// Number of distinct stages (histogram array dimension).
pub const N_STAGES: usize = 7;

impl Stage {
    /// All stages in ladder order (engine rungs first, then the
    /// router-level fan-out stages).
    pub const ALL: [Stage; N_STAGES] = [
        Stage::LutCollapse,
        Stage::CoarseProbe,
        Stage::BlockedScan,
        Stage::Rerank,
        Stage::Fanout,
        Stage::ShardRpc,
        Stage::Merge,
    ];

    /// Stable snake_case name (wire docs, Prometheus `stage` label,
    /// JSON trace output).
    pub fn name(self) -> &'static str {
        match self {
            Stage::LutCollapse => "lut_collapse",
            Stage::CoarseProbe => "coarse_probe",
            Stage::BlockedScan => "blocked_scan",
            Stage::Rerank => "rerank",
            Stage::Fanout => "fanout",
            Stage::ShardRpc => "shard_rpc",
            Stage::Merge => "merge",
        }
    }

    /// Stable wire discriminant.
    pub fn as_u8(self) -> u8 {
        match self {
            Stage::LutCollapse => 0,
            Stage::CoarseProbe => 1,
            Stage::BlockedScan => 2,
            Stage::Rerank => 3,
            Stage::Fanout => 4,
            Stage::ShardRpc => 5,
            Stage::Merge => 6,
        }
    }

    /// Inverse of [`Stage::as_u8`]; `None` for unknown discriminants
    /// (hostile wire input).
    pub fn from_u8(v: u8) -> Option<Stage> {
        match v {
            0 => Some(Stage::LutCollapse),
            1 => Some(Stage::CoarseProbe),
            2 => Some(Stage::BlockedScan),
            3 => Some(Stage::Rerank),
            4 => Some(Stage::Fanout),
            5 => Some(Stage::ShardRpc),
            6 => Some(Stage::Merge),
            _ => None,
        }
    }

    /// Index into per-stage histogram arrays.
    pub fn index(self) -> usize {
        usize::from(self.as_u8())
    }
}

/// One timed stage of a query, with candidate-set accounting.
///
/// For `BlockedScan`, `candidates_in - items_abandoned == candidates_out`
/// (the prune-cascade conservation law tested in the proptest harness).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StageSpan {
    pub stage: Stage,
    /// Wall-clock time spent in the stage, microseconds.
    pub wall_us: u64,
    /// Candidates entering the stage.
    pub candidates_in: u64,
    /// Candidates surviving the stage.
    pub candidates_out: u64,
}

/// "Why ranked": per-hit provenance in a traced response.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HitExplain {
    /// Database index of the hit (mirrors the hit list ordering).
    pub index: u64,
    /// PQ-space distance estimate that admitted the item.
    pub pq_estimate: f64,
    /// Exact windowed DTW, present iff the hit was re-ranked.
    pub exact_dtw: Option<f64>,
    /// The last stage that (re)admitted the hit into the result set.
    pub admitted_by: Stage,
    /// The shard whose engine admitted the hit (routed traces only;
    /// `None` for single-engine traces and job-plane explains).
    pub shard: Option<u64>,
}

/// One shard's sub-trace inside a routed [`QueryTrace`]: the shard's
/// own engine trace plus the router's per-leg annotations. Child traces
/// are depth-1 by construction — a child never carries children of its
/// own (the wire decoder rejects deeper nesting).
#[derive(Debug, Clone, PartialEq)]
pub struct ChildTrace {
    /// Shard index (position in the router's `--shards` list).
    pub shard: u64,
    /// The leg was re-attempted after a hard failure.
    pub retried: bool,
    /// The leg was re-attempted after a read timeout.
    pub hedged: bool,
    /// The shard did not contribute to the merged answer (its trace is
    /// whatever arrived before the leg failed — usually empty).
    pub degraded: bool,
    /// The shard server's own trace for this query.
    pub trace: QueryTrace,
}

/// End-to-end record of one query's walk down the ladder.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct QueryTrace {
    /// Client-supplied request id (0 when unset; the net server stamps
    /// the wire value over whatever the engine recorded).
    pub request_id: u64,
    /// Stage ladder in execution order. Stages that did not run for this
    /// query (e.g. `coarse_probe` on an exhaustive scan) are absent.
    pub spans: Vec<StageSpan>,
    /// Per-hit explainability, parallel to the response's hit list.
    /// Empty when the client did not request explanations.
    pub hits: Vec<HitExplain>,
    /// This query's kernel counters (quiescent per-query sink snapshot).
    pub scan: ScanSnapshot,
    /// Per-shard sub-traces, ascending by shard (routed traces only;
    /// empty for single-engine traces).
    pub children: Vec<ChildTrace>,
}

impl QueryTrace {
    /// Find a span by stage, if that stage ran.
    pub fn span(&self, stage: Stage) -> Option<&StageSpan> {
        self.spans.iter().find(|s| s.stage == stage)
    }

    /// One-line per-stage wall-time summary
    /// (`"fanout=3us shard_rpc=120us merge=2us"`) — the `spans` field
    /// of `slow_query` log events.
    pub fn span_summary(&self) -> String {
        let parts: Vec<String> = self
            .spans
            .iter()
            .map(|s| format!("{}={}us", s.stage.name(), s.wall_us))
            .collect();
        parts.join(" ")
    }

    /// Render the trace as human-readable text (the `query --trace` CLI
    /// output; one line per span, then one per explained hit, then —
    /// for routed traces — each shard's sub-ladder indented below).
    pub fn render_text(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("trace request_id={}\n", self.request_id));
        self.render_body(&mut out, "  ");
        for c in &self.children {
            let mut flags = String::new();
            if c.retried {
                flags.push_str(" retried");
            }
            if c.hedged {
                flags.push_str(" hedged");
            }
            if c.degraded {
                flags.push_str(" degraded");
            }
            out.push_str(&format!("  shard {}{flags}\n", c.shard));
            out.push_str(&format!("    trace request_id={}\n", c.trace.request_id));
            c.trace.render_body(&mut out, "    ");
        }
        out
    }

    /// The span/scan/hit lines shared by top-level and child renderings.
    fn render_body(&self, out: &mut String, pad: &str) {
        for s in &self.spans {
            out.push_str(&format!(
                "{pad}stage {:<13} wall_us={:<8} in={:<8} out={}\n",
                s.stage.name(),
                s.wall_us,
                s.candidates_in,
                s.candidates_out
            ));
        }
        out.push_str(&format!(
            "{pad}scan items={} abandoned={} ({:.1}%) blocks_skipped={} \
             lut_collapses={}\n",
            self.scan.items_scanned,
            self.scan.items_abandoned,
            100.0 * self.scan.abandon_rate(),
            self.scan.blocks_skipped,
            self.scan.lut_collapses
        ));
        for h in &self.hits {
            let exact = match h.exact_dtw {
                Some(d) => format!(" exact_dtw={d:.6}"),
                None => String::new(),
            };
            let shard = match h.shard {
                Some(s) => format!(" shard={s}"),
                None => String::new(),
            };
            out.push_str(&format!(
                "{pad}hit index={:<6} pq_estimate={:.6}{} admitted_by={}{}\n",
                h.index,
                h.pq_estimate,
                exact,
                h.admitted_by.name(),
                shard
            ));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scan_stats_accumulates_and_snapshots() {
        let s = ScanStats::new();
        s.add_range(64, 10, 0);
        s.add_range(36, 0, 1);
        s.add_lut_collapse();
        s.add_shard_time(120);
        let snap = s.snapshot();
        assert_eq!(snap.items_scanned, 100);
        assert_eq!(snap.items_abandoned, 54 + 36);
        assert_eq!(snap.blocks_skipped, 1);
        assert_eq!(snap.lut_collapses, 1);
        assert_eq!(snap.shard_time_us, 120);
        assert_eq!(snap.shards, 1);
        assert!((snap.abandon_rate() - 0.9).abs() < 1e-12);
    }

    #[test]
    fn merge_into_adds_totals() {
        let a = ScanStats::new();
        let b = ScanStats::new();
        a.add_range(10, 4, 0);
        b.add_range(5, 5, 0);
        a.merge_into(&b);
        let snap = b.snapshot();
        assert_eq!(snap.items_scanned, 15);
        assert_eq!(snap.items_abandoned, 6);
    }

    #[test]
    fn abandon_rate_of_empty_snapshot_is_zero() {
        assert_eq!(ScanSnapshot::default().abandon_rate(), 0.0);
    }

    #[test]
    fn stage_u8_roundtrip_is_stable() {
        for stage in Stage::ALL {
            assert_eq!(Stage::from_u8(stage.as_u8()), Some(stage));
        }
        assert_eq!(Stage::from_u8(7), None);
        assert_eq!(Stage::from_u8(255), None);
        // The discriminants are part of the wire format — pin them.
        assert_eq!(Stage::LutCollapse.as_u8(), 0);
        assert_eq!(Stage::CoarseProbe.as_u8(), 1);
        assert_eq!(Stage::BlockedScan.as_u8(), 2);
        assert_eq!(Stage::Rerank.as_u8(), 3);
        assert_eq!(Stage::Fanout.as_u8(), 4);
        assert_eq!(Stage::ShardRpc.as_u8(), 5);
        assert_eq!(Stage::Merge.as_u8(), 6);
    }

    #[test]
    fn stage_names_are_unique_snake_case() {
        let names: Vec<&str> = Stage::ALL.iter().map(|s| s.name()).collect();
        for (i, a) in names.iter().enumerate() {
            assert!(a.chars().all(|c| c.is_ascii_lowercase() || c == '_'));
            for b in &names[i + 1..] {
                assert_ne!(a, b);
            }
        }
    }

    #[test]
    fn trace_span_lookup_and_text_rendering() {
        let trace = QueryTrace {
            request_id: 42,
            spans: vec![
                StageSpan {
                    stage: Stage::LutCollapse,
                    wall_us: 3,
                    candidates_in: 100,
                    candidates_out: 100,
                },
                StageSpan {
                    stage: Stage::BlockedScan,
                    wall_us: 50,
                    candidates_in: 100,
                    candidates_out: 12,
                },
            ],
            hits: vec![HitExplain {
                index: 7,
                pq_estimate: 1.25,
                exact_dtw: Some(1.5),
                admitted_by: Stage::Rerank,
                shard: None,
            }],
            scan: ScanSnapshot {
                items_scanned: 100,
                items_abandoned: 88,
                blocks_skipped: 1,
                lut_collapses: 1,
                shard_time_us: 49,
                shards: 1,
            },
            children: Vec::new(),
        };
        assert_eq!(trace.span(Stage::BlockedScan).map(|s| s.wall_us), Some(50));
        assert_eq!(trace.span(Stage::Rerank), None);
        let text = trace.render_text();
        assert!(text.contains("request_id=42"));
        assert!(text.contains("blocked_scan"));
        assert!(text.contains("abandoned=88"));
        assert!(text.contains("admitted_by=rerank"));
    }

    #[test]
    fn routed_trace_renders_the_cross_node_ladder() {
        let child = QueryTrace {
            request_id: 9,
            spans: vec![StageSpan {
                stage: Stage::BlockedScan,
                wall_us: 40,
                candidates_in: 50,
                candidates_out: 5,
            }],
            hits: Vec::new(),
            scan: ScanSnapshot::default(),
            children: Vec::new(),
        };
        let trace = QueryTrace {
            request_id: 9,
            spans: vec![
                StageSpan {
                    stage: Stage::Fanout,
                    wall_us: 55,
                    candidates_in: 3,
                    candidates_out: 2,
                },
                StageSpan {
                    stage: Stage::ShardRpc,
                    wall_us: 40,
                    candidates_in: 0,
                    candidates_out: 5,
                },
                StageSpan {
                    stage: Stage::Merge,
                    wall_us: 2,
                    candidates_in: 10,
                    candidates_out: 4,
                },
            ],
            hits: vec![HitExplain {
                index: 4,
                pq_estimate: 0.5,
                exact_dtw: None,
                admitted_by: Stage::Merge,
                shard: Some(1),
            }],
            scan: ScanSnapshot::default(),
            children: vec![
                ChildTrace {
                    shard: 1,
                    retried: false,
                    hedged: false,
                    degraded: false,
                    trace: child,
                },
                ChildTrace {
                    shard: 2,
                    retried: true,
                    hedged: false,
                    degraded: true,
                    trace: QueryTrace::default(),
                },
            ],
        };
        let text = trace.render_text();
        assert!(text.contains("stage fanout"), "{text}");
        assert!(text.contains("stage shard_rpc"), "{text}");
        assert!(text.contains("stage merge"), "{text}");
        assert!(text.contains("shard 1\n"), "{text}");
        assert!(text.contains("shard 2 retried degraded\n"), "{text}");
        assert!(text.contains("shard=1"), "{text}");
        assert!(text.contains("    stage blocked_scan"), "{text}");
    }
}
