//! Structured JSON-lines event logging for the serving plane.
//!
//! The `no-raw-stderr-in-serving` lint forbids `eprintln!`/`eprint!` in
//! `net/` and `coordinator/`; serving code logs through [`JsonLogger`]
//! instead, so events are machine-parseable (one JSON object per line)
//! and logging can be disabled without sprinkling `if` at call sites.
//!
//! Event shape: `{"ts":<unix_secs>,"event":"<name>",...fields}`.
//! Field values are JSON numbers, strings, or booleans; strings are
//! escaped per JSON. The event shapes the server emits are documented in
//! `docs/observability.md`.

use std::io::Write;
use std::sync::Mutex;
use std::time::{SystemTime, UNIX_EPOCH};

/// A typed field value for one log event.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    U64(u64),
    I64(i64),
    F64(f64),
    Str(String),
    Bool(bool),
}

impl From<u64> for Value {
    fn from(v: u64) -> Self {
        Value::U64(v)
    }
}

impl From<&str> for Value {
    fn from(v: &str) -> Self {
        Value::Str(v.to_string())
    }
}

impl From<String> for Value {
    fn from(v: String) -> Self {
        Value::Str(v)
    }
}

impl From<bool> for Value {
    fn from(v: bool) -> Self {
        Value::Bool(v)
    }
}

/// JSON-lines event logger. Disabled loggers are free: `event` returns
/// before formatting anything.
pub struct JsonLogger {
    sink: Option<Mutex<Box<dyn Write + Send>>>,
}

impl std::fmt::Debug for JsonLogger {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("JsonLogger")
            .field("enabled", &self.is_enabled())
            .finish()
    }
}

impl Default for JsonLogger {
    fn default() -> Self {
        Self::disabled()
    }
}

impl JsonLogger {
    /// A logger that drops every event (the default for embedded use).
    pub fn disabled() -> Self {
        JsonLogger { sink: None }
    }

    /// A logger writing JSON lines to stderr (`serve --log-json`).
    pub fn stderr() -> Self {
        JsonLogger {
            sink: Some(Mutex::new(Box::new(std::io::stderr()))),
        }
    }

    /// A logger writing to an arbitrary sink (tests).
    pub fn to_writer(w: Box<dyn Write + Send>) -> Self {
        JsonLogger {
            sink: Some(Mutex::new(w)),
        }
    }

    pub fn is_enabled(&self) -> bool {
        self.sink.is_some()
    }

    /// Emit one event line. Logging failures (closed pipe, poisoned
    /// mutex) are swallowed: observability must never take the serving
    /// plane down.
    pub fn event(&self, name: &str, fields: &[(&str, Value)]) {
        let Some(sink) = &self.sink else {
            return;
        };
        let line = render_event(unix_secs(), name, fields);
        if let Ok(mut w) = sink.lock() {
            let _ = w.write_all(line.as_bytes());
            let _ = w.flush();
        }
    }
}

fn unix_secs() -> u64 {
    SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0)
}

/// Render one event as a single JSON line (trailing `\n`).
pub fn render_event(ts: u64, name: &str, fields: &[(&str, Value)]) -> String {
    let mut out = String::with_capacity(64);
    out.push_str(&format!("{{\"ts\":{ts},\"event\":\"{}\"", escape(name)));
    for (key, value) in fields {
        out.push_str(&format!(",\"{}\":", escape(key)));
        match value {
            Value::U64(v) => out.push_str(&v.to_string()),
            Value::I64(v) => out.push_str(&v.to_string()),
            Value::F64(v) => {
                if v.is_finite() {
                    out.push_str(&format!("{v}"));
                } else {
                    // JSON has no NaN/Inf; stringify to stay parseable.
                    out.push_str(&format!("\"{v}\""));
                }
            }
            Value::Str(v) => out.push_str(&format!("\"{}\"", escape(v))),
            Value::Bool(v) => out.push_str(if *v { "true" } else { "false" }),
        }
    }
    out.push_str("}\n");
    out
}

/// JSON string escaping (shared with the `/healthz` body builder).
pub(crate) fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::{Arc, Mutex as StdMutex};

    /// Shared Vec<u8> sink for asserting on emitted lines.
    #[derive(Clone, Default)]
    struct Buf(Arc<StdMutex<Vec<u8>>>);

    impl Write for Buf {
        fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
            self.0.lock().unwrap().extend_from_slice(buf);
            Ok(buf.len())
        }
        fn flush(&mut self) -> std::io::Result<()> {
            Ok(())
        }
    }

    #[test]
    fn disabled_logger_emits_nothing_and_reports_disabled() {
        let log = JsonLogger::disabled();
        assert!(!log.is_enabled());
        log.event("connect", &[("conn", Value::U64(1))]);
    }

    #[test]
    fn events_are_one_json_object_per_line() {
        let buf = Buf::default();
        let log = JsonLogger::to_writer(Box::new(buf.clone()));
        assert!(log.is_enabled());
        log.event("connect", &[("conn", Value::U64(7)), ("peer", Value::from("1.2.3.4:5"))]);
        log.event("disconnect", &[("conn", Value::U64(7)), ("ok", Value::Bool(true))]);
        let bytes = buf.0.lock().unwrap().clone();
        let text = String::from_utf8(bytes).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        assert!(lines[0].starts_with("{\"ts\":"));
        assert!(lines[0].contains("\"event\":\"connect\""));
        assert!(lines[0].contains("\"conn\":7"));
        assert!(lines[0].contains("\"peer\":\"1.2.3.4:5\""));
        assert!(lines[1].contains("\"ok\":true"));
        assert!(lines.iter().all(|l| l.ends_with('}')));
    }

    #[test]
    fn concurrent_writers_never_interleave_lines() {
        // N threads × M events into one shared sink must come out as
        // exactly N×M well-formed JSON lines — `event` writes the whole
        // rendered line under the sink mutex, so no interleaving, no
        // torn lines, no lost events.
        const N_THREADS: usize = 8;
        const M_EVENTS: usize = 50;
        let buf = Buf::default();
        let log = Arc::new(JsonLogger::to_writer(Box::new(buf.clone())));
        let mut handles = Vec::new();
        for t in 0..N_THREADS {
            let log = Arc::clone(&log);
            handles.push(std::thread::spawn(move || {
                for i in 0..M_EVENTS {
                    log.event(
                        "job_progress",
                        &[
                            ("thread", Value::U64(t as u64)),
                            ("i", Value::U64(i as u64)),
                            ("msg", Value::from("chunk \"done\"\nnext")),
                        ],
                    );
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let bytes = buf.0.lock().unwrap().clone();
        let text = String::from_utf8(bytes).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), N_THREADS * M_EVENTS);
        let mut seen = vec![0usize; N_THREADS];
        for line in &lines {
            assert!(line.starts_with("{\"ts\":"), "torn line: {line:?}");
            assert!(line.ends_with('}'), "torn line: {line:?}");
            assert!(line.contains("\"event\":\"job_progress\""));
            // Balanced quoting is a cheap well-formedness proxy: every
            // line must contain an even number of unescaped quotes.
            let unescaped_quotes = line
                .as_bytes()
                .windows(2)
                .filter(|w| w[1] == b'"' && w[0] != b'\\')
                .count()
                + usize::from(line.starts_with('"'));
            assert_eq!(unescaped_quotes % 2, 0, "unbalanced quotes: {line:?}");
            let t_field = line
                .split("\"thread\":")
                .nth(1)
                .and_then(|r| r.split(',').next())
                .and_then(|v| v.parse::<usize>().ok())
                .unwrap_or_else(|| panic!("missing thread field: {line:?}"));
            seen[t_field] += 1;
        }
        assert!(seen.iter().all(|&c| c == M_EVENTS), "per-thread counts: {seen:?}");
    }

    #[test]
    fn strings_are_json_escaped() {
        let line = render_event(
            1,
            "error",
            &[("msg", Value::from("quote \" slash \\ nl \n tab \t"))],
        );
        assert!(line.contains("\\\""));
        assert!(line.contains("\\\\"));
        assert!(line.contains("\\n"));
        assert!(line.contains("\\t"));
        assert!(!line[..line.len() - 1].contains('\n'));
    }

    #[test]
    fn non_finite_f64_is_stringified_not_bare() {
        let line = render_event(0, "x", &[("v", Value::F64(f64::NAN))]);
        assert!(line.contains("\"v\":\"NaN\""));
        let line = render_event(0, "x", &[("v", Value::F64(2.5))]);
        assert!(line.contains("\"v\":2.5"));
    }
}
