//! Prometheus text exposition rendering (version 0.0.4 of the format).
//!
//! Std-only builder: each metric family gets exactly one `# TYPE` line,
//! histograms are emitted as cumulative `_bucket{le="..."}` series plus
//! `_sum`/`_count`, and label values are escaped per the exposition
//! format. [`validate_exposition`] is a minimal parser used by tests to
//! assert output well-formedness (unique family names, `# TYPE` lines,
//! parseable samples).

/// Incremental builder for one exposition document.
#[derive(Debug, Default)]
pub struct PromText {
    out: String,
    families: Vec<String>,
}

impl PromText {
    pub fn new() -> Self {
        Self::default()
    }

    /// Declare a metric family (`kind` is `counter`, `gauge`, or
    /// `histogram`). Each family must be declared exactly once, before
    /// its samples.
    pub fn family(&mut self, name: &str, kind: &str) {
        debug_assert!(
            !self.families.iter().any(|f| f == name),
            "duplicate metric family {name}"
        );
        self.families.push(name.to_string());
        self.out.push_str(&format!("# TYPE {name} {kind}\n"));
    }

    /// Emit one sample. `labels` may be empty.
    pub fn sample(&mut self, name: &str, labels: &[(&str, &str)], value: f64) {
        self.out.push_str(name);
        self.push_labels(labels);
        self.out.push(' ');
        self.out.push_str(&fmt_value(value));
        self.out.push('\n');
    }

    /// Declare + emit a label-less counter in one call.
    pub fn counter(&mut self, name: &str, value: u64) {
        self.family(name, "counter");
        self.sample(name, &[], value as f64);
    }

    /// Declare + emit a label-less gauge in one call.
    pub fn gauge(&mut self, name: &str, value: f64) {
        self.family(name, "gauge");
        self.sample(name, &[], value);
    }

    /// Emit one histogram series under an already-declared family.
    ///
    /// `buckets` are `(upper_bound_us, count)` pairs with *per-bucket*
    /// counts (the repo's internal shape); this renders the cumulative
    /// `_bucket` ladder the format requires, mapping the `u64::MAX`
    /// sentinel to `+Inf`. `sum` is the observed-value total.
    pub fn histogram_series(
        &mut self,
        name: &str,
        labels: &[(&str, &str)],
        buckets: &[(u64, u64)],
        sum: f64,
    ) {
        let mut acc: u64 = 0;
        for &(ub, count) in buckets {
            acc = acc.saturating_add(count);
            let le = if ub == u64::MAX {
                "+Inf".to_string()
            } else {
                ub.to_string()
            };
            let mut all: Vec<(&str, &str)> = labels.to_vec();
            all.push(("le", &le));
            self.sample(&format!("{name}_bucket"), &all, acc as f64);
        }
        self.sample(&format!("{name}_sum"), labels, sum);
        self.sample(&format!("{name}_count"), labels, acc as f64);
    }

    pub fn finish(self) -> String {
        self.out
    }

    fn push_labels(&mut self, labels: &[(&str, &str)]) {
        if labels.is_empty() {
            return;
        }
        self.out.push('{');
        for (i, (k, v)) in labels.iter().enumerate() {
            if i > 0 {
                self.out.push(',');
            }
            self.out.push_str(&format!("{k}=\"{}\"", escape_label(v)));
        }
        self.out.push('}');
    }
}

fn fmt_value(v: f64) -> String {
    if v == v.trunc() && v.is_finite() && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

fn escape_label(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out
}

/// Minimal exposition-format checker used by tests and the `--verify`
/// style assertions: every sample line must parse, every metric family
/// must have exactly one `# TYPE` line, and every sample must belong to
/// a declared family (histogram suffixes `_bucket`/`_sum`/`_count`
/// resolve to their base family). Returns the number of sample lines.
pub fn validate_exposition(text: &str) -> Result<usize, String> {
    let mut families: Vec<String> = Vec::new();
    let mut samples = 0usize;
    for (lineno, line) in text.lines().enumerate() {
        let n = lineno + 1;
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("# TYPE ") {
            let mut parts = rest.split_whitespace();
            let name = parts.next().ok_or(format!("line {n}: TYPE without name"))?;
            let kind = parts.next().ok_or(format!("line {n}: TYPE without kind"))?;
            if !matches!(kind, "counter" | "gauge" | "histogram" | "summary" | "untyped") {
                return Err(format!("line {n}: unknown metric kind `{kind}`"));
            }
            if families.iter().any(|f| f == name) {
                return Err(format!("line {n}: duplicate # TYPE for `{name}`"));
            }
            if !valid_metric_name(name) {
                return Err(format!("line {n}: invalid metric name `{name}`"));
            }
            families.push(name.to_string());
            continue;
        }
        if line.starts_with('#') {
            continue; // HELP or comment
        }
        let name_end = line
            .find(|c: char| c == '{' || c == ' ')
            .ok_or(format!("line {n}: sample without value"))?;
        let name = &line[..name_end];
        if !valid_metric_name(name) {
            return Err(format!("line {n}: invalid sample name `{name}`"));
        }
        let rest = &line[name_end..];
        let value_part = if let Some(stripped) = rest.strip_prefix('{') {
            let close = stripped.find('}').ok_or(format!("line {n}: unclosed label set"))?;
            &stripped[close + 1..]
        } else {
            rest
        };
        let value = value_part.trim();
        if value.parse::<f64>().is_err() && value != "+Inf" && value != "-Inf" {
            return Err(format!("line {n}: unparseable value `{value}`"));
        }
        let base = name
            .strip_suffix("_bucket")
            .or_else(|| name.strip_suffix("_sum"))
            .or_else(|| name.strip_suffix("_count"))
            .filter(|b| families.iter().any(|f| f == b))
            .unwrap_or(name);
        if !families.iter().any(|f| f == base) {
            return Err(format!("line {n}: sample `{name}` has no # TYPE line"));
        }
        samples += 1;
    }
    Ok(samples)
}

fn valid_metric_name(name: &str) -> bool {
    let mut chars = name.chars();
    match chars.next() {
        Some(c) if c.is_ascii_alphabetic() || c == '_' || c == ':' => {}
        _ => return false,
    }
    chars.all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':')
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_gauges_and_histograms_render_and_validate() {
        let mut p = PromText::new();
        p.counter("pqdtw_requests_total", 12);
        p.gauge("pqdtw_uptime_seconds", 3.5);
        p.family("pqdtw_request_latency_microseconds", "histogram");
        p.histogram_series(
            "pqdtw_request_latency_microseconds",
            &[("class", "top_k")],
            &[(10, 2), (100, 3), (u64::MAX, 1)],
            420.0,
        );
        let text = p.finish();
        assert!(text.contains("# TYPE pqdtw_requests_total counter\n"));
        assert!(text.contains("pqdtw_requests_total 12\n"));
        assert!(text.contains("le=\"10\"} 2\n"));
        assert!(text.contains("le=\"100\"} 5\n"));
        assert!(text.contains("le=\"+Inf\"} 6\n"));
        assert!(text.contains("pqdtw_request_latency_microseconds_count{class=\"top_k\"} 6\n"));
        assert!(text.contains("pqdtw_request_latency_microseconds_sum{class=\"top_k\"} 420\n"));
        let samples = validate_exposition(&text).expect("valid exposition");
        assert_eq!(samples, 2 + 5);
    }

    #[test]
    fn label_values_are_escaped() {
        let mut p = PromText::new();
        p.family("m", "gauge");
        p.sample("m", &[("l", "a\"b\\c\nd")], 1.0);
        let text = p.finish();
        assert!(text.contains("l=\"a\\\"b\\\\c\\nd\""));
        validate_exposition(&text).expect("escaped labels still validate");
    }

    #[test]
    fn hostile_label_values_round_trip_through_the_validator() {
        // Every hostile value must (a) escape to something the
        // validator accepts as a single sample line, and (b) unescape
        // back to the original bytes — i.e. escaping is lossless.
        for v in [
            "plain",
            "new\nline",
            "quo\"te",
            "back\\slash",
            "\\n already escaped-looking",
            "mix \\\"\n end",
            "",
        ] {
            let escaped = escape_label(v);
            let mut back = String::new();
            let mut chars = escaped.chars();
            while let Some(c) = chars.next() {
                if c == '\\' {
                    match chars.next() {
                        Some('\\') => back.push('\\'),
                        Some('"') => back.push('"'),
                        Some('n') => back.push('\n'),
                        other => panic!("stray escape \\{other:?} in {escaped:?}"),
                    }
                } else {
                    back.push(c);
                }
            }
            assert_eq!(back, v, "escape must round-trip losslessly");
            let mut p = PromText::new();
            p.family("m", "gauge");
            p.sample("m", &[("l", v)], 1.0);
            let text = p.finish();
            assert_eq!(
                text.lines().count(),
                2,
                "an escaped newline must not split the sample line: {text:?}"
            );
            let samples = validate_exposition(&text)
                .unwrap_or_else(|e| panic!("value {v:?} broke the exposition: {e}"));
            assert_eq!(samples, 1);
        }
    }

    #[test]
    fn zero_observation_histogram_stays_parseable() {
        let mut p = PromText::new();
        p.family("h", "histogram");
        p.histogram_series("h", &[("kind", "x")], &[(10, 0), (100, 0), (u64::MAX, 0)], 0.0);
        let text = p.finish();
        let samples = validate_exposition(&text).expect("zero-observation histogram");
        assert_eq!(samples, 5);
        assert!(text.contains("h_bucket{kind=\"x\",le=\"+Inf\"} 0\n"));
        assert!(text.contains("h_sum{kind=\"x\"} 0\n"));
        assert!(text.contains("h_count{kind=\"x\"} 0\n"));
    }

    #[test]
    fn all_overflow_bucket_histogram_stays_parseable_and_cumulative() {
        // Every observation past the last finite bound: the finite
        // ladder stays at zero and only +Inf (and _count) move.
        let mut p = PromText::new();
        p.family("h", "histogram");
        p.histogram_series("h", &[], &[(10, 0), (100, 0), (u64::MAX, 7)], 9e9);
        let text = p.finish();
        validate_exposition(&text).expect("all-overflow histogram");
        assert!(text.contains("h_bucket{le=\"10\"} 0\n"));
        assert!(text.contains("h_bucket{le=\"100\"} 0\n"));
        assert!(text.contains("h_bucket{le=\"+Inf\"} 7\n"));
        assert!(text.contains("h_count 7\n"));
        assert!(text.contains("h_sum 9000000000\n"));
    }

    #[test]
    fn validator_rejects_duplicate_families_and_untyped_samples() {
        assert!(validate_exposition("# TYPE a counter\n# TYPE a counter\na 1\n").is_err());
        assert!(validate_exposition("orphan_metric 3\n").is_err());
        assert!(validate_exposition("# TYPE a counter\na notanumber\n").is_err());
        assert!(validate_exposition("# TYPE a counter\na 1\n").is_ok());
    }

    #[test]
    fn integral_values_render_without_fraction() {
        assert_eq!(fmt_value(5.0), "5");
        assert_eq!(fmt_value(2.5), "2.5");
        assert_eq!(fmt_value(0.0), "0");
    }
}
