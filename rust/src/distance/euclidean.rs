//! Euclidean (lock-step) distance.

/// Squared Euclidean distance between equal-length slices.
#[inline]
pub fn euclidean_sq(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut s = 0.0;
    for i in 0..a.len() {
        let d = a[i] - b[i];
        s += d * d;
    }
    s
}

/// Euclidean distance.
#[inline]
pub fn euclidean(a: &[f64], b: &[f64]) -> f64 {
    euclidean_sq(a, b).sqrt()
}

/// Early-abandoning squared Euclidean distance: returns `f64::INFINITY`
/// as soon as the running sum exceeds `ub_sq`. Used by 1-NN search.
#[inline]
pub fn euclidean_ea_sq(a: &[f64], b: &[f64], ub_sq: f64) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut s = 0.0;
    // Check every 8 terms: cheap enough to matter, rare enough not to.
    for (ca, cb) in a.chunks(8).zip(b.chunks(8)) {
        for i in 0..ca.len() {
            let d = ca[i] - cb[i];
            s += d * d;
        }
        if s > ub_sq {
            return f64::INFINITY;
        }
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic() {
        assert_eq!(euclidean_sq(&[0.0, 0.0], &[3.0, 4.0]), 25.0);
        assert_eq!(euclidean(&[0.0, 0.0], &[3.0, 4.0]), 5.0);
    }

    #[test]
    fn zero_on_identical() {
        let v = [1.0, -2.0, 3.5];
        assert_eq!(euclidean(&v, &v), 0.0);
    }

    #[test]
    fn early_abandon_triggers() {
        let a = vec![0.0; 100];
        let b = vec![1.0; 100];
        assert!(euclidean_ea_sq(&a, &b, 10.0).is_infinite());
        assert_eq!(euclidean_ea_sq(&a, &b, 1000.0), 100.0);
    }

    #[test]
    fn early_abandon_equals_exact_when_under_bound() {
        let a = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0, 9.0];
        let b = [2.0, 1.0, 4.0, 3.0, 6.0, 5.0, 8.0, 7.0, 9.5];
        assert_eq!(euclidean_ea_sq(&a, &b, 1e9), euclidean_sq(&a, &b));
    }
}
