//! Shape-Based Distance (Paparrizos & Gravano, k-Shape, SIGMOD 2015).
//!
//! `SBD(x, y) = 1 - max_w NCCc_w(x, y)` where `NCCc` is the coefficient-
//! normalized cross-correlation. SBD is shift-invariant, lies in `[0, 2]`,
//! and is the paper's strongest non-elastic baseline. Cross-correlation is
//! evaluated with the FFT in `O(n log n)`.

use super::fft::cross_correlate;

/// Shape-based distance between `x` and `y`, in `[0, 2]`.
pub fn sbd(x: &[f64], y: &[f64]) -> f64 {
    if x.is_empty() || y.is_empty() {
        return if x.len() == y.len() { 0.0 } else { 2.0 };
    }
    let nx = x.iter().map(|v| v * v).sum::<f64>().sqrt();
    let ny = y.iter().map(|v| v * v).sum::<f64>().sqrt();
    let denom = nx * ny;
    if denom < 1e-12 {
        // One of the series is all-zero: correlation undefined; by k-Shape
        // convention the distance is 1 (no similarity information).
        return 1.0;
    }
    let cc = cross_correlate(x, y);
    let max_cc = cc.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    1.0 - max_cc / denom
}

/// SBD together with the maximizing shift (for alignment uses). The shift
/// is how far `y` must be moved right to best match `x`.
pub fn sbd_with_shift(x: &[f64], y: &[f64]) -> (f64, isize) {
    let nx = x.iter().map(|v| v * v).sum::<f64>().sqrt();
    let ny = y.iter().map(|v| v * v).sum::<f64>().sqrt();
    let denom = nx * ny;
    if denom < 1e-12 {
        return (1.0, 0);
    }
    let cc = cross_correlate(x, y);
    let m = y.len();
    let (mut best, mut best_idx) = (f64::NEG_INFINITY, 0usize);
    for (i, &v) in cc.iter().enumerate() {
        if v > best {
            best = v;
            best_idx = i;
        }
    }
    (1.0 - best / denom, best_idx as isize - (m as isize - 1))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::preprocess::znorm;
    use crate::core::rng::Rng;

    #[test]
    fn zero_on_identical() {
        let x = znorm(&[1.0, 3.0, 2.0, 5.0, 4.0, 1.0, 0.0, 2.0]);
        let d = sbd(&x, &x);
        assert!(d.abs() < 1e-9, "d={d}");
    }

    #[test]
    fn range_bounds() {
        let mut rng = Rng::new(61);
        for _ in 0..40 {
            let x: Vec<f64> = (0..32).map(|_| rng.normal()).collect();
            let y: Vec<f64> = (0..32).map(|_| rng.normal()).collect();
            let d = sbd(&x, &y);
            assert!((-1e-9..=2.0 + 1e-9).contains(&d), "d={d}");
        }
    }

    #[test]
    fn shift_invariance() {
        // A circularly-shifted copy padded with ~0 should give a near-zero
        // distance thanks to the maximizing shift.
        let base: Vec<f64> = (0..64).map(|i| ((i as f64) * 0.4).sin()).collect();
        let mut shifted = vec![0.0; 5];
        shifted.extend_from_slice(&base[..59]);
        let d = sbd(&base, &shifted);
        assert!(d < 0.05, "d={d}");
        let (_, shift) = sbd_with_shift(&base, &shifted);
        assert_eq!(shift, -5);
    }

    #[test]
    fn anticorrelated_near_two() {
        let x: Vec<f64> = (0..32).map(|i| ((i as f64) * 0.3).sin()).collect();
        let y: Vec<f64> = x.iter().map(|v| -v).collect();
        let d = sbd(&x, &y);
        // Maximum correlation of a sine with its negation over all shifts
        // is achieved at a half-period offset; distance stays well above 0.
        assert!(d > 0.1, "d={d}");
    }

    #[test]
    fn zero_series_convention() {
        assert_eq!(sbd(&[0.0; 8], &[1.0; 8]), 1.0);
    }

    #[test]
    fn symmetric() {
        let mut rng = Rng::new(67);
        for _ in 0..20 {
            let x: Vec<f64> = (0..24).map(|_| rng.normal()).collect();
            let y: Vec<f64> = (0..24).map(|_| rng.normal()).collect();
            assert!((sbd(&x, &y) - sbd(&y, &x)).abs() < 1e-9);
        }
    }
}
