//! Keogh warping envelopes (upper/lower running min/max over the warping
//! window), computed in O(n) with Lemire's streaming min/max (monotonic
//! deques) rather than the naive O(n·w) scan.
//!
//! For a series `c` and window `w`, `U[i] = max(c[i-w ..= i+w])` and
//! `L[i] = min(c[i-w ..= i+w])`. Any series `q` aligned to `c` under a
//! Sakoe-Chiba band of half-width `w` satisfies `L[i] <= (aligned value)
//! <= U[i]`, which is what makes LB_Keogh a valid lower bound.

use std::collections::VecDeque;

/// Upper and lower Keogh envelope of a series.
#[derive(Debug, Clone, PartialEq)]
pub struct Envelope {
    /// Pointwise upper envelope `U`.
    pub upper: Vec<f64>,
    /// Pointwise lower envelope `L`.
    pub lower: Vec<f64>,
}

impl Envelope {
    /// Compute the envelope of `c` for warping window `w` (half-width in
    /// samples). `w >= len` degrades gracefully to global min/max.
    pub fn new(c: &[f64], w: usize) -> Self {
        let n = c.len();
        let mut upper = vec![0.0; n];
        let mut lower = vec![0.0; n];
        if n == 0 {
            return Envelope { upper, lower };
        }
        // Monotonic deques over the sliding window [i-w, i+w].
        let mut maxq: VecDeque<usize> = VecDeque::new();
        let mut minq: VecDeque<usize> = VecDeque::new();
        // Window for position i covers indices [i-w, min(i+w, n-1)].
        // Sweep the right edge r = 0..n+w; emit position i = r - w.
        for r in 0..(n + w) {
            if r < n {
                while let Some(&b) = maxq.back() {
                    if c[b] <= c[r] {
                        maxq.pop_back();
                    } else {
                        break;
                    }
                }
                maxq.push_back(r);
                while let Some(&b) = minq.back() {
                    if c[b] >= c[r] {
                        minq.pop_back();
                    } else {
                        break;
                    }
                }
                minq.push_back(r);
            }
            if r >= w {
                let i = r - w;
                if i >= n {
                    break;
                }
                // Evict entries left of the window start i-w.
                let start = i.saturating_sub(w);
                while let Some(&f) = maxq.front() {
                    if f < start {
                        maxq.pop_front();
                    } else {
                        break;
                    }
                }
                while let Some(&f) = minq.front() {
                    if f < start {
                        minq.pop_front();
                    } else {
                        break;
                    }
                }
                upper[i] = c[*maxq.front().unwrap()];
                lower[i] = c[*minq.front().unwrap()];
            }
        }
        Envelope { upper, lower }
    }

    /// Series length.
    pub fn len(&self) -> usize {
        self.upper.len()
    }

    /// True when empty.
    pub fn is_empty(&self) -> bool {
        self.upper.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Naive O(n·w) reference.
    fn naive(c: &[f64], w: usize) -> Envelope {
        let n = c.len();
        let mut upper = vec![f64::NEG_INFINITY; n];
        let mut lower = vec![f64::INFINITY; n];
        for i in 0..n {
            let lo = i.saturating_sub(w);
            let hi = (i + w).min(n - 1);
            for j in lo..=hi {
                if c[j] > upper[i] {
                    upper[i] = c[j];
                }
                if c[j] < lower[i] {
                    lower[i] = c[j];
                }
            }
        }
        Envelope { upper, lower }
    }

    #[test]
    fn matches_naive_reference() {
        let c: Vec<f64> = (0..64)
            .map(|i| ((i as f64) * 0.3).sin() * 2.0 + ((i * 7 % 13) as f64) * 0.1)
            .collect();
        for w in [0, 1, 2, 5, 10, 63, 100] {
            assert_eq!(Envelope::new(&c, w), naive(&c, w), "w={w}");
        }
    }

    #[test]
    fn zero_window_is_identity() {
        let c = [3.0, -1.0, 2.0];
        let e = Envelope::new(&c, 0);
        assert_eq!(e.upper, c.to_vec());
        assert_eq!(e.lower, c.to_vec());
    }

    #[test]
    fn envelope_bounds_series() {
        let c: Vec<f64> = (0..40).map(|i| ((i as f64) * 0.7).cos()).collect();
        for w in [1, 3, 8] {
            let e = Envelope::new(&c, w);
            for i in 0..c.len() {
                assert!(e.lower[i] <= c[i] && c[i] <= e.upper[i]);
            }
        }
    }

    #[test]
    fn huge_window_is_global_extrema() {
        let c = [1.0, 9.0, -4.0, 5.0];
        let e = Envelope::new(&c, 100);
        assert!(e.upper.iter().all(|&u| u == 9.0));
        assert!(e.lower.iter().all(|&l| l == -4.0));
    }

    #[test]
    fn empty_series() {
        let e = Envelope::new(&[], 3);
        assert!(e.is_empty());
    }
}
