//! Unified measure abstraction used by the 1-NN / clustering harnesses and
//! the benchmark drivers. Mirrors the paper's baseline set: ED, DTW
//! (PrunedDTW under the hood), cDTW with a window fraction, and SBD. SAX
//! and the PQ variants are representation-based and therefore live behind
//! their own precomputed-representation paths (`repr::sax`, `pq`), but are
//! addressable through [`Measure`] for naming/reporting.

use super::dtw::dtw_sq;
use super::euclidean::euclidean;
use super::pruned_dtw::pruned_dtw_sq;
use super::sbd::sbd;

/// A pairwise time-series distance measure.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Measure {
    /// Lock-step Euclidean distance.
    Euclidean,
    /// Unconstrained DTW (computed with PrunedDTW using the Euclidean
    /// upper bound, per the paper's experimental settings).
    Dtw,
    /// Sakoe-Chiba-constrained DTW; `window_frac` is the half-width as a
    /// fraction of series length (e.g. 0.05 for cDTW5).
    CDtw { window_frac: f64 },
    /// Shape-based distance (k-Shape).
    Sbd,
    /// SAX MINDIST (requires representation precomputation; `dist` on raw
    /// series converts on the fly — used only in tests).
    Sax { alphabet: usize, seg_frac: f64 },
}

impl Measure {
    /// Resolve the warping window (samples) for series of length `len`.
    /// `None` for measures without a window.
    pub fn window(&self, len: usize) -> Option<usize> {
        match self {
            Measure::CDtw { window_frac } => {
                Some(((window_frac * len as f64).ceil() as usize).max(1))
            }
            _ => None,
        }
    }

    /// Distance between two raw series.
    pub fn dist(&self, a: &[f64], b: &[f64]) -> f64 {
        match self {
            Measure::Euclidean => euclidean(a, b),
            Measure::Dtw => {
                // ED is a valid upper bound when lengths match; otherwise
                // run unpruned.
                let ub = if a.len() == b.len() {
                    super::euclidean::euclidean_sq(a, b)
                } else {
                    f64::INFINITY
                };
                let d = pruned_dtw_sq(a, b, None, ub + 1e-12);
                if d.is_finite() { d.sqrt() } else { ub.sqrt() }
            }
            Measure::CDtw { .. } => {
                let w = self.window(a.len().max(b.len()));
                dtw_sq(a, b, w).sqrt()
            }
            Measure::Sbd => sbd(a, b),
            Measure::Sax { alphabet, seg_frac } => {
                let sax = crate::repr::sax::SaxEncoder::new(a.len(), *alphabet, *seg_frac);
                let wa = sax.encode(a);
                let wb = sax.encode(b);
                sax.mindist(&wa, &wb)
            }
        }
    }

    /// Short display name matching the paper's tables.
    pub fn name(&self) -> String {
        match self {
            Measure::Euclidean => "ED".into(),
            Measure::Dtw => "DTW".into(),
            Measure::CDtw { window_frac } => format!("cDTW{}", (window_frac * 100.0).round()),
            Measure::Sbd => "SBD".into(),
            Measure::Sax { .. } => "SAX".into(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::rng::Rng;

    #[test]
    fn dtw_variant_consistency() {
        let mut rng = Rng::new(71);
        for _ in 0..20 {
            let a: Vec<f64> = (0..40).map(|_| rng.normal()).collect();
            let b: Vec<f64> = (0..40).map(|_| rng.normal()).collect();
            let full = Measure::Dtw.dist(&a, &b);
            let exact = super::super::dtw::dtw(&a, &b, None);
            assert!((full - exact).abs() < 1e-9);
            // cDTW with full-width window == DTW
            let cw = Measure::CDtw { window_frac: 1.0 }.dist(&a, &b);
            assert!((cw - exact).abs() < 1e-9);
        }
    }

    #[test]
    fn ordering_ed_ge_cdtw_ge_dtw() {
        let mut rng = Rng::new(73);
        for _ in 0..20 {
            let a: Vec<f64> = (0..50).map(|_| rng.normal()).collect();
            let b: Vec<f64> = (0..50).map(|_| rng.normal()).collect();
            let ed = Measure::Euclidean.dist(&a, &b);
            let c5 = Measure::CDtw { window_frac: 0.05 }.dist(&a, &b);
            let c10 = Measure::CDtw { window_frac: 0.10 }.dist(&a, &b);
            let dtw = Measure::Dtw.dist(&a, &b);
            assert!(ed + 1e-9 >= c5, "ed={ed} c5={c5}");
            assert!(c5 + 1e-9 >= c10);
            assert!(c10 + 1e-9 >= dtw);
        }
    }

    #[test]
    fn window_resolution() {
        assert_eq!(Measure::CDtw { window_frac: 0.05 }.window(100), Some(5));
        assert_eq!(Measure::CDtw { window_frac: 0.10 }.window(140), Some(14));
        assert_eq!(Measure::Euclidean.window(100), None);
        // tiny lengths round up to at least 1
        assert_eq!(Measure::CDtw { window_frac: 0.05 }.window(4), Some(1));
    }

    #[test]
    fn names_match_paper() {
        assert_eq!(Measure::CDtw { window_frac: 0.05 }.name(), "cDTW5");
        assert_eq!(Measure::CDtw { window_frac: 0.10 }.name(), "cDTW10");
        assert_eq!(Measure::Dtw.name(), "DTW");
    }
}
