//! DTW lower bounds: LB_Kim (constant time), LB_Keogh (linear time) and
//! the cascade used by the PQDTW encoder (paper §3.2).
//!
//! All bounds here are expressed in **squared** units so they compare
//! directly against `dtw_sq` / a squared best-so-far without taking roots
//! in the hot loop.
//!
//! The PQDTW encoder *reverses* the query/data roles (Rakthanmanon et al.
//! 2012): envelopes are built once around the **codebook centroids** at
//! training time, and at encode time the bound is computed by walking the
//! query against the candidate centroid's precomputed envelope. That makes
//! the per-encode cost O(D/M) with no envelope construction per query.

use super::envelope::Envelope;

/// LB_Kim (the constant-time *FL* variant used by the UCR suite): squared
/// distance between first points plus squared distance between last
/// points. Valid because any warping path must match the two endpoints.
#[inline]
pub fn lb_kim_sq(q: &[f64], c: &[f64]) -> f64 {
    if q.is_empty() || c.is_empty() {
        return 0.0;
    }
    let df = q[0] - c[0];
    let dl = q[q.len() - 1] - c[c.len() - 1];
    df * df + dl * dl
}

/// LB_Keogh: squared exceedance of `q` outside the envelope `env`
/// (built from the *candidate* series with the same warping window).
///
/// Early-abandons against `ub_sq`: returns `f64::INFINITY` once the
/// partial sum exceeds it.
#[inline]
pub fn lb_keogh_sq(q: &[f64], env: &Envelope, ub_sq: f64) -> f64 {
    debug_assert_eq!(q.len(), env.len());
    let mut s = 0.0;
    for i in 0..q.len() {
        let x = q[i];
        let u = env.upper[i];
        let l = env.lower[i];
        if x > u {
            let d = x - u;
            s += d * d;
        } else if x < l {
            let d = l - x;
            s += d * d;
        }
        if s > ub_sq {
            return f64::INFINITY;
        }
    }
    s
}

/// Cascading lower bound used by the PQDTW encoder: LB_Kim first (O(1)),
/// then reversed LB_Keogh (O(n)) only when LB_Kim did not already prune.
/// Returns a squared lower bound on `dtw_sq(q, c, window)`, or
/// `f64::INFINITY` when the bound exceeds `ub_sq` (candidate prunable).
#[inline]
pub fn lb_cascade_sq(q: &[f64], c: &[f64], env: &Envelope, ub_sq: f64) -> f64 {
    let kim = lb_kim_sq(q, c);
    if kim > ub_sq {
        return f64::INFINITY;
    }
    // The reversed Keogh bound (query walked against candidate envelope)
    // dominates Kim on everything except the endpoints; take the max so
    // the cascade is at least as tight as its parts.
    let keogh = lb_keogh_sq(q, env, ub_sq);
    kim.max(keogh)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::rng::Rng;
    use crate::distance::dtw::dtw_sq;

    fn rand_series(rng: &mut Rng, n: usize) -> Vec<f64> {
        // Random walk: adjacent-sample correlation makes bounds non-trivial.
        let mut v = Vec::with_capacity(n);
        let mut x = 0.0;
        for _ in 0..n {
            x += rng.normal();
            v.push(x);
        }
        v
    }

    #[test]
    fn lb_kim_is_lower_bound() {
        let mut rng = Rng::new(17);
        for _ in 0..50 {
            let q = rand_series(&mut rng, 30);
            let c = rand_series(&mut rng, 30);
            for w in [0usize, 2, 5, 30] {
                let d = dtw_sq(&q, &c, Some(w));
                assert!(lb_kim_sq(&q, &c) <= d + 1e-9);
            }
        }
    }

    #[test]
    fn lb_keogh_is_lower_bound() {
        let mut rng = Rng::new(23);
        for _ in 0..50 {
            let q = rand_series(&mut rng, 40);
            let c = rand_series(&mut rng, 40);
            for w in [0usize, 1, 3, 8] {
                let env = Envelope::new(&c, w);
                let lb = lb_keogh_sq(&q, &env, f64::INFINITY);
                let d = dtw_sq(&q, &c, Some(w));
                assert!(lb <= d + 1e-9, "w={w} lb={lb} dtw={d}");
            }
        }
    }

    #[test]
    fn lb_cascade_is_lower_bound() {
        let mut rng = Rng::new(31);
        for _ in 0..50 {
            let q = rand_series(&mut rng, 25);
            let c = rand_series(&mut rng, 25);
            let w = 4;
            let env = Envelope::new(&c, w);
            let lb = lb_cascade_sq(&q, &c, &env, f64::INFINITY);
            assert!(lb <= dtw_sq(&q, &c, Some(w)) + 1e-9);
        }
    }

    #[test]
    fn keogh_zero_when_inside_envelope() {
        let c = [0.0, 1.0, 2.0, 1.0, 0.0];
        let env = Envelope::new(&c, 2);
        // A series within [L, U] everywhere gives bound 0.
        let q: Vec<f64> = env
            .lower
            .iter()
            .zip(env.upper.iter())
            .map(|(l, u)| 0.5 * (l + u))
            .collect();
        assert_eq!(lb_keogh_sq(&q, &env, f64::INFINITY), 0.0);
    }

    #[test]
    fn keogh_early_abandons() {
        let c = [0.0; 16];
        let env = Envelope::new(&c, 1);
        let q = [10.0; 16];
        assert!(lb_keogh_sq(&q, &env, 5.0).is_infinite());
    }

    #[test]
    fn cascade_prunes_on_kim() {
        // Endpoints far apart: Kim alone exceeds the bound.
        let q = [100.0, 0.0, 0.0, 0.0];
        let c = [0.0, 0.0, 0.0, 0.0];
        let env = Envelope::new(&c, 1);
        assert!(lb_cascade_sq(&q, &c, &env, 1.0).is_infinite());
    }
}
