//! Elastic and lock-step distance measures plus their acceleration
//! machinery (envelopes, lower bounds, pruning).
//!
//! Conventions (shared with the Python oracle `python/compile/kernels/ref.py`
//! and checked by the cross-language golden tests):
//!
//! - DTW accumulates **squared** pointwise costs, as in the paper's Eq. (1),
//!   and all public entry points return the **square root** of the
//!   accumulated cost, so DTW and the Euclidean distance coincide when the
//!   warping window is zero and every lower bound is directly comparable.
//! - A warping window `w` is the Sakoe-Chiba band half-width in *samples*;
//!   `None` means unconstrained.

pub mod dtw;
pub mod envelope;
pub mod euclidean;
pub mod fft;
pub mod lower_bounds;
pub mod measure;
pub mod pruned_dtw;
pub mod sbd;

pub use dtw::{dtw, dtw_ea, dtw_sq};
pub use envelope::Envelope;
pub use euclidean::{euclidean, euclidean_sq, euclidean_ea_sq};
pub use lower_bounds::{lb_cascade_sq, lb_keogh_sq, lb_kim_sq};
pub use measure::Measure;
pub use sbd::sbd;
