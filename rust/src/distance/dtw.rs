//! Dynamic Time Warping (Sakoe & Chiba 1978).
//!
//! Rolling two-row dynamic program with optional Sakoe-Chiba band and
//! optional early abandoning against an upper bound. This is the hot-path
//! reference implementation used everywhere in the library; the AOT
//! JAX/Pallas kernel (python/compile/kernels/dtw_band.py) implements the
//! same recurrence and is checked against this one by the golden tests.

/// Scratch buffers for the DTW dynamic program, reusable across calls to
/// avoid per-call allocation in hot loops (encoding, pairwise matrices).
#[derive(Debug, Default, Clone)]
pub struct DtwScratch {
    prev: Vec<f64>,
    curr: Vec<f64>,
}

impl DtwScratch {
    /// Scratch sized for series of length `n` (second argument of the DP).
    pub fn new(n: usize) -> Self {
        DtwScratch { prev: vec![0.0; n + 1], curr: vec![0.0; n + 1] }
    }

    fn ensure(&mut self, n: usize) {
        if self.prev.len() < n + 1 {
            self.prev.resize(n + 1, 0.0);
            self.curr.resize(n + 1, 0.0);
        }
    }
}

/// Accumulated **squared** DTW cost between `a` and `b` under a
/// Sakoe-Chiba band of half-width `window` (`None` = unconstrained).
///
/// Early abandoning: if `ub_sq` is finite and every cell of some row
/// exceeds it, returns `f64::INFINITY` immediately — the true cost is
/// then guaranteed to exceed `ub_sq`.
pub fn dtw_sq_scratch(
    a: &[f64],
    b: &[f64],
    window: Option<usize>,
    ub_sq: f64,
    scratch: &mut DtwScratch,
) -> f64 {
    let (n, m) = (a.len(), b.len());
    if n == 0 || m == 0 {
        return if n == m { 0.0 } else { f64::INFINITY };
    }
    // The band must be at least |n - m| wide for any path to exist.
    let w = match window {
        Some(w) => w.max(n.abs_diff(m)),
        None => n.max(m),
    };
    scratch.ensure(m);
    let prev = &mut scratch.prev;
    let curr = &mut scratch.curr;
    // One-time init: row 1 only ever reads prev[lo_1 - 1 ..= hi_1].
    prev[0] = 0.0;
    for j in 1..=m {
        prev[j] = f64::INFINITY;
    }
    // Banded rows write only their band plus two boundary sentinels
    // (O(1) per row instead of clearing the whole row): row i+1 reads
    // prev indices in [lo' - 1, hi'] ⊆ [lo - 1, hi + 1], all of which
    // this row writes (computed cells or the two sentinels).
    for i in 1..=n {
        // Band limits for row i (1-based DP indices over b).
        let lo = i.saturating_sub(w).max(1);
        let hi = (i + w).min(m);
        // Left boundary sentinel: the `left` read at j = lo.
        curr[lo - 1] = f64::INFINITY;
        let ai = a[i - 1];
        let mut row_min = f64::INFINITY;
        for j in lo..=hi {
            let d = ai - b[j - 1];
            let cost = d * d;
            // min of (i-1,j-1), (i-1,j), (i,j-1)
            let diag = prev[j - 1];
            let up = prev[j];
            let left = curr[j - 1];
            let mut best = diag;
            if up < best {
                best = up;
            }
            if left < best {
                best = left;
            }
            let v = cost + best;
            curr[j] = v;
            if v < row_min {
                row_min = v;
            }
        }
        // Right boundary sentinel: the next row's `up` read at hi + 1.
        if hi < m {
            curr[hi + 1] = f64::INFINITY;
        }
        if row_min > ub_sq {
            return f64::INFINITY;
        }
        std::mem::swap(prev, curr);
    }
    prev[m]
}

/// Accumulated squared DTW cost (allocating convenience wrapper).
pub fn dtw_sq(a: &[f64], b: &[f64], window: Option<usize>) -> f64 {
    let mut s = DtwScratch::new(b.len());
    dtw_sq_scratch(a, b, window, f64::INFINITY, &mut s)
}

/// DTW distance: `sqrt` of the accumulated squared cost.
pub fn dtw(a: &[f64], b: &[f64], window: Option<usize>) -> f64 {
    dtw_sq(a, b, window).sqrt()
}

/// Early-abandoning DTW distance against upper bound `ub` (same units as
/// the returned distance). Returns `f64::INFINITY` when the distance
/// provably exceeds `ub`.
pub fn dtw_ea(a: &[f64], b: &[f64], window: Option<usize>, ub: f64) -> f64 {
    let mut s = DtwScratch::new(b.len());
    dtw_sq_scratch(a, b, window, ub * ub, &mut s).sqrt()
}

/// Full DTW cost matrix (for tests and DBA alignment). Entry `[i][j]` is
/// the accumulated squared cost of aligning `a[..=i]` with `b[..=j]`.
pub fn dtw_matrix(a: &[f64], b: &[f64], window: Option<usize>) -> Vec<Vec<f64>> {
    let (n, m) = (a.len(), b.len());
    let w = match window {
        Some(w) => w.max(n.abs_diff(m)),
        None => n.max(m),
    };
    let mut dp = vec![vec![f64::INFINITY; m + 1]; n + 1];
    dp[0][0] = 0.0;
    for i in 1..=n {
        let lo = i.saturating_sub(w).max(1);
        let hi = (i + w).min(m);
        for j in lo..=hi {
            let d = a[i - 1] - b[j - 1];
            let best = dp[i - 1][j - 1].min(dp[i - 1][j]).min(dp[i][j - 1]);
            dp[i][j] = d * d + best;
        }
    }
    dp
}

/// Optimal warping path as `(i, j)` index pairs (0-based), computed by
/// backtracking the full cost matrix. Used by DBA.
pub fn dtw_path(a: &[f64], b: &[f64], window: Option<usize>) -> Vec<(usize, usize)> {
    let dp = dtw_matrix(a, b, window);
    let (mut i, mut j) = (a.len(), b.len());
    let mut path = Vec::with_capacity(a.len() + b.len());
    while i > 0 && j > 0 {
        path.push((i - 1, j - 1));
        // Move to the predecessor with minimal accumulated cost.
        let diag = dp[i - 1][j - 1];
        let up = dp[i - 1][j];
        let left = dp[i][j - 1];
        if diag <= up && diag <= left {
            i -= 1;
            j -= 1;
        } else if up <= left {
            i -= 1;
        } else {
            j -= 1;
        }
    }
    path.reverse();
    path
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::distance::euclidean::euclidean_sq;

    #[test]
    fn identical_series_zero() {
        let a = [1.0, 2.0, 3.0, 2.0, 1.0];
        assert_eq!(dtw(&a, &a, None), 0.0);
        assert_eq!(dtw(&a, &a, Some(1)), 0.0);
    }

    #[test]
    fn hand_checked_small_case() {
        // a=[0,1], b=[0,0,1]: optimal path aligns 0->{0,0}, 1->1, cost 0.
        assert_eq!(dtw_sq(&[0.0, 1.0], &[0.0, 0.0, 1.0], None), 0.0);
        // a=[0,1], b=[2,2]: best alignment cost = 4 + min(4+1,1,1+1) => DP:
        // dp(1,1)=4; dp(1,2)=4+4=8; dp(2,1)=1+4=5; dp(2,2)=1+min(4,8,5)=5.
        assert_eq!(dtw_sq(&[0.0, 1.0], &[2.0, 2.0], None), 5.0);
    }

    #[test]
    fn window_zero_equals_euclidean() {
        let a = [1.0, 3.0, 2.0, 5.0, 4.0];
        let b = [2.0, 2.0, 2.0, 4.0, 6.0];
        assert!((dtw_sq(&a, &b, Some(0)) - euclidean_sq(&a, &b)).abs() < 1e-12);
    }

    #[test]
    fn shifted_peak_cheaper_than_euclidean() {
        // DTW should absorb a phase shift the Euclidean distance cannot.
        let a: Vec<f64> = (0..32).map(|i| if i == 10 { 1.0 } else { 0.0 }).collect();
        let b: Vec<f64> = (0..32).map(|i| if i == 13 { 1.0 } else { 0.0 }).collect();
        assert!(dtw_sq(&a, &b, None) < 1e-12);
        assert!(euclidean_sq(&a, &b) > 1.0);
    }

    #[test]
    fn band_monotone_in_window() {
        // Widening the band can only lower (or keep) the optimal cost.
        let a = [0.0, 1.0, 2.0, 1.0, 0.0, -1.0, 0.0, 2.0];
        let b = [0.0, 0.0, 1.0, 2.0, 1.0, 0.0, -1.0, 0.0];
        let mut last = f64::INFINITY;
        for w in 0..8 {
            let d = dtw_sq(&a, &b, Some(w));
            assert!(d <= last + 1e-12, "w={w}: {d} > {last}");
            last = d;
        }
        assert!((dtw_sq(&a, &b, Some(8)) - dtw_sq(&a, &b, None)).abs() < 1e-12);
    }

    #[test]
    fn symmetry() {
        let a = [0.3, -1.2, 0.8, 2.0, -0.5];
        let b = [1.0, 0.2, -0.7, 1.5];
        for w in [None, Some(1), Some(2), Some(4)] {
            assert!((dtw_sq(&a, &b, w) - dtw_sq(&b, &a, w)).abs() < 1e-12);
        }
    }

    #[test]
    fn unequal_lengths_band_widened() {
        // |n-m| > window still yields a finite distance (band auto-widens).
        let a = [1.0; 10];
        let b = [1.0; 3];
        assert_eq!(dtw(&a, &b, Some(0)), 0.0);
    }

    #[test]
    fn early_abandon_consistent() {
        let a = [0.0, 5.0, 1.0, 4.0];
        let b = [2.0, 2.0, 2.0, 2.0];
        let exact = dtw(&a, &b, None);
        // Bound above the true distance: exact result.
        assert!((dtw_ea(&a, &b, None, exact + 1.0) - exact).abs() < 1e-12);
        // Bound below: abandoned.
        assert!(dtw_ea(&a, &b, None, exact * 0.5).is_infinite());
    }

    #[test]
    fn matrix_agrees_with_rolling() {
        let a = [0.1, 0.9, -0.4, 1.2, 0.0, 0.3];
        let b = [0.0, 1.0, -0.5, 1.0, 0.1, 0.2];
        for w in [None, Some(1), Some(3)] {
            let dp = dtw_matrix(&a, &b, w);
            assert!((dp[6][6] - dtw_sq(&a, &b, w)).abs() < 1e-12);
        }
    }

    #[test]
    fn path_is_valid_warping_path() {
        let a = [0.0, 1.0, 2.0, 1.0];
        let b = [0.0, 2.0, 1.0];
        let p = dtw_path(&a, &b, None);
        assert_eq!(p.first(), Some(&(0, 0)));
        assert_eq!(p.last(), Some(&(3, 2)));
        for k in 1..p.len() {
            let (di, dj) = (p[k].0 - p[k - 1].0, p[k].1 as i64 - p[k - 1].1 as i64);
            assert!(di <= 1 && (0..=1).contains(&dj) && (di == 1 || dj == 1));
        }
    }

    #[test]
    fn path_cost_equals_distance() {
        let a = [0.3, 1.7, -0.2, 0.9, 2.2];
        let b = [0.1, 1.5, 0.0, 1.0, 2.0];
        let p = dtw_path(&a, &b, None);
        let cost: f64 = p.iter().map(|&(i, j)| (a[i] - b[j]) * (a[i] - b[j])).sum();
        assert!((cost - dtw_sq(&a, &b, None)).abs() < 1e-9);
    }

    #[test]
    fn empty_series() {
        assert_eq!(dtw_sq(&[], &[], None), 0.0);
        assert!(dtw_sq(&[1.0], &[], None).is_infinite());
    }
}
