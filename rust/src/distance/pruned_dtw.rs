//! PrunedDTW (Silva & Batista, SDM 2016): exact DTW that skips cells whose
//! accumulated cost already exceeds an upper bound on the final distance.
//!
//! The pruning is *exact*: with any valid upper bound (e.g. the Euclidean
//! distance, which is DTW's cost along the diagonal path), the returned
//! value equals plain DTW. With `ub_sq = f64::INFINITY` no pruning happens
//! and the routine degenerates to the standard rolling-row DP. When the
//! true DTW cost exceeds the bound, `f64::INFINITY` is returned (early
//! abandon), which is exactly what 1-NN and pairwise-matrix loops want.

/// Accumulated squared PrunedDTW cost between `a` and `b` under an
/// optional Sakoe-Chiba band, pruned against `ub_sq`.
pub fn pruned_dtw_sq(a: &[f64], b: &[f64], window: Option<usize>, ub_sq: f64) -> f64 {
    let (n, m) = (a.len(), b.len());
    if n == 0 || m == 0 {
        return if n == m { 0.0 } else { f64::INFINITY };
    }
    let w = match window {
        Some(w) => w.max(n.abs_diff(m)),
        None => n.max(m),
    };
    let mut prev = vec![f64::INFINITY; m + 1];
    let mut curr = vec![f64::INFINITY; m + 1];
    prev[0] = 0.0;

    // Pruning state: sc = first column that can still be on an optimal
    // path, ec = one past the last column with a non-pruned value in the
    // previous row.
    let mut sc: usize = 1;
    let mut ec: usize = 1;

    for i in 1..=n {
        let band_lo = i.saturating_sub(w).max(1);
        let band_hi = (i + w).min(m);
        let beg = band_lo.max(sc);
        if beg > band_hi {
            return f64::INFINITY; // pruned region left the band: abandon
        }
        curr[0] = f64::INFINITY;
        // Cells before `beg` in this row are unreachable or pruned.
        curr[beg - 1] = f64::INFINITY;

        let ai = a[i - 1];
        let mut smaller_found = false;
        let mut sc_next = beg;
        let mut ec_next = beg;
        let mut pruned_all = true;

        for j in beg..=band_hi {
            let d = ai - b[j - 1];
            let cost = d * d;
            // Predecessors outside [sc-1, ec] of the previous row hold
            // stale values; they were set to INF when that row was filled.
            let diag = prev[j - 1];
            let up = if j >= ec && j > beg { f64::INFINITY } else { prev[j] };
            let left = curr[j - 1];
            let best = diag.min(up).min(left);
            let v = cost + best;

            if v > ub_sq {
                curr[j] = f64::INFINITY;
                if !smaller_found {
                    sc_next = j + 1;
                }
                if j >= ec {
                    // Everything to the right can only grow: stop the row.
                    for k in (j + 1)..=band_hi {
                        curr[k] = f64::INFINITY;
                    }
                    break;
                }
            } else {
                curr[j] = v;
                pruned_all = false;
                if !smaller_found {
                    smaller_found = true;
                    sc_next = j;
                }
                ec_next = j + 1;
            }
        }
        for k in (band_hi + 1)..=m {
            curr[k] = f64::INFINITY;
        }
        if pruned_all {
            return f64::INFINITY;
        }
        sc = sc_next;
        ec = ec_next;
        std::mem::swap(&mut prev, &mut curr);
    }
    prev[m]
}

/// PrunedDTW distance (square root of the accumulated squared cost).
pub fn pruned_dtw(a: &[f64], b: &[f64], window: Option<usize>, ub: f64) -> f64 {
    pruned_dtw_sq(a, b, window, ub * ub).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::rng::Rng;
    use crate::distance::dtw::dtw_sq;
    use crate::distance::euclidean::euclidean_sq;

    fn rand_walk(rng: &mut Rng, n: usize) -> Vec<f64> {
        let mut v = Vec::with_capacity(n);
        let mut x = 0.0;
        for _ in 0..n {
            x += rng.normal();
            v.push(x);
        }
        v
    }

    #[test]
    fn equals_dtw_with_euclidean_bound() {
        // ED is a valid DTW upper bound (diagonal path), so PrunedDTW must
        // return the exact DTW cost.
        let mut rng = Rng::new(41);
        for _ in 0..60 {
            let a = rand_walk(&mut rng, 35);
            let b = rand_walk(&mut rng, 35);
            for w in [None, Some(3), Some(10)] {
                let ub = euclidean_sq(&a, &b);
                let exact = dtw_sq(&a, &b, w);
                let pruned = pruned_dtw_sq(&a, &b, w, ub + 1e-9);
                assert!(
                    (exact - pruned).abs() < 1e-9,
                    "w={w:?} exact={exact} pruned={pruned}"
                );
            }
        }
    }

    #[test]
    fn equals_dtw_without_bound() {
        let mut rng = Rng::new(43);
        let a = rand_walk(&mut rng, 50);
        let b = rand_walk(&mut rng, 50);
        assert!((pruned_dtw_sq(&a, &b, None, f64::INFINITY) - dtw_sq(&a, &b, None)).abs() < 1e-9);
    }

    #[test]
    fn abandons_below_true_cost() {
        let mut rng = Rng::new(47);
        let a = rand_walk(&mut rng, 30);
        let b: Vec<f64> = a.iter().map(|x| x + 50.0).collect();
        let exact = dtw_sq(&a, &b, None);
        assert!(pruned_dtw_sq(&a, &b, None, exact * 0.1).is_infinite());
    }

    #[test]
    fn identical_is_zero() {
        let a = [1.0, 2.0, 1.0, 0.0];
        assert_eq!(pruned_dtw_sq(&a, &a, None, 1e-6), 0.0);
    }

    #[test]
    fn unequal_lengths() {
        let mut rng = Rng::new(53);
        let a = rand_walk(&mut rng, 20);
        let b = rand_walk(&mut rng, 33);
        let exact = dtw_sq(&a, &b, None);
        assert!((pruned_dtw_sq(&a, &b, None, exact * 2.0 + 1.0) - exact).abs() < 1e-9);
    }
}
