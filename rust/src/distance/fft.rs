//! Minimal radix-2 complex FFT, sufficient for the cross-correlations SBD
//! needs. The offline registry carries no FFT crate, so we ship our own
//! iterative Cooley–Tukey with bit-reversal permutation.

/// Complex number as a `(re, im)` pair; kept deliberately tiny.
pub type Complex = (f64, f64);

#[inline]
fn cmul(a: Complex, b: Complex) -> Complex {
    (a.0 * b.0 - a.1 * b.1, a.0 * b.1 + a.1 * b.0)
}

/// In-place iterative radix-2 FFT. `data.len()` must be a power of two.
/// `inverse` computes the unscaled inverse transform (caller divides by n).
pub fn fft_inplace(data: &mut [Complex], inverse: bool) {
    let n = data.len();
    assert!(n.is_power_of_two(), "fft: length {n} not a power of two");
    if n <= 1 {
        return;
    }
    // Bit-reversal permutation.
    let bits = n.trailing_zeros();
    for i in 0..n {
        let j = (i.reverse_bits() >> (usize::BITS - bits)) & (n - 1);
        if i < j {
            data.swap(i, j);
        }
    }
    // Butterflies.
    let sign = if inverse { 1.0 } else { -1.0 };
    let mut len = 2;
    while len <= n {
        let ang = sign * std::f64::consts::TAU / len as f64;
        let wlen = (ang.cos(), ang.sin());
        let mut i = 0;
        while i < n {
            let mut w: Complex = (1.0, 0.0);
            for k in 0..len / 2 {
                let u = data[i + k];
                let v = cmul(data[i + k + len / 2], w);
                data[i + k] = (u.0 + v.0, u.1 + v.1);
                data[i + k + len / 2] = (u.0 - v.0, u.1 - v.1);
                w = cmul(w, wlen);
            }
            i += len;
        }
        len <<= 1;
    }
}

/// Next power of two ≥ `n`.
pub fn next_pow2(n: usize) -> usize {
    n.next_power_of_two()
}

/// Full cross-correlation of `x` and `y` via FFT:
/// `out[k] = Σ_i x[i+k-(m-1)] · y[i]` for shifts `k ∈ [0, n+m-1)`,
/// i.e. the standard `numpy.correlate(x, y, "full")` layout reversed so
/// that index `m-1` is the zero-shift term.
pub fn cross_correlate(x: &[f64], y: &[f64]) -> Vec<f64> {
    let (n, m) = (x.len(), y.len());
    if n == 0 || m == 0 {
        return Vec::new();
    }
    let size = next_pow2(n + m - 1);
    let mut fx: Vec<Complex> = Vec::with_capacity(size);
    fx.extend(x.iter().map(|&v| (v, 0.0)));
    fx.resize(size, (0.0, 0.0));
    let mut fy: Vec<Complex> = Vec::with_capacity(size);
    fy.extend(y.iter().map(|&v| (v, 0.0)));
    fy.resize(size, (0.0, 0.0));
    fft_inplace(&mut fx, false);
    fft_inplace(&mut fy, false);
    // x ⋆ y = IFFT(FFT(x) · conj(FFT(y)))
    for i in 0..size {
        let c = cmul(fx[i], (fy[i].0, -fy[i].1));
        fx[i] = c;
    }
    fft_inplace(&mut fx, true);
    let scale = 1.0 / size as f64;
    // Circular correlation: lag k >= 0 at index k, negative lags wrap to
    // the end. Unpack to linear layout [-(m-1) .. n-1].
    let mut out = Vec::with_capacity(n + m - 1);
    for lag in -((m as isize) - 1)..(n as isize) {
        let idx = if lag >= 0 { lag as usize } else { size - (-lag) as usize };
        out.push(fx[idx].0 * scale);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Naive O(n·m) cross-correlation reference.
    fn naive_xcorr(x: &[f64], y: &[f64]) -> Vec<f64> {
        let (n, m) = (x.len(), y.len());
        let mut out = Vec::with_capacity(n + m - 1);
        for lag in -((m as isize) - 1)..(n as isize) {
            let mut s = 0.0;
            for j in 0..m {
                let i = lag + j as isize;
                if i >= 0 && (i as usize) < n {
                    s += x[i as usize] * y[j];
                }
            }
            out.push(s);
        }
        out
    }

    #[test]
    fn fft_roundtrip() {
        let orig: Vec<Complex> = (0..16).map(|i| (i as f64, (i * i) as f64 * 0.1)).collect();
        let mut data = orig.clone();
        fft_inplace(&mut data, false);
        fft_inplace(&mut data, true);
        for (a, b) in data.iter().zip(orig.iter()) {
            assert!((a.0 / 16.0 - b.0).abs() < 1e-9);
            assert!((a.1 / 16.0 - b.1).abs() < 1e-9);
        }
    }

    #[test]
    fn fft_matches_dft() {
        // Compare against a literal O(n²) DFT.
        let x: Vec<f64> = vec![1.0, 2.0, -1.0, 0.5, 3.0, -2.0, 0.0, 1.5];
        let mut data: Vec<Complex> = x.iter().map(|&v| (v, 0.0)).collect();
        fft_inplace(&mut data, false);
        let n = x.len();
        for k in 0..n {
            let (mut re, mut im) = (0.0, 0.0);
            for (j, &v) in x.iter().enumerate() {
                let ang = -std::f64::consts::TAU * (k * j) as f64 / n as f64;
                re += v * ang.cos();
                im += v * ang.sin();
            }
            assert!((data[k].0 - re).abs() < 1e-9, "k={k}");
            assert!((data[k].1 - im).abs() < 1e-9, "k={k}");
        }
    }

    #[test]
    fn xcorr_matches_naive() {
        let x = vec![1.0, 2.0, 3.0, 4.0, 0.5];
        let y = vec![-1.0, 0.5, 2.0];
        let got = cross_correlate(&x, &y);
        let want = naive_xcorr(&x, &y);
        assert_eq!(got.len(), want.len());
        for (g, w) in got.iter().zip(want.iter()) {
            assert!((g - w).abs() < 1e-9, "{got:?} vs {want:?}");
        }
    }

    #[test]
    fn xcorr_equal_lengths() {
        let x = vec![0.2, -0.5, 1.0, 0.7, -0.1, 0.4, 0.9, -0.8];
        let y = vec![0.3, 0.1, -0.2, 0.8, 0.5, -0.6, 0.2, 0.0];
        let got = cross_correlate(&x, &y);
        let want = naive_xcorr(&x, &y);
        for (g, w) in got.iter().zip(want.iter()) {
            assert!((g - w).abs() < 1e-9);
        }
    }

    #[test]
    fn zero_shift_is_dot_product() {
        let x = vec![1.0, 2.0, 3.0];
        let y = vec![4.0, 5.0, 6.0];
        let c = cross_correlate(&x, &y);
        // index m-1 = 2 is the aligned (zero-lag) dot product
        assert!((c[2] - 32.0).abs() < 1e-9);
    }
}
