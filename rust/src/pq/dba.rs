//! DTW Barycenter Averaging (Petitjean, Ketterlin & Gançarski 2011).
//!
//! DBA computes a length-`L` average of a set of series under DTW: each
//! iteration aligns every series to the current average with a full DTW
//! path, accumulates the values matched to each average coordinate, and
//! replaces the average by the per-coordinate mean. The barycenter is what
//! DBA-k-means uses as its centroid update (paper §3.1).

use crate::distance::dtw::dtw_path;

/// One DBA refinement step: align all `series` to `center`, return the
/// per-coordinate means. `window` constrains the alignment.
pub fn dba_step(center: &[f64], series: &[&[f64]], window: Option<usize>) -> Vec<f64> {
    let l = center.len();
    let mut sums = vec![0.0; l];
    let mut counts = vec![0usize; l];
    for s in series {
        for (ci, sj) in dtw_path(center, s, window) {
            sums[ci] += s[sj];
            counts[ci] += 1;
        }
    }
    sums.iter()
        .zip(counts.iter())
        .zip(center.iter())
        .map(|((&s, &c), &old)| if c > 0 { s / c as f64 } else { old })
        .collect()
}

/// DBA barycenter of `series`, starting from `init`, with at most
/// `max_iters` refinement steps (stops early on numerical convergence).
pub fn dba(init: &[f64], series: &[&[f64]], window: Option<usize>, max_iters: usize) -> Vec<f64> {
    let mut center = init.to_vec();
    if series.is_empty() {
        return center;
    }
    for _ in 0..max_iters {
        let next = dba_step(&center, series, window);
        let delta: f64 = next
            .iter()
            .zip(center.iter())
            .map(|(a, b)| (a - b) * (a - b))
            .sum();
        center = next;
        if delta < 1e-12 {
            break;
        }
    }
    center
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::rng::Rng;
    use crate::distance::dtw::dtw_sq;

    #[test]
    fn average_of_identical_series_is_the_series() {
        let s = [0.0, 1.0, 2.0, 1.0, 0.0];
        let out = dba(&s, &[&s, &s, &s], None, 5);
        for (a, b) in out.iter().zip(s.iter()) {
            assert!((a - b).abs() < 1e-12);
        }
    }

    #[test]
    fn single_series_converges_to_it() {
        let init = [0.0; 6];
        let s = [1.0, 2.0, 3.0, 3.0, 2.0, 1.0];
        let out = dba(&init, &[&s], None, 20);
        // With one series the barycenter matches its aligned values.
        assert!(dtw_sq(&out, &s, None) < 1e-9, "out={out:?}");
    }

    #[test]
    fn reduces_within_cluster_inertia() {
        // DBA should (weakly) lower the sum of DTW costs to the members
        // compared to a random member as center.
        let mut rng = Rng::new(127);
        let base: Vec<f64> = (0..24).map(|i| ((i as f64) * 0.4).sin()).collect();
        let members: Vec<Vec<f64>> = (0..6)
            .map(|_| base.iter().map(|v| v + 0.1 * rng.normal()).collect())
            .collect();
        let refs: Vec<&[f64]> = members.iter().map(|v| v.as_slice()).collect();
        let inertia = |c: &[f64]| refs.iter().map(|s| dtw_sq(c, s, None)).sum::<f64>();
        let before = inertia(&members[0]);
        let center = dba(&members[0], &refs, None, 10);
        let after = inertia(&center);
        assert!(after <= before + 1e-9, "after={after} before={before}");
    }

    #[test]
    fn respects_window() {
        let mut rng = Rng::new(131);
        let members: Vec<Vec<f64>> =
            (0..4).map(|_| (0..16).map(|_| rng.normal()).collect()).collect();
        let refs: Vec<&[f64]> = members.iter().map(|v| v.as_slice()).collect();
        let c = dba(&members[0], &refs, Some(2), 5);
        assert_eq!(c.len(), 16);
        assert!(c.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn empty_input_returns_init() {
        let init = [1.0, 2.0];
        assert_eq!(dba(&init, &[], None, 3), init.to_vec());
    }
}
