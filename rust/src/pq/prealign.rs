//! Subspace extraction: fixed or MODWT-pre-aligned partitioning of a
//! series into `M` equal-length subspace vectors (paper §3.5).
//!
//! With pre-alignment enabled, each fixed split point may move backwards
//! by up to `tail` samples onto a MODWT sign-change point; the resulting
//! variable-length segments are linearly re-interpolated to the common
//! length `sub_len = ceil(D/M) + tail`, which is what makes the Keogh
//! envelopes of the codebook precomputable.

use crate::core::preprocess::reinterpolate;
use crate::wavelet::segment::{cut_at, elastic_split_points, fixed_split_points};

/// How a series is partitioned into subspaces.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Segmenter {
    /// Number of subspaces `M`.
    pub n_subspaces: usize,
    /// MODWT decomposition level (ignored when `tail == 0`).
    pub level: usize,
    /// Tail length in samples; `0` disables pre-alignment.
    pub tail: usize,
}

impl Segmenter {
    /// Fixed-length segmentation (no pre-alignment).
    pub fn fixed(n_subspaces: usize) -> Self {
        Segmenter { n_subspaces, level: 1, tail: 0 }
    }

    /// MODWT pre-aligned segmentation.
    pub fn prealigned(n_subspaces: usize, level: usize, tail: usize) -> Self {
        Segmenter { n_subspaces, level, tail }
    }

    /// Common subspace vector length for series of length `len`.
    pub fn sub_len(&self, len: usize) -> usize {
        len.div_ceil(self.n_subspaces) + self.tail
    }

    /// Split `x` into `M` subspace vectors, each of length
    /// [`Segmenter::sub_len`]. Segments are re-interpolated whenever their
    /// raw length differs from the target (always true with pre-alignment
    /// and whenever `len % M != 0`).
    pub fn segment(&self, x: &[f64]) -> Vec<Vec<f64>> {
        assert!(
            x.len() >= 2 * self.n_subspaces,
            "series of length {} too short for {} subspaces",
            x.len(),
            self.n_subspaces
        );
        let boundaries = if self.tail == 0 {
            fixed_split_points(x.len(), self.n_subspaces)
        } else {
            elastic_split_points(x, self.n_subspaces, self.level, self.tail)
        };
        let target = self.sub_len(x.len());
        cut_at(x, &boundaries)
            .into_iter()
            .map(|seg| {
                if seg.len() == target {
                    seg.to_vec()
                } else {
                    reinterpolate(seg, target)
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::rng::Rng;

    #[test]
    fn fixed_segmentation_shapes() {
        let x: Vec<f64> = (0..120).map(|i| i as f64).collect();
        let seg = Segmenter::fixed(4);
        let parts = seg.segment(&x);
        assert_eq!(parts.len(), 4);
        for p in &parts {
            assert_eq!(p.len(), 30);
        }
        // Exact division: segmentation is pure slicing.
        assert_eq!(parts[0], (0..30).map(|i| i as f64).collect::<Vec<_>>());
    }

    #[test]
    fn non_divisible_length_reinterpolated() {
        let x: Vec<f64> = (0..100).map(|i| (i as f64 * 0.3).sin()).collect();
        let seg = Segmenter::fixed(3);
        let parts = seg.segment(&x);
        let target = seg.sub_len(100); // ceil(100/3) = 34
        assert_eq!(target, 34);
        for p in &parts {
            assert_eq!(p.len(), target);
        }
    }

    #[test]
    fn prealigned_segments_have_common_length() {
        let mut rng = Rng::new(137);
        let x: Vec<f64> = {
            let mut acc = 0.0;
            (0..128)
                .map(|_| {
                    acc += rng.normal();
                    acc
                })
                .collect()
        };
        let seg = Segmenter::prealigned(4, 2, 6);
        let parts = seg.segment(&x);
        assert_eq!(parts.len(), 4);
        for p in &parts {
            assert_eq!(p.len(), 32 + 6);
        }
    }

    #[test]
    fn segments_preserve_endpoints() {
        let mut rng = Rng::new(139);
        let x: Vec<f64> = (0..64).map(|_| rng.normal()).collect();
        for seg in [Segmenter::fixed(4), Segmenter::prealigned(4, 2, 4)] {
            let parts = seg.segment(&x);
            assert!((parts[0][0] - x[0]).abs() < 1e-12);
            let last = parts.last().unwrap();
            assert!((last.last().unwrap() - x.last().unwrap()).abs() < 1e-12);
        }
    }

    #[test]
    #[should_panic]
    fn too_short_series_panics() {
        Segmenter::fixed(8).segment(&[1.0; 10]);
    }
}
