//! The trained codebook: per-subspace centroids plus the two precomputed
//! acceleration structures from Algorithm 1 — the Keogh envelope of every
//! centroid (for the reversed lower-bound cascade at encode time) and the
//! `M×K×K` symmetric distance LUT (for O(M) symmetric distances).

use crate::distance::dtw::dtw_sq;
use crate::distance::envelope::Envelope;
use crate::distance::euclidean::euclidean_sq;

/// Metric the quantizer operates under.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PqMetric {
    /// Windowed DTW (the paper's PQDTW).
    Dtw,
    /// Plain Euclidean (the `PQ_ED` baseline).
    Euclidean,
}

/// Trained per-subspace codebooks with precomputed envelopes and LUT.
#[derive(Debug, Clone)]
pub struct Codebook {
    /// Number of subspaces `M`.
    pub n_subspaces: usize,
    /// Codebook size `K` (identical across subspaces).
    pub k: usize,
    /// Subspace vector length `L`.
    pub sub_len: usize,
    /// Quantization warping window (samples) used for encoding, the LUT
    /// and the envelopes; `None` = unconstrained.
    pub window: Option<usize>,
    /// Metric.
    pub metric: PqMetric,
    /// Centroids, flat `M × K × L` row-major.
    pub centroids: Vec<f64>,
    /// Keogh envelope per centroid (`M × K`), empty for the ED metric.
    pub envelopes: Vec<Envelope>,
    /// Squared symmetric distances, flat `M × K × K`.
    pub lut_sq: Vec<f64>,
}

impl Codebook {
    /// Assemble a codebook from per-subspace centroid buffers (each
    /// `K × L` flat) and precompute envelopes + LUT.
    pub fn build(
        per_subspace: Vec<Vec<f64>>,
        sub_len: usize,
        window: Option<usize>,
        metric: PqMetric,
    ) -> Self {
        let n_subspaces = per_subspace.len();
        assert!(n_subspaces > 0);
        let k = per_subspace[0].len() / sub_len;
        assert!(per_subspace.iter().all(|c| c.len() == k * sub_len), "ragged codebooks");

        let mut centroids = Vec::with_capacity(n_subspaces * k * sub_len);
        for c in &per_subspace {
            centroids.extend_from_slice(c);
        }

        let mut cb = Codebook {
            n_subspaces,
            k,
            sub_len,
            window,
            metric,
            centroids,
            envelopes: Vec::new(),
            lut_sq: vec![0.0; n_subspaces * k * k],
        };
        cb.precompute();
        cb
    }

    /// Recompute the envelopes and distance LUT (Algorithm 1's
    /// post-clustering loop).
    fn precompute(&mut self) {
        let (m_n, k, l) = (self.n_subspaces, self.k, self.sub_len);
        // Envelopes: only meaningful under DTW. With window = None the
        // envelope degenerates to global min/max (still a valid bound).
        if self.metric == PqMetric::Dtw {
            let w = self.window.unwrap_or(l);
            self.envelopes = (0..m_n * k)
                .map(|i| Envelope::new(&self.centroids[i * l..(i + 1) * l], w))
                .collect();
        } else {
            self.envelopes.clear();
        }
        // Symmetric LUT.
        for m in 0..m_n {
            for i in 0..k {
                let ci = self.centroid(m, i).to_vec();
                for j in (i + 1)..k {
                    let cj = self.centroid(m, j);
                    let d = match self.metric {
                        PqMetric::Dtw => dtw_sq(&ci, cj, self.window),
                        PqMetric::Euclidean => euclidean_sq(&ci, cj),
                    };
                    self.lut_sq[m * k * k + i * k + j] = d;
                    self.lut_sq[m * k * k + j * k + i] = d;
                }
            }
        }
    }

    /// Borrow centroid `(m, k)`.
    #[inline]
    pub fn centroid(&self, m: usize, k: usize) -> &[f64] {
        let base = (m * self.k + k) * self.sub_len;
        &self.centroids[base..base + self.sub_len]
    }

    /// Envelope of centroid `(m, k)` (DTW metric only).
    #[inline]
    pub fn envelope(&self, m: usize, k: usize) -> &Envelope {
        &self.envelopes[m * self.k + k]
    }

    /// Squared LUT entry for centroids `i, j` of subspace `m`.
    #[inline]
    pub fn lut_sq(&self, m: usize, i: usize, j: usize) -> f64 {
        self.lut_sq[m * self.k * self.k + i * self.k + j]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::rng::Rng;

    fn toy_codebook(metric: PqMetric) -> Codebook {
        let mut rng = Rng::new(179);
        let (m, k, l) = (3, 4, 8);
        let per: Vec<Vec<f64>> = (0..m)
            .map(|_| (0..k * l).map(|_| rng.normal()).collect())
            .collect();
        Codebook::build(per, l, Some(2), metric)
    }

    #[test]
    fn shapes() {
        let cb = toy_codebook(PqMetric::Dtw);
        assert_eq!(cb.n_subspaces, 3);
        assert_eq!(cb.k, 4);
        assert_eq!(cb.sub_len, 8);
        assert_eq!(cb.centroids.len(), 3 * 4 * 8);
        assert_eq!(cb.envelopes.len(), 12);
        assert_eq!(cb.lut_sq.len(), 3 * 16);
    }

    #[test]
    fn lut_symmetric_zero_diagonal() {
        let cb = toy_codebook(PqMetric::Dtw);
        for m in 0..3 {
            for i in 0..4 {
                assert_eq!(cb.lut_sq(m, i, i), 0.0);
                for j in 0..4 {
                    assert_eq!(cb.lut_sq(m, i, j), cb.lut_sq(m, j, i));
                }
            }
        }
    }

    #[test]
    fn lut_matches_direct_dtw() {
        let cb = toy_codebook(PqMetric::Dtw);
        for m in 0..3 {
            for i in 0..4 {
                for j in 0..4 {
                    let d = dtw_sq(cb.centroid(m, i), cb.centroid(m, j), cb.window);
                    assert!((cb.lut_sq(m, i, j) - d).abs() < 1e-12);
                }
            }
        }
    }

    #[test]
    fn euclidean_metric_has_no_envelopes() {
        let cb = toy_codebook(PqMetric::Euclidean);
        assert!(cb.envelopes.is_empty());
        for m in 0..3 {
            let d = euclidean_sq(cb.centroid(m, 0), cb.centroid(m, 1));
            assert!((cb.lut_sq(m, 0, 1) - d).abs() < 1e-12);
        }
    }

    #[test]
    fn envelopes_bound_centroids() {
        let cb = toy_codebook(PqMetric::Dtw);
        for m in 0..3 {
            for k in 0..4 {
                let c = cb.centroid(m, k);
                let e = cb.envelope(m, k);
                for (i, &v) in c.iter().enumerate() {
                    assert!(e.lower[i] <= v && v <= e.upper[i]);
                }
            }
        }
    }
}
