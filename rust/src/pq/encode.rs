//! Encoding: nearest-centroid search per subspace (Algorithm 2).
//!
//! The DTW path runs the reversed lower-bound cascade — LB_Kim (O(1)),
//! then reversed LB_Keogh against the centroid's *precomputed* envelope
//! (O(L)) — before paying for an early-abandoned DTW. The Euclidean path
//! (PQ_ED) uses plain early abandoning. Pruning counters are recorded so
//! the benchmarks (and the paper's Fig. 5 narrative about LB pruning) can
//! be verified quantitatively.

use super::codebook::{Codebook, PqMetric};
use crate::distance::dtw::{dtw_sq_scratch, DtwScratch};
use crate::distance::euclidean::euclidean_ea_sq;
use crate::distance::lower_bounds::{lb_keogh_sq, lb_kim_sq};

/// Counters describing how much work encoding did.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct EncodeStats {
    /// Candidates pruned by LB_Kim alone.
    pub pruned_kim: usize,
    /// Candidates pruned by reversed LB_Keogh.
    pub pruned_keogh: usize,
    /// Full (early-abandoned) DTW evaluations.
    pub dtw_evals: usize,
    /// Of those, evaluations abandoned before completion.
    pub dtw_abandoned: usize,
}

impl EncodeStats {
    /// Merge counters (for dataset-level aggregation).
    pub fn merge(&mut self, o: &EncodeStats) {
        self.pruned_kim += o.pruned_kim;
        self.pruned_keogh += o.pruned_keogh;
        self.dtw_evals += o.dtw_evals;
        self.dtw_abandoned += o.dtw_abandoned;
    }

    /// Total candidates examined.
    pub fn candidates(&self) -> usize {
        self.pruned_kim + self.pruned_keogh + self.dtw_evals
    }
}

/// Result of encoding one subspace vector.
#[derive(Debug, Clone, Copy)]
pub struct SubspaceCode {
    /// Winning centroid id.
    pub code: u16,
    /// Exact squared distance from the vector to the winning centroid.
    pub dist_sq: f64,
    /// Squared reversed LB_Keogh between the vector and the winning
    /// centroid's envelope — the replacement value used by the Keogh-
    /// patched symmetric distance in clustering (paper §4.2). 0 under ED.
    pub lb_self_sq: f64,
}

/// Nearest-centroid search for subspace `m` of the codebook.
pub fn encode_subspace(
    q: &[f64],
    m: usize,
    cb: &Codebook,
    scratch: &mut DtwScratch,
    stats: &mut EncodeStats,
) -> SubspaceCode {
    debug_assert_eq!(q.len(), cb.sub_len);
    let mut best_sq = f64::INFINITY;
    let mut best_k = 0usize;
    match cb.metric {
        PqMetric::Dtw => {
            for k in 0..cb.k {
                let c = cb.centroid(m, k);
                // Cascade stage 1: LB_Kim, O(1).
                let kim = lb_kim_sq(q, c);
                if kim >= best_sq {
                    stats.pruned_kim += 1;
                    continue;
                }
                // Cascade stage 2: reversed LB_Keogh against the
                // precomputed centroid envelope, O(L), early-abandoning.
                let keogh = lb_keogh_sq(q, cb.envelope(m, k), best_sq);
                if keogh >= best_sq {
                    stats.pruned_keogh += 1;
                    continue;
                }
                // Full early-abandoned DTW.
                stats.dtw_evals += 1;
                let d = dtw_sq_scratch(q, c, cb.window, best_sq, scratch);
                if d.is_infinite() {
                    stats.dtw_abandoned += 1;
                } else if d < best_sq {
                    best_sq = d;
                    best_k = k;
                }
            }
        }
        PqMetric::Euclidean => {
            for k in 0..cb.k {
                let c = cb.centroid(m, k);
                stats.dtw_evals += 1;
                let d = euclidean_ea_sq(q, c, best_sq);
                if d.is_infinite() {
                    stats.dtw_abandoned += 1;
                } else if d < best_sq {
                    best_sq = d;
                    best_k = k;
                }
            }
        }
    }
    let lb_self_sq = if cb.metric == PqMetric::Dtw {
        lb_keogh_sq(q, cb.envelope(m, best_k), f64::INFINITY)
    } else {
        0.0
    };
    SubspaceCode { code: best_k as u16, dist_sq: best_sq, lb_self_sq }
}

/// Brute-force nearest centroid (no bounds) — the correctness oracle for
/// [`encode_subspace`], also used by tests.
pub fn encode_subspace_bruteforce(q: &[f64], m: usize, cb: &Codebook) -> (u16, f64) {
    let mut scratch = DtwScratch::new(cb.sub_len);
    let mut best_sq = f64::INFINITY;
    let mut best_k = 0usize;
    for k in 0..cb.k {
        let c = cb.centroid(m, k);
        let d = match cb.metric {
            PqMetric::Dtw => dtw_sq_scratch(q, c, cb.window, f64::INFINITY, &mut scratch),
            PqMetric::Euclidean => crate::distance::euclidean::euclidean_sq(q, c),
        };
        if d < best_sq {
            best_sq = d;
            best_k = k;
        }
    }
    (best_k as u16, best_sq)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::rng::Rng;

    fn toy_codebook(metric: PqMetric, seed: u64) -> Codebook {
        let mut rng = Rng::new(seed);
        let (m, k, l) = (2, 16, 12);
        let per: Vec<Vec<f64>> = (0..m)
            .map(|_| {
                (0..k * l)
                    .map(|_| {
                        // random walks so LBs have teeth
                        rng.normal()
                    })
                    .collect()
            })
            .collect();
        Codebook::build(per, l, Some(2), metric)
    }

    #[test]
    fn cascade_matches_bruteforce_dtw() {
        let cb = toy_codebook(PqMetric::Dtw, 191);
        let mut rng = Rng::new(193);
        let mut scratch = DtwScratch::new(cb.sub_len);
        for _ in 0..100 {
            let q: Vec<f64> = (0..cb.sub_len).map(|_| rng.normal()).collect();
            for m in 0..cb.n_subspaces {
                let mut stats = EncodeStats::default();
                let fast = encode_subspace(&q, m, &cb, &mut scratch, &mut stats);
                let (slow_k, slow_d) = encode_subspace_bruteforce(&q, m, &cb);
                assert!(
                    (fast.dist_sq - slow_d).abs() < 1e-9,
                    "dist mismatch: {} vs {}",
                    fast.dist_sq,
                    slow_d
                );
                // Ties can legitimately differ in id; distances must agree.
                if fast.code != slow_k {
                    assert!((fast.dist_sq - slow_d).abs() < 1e-12);
                }
            }
        }
    }

    #[test]
    fn cascade_matches_bruteforce_euclidean() {
        let cb = toy_codebook(PqMetric::Euclidean, 197);
        let mut rng = Rng::new(199);
        let mut scratch = DtwScratch::new(cb.sub_len);
        for _ in 0..50 {
            let q: Vec<f64> = (0..cb.sub_len).map(|_| rng.normal()).collect();
            let mut stats = EncodeStats::default();
            let fast = encode_subspace(&q, 0, &cb, &mut scratch, &mut stats);
            let (_, slow_d) = encode_subspace_bruteforce(&q, 0, &cb);
            assert!((fast.dist_sq - slow_d).abs() < 1e-9);
        }
    }

    #[test]
    fn pruning_actually_happens() {
        let cb = toy_codebook(PqMetric::Dtw, 211);
        let mut rng = Rng::new(223);
        let mut scratch = DtwScratch::new(cb.sub_len);
        let mut stats = EncodeStats::default();
        for _ in 0..50 {
            let q: Vec<f64> = (0..cb.sub_len).map(|_| rng.normal()).collect();
            encode_subspace(&q, 0, &cb, &mut scratch, &mut stats);
        }
        assert_eq!(stats.candidates(), 50 * cb.k);
        assert!(
            stats.pruned_kim + stats.pruned_keogh > 0,
            "no LB pruning at all: {stats:?}"
        );
        assert!(stats.dtw_evals < 50 * cb.k, "no candidate ever pruned");
    }

    #[test]
    fn exact_centroid_encodes_to_itself() {
        let cb = toy_codebook(PqMetric::Dtw, 227);
        let mut scratch = DtwScratch::new(cb.sub_len);
        for m in 0..cb.n_subspaces {
            for k in 0..cb.k {
                let q = cb.centroid(m, k).to_vec();
                let mut stats = EncodeStats::default();
                let out = encode_subspace(&q, m, &cb, &mut scratch, &mut stats);
                assert!(out.dist_sq < 1e-12);
                // The winner must be a centroid at distance 0 (could tie).
                let d = crate::distance::dtw::dtw_sq(&q, cb.centroid(m, out.code as usize), cb.window);
                assert!(d < 1e-12);
            }
        }
    }

    #[test]
    fn lb_self_is_lower_bound_of_dist() {
        let cb = toy_codebook(PqMetric::Dtw, 229);
        let mut rng = Rng::new(233);
        let mut scratch = DtwScratch::new(cb.sub_len);
        for _ in 0..50 {
            let q: Vec<f64> = (0..cb.sub_len).map(|_| rng.normal()).collect();
            let mut stats = EncodeStats::default();
            let out = encode_subspace(&q, 1, &cb, &mut scratch, &mut stats);
            assert!(out.lb_self_sq <= out.dist_sq + 1e-9);
        }
    }
}
