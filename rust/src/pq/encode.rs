//! Encoding: nearest-centroid search per subspace (Algorithm 2).
//!
//! The DTW path runs the reversed lower-bound cascade — LB_Kim (O(1)),
//! then reversed LB_Keogh against the centroid's *precomputed* envelope
//! (O(L)) — before paying for an early-abandoned DTW. The Euclidean path
//! (PQ_ED) uses plain early abandoning. Pruning counters are recorded so
//! the benchmarks (and the paper's Fig. 5 narrative about LB pruning) can
//! be verified quantitatively.

use super::codebook::{Codebook, PqMetric};
use crate::distance::dtw::{dtw_sq_scratch, DtwScratch};
use crate::distance::euclidean::euclidean_ea_sq;
use crate::distance::lower_bounds::{lb_keogh_sq, lb_kim_sq};

/// Counters describing how much work encoding did.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct EncodeStats {
    /// Candidates pruned by LB_Kim alone.
    pub pruned_kim: usize,
    /// Candidates pruned by reversed LB_Keogh.
    pub pruned_keogh: usize,
    /// Full (early-abandoned) DTW evaluations.
    pub dtw_evals: usize,
    /// Of those, evaluations abandoned before completion.
    pub dtw_abandoned: usize,
}

impl EncodeStats {
    /// Merge counters (for dataset-level aggregation).
    pub fn merge(&mut self, o: &EncodeStats) {
        self.pruned_kim += o.pruned_kim;
        self.pruned_keogh += o.pruned_keogh;
        self.dtw_evals += o.dtw_evals;
        self.dtw_abandoned += o.dtw_abandoned;
    }

    /// Total candidates examined.
    pub fn candidates(&self) -> usize {
        self.pruned_kim + self.pruned_keogh + self.dtw_evals
    }
}

/// Result of encoding one subspace vector.
#[derive(Debug, Clone, Copy)]
pub struct SubspaceCode {
    /// Winning centroid id.
    pub code: u16,
    /// Exact squared distance from the vector to the winning centroid.
    pub dist_sq: f64,
    /// Squared reversed LB_Keogh between the vector and the winning
    /// centroid's envelope — the replacement value used by the Keogh-
    /// patched symmetric distance in clustering (paper §4.2). 0 under ED.
    pub lb_self_sq: f64,
}

/// Nearest-centroid search for subspace `m` of the codebook.
pub fn encode_subspace(
    q: &[f64],
    m: usize,
    cb: &Codebook,
    scratch: &mut DtwScratch,
    stats: &mut EncodeStats,
) -> SubspaceCode {
    debug_assert_eq!(q.len(), cb.sub_len);
    let mut best_sq = f64::INFINITY;
    let mut best_k = 0usize;
    match cb.metric {
        PqMetric::Dtw => {
            for k in 0..cb.k {
                let c = cb.centroid(m, k);
                // Cascade stage 1: LB_Kim, O(1).
                let kim = lb_kim_sq(q, c);
                if kim >= best_sq {
                    stats.pruned_kim += 1;
                    continue;
                }
                // Cascade stage 2: reversed LB_Keogh against the
                // precomputed centroid envelope, O(L), early-abandoning.
                let keogh = lb_keogh_sq(q, cb.envelope(m, k), best_sq);
                if keogh >= best_sq {
                    stats.pruned_keogh += 1;
                    continue;
                }
                // Full early-abandoned DTW.
                stats.dtw_evals += 1;
                let d = dtw_sq_scratch(q, c, cb.window, best_sq, scratch);
                if d.is_infinite() {
                    stats.dtw_abandoned += 1;
                } else if d < best_sq {
                    best_sq = d;
                    best_k = k;
                }
            }
        }
        PqMetric::Euclidean => {
            for k in 0..cb.k {
                let c = cb.centroid(m, k);
                stats.dtw_evals += 1;
                let d = euclidean_ea_sq(q, c, best_sq);
                if d.is_infinite() {
                    stats.dtw_abandoned += 1;
                } else if d < best_sq {
                    best_sq = d;
                    best_k = k;
                }
            }
        }
    }
    let lb_self_sq = if cb.metric == PqMetric::Dtw {
        lb_keogh_sq(q, cb.envelope(m, best_k), f64::INFINITY)
    } else {
        0.0
    };
    SubspaceCode { code: best_k as u16, dist_sq: best_sq, lb_self_sq }
}

/// Items per scan block of a [`CodeBlocks`] layout. 64 items × one code
/// byte per subspace keeps a whole block's segment row in a single
/// cache line on the `u8` path, and the per-block `f64` accumulator at
/// 512 B — comfortably register/L1-resident (`docs/DESIGN.md` §6).
pub const SCAN_BLOCK: usize = 64;

/// Codes transposed into fixed-size *segment-major* blocks: within each
/// block of [`SCAN_BLOCK`] items, all first-subspace codes are stored
/// contiguously, then all second-subspace codes, and so on. The scan
/// kernel ([`crate::pq::scan`]) therefore streams one contiguous lane
/// of code bytes per subspace instead of striding through row-major
/// `N × M` code words.
///
/// Codes are narrowed to `u8` when `K <= 256` (the common case — the
/// paper uses `K = 256`), halving the bytes the inner loop streams vs
/// the row-major `u16` layout; a `u16` lane path covers larger
/// codebooks. The per-item squared self bounds can ride along in the
/// same blocked layout so the Keogh-patched symmetric mode resolves its
/// diagonal substitution without leaving the block — they are opt-in
/// (pass an empty slice to skip them), because the plain symmetric and
/// asymmetric scan paths never read them and the bounds cost `N·M·8`
/// bytes, eight times the `u8` code lanes they accompany.
///
/// The trailing partial block is zero-padded; padded lanes are never
/// read because every scan is bounded by [`CodeBlocks::n`]. This is
/// derived state: it is rebuilt from the row-major codes on
/// `Engine::build`/`Engine::open` and never persisted.
#[derive(Debug, Clone)]
pub struct CodeBlocks {
    /// Number of encoded items.
    n: usize,
    /// Subspace count `M`.
    m: usize,
    /// Codebook size `K` (decides the lane width).
    k: usize,
    /// `u8` code lanes (`K <= 256`); empty on the `u16` path.
    pub(crate) lanes8: Vec<u8>,
    /// `u16` code lanes (`K > 256`); empty on the `u8` path.
    pub(crate) lanes16: Vec<u16>,
    /// Squared self bounds in the same blocked layout; empty when the
    /// blocks were built without bounds (symmetric/asymmetric only).
    pub(crate) lb: Vec<f64>,
}

impl CodeBlocks {
    /// Transpose row-major codes (`n × m`, one `u16` per subspace) into
    /// the blocked layout. Every code must be `< k` (guaranteed by the
    /// encoder and validated by the store). `lb_self_sq` may be empty —
    /// only Keogh-patched scans read the self bounds, so the plain
    /// scan paths skip the allocation entirely; pass the full `n × m`
    /// bound buffer to enable patched scans over the result.
    pub fn build(codes: &[u16], lb_self_sq: &[f64], m: usize, k: usize) -> Self {
        assert!(m >= 1, "CodeBlocks requires at least one subspace");
        assert!(k >= 1, "CodeBlocks requires a non-empty codebook");
        assert_eq!(codes.len() % m, 0, "ragged code buffer");
        assert!(
            lb_self_sq.is_empty() || lb_self_sq.len() == codes.len(),
            "self-bound buffer disagrees with codes"
        );
        let n = codes.len() / m;
        let cells = n.div_ceil(SCAN_BLOCK) * m * SCAN_BLOCK;
        let narrow = k <= 256;
        let with_bounds = !lb_self_sq.is_empty();
        let mut lanes8 = vec![0u8; if narrow { cells } else { 0 }];
        let mut lanes16 = vec![0u16; if narrow { 0 } else { cells }];
        let mut lb = vec![0.0f64; if with_bounds { cells } else { 0 }];
        for i in 0..n {
            let block = i / SCAN_BLOCK;
            let lane = i % SCAN_BLOCK;
            for s in 0..m {
                let c = codes[i * m + s];
                assert!((c as usize) < k, "code {c} out of range (K = {k})");
                let cell = (block * m + s) * SCAN_BLOCK + lane;
                if narrow {
                    lanes8[cell] = c as u8;
                } else {
                    lanes16[cell] = c;
                }
                if with_bounds {
                    lb[cell] = lb_self_sq[i * m + s];
                }
            }
        }
        CodeBlocks { n, m, k, lanes8, lanes16, lb }
    }

    /// True when the blocked self bounds are present (required by the
    /// Keogh-patched scan mode).
    pub fn has_bounds(&self) -> bool {
        !self.lb.is_empty()
    }

    /// Number of items held.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Subspace count `M`.
    pub fn n_subspaces(&self) -> usize {
        self.m
    }

    /// Codebook size `K` the lanes were sized for.
    pub fn k(&self) -> usize {
        self.k
    }

    /// True when the narrow `u8` lane path is in use (`K <= 256`).
    pub fn uses_u8(&self) -> bool {
        self.k <= 256
    }

    /// Number of blocks (the last one may be partial).
    pub fn n_blocks(&self) -> usize {
        self.n.div_ceil(SCAN_BLOCK)
    }
}

/// Brute-force nearest centroid (no bounds) — the correctness oracle for
/// [`encode_subspace`], also used by tests.
pub fn encode_subspace_bruteforce(q: &[f64], m: usize, cb: &Codebook) -> (u16, f64) {
    let mut scratch = DtwScratch::new(cb.sub_len);
    let mut best_sq = f64::INFINITY;
    let mut best_k = 0usize;
    for k in 0..cb.k {
        let c = cb.centroid(m, k);
        let d = match cb.metric {
            PqMetric::Dtw => dtw_sq_scratch(q, c, cb.window, f64::INFINITY, &mut scratch),
            PqMetric::Euclidean => crate::distance::euclidean::euclidean_sq(q, c),
        };
        if d < best_sq {
            best_sq = d;
            best_k = k;
        }
    }
    (best_k as u16, best_sq)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::rng::Rng;

    fn toy_codebook(metric: PqMetric, seed: u64) -> Codebook {
        let mut rng = Rng::new(seed);
        let (m, k, l) = (2, 16, 12);
        let per: Vec<Vec<f64>> = (0..m)
            .map(|_| {
                (0..k * l)
                    .map(|_| {
                        // random walks so LBs have teeth
                        rng.normal()
                    })
                    .collect()
            })
            .collect();
        Codebook::build(per, l, Some(2), metric)
    }

    #[test]
    fn cascade_matches_bruteforce_dtw() {
        let cb = toy_codebook(PqMetric::Dtw, 191);
        let mut rng = Rng::new(193);
        let mut scratch = DtwScratch::new(cb.sub_len);
        for _ in 0..100 {
            let q: Vec<f64> = (0..cb.sub_len).map(|_| rng.normal()).collect();
            for m in 0..cb.n_subspaces {
                let mut stats = EncodeStats::default();
                let fast = encode_subspace(&q, m, &cb, &mut scratch, &mut stats);
                let (slow_k, slow_d) = encode_subspace_bruteforce(&q, m, &cb);
                assert!(
                    (fast.dist_sq - slow_d).abs() < 1e-9,
                    "dist mismatch: {} vs {}",
                    fast.dist_sq,
                    slow_d
                );
                // Ties can legitimately differ in id; distances must agree.
                if fast.code != slow_k {
                    assert!((fast.dist_sq - slow_d).abs() < 1e-12);
                }
            }
        }
    }

    #[test]
    fn cascade_matches_bruteforce_euclidean() {
        let cb = toy_codebook(PqMetric::Euclidean, 197);
        let mut rng = Rng::new(199);
        let mut scratch = DtwScratch::new(cb.sub_len);
        for _ in 0..50 {
            let q: Vec<f64> = (0..cb.sub_len).map(|_| rng.normal()).collect();
            let mut stats = EncodeStats::default();
            let fast = encode_subspace(&q, 0, &cb, &mut scratch, &mut stats);
            let (_, slow_d) = encode_subspace_bruteforce(&q, 0, &cb);
            assert!((fast.dist_sq - slow_d).abs() < 1e-9);
        }
    }

    #[test]
    fn pruning_actually_happens() {
        let cb = toy_codebook(PqMetric::Dtw, 211);
        let mut rng = Rng::new(223);
        let mut scratch = DtwScratch::new(cb.sub_len);
        let mut stats = EncodeStats::default();
        for _ in 0..50 {
            let q: Vec<f64> = (0..cb.sub_len).map(|_| rng.normal()).collect();
            encode_subspace(&q, 0, &cb, &mut scratch, &mut stats);
        }
        assert_eq!(stats.candidates(), 50 * cb.k);
        assert!(
            stats.pruned_kim + stats.pruned_keogh > 0,
            "no LB pruning at all: {stats:?}"
        );
        assert!(stats.dtw_evals < 50 * cb.k, "no candidate ever pruned");
    }

    #[test]
    fn exact_centroid_encodes_to_itself() {
        let cb = toy_codebook(PqMetric::Dtw, 227);
        let mut scratch = DtwScratch::new(cb.sub_len);
        for m in 0..cb.n_subspaces {
            for k in 0..cb.k {
                let q = cb.centroid(m, k).to_vec();
                let mut stats = EncodeStats::default();
                let out = encode_subspace(&q, m, &cb, &mut scratch, &mut stats);
                assert!(out.dist_sq < 1e-12);
                // The winner must be a centroid at distance 0 (could tie).
                let d = crate::distance::dtw::dtw_sq(&q, cb.centroid(m, out.code as usize), cb.window);
                assert!(d < 1e-12);
            }
        }
    }

    #[test]
    fn code_blocks_transpose_roundtrips_u8() {
        let mut rng = Rng::new(307);
        let (m, k) = (3usize, 16usize);
        for n in [1usize, SCAN_BLOCK - 1, SCAN_BLOCK, SCAN_BLOCK + 1, 2 * SCAN_BLOCK + 7] {
            let codes: Vec<u16> = (0..n * m).map(|_| rng.below(k) as u16).collect();
            let lb: Vec<f64> = (0..n * m).map(|_| rng.uniform()).collect();
            let blocks = CodeBlocks::build(&codes, &lb, m, k);
            assert!(blocks.uses_u8());
            assert_eq!(blocks.n(), n);
            assert_eq!(blocks.n_subspaces(), m);
            assert_eq!(blocks.n_blocks(), n.div_ceil(SCAN_BLOCK));
            assert_eq!(blocks.lanes8.len(), blocks.n_blocks() * m * SCAN_BLOCK);
            assert!(blocks.lanes16.is_empty());
            for i in 0..n {
                let (b, lane) = (i / SCAN_BLOCK, i % SCAN_BLOCK);
                for s in 0..m {
                    let cell = (b * m + s) * SCAN_BLOCK + lane;
                    assert_eq!(blocks.lanes8[cell] as u16, codes[i * m + s], "item {i} seg {s}");
                    assert_eq!(blocks.lb[cell], lb[i * m + s]);
                }
            }
        }
    }

    #[test]
    fn code_blocks_wide_codebooks_use_u16_lanes() {
        let (m, k, n) = (2usize, 300usize, SCAN_BLOCK + 5);
        let codes: Vec<u16> = (0..n * m).map(|i| (i % k) as u16).collect();
        let lb = vec![0.0; n * m];
        let blocks = CodeBlocks::build(&codes, &lb, m, k);
        assert!(!blocks.uses_u8());
        assert!(blocks.lanes8.is_empty());
        assert_eq!(blocks.lanes16.len(), blocks.n_blocks() * m * SCAN_BLOCK);
        for i in 0..n {
            let (b, lane) = (i / SCAN_BLOCK, i % SCAN_BLOCK);
            for s in 0..m {
                let cell = (b * m + s) * SCAN_BLOCK + lane;
                assert_eq!(blocks.lanes16[cell], codes[i * m + s]);
            }
        }
    }

    #[test]
    fn code_blocks_without_bounds_skip_the_lb_allocation() {
        let mut rng = Rng::new(311);
        let (m, k, n) = (4usize, 16usize, SCAN_BLOCK + 10);
        let codes: Vec<u16> = (0..n * m).map(|_| rng.below(k) as u16).collect();
        let blocks = CodeBlocks::build(&codes, &[], m, k);
        assert!(!blocks.has_bounds());
        assert!(blocks.lb.is_empty());
        assert_eq!(blocks.n(), n);
        // bounds-carrying build over the same codes reports has_bounds
        let lb = vec![0.5; n * m];
        assert!(CodeBlocks::build(&codes, &lb, m, k).has_bounds());
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn code_blocks_reject_out_of_range_codes() {
        let codes = vec![9u16, 1];
        let lb = vec![0.0; 2];
        CodeBlocks::build(&codes, &lb, 2, 8);
    }

    #[test]
    fn lb_self_is_lower_bound_of_dist() {
        let cb = toy_codebook(PqMetric::Dtw, 229);
        let mut rng = Rng::new(233);
        let mut scratch = DtwScratch::new(cb.sub_len);
        for _ in 0..50 {
            let q: Vec<f64> = (0..cb.sub_len).map(|_| rng.normal()).collect();
            let mut stats = EncodeStats::default();
            let out = encode_subspace(&q, 1, &cb, &mut scratch, &mut stats);
            assert!(out.lb_self_sq <= out.dist_sq + 1e-9);
        }
    }
}
