//! Cache-resident blocked scan kernel for the top-k hot path
//! (`docs/DESIGN.md` §6).
//!
//! The scalar scan loop pays one gather per subspace into the full
//! `M×K²` elastic LUT per database item — a memory-bound access pattern
//! over a table that does not fit L1/L2 at realistic `K`. This module
//! removes that bottleneck in three moves:
//!
//! 1. **Query-collapsed LUT** ([`CollapsedLut`]): for the symmetric
//!    modes, the query's `cx[m]` rows are sliced out of the full LUT
//!    once per query into a compact `M×K` table — the same shape the
//!    asymmetric path already uses — shrinking the per-scan working set
//!    by a factor of `K`.
//! 2. **Segment-major blocks** ([`super::encode::CodeBlocks`]): the
//!    inner loop streams contiguous code bytes per subspace instead of
//!    striding through row-major code words, with a `u8` fast path when
//!    `K <= 256`.
//! 3. **Pruning cascade** ([`scan_block`]): a caller-supplied threshold
//!    (the top-k collector's running admission bound) abandons items
//!    whose partial sum already exceeds it. Every kernel term is a
//!    non-negative squared distance, so a partial sum only ever grows —
//!    the abandon is *exact*, not approximate.
//!
//! Bit-identity is load-bearing: the collapsed table holds verbatim
//! copies of the scalar path's `f64` values and items accumulate in the
//! same `m = 0..M` order, so every emitted distance is bit-identical to
//! the scalar reference in all three modes (enforced by the proptests).

use std::borrow::Cow;

use super::codebook::Codebook;
use super::encode::{CodeBlocks, SCAN_BLOCK};

/// Per-query `M×K` lookup table in the kernel's collapsed form, plus
/// the diagonal-substitution state of the Keogh-patched mode.
///
/// The table is owned on the symmetric paths (the collapse genuinely
/// produces new data) and *borrowed* on the asymmetric path — the
/// query table already exists, and cloning `M·K` f64s per query would
/// be a needless memcpy on the exact hot path this kernel exists to
/// speed up.
#[derive(Debug, Clone)]
pub struct CollapsedLut<'a> {
    /// Flat `M×K` table: `table[s*K + c]` is the query's squared
    /// subspace-`s` distance to centroid `c`.
    table: Cow<'a, [f64]>,
    /// Subspace count `M`.
    m: usize,
    /// Codebook size `K`.
    k: usize,
    /// Keogh-patched mode: the query's code word and its squared self
    /// bounds. At the `cy[s] == cx[s]` slot the LUT term is 0 (distance
    /// of a centroid to itself), and the scalar path substitutes
    /// `max(lbx[s], lby[s])`; the kernel resolves the same substitution
    /// per item from this state plus the block's `lb` lane.
    diag: Option<(Vec<u16>, Vec<f64>)>,
}

impl<'a> CollapsedLut<'a> {
    /// Collapse the full `M×K²` symmetric LUT onto the query's rows.
    pub fn symmetric(cb: &Codebook, cx: &[u16]) -> Self {
        assert_eq!(cx.len(), cb.n_subspaces, "query code word has wrong M");
        let (m, k) = (cb.n_subspaces, cb.k);
        let kk = k * k;
        let mut table = Vec::with_capacity(m * k);
        for (s, &c) in cx.iter().enumerate() {
            let c = c as usize;
            assert!(c < k, "query code {c} out of range (K = {k})");
            let base = s * kk + c * k;
            table.extend_from_slice(&cb.lut_sq[base..base + k]);
        }
        CollapsedLut { table: Cow::Owned(table), m, k, diag: None }
    }

    /// Collapsed LUT for the Keogh-patched symmetric mode: `lbx` is the
    /// query's per-subspace squared reversed-Keogh self bound.
    pub fn patched(cb: &Codebook, cx: &[u16], lbx: &[f64]) -> Self {
        assert_eq!(lbx.len(), cb.n_subspaces, "self-bound row has wrong M");
        let mut lut = Self::symmetric(cb, cx);
        lut.diag = Some((cx.to_vec(), lbx.to_vec()));
        lut
    }

    /// Borrow an asymmetric query table (already `M×K`, from
    /// [`super::distance::asymmetric_table`]) — zero-copy.
    pub fn asymmetric(cb: &Codebook, table: &'a [f64]) -> Self {
        assert_eq!(table.len(), cb.n_subspaces * cb.k, "asymmetric table is not M×K");
        CollapsedLut { table: Cow::Borrowed(table), m: cb.n_subspaces, k: cb.k, diag: None }
    }

    /// Subspace count `M`.
    pub fn n_subspaces(&self) -> usize {
        self.m
    }

    /// The flat `M×K` table, whichever side owns it.
    #[inline]
    fn table(&self) -> &[f64] {
        match &self.table {
            Cow::Borrowed(t) => t,
            Cow::Owned(v) => v,
        }
    }

    /// Scalar reference: squared distance to one row-major code word.
    /// `lby` is the item's self-bound row; it is only read in patched
    /// mode (pass `&[]` otherwise). Bit-identical to the corresponding
    /// `pq::distance` scalar function.
    pub fn dist_sq(&self, cy: &[u16], lby: &[f64]) -> f64 {
        debug_assert_eq!(cy.len(), self.m);
        let table = self.table();
        let mut acc = 0.0;
        match &self.diag {
            None => {
                for (s, &c) in cy.iter().enumerate() {
                    acc += table[s * self.k + c as usize];
                }
            }
            Some((cx, lbx)) => {
                debug_assert_eq!(lby.len(), self.m);
                for (s, &c) in cy.iter().enumerate() {
                    acc += if c == cx[s] {
                        lbx[s].max(lby[s])
                    } else {
                        table[s * self.k + c as usize]
                    };
                }
            }
        }
        acc
    }

    /// Batch over a flat row-major code block: `out[i]` becomes the
    /// squared distance of item `i`. `lb` must parallel `codes` in
    /// patched mode and may be empty otherwise. Values are bit-identical
    /// to the per-item scalar path (same `m = 0..M` accumulation order).
    pub fn dist_sq_rows(&self, codes: &[u16], lb: &[f64], out: &mut [f64]) {
        let m = self.m;
        assert_eq!(codes.len() % m, 0, "ragged code block");
        assert_eq!(out.len(), codes.len() / m, "output slice mis-sized");
        match &self.diag {
            None => {
                let table = self.table();
                for (o, cy) in out.iter_mut().zip(codes.chunks_exact(m)) {
                    let mut acc = 0.0;
                    for (s, &c) in cy.iter().enumerate() {
                        acc += table[s * self.k + c as usize];
                    }
                    *o = acc;
                }
            }
            Some(..) => {
                assert_eq!(lb.len(), codes.len(), "self bounds must parallel codes");
                let rows = codes.chunks_exact(m).zip(lb.chunks_exact(m));
                for (o, (cy, lby)) in out.iter_mut().zip(rows) {
                    *o = self.dist_sq(cy, lby);
                }
            }
        }
    }
}

/// A code lane element: `u8` on the narrow path, `u16` on the wide one.
trait CodeLane: Copy {
    fn idx(self) -> usize;
}

impl CodeLane for u8 {
    #[inline(always)]
    fn idx(self) -> usize {
        self as usize
    }
}

impl CodeLane for u16 {
    #[inline(always)]
    fn idx(self) -> usize {
        self as usize
    }
}

/// Scan lanes `[lo, hi)` of one block, calling `emit(lane, d_sq)` for
/// every item that survives the pruning cascade.
///
/// `thr` is the caller's current admission bound (squared): after each
/// subspace except the last, items whose partial sum *strictly* exceeds
/// `thr` are abandoned. Since every term is a non-negative squared
/// distance, an abandoned item's full sum would also exceed `thr`, so
/// the abandon is exact — a top-k collector with threshold `thr` could
/// never have admitted it. Items that are emitted carry their full,
/// bit-identical squared distance (an emitted item may still exceed
/// `thr`; the caller's collector rejects it in `O(1)`). Pass
/// `f64::INFINITY` to disable pruning and emit every lane.
pub fn scan_block<F: FnMut(usize, f64)>(
    lut: &CollapsedLut,
    blocks: &CodeBlocks,
    block: usize,
    lo: usize,
    hi: usize,
    thr: f64,
    emit: F,
) {
    debug_assert!(lo <= hi && hi <= SCAN_BLOCK, "lane range out of bounds");
    debug_assert_eq!(lut.m, blocks.n_subspaces(), "LUT / blocks subspace mismatch");
    debug_assert_eq!(lut.k, blocks.k(), "LUT / blocks codebook mismatch");
    assert!(
        lut.diag.is_none() || blocks.has_bounds(),
        "patched scan requires blocks built with self bounds"
    );
    if blocks.uses_u8() {
        scan_block_impl(lut, &blocks.lanes8[..], &blocks.lb, block, lo, hi, thr, emit);
    } else {
        scan_block_impl(lut, &blocks.lanes16[..], &blocks.lb, block, lo, hi, thr, emit);
    }
}

#[allow(clippy::too_many_arguments)]
fn scan_block_impl<T: CodeLane, F: FnMut(usize, f64)>(
    lut: &CollapsedLut,
    lanes: &[T],
    lb: &[f64],
    block: usize,
    lo: usize,
    hi: usize,
    thr: f64,
    mut emit: F,
) {
    let (m, k) = (lut.m, lut.k);
    let table = lut.table();
    let base = block * m * SCAN_BLOCK;
    let mut acc = [0.0f64; SCAN_BLOCK];
    if thr == f64::INFINITY {
        // Streaming path: nothing can be pruned, so run the pure
        // segment-major loop the compiler can vectorise.
        for s in 0..m {
            let row = &table[s * k..(s + 1) * k];
            let seg = &lanes[base + s * SCAN_BLOCK..base + (s + 1) * SCAN_BLOCK];
            match &lut.diag {
                None => {
                    for (a, c) in acc[lo..hi].iter_mut().zip(&seg[lo..hi]) {
                        *a += row[c.idx()];
                    }
                }
                Some((cx, lbx)) => {
                    let cxs = cx[s] as usize;
                    let lbxs = lbx[s];
                    let lbseg = &lb[base + s * SCAN_BLOCK..base + (s + 1) * SCAN_BLOCK];
                    let items = acc[lo..hi].iter_mut().zip(&seg[lo..hi]).zip(&lbseg[lo..hi]);
                    for ((a, c), &b) in items {
                        let c = c.idx();
                        *a += if c == cxs { lbxs.max(b) } else { row[c] };
                    }
                }
            }
        }
        for (lane, &a) in acc.iter().enumerate().take(hi).skip(lo) {
            emit(lane, a);
        }
    } else {
        // Pruning cascade: accumulate segment-at-a-time over the list
        // of still-alive lanes, dropping lanes whose partial sum
        // already exceeds the threshold. The comparison keeps NaNs
        // (`!(NaN > thr)`), so pathological inputs are never pruned —
        // the collector's total order deals with them downstream.
        let mut alive = [0usize; SCAN_BLOCK];
        let mut n_alive = hi - lo;
        for (slot, lane) in alive[..n_alive].iter_mut().zip(lo..hi) {
            *slot = lane;
        }
        for s in 0..m {
            let row = &table[s * k..(s + 1) * k];
            let seg = &lanes[base + s * SCAN_BLOCK..base + (s + 1) * SCAN_BLOCK];
            match &lut.diag {
                None => {
                    for &lane in &alive[..n_alive] {
                        acc[lane] += row[seg[lane].idx()];
                    }
                }
                Some((cx, lbx)) => {
                    let cxs = cx[s] as usize;
                    let lbxs = lbx[s];
                    let lbseg = &lb[base + s * SCAN_BLOCK..base + (s + 1) * SCAN_BLOCK];
                    for &lane in &alive[..n_alive] {
                        let c = seg[lane].idx();
                        acc[lane] += if c == cxs { lbxs.max(lbseg[lane]) } else { row[c] };
                    }
                }
            }
            if s + 1 < m {
                let mut kept = 0usize;
                for slot in 0..n_alive {
                    let lane = alive[slot];
                    // Note: deliberately *not* `acc <= thr` — a NaN
                    // partial must be kept, not pruned.
                    let pruned = acc[lane] > thr;
                    if !pruned {
                        alive[kept] = lane;
                        kept += 1;
                    }
                }
                n_alive = kept;
                if n_alive == 0 {
                    return;
                }
            }
        }
        for &lane in &alive[..n_alive] {
            emit(lane, acc[lane]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::rng::Rng;
    use crate::pq::codebook::PqMetric;
    use crate::pq::distance::{
        asymmetric_sq, asymmetric_table, patched_symmetric_sq, symmetric_sq,
    };

    fn toy_codebook(m: usize, k: usize, l: usize, seed: u64) -> Codebook {
        let mut rng = Rng::new(seed);
        let per: Vec<Vec<f64>> =
            (0..m).map(|_| (0..k * l).map(|_| rng.normal()).collect()).collect();
        Codebook::build(per, l, Some(2), PqMetric::Dtw)
    }

    fn random_rows(rng: &mut Rng, n: usize, m: usize, k: usize) -> (Vec<u16>, Vec<f64>) {
        let codes = (0..n * m).map(|_| rng.below(k) as u16).collect();
        let lb = (0..n * m).map(|_| rng.uniform()).collect();
        (codes, lb)
    }

    /// Drive `scan_block` over every block of `blocks`, collecting
    /// `(item, d_sq)` for everything emitted.
    fn scan_all(lut: &CollapsedLut, blocks: &CodeBlocks, thr: f64) -> Vec<(usize, f64)> {
        let mut out = Vec::new();
        for b in 0..blocks.n_blocks() {
            let hi = (blocks.n() - b * SCAN_BLOCK).min(SCAN_BLOCK);
            scan_block(lut, blocks, b, 0, hi, thr, |lane, d| {
                out.push((b * SCAN_BLOCK + lane, d));
            });
        }
        out
    }

    #[test]
    fn collapsed_symmetric_is_bit_identical_to_scalar() {
        let cb = toy_codebook(3, 8, 6, 401);
        let mut rng = Rng::new(403);
        for n in [1usize, SCAN_BLOCK - 1, SCAN_BLOCK, SCAN_BLOCK + 1, 150] {
            let (codes, lb) = random_rows(&mut rng, n, 3, 8);
            let blocks = CodeBlocks::build(&codes, &lb, 3, 8);
            let cx: Vec<u16> = (0..3).map(|_| rng.below(8) as u16).collect();
            let lut = CollapsedLut::symmetric(&cb, &cx);
            let got = scan_all(&lut, &blocks, f64::INFINITY);
            assert_eq!(got.len(), n, "n={n}: every item must be emitted");
            for (i, d) in got {
                let cy = &codes[i * 3..(i + 1) * 3];
                let want = symmetric_sq(&cb, &cx, cy);
                assert_eq!(d.to_bits(), want.to_bits(), "n={n} item {i}");
                assert_eq!(lut.dist_sq(cy, &[]).to_bits(), want.to_bits());
            }
        }
    }

    #[test]
    fn collapsed_patched_is_bit_identical_to_scalar() {
        let cb = toy_codebook(4, 6, 5, 409);
        let mut rng = Rng::new(419);
        let n = SCAN_BLOCK + 9;
        let (mut codes, lb) = random_rows(&mut rng, n, 4, 6);
        let cx: Vec<u16> = (0..4).map(|_| rng.below(6) as u16).collect();
        let lbx: Vec<f64> = (0..4).map(|_| rng.uniform()).collect();
        // Force plenty of diagonal hits: every third item shares the
        // query's code in at least one subspace.
        for i in (0..n).step_by(3) {
            let s = i % 4;
            codes[i * 4 + s] = cx[s];
        }
        let blocks = CodeBlocks::build(&codes, &lb, 4, 6);
        let lut = CollapsedLut::patched(&cb, &cx, &lbx);
        let got = scan_all(&lut, &blocks, f64::INFINITY);
        assert_eq!(got.len(), n);
        for (i, d) in got {
            let cy = &codes[i * 4..(i + 1) * 4];
            let lby = &lb[i * 4..(i + 1) * 4];
            let want = patched_symmetric_sq(&cb, &cx, cy, &lbx, lby);
            assert_eq!(d.to_bits(), want.to_bits(), "item {i}");
            assert_eq!(lut.dist_sq(cy, lby).to_bits(), want.to_bits());
        }
    }

    #[test]
    fn collapsed_asymmetric_is_bit_identical_to_scalar() {
        let cb = toy_codebook(2, 10, 7, 421);
        let mut rng = Rng::new(431);
        let n = 2 * SCAN_BLOCK;
        let (codes, lb) = random_rows(&mut rng, n, 2, 10);
        let subs: Vec<Vec<f64>> = (0..2)
            .map(|_| (0..cb.sub_len).map(|_| rng.normal()).collect())
            .collect();
        let table = asymmetric_table(&cb, &subs);
        let blocks = CodeBlocks::build(&codes, &lb, 2, 10);
        let lut = CollapsedLut::asymmetric(&cb, &table);
        for (i, d) in scan_all(&lut, &blocks, f64::INFINITY) {
            let cy = &codes[i * 2..(i + 1) * 2];
            let want = asymmetric_sq(&cb, &table, cy);
            assert_eq!(d.to_bits(), want.to_bits(), "item {i}");
        }
    }

    #[test]
    fn u16_lane_path_matches_scalar() {
        // K > 256 forces the wide lanes; keep L tiny so the O(K²) LUT
        // precompute stays cheap.
        let cb = toy_codebook(1, 260, 3, 433);
        let mut rng = Rng::new(439);
        let n = SCAN_BLOCK + 3;
        let (codes, lb) = random_rows(&mut rng, n, 1, 260);
        let blocks = CodeBlocks::build(&codes, &lb, 1, 260);
        assert!(!blocks.uses_u8());
        let cx = vec![rng.below(260) as u16];
        let lut = CollapsedLut::symmetric(&cb, &cx);
        let got = scan_all(&lut, &blocks, f64::INFINITY);
        assert_eq!(got.len(), n);
        for (i, d) in got {
            let want = symmetric_sq(&cb, &cx, &codes[i..i + 1]);
            assert_eq!(d.to_bits(), want.to_bits(), "item {i}");
        }
    }

    #[test]
    fn pruning_is_exact_and_emits_all_admissible_items() {
        let cb = toy_codebook(4, 12, 6, 443);
        let mut rng = Rng::new(449);
        let n = 3 * SCAN_BLOCK + 17;
        let (codes, lb) = random_rows(&mut rng, n, 4, 12);
        let blocks = CodeBlocks::build(&codes, &lb, 4, 12);
        let cx: Vec<u16> = (0..4).map(|_| rng.below(12) as u16).collect();
        let lut = CollapsedLut::symmetric(&cb, &cx);
        let full: Vec<f64> = (0..n)
            .map(|i| symmetric_sq(&cb, &cx, &codes[i * 4..(i + 1) * 4]))
            .collect();
        // Threshold at a mid-range distance: everything at or under it
        // must be emitted with bit-identical values; everything pruned
        // must be strictly over it.
        let mut sorted = full.clone();
        sorted.sort_by(f64::total_cmp);
        let thr = sorted[n / 2];
        let got = scan_all(&lut, &blocks, thr);
        let emitted: std::collections::HashMap<usize, f64> = got.into_iter().collect();
        for (i, &want) in full.iter().enumerate() {
            match emitted.get(&i) {
                Some(d) => assert_eq!(d.to_bits(), want.to_bits(), "item {i}"),
                None => assert!(want > thr, "item {i} (d={want}) pruned at thr={thr}"),
            }
        }
        // Oracle check of the cascade semantics: an item is abandoned
        // iff one of its prefix sums — checked after every segment but
        // the last — strictly exceeds the threshold.
        let mut want_pruned = 0usize;
        for i in 0..n {
            let mut acc = 0.0;
            for s in 0..3 {
                acc += cb.lut_sq(s, cx[s] as usize, codes[i * 4 + s] as usize);
                if acc > thr {
                    want_pruned += 1;
                    break;
                }
            }
        }
        assert_eq!(emitted.len(), n - want_pruned, "cascade pruned a different set");
    }

    #[test]
    #[should_panic(expected = "requires blocks built with self bounds")]
    fn patched_scan_over_boundless_blocks_is_rejected() {
        let cb = toy_codebook(2, 4, 4, 463);
        let codes = vec![0u16, 1, 2, 3];
        let blocks = CodeBlocks::build(&codes, &[], 2, 4);
        let lut = CollapsedLut::patched(&cb, &[0, 1], &[0.1, 0.2]);
        scan_block(&lut, &blocks, 0, 0, 2, f64::INFINITY, |_, _| {});
    }

    #[test]
    fn lane_subranges_scan_only_their_lanes() {
        let cb = toy_codebook(2, 5, 4, 457);
        let mut rng = Rng::new(461);
        let (codes, lb) = random_rows(&mut rng, SCAN_BLOCK, 2, 5);
        let blocks = CodeBlocks::build(&codes, &lb, 2, 5);
        let cx = vec![1u16, 3];
        let lut = CollapsedLut::symmetric(&cb, &cx);
        let mut seen = Vec::new();
        scan_block(&lut, &blocks, 0, 5, 20, f64::INFINITY, |lane, _| seen.push(lane));
        assert_eq!(seen, (5..20).collect::<Vec<_>>());
    }
}
