//! Product quantization under time warping — the paper's contribution.
//!
//! Pipeline:
//!
//! 1. [`prealign`] cuts each series into `M` subspaces, optionally snapping
//!    boundaries to MODWT structure points and re-interpolating to a fixed
//!    sub-length.
//! 2. [`kmeans`] (+ [`dba`]) learns a `K`-centroid codebook per subspace.
//! 3. [`codebook`] stores centroids, their Keogh envelopes and the `M×K×K`
//!    symmetric distance LUT.
//! 4. [`encode`] maps a subspace vector to its nearest centroid id using
//!    the LB_Kim → reversed-LB_Keogh cascade with early-abandoned DTW.
//! 5. [`distance`] computes symmetric / asymmetric / Keogh-patched
//!    approximate distances between codes.
//! 6. [`quantizer`] is the user-facing API tying it together.
//! 7. [`scan`] is the blocked scan kernel for the top-k hot path:
//!    query-collapsed `M×K` LUTs over segment-major code blocks with an
//!    exact pruning cascade (`docs/DESIGN.md` §6).

pub mod codebook;
pub mod dba;
pub mod distance;
pub mod encode;
pub mod kmeans;
pub mod prealign;
pub mod quantizer;
pub mod scan;

pub use codebook::Codebook;
pub use encode::{CodeBlocks, SCAN_BLOCK};
pub use quantizer::{EncodedDataset, PqConfig, PqMetric, PrealignConfig, ProductQuantizer};
pub use scan::CollapsedLut;
