//! Approximate distances between PQ codes (paper §3.3, §4.2).
//!
//! - **Symmetric**: both series encoded; distance is `O(M)` LUT lookups.
//! - **Keogh-patched symmetric**: clustering variant — when two series map
//!   to the *same* centroid in a subspace the LUT term is 0, which
//!   collapses distance rankings; the patch substitutes the larger of the
//!   two stored reversed-Keogh bounds, guaranteed to lie between 0 and the
//!   true subspace distance.
//! - **Asymmetric**: only the database side encoded; a query-specific
//!   `M×K` table is built once with real DTW, then each database distance
//!   is `O(M)` lookups into it.

use super::codebook::{Codebook, PqMetric};
use super::scan::CollapsedLut;
use crate::distance::dtw::{dtw_sq_scratch, DtwScratch};
use crate::distance::euclidean::euclidean_sq;

/// Squared symmetric PQ distance between two code words.
#[inline]
pub fn symmetric_sq(cb: &Codebook, cx: &[u16], cy: &[u16]) -> f64 {
    debug_assert_eq!(cx.len(), cb.n_subspaces);
    debug_assert_eq!(cy.len(), cb.n_subspaces);
    let k = cb.k;
    let kk = k * k;
    let mut s = 0.0;
    for m in 0..cb.n_subspaces {
        s += cb.lut_sq[m * kk + cx[m] as usize * k + cy[m] as usize];
    }
    s
}

/// Symmetric PQ distance (`sqrt` of [`symmetric_sq`]).
#[inline]
pub fn symmetric(cb: &Codebook, cx: &[u16], cy: &[u16]) -> f64 {
    symmetric_sq(cb, cx, cy).sqrt()
}

/// Squared Keogh-patched symmetric distance. `lbx`/`lby` are the stored
/// per-subspace squared reversed-Keogh bounds of each series to its own
/// centroid ([`super::encode::SubspaceCode::lb_self_sq`]).
#[inline]
pub fn patched_symmetric_sq(
    cb: &Codebook,
    cx: &[u16],
    cy: &[u16],
    lbx: &[f64],
    lby: &[f64],
) -> f64 {
    let k = cb.k;
    let kk = k * k;
    let mut s = 0.0;
    for m in 0..cb.n_subspaces {
        let (i, j) = (cx[m] as usize, cy[m] as usize);
        if i == j {
            // Same centroid: LUT says 0; replace with the Keogh bound,
            // which lies in [0, d(x^m, y^m)] — see paper §4.2.
            s += lbx[m].max(lby[m]);
        } else {
            s += cb.lut_sq[m * kk + i * k + j];
        }
    }
    s
}

/// Keogh-patched symmetric distance.
#[inline]
pub fn patched_symmetric(
    cb: &Codebook,
    cx: &[u16],
    cy: &[u16],
    lbx: &[f64],
    lby: &[f64],
) -> f64 {
    patched_symmetric_sq(cb, cx, cy, lbx, lby).sqrt()
}

/// Build the asymmetric distance table for a query: `table[m·K + k]` is
/// the squared distance between the query's `m`-th subspace vector and
/// centroid `k`. Cost: `M×K` DTW (or ED) evaluations, paid once per query.
pub fn asymmetric_table(cb: &Codebook, query_subspaces: &[Vec<f64>]) -> Vec<f64> {
    assert_eq!(query_subspaces.len(), cb.n_subspaces);
    let mut table = vec![0.0; cb.n_subspaces * cb.k];
    let mut scratch = DtwScratch::new(cb.sub_len);
    for (m, q) in query_subspaces.iter().enumerate() {
        for k in 0..cb.k {
            let c = cb.centroid(m, k);
            table[m * cb.k + k] = match cb.metric {
                PqMetric::Dtw => dtw_sq_scratch(q, c, cb.window, f64::INFINITY, &mut scratch),
                PqMetric::Euclidean => euclidean_sq(q, c),
            };
        }
    }
    table
}

/// Squared asymmetric distance of one encoded database item against a
/// query table from [`asymmetric_table`].
#[inline]
pub fn asymmetric_sq(cb: &Codebook, table: &[f64], codes: &[u16]) -> f64 {
    let mut s = 0.0;
    for m in 0..cb.n_subspaces {
        s += table[m * cb.k + codes[m] as usize];
    }
    s
}

/// Batch variant of [`symmetric_sq`]: squared distances of `cx` against
/// every code word in the flat block `codes` (`codes.len() / M` items,
/// row-major), appended to `out`. A thin wrapper over the collapsed-LUT
/// kernel ([`CollapsedLut`]): the output is sized once and written
/// through a slice (no per-item reserve/push), and the per-item values
/// are bit-identical to the per-item call.
pub fn symmetric_sq_batch(cb: &Codebook, cx: &[u16], codes: &[u16], out: &mut Vec<f64>) {
    let m = cb.n_subspaces;
    debug_assert_eq!(codes.len() % m, 0, "ragged code block");
    let start = out.len();
    out.resize(start + codes.len() / m, 0.0);
    CollapsedLut::symmetric(cb, cx).dist_sq_rows(codes, &[], &mut out[start..]);
}

/// Batch variant of [`asymmetric_sq`] over a flat block of code words,
/// appended to `out`. Same wrapper shape as [`symmetric_sq_batch`];
/// computes exactly the same f64 values as the per-item call (the
/// IVF-vs-exhaustive equivalence tests rely on bit-identical results
/// between the two paths).
pub fn asymmetric_sq_batch(cb: &Codebook, table: &[f64], codes: &[u16], out: &mut Vec<f64>) {
    let m = cb.n_subspaces;
    debug_assert_eq!(codes.len() % m, 0, "ragged code block");
    let start = out.len();
    out.resize(start + codes.len() / m, 0.0);
    CollapsedLut::asymmetric(cb, table).dist_sq_rows(codes, &[], &mut out[start..]);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::rng::Rng;
    use crate::distance::dtw::dtw_sq;
    use crate::pq::encode::{encode_subspace, EncodeStats};

    fn toy_codebook() -> Codebook {
        let mut rng = Rng::new(239);
        let (m, k, l) = (4, 8, 10);
        let per: Vec<Vec<f64>> =
            (0..m).map(|_| (0..k * l).map(|_| rng.normal()).collect()).collect();
        Codebook::build(per, l, Some(2), PqMetric::Dtw)
    }

    #[test]
    fn symmetric_equals_manual_lut_sum() {
        let cb = toy_codebook();
        let cx = vec![1u16, 3, 0, 7];
        let cy = vec![2u16, 3, 5, 7];
        let mut want = 0.0;
        for m in 0..4 {
            want += cb.lut_sq(m, cx[m] as usize, cy[m] as usize);
        }
        assert!((symmetric_sq(&cb, &cx, &cy) - want).abs() < 1e-12);
        assert!((symmetric(&cb, &cx, &cy) - want.sqrt()).abs() < 1e-12);
    }

    #[test]
    fn symmetric_zero_iff_equal_codes() {
        let cb = toy_codebook();
        let cx = vec![1u16, 2, 3, 4];
        assert_eq!(symmetric_sq(&cb, &cx, &cx), 0.0);
    }

    #[test]
    fn patched_distance_breaks_zero_ties() {
        let cb = toy_codebook();
        let mut rng = Rng::new(241);
        let mut scratch = crate::distance::dtw::DtwScratch::new(cb.sub_len);
        // Two distinct series near the same centroids.
        let mut make = |rng: &mut Rng| -> (Vec<u16>, Vec<f64>) {
            let mut codes = Vec::new();
            let mut lbs = Vec::new();
            for m in 0..cb.n_subspaces {
                let base = cb.centroid(m, 3).to_vec();
                let q: Vec<f64> = base.iter().map(|v| v + 0.05 * rng.normal()).collect();
                let mut st = EncodeStats::default();
                let out = encode_subspace(&q, m, &cb, &mut scratch, &mut st);
                codes.push(out.code);
                lbs.push(out.lb_self_sq);
            }
            (codes, lbs)
        };
        let (cx, lbx) = make(&mut rng);
        let (cy, lby) = make(&mut rng);
        if cx == cy {
            let plain = symmetric_sq(&cb, &cx, &cy);
            let patched = patched_symmetric_sq(&cb, &cx, &cy, &lbx, &lby);
            assert_eq!(plain, 0.0);
            assert!(patched >= 0.0);
            // patched >= plain always
            assert!(patched >= plain);
        }
    }

    #[test]
    fn patched_equals_plain_when_codes_differ() {
        let cb = toy_codebook();
        let cx = vec![0u16, 1, 2, 3];
        let cy = vec![4u16, 5, 6, 7];
        let lb = vec![9.9; 4];
        assert_eq!(
            patched_symmetric_sq(&cb, &cx, &cy, &lb, &lb),
            symmetric_sq(&cb, &cx, &cy)
        );
    }

    #[test]
    fn asymmetric_table_matches_direct_dtw() {
        let cb = toy_codebook();
        let mut rng = Rng::new(251);
        let subs: Vec<Vec<f64>> = (0..cb.n_subspaces)
            .map(|_| (0..cb.sub_len).map(|_| rng.normal()).collect())
            .collect();
        let table = asymmetric_table(&cb, &subs);
        for m in 0..cb.n_subspaces {
            for k in 0..cb.k {
                let want = dtw_sq(&subs[m], cb.centroid(m, k), cb.window);
                assert!((table[m * cb.k + k] - want).abs() < 1e-12);
            }
        }
        // asymmetric distance of a code word = sum of its table cells
        let codes = vec![1u16, 0, 7, 4];
        let want: f64 = (0..4).map(|m| table[m * cb.k + codes[m] as usize]).sum();
        assert!((asymmetric_sq(&cb, &table, &codes) - want).abs() < 1e-12);
    }

    #[test]
    fn batch_helpers_match_per_item_calls() {
        let cb = toy_codebook();
        let mut rng = Rng::new(263);
        // a flat block of 5 random code words
        let codes: Vec<u16> = (0..5 * cb.n_subspaces)
            .map(|_| (rng.normal().abs() * 1e3) as u16 % cb.k as u16)
            .collect();
        let cx = vec![1u16, 3, 0, 7];
        let mut out = Vec::new();
        symmetric_sq_batch(&cb, &cx, &codes, &mut out);
        assert_eq!(out.len(), 5);
        for (i, cy) in codes.chunks_exact(cb.n_subspaces).enumerate() {
            assert_eq!(out[i], symmetric_sq(&cb, &cx, cy), "sym item {i}");
        }
        let subs: Vec<Vec<f64>> = (0..cb.n_subspaces)
            .map(|_| (0..cb.sub_len).map(|_| rng.normal()).collect())
            .collect();
        let table = asymmetric_table(&cb, &subs);
        let mut out = Vec::new();
        asymmetric_sq_batch(&cb, &table, &codes, &mut out);
        for (i, cy) in codes.chunks_exact(cb.n_subspaces).enumerate() {
            assert_eq!(out[i], asymmetric_sq(&cb, &table, cy), "asym item {i}");
        }
    }

    #[test]
    fn asymmetric_tighter_than_symmetric_on_average() {
        // Asymmetric uses the raw query, so its expected distortion is
        // lower: check aggregate behaviour on random data.
        let cb = toy_codebook();
        let mut rng = Rng::new(257);
        let mut scratch = crate::distance::dtw::DtwScratch::new(cb.sub_len);
        let mut sym_err = 0.0;
        let mut asym_err = 0.0;
        let trials = 30;
        for _ in 0..trials {
            let xs: Vec<Vec<f64>> = (0..cb.n_subspaces)
                .map(|_| (0..cb.sub_len).map(|_| rng.normal()).collect())
                .collect();
            let ys: Vec<Vec<f64>> = (0..cb.n_subspaces)
                .map(|_| (0..cb.sub_len).map(|_| rng.normal()).collect())
                .collect();
            // true subspace-sum distance
            let truth: f64 = xs
                .iter()
                .zip(ys.iter())
                .map(|(x, y)| dtw_sq(x, y, cb.window))
                .sum();
            let mut st = EncodeStats::default();
            let cx: Vec<u16> = (0..cb.n_subspaces)
                .map(|m| encode_subspace(&xs[m], m, &cb, &mut scratch, &mut st).code)
                .collect();
            let cy: Vec<u16> = (0..cb.n_subspaces)
                .map(|m| encode_subspace(&ys[m], m, &cb, &mut scratch, &mut st).code)
                .collect();
            let sym = symmetric_sq(&cb, &cx, &cy);
            let table = asymmetric_table(&cb, &xs);
            let asym = asymmetric_sq(&cb, &table, &cy);
            sym_err += (sym - truth).abs();
            asym_err += (asym - truth).abs();
        }
        assert!(
            asym_err <= sym_err,
            "asym_err={asym_err} should be <= sym_err={sym_err}"
        );
    }
}
