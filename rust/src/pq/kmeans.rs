//! k-means over subspace vectors with pluggable geometry:
//!
//! - **DBA k-means** (paper §3.1): DTW assignment + DTW-barycenter update,
//!   used to learn the PQDTW codebook;
//! - **Euclidean k-means**: lock-step assignment + arithmetic-mean update,
//!   used by the `PQ_ED` baseline.
//!
//! Initialization is k-means++ under the chosen metric. Empty clusters are
//! re-seeded from the member of the most populous cluster farthest from
//! its centroid (a standard fix that keeps exactly `K` codewords).

use crate::core::rng::Rng;
use crate::distance::dtw::{dtw_sq_scratch, DtwScratch};
use crate::distance::euclidean::euclidean_sq;
use crate::pq::dba::dba;

/// Metric/update geometry for the clustering.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum KmeansGeometry {
    /// DTW assignment (optional band, in samples) + DBA update.
    Dtw { window: Option<usize>, dba_iters: usize },
    /// Squared-Euclidean assignment + mean update.
    Euclidean,
}

impl KmeansGeometry {
    #[inline]
    fn dist_sq(&self, a: &[f64], b: &[f64], scratch: &mut DtwScratch) -> f64 {
        match self {
            KmeansGeometry::Dtw { window, .. } => {
                dtw_sq_scratch(a, b, *window, f64::INFINITY, scratch)
            }
            KmeansGeometry::Euclidean => euclidean_sq(a, b),
        }
    }
}

/// Result of a k-means run.
#[derive(Debug, Clone)]
pub struct KmeansResult {
    /// Flat centroid buffer, `k × dim` row-major.
    pub centroids: Vec<f64>,
    /// Vector length of each centroid.
    pub dim: usize,
    /// Cluster id per input row.
    pub assignment: Vec<usize>,
    /// Final within-cluster sum of squared distances.
    pub inertia: f64,
    /// Iterations executed.
    pub iterations: usize,
}

impl KmeansResult {
    /// Borrow centroid `k`.
    pub fn centroid(&self, k: usize) -> &[f64] {
        &self.centroids[k * self.dim..(k + 1) * self.dim]
    }

    /// Number of centroids.
    pub fn k(&self) -> usize {
        if self.dim == 0 { 0 } else { self.centroids.len() / self.dim }
    }
}

/// k-means++ seeding: first center uniform, then proportional to squared
/// distance to the nearest chosen center.
fn kmeanspp_init(
    rows: &[&[f64]],
    k: usize,
    geo: KmeansGeometry,
    rng: &mut Rng,
) -> Vec<usize> {
    let n = rows.len();
    let mut scratch = DtwScratch::new(rows[0].len());
    let mut chosen = Vec::with_capacity(k);
    chosen.push(rng.below(n));
    let mut d2: Vec<f64> = rows
        .iter()
        .map(|r| geo.dist_sq(r, rows[chosen[0]], &mut scratch))
        .collect();
    while chosen.len() < k {
        let total: f64 = d2.iter().sum();
        let next = if total <= 0.0 {
            // All points coincide with a center; fall back to uniform.
            rng.below(n)
        } else {
            let mut target = rng.uniform() * total;
            let mut pick = n - 1;
            for (i, &w) in d2.iter().enumerate() {
                target -= w;
                if target <= 0.0 {
                    pick = i;
                    break;
                }
            }
            pick
        };
        chosen.push(next);
        for (i, r) in rows.iter().enumerate() {
            let d = geo.dist_sq(r, rows[next], &mut scratch);
            if d < d2[i] {
                d2[i] = d;
            }
        }
    }
    chosen
}

/// Run k-means over `rows` (each of equal length) with `k` clusters.
///
/// `max_iters` bounds the assign/update loop; the loop stops early when
/// the assignment reaches a fixed point.
pub fn kmeans(
    rows: &[&[f64]],
    k: usize,
    geo: KmeansGeometry,
    max_iters: usize,
    rng: &mut Rng,
) -> KmeansResult {
    let n = rows.len();
    assert!(n > 0, "kmeans: empty input");
    let dim = rows[0].len();
    assert!(rows.iter().all(|r| r.len() == dim), "kmeans: ragged rows");
    let k = k.min(n);

    let seeds = kmeanspp_init(rows, k, geo, rng);
    let mut centroids: Vec<f64> = Vec::with_capacity(k * dim);
    for &s in &seeds {
        centroids.extend_from_slice(rows[s]);
    }

    let mut scratch = DtwScratch::new(dim);
    let mut assignment = vec![usize::MAX; n];
    let mut inertia = f64::INFINITY;
    let mut iterations = 0;

    for it in 0..max_iters {
        iterations = it + 1;
        // --- assignment step ---
        let mut changed = false;
        let mut new_inertia = 0.0;
        for (i, row) in rows.iter().enumerate() {
            let mut best = f64::INFINITY;
            let mut best_k = 0;
            for c in 0..k {
                let d = geo.dist_sq(row, &centroids[c * dim..(c + 1) * dim], &mut scratch);
                if d < best {
                    best = d;
                    best_k = c;
                }
            }
            if assignment[i] != best_k {
                assignment[i] = best_k;
                changed = true;
            }
            new_inertia += best;
        }
        inertia = new_inertia;
        if !changed && it > 0 {
            break;
        }

        // --- empty-cluster repair ---
        let mut counts = vec![0usize; k];
        for &a in &assignment {
            counts[a] += 1;
        }
        for c in 0..k {
            if counts[c] == 0 {
                // Steal the farthest member of the largest cluster.
                let big = (0..k).max_by_key(|&x| counts[x]).unwrap();
                let (mut far_i, mut far_d) = (0usize, -1.0);
                for (i, row) in rows.iter().enumerate() {
                    if assignment[i] == big {
                        let d = geo.dist_sq(
                            row,
                            &centroids[big * dim..(big + 1) * dim],
                            &mut scratch,
                        );
                        if d > far_d {
                            far_d = d;
                            far_i = i;
                        }
                    }
                }
                assignment[far_i] = c;
                counts[c] += 1;
                counts[big] -= 1;
                centroids[c * dim..(c + 1) * dim].copy_from_slice(rows[far_i]);
            }
        }

        // --- update step ---
        match geo {
            KmeansGeometry::Euclidean => {
                let mut sums = vec![0.0; k * dim];
                let mut counts = vec![0usize; k];
                for (i, row) in rows.iter().enumerate() {
                    let a = assignment[i];
                    counts[a] += 1;
                    for (j, &v) in row.iter().enumerate() {
                        sums[a * dim + j] += v;
                    }
                }
                for c in 0..k {
                    if counts[c] > 0 {
                        for j in 0..dim {
                            centroids[c * dim + j] = sums[c * dim + j] / counts[c] as f64;
                        }
                    }
                }
            }
            KmeansGeometry::Dtw { window, dba_iters } => {
                for c in 0..k {
                    let members: Vec<&[f64]> = rows
                        .iter()
                        .enumerate()
                        .filter(|(i, _)| assignment[*i] == c)
                        .map(|(_, r)| *r)
                        .collect();
                    if !members.is_empty() {
                        let init = centroids[c * dim..(c + 1) * dim].to_vec();
                        let updated = dba(&init, &members, window, dba_iters);
                        centroids[c * dim..(c + 1) * dim].copy_from_slice(&updated);
                    }
                }
            }
        }
    }

    KmeansResult { centroids, dim, assignment, inertia, iterations }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn two_blob_rows(rng: &mut Rng, n_per: usize, dim: usize) -> Vec<Vec<f64>> {
        let mut rows = Vec::new();
        for c in 0..2 {
            let offset = if c == 0 { -3.0 } else { 3.0 };
            for _ in 0..n_per {
                rows.push((0..dim).map(|_| offset + 0.3 * rng.normal()).collect());
            }
        }
        rows
    }

    #[test]
    fn euclidean_separates_two_blobs() {
        let mut rng = Rng::new(149);
        let rows = two_blob_rows(&mut rng, 20, 8);
        let refs: Vec<&[f64]> = rows.iter().map(|r| r.as_slice()).collect();
        let res = kmeans(&refs, 2, KmeansGeometry::Euclidean, 50, &mut rng);
        // All of blob 0 in one cluster, all of blob 1 in the other.
        let a0 = res.assignment[0];
        assert!(res.assignment[..20].iter().all(|&a| a == a0));
        assert!(res.assignment[20..].iter().all(|&a| a != a0));
    }

    #[test]
    fn dtw_separates_shifted_shapes() {
        // Class A: early peak; class B: valley. DTW k-means must separate
        // them even with phase jitter within a class.
        let mut rng = Rng::new(151);
        let mut rows: Vec<Vec<f64>> = Vec::new();
        for _ in 0..10 {
            let shift = rng.below(4);
            let mut v = vec![0.0; 20];
            for (j, x) in v.iter_mut().enumerate().skip(4 + shift).take(4) {
                *x = 2.0 + 0.05 * (j as f64);
            }
            rows.push(v);
        }
        for _ in 0..10 {
            let shift = rng.below(4);
            let mut v = vec![0.0; 20];
            for x in v.iter_mut().skip(4 + shift).take(4) {
                *x = -2.0;
            }
            rows.push(v);
        }
        let refs: Vec<&[f64]> = rows.iter().map(|r| r.as_slice()).collect();
        let geo = KmeansGeometry::Dtw { window: None, dba_iters: 3 };
        let res = kmeans(&refs, 2, geo, 20, &mut rng);
        let a0 = res.assignment[0];
        assert!(res.assignment[..10].iter().all(|&a| a == a0), "{:?}", res.assignment);
        assert!(res.assignment[10..].iter().all(|&a| a != a0), "{:?}", res.assignment);
    }

    #[test]
    fn k_clamped_to_n() {
        let mut rng = Rng::new(157);
        let rows = vec![vec![1.0, 2.0], vec![3.0, 4.0]];
        let refs: Vec<&[f64]> = rows.iter().map(|r| r.as_slice()).collect();
        let res = kmeans(&refs, 10, KmeansGeometry::Euclidean, 5, &mut rng);
        assert_eq!(res.k(), 2);
    }

    #[test]
    fn no_empty_clusters() {
        let mut rng = Rng::new(163);
        let rows: Vec<Vec<f64>> =
            (0..30).map(|_| (0..6).map(|_| rng.normal()).collect()).collect();
        let refs: Vec<&[f64]> = rows.iter().map(|r| r.as_slice()).collect();
        for geo in [
            KmeansGeometry::Euclidean,
            KmeansGeometry::Dtw { window: Some(2), dba_iters: 2 },
        ] {
            let res = kmeans(&refs, 8, geo, 15, &mut rng);
            let mut counts = vec![0usize; res.k()];
            for &a in &res.assignment {
                counts[a] += 1;
            }
            assert!(counts.iter().all(|&c| c > 0), "{counts:?}");
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let mut rng_rows = Rng::new(167);
        let rows = two_blob_rows(&mut rng_rows, 10, 5);
        let refs: Vec<&[f64]> = rows.iter().map(|r| r.as_slice()).collect();
        let r1 = kmeans(&refs, 3, KmeansGeometry::Euclidean, 20, &mut Rng::new(1));
        let r2 = kmeans(&refs, 3, KmeansGeometry::Euclidean, 20, &mut Rng::new(1));
        assert_eq!(r1.assignment, r2.assignment);
        assert_eq!(r1.centroids, r2.centroids);
    }

    #[test]
    fn inertia_reported_finite() {
        let mut rng = Rng::new(173);
        let rows = two_blob_rows(&mut rng, 8, 4);
        let refs: Vec<&[f64]> = rows.iter().map(|r| r.as_slice()).collect();
        let res = kmeans(&refs, 2, KmeansGeometry::Euclidean, 10, &mut rng);
        assert!(res.inertia.is_finite());
        assert!(res.inertia >= 0.0);
    }
}
