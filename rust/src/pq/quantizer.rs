//! User-facing product quantizer: configuration, training, encoding and
//! the memory model from paper §3.4.

use anyhow::{bail, Result};

use super::codebook::Codebook;
pub use super::codebook::PqMetric;
use super::distance as pqdist;
use super::encode::{encode_subspace, CodeBlocks, EncodeStats};
use super::kmeans::{kmeans, KmeansGeometry};
use super::prealign::Segmenter;
use crate::core::rng::Rng;
use crate::core::series::Dataset;
use crate::distance::dtw::DtwScratch;

/// MODWT pre-alignment settings (paper §3.5).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PrealignConfig {
    /// Wavelet decomposition level `J`.
    pub level: usize,
    /// Tail as a fraction of the subspace length (e.g. `0.2` ⇒ the split
    /// may move back by up to 20 % of `D/M`).
    pub tail_frac: f64,
}

impl Default for PrealignConfig {
    fn default() -> Self {
        PrealignConfig { level: 2, tail_frac: 0.15 }
    }
}

/// Product quantizer hyper-parameters.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PqConfig {
    /// Number of subspaces `M`.
    pub n_subspaces: usize,
    /// Codebook size `K` (clamped to the training-set size, as in the
    /// paper's "or all time series in the training set if there are
    /// less examples").
    pub codebook_size: usize,
    /// Quantization warping window as a fraction of the subspace length;
    /// `>= 1.0` means unconstrained.
    pub window_frac: f64,
    /// DTW (PQDTW) or Euclidean (PQ_ED).
    pub metric: PqMetric,
    /// Optional MODWT pre-alignment.
    pub prealign: Option<PrealignConfig>,
    /// Max k-means assign/update iterations.
    pub kmeans_iters: usize,
    /// DBA refinement steps per k-means update.
    pub dba_iters: usize,
    /// Optional cap on the number of training series used to learn the
    /// codebook (PQ classically trains on a subset).
    pub train_subsample: Option<usize>,
}

impl Default for PqConfig {
    fn default() -> Self {
        PqConfig {
            n_subspaces: 4,
            codebook_size: 256,
            window_frac: 0.1,
            metric: PqMetric::Dtw,
            prealign: None,
            kmeans_iters: 10,
            dba_iters: 3,
            train_subsample: None,
        }
    }
}

/// A dataset re-represented as PQ codes.
#[derive(Debug, Clone)]
pub struct EncodedDataset {
    /// Codes, flat `N × M` row-major.
    pub codes: Vec<u16>,
    /// Squared reversed-Keogh self bounds, flat `N × M` (zeros under ED).
    pub lb_self_sq: Vec<f64>,
    /// Number of subspaces.
    pub n_subspaces: usize,
    /// Labels carried over from the source dataset (may be empty).
    pub labels: Vec<i64>,
    /// Aggregated encoding work counters.
    pub stats: EncodeStats,
}

impl EncodedDataset {
    /// Number of encoded series.
    pub fn n(&self) -> usize {
        if self.n_subspaces == 0 { 0 } else { self.codes.len() / self.n_subspaces }
    }

    /// Code word of series `i`.
    #[inline]
    pub fn code(&self, i: usize) -> &[u16] {
        &self.codes[i * self.n_subspaces..(i + 1) * self.n_subspaces]
    }

    /// Self-bound row of series `i`.
    #[inline]
    pub fn lb_self(&self, i: usize) -> &[f64] {
        &self.lb_self_sq[i * self.n_subspaces..(i + 1) * self.n_subspaces]
    }

    /// Blocked segment-major copy of the codes for the scan kernel
    /// (`k` is the codebook size, deciding the `u8`/`u16` lane width —
    /// see [`CodeBlocks`]). Derived state: build once per database,
    /// scan many. The blocked self bounds are omitted — they are only
    /// read by the Keogh-patched scan mode, which the serving paths
    /// never use; call [`CodeBlocks::build`] with `lb_self_sq` directly
    /// to enable patched scans.
    pub fn to_blocks(&self, k: usize) -> CodeBlocks {
        CodeBlocks::build(&self.codes, &[], self.n_subspaces, k)
    }
}

/// Analytic memory model (paper §3.4), in bits, assuming the paper's
/// single-precision storage convention.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MemoryModel {
    /// Bits per original series (`32·D`).
    pub raw_bits_per_series: u64,
    /// Bits per PQ code (`M·ceil(log2 K)`).
    pub code_bits_per_series: u64,
    /// Compression factor `raw / code`.
    pub compression_factor: f64,
    /// Codebook storage (`32·M·K·L` bits).
    pub codebook_bits: u64,
    /// Distance LUT storage (`32·K²·M` bits).
    pub lut_bits: u64,
    /// Envelope storage (`2·32·M·K·L` bits).
    pub envelope_bits: u64,
}

impl MemoryModel {
    /// Total auxiliary (non-data) bits.
    pub fn aux_bits(&self) -> u64 {
        self.codebook_bits + self.lut_bits + self.envelope_bits
    }
}

/// A trained product quantizer (PQDTW or PQ_ED).
#[derive(Debug, Clone)]
pub struct ProductQuantizer {
    /// Training configuration.
    pub config: PqConfig,
    /// Subspace segmenter (fixed or pre-aligned).
    pub segmenter: Segmenter,
    /// Trained codebook with envelopes + LUT.
    pub codebook: Codebook,
    /// Series length the quantizer was trained for.
    pub series_len: usize,
}

impl ProductQuantizer {
    /// Train on `data` (Algorithm 1). `seed` drives k-means seeding and
    /// the optional training subsample.
    pub fn train(data: &Dataset, cfg: &PqConfig, seed: u64) -> Result<Self> {
        if data.n_series() == 0 {
            bail!("PQ training requires a non-empty dataset");
        }
        if cfg.n_subspaces == 0 {
            bail!("n_subspaces must be >= 1");
        }
        if data.len < 2 * cfg.n_subspaces {
            bail!(
                "series length {} too short for {} subspaces",
                data.len,
                cfg.n_subspaces
            );
        }
        let mut rng = Rng::new(seed);

        // Optional training subsample.
        let train: Dataset = match cfg.train_subsample {
            Some(cap) if cap < data.n_series() => {
                let idx = rng.sample_indices(data.n_series(), cap);
                data.subset(&idx)
            }
            _ => data.clone(),
        };

        let sub_len_base = data.len.div_ceil(cfg.n_subspaces);
        let tail = match cfg.prealign {
            Some(p) => ((p.tail_frac * sub_len_base as f64).round() as usize)
                .min(sub_len_base.saturating_sub(1)),
            None => 0,
        };
        let segmenter = match cfg.prealign {
            Some(p) if tail > 0 => Segmenter::prealigned(cfg.n_subspaces, p.level, tail),
            _ => Segmenter::fixed(cfg.n_subspaces),
        };
        let sub_len = segmenter.sub_len(data.len);
        let window = if cfg.window_frac >= 1.0 {
            None
        } else {
            Some(((cfg.window_frac * sub_len as f64).ceil() as usize).max(1))
        };

        // Segment all training series once: per-subspace row matrices.
        let n = train.n_series();
        let k = cfg.codebook_size.min(n);
        let mut per_subspace_rows: Vec<Vec<Vec<f64>>> =
            vec![Vec::with_capacity(n); cfg.n_subspaces];
        for i in 0..n {
            let segs = segmenter.segment(train.row(i));
            for (m, s) in segs.into_iter().enumerate() {
                per_subspace_rows[m].push(s);
            }
        }

        // DBA-k-means per subspace (Algorithm 1 main loop).
        let geo = match cfg.metric {
            PqMetric::Dtw => KmeansGeometry::Dtw { window, dba_iters: cfg.dba_iters },
            PqMetric::Euclidean => KmeansGeometry::Euclidean,
        };
        let mut per_subspace_centroids = Vec::with_capacity(cfg.n_subspaces);
        for rows in &per_subspace_rows {
            let refs: Vec<&[f64]> = rows.iter().map(|r| r.as_slice()).collect();
            let res = kmeans(&refs, k, geo, cfg.kmeans_iters, &mut rng);
            per_subspace_centroids.push(res.centroids);
        }

        let codebook = Codebook::build(per_subspace_centroids, sub_len, window, cfg.metric);
        Ok(ProductQuantizer { config: *cfg, segmenter, codebook, series_len: data.len })
    }

    /// Cut a series into subspace vectors using the trained segmenter.
    pub fn segment(&self, x: &[f64]) -> Vec<Vec<f64>> {
        self.segmenter.segment(x)
    }

    /// Encode one series (Algorithm 2). Returns the code word, the
    /// per-subspace squared self bounds and the work counters.
    pub fn encode(&self, x: &[f64]) -> (Vec<u16>, Vec<f64>, EncodeStats) {
        assert_eq!(x.len(), self.series_len, "series length mismatch");
        let subs = self.segment(x);
        let mut scratch = DtwScratch::new(self.codebook.sub_len);
        let mut stats = EncodeStats::default();
        let mut codes = Vec::with_capacity(self.config.n_subspaces);
        let mut lbs = Vec::with_capacity(self.config.n_subspaces);
        for (m, q) in subs.iter().enumerate() {
            let out = encode_subspace(q, m, &self.codebook, &mut scratch, &mut stats);
            codes.push(out.code);
            lbs.push(out.lb_self_sq);
        }
        (codes, lbs, stats)
    }

    /// Encode a whole dataset.
    pub fn encode_dataset(&self, data: &Dataset) -> EncodedDataset {
        let n = data.n_series();
        let m = self.config.n_subspaces;
        let mut codes = Vec::with_capacity(n * m);
        let mut lb = Vec::with_capacity(n * m);
        let mut stats = EncodeStats::default();
        for i in 0..n {
            let (c, l, s) = self.encode(data.row(i));
            codes.extend_from_slice(&c);
            lb.extend_from_slice(&l);
            stats.merge(&s);
        }
        EncodedDataset {
            codes,
            lb_self_sq: lb,
            n_subspaces: m,
            labels: data.labels.clone(),
            stats,
        }
    }

    /// Symmetric PQ distance between two code words.
    pub fn symmetric_distance(&self, cx: &[u16], cy: &[u16]) -> f64 {
        pqdist::symmetric(&self.codebook, cx, cy)
    }

    /// Keogh-patched symmetric distance between encoded items `i`, `j`.
    pub fn patched_distance(&self, enc: &EncodedDataset, i: usize, j: usize) -> f64 {
        pqdist::patched_symmetric(
            &self.codebook,
            enc.code(i),
            enc.code(j),
            enc.lb_self(i),
            enc.lb_self(j),
        )
    }

    /// Asymmetric distance table for a raw query (`M×K` squared entries).
    pub fn asymmetric_table(&self, y: &[f64]) -> Vec<f64> {
        pqdist::asymmetric_table(&self.codebook, &self.segment(y))
    }

    /// Asymmetric distance of an encoded item against a query table.
    pub fn asymmetric_distance(&self, table: &[f64], codes: &[u16]) -> f64 {
        pqdist::asymmetric_sq(&self.codebook, table, codes).sqrt()
    }

    /// The paper's §3.4 memory model for this quantizer.
    pub fn memory_model(&self) -> MemoryModel {
        let d = self.series_len as u64;
        let m = self.config.n_subspaces as u64;
        let k = self.codebook.k as u64;
        let l = self.codebook.sub_len as u64;
        let code_bits = m * (64 - (k.max(2) - 1).leading_zeros() as u64).max(1);
        let raw_bits = 32 * d;
        MemoryModel {
            raw_bits_per_series: raw_bits,
            code_bits_per_series: code_bits,
            compression_factor: raw_bits as f64 / code_bits as f64,
            codebook_bits: 32 * m * k * l,
            lut_bits: 32 * k * k * m,
            envelope_bits: 2 * 32 * m * k * l,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::random_walk::RandomWalks;

    fn train_toy(metric: PqMetric, prealign: Option<PrealignConfig>) -> (ProductQuantizer, Dataset) {
        let data = RandomWalks::new(31).generate(40, 64);
        let cfg = PqConfig {
            n_subspaces: 4,
            codebook_size: 8,
            window_frac: 0.2,
            metric,
            prealign,
            kmeans_iters: 5,
            dba_iters: 2,
            train_subsample: None,
        };
        (ProductQuantizer::train(&data, &cfg, 3).unwrap(), data)
    }

    #[test]
    fn train_encode_roundtrip() {
        let (pq, data) = train_toy(PqMetric::Dtw, None);
        assert_eq!(pq.codebook.k, 8);
        assert_eq!(pq.codebook.sub_len, 16);
        let enc = pq.encode_dataset(&data);
        assert_eq!(enc.n(), 40);
        assert!(enc.codes.iter().all(|&c| (c as usize) < 8));
    }

    #[test]
    fn symmetric_self_distance_zero() {
        let (pq, data) = train_toy(PqMetric::Dtw, None);
        let enc = pq.encode_dataset(&data);
        for i in [0usize, 7, 23] {
            assert_eq!(pq.symmetric_distance(enc.code(i), enc.code(i)), 0.0);
        }
    }

    #[test]
    fn patched_ge_symmetric() {
        let (pq, data) = train_toy(PqMetric::Dtw, None);
        let enc = pq.encode_dataset(&data);
        for i in 0..10 {
            for j in (i + 1)..10 {
                let s = pq.symmetric_distance(enc.code(i), enc.code(j));
                let p = pq.patched_distance(&enc, i, j);
                assert!(p >= s - 1e-12, "patched {p} < symmetric {s}");
            }
        }
    }

    #[test]
    fn asymmetric_consistent_with_encoding() {
        let (pq, data) = train_toy(PqMetric::Dtw, None);
        let enc = pq.encode_dataset(&data);
        // The asymmetric distance from x to its own code must equal the
        // encode-time distance (same table cells).
        let x = data.row(5);
        let table = pq.asymmetric_table(x);
        let d = pq.asymmetric_distance(&table, enc.code(5));
        // d² = Σ_m dist_sq(x^m, chosen centroid) = Σ encode dist
        let (_, _, _) = pq.encode(x);
        let subs = pq.segment(x);
        let want: f64 = subs
            .iter()
            .enumerate()
            .map(|(m, q)| {
                crate::distance::dtw::dtw_sq(
                    q,
                    pq.codebook.centroid(m, enc.code(5)[m] as usize),
                    pq.codebook.window,
                )
            })
            .sum::<f64>()
            .sqrt();
        assert!((d - want).abs() < 1e-9);
    }

    #[test]
    fn prealigned_variant_trains() {
        let (pq, data) = train_toy(PqMetric::Dtw, Some(PrealignConfig { level: 2, tail_frac: 0.2 }));
        assert!(pq.segmenter.tail > 0);
        assert_eq!(pq.codebook.sub_len, 16 + pq.segmenter.tail);
        let enc = pq.encode_dataset(&data);
        assert_eq!(enc.n(), 40);
    }

    #[test]
    fn pq_ed_variant_trains() {
        let (pq, data) = train_toy(PqMetric::Euclidean, None);
        assert!(pq.codebook.envelopes.is_empty());
        let enc = pq.encode_dataset(&data);
        assert_eq!(enc.n(), 40);
        // ED encoding never records keogh bounds
        assert!(enc.lb_self_sq.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn codebook_size_clamped_to_n() {
        let data = RandomWalks::new(5).generate(6, 32);
        let cfg = PqConfig { n_subspaces: 2, codebook_size: 256, ..Default::default() };
        let pq = ProductQuantizer::train(&data, &cfg, 1).unwrap();
        assert_eq!(pq.codebook.k, 6);
    }

    #[test]
    fn memory_model_matches_paper_example() {
        // Paper §3.4: D=140, K=256, M=7 → compression 80×, aux ≈ 2.3 MB.
        let data = RandomWalks::new(9).generate(300, 140);
        let cfg = PqConfig {
            n_subspaces: 7,
            codebook_size: 256,
            train_subsample: Some(256),
            ..Default::default()
        };
        let pq = ProductQuantizer::train(&data, &cfg, 1).unwrap();
        let mm = pq.memory_model();
        assert_eq!(mm.code_bits_per_series, 7 * 8);
        assert!((mm.compression_factor - 80.0).abs() < 1e-9);
        // aux total: paper says ~2.3 MB with L = D/M = 20
        let mb = mm.aux_bits() as f64 / 8.0 / 1024.0 / 1024.0;
        assert!(mb > 1.5 && mb < 3.5, "aux = {mb} MB");
    }

    #[test]
    fn errors_on_bad_config() {
        let data = RandomWalks::new(2).generate(4, 16);
        let cfg = PqConfig { n_subspaces: 0, ..Default::default() };
        assert!(ProductQuantizer::train(&data, &cfg, 1).is_err());
        let cfg = PqConfig { n_subspaces: 12, ..Default::default() };
        assert!(ProductQuantizer::train(&data, &cfg, 1).is_err());
    }

    #[test]
    fn deterministic_training() {
        let data = RandomWalks::new(77).generate(20, 48);
        let cfg = PqConfig { n_subspaces: 3, codebook_size: 6, ..Default::default() };
        let a = ProductQuantizer::train(&data, &cfg, 42).unwrap();
        let b = ProductQuantizer::train(&data, &cfg, 42).unwrap();
        assert_eq!(a.codebook.centroids, b.codebook.centroids);
    }
}
