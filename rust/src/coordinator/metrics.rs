//! Lock-free service metrics: request counters, latency histogram and
//! batch-size accounting.

use std::sync::atomic::{AtomicU64, Ordering};

/// Log-spaced latency buckets in microseconds (upper bounds).
const BUCKETS_US: [u64; 12] =
    [10, 25, 50, 100, 250, 500, 1_000, 2_500, 5_000, 10_000, 50_000, u64::MAX];

/// Concurrent metrics sink.
#[derive(Debug, Default)]
pub struct Metrics {
    requests: AtomicU64,
    errors: AtomicU64,
    batches: AtomicU64,
    batched_items: AtomicU64,
    latency_sum_us: AtomicU64,
    latency_buckets: [AtomicU64; 12],
}

/// A point-in-time copy of the metrics.
#[derive(Debug, Clone, PartialEq)]
pub struct MetricsSnapshot {
    /// Total requests served.
    pub requests: u64,
    /// Requests that returned an error.
    pub errors: u64,
    /// Batches executed.
    pub batches: u64,
    /// Mean batch size.
    pub mean_batch_size: f64,
    /// Mean latency (µs).
    pub mean_latency_us: f64,
    /// Latency histogram (bucket upper bound µs, count).
    pub histogram: Vec<(u64, u64)>,
}

impl MetricsSnapshot {
    /// Approximate latency percentile (µs) from the histogram (upper
    /// bound of the bucket containing the percentile).
    pub fn percentile_us(&self, p: f64) -> u64 {
        let total: u64 = self.histogram.iter().map(|(_, c)| c).sum();
        if total == 0 {
            return 0;
        }
        let target = (p * total as f64).ceil() as u64;
        let mut acc = 0;
        for &(ub, c) in &self.histogram {
            acc += c;
            if acc >= target {
                return ub;
            }
        }
        u64::MAX
    }
}

impl Metrics {
    /// New zeroed metrics.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one served request with its latency.
    pub fn record_request(&self, latency_us: u64, is_error: bool) {
        self.requests.fetch_add(1, Ordering::Relaxed);
        if is_error {
            self.errors.fetch_add(1, Ordering::Relaxed);
        }
        self.latency_sum_us.fetch_add(latency_us, Ordering::Relaxed);
        let idx = BUCKETS_US.iter().position(|&ub| latency_us <= ub).unwrap();
        self.latency_buckets[idx].fetch_add(1, Ordering::Relaxed);
    }

    /// Record one executed batch of `size` items.
    pub fn record_batch(&self, size: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_items.fetch_add(size as u64, Ordering::Relaxed);
    }

    /// Take a snapshot.
    pub fn snapshot(&self) -> MetricsSnapshot {
        let requests = self.requests.load(Ordering::Relaxed);
        let batches = self.batches.load(Ordering::Relaxed);
        let items = self.batched_items.load(Ordering::Relaxed);
        let lat_sum = self.latency_sum_us.load(Ordering::Relaxed);
        MetricsSnapshot {
            requests,
            errors: self.errors.load(Ordering::Relaxed),
            batches,
            mean_batch_size: if batches > 0 { items as f64 / batches as f64 } else { 0.0 },
            mean_latency_us: if requests > 0 { lat_sum as f64 / requests as f64 } else { 0.0 },
            histogram: BUCKETS_US
                .iter()
                .zip(self.latency_buckets.iter())
                .map(|(&ub, c)| (ub, c.load(Ordering::Relaxed)))
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_snapshots() {
        let m = Metrics::new();
        m.record_request(30, false);
        m.record_request(700, true);
        m.record_batch(2);
        let s = m.snapshot();
        assert_eq!(s.requests, 2);
        assert_eq!(s.errors, 1);
        assert_eq!(s.batches, 1);
        assert_eq!(s.mean_batch_size, 2.0);
        assert!((s.mean_latency_us - 365.0).abs() < 1e-9);
        // 30µs lands in the ≤50 bucket, 700µs in ≤1000
        assert_eq!(s.histogram[2].1, 1);
        assert_eq!(s.histogram[6].1, 1);
    }

    #[test]
    fn percentiles_from_histogram() {
        let m = Metrics::new();
        for _ in 0..99 {
            m.record_request(20, false);
        }
        m.record_request(40_000, false);
        let s = m.snapshot();
        assert_eq!(s.percentile_us(0.5), 25);
        assert_eq!(s.percentile_us(0.999), 50_000);
    }

    #[test]
    fn concurrent_updates() {
        use std::sync::Arc;
        let m = Arc::new(Metrics::new());
        let mut hs = Vec::new();
        for _ in 0..4 {
            let m = Arc::clone(&m);
            hs.push(std::thread::spawn(move || {
                for _ in 0..1000 {
                    m.record_request(100, false);
                }
            }));
        }
        for h in hs {
            h.join().unwrap();
        }
        assert_eq!(m.snapshot().requests, 4000);
    }
}
