//! Lock-free service metrics: request counters, latency histogram,
//! batch-size accounting, per-request-class (serving mode) latency
//! counters so the recall/latency dial of the top-k path is observable,
//! and per-query-stage latency histograms (`lut_collapse` /
//! `coarse_probe` / `blocked_scan` / `rerank`) fed by the engine's
//! stage ladder. All histograms share the same log-spaced buckets and
//! can be rendered in Prometheus text exposition format
//! ([`Metrics::render_prometheus`]).

use std::sync::atomic::{AtomicU64, Ordering};

use crate::obs::prometheus::PromText;
use crate::obs::{Stage, N_STAGES};

/// Log-spaced latency buckets in microseconds (upper bounds). Public
/// because the bucket bounds are part of the metrics-federation
/// contract: `StatsResult` carries raw per-bucket counts aligned with
/// this array, and the router merges fleets bucket-wise over it.
pub const BUCKETS_US: [u64; 12] =
    [10, 25, 50, 100, 250, 500, 1_000, 2_500, 5_000, 10_000, 50_000, u64::MAX];

/// Request families tracked with separate throughput/latency counters.
/// The three top-k classes are the serving modes of the recall/latency
/// dial: exhaustive scan, IVF-probed, and DTW re-ranked. `Ping` and
/// `Stats` are served by the network plane without touching the engine
/// but share the same sink so a remote `stats` call sees all traffic.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RequestClass {
    /// Encode a raw series into a code word.
    Encode,
    /// 1-NN query (linear or probed).
    Nn,
    /// Pairwise distance between database items.
    PairDist,
    /// Top-k via exhaustive (possibly sharded) scan.
    TopKExhaustive,
    /// Top-k via IVF cell probing.
    TopKProbed,
    /// Top-k with an exact DTW re-rank stage (probed or exhaustive).
    TopKReranked,
    /// Liveness ping answered by the network plane.
    Ping,
    /// Metrics snapshot served by the network plane.
    Stats,
    /// Job-plane control frame (submit/status/events/cancel/result)
    /// answered by the network plane via the job manager.
    JobControl,
}

/// Number of tracked request classes.
pub const N_REQUEST_CLASSES: usize = 9;

impl RequestClass {
    /// All classes, index-aligned with the per-class metric arrays.
    pub const ALL: [RequestClass; N_REQUEST_CLASSES] = [
        RequestClass::Encode,
        RequestClass::Nn,
        RequestClass::PairDist,
        RequestClass::TopKExhaustive,
        RequestClass::TopKProbed,
        RequestClass::TopKReranked,
        RequestClass::Ping,
        RequestClass::Stats,
        RequestClass::JobControl,
    ];

    /// Stable display name.
    pub fn name(self) -> &'static str {
        match self {
            RequestClass::Encode => "encode",
            RequestClass::Nn => "nn",
            RequestClass::PairDist => "pair_dist",
            RequestClass::TopKExhaustive => "topk_exhaustive",
            RequestClass::TopKProbed => "topk_probed",
            RequestClass::TopKReranked => "topk_reranked",
            RequestClass::Ping => "ping",
            RequestClass::Stats => "stats",
            RequestClass::JobControl => "job_control",
        }
    }

    #[inline]
    fn idx(self) -> usize {
        match self {
            RequestClass::Encode => 0,
            RequestClass::Nn => 1,
            RequestClass::PairDist => 2,
            RequestClass::TopKExhaustive => 3,
            RequestClass::TopKProbed => 4,
            RequestClass::TopKReranked => 5,
            RequestClass::Ping => 6,
            RequestClass::Stats => 7,
            RequestClass::JobControl => 8,
        }
    }
}

/// Concurrent metrics sink.
#[derive(Debug, Default)]
pub struct Metrics {
    requests: AtomicU64,
    errors: AtomicU64,
    batches: AtomicU64,
    batched_items: AtomicU64,
    latency_sum_us: AtomicU64,
    latency_buckets: [AtomicU64; 12],
    class_requests: [AtomicU64; N_REQUEST_CLASSES],
    class_latency_us: [AtomicU64; N_REQUEST_CLASSES],
    class_latency_buckets: [[AtomicU64; 12]; N_REQUEST_CLASSES],
    stage_count: [AtomicU64; N_STAGES],
    stage_latency_us: [AtomicU64; N_STAGES],
    stage_latency_buckets: [[AtomicU64; 12]; N_STAGES],
    slow_queries: AtomicU64,
}

/// Approximate percentile over a `(bucket upper bound µs, count)`
/// histogram: the upper bound of the bucket containing the percentile.
/// `p = 0.0` lands on the first non-empty bucket, `p = 1.0` on the last
/// non-empty one; an empty histogram reports `0`. Public because the
/// router computes fleet percentiles from bucket-wise-merged histograms
/// with exactly this function, so routed and single-node percentiles
/// share one definition.
pub fn histogram_percentile(hist: &[(u64, u64)], p: f64) -> u64 {
    let total: u64 = hist.iter().map(|(_, c)| c).sum();
    if total == 0 {
        return 0;
    }
    let target = ((p * total as f64).ceil() as u64).clamp(1, total);
    let mut acc = 0;
    for &(ub, c) in hist {
        acc += c;
        if acc >= target {
            return ub;
        }
    }
    u64::MAX
}

/// Per-class slice of a [`MetricsSnapshot`].
#[derive(Debug, Clone, PartialEq)]
pub struct ClassSnapshot {
    /// The request class.
    pub class: RequestClass,
    /// Requests served in this class.
    pub requests: u64,
    /// Mean latency (µs) within the class.
    pub mean_latency_us: f64,
    /// Median latency (µs) within the class (histogram upper bound).
    pub p50_us: u64,
    /// 99th-percentile latency (µs) within the class (histogram upper
    /// bound).
    pub p99_us: u64,
    /// Raw latency histogram (bucket upper bound µs, count), aligned
    /// with [`BUCKETS_US`] — the lossless federation payload.
    pub histogram: Vec<(u64, u64)>,
}

/// Per-query-stage slice of a [`MetricsSnapshot`] (same shape as
/// [`ClassSnapshot`], keyed by ladder stage instead of request class).
#[derive(Debug, Clone, PartialEq)]
pub struct StageSnapshot {
    /// The query ladder stage.
    pub stage: Stage,
    /// Spans recorded for this stage.
    pub count: u64,
    /// Mean span wall-time (µs).
    pub mean_us: f64,
    /// Median span wall-time (µs, histogram upper bound).
    pub p50_us: u64,
    /// 99th-percentile span wall-time (µs, histogram upper bound).
    pub p99_us: u64,
    /// Raw span-latency histogram (bucket upper bound µs, count),
    /// aligned with [`BUCKETS_US`].
    pub histogram: Vec<(u64, u64)>,
}

/// A point-in-time copy of the metrics.
#[derive(Debug, Clone, PartialEq)]
pub struct MetricsSnapshot {
    /// Total requests served.
    pub requests: u64,
    /// Requests that returned an error.
    pub errors: u64,
    /// Batches executed.
    pub batches: u64,
    /// Mean batch size.
    pub mean_batch_size: f64,
    /// Mean latency (µs).
    pub mean_latency_us: f64,
    /// Latency histogram (bucket upper bound µs, count).
    pub histogram: Vec<(u64, u64)>,
    /// Per-request-class counters, index-aligned with
    /// [`RequestClass::ALL`].
    pub per_class: Vec<ClassSnapshot>,
    /// Per-query-stage counters, index-aligned with [`Stage::ALL`].
    pub per_stage: Vec<StageSnapshot>,
}

impl MetricsSnapshot {
    /// Approximate latency percentile (µs) from the histogram (upper
    /// bound of the bucket containing the percentile). `p = 0.0` is the
    /// first non-empty bucket, `p = 1.0` the last non-empty one; an
    /// empty histogram reports `0`.
    pub fn percentile_us(&self, p: f64) -> u64 {
        histogram_percentile(&self.histogram, p)
    }

    /// Counters for one request class.
    pub fn class(&self, class: RequestClass) -> ClassSnapshot {
        self.per_class[class.idx()].clone()
    }

    /// Counters for one query ladder stage.
    pub fn stage(&self, stage: Stage) -> StageSnapshot {
        self.per_stage[stage.index()].clone()
    }
}

impl Metrics {
    /// New zeroed metrics.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one served request of the given class with its latency.
    pub fn record_request(&self, class: RequestClass, latency_us: u64, is_error: bool) {
        self.requests.fetch_add(1, Ordering::Relaxed);
        if is_error {
            self.errors.fetch_add(1, Ordering::Relaxed);
        }
        self.latency_sum_us.fetch_add(latency_us, Ordering::Relaxed);
        let idx = BUCKETS_US.iter().position(|&ub| latency_us <= ub).unwrap();
        self.latency_buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.class_requests[class.idx()].fetch_add(1, Ordering::Relaxed);
        self.class_latency_us[class.idx()].fetch_add(latency_us, Ordering::Relaxed);
        self.class_latency_buckets[class.idx()][idx].fetch_add(1, Ordering::Relaxed);
    }

    /// Record one executed batch of `size` items.
    pub fn record_batch(&self, size: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_items.fetch_add(size as u64, Ordering::Relaxed);
    }

    /// Record one query that crossed the configured slow-query
    /// threshold (`serve --slow-query-ms`).
    pub fn record_slow_query(&self) {
        self.slow_queries.fetch_add(1, Ordering::Relaxed);
    }

    /// Queries that crossed the slow-query threshold so far.
    pub fn slow_queries(&self) -> u64 {
        self.slow_queries.load(Ordering::Relaxed)
    }

    /// Record one query-stage span's wall-time, reusing the same
    /// log-spaced buckets as the request latency histograms.
    pub fn record_stage(&self, stage: Stage, wall_us: u64) {
        let idx = BUCKETS_US.iter().position(|&ub| wall_us <= ub).unwrap();
        let s = stage.index();
        self.stage_count[s].fetch_add(1, Ordering::Relaxed);
        self.stage_latency_us[s].fetch_add(wall_us, Ordering::Relaxed);
        self.stage_latency_buckets[s][idx].fetch_add(1, Ordering::Relaxed);
    }

    /// Take a snapshot.
    pub fn snapshot(&self) -> MetricsSnapshot {
        let requests = self.requests.load(Ordering::Relaxed);
        let batches = self.batches.load(Ordering::Relaxed);
        let items = self.batched_items.load(Ordering::Relaxed);
        let lat_sum = self.latency_sum_us.load(Ordering::Relaxed);
        let per_class = RequestClass::ALL
            .iter()
            .map(|&class| {
                let n = self.class_requests[class.idx()].load(Ordering::Relaxed);
                let lat = self.class_latency_us[class.idx()].load(Ordering::Relaxed);
                let hist: Vec<(u64, u64)> = BUCKETS_US
                    .iter()
                    .zip(self.class_latency_buckets[class.idx()].iter())
                    .map(|(&ub, c)| (ub, c.load(Ordering::Relaxed)))
                    .collect();
                ClassSnapshot {
                    class,
                    requests: n,
                    mean_latency_us: if n > 0 { lat as f64 / n as f64 } else { 0.0 },
                    p50_us: histogram_percentile(&hist, 0.5),
                    p99_us: histogram_percentile(&hist, 0.99),
                    histogram: hist,
                }
            })
            .collect();
        let per_stage = Stage::ALL
            .iter()
            .map(|&stage| {
                let n = self.stage_count[stage.index()].load(Ordering::Relaxed);
                let lat = self.stage_latency_us[stage.index()].load(Ordering::Relaxed);
                let hist: Vec<(u64, u64)> = BUCKETS_US
                    .iter()
                    .zip(self.stage_latency_buckets[stage.index()].iter())
                    .map(|(&ub, c)| (ub, c.load(Ordering::Relaxed)))
                    .collect();
                StageSnapshot {
                    stage,
                    count: n,
                    mean_us: if n > 0 { lat as f64 / n as f64 } else { 0.0 },
                    p50_us: histogram_percentile(&hist, 0.5),
                    p99_us: histogram_percentile(&hist, 0.99),
                    histogram: hist,
                }
            })
            .collect();
        MetricsSnapshot {
            requests,
            errors: self.errors.load(Ordering::Relaxed),
            batches,
            mean_batch_size: if batches > 0 { items as f64 / batches as f64 } else { 0.0 },
            mean_latency_us: if requests > 0 { lat_sum as f64 / requests as f64 } else { 0.0 },
            histogram: BUCKETS_US
                .iter()
                .zip(self.latency_buckets.iter())
                .map(|(&ub, c)| (ub, c.load(Ordering::Relaxed)))
                .collect(),
            per_class,
            per_stage,
        }
    }

    /// Render every counter and histogram into a Prometheus exposition
    /// builder: total counters, per-class request-latency histograms
    /// (`class` label), and per-stage span histograms (`stage` label).
    /// The caller layers process-level families (uptime, build/index
    /// info, scan counters) on top before finishing the document.
    pub fn render_prometheus(&self, p: &mut PromText) {
        p.counter("pqdtw_requests_total", self.requests.load(Ordering::Relaxed));
        p.counter("pqdtw_errors_total", self.errors.load(Ordering::Relaxed));
        p.counter("pqdtw_batches_total", self.batches.load(Ordering::Relaxed));
        p.counter(
            "pqdtw_batched_items_total",
            self.batched_items.load(Ordering::Relaxed),
        );
        p.counter("pqdtw_slow_queries_total", self.slow_queries.load(Ordering::Relaxed));
        p.family("pqdtw_request_latency_microseconds", "histogram");
        for &class in RequestClass::ALL.iter() {
            let hist: Vec<(u64, u64)> = BUCKETS_US
                .iter()
                .zip(self.class_latency_buckets[class.idx()].iter())
                .map(|(&ub, c)| (ub, c.load(Ordering::Relaxed)))
                .collect();
            let sum = self.class_latency_us[class.idx()].load(Ordering::Relaxed);
            p.histogram_series(
                "pqdtw_request_latency_microseconds",
                &[("class", class.name())],
                &hist,
                sum as f64,
            );
        }
        p.family("pqdtw_stage_latency_microseconds", "histogram");
        for stage in Stage::ALL {
            let hist: Vec<(u64, u64)> = BUCKETS_US
                .iter()
                .zip(self.stage_latency_buckets[stage.index()].iter())
                .map(|(&ub, c)| (ub, c.load(Ordering::Relaxed)))
                .collect();
            let sum = self.stage_latency_us[stage.index()].load(Ordering::Relaxed);
            p.histogram_series(
                "pqdtw_stage_latency_microseconds",
                &[("stage", stage.name())],
                &hist,
                sum as f64,
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_snapshots() {
        let m = Metrics::new();
        m.record_request(RequestClass::Nn, 30, false);
        m.record_request(RequestClass::TopKProbed, 700, true);
        m.record_batch(2);
        let s = m.snapshot();
        assert_eq!(s.requests, 2);
        assert_eq!(s.errors, 1);
        assert_eq!(s.batches, 1);
        assert_eq!(s.mean_batch_size, 2.0);
        assert!((s.mean_latency_us - 365.0).abs() < 1e-9);
        // 30µs lands in the ≤50 bucket, 700µs in ≤1000
        assert_eq!(s.histogram[2].1, 1);
        assert_eq!(s.histogram[6].1, 1);
    }

    #[test]
    fn per_class_latency_split() {
        let m = Metrics::new();
        m.record_request(RequestClass::TopKExhaustive, 100, false);
        m.record_request(RequestClass::TopKExhaustive, 300, false);
        m.record_request(RequestClass::TopKProbed, 10, false);
        let s = m.snapshot();
        let exh = s.class(RequestClass::TopKExhaustive);
        assert_eq!(exh.requests, 2);
        assert!((exh.mean_latency_us - 200.0).abs() < 1e-9);
        let probed = s.class(RequestClass::TopKProbed);
        assert_eq!(probed.requests, 1);
        assert!((probed.mean_latency_us - 10.0).abs() < 1e-9);
        assert_eq!(s.class(RequestClass::TopKReranked).requests, 0);
        assert_eq!(s.per_class.len(), N_REQUEST_CLASSES);
    }

    #[test]
    fn percentiles_from_histogram() {
        let m = Metrics::new();
        for _ in 0..99 {
            m.record_request(RequestClass::Nn, 20, false);
        }
        m.record_request(RequestClass::Nn, 40_000, false);
        let s = m.snapshot();
        assert_eq!(s.percentile_us(0.5), 25);
        assert_eq!(s.percentile_us(0.999), 50_000);
    }

    #[test]
    fn percentile_of_empty_histogram_is_zero() {
        let s = Metrics::new().snapshot();
        for p in [0.0, 0.5, 0.99, 1.0] {
            assert_eq!(s.percentile_us(p), 0, "p = {p}");
        }
        // per-class percentiles are zero too
        for c in &s.per_class {
            assert_eq!((c.p50_us, c.p99_us), (0, 0), "{:?}", c.class);
        }
    }

    #[test]
    fn percentile_extremes_land_on_non_empty_buckets() {
        let m = Metrics::new();
        m.record_request(RequestClass::Nn, 20, false); // ≤25 bucket
        m.record_request(RequestClass::Nn, 700, false); // ≤1000 bucket
        let s = m.snapshot();
        // p = 0.0 must be the first non-empty bucket, not histogram[0]
        assert_eq!(s.percentile_us(0.0), 25);
        // p = 1.0 must be the last non-empty bucket, not u64::MAX
        assert_eq!(s.percentile_us(1.0), 1_000);
    }

    #[test]
    fn percentile_with_all_counts_in_one_bucket() {
        let m = Metrics::new();
        for _ in 0..10 {
            m.record_request(RequestClass::TopKProbed, 60, false); // ≤100 bucket
        }
        let s = m.snapshot();
        for p in [0.0, 0.5, 0.99, 1.0] {
            assert_eq!(s.percentile_us(p), 100, "p = {p}");
        }
        let c = s.class(RequestClass::TopKProbed);
        assert_eq!((c.p50_us, c.p99_us), (100, 100));
    }

    #[test]
    fn per_class_percentiles_are_independent() {
        let m = Metrics::new();
        for _ in 0..98 {
            m.record_request(RequestClass::TopKExhaustive, 20, false);
        }
        m.record_request(RequestClass::TopKExhaustive, 40_000, false);
        m.record_request(RequestClass::TopKExhaustive, 40_000, false);
        m.record_request(RequestClass::Ping, 5, false);
        let s = m.snapshot();
        let exh = s.class(RequestClass::TopKExhaustive);
        // rank ⌈0.99·100⌉ = 99 falls past the 98 fast requests
        assert_eq!(exh.p50_us, 25);
        assert_eq!(exh.p99_us, 50_000);
        let ping = s.class(RequestClass::Ping);
        assert_eq!((ping.p50_us, ping.p99_us), (10, 10));
        assert_eq!(s.class(RequestClass::Stats).requests, 0);
    }

    #[test]
    fn stage_spans_reuse_the_latency_buckets() {
        let m = Metrics::new();
        for _ in 0..9 {
            m.record_stage(Stage::BlockedScan, 30);
        }
        m.record_stage(Stage::BlockedScan, 8_000);
        m.record_stage(Stage::Rerank, 400);
        let s = m.snapshot();
        assert_eq!(s.per_stage.len(), N_STAGES);
        let scan = s.stage(Stage::BlockedScan);
        assert_eq!(scan.count, 10);
        assert!((scan.mean_us - (9.0 * 30.0 + 8_000.0) / 10.0).abs() < 1e-9);
        assert_eq!(scan.p50_us, 50);
        assert_eq!(scan.p99_us, 10_000);
        let rr = s.stage(Stage::Rerank);
        assert_eq!((rr.count, rr.p50_us), (1, 500));
        assert_eq!(s.stage(Stage::LutCollapse).count, 0);
        // Stage spans do not perturb request counters.
        assert_eq!(s.requests, 0);
    }

    #[test]
    fn snapshots_retain_raw_bucket_counts() {
        let m = Metrics::new();
        m.record_request(RequestClass::Nn, 30, false); // ≤50 bucket
        m.record_request(RequestClass::Nn, 30, false);
        m.record_stage(Stage::Rerank, 400); // ≤500 bucket
        let s = m.snapshot();
        let nn = s.class(RequestClass::Nn);
        assert_eq!(nn.histogram.len(), BUCKETS_US.len());
        assert_eq!(nn.histogram[2], (50, 2));
        assert_eq!(nn.histogram.iter().map(|&(_, c)| c).sum::<u64>(), 2);
        let rr = s.stage(Stage::Rerank);
        assert_eq!(rr.histogram[5], (500, 1));
        // Bucket bounds mirror BUCKETS_US exactly, in order.
        for (got, want) in nn.histogram.iter().zip(BUCKETS_US.iter()) {
            assert_eq!(got.0, *want);
        }
    }

    #[test]
    fn slow_query_counter_accumulates_and_renders() {
        let m = Metrics::new();
        assert_eq!(m.slow_queries(), 0);
        m.record_slow_query();
        m.record_slow_query();
        assert_eq!(m.slow_queries(), 2);
        let mut p = PromText::new();
        m.render_prometheus(&mut p);
        let text = p.finish();
        assert!(text.contains("# TYPE pqdtw_slow_queries_total counter"));
        assert!(text.contains("pqdtw_slow_queries_total 2"));
    }

    #[test]
    fn prometheus_rendering_is_valid_exposition() {
        use crate::obs::prometheus::validate_exposition;
        let m = Metrics::new();
        m.record_request(RequestClass::TopKProbed, 120, false);
        m.record_request(RequestClass::Ping, 3, false);
        m.record_stage(Stage::BlockedScan, 90);
        let mut p = PromText::new();
        m.render_prometheus(&mut p);
        let text = p.finish();
        let samples = validate_exposition(&text).expect("valid exposition");
        assert!(samples > 0);
        assert!(text.contains("# TYPE pqdtw_requests_total counter"));
        assert!(text.contains("pqdtw_requests_total 2"));
        assert!(text.contains("class=\"topk_probed\""));
        assert!(text.contains("stage=\"blocked_scan\""));
        assert!(text
            .contains("pqdtw_request_latency_microseconds_count{class=\"topk_probed\"} 1"));
        assert!(text.contains("pqdtw_stage_latency_microseconds_sum{stage=\"blocked_scan\"} 90"));
        // The +Inf bucket closes every histogram series.
        assert_eq!(text.matches("le=\"+Inf\"").count(), N_REQUEST_CLASSES + N_STAGES);
    }

    #[test]
    fn concurrent_updates() {
        use std::sync::Arc;
        let m = Arc::new(Metrics::new());
        let mut hs = Vec::new();
        for _ in 0..4 {
            let m = Arc::clone(&m);
            hs.push(std::thread::spawn(move || {
                for _ in 0..1000 {
                    m.record_request(RequestClass::Encode, 100, false);
                }
            }));
        }
        for h in hs {
            h.join().unwrap();
        }
        let s = m.snapshot();
        assert_eq!(s.requests, 4000);
        assert_eq!(s.class(RequestClass::Encode).requests, 4000);
    }
}
