//! The request service: worker threads pull batches from the dynamic
//! batcher and execute them on the shared [`Engine`], answering through
//! per-request oneshot channels.

// rustc-side twin of the xtask no-panic-in-serving rule: serving code
// must propagate errors. Test code (crate-wide `cfg(test)` under
// `cargo test`) is exempt on purpose.
#![cfg_attr(not(test), warn(clippy::unwrap_used, clippy::expect_used))]

use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use super::batcher::{BatcherConfig, DynamicBatcher};
use super::engine::{Engine, Request, Response};
use super::metrics::{Metrics, MetricsSnapshot};

/// Service sizing.
#[derive(Debug, Clone, Copy)]
pub struct ServiceConfig {
    /// Worker threads.
    pub n_workers: usize,
    /// Batching policy.
    pub batcher: BatcherConfig,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig { n_workers: 2, batcher: BatcherConfig::default() }
    }
}

struct Job {
    request: Request,
    submitted: Instant,
    reply: mpsc::Sender<Response>,
}

/// A running similarity-search service. Cloneable handles are cheap
/// (everything shared is behind `Arc`).
pub struct Service {
    batcher: Arc<DynamicBatcher<Job>>,
    metrics: Arc<Metrics>,
    workers: Vec<JoinHandle<()>>,
}

impl Service {
    /// Start `cfg.n_workers` workers over a shared engine.
    pub fn start(engine: Arc<Engine>, cfg: ServiceConfig) -> Self {
        let batcher: Arc<DynamicBatcher<Job>> = Arc::new(DynamicBatcher::new(cfg.batcher));
        let metrics = Arc::new(Metrics::new());
        let mut workers = Vec::with_capacity(cfg.n_workers);
        for _ in 0..cfg.n_workers.max(1) {
            let batcher = Arc::clone(&batcher);
            let metrics = Arc::clone(&metrics);
            let engine = Arc::clone(&engine);
            workers.push(std::thread::spawn(move || {
                while let Some(batch) = batcher.next_batch() {
                    metrics.record_batch(batch.len());
                    for job in batch {
                        let class = job.request.class();
                        let resp = engine.handle(&job.request);
                        let is_err = matches!(resp, Response::Error(_));
                        let latency = job.submitted.elapsed().as_micros() as u64;
                        metrics.record_request(class, latency, is_err);
                        // Receiver may have given up; that's fine.
                        let _ = job.reply.send(resp);
                    }
                }
            }));
        }
        Service { batcher, metrics, workers }
    }

    /// Submit a request; returns a oneshot receiver for the response.
    /// `None` if the service is shutting down.
    pub fn submit(&self, request: Request) -> Option<mpsc::Receiver<Response>> {
        let (tx, rx) = mpsc::channel();
        let ok = self.batcher.push(Job { request, submitted: Instant::now(), reply: tx });
        ok.then_some(rx)
    }

    /// Convenience: submit and block for the response.
    pub fn call(&self, request: Request) -> Response {
        match self.submit(request) {
            Some(rx) => rx
                .recv()
                .unwrap_or_else(|_| Response::Error("worker dropped request".into())),
            None => Response::Error("service closed".into()),
        }
    }

    /// Current metrics.
    pub fn metrics(&self) -> MetricsSnapshot {
        self.metrics.snapshot()
    }

    /// Record a request served outside the engine path — e.g. the
    /// network plane's ping/stats frames — into the same metrics sink,
    /// so a remote `stats` call accounts for every request class.
    pub fn record_external(
        &self,
        class: super::metrics::RequestClass,
        latency_us: u64,
        is_error: bool,
    ) {
        self.metrics.record_request(class, latency_us, is_error);
    }

    /// Queue depth (backpressure signal).
    pub fn queue_depth(&self) -> usize {
        self.batcher.depth()
    }

    /// Drain and stop all workers.
    pub fn shutdown(mut self) -> MetricsSnapshot {
        self.batcher.close();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        self.metrics.snapshot()
    }
}

impl Drop for Service {
    fn drop(&mut self) {
        // Close *and join*: merely closing the batcher would let worker
        // threads race process exit, silently dropping in-flight
        // replies (`drop_delivers_in_flight_replies` is the regression
        // test). `shutdown()` drains `workers`, so a second pass here
        // is a no-op.
        self.batcher.close();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::ucr_like::ucr_like_by_name;
    use crate::nn::knn::PqQueryMode;
    use crate::pq::quantizer::PqConfig;

    fn toy_service(n_workers: usize) -> (Service, crate::core::series::Dataset) {
        let tt = ucr_like_by_name("SpikePosition", 43).unwrap();
        let cfg = PqConfig {
            n_subspaces: 4,
            codebook_size: 8,
            window_frac: 0.2,
            ..Default::default()
        };
        let engine = Arc::new(Engine::build(&tt.train, &cfg, 1).unwrap());
        let svc = Service::start(
            engine,
            ServiceConfig { n_workers, batcher: BatcherConfig::default() },
        );
        (svc, tt.test)
    }

    #[test]
    fn serves_blocking_calls() {
        let (svc, test) = toy_service(2);
        for i in 0..5 {
            match svc.call(Request::NnQuery {
                series: test.row(i).to_vec(),
                mode: PqQueryMode::Symmetric,
                nprobe: None,
            }) {
                Response::Nn { distance, .. } => assert!(distance.is_finite()),
                other => panic!("unexpected {other:?}"),
            }
        }
        let m = svc.shutdown();
        assert_eq!(m.requests, 5);
        assert_eq!(m.errors, 0);
        assert!(m.batches >= 1);
        assert_eq!(m.class(crate::coordinator::metrics::RequestClass::Nn).requests, 5);
    }

    #[test]
    fn concurrent_clients() {
        let (svc, test) = toy_service(3);
        let svc = Arc::new(svc);
        let test = Arc::new(test);
        let mut handles = Vec::new();
        for t in 0..4 {
            let svc = Arc::clone(&svc);
            let test = Arc::clone(&test);
            handles.push(std::thread::spawn(move || {
                for i in 0..8 {
                    let idx = (t * 8 + i) % test.n_series();
                    let r = svc.call(Request::Encode { series: test.row(idx).to_vec() });
                    assert!(matches!(r, Response::Codes(_)));
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let m = svc.metrics();
        assert_eq!(m.requests, 32);
    }

    #[test]
    fn error_requests_counted() {
        let (svc, _) = toy_service(1);
        let r = svc.call(Request::Encode { series: vec![1.0, 2.0] });
        assert!(matches!(r, Response::Error(_)));
        let m = svc.shutdown();
        assert_eq!(m.errors, 1);
    }

    #[test]
    fn drop_delivers_in_flight_replies() {
        // Teardown regression: dropping the service must close the
        // batcher AND join the workers, so every request submitted
        // before the drop still gets its reply (workers drain the queue
        // before exiting). Without the joins, replies race process
        // teardown and are silently lost.
        let (svc, test) = toy_service(2);
        let mut pending = Vec::new();
        for i in 0..6 {
            let rx = svc
                .submit(Request::Encode { series: test.row(i).to_vec() })
                .expect("service accepts requests before drop");
            pending.push(rx);
        }
        drop(svc);
        for (i, rx) in pending.into_iter().enumerate() {
            let resp = rx.recv().unwrap_or_else(|_| {
                panic!("request {i}: reply dropped — workers not joined on drop")
            });
            assert!(matches!(resp, Response::Codes(_)), "request {i}: {resp:?}");
        }
    }

    #[test]
    fn external_requests_share_the_metrics_sink() {
        let (svc, _) = toy_service(1);
        svc.record_external(crate::coordinator::metrics::RequestClass::Ping, 3, false);
        let m = svc.shutdown();
        assert_eq!(m.requests, 1);
        assert_eq!(m.class(crate::coordinator::metrics::RequestClass::Ping).requests, 1);
    }

    #[test]
    fn shutdown_rejects_new_requests() {
        let (svc, test) = toy_service(1);
        let q = test.row(0).to_vec();
        let m = svc.shutdown();
        assert_eq!(m.errors, 0);
        // new service needed after shutdown — check a fresh one works
        let (svc2, _) = toy_service(1);
        assert!(matches!(svc2.call(Request::Encode { series: q }), Response::Codes(_)));
    }
}
